"""Shared fixtures/helpers for the FADiff python test suite."""

import sys
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np

from compile import constants as C


def divisors(n, k_max=C.K_MAX):
    """Divisor candidates of n, log-subsampled to k_max (mirrors Rust)."""
    ds = [j for j in range(1, n + 1) if n % j == 0]
    if len(ds) <= k_max:
        return ds
    # keep 1 and n, evenly subsample the interior by index
    idx = np.unique(np.round(np.linspace(0, len(ds) - 1, k_max)).astype(int))
    return [ds[i] for i in idx]


def divisor_tables(dims, k_max=C.K_MAX):
    """Build padded [L,7,K] divisor/mask tables for a dims array."""
    l = dims.shape[0]
    div = np.ones((l, 7, k_max), np.float32)
    mask = np.zeros((l, 7, k_max), np.float32)
    for i in range(l):
        for d in range(7):
            ds = divisors(int(dims[i, d]), k_max)
            div[i, d, :len(ds)] = ds
            mask[i, d, :len(ds)] = 1.0
    return div, mask


def hw_vector(pe_rows=32, pe_cols=32, l1_kb=64, l2_kb=512,
              bw3=16, bw2=64, bw1=64,
              epa3=100.0, epa2=2.6, epa1=1.06, epa0=0.05,
              epo=0.3, eb=2.0):
    hw = np.zeros(C.NHW, np.float32)
    hw[C.HW_PE_ROWS] = pe_rows
    hw[C.HW_PE_COLS] = pe_cols
    hw[C.HW_C1] = l1_kb * 1024
    hw[C.HW_C2] = l2_kb * 1024
    hw[C.HW_BW3] = bw3
    hw[C.HW_BW2] = bw2
    hw[C.HW_BW1] = bw1
    hw[C.HW_EPA3] = epa3
    hw[C.HW_EPA2] = epa2
    hw[C.HW_EPA1] = epa1
    hw[C.HW_EPA0] = epa0
    hw[C.HW_EPO] = epo
    hw[C.HW_EB] = eb
    return hw


def conv_chain(l_total=C.L_MAX):
    """A small VGG-ish conv chain padded to l_total; returns dims, masks."""
    layers = [
        [1, 64, 3, 224, 224, 3, 3],
        [1, 64, 64, 224, 224, 3, 3],
        [1, 128, 64, 112, 112, 3, 3],
        [1, 128, 128, 112, 112, 3, 3],
    ]
    dims = np.ones((l_total, 7), np.float32)
    dims[:len(layers)] = np.asarray(layers, np.float32)
    lmask = np.zeros(l_total, np.float32)
    lmask[:len(layers)] = 1.0
    emask = np.zeros(l_total, np.float32)
    emask[:len(layers) - 1] = 1.0
    return dims, lmask, emask
