"""AOT pipeline invariants: manifest consistency, HLO text properties,
and the exact input ordering contract the Rust runtime depends on."""

import json
import os

import jax
import numpy as np
import pytest

from compile import aot, constants as C


def test_grad_specs_order_matches_rust_contract():
    """The Rust GradientConfig stages inputs in this exact order."""
    names = [n for n, _ in aot.grad_specs()]
    assert names == [
        "theta", "sigma_logit", "dims", "div", "div_mask", "layer_mask",
        "edge_mask", "gumbel", "tau", "alpha", "lam", "hw",
    ]


def test_spec_shapes_consistent_with_constants():
    specs = dict(aot.grad_specs())
    assert specs["theta"].shape == (C.L_MAX, 7, 4)
    assert specs["div"].shape == (C.L_MAX, 7, C.K_MAX)
    assert specs["gumbel"].shape == (C.L_MAX, 7, 4, C.K_MAX)
    assert specs["hw"].shape == (C.NHW,)
    especs = dict(aot.eval_specs())
    assert especs["factors"].shape == (C.B_EVAL, C.L_MAX, 7, 4)


def test_all_grad_inputs_are_live():
    """jax.jit silently DROPS unused arguments from the lowered HLO; an
    unused input would desynchronize the Rust operand order. Lower the
    loss and check the parameter count survives."""
    from compile import model

    import re

    specs = [s for _, s in aot.grad_specs()]
    lowered = jax.jit(model.loss_and_grad).lower(*specs)
    text = lowered.as_text()
    sig = re.search(r"func\.func public @main\((.*?)\)\s*->", text,
                    re.S).group(1)
    assert sig.count("tensor<") == len(specs), (
        "an input was dead-code-eliminated; Rust operand order would break"
    )


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "..", "..",
                                    "artifacts", "manifest.json")),
    reason="artifacts not built")
def test_manifest_matches_generated_files():
    root = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    with open(os.path.join(root, "manifest.json")) as f:
        m = json.load(f)
    assert m["l_max"] == C.L_MAX
    assert m["k_max"] == C.K_MAX
    assert m["b_eval"] == C.B_EVAL
    for name, spec in m["artifacts"].items():
        path = os.path.join(root, spec["file"])
        assert os.path.exists(path), f"{name} file missing"
        text = open(path).read()
        assert text.startswith("HloModule"), f"{name} is not HLO text"
        assert len(text) > 1000
        # input element counts are positive and match shapes
        for t in spec["inputs"]:
            assert int(np.prod(t["shape"]) if t["shape"] else 1) >= 1


def test_to_hlo_text_roundtrip_small_fn():
    """The HLO-text interchange path works for an arbitrary function."""
    import jax.numpy as jnp

    def f(x, y):
        return (x @ y + 1.0,)

    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    text = aot.to_hlo_text(f, [("x", spec), ("y", spec)])
    assert text.startswith("HloModule")
    assert "f32[4,4]" in text
