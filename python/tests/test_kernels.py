"""L1 kernel correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes and value regimes; every property asserts
allclose between kernel and `ref.py`. These are the tests that certify
what actually gets lowered into the AOT artifacts.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import constants as C
from compile.kernels import gumbel_snap, traffic
from compile.kernels.ad import gumbel_snap_ad, traffic_ad
from compile.kernels.ref import ref_gumbel_snap, ref_traffic

from .conftest import divisor_tables

LB = 8  # kernel layer-block; L must be a multiple

DIM_POOL = [1, 2, 3, 4, 7, 8, 16, 32, 56, 64, 112, 128, 224, 512, 2048]


def _random_problem(rng, l, k):
    dims = rng.choice(DIM_POOL, (l, 7)).astype(np.float32)
    div, mask = divisor_tables(dims, k)
    theta = rng.normal(1.0, 1.5, (l, 7, 4)).astype(np.float32)
    gum = rng.gumbel(size=(l, 7, 4, k)).astype(np.float32)
    return dims, div, mask, theta, gum


@settings(max_examples=25, deadline=None)
@given(
    l=st.sampled_from([8, 16, 32]),
    k=st.sampled_from([8, 16, 32]),
    tau=st.floats(0.05, 5.0),
    alpha=st.floats(0.01, 2.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_gumbel_snap_matches_ref(l, k, tau, alpha, seed):
    rng = np.random.default_rng(seed)
    dims, div, mask, theta, gum = _random_problem(rng, l, k)
    tau32, alpha32 = np.float32(tau), np.float32(alpha)
    s1, h1 = gumbel_snap(theta, div, mask, gum, tau32, alpha32)
    s2, h2 = ref_gumbel_snap(*map(jnp.asarray,
                                  (theta, div, mask, gum, tau32, alpha32)))
    np.testing.assert_allclose(s1, s2, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(h1, h2, rtol=0, atol=0)


@settings(max_examples=25, deadline=None)
@given(
    l=st.sampled_from([8, 16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
    frac_pad=st.floats(0.0, 0.5),
)
def test_traffic_matches_ref(l, seed, frac_pad):
    rng = np.random.default_rng(seed)
    dims, div, mask, theta, _ = _random_problem(rng, l, 16)
    # random *divisor* factors so products stay meaningful
    idx = rng.integers(0, 16, (l, 7, 4))
    factors = np.take_along_axis(
        np.broadcast_to(div[:, :, None, :], (l, 7, 4, 16)),
        idx[..., None], axis=-1)[..., 0].astype(np.float32)
    # some padding layers
    lm = np.ones(l, np.float32)
    lm[int(l * (1 - frac_pad)):] = 0.0
    c1, t31 = traffic(factors, dims, lm)
    c2, t32 = ref_traffic(*map(jnp.asarray, (factors, dims, lm)))
    np.testing.assert_allclose(c1, c2, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(t31, t32, rtol=1e-5, atol=1e-5)


def test_gumbel_snap_hard_is_valid_divisor():
    rng = np.random.default_rng(3)
    dims, div, mask, theta, gum = _random_problem(rng, 16, 16)
    _, hard = gumbel_snap(theta, div, mask, gum, np.float32(0.5),
                          np.float32(0.1))
    hard = np.asarray(hard)
    for i in range(16):
        for d in range(7):
            n = int(dims[i, d])
            for m in range(4):
                f = hard[i, d, m]
                assert f >= 1 and n % int(round(f)) == 0, (
                    f"snap produced non-divisor {f} of {n}")


def test_gumbel_snap_zero_tau_limit_prefers_nearest():
    """As tau -> small and no noise, hard snap = nearest divisor."""
    l = 8
    dims = np.full((l, 7), 12.0, np.float32)
    div, mask = divisor_tables(dims, 8)
    theta = np.log2(np.full((l, 7, 4), 3.8, np.float32))  # nearest div = 4
    gum = np.zeros((l, 7, 4, 8), np.float32)
    _, hard = gumbel_snap(theta, div, mask, gum, np.float32(0.01),
                          np.float32(1.0))
    np.testing.assert_allclose(np.asarray(hard), 4.0)


def test_traffic_ops_and_pes():
    """Ops = prod(dims); PEs = spatial K * spatial C."""
    l = 8
    dims = np.ones((l, 7), np.float32)
    dims[0] = [2, 8, 4, 6, 6, 3, 3]
    factors = np.ones((l, 7, 4), np.float32)
    factors[0, C.DIM_K, C.SLOT_S] = 8
    factors[0, C.DIM_C, C.SLOT_S] = 2
    lm = np.zeros(l, np.float32)
    lm[0] = 1
    comp, _ = traffic(factors, dims, lm)
    comp = np.asarray(comp)
    assert comp[0, C.C_OPS] == 2 * 8 * 4 * 6 * 6 * 3 * 3
    assert comp[0, C.C_PES] == 16
    # spatial on non-K/C dims must not affect PEs
    factors[0, C.DIM_P, C.SLOT_S] = 4
    comp2, _ = traffic(factors, dims, lm)
    assert np.asarray(comp2)[0, C.C_PES] == 16


def test_traffic_padding_layers_are_zero():
    l = 8
    dims = np.full((l, 7), 4.0, np.float32)
    factors = np.full((l, 7, 4), 1.0, np.float32)
    lm = np.zeros(l, np.float32)
    comp, t3 = traffic(factors, dims, lm)
    np.testing.assert_allclose(np.asarray(comp), 0.0)
    np.testing.assert_allclose(np.asarray(t3), 1.0)


def test_traffic_tilesize_fetchcount_identity():
    """Eq. (4)-(6): full tiling at L2 => fill equals tensor size once."""
    l = 8
    dims = np.ones((l, 7), np.float32)
    dims[0] = [1, 16, 8, 10, 10, 3, 3]
    factors = np.ones((l, 7, 4), np.float32)
    factors[0, :, C.SLOT_T2] = dims[0]          # entire problem tiled at L2
    lm = np.zeros(l, np.float32)
    lm[0] = 1
    comp, t3 = traffic(factors, dims, lm)
    comp = np.asarray(comp)
    w_size = 16 * 8 * 3 * 3
    i_size = 1 * 8 * 10 * 10 * 3 * 3
    assert comp[0, C.C_FILL2_W] == w_size
    assert comp[0, C.C_FILL2_I] == i_size
    np.testing.assert_allclose(np.asarray(t3)[0], 1.0)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_ad_wrappers_forward_equals_kernel(seed):
    rng = np.random.default_rng(seed)
    dims, div, mask, theta, gum = _random_problem(rng, 8, 8)
    tau, alpha = np.float32(1.0), np.float32(0.1)
    s1, h1 = gumbel_snap(theta, div, mask, gum, tau, alpha)
    s2, h2 = gumbel_snap_ad(theta, div, mask, gum, tau, alpha)
    np.testing.assert_allclose(s1, s2, rtol=0)
    np.testing.assert_allclose(h1, h2, rtol=0)
    lm = np.ones(8, np.float32)
    c1, t1 = traffic(np.asarray(h1), dims, lm)
    c2, t2 = traffic_ad(jnp.asarray(np.asarray(h1)), jnp.asarray(dims),
                        jnp.asarray(lm))
    np.testing.assert_allclose(c1, c2, rtol=0)
    np.testing.assert_allclose(t1, t2, rtol=0)


def test_ad_wrapper_gradient_matches_ref_gradient():
    """custom_vjp backward must equal the oracle's autodiff gradient."""
    import jax

    rng = np.random.default_rng(7)
    dims, div, mask, theta, gum = _random_problem(rng, 8, 8)
    tau, alpha = np.float32(1.0), np.float32(0.1)

    def via_kernel(th):
        soft, _ = gumbel_snap_ad(th, div, mask, gum, tau, alpha)
        return jnp.sum(soft ** 2)

    def via_ref(th):
        soft, _ = ref_gumbel_snap(th, jnp.asarray(div), jnp.asarray(mask),
                                  jnp.asarray(gum), tau, alpha)
        return jnp.sum(soft ** 2)

    g1 = jax.grad(via_kernel)(jnp.asarray(theta))
    g2 = jax.grad(via_ref)(jnp.asarray(theta))
    np.testing.assert_allclose(g1, g2, rtol=1e-4, atol=1e-5)
