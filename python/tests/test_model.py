"""L2 model semantics: fusion boundary, penalties, gradients, batched eval."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import constants as C
from compile import model

from .conftest import conv_chain, divisor_tables, hw_vector


def _loss_inputs(seed=0, sigma_logit=None, theta=None, lam=1.0, hw=None):
    rng = np.random.default_rng(seed)
    dims, lmask, emask = conv_chain()
    div, dmask = divisor_tables(dims)
    if theta is None:
        theta = rng.normal(1.0, 1.0, (C.L_MAX, 7, 4)).astype(np.float32)
    if sigma_logit is None:
        sigma_logit = np.zeros(C.L_MAX, np.float32)
    gum = np.zeros((C.L_MAX, 7, 4, C.K_MAX), np.float32)
    if hw is None:
        hw = hw_vector()
    return [jnp.asarray(x) for x in (
        theta, sigma_logit, dims, div, dmask, lmask, emask, gum,
        np.float32(1.0), np.float32(0.05), np.float32(lam), hw)]


def test_loss_and_grad_finite():
    out = model.loss_and_grad(*_loss_inputs())
    loss, edp, en, lat, pen, gt, gs = out
    assert np.isfinite(float(loss))
    assert float(edp) > 0 and float(en) > 0 and float(lat) > 0
    assert bool(jnp.all(jnp.isfinite(gt)))
    assert bool(jnp.all(jnp.isfinite(gs)))


def test_fusion_reduces_dram_traffic():
    """sigma=1 on an edge must strictly reduce DRAM accesses (Eqs 13-15)."""
    dims, lmask, emask = conv_chain()
    factors = np.ones((C.L_MAX, 7, 4), np.float32)
    factors[:, :, C.SLOT_T2] = dims          # everything resident at L2
    hw = jnp.asarray(hw_vector())
    sig0 = jnp.zeros(C.L_MAX)
    sig1 = jnp.zeros(C.L_MAX).at[0].set(1.0)
    args = (jnp.asarray(factors), jnp.asarray(dims), jnp.asarray(lmask))
    comp, _ = model.traffic(*args)
    c0 = model.fusion_costs(comp, sig0, jnp.asarray(emask),
                            jnp.asarray(lmask), hw)
    c1 = model.fusion_costs(comp, sig1, jnp.asarray(emask),
                            jnp.asarray(lmask), hw)
    a3_0 = float(jnp.sum(c0["access"][:, 3]))
    a3_1 = float(jnp.sum(c1["access"][:, 3]))
    assert a3_1 < a3_0, "fusion did not reduce DRAM traffic"
    # on-chip copy appears instead
    assert float(jnp.sum(c1["copy12"])) > 0
    assert float(jnp.sum(c0["copy12"])) == 0


def test_fusion_sigma_monotone_in_edp():
    """For a bandwidth-bound chain, EDP decreases monotonically in sigma."""
    dims, lmask, emask = conv_chain()
    factors = np.ones((C.L_MAX, 7, 4), np.float32)
    factors[:, :, C.SLOT_T2] = dims
    hw = jnp.asarray(hw_vector())
    comp, _ = model.traffic(jnp.asarray(factors), jnp.asarray(dims),
                            jnp.asarray(lmask))
    prev = None
    for s in (0.0, 0.25, 0.5, 0.75, 1.0):
        sig = jnp.full((C.L_MAX,), s)
        cost = model.fusion_costs(comp, sig, jnp.asarray(emask),
                                  jnp.asarray(lmask), hw)
        edp = float(cost["edp"])
        if prev is not None:
            assert edp <= prev * (1 + 1e-6)
        prev = edp


def test_sigma_gradient_sign_points_toward_fusion():
    """With fusion profitable, d loss / d sigma_logit must be negative."""
    out = model.loss_and_grad(*_loss_inputs(lam=0.0))
    gs = np.asarray(out[6])
    dims, lmask, emask = conv_chain()
    real_edges = int(emask.sum())
    assert (gs[:real_edges] < 0).all(), gs[:real_edges + 1]


def test_penalty_spatial_overflow():
    """Spatial factors beyond the PE array must be penalized."""
    theta = np.zeros((C.L_MAX, 7, 4), np.float32)
    theta[:, C.DIM_K, C.SLOT_S] = 7.0          # 2^7 = 128 > 32 cols
    args = _loss_inputs(theta=theta)
    out = model.loss_and_grad(*args)
    pen = float(out[4])
    assert pen > 0


def test_penalty_zero_for_trivial_mapping():
    """All-ones factors (everything at DRAM) violate nothing."""
    theta = np.zeros((C.L_MAX, 7, 4), np.float32)   # 2^0 = 1
    out = model.loss_and_grad(*_loss_inputs(theta=theta))
    # alignment: sigma=0.5 default with equal tiles => tiny alignment term
    assert float(out[4]) < 1e-3


def test_group_scan_matches_exact_group_sums():
    """Binary sigma scan == exact per-group running footprint."""
    s = jnp.asarray(np.array([10., 20., 30., 40., 50.], np.float32))
    sig_in = jnp.asarray(np.array([0., 1., 1., 0., 1.], np.float32))
    r = model._group_scan(s, sig_in)
    np.testing.assert_allclose(np.asarray(r), [10., 30., 60., 40., 90.])


def test_eval_batch_matches_eval_one():
    rng = np.random.default_rng(5)
    dims, lmask, emask = conv_chain()
    hw = hw_vector()
    b = 4
    fac = np.ones((b, C.L_MAX, 7, 4), np.float32)
    for i in range(b):
        fac[i, :, :, C.SLOT_T1] = rng.choice([1, 2, 4], (C.L_MAX, 7))
    sig = (rng.random((b, C.L_MAX)) > 0.5).astype(np.float32)
    eb, enb, latb, vb = model.eval_batch(*map(jnp.asarray,
                                              (fac, sig, dims, lmask,
                                               emask, hw)))
    for i in range(b):
        e1, en1, lat1, v1 = model.eval_one(*map(jnp.asarray,
                                                (fac[i], sig[i], dims, lmask,
                                                 emask, hw)))
        np.testing.assert_allclose(float(eb[i]), float(e1), rtol=1e-5)
        np.testing.assert_allclose(float(vb[i]), float(v1), rtol=1e-5)


def test_detail_totals_consistent():
    dims, lmask, emask = conv_chain()
    hw = hw_vector()
    fac = np.ones((C.L_MAX, 7, 4), np.float32)
    sig = np.zeros(C.L_MAX, np.float32)
    edp, en, lat, comp, access, lat_l, en_l, t3 = model.detail(
        *map(jnp.asarray, (fac, sig, dims, lmask, emask, hw)))
    np.testing.assert_allclose(float(en), float(jnp.sum(en_l)), rtol=1e-6)
    np.testing.assert_allclose(float(lat), float(jnp.sum(lat_l)), rtol=1e-6)
    np.testing.assert_allclose(float(edp), float(en) * float(lat), rtol=1e-6)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), lam=st.floats(0.1, 10.0))
def test_loss_grad_always_finite(seed, lam):
    out = model.loss_and_grad(*_loss_inputs(seed=seed, lam=lam))
    assert np.isfinite(float(out[0]))
    assert bool(jnp.all(jnp.isfinite(out[5])))
    assert bool(jnp.all(jnp.isfinite(out[6])))


def test_latency_roofline_compute_bound():
    """A tiny-traffic, big-compute layer must be compute-bound (Eq 16)."""
    dims = np.ones((C.L_MAX, 7), np.float32)
    dims[0] = [1, 32, 32, 1, 1, 1, 1]          # 1024 MACs
    lmask = np.zeros(C.L_MAX, np.float32)
    lmask[0] = 1
    emask = np.zeros(C.L_MAX, np.float32)
    fac = np.ones((C.L_MAX, 7, 4), np.float32)
    fac[0, C.DIM_K, C.SLOT_S] = 32
    fac[0, C.DIM_C, C.SLOT_S] = 32
    fac[0, :, C.SLOT_T2] = dims[0] / fac[0, :, C.SLOT_S]
    hw = hw_vector(bw3=1e9, bw2=1e9, bw1=1e9)   # infinite bandwidth
    comp, _ = model.traffic(jnp.asarray(fac), jnp.asarray(dims),
                            jnp.asarray(lmask))
    cost = model.fusion_costs(comp, jnp.zeros(C.L_MAX), jnp.asarray(emask),
                              jnp.asarray(lmask), jnp.asarray(hw))
    ops = 32 * 32
    np.testing.assert_allclose(float(cost["latency"]), ops * 1.0 / 1024,
                               rtol=1e-6)
