#!/usr/bin/env python3
"""Gate the perf trajectory: compare a fresh BENCH_hotpath.json against
the checked-in baseline and fail CI on hot-path regressions.

Usage:
    python3 python/tools/check_bench.py BASELINE.json CURRENT.json

Two kinds of checks:

* **Absolute lanes** (SoA batch kernel, native gradient step): the
  current number must not fall more than ``MAX_REGRESSION`` below the
  checked-in baseline. Absolute throughput is machine-dependent, so a
  baseline carrying ``"bootstrap": true`` (committed from an
  environment that could not run the bench) downgrades these to
  advisory — the first CI run on real hardware should replace the
  baseline with its own numbers and drop the flag.
* **Machine-relative invariants** (self-normalizing, enforced on any
  runner with 4+ hardware threads): multi-chain (C=8) gradient search
  must reach a best-loss at least as good as the single-chain
  baseline on both zoo workloads, and the aggregate grad-steps/sec of
  8 parallel chains must clear a scaling floor over the single
  chain's — >= 3x on a true 4+-physical-core runner (8+ hardware
  threads), >= 2x on 4-7 hardware threads (SMT "4-core" runners
  expose two physical cores). Below 4 threads the chains timeshare
  one or two cores and both checks are advisory. Additionally the
  fleet-serving lane: N concurrent jobs through the coordinator
  (cross-job batch merging) must sustain at least
  ``FLEET_FLOOR`` x the serial one-job-at-a-time throughput of the
  same machine — concurrency plus merging must never cost throughput.
  The bound-and-prune lanes add one more hard invariant: pruned and
  unpruned random search (same seed, same budget, same machine) must
  report the *same* best EDP per workload — the screen is admissible
  and may only skip work, never change the answer. The prune and
  warm-start speedup floors are throughput claims on the same run, so
  they are enforced on real baselines and advisory while the
  ``bootstrap`` flag stands. Finally, the exact-mapper lane: the
  branch-and-bound oracle must certify all three exhaustively-solvable
  ``micro-*`` workloads (machine-independent, enforced even on
  bootstrap baselines); its node counts and prune ratio are recorded
  so the mapper's pruning power is tracked PR-over-PR.
"""

import json
import sys

# Lanes compared against the checked-in baseline (higher is better).
ABSOLUTE_LANES = [
    "soa_batch_evals_per_sec",
    "native_grad_steps_per_sec",
]

# Fail when current < (1 - MAX_REGRESSION) * baseline.
MAX_REGRESSION = 0.25

# Minimum C=8-vs-C=1 grad-steps/sec ratio, tiered by hardware threads:
# a "4-core" hosted runner is often 2 physical cores with SMT, where
# the f64-bound gradient kernel cannot reach the full 3x, so the 3x
# floor applies from 8 hardware threads and a 2x floor from 4.
SPEEDUP_FLOORS = [(8, 3.0), (4, 2.0)]

# Bound-and-prune screening must not cost throughput on the default-on
# random path (it skips kernel work for pruned candidates and the
# screen itself is cheap), and a warm-started repeat-shape search must
# reach the cold run's final quality markedly faster (its library
# seeds are offered before the first fresh sample). Both are same-run
# speedups, but they lean on timing jitter at sub-second scales, so
# they stay advisory while the baseline carries ``bootstrap``.
PRUNE_SPEEDUP_FLOOR = 1.0
WARM_SPEEDUP_FLOOR = 2.0

# Minimum merged-vs-serial evals/sec ratio for the fleet-serving lane
# (same-machine comparison, so no bootstrap caveat): concurrent jobs
# with cross-job batch merging must at least match running the jobs
# one at a time. On 4+ threads the merged path should win outright;
# the 0.9 floor absorbs scheduling jitter without letting a real
# serialization bug (ratio well under 1) pass.
FLEET_FLOOR = 0.9


def main(argv):
    if len(argv) != 3:
        print(__doc__)
        return 2
    with open(argv[1]) as f:
        base = json.load(f)
    with open(argv[2]) as f:
        cur = json.load(f)

    failures = []
    bootstrap = bool(base.get("bootstrap"))
    if bootstrap:
        print(
            "baseline is a bootstrap placeholder: absolute-lane "
            "comparisons are advisory this run"
        )

    for lane in ABSOLUTE_LANES:
        b, c = base.get(lane), cur.get(lane)
        if c is None:
            failures.append(f"current run is missing lane {lane!r}")
            continue
        if b is None:
            print(f"{lane}: no baseline value, recording {c:.1f}")
            continue
        ratio = c / b if b else float("inf")
        print(f"{lane}: baseline {b:.1f} -> current {c:.1f} "
              f"({ratio:.2f}x)")
        if ratio < 1.0 - MAX_REGRESSION:
            msg = (f"{lane} regressed >25%: {b:.1f} -> {c:.1f} "
                   f"({ratio:.2f}x)")
            if bootstrap:
                print(f"advisory (bootstrap baseline): {msg}")
            else:
                failures.append(msg)

    cores = cur.get("chain_threads", 0)
    better = cur.get("multi_chain_better_workloads")
    if better is None:
        failures.append(
            "current run is missing multi_chain_better_workloads"
        )
    else:
        print(f"multi-chain better best-loss on {better:.0f}/2 "
              "workloads")
        if better < 2 and cores >= 4:
            failures.append(
                "multi-chain (C=8) gradient search must reach a "
                "best-loss at least as good as single-chain on both "
                f"zoo workloads (got {better:.0f}/2 on {cores:.0f} "
                "threads)"
            )
        elif better < 2:
            # below 4 hardware threads 8 chains timeshare one or two
            # cores — advisory, same policy as gradient_native.rs
            print(f"  (only {cores:.0f} hardware threads: best-loss "
                  "comparison is advisory)")

    speedup = cur.get("parallel_grad_steps_speedup")
    if speedup is None:
        failures.append(
            "current run is missing parallel_grad_steps_speedup"
        )
    else:
        floor = next((f for c, f in SPEEDUP_FLOORS if cores >= c),
                     None)
        print(f"parallel grad-steps/sec speedup {speedup:.2f}x on "
              f"{cores:.0f} hardware threads")
        if floor is None:
            print("  (fewer than 4 threads: no speedup floor "
                  "enforced)")
        elif speedup < floor:
            failures.append(
                f"C=8 grad-steps/sec speedup {speedup:.2f}x is below "
                f"the {floor}x floor for a {cores:.0f}-thread runner"
            )

    fleet = cur.get("fleet_merged_vs_serial_speedup")
    if fleet is None:
        failures.append(
            "current run is missing fleet_merged_vs_serial_speedup"
        )
    else:
        print(f"fleet merged-vs-serial throughput {fleet:.2f}x on "
              f"{cores:.0f} hardware threads")
        if cores < 4:
            print("  (fewer than 4 threads: fleet floor is advisory)")
        elif fleet < FLEET_FLOOR:
            failures.append(
                f"fleet serving throughput {fleet:.2f}x serial is "
                f"below the {FLEET_FLOOR}x floor: concurrent jobs "
                "with batch merging must not be slower than running "
                "them one at a time"
            )

    # bound-and-prune: the default-on screen may only skip work, never
    # change the answer — pruned and unpruned search report the same
    # best EDP. Same machine, same run: enforced even on bootstrap.
    for wl in ("llama", "gpt3"):
        p = cur.get(f"pruned_best_edp_{wl}")
        u = cur.get(f"unpruned_best_edp_{wl}")
        if p is None or u is None:
            failures.append(
                "current run is missing the pruned/unpruned best-EDP "
                f"lanes for {wl}"
            )
        elif p != u:
            failures.append(
                f"bound-and-prune changed the {wl} answer: pruned "
                f"best EDP {p!r} != unpruned {u!r}"
            )
        else:
            print(f"pruned == unpruned best EDP on {wl}: {p:.6g}")

    for lane, floor in (
        ("prune_evals_speedup", PRUNE_SPEEDUP_FLOOR),
        ("warm_start_speedup", WARM_SPEEDUP_FLOOR),
    ):
        v = cur.get(lane)
        if v is None:
            failures.append(f"current run is missing lane {lane!r}")
            continue
        print(f"{lane}: {v:.2f}x (floor {floor}x)")
        if v < floor:
            msg = f"{lane} {v:.2f}x is below the {floor}x floor"
            if bootstrap:
                print(f"advisory (bootstrap baseline): {msg}")
            else:
                failures.append(msg)

    # exact mapper: certifying the micro trio is machine-independent
    # (the spaces are exhaustively enumerable under the default node
    # cap), so a lost certification means the mapper or its bounds
    # regressed — enforced even on bootstrap baselines. Node counts
    # and prune ratio are recorded for the perf trajectory.
    cert = cur.get("exact_certified_workloads")
    if cert is None:
        failures.append(
            "current run is missing exact_certified_workloads"
        )
    elif cert < 3:
        failures.append(
            f"exact mapper certified only {cert:.0f}/3 micro "
            "workloads — branch-and-bound or its bounds regressed"
        )
    else:
        print(f"exact mapper certified {cert:.0f}/3 micro workloads")
    for lane in ("exact_nodes_per_sec", "exact_prune_ratio",
                 "exact_nodes_expanded", "exact_pruned"):
        v = cur.get(lane)
        if v is None:
            failures.append(f"current run is missing lane {lane!r}")
        else:
            print(f"{lane}: {v:.6g}")

    if failures:
        print("\nFAIL:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("\nbench gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
