"""Fit the on-chip EPA (energy-per-access) MLP and bake its weights.

The paper (Sec 2.1) models the energy-per-access of on-chip buffers with
"a small MLP as a function of buffer capacity". The silicon calibration
data behind the authors' MLP is not published, so we fit the same MLP
architecture to a CACTI-class analytic target

    epa(kb) = 0.18 + 0.11 * sqrt(kb)        [pJ / element, 2-byte elems]

over capacities 1 KB .. 4 MB. The MLP is 1 -> H -> H -> 1 with tanh
activations; hidden weights are fixed random features (seeded, so the fit
is deterministic) and the two output layers are solved in closed form via
ridge-regularized least squares — no iterative training, bit-identical
re-runs.

Output: data/epa_mlp.json consumed by BOTH the Rust config layer
(`rust/src/config/epa.rs`) and the python tests, so L2 and L3 evaluate
the same EPA curve.
"""

import json
import os

import numpy as np

H = 8
SEED = 20250710


def target(kb):
    return 0.18 + 0.11 * np.sqrt(kb)


def fit():
    rng = np.random.default_rng(SEED)
    kb = np.logspace(0, np.log10(4096.0), 256)
    # normalized feature: (log2(KB) - 6) / 6 keeps tanh unsaturated
    x = ((np.log2(kb) - 6.0) / 6.0)[:, None]
    y = target(kb)[:, None]

    w1 = rng.normal(0, 1.0, (1, H))
    b1 = rng.normal(0, 1.0, (H,))
    h1 = np.tanh(x @ w1 + b1)

    w2 = rng.normal(0, 1.0, (H, H))
    b2 = rng.normal(0, 1.0, (H,))
    h2 = np.tanh(h1 @ w2 + b2)

    # closed-form ridge solve for the linear readout
    a = np.concatenate([h2, np.ones((len(kb), 1))], axis=1)
    coef = np.linalg.solve(a.T @ a + 1e-6 * np.eye(H + 1), a.T @ y)
    w3, b3 = coef[:H, 0], coef[H, 0]

    pred = (h2 @ w3 + b3)
    err = float(np.max(np.abs(pred - y[:, 0]) / y[:, 0]))
    return {
        "arch": "1-8-8-1 tanh, input (log2(KB)-6)/6, output pJ/element",
        "seed": SEED,
        "max_rel_err": err,
        "w1": w1.tolist(), "b1": b1.tolist(),
        "w2": w2.tolist(), "b2": b2.tolist(),
        "w3": w3.tolist(), "b3": float(b3),
    }


def mlp_epa(weights, kb):
    """Reference evaluation (mirrored in rust/src/config/epa.rs)."""
    x = ((np.atleast_1d(np.log2(kb)).astype(np.float64) - 6.0) / 6.0)[:, None]
    h1 = np.tanh(x @ np.asarray(weights["w1"]) + np.asarray(weights["b1"]))
    h2 = np.tanh(h1 @ np.asarray(weights["w2"]) + np.asarray(weights["b2"]))
    return h2 @ np.asarray(weights["w3"]) + weights["b3"]


if __name__ == "__main__":
    w = fit()
    out = os.path.join(os.path.dirname(__file__), "..", "..", "data",
                       "epa_mlp.json")
    out = os.path.normpath(out)
    with open(out, "w") as f:
        json.dump(w, f, indent=2)
    print(f"wrote {out} (max rel err {w['max_rel_err']:.4f})")
    for kb in (8, 64, 512):
        print(f"  epa({kb} KB) = {mlp_epa(w, kb)[0]:.4f} pJ/elem "
              f"(target {target(kb):.4f})")
