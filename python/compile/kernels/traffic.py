"""L1 Pallas kernel: per-layer traffic accounting (paper Eqs. (4)-(12)).

Given (possibly continuous) tiling factors, computes every data-movement
component of the unified cost model: fills, inter-memory reads,
PE-supplying reads (with broadcast reuse), accumulation write-backs (with
spatial reduction), and the baseline inter-memory write-back that the
fusion variable sigma later modulates (Eqs. (13)-(15), applied in L2).

TPU mapping: grid over layer blocks; per-program state is a [LB, 7, 4]
factor tile plus [7]-wide membership masks — everything stays in VMEM and
reduces along the short dim axis with dense vector ops. interpret=True
(see gumbel_snap.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import constants as C

LB = 8  # layer block per grid step

# Static dim-index tuples for the membership products (constants.py masks,
# written as explicit indices: Pallas kernels may not capture array
# constants, and a 7-way static product is VPU-trivial anyway).
_W_IDX = tuple(d for d in range(7) if C.W_DIMS[d])          # K,C,R,S
_I_IDX = tuple(d for d in range(7) if C.I_DIMS[d])          # N,C,P,Q,R,S
_O_IDX = tuple(d for d in range(7) if C.O_DIMS[d])          # N,K,P,Q


def _iprod(x, idx):
    """Product over a static tuple of dim indices of [LB, 7] `x`."""
    out = x[:, idx[0]]
    for d in idx[1:]:
        out = out * x[:, d]
    return out


def _kernel(factors_ref, dims_ref, lmask_ref, comp_ref, t3_ref):
    f = factors_ref[...]                          # [LB,7,4]
    dims = dims_ref[...]                          # [LB,7]
    lm = lmask_ref[...]                           # [LB]

    t0, t1, t2 = f[:, :, C.SLOT_T0], f[:, :, C.SLOT_T1], f[:, :, C.SLOT_T2]
    sp = f[:, :, C.SLOT_S]
    # spatial unrolling exists on K (cols) and C (rows) only
    sp_k = sp[:, C.DIM_K]
    sp_c = sp[:, C.DIM_C]
    sp_eff = jnp.ones_like(sp)
    sp_eff = sp_eff.at[:, C.DIM_K].set(sp_k)
    sp_eff = sp_eff.at[:, C.DIM_C].set(sp_c)

    inner = t0 * t1 * t2 * sp_eff
    t3 = dims / jnp.maximum(inner, C.EPS)         # derived DRAM factor
    # Honest-traffic clamp: an over-tiled dim (inner > dim, t3 < 1) must
    # not UNDERcount fetches — that would reward constraint violations
    # with fictitious reuse. P_valid still drives t3 back above 1.
    t3c = jnp.maximum(t3, 1.0)

    ops = jnp.prod(dims, axis=1)
    pes = sp_k * sp_c

    ext0 = t0 * sp_eff
    ext1 = ext0 * t1
    ext2 = ext1 * t2

    s_w2 = _iprod(ext2, _W_IDX)
    s_i2 = _iprod(ext2, _I_IDX)
    s_w0 = _iprod(ext0, _W_IDX)
    s_o1 = _iprod(ext1, _O_IDX)

    fetch2 = jnp.prod(t3c, axis=1)
    fetch0 = jnp.prod(t3c * t2 * t1, axis=1)
    wcount1 = jnp.prod(t3c * t2, axis=1)

    fill2_i = s_i2 * fetch2
    fill2_w = s_w2 * fetch2
    fill0_w = s_w0 * fetch0

    # Bcast_I = spatial K (inputs broadcast across array columns);
    # Reduce_O = spatial C (partial sums reduced across array rows).
    read_pe_i = ops / jnp.maximum(sp_k, C.EPS)
    read0_w = ops                                  # Bcast_W == 1

    accwb_o = ops / jnp.maximum(sp_c, C.EPS)
    wb0_o = s_o1 * wcount1

    comp = jnp.stack(
        [
            ops, pes, fill2_i, fill2_w, fill0_w, read_pe_i, accwb_o, wb0_o,
            s_w2, s_i2, s_o1,
            ext2[:, C.DIM_P], ext2[:, C.DIM_Q],
            ext2[:, C.DIM_K], ext2[:, C.DIM_C],
            read0_w,
        ],
        axis=1,
    )
    comp_ref[...] = comp * lm[:, None]
    t3_ref[...] = jnp.where(lm[:, None] > 0, t3, 1.0)


@functools.partial(jax.jit, static_argnames=())
def traffic(factors, dims, layer_mask):
    """Pallas entry point; signature mirrors `ref.ref_traffic`."""
    l = factors.shape[0]
    assert l % LB == 0, f"layer count {l} must be a multiple of {LB}"
    grid = (l // LB,)
    comp, t3 = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((LB, 7, 4), lambda i: (i, 0, 0)),
            pl.BlockSpec((LB, 7), lambda i: (i, 0)),
            pl.BlockSpec((LB,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((LB, C.NCOMP), lambda i: (i, 0)),
            pl.BlockSpec((LB, 7), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((l, C.NCOMP), jnp.float32),
            jax.ShapeDtypeStruct((l, 7), jnp.float32),
        ],
        interpret=True,
    )(factors, dims, layer_mask)
    return comp, t3
