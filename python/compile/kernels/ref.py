"""Pure-jnp oracle for the L1 Pallas kernels.

Every Pallas kernel in this package has a reference implementation here,
written with plain `jax.numpy` broadcasting only. pytest/hypothesis
compare kernel vs oracle over swept shapes — this is the core L1
correctness signal (the kernels are what actually lower into the AOT
artifacts the Rust runtime executes).
"""

import jax.numpy as jnp

from .. import constants as C


def ref_gumbel_snap(theta, div, div_mask, gumbel, tau, alpha):
    """Gumbel-Softmax divisor snap (paper Eqs. (1)-(3)).

    Args:
      theta:    [L, 7, 4] log2-space continuous tiling factors.
      div:      [L, 7, K] divisor candidates of each problem dim (padded).
      div_mask: [L, 7, K] 1.0 for valid candidates, 0.0 for padding.
      gumbel:   [L, 7, 4, K] pre-sampled Gumbel(0,1) noise (0 => greedy).
      tau:      scalar softmax temperature (annealed by the L3 driver).
      alpha:    scalar proximity sharpness for the logits (Eq. (1)).

    Returns:
      soft: [L, 7, 4] expected divisor  sum_j p_j d_j          (Eq. (3))
      hard: [L, 7, 4] argmax divisor (straight-through forward value)
    """
    tau = jnp.asarray(tau, jnp.float32)
    alpha = jnp.asarray(alpha, jnp.float32)
    d = div[:, :, None, :]                                # [L,7,1,K]
    m = div_mask[:, :, None, :]                           # [L,7,1,K]
    # Eq. (1), log-domain proximity (see gumbel_snap.py)
    ld = jnp.log2(jnp.maximum(d, 1e-9))
    logits = -alpha * (theta[..., None] - ld) ** 2
    z = (logits + gumbel) / tau                           # Eq. (2)
    z = jnp.where(m > 0, z, C.NEG_INF)
    zmax = jnp.max(z, axis=-1, keepdims=True)
    # clamped exactly like the Pallas kernel (see gumbel_snap.py)
    e = jnp.exp(jnp.maximum(z - zmax, -100.0)) * m
    p = e / (jnp.sum(e, axis=-1, keepdims=True) + C.EPS)
    soft = jnp.sum(p * d, axis=-1)                        # Eq. (3)
    onehot = jnp.where((z >= zmax) & (m > 0), 1.0, 0.0)
    onehot = onehot / (jnp.sum(onehot, axis=-1, keepdims=True) + C.EPS)
    hard = jnp.sum(onehot * d, axis=-1)
    return soft, hard


def ref_traffic(factors, dims, layer_mask):
    """Per-layer traffic components (paper Eqs. (4)-(12)).

    Args:
      factors:    [L, 7, 4] tiling factors (slots t_L0, t_L1, t_L2, spatial).
                  May be continuous (soft/ST) or integer-valued.
      dims:       [L, 7] full problem dimension sizes.
      layer_mask: [L] 1.0 for real layers, 0.0 for padding.

    Returns:
      comp: [L, NCOMP] traffic components (see constants.py).
      t3:   [L, 7] derived DRAM-level temporal factor dim/(t0*t1*t2*s).
    """
    t0 = factors[:, :, C.SLOT_T0]
    t1 = factors[:, :, C.SLOT_T1]
    t2 = factors[:, :, C.SLOT_T2]
    sp = factors[:, :, C.SLOT_S]

    w = jnp.asarray(C.W_DIMS, jnp.float32)
    i_ = jnp.asarray(C.I_DIMS, jnp.float32)
    o = jnp.asarray(C.O_DIMS, jnp.float32)
    sd = jnp.asarray(C.SPATIAL_DIMS, jnp.float32)

    sp_eff = jnp.where(sd > 0, sp, 1.0)                    # spatial off non-KC
    inner = t0 * t1 * t2 * sp_eff                          # product below DRAM
    t3 = dims / jnp.maximum(inner, C.EPS)                  # derived (Sec 3.1.1)
    t3c = jnp.maximum(t3, 1.0)              # honest-traffic clamp (kernel)

    def mprod(x, mask):
        # masked product over the dim axis: prod_{d: mask[d]=1} x[:, d]
        return jnp.prod(jnp.where(mask > 0, x, 1.0), axis=1)

    ops = jnp.prod(dims, axis=1)                           # total MACs
    pes = jnp.prod(sp_eff, axis=1)                         # effective PEs

    # Tile extents per dim at each residence level (spatial counts at L0+).
    ext0 = t0 * sp_eff
    ext1 = ext0 * t1
    ext2 = ext1 * t2                                       # extent at L2

    # TileSize(i, T): Eq. (5); FetchCount / WriteCount: Eq. (6) over all d.
    s_w2 = mprod(ext2, w)
    s_i2 = mprod(ext2, i_)
    s_w0 = mprod(ext0, w)
    s_o1 = mprod(ext1, o)

    fetch2 = jnp.prod(t3c, axis=1)                         # outer-of-L2 iters
    fetch0 = jnp.prod(t3c * t2 * t1, axis=1)               # outer-of-L0 iters
    wcount1 = jnp.prod(t3c * t2, axis=1)                   # outer-of-L1 iters

    fill2_i = s_i2 * fetch2                                # Eq. (4)
    fill2_w = s_w2 * fetch2
    fill0_w = s_w0 * fetch0                                # Eq. (7) L2->L0

    # PE-supplying reads, Eqs. (8)-(9): inputs broadcast across spatial K
    # (array columns); weights are per-PE (K and C both index W => Bcast=1).
    bcast_i = mprod(sp_eff, (1.0 - i_) * sd)               # = spatial K
    read_pe_i = ops / jnp.maximum(bcast_i, C.EPS)
    read0_w = ops                                          # Bcast_W == 1

    # Accumulation write-back, Eqs. (11)-(12): partial sums reduced across
    # spatial C (array rows) before hitting the L1 accumulator.
    reduce_o = mprod(sp_eff, (1.0 - o) * sd)               # = spatial C
    accwb_o = ops / jnp.maximum(reduce_o, C.EPS)

    # Inter-memory write-back L1 -> L3 (baseline, pre-fusion), Eq. (10).
    wb0_o = s_o1 * wcount1

    comp = jnp.stack(
        [
            ops, pes, fill2_i, fill2_w, fill0_w, read_pe_i, accwb_o, wb0_o,
            s_w2, s_i2, s_o1,
            ext2[:, C.DIM_P], ext2[:, C.DIM_Q],
            ext2[:, C.DIM_K], ext2[:, C.DIM_C],
            read0_w,
        ],
        axis=1,
    )
    comp = comp * layer_mask[:, None]
    t3 = jnp.where(layer_mask[:, None] > 0, t3, 1.0)
    return comp, t3
