"""Reverse-mode AD wrappers for the L1 Pallas kernels.

`pallas_call` (interpret mode) has no registered transpose rule, so the
kernels cannot be differentiated directly. Each kernel gets a
`jax.custom_vjp`: the *forward* pass executes the Pallas kernel (this is
what dominates the lowered HLO), while the *backward* pass reuses the
pure-jnp oracle's VJP — mathematically identical (ref == kernel is
asserted by the pytest/hypothesis suite), and both halves are lowered into
the single AOT artifact the Rust runtime executes.

Cotangents are propagated to the continuously-optimized inputs only
(`theta` for the snap, `factors` for traffic); all other inputs are
constants of the optimization step.
"""

import jax
import jax.numpy as jnp

from . import gumbel_snap as _gumbel_snap_kernel
from . import traffic as _traffic_kernel
from .ref import ref_gumbel_snap, ref_traffic


@jax.custom_vjp
def gumbel_snap_ad(theta, div, div_mask, gumbel, tau, alpha):
    return _gumbel_snap_kernel(theta, div, div_mask, gumbel, tau, alpha)


def _snap_fwd(theta, div, div_mask, gumbel, tau, alpha):
    out = _gumbel_snap_kernel(theta, div, div_mask, gumbel, tau, alpha)
    return out, (theta, div, div_mask, gumbel, tau, alpha)


def _snap_bwd(res, ct):
    theta, div, div_mask, gumbel, tau, alpha = res
    _, vjp = jax.vjp(
        lambda th: ref_gumbel_snap(th, div, div_mask, gumbel, tau, alpha),
        theta)
    (g_theta,) = vjp(ct)
    z = lambda x: jnp.zeros_like(x)
    return (g_theta, z(div), z(div_mask), z(gumbel), z(tau), z(alpha))


gumbel_snap_ad.defvjp(_snap_fwd, _snap_bwd)


@jax.custom_vjp
def traffic_ad(factors, dims, layer_mask):
    return _traffic_kernel(factors, dims, layer_mask)


def _traffic_fwd(factors, dims, layer_mask):
    return _traffic_kernel(factors, dims, layer_mask), (factors, dims,
                                                        layer_mask)


def _traffic_bwd(res, ct):
    factors, dims, layer_mask = res
    _, vjp = jax.vjp(lambda f: ref_traffic(f, dims, layer_mask), factors)
    (g_factors,) = vjp(ct)
    return (g_factors, jnp.zeros_like(dims), jnp.zeros_like(layer_mask))


traffic_ad.defvjp(_traffic_fwd, _traffic_bwd)
