"""L1 Pallas kernel: Gumbel-Softmax divisor snap (paper Eqs. (1)-(3)).

The kernel maps the continuous tiling factor `2**theta` onto the divisor
set of each problem dimension through a temperature-annealed, noisy
softmax, producing both the soft expectation (backward path) and the
argmax selection (straight-through forward path).

TPU mapping (DESIGN.md §6): the grid blocks over layers; each program
holds one [LB, 7, 4, K] logit tile in VMEM and performs a masked dense
softmax over the K≤32 divisor slots — no gathers, fully vectorized on the
VPU. `interpret=True` everywhere: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret-mode lowers to plain HLO that the Rust
runtime runs unmodified.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import constants as C

LB = 8  # layer block per grid step


def _kernel(theta_ref, div_ref, mask_ref, gumbel_ref, ta_ref,
            soft_ref, hard_ref):
    theta = theta_ref[...]                       # [LB,7,4]
    d = div_ref[...][:, :, None, :]              # [LB,7,1,K]
    m = mask_ref[...][:, :, None, :]             # [LB,7,1,K]
    g = gumbel_ref[...]                          # [LB,7,4,K]
    tau = ta_ref[0]
    alpha = ta_ref[1]

    # Eq. (1) with log-domain proximity: divisor candidates are close to
    # uniform in log space, so measuring distance in log2 keeps the
    # softmax unsaturated across dims from 3 to 25088 (linear-space
    # distance collapses the gradient for large dims; DESIGN.md §2).
    ld = jnp.log2(jnp.maximum(d, 1e-9))
    logits = -alpha * (theta[..., None] - ld) ** 2
    z = (logits + g) / tau                       # Eq. (2)
    z = jnp.where(m > 0, z, C.NEG_INF)
    zmax = jnp.max(z, axis=-1, keepdims=True)
    # Clamp before exp: XLA 0.5.x's vectorized expf integer-overflows for
    # arguments around -1e30 (Eigen pexp round(x/ln2)); exp(-100) is
    # already exactly 0 in f32, so the clamp is value-preserving.
    e = jnp.exp(jnp.maximum(z - zmax, -100.0)) * m
    p = e / (jnp.sum(e, axis=-1, keepdims=True) + C.EPS)
    soft_ref[...] = jnp.sum(p * d, axis=-1)      # Eq. (3)

    onehot = jnp.where((z >= zmax) & (m > 0), 1.0, 0.0)
    onehot = onehot / (jnp.sum(onehot, axis=-1, keepdims=True) + C.EPS)
    hard_ref[...] = jnp.sum(onehot * d, axis=-1)


@functools.partial(jax.jit, static_argnames=())
def gumbel_snap(theta, div, div_mask, gumbel, tau, alpha):
    """Pallas entry point; signature mirrors `ref.ref_gumbel_snap`.

    tau/alpha are scalar (0-d or [1]) arrays; they are packed into one
    [2] operand so the kernel sees a single tiny SMEM-class input.
    """
    l, _, _ = theta.shape
    k = div.shape[-1]
    assert l % LB == 0, f"layer count {l} must be a multiple of {LB}"
    ta = jnp.stack([jnp.asarray(tau, jnp.float32).reshape(()),
                    jnp.asarray(alpha, jnp.float32).reshape(())])
    grid = (l // LB,)
    blk = lambda *shape: shape  # readability
    soft, hard = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(blk(LB, 7, 4), lambda i: (i, 0, 0)),
            pl.BlockSpec(blk(LB, 7, k), lambda i: (i, 0, 0)),
            pl.BlockSpec(blk(LB, 7, k), lambda i: (i, 0, 0)),
            pl.BlockSpec(blk(LB, 7, 4, k), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec(blk(2), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec(blk(LB, 7, 4), lambda i: (i, 0, 0)),
            pl.BlockSpec(blk(LB, 7, 4), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((l, 7, 4), jnp.float32),
            jax.ShapeDtypeStruct((l, 7, 4), jnp.float32),
        ],
        interpret=True,
    )(theta, div, div_mask, gumbel, ta)
    return soft, hard
