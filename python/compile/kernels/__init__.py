"""L1 Pallas kernels for the FADiff cost-model hot spots."""
from .gumbel_snap import gumbel_snap
from .traffic import traffic
from .ad import gumbel_snap_ad, traffic_ad
__all__ = ["gumbel_snap", "traffic", "gumbel_snap_ad", "traffic_ad"]
