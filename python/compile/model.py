"""L2: the unified differentiable energy/latency/EDP model of FADiff.

Composes the L1 Pallas kernels (`gumbel_snap`, `traffic`) into the paper's
cost model:

  * fusion-aware boundary modulation           Eqs. (13)-(15)
  * roofline latency                           Eq.  (16)
  * compute + data-movement energy             Eqs. (17)-(19)
  * augmented loss with penalty terms          Eqs. (20)-(26)

Three entry points are AOT-lowered by `aot.py`:

  loss_and_grad  — value_and_grad of the augmented loss w.r.t.
                   (theta, sigma_logit); the FADiff optimization hot path.
  eval_batch     — discrete EDP/energy/latency/feasibility for a
                   population of candidate strategies (GA / BO hot path).
  detail         — single-strategy per-layer breakdown (validation, Fig 3).

Conventions: all tensors f32; `sigma_logit[i]` controls the edge
v_i -> v_{i+1}; padding handled by `layer_mask` / `edge_mask`. The loss
uses log(EDP) + lambda * (normalized penalties): the log is a monotone
transform of the paper's EDP objective (same optimum, scale-invariant
gradients across workloads whose raw EDP spans 1e10..1e15), and penalties
are expressed as relative violations for the same reason (DESIGN.md §2).
"""

import jax
import jax.numpy as jnp

from . import constants as C
from .kernels import gumbel_snap, traffic
from .kernels.ad import gumbel_snap_ad, traffic_ad

ACC_BYTES = 4.0  # the L1 accumulator holds 4-byte partial sums


def _shift_in(x):
    """sigma of the incoming edge of each layer: sig_in[l] = sig_out[l-1]."""
    return jnp.concatenate([jnp.zeros((1,), x.dtype), x[:-1]])


def _group_scan(s_bytes, sig_in):
    """Soft fusion-group footprint R_l = S_l + sigma_in[l] * R_{l-1}.

    The differentiable analogue of Eq. (24)'s per-group sum; with binary
    sigma the scan reproduces the exact running group totals.
    """

    def step(r_prev, xs):
        s_l, sg = xs
        r = s_l + sg * r_prev
        return r, r

    _, r = jax.lax.scan(step, 0.0, (s_bytes, sig_in))
    return r


# --------------------------------------------------------------------------
# cost aggregation (fusion boundary + latency + energy)
# --------------------------------------------------------------------------

def fusion_costs(comp, sigma, edge_mask, layer_mask, hw):
    """Fusion-modulated per-level accesses, latency and energy per layer.

    Args:
      comp:       [L, NCOMP] traffic components from the L1 kernel.
      sigma:      [L] continuous fusion variable for edge l -> l+1, in [0,1].
      edge_mask:  [L] 1.0 where that edge is fusible.
      layer_mask: [L] real-layer mask.
      hw:         [NHW] hardware vector (see constants.py).
    """
    sig_out = sigma * edge_mask * layer_mask          # edge leaving layer l
    sig_in = _shift_in(sig_out)

    ops = comp[:, C.C_OPS]
    pes = jnp.maximum(comp[:, C.C_PES], 1.0)
    fill2_i = comp[:, C.C_FILL2_I]
    fill2_w = comp[:, C.C_FILL2_W]
    fill0_w = comp[:, C.C_FILL0_W]
    read_pe_i = comp[:, C.C_READPE_I]
    accwb = comp[:, C.C_ACCWB_O]
    wb0 = comp[:, C.C_WB0_O]
    read0_w = comp[:, C.C_READ0_W]

    # Fusion-aware boundary, Eqs. (13)-(15).
    wb3 = (1.0 - sig_out) * wb0                       # L1 -> L3 write-back
    copy12 = sig_out * wb0                            # L1 -> L2 on-chip copy
    fill2_i_eff = (1.0 - sig_in) * fill2_i            # consumer skips DRAM

    a3 = fill2_i_eff + fill2_w + wb3                  # DRAM port traffic
    a2 = fill2_i_eff + fill2_w + fill0_w + read_pe_i + copy12
    a1 = accwb + wb0                                  # acc writes + drains
    a0 = fill0_w + read0_w                            # PE register file

    eb = hw[C.HW_EB]
    # Roofline latency, Eq. (16); L0 is array-internal (bandwidth-matched).
    lat = jnp.maximum(ops / pes,
                      jnp.maximum(a3 * eb / hw[C.HW_BW3],
                                  jnp.maximum(a2 * eb / hw[C.HW_BW2],
                                              a1 * eb / hw[C.HW_BW1])))
    lat = lat * layer_mask

    # Energy, Eqs. (17)-(19).
    en = (ops * hw[C.HW_EPO]
          + a3 * hw[C.HW_EPA3] + a2 * hw[C.HW_EPA2]
          + a1 * hw[C.HW_EPA1] + a0 * hw[C.HW_EPA0])
    en = en * layer_mask

    latency = jnp.sum(lat)
    energy = jnp.sum(en)
    return {
        "access": jnp.stack([a0, a1, a2, a3], axis=1) * layer_mask[:, None],
        "lat_l": lat,
        "en_l": en,
        "latency": latency,
        "energy": energy,
        "edp": energy * latency,
        "wb3": wb3,
        "copy12": copy12,
    }


# --------------------------------------------------------------------------
# penalties (Eqs. (20)-(26))
# --------------------------------------------------------------------------

def penalties(theta, factors, t3, comp, sigma, edge_mask, layer_mask, hw):
    """Normalized mapping-validity, memory-capacity, alignment penalties."""
    sd = jnp.asarray(C.SPATIAL_DIMS, jnp.float32)
    lm2 = layer_mask[:, None]

    # --- P_map = P_valid + P_spatial (Eqs. (21)-(23)) ---------------------
    # Violations are measured in LOG-relative form, matching the log-EDP
    # objective's scale: a 2x overflow costs the same no matter whether it
    # is 2 KB over an 1 KB budget or 1 MB over 512 KB. (The paper's raw
    # quadratic form makes penalty gradients dwarf the objective by many
    # orders of magnitude on large workloads; DESIGN.md §2.)
    def logviol(ratio):
        return jnp.maximum(0.0, jnp.log(jnp.maximum(ratio, C.EPS))) ** 2

    t_cont = jnp.exp2(theta)                       # raw continuous factors
    p_valid = (jnp.sum(jnp.maximum(0.0, 1.0 - t_cont) ** 2
                       * layer_mask[:, None, None])
               + jnp.sum(logviol(1.0 / jnp.maximum(t3, C.EPS)) * lm2))

    sp = factors[:, :, C.SLOT_S]
    sp_eff = jnp.where(sd > 0, sp, 1.0)
    n_pe = hw[C.HW_PE_ROWS] * hw[C.HW_PE_COLS]
    pes = jnp.prod(sp_eff, axis=1)
    p_spatial = jnp.sum(logviol(pes / n_pe) * layer_mask)
    # Gemmini refinement: per-axis limits (K on columns, C on rows).
    p_spatial += jnp.sum(
        logviol(sp[:, C.DIM_K] / hw[C.HW_PE_COLS]) * layer_mask)
    p_spatial += jnp.sum(
        logviol(sp[:, C.DIM_C] / hw[C.HW_PE_ROWS]) * layer_mask)
    p_map = p_valid + p_spatial

    # --- P_mem (Eqs. (24)-(25)) -------------------------------------------
    eb = hw[C.HW_EB]
    s_l2 = (comp[:, C.C_SW2] + comp[:, C.C_SI2]) * eb      # per-layer bytes
    sig_out = sigma * edge_mask * layer_mask
    r = _group_scan(s_l2, _shift_in(sig_out))
    p_mem = jnp.sum(logviol(r / hw[C.HW_C2]) * layer_mask)
    s_l1 = comp[:, C.C_SO1] * ACC_BYTES
    p_mem += jnp.sum(logviol(s_l1 / hw[C.HW_C1]) * layer_mask)

    # --- P_align (Eq. (26)), sigma-weighted so it binds where fusing ------
    tp, tq = comp[:, C.C_TP2], comp[:, C.C_TQ2]
    tk, tc = comp[:, C.C_TK2], comp[:, C.C_TC2]

    def rel(a, b):
        return ((a - b) / (a + b + C.EPS)) ** 2

    def nxt(x):
        return jnp.concatenate([x[1:], jnp.ones((1,), x.dtype)])

    pair = rel(tp, nxt(tp)) + rel(tq, nxt(tq)) + rel(tk, nxt(tc))
    p_align = jnp.sum(pair * sig_out)

    return p_map, p_mem, p_align


def _violation(comp, t3, factors, sigma_bin, edge_mask, layer_mask, hw):
    """Hard feasibility signal (relative violation, 0 = feasible)."""
    eb = hw[C.HW_EB]
    s_l2 = (comp[:, C.C_SW2] + comp[:, C.C_SI2]) * eb
    sig_out = sigma_bin * edge_mask * layer_mask
    r = _group_scan(s_l2, _shift_in(sig_out))
    viol = jnp.sum(jnp.maximum(0.0, r / hw[C.HW_C2] - 1.0) * layer_mask)
    viol += jnp.sum(
        jnp.maximum(0.0, comp[:, C.C_SO1] * ACC_BYTES / hw[C.HW_C1] - 1.0)
        * layer_mask)
    sd = jnp.asarray(C.SPATIAL_DIMS, jnp.float32)
    sp = factors[:, :, C.SLOT_S]
    pes = jnp.prod(jnp.where(sd > 0, sp, 1.0), axis=1)
    n_pe = hw[C.HW_PE_ROWS] * hw[C.HW_PE_COLS]
    viol += jnp.sum(jnp.maximum(0.0, pes / n_pe - 1.0) * layer_mask)
    viol += jnp.sum(jnp.maximum(0.0, 1.0 - t3) * layer_mask[:, None])
    return viol


# --------------------------------------------------------------------------
# AOT entry points
# --------------------------------------------------------------------------

def loss_fn(theta, sigma_logit, dims, div, div_mask, layer_mask, edge_mask,
            gumbel, tau, alpha, lam, hw):
    """Augmented loss (Eq. (20)) on the continuous relaxation."""
    soft, hard = gumbel_snap_ad(theta, div, div_mask, gumbel, tau, alpha)
    # Straight-through estimator: discrete forward, soft backward.
    st = soft + jax.lax.stop_gradient(hard - soft)
    comp, t3 = traffic_ad(st, dims, layer_mask)
    sigma = jax.nn.sigmoid(sigma_logit)
    cost = fusion_costs(comp, sigma, edge_mask, layer_mask, hw)
    p_map, p_mem, p_align = penalties(
        theta, st, t3, comp, sigma, edge_mask, layer_mask, hw)
    pen = p_map + p_mem + p_align
    loss = jnp.log(cost["edp"] + C.EPS) + lam.reshape(()) * pen
    aux = (cost["edp"], cost["energy"], cost["latency"], pen)
    return loss, aux


def loss_and_grad(theta, sigma_logit, dims, div, div_mask, layer_mask,
                  edge_mask, gumbel, tau, alpha, lam, hw):
    """The gradient-search hot path: value, aux metrics, and gradients."""
    (loss, aux), (g_theta, g_sigma) = jax.value_and_grad(
        loss_fn, argnums=(0, 1), has_aux=True)(
            theta, sigma_logit, dims, div, div_mask, layer_mask, edge_mask,
            gumbel, tau, alpha, lam, hw)
    edp, energy, latency, pen = aux
    return loss, edp, energy, latency, pen, g_theta, g_sigma


def eval_one(factors, sigma_bin, dims, layer_mask, edge_mask, hw):
    """Discrete evaluation of one decoded strategy."""
    comp, t3 = traffic(factors, dims, layer_mask)
    cost = fusion_costs(comp, sigma_bin, edge_mask, layer_mask, hw)
    viol = _violation(comp, t3, factors, sigma_bin, edge_mask, layer_mask, hw)
    return cost["edp"], cost["energy"], cost["latency"], viol


def eval_batch(factors, sigma_bin, dims, layer_mask, edge_mask, hw):
    """Population evaluation for GA/BO: one PJRT call per generation.

    factors: [B, L, 7, 4]; sigma_bin: [B, L]. The traffic kernel runs once
    over the flattened [B*L] layer axis (single grid launch), then the
    per-candidate reductions are vectorized with vmap.
    """
    b, l = factors.shape[0], factors.shape[1]
    flat = factors.reshape(b * l, 7, 4)
    dims_b = jnp.broadcast_to(dims, (b, l, 7)).reshape(b * l, 7)
    lm_b = jnp.broadcast_to(layer_mask, (b, l)).reshape(b * l)
    comp, t3 = traffic(flat, dims_b, lm_b)
    comp = comp.reshape(b, l, C.NCOMP)
    t3 = t3.reshape(b, l, 7)

    def one(comp_i, t3_i, fac_i, sig_i):
        cost = fusion_costs(comp_i, sig_i, edge_mask, layer_mask, hw)
        viol = _violation(comp_i, t3_i, fac_i, sig_i, edge_mask,
                          layer_mask, hw)
        return cost["edp"], cost["energy"], cost["latency"], viol

    return jax.vmap(one)(comp, t3, factors, sigma_bin)


def detail(factors, sigma_bin, dims, layer_mask, edge_mask, hw):
    """Single-strategy per-layer breakdown for validation and Fig 3."""
    comp, t3 = traffic(factors, dims, layer_mask)
    cost = fusion_costs(comp, sigma_bin, edge_mask, layer_mask, hw)
    return (cost["edp"], cost["energy"], cost["latency"],
            comp, cost["access"], cost["lat_l"], cost["en_l"], t3)
