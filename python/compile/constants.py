"""Shared constants for the FADiff differentiable cost model.

These mirror `rust/src/costmodel/` exactly — any change here must be
reflected there (cross-checked by the runtime consistency tests).

Problem space: the unified 7-dim space of the paper (Sec 3.1.1),
  N, K, C, P, Q, R, S
GEMM layers use P for the M (row) dimension, K for output columns, C for
the reduction dimension, N for batch; R = S = 1.

Memory hierarchy (Sec 2.1, Gemmini):
  L0 = PE registers (weights, weight-stationary)
  L1 = accumulator (outputs / partial sums only)
  L2 = scratchpad (inputs + weights)
  L3 = DRAM
"""

# ---- problem dimensions -------------------------------------------------
DIM_N, DIM_K, DIM_C, DIM_P, DIM_Q, DIM_R, DIM_S = range(7)
NDIMS = 7
DIM_NAMES = ["N", "K", "C", "P", "Q", "R", "S"]

# ---- factor slots: theta[..., slot] ------------------------------------
# temporal tiling factors at L0, L1, L2; spatial factor (PE array, at L0).
# The DRAM (L3) temporal factor is DERIVED as dim / (t0*t1*t2*s) so the
# per-dimension product constraint holds by construction.
SLOT_T0, SLOT_T1, SLOT_T2, SLOT_S = range(4)
NSLOTS = 4

# ---- tensor membership masks (which dims index each tensor) ------------
#          N  K  C  P  Q  R  S
W_DIMS = [0, 1, 1, 0, 0, 1, 1]  # weights:  K,C,R,S
I_DIMS = [1, 0, 1, 1, 1, 1, 1]  # inputs:   N,C,(P,Q,R,S via sliding window; halo ignored)
O_DIMS = [1, 1, 0, 1, 1, 0, 0]  # outputs:  N,K,P,Q

# Spatial unrolling is allowed on K (array columns) and C (array rows)
# only, matching Gemmini's 2-D weight-stationary systolic array.
SPATIAL_DIMS = [0, 1, 1, 0, 0, 0, 0]

# ---- traffic component indices (kernel output comp[L, NCOMP]) ----------
C_OPS = 0        # total MACs
C_PES = 1        # effective PEs = prod of spatial factors
C_FILL2_I = 2    # DRAM -> L2 fill of inputs            (elements)
C_FILL2_W = 3    # DRAM -> L2 fill of weights
C_FILL0_W = 4    # L2 -> L0 fill of weights
C_READPE_I = 5   # L2 -> PE supply reads of inputs   = Ops / Bcast_I
C_ACCWB_O = 6    # PE -> L1 accumulation write-back  = Ops / Reduce_O
C_WB0_O = 7      # L1 -> L3 baseline output write-back (pre-fusion)
C_SW2 = 8        # W tile footprint at L2 (elements)
C_SI2 = 9        # I tile footprint at L2 (elements)
C_SO1 = 10       # O tile footprint at L1 (elements)
C_TP2 = 11       # P tile extent at L2 (output tile rows)
C_TQ2 = 12       # Q tile extent at L2
C_TK2 = 13       # K tile extent at L2 (output channels on-chip)
C_TC2 = 14       # C tile extent at L2 (input channels on-chip)
C_READ0_W = 15   # L0 -> PE weight reads             = Ops / Bcast_W
NCOMP = 16

# ---- hardware vector hw[NHW] -------------------------------------------
HW_PE_ROWS = 0    # systolic array rows  (spatial C limit)
HW_PE_COLS = 1    # systolic array cols  (spatial K limit)
HW_C1 = 2         # accumulator capacity, bytes
HW_C2 = 3         # scratchpad capacity, bytes
HW_BW3 = 4        # DRAM bandwidth, bytes / cycle
HW_BW2 = 5        # scratchpad bandwidth, bytes / cycle
HW_BW1 = 6        # accumulator bandwidth, bytes / cycle
HW_EPA3 = 7       # DRAM energy, pJ / element access
HW_EPA2 = 8       # scratchpad EPA, pJ / element (from the EPA MLP)
HW_EPA1 = 9       # accumulator EPA, pJ / element (from the EPA MLP)
HW_EPA0 = 10      # PE register EPA, pJ / element
HW_EPO = 11       # compute energy, pJ / MAC
HW_EB = 12        # bytes per element
NHW = 16          # padded

# ---- AOT artifact static shapes ----------------------------------------
L_MAX = 32        # padded layer count (largest zoo model has 29 layers)
K_MAX = 32        # padded divisor-candidate count per (dim, slot)
B_EVAL = 64       # population batch for the discrete eval artifact

EPS = 1e-9
NEG_INF = -1e30
