"""AOT lowering: JAX (L2) -> HLO text artifacts for the Rust (L3) runtime.

Usage (from `make artifacts`):
    cd python && python -m compile.aot --out ../artifacts

Emits, under the output directory:
    fadiff_grad.hlo.txt    loss_and_grad   (FADiff / DOSA hot path)
    fadiff_eval.hlo.txt    eval_batch      (GA / BO population eval)
    fadiff_detail.hlo.txt  detail          (validation, Fig 3 breakdowns)
    manifest.json          shapes + operand order for each artifact

Interchange is HLO *text*, not a serialized HloModuleProto: the `xla`
crate's xla_extension 0.5.1 rejects jax>=0.5 protos (64-bit instruction
ids), while the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import constants as C
from . import model

F32 = jnp.float32


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def grad_specs(l=C.L_MAX, k=C.K_MAX):
    """(name, spec) list for `loss_and_grad`, in operand order."""
    return [
        ("theta", _spec(l, 7, 4)),
        ("sigma_logit", _spec(l)),
        ("dims", _spec(l, 7)),
        ("div", _spec(l, 7, k)),
        ("div_mask", _spec(l, 7, k)),
        ("layer_mask", _spec(l)),
        ("edge_mask", _spec(l)),
        ("gumbel", _spec(l, 7, 4, k)),
        ("tau", _spec()),
        ("alpha", _spec()),
        ("lam", _spec()),
        ("hw", _spec(C.NHW)),
    ]


def eval_specs(b=C.B_EVAL, l=C.L_MAX):
    return [
        ("factors", _spec(b, l, 7, 4)),
        ("sigma_bin", _spec(b, l)),
        ("dims", _spec(l, 7)),
        ("layer_mask", _spec(l)),
        ("edge_mask", _spec(l)),
        ("hw", _spec(C.NHW)),
    ]


def detail_specs(l=C.L_MAX):
    return [
        ("factors", _spec(l, 7, 4)),
        ("sigma_bin", _spec(l)),
        ("dims", _spec(l, 7)),
        ("layer_mask", _spec(l)),
        ("edge_mask", _spec(l)),
        ("hw", _spec(C.NHW)),
    ]


GRAD_OUTPUTS = [
    ("loss", []), ("edp", []), ("energy", []), ("latency", []),
    ("penalty", []),
    ("grad_theta", [C.L_MAX, 7, 4]), ("grad_sigma", [C.L_MAX]),
]
EVAL_OUTPUTS = [
    ("edp", [C.B_EVAL]), ("energy", [C.B_EVAL]), ("latency", [C.B_EVAL]),
    ("violation", [C.B_EVAL]),
]
DETAIL_OUTPUTS = [
    ("edp", []), ("energy", []), ("latency", []),
    ("comp", [C.L_MAX, C.NCOMP]), ("access", [C.L_MAX, 4]),
    ("lat_l", [C.L_MAX]), ("en_l", [C.L_MAX]), ("t3", [C.L_MAX, 7]),
]


def to_hlo_text(fn, specs):
    """Lower a jitted fn at the given example specs to HLO text."""
    lowered = jax.jit(fn).lower(*[s for _, s in specs])
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


ARTIFACTS = {
    "fadiff_grad": (model.loss_and_grad, grad_specs, GRAD_OUTPUTS),
    "fadiff_eval": (model.eval_batch, eval_specs, EVAL_OUTPUTS),
    "fadiff_detail": (model.detail, detail_specs, DETAIL_OUTPUTS),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="comma-separated artifact subset")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {
        "l_max": C.L_MAX,
        "k_max": C.K_MAX,
        "b_eval": C.B_EVAL,
        "nhw": C.NHW,
        "ncomp": C.NCOMP,
        "artifacts": {},
    }
    only = set(args.only.split(",")) if args.only else None
    for name, (fn, mkspecs, outs) in ARTIFACTS.items():
        if only and name not in only:
            continue
        specs = mkspecs()
        text = to_hlo_text(fn, specs)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [{"name": n, "shape": list(s.shape)}
                       for n, s in specs],
            "outputs": [{"name": n, "shape": shape} for n, shape in outs],
        }
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
