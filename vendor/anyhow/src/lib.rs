//! Minimal, dependency-free stand-in for the `anyhow` crate, vendored so
//! the offline build image needs no registry access (DESIGN: hand-rolled
//! substrates, see `fadiff::util`).
//!
//! Covers exactly the surface `fadiff` uses: the [`Error`] type with a
//! context chain, the [`Result`] alias, the [`anyhow!`] / [`bail!`]
//! macros (with inline format captures), the [`Context`] extension trait
//! on `Result`, and a blanket `From` impl so `?` converts any standard
//! error. Like the real crate, `Error` deliberately does NOT implement
//! `std::error::Error` — that is what makes the blanket impls coherent.

use std::fmt::{self, Display};

/// An error message with an optional chain of underlying causes.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from anything printable.
    pub fn msg<M: Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// The outermost (most recent context) message.
    pub fn message(&self) -> &str {
        &self.msg
    }

    /// The chain of messages, outermost first.
    pub fn chain(&self) -> Vec<&str> {
        let mut out = vec![self.msg.as_str()];
        let mut cur = &self.source;
        while let Some(e) = cur {
            out.push(e.msg.as_str());
            cur = &e.source;
        }
        out
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            let mut cur = &self.source;
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = &e.source;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = &self.source;
        let mut first = true;
        while let Some(e) = cur {
            if first {
                write!(f, "\n\nCaused by:")?;
                first = false;
            }
            write!(f, "\n    {}", e.msg)?;
            cur = &e.source;
        }
        Ok(())
    }
}

// `?` conversion from any standard error. Coherent because `Error`
// itself does not implement `std::error::Error` (the real anyhow uses
// the same trick).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::msg(&e)
    }
}

/// Private conversion helper so [`Context`] can be implemented once for
/// both `Result<T, impl std::error::Error>` and `Result<T, Error>`
/// (mirrors anyhow's `ext::StdError` sealed-trait pattern).
mod ext {
    pub trait IntoError {
        fn into_error(self) -> crate::Error;
    }

    impl<E> IntoError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn into_error(self) -> crate::Error {
            crate::Error::msg(&self)
        }
    }

    impl IntoError for crate::Error {
        fn into_error(self) -> crate::Error {
            self
        }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// results, exactly as call sites expect from the real crate.
pub trait Context<T, E> {
    /// Attach a fixed context message to the error.
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static;

    /// Attach a lazily-built context message to the error.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: ext::IntoError,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_error().context(f()))
    }
}

/// Construct an [`Error`] from a format string (with inline captures) or
/// from any printable expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn macro_formats_with_captures() {
        let name = "theta";
        let e = anyhow!("unknown artifact {name:?}");
        assert_eq!(e.to_string(), "unknown artifact \"theta\"");
        let e2 = anyhow!("expected {} got {}", 2, 3);
        assert_eq!(e2.to_string(), "expected 2 got 3");
        let e3 = anyhow!(String::from("plain"));
        assert_eq!(e3.to_string(), "plain");
    }

    #[test]
    fn bail_returns_err() {
        fn f(x: usize) -> Result<usize> {
            if x == 0 {
                bail!("zero not allowed");
            }
            Ok(x)
        }
        assert!(f(1).is_ok());
        assert_eq!(f(0).unwrap_err().to_string(), "zero not allowed");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<f64> {
            Ok(s.parse::<f64>()?)
        }
        assert_eq!(parse("2.5").unwrap(), 2.5);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn context_on_std_and_anyhow_errors() {
        let e: Result<(), std::io::Error> = Err(io_err());
        let e = e.with_context(|| "reading manifest".to_string());
        let err = e.unwrap_err();
        assert_eq!(err.to_string(), "reading manifest");
        assert_eq!(err.chain(), vec!["reading manifest", "gone"]);

        let inner: Result<()> = Err(anyhow!("inner"));
        let outer = inner.context("outer").unwrap_err();
        assert_eq!(format!("{outer:#}"), "outer: inner");
        assert_eq!(outer.to_string(), "outer");
    }

    #[test]
    fn debug_prints_cause_chain() {
        let e = Error::msg("low").context("mid").context("high");
        let dbg = format!("{e:?}");
        assert!(dbg.starts_with("high"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("low"));
    }
}
