//! Offline stub of the PJRT-backed `xla` crate the FADiff runtime links
//! against. The host-side pieces ([`Literal`] packing, shape checks) are
//! real; anything that would need the native XLA/PJRT runtime reports
//! itself unavailable with an actionable error instead.
//!
//! The contract mirrors exactly the subset `fadiff::runtime` and
//! `fadiff::search::gradient` use. Swapping in a real PJRT-backed `xla`
//! crate (same API) re-enables artifact execution without touching
//! `fadiff` itself; until then, `Runtime::load` still works (manifest
//! parsing, error paths) and compilation/execution fail gracefully so
//! native-cost-model code paths stay fully usable.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

/// Error type; call sites format it with `{:?}`.
pub struct XlaError {
    msg: String,
}

impl XlaError {
    fn new(msg: impl Into<String>) -> XlaError {
        XlaError { msg: msg.into() }
    }

    fn unavailable(what: &str) -> XlaError {
        XlaError::new(format!(
            "{what} unavailable: fadiff was built against the offline \
             stub `xla` crate; link a PJRT-backed xla crate (and run \
             `make artifacts`) to execute AOT artifacts"
        ))
    }
}

impl fmt::Debug for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for XlaError {}

/// Result alias matching the real crate's fallible surface.
pub type Result<T> = std::result::Result<T, XlaError>;

/// Element types a [`Literal`] can be unpacked to.
pub trait NativeType: Copy {
    fn from_f32(x: f32) -> Self;
}

impl NativeType for f32 {
    fn from_f32(x: f32) -> f32 {
        x
    }
}

/// A host tensor: flat f32 data plus a shape (empty = scalar).
#[derive(Clone, Debug)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// A rank-1 literal over a host slice.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal { data: data.to_vec(), dims: vec![data.len() as i64] }
    }

    /// A rank-0 (scalar) literal.
    pub fn scalar(x: f32) -> Literal {
        Literal { data: vec![x], dims: Vec::new() }
    }

    /// Reinterpret under a new shape; element count must match.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want < 0 || want as usize != self.data.len() {
            return Err(XlaError::new(format!(
                "reshape: {} elements cannot view as {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Shape accessor (rank-0 = scalar).
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Decompose a tuple literal into its parts. The stub never produces
    /// tuples (execution is unavailable), so this only errs.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(XlaError::unavailable("tuple decomposition"))
    }

    /// Copy out as a typed host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&x| T::from_f32(x)).collect())
    }
}

/// Parsed HLO module text. The stub validates the file is readable and
/// plausibly HLO text; real parsing happens in the native crate.
pub struct HloModuleProto {
    #[allow(dead_code)]
    text: String,
}

impl HloModuleProto {
    /// Load HLO text from a file.
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path).map_err(|e| {
            XlaError::new(format!("read {path:?}: {e}"))
        })?;
        Ok(HloModuleProto { text })
    }
}

/// An XLA computation wrapping a parsed module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// The PJRT client. Creation succeeds (so manifest-level tooling and
/// error paths stay exercisable); compilation reports unavailability.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// CPU client handle.
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _private: () })
    }

    /// Compile a computation. Always unavailable in the stub.
    pub fn compile(&self, _comp: &XlaComputation)
                   -> Result<PjRtLoadedExecutable> {
        Err(XlaError::unavailable("XLA compilation"))
    }
}

/// A compiled executable. Unconstructible through the stub client, but
/// the type exists so downstream structs and signatures compile.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with device inputs. Unreachable via the stub (no
    /// executable can be built), kept for API parity.
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L])
                                       -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError::unavailable("XLA execution"))
    }
}

/// A device buffer handle.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(XlaError::unavailable("device-to-host transfer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(l.dims(), &[6]);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.dims(), &[2, 3]);
        assert_eq!(r.to_vec::<f32>().unwrap(),
                   vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(l.reshape(&[4, 2]).is_err());
        let s = Literal::scalar(7.5);
        assert_eq!(s.dims().len(), 0);
        assert_eq!(s.to_vec::<f32>().unwrap(), vec![7.5]);
    }

    #[test]
    fn client_compiles_nothing() {
        let client = PjRtClient::cpu().unwrap();
        let proto = HloModuleProto { text: String::new() };
        let comp = XlaComputation::from_proto(&proto);
        let err = client.compile(&comp).unwrap_err();
        assert!(format!("{err:?}").contains("stub"));
    }

    #[test]
    fn missing_file_errors_with_path() {
        let err = HloModuleProto::from_text_file("/no/such/ghost.hlo.txt")
            .unwrap_err();
        assert!(format!("{err:?}").contains("ghost.hlo.txt"));
    }
}
