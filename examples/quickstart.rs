//! Quickstart: optimize ResNet-18 deployment on the large Gemmini with
//! FADiff and print the resulting strategy.
//!
//! Run with:  cargo run --release --example quickstart
//! (runs everywhere on the native differentiable backend; `make
//! artifacts` once beforehand lets PJRT accelerate the inner loop)

use fadiff::config::{load_config, repo_root};
use fadiff::costmodel;
use fadiff::runtime::Runtime;
use fadiff::search::{gradient, Budget};
use fadiff::workload::{zoo, DIM_NAMES};

fn main() -> anyhow::Result<()> {
    // 1. probe the optional PJRT accelerator (native backend otherwise)
    let rt = Runtime::load_if_available(&repo_root().join("artifacts"));
    let backend = if rt.is_some() {
        "PJRT (AOT artifacts)"
    } else {
        "native differentiable model"
    };
    println!("gradient backend: {backend}");

    // 2. pick a workload and a hardware configuration
    let workload = zoo::resnet18();
    let hw = load_config(&repo_root(), "large")?;
    println!("workload: {} ({} layers, {:.2} GMACs)",
             workload.name, workload.len(),
             workload.total_ops() / 1e9);
    println!("hardware: {}x{} PEs, {} KB scratchpad, {} KB accumulator",
             hw.pe_rows, hw.pe_cols,
             hw.c2_bytes / 1024.0, hw.c1_bytes / 1024.0);

    // 3. run the fusion-aware gradient search (10 s budget). On the
    //    native backend the default config's 8 restarts step as
    //    parallel chains — each gets the full schedule and the worst
    //    half periodically respawns from the best chain.
    let cfg = gradient::GradientConfig::default();
    println!("parallel chains: {}", cfg.chain_count());
    let result = gradient::optimize(
        rt.as_ref(), &workload, &hw, &cfg,
        Budget { seconds: 10.0, max_iters: usize::MAX },
    )?;

    // 4. inspect the result
    println!("\nbest EDP     : {:.4e} pJ*cycles", result.edp);
    println!("energy       : {:.4e} pJ", result.energy);
    println!("latency      : {:.4e} cycles ({:.3} ms @ 1 GHz)",
             result.latency, result.latency / 1e6);
    println!("iterations   : {} (evals {})", result.iters, result.evals);

    println!("\nfusion groups:");
    for (a, b) in result.best.groups() {
        let names: Vec<&str> = workload.layers[a..=b]
            .iter()
            .map(|l| l.name.as_str())
            .collect();
        if a == b {
            println!("  [single] {}", names[0]);
        } else {
            println!("  [fused ] {}", names.join(" -> "));
        }
    }

    // 5. show one layer's decoded mapping in detail
    let li = 1;
    let m = &result.best.mappings[li];
    println!("\nmapping of {} (dims N,K,C,P,Q,R,S = {:?}):",
             workload.layers[li].name, workload.layers[li].dims);
    println!("  {:>4} {:>6} {:>6} {:>6} {:>8}", "dim", "t_L0", "t_L1",
             "t_L2", "spatial");
    for d in 0..7 {
        println!("  {:>4} {:>6} {:>6} {:>6} {:>8}", DIM_NAMES[d],
                 m.factors[d][0], m.factors[d][1], m.factors[d][2],
                 m.factors[d][3]);
    }

    // 6. verify hardware validity end to end
    costmodel::feasible(&result.best, &workload, &hw)
        .expect("strategy must be hardware-valid");
    println!("\nstrategy validated: fits PE array, scratchpad and \
              accumulator budgets");
    Ok(())
}
