//! Gap report: solve the exhaustively-enumerable `micro-*` zoo trio
//! with the branch-and-bound exact mapper, then run every baseline
//! method under the same budget and print each method's *measured*
//! optimality gap — the absolute comparison Table 1 cannot give
//! (Table 1 only ranks methods against each other).
//!
//! Run with:  cargo run --release --example gap_report
//! (everything runs on the native backends; no AOT artifacts needed)
//!
//! The same report is served over the wire by the coordinator's `gap`
//! verb — see docs/protocol.md and docs/exact.md.

use fadiff::coordinator::JobRequest;
use fadiff::experiments::gap::{self, GapReport};

fn main() -> anyhow::Result<()> {
    let workloads = ["micro-mlp", "micro-gemm", "micro-chain"];
    let methods = Vec::new(); // default panel: fadiff, ga, bo, random

    let mut reports: Vec<GapReport> = Vec::new();
    for name in workloads {
        println!("solving {name} exactly + running baselines ...");
        let base = JobRequest {
            workload: name.to_string(),
            config: "large".to_string(),
            seconds: 5.0,
            max_iters: 20_000,
            seed: 1,
            ..Default::default()
        };
        let rep = gap::measure(None, &base, &methods)?;
        println!(
            "  exact EDP {:.4e} ({}) — {} nodes expanded, {} pruned",
            rep.exact_edp,
            if rep.certified { "certified" } else { "UNCERTIFIED" },
            rep.nodes_expanded,
            rep.pruned,
        );
        reports.push(rep);
    }

    // one Table-1-style block: a row per workload, a gap per method
    let columns: Vec<String> = reports[0]
        .rows
        .iter()
        .map(|r| r.method.clone())
        .collect();
    println!("\nmeasured optimality gaps (vs certified optimum):\n");
    print!("{}", GapReport::header(&columns));
    for rep in &reports {
        print!("{}", rep.row());
    }

    // the oracle is the floor by construction: every certified row's
    // gaps are non-negative
    for rep in &reports {
        assert!(rep.certified, "{}: oracle should certify", rep.workload);
        for row in &rep.rows {
            assert!(row.gap >= -1e-12,
                    "{}: {} beat a certified optimum",
                    rep.workload, row.method);
        }
    }
    println!("\nall gaps >= 0: no method beat the certified optimum");
    Ok(())
}
