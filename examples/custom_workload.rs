//! Custom workloads end-to-end: define a model as a JSON workload spec
//! (no code, no rebuild), validate it, and run every search method on
//! it through the coordinator — exactly what the serving layer does
//! with the protocol's `workload_spec` parameter or a
//! `data/workloads/*.json` file.
//!
//! Run with:  cargo run --release --example custom_workload

use std::sync::Arc;

use fadiff::coordinator::{execute_job, JobRequest, Method};
use fadiff::workload::spec;

/// A small edge-vision backbone that exists nowhere in the zoo: a
/// depthwise-separable stem feeding a pooled classifier head. The
/// `blocked` edge marks the flatten boundary (not producer-consumer).
const SPEC: &str = r#"{
  "name": "edge-backbone",
  "replicas": 1,
  "layers": [
    {"name": "stem",    "kind": "conv",
     "dims": [1, 32, 3, 112, 112, 3, 3]},
    {"name": "dw1",     "kind": "depthwise",
     "dims": [1, 32, 1, 112, 112, 3, 3]},
    {"name": "pw1",     "kind": "pointwise",
     "dims": [1, 64, 32, 112, 112, 1, 1]},
    {"name": "dw2",     "kind": "depthwise",
     "dims": [1, 64, 1, 56, 56, 3, 3]},
    {"name": "pw2",     "kind": "pointwise",
     "dims": [1, 128, 64, 56, 56, 1, 1]},
    {"name": "head",    "kind": "fc",
     "dims": [1, 100, 128, 1, 1, 1, 1]}
  ],
  "blocked": [4]
}"#;

fn main() -> anyhow::Result<()> {
    // 1. parse + validate the spec (any malformed document errors here,
    //    with the same validation the TCP server applies to inline
    //    workload_spec requests)
    let workload = spec::from_str(SPEC)?;
    println!("workload  : {} ({} layers, {:.3} GMACs, fingerprint {})",
             workload.name, workload.len(),
             workload.total_ops() / 1e9,
             spec::fingerprint(&workload));
    println!("fusible   : {:?}", workload.fusible);

    // 2. run every search method on it — inline specs ride in
    //    JobRequest::spec; no zoo registration anywhere
    let spec_arc = Arc::new(workload);
    println!("\n{:<8} {:>12} {:>8} {:>8}", "method", "EDP", "iters",
             "evals");
    for method in [Method::FADiff, Method::Dosa, Method::Ga, Method::Bo,
                   Method::Random] {
        let req = JobRequest {
            workload: spec_arc.name.clone(),
            method,
            seconds: 2.0,
            max_iters: 60,
            seed: 7,
            spec: Some(Arc::clone(&spec_arc)),
            ..Default::default()
        };
        let r = execute_job(None, &req)?;
        println!("{:<8} {:>12.4e} {:>8} {:>8}", method.name(), r.edp,
                 r.iters, r.evals);
    }

    // 3. the same document would be served over TCP as:
    //    {"verb": "optimize", "method": "fadiff",
    //     "workload_spec": { ... }}
    //    or dropped into data/workloads/edge-backbone.json and run as
    //    {"verb": "optimize", "workload": "edge-backbone"}
    println!("\n(see docs/protocol.md for the wire form)");
    Ok(())
}
