//! End-to-end driver: exercises the FULL system on the paper's real
//! evaluation workload suite, proving all layers compose —
//!
//!   L1 Pallas kernels -> L2 JAX model -> AOT HLO -> L3 PJRT runtime ->
//!   gradient/GA/BO searches -> decode -> native model -> golden
//!   simulator cross-check -> experiment harnesses.
//!
//! It optimizes every Table-1 workload on both Gemmini configurations
//! with all four methods (short budgets), validates every produced
//! strategy against the independent tile simulator, reruns the Sec 4.2
//! validation and Fig 3 trends, and prints a compact reproduction
//! summary (the full-budget run is recorded in EXPERIMENTS.md).
//!
//! Run with:  cargo run --release --example end_to_end

use fadiff::config::{load_config, repo_root};
use fadiff::experiments::{fig3, table1, validation};
use fadiff::sim::tilesim;
use fadiff::workload::zoo;

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    let repo = repo_root();

    println!("=== [1/4] Table-1 suite: 5 workloads x 2 configs x 4 \
              methods (4 s budget/cell) ===");
    let t = table1::run(&repo.join("artifacts"), 4.0, 4, 1)?;
    println!("{}", table1::render(&t));

    println!("=== [2/4] golden-simulator cross-check of every FADiff \
              strategy ===");
    // re-run FADiff quickly per cell and verify the winning strategies
    // against the independent tile-walking simulator
    let rt = fadiff::runtime::Runtime::load_if_available(
        &repo.join("artifacts"));
    let mut checked = 0;
    for config in ["large", "small"] {
        let hw = load_config(&repo, config)?;
        for w in zoo::table1_suite() {
            let r = fadiff::search::gradient::optimize(
                rt.as_ref(), &w, &hw,
                &fadiff::search::gradient::GradientConfig::default(),
                fadiff::search::Budget { seconds: 2.0,
                                         max_iters: usize::MAX })?;
            let native = fadiff::costmodel::evaluate(&r.best, &w, &hw);
            let sim = tilesim::simulate(&r.best, &w, &hw);
            let ratio = sim.edp / native.edp;
            println!("  {:<14} {:<6} model {:.3e} sim {:.3e} \
                      (sim/model {:.2})",
                     w.name, config, native.edp, sim.edp, ratio);
            assert!(ratio > 0.05 && ratio < 20.0,
                    "model and simulator diverge wildly");
            checked += 1;
        }
    }
    println!("  {checked} strategies cross-checked OK");

    println!("\n=== [3/4] cost-model validation (paper Sec 4.2) ===");
    let hw = load_config(&repo, "large")?;
    let v = validation::run(&hw, 40, 11);
    println!("{}", validation::render(&v));

    println!("=== [4/4] fusion trend vs depth-first baseline (Fig 3) ===");
    let (two, three) = fig3::run(&hw);
    println!("2-layer: latency corr {:.3}, energy corr {:.3}",
             two.latency_corr, two.energy_corr);
    println!("3-layer: latency corr {:.3}, energy corr {:.3}",
             three.latency_corr, three.energy_corr);

    println!("\n=== reproduction summary ===");
    for config in ["large", "small"] {
        println!("  FADiff vs DOSA ({config}): {:.1}% EDP reduction",
                 t.improvement_vs_dosa(config) * 100.0);
        let fadiff = t.column_geomean(config, "FADiff");
        let ga = t.column_geomean(config, "GA");
        let bo = t.column_geomean(config, "BO");
        println!("    GA {:.0}x worse, BO {:.0}x worse than FADiff",
                 ga / fadiff, bo / fadiff);
    }
    println!("  cost model: access acc {:.2}, lat tau {:.2}, \
              en tau {:.2} (paper: 0.96 / 1.00 / 0.78)",
             v.mean_access_accuracy, v.mean_latency_tau,
             v.mean_energy_tau);
    println!("\nend-to-end drive completed in {:.1}s",
             t0.elapsed().as_secs_f64());
    Ok(())
}
