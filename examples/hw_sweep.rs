//! Hardware co-design sweep: how does the optimal deployment EDP move as
//! the accelerator's PE array and scratchpad scale? Sweeps custom
//! Gemmini geometries and reports FADiff-optimized EDP per point — the
//! hw-codesign workflow this framework serves.
//!
//! Run with:  cargo run --release --example hw_sweep

use fadiff::config::{custom_config, repo_root};
use fadiff::runtime::Runtime;
use fadiff::search::{gradient, Budget};
use fadiff::workload::zoo;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load_if_available(&repo_root().join("artifacts"));
    let w = zoo::mobilenet_v1();
    let budget = Budget { seconds: 4.0, max_iters: usize::MAX };
    println!("workload: {} ({:.2} GMACs)\n", w.name,
             w.total_ops() / 1e9);

    println!("--- PE array sweep (L1 64 KB, L2 512 KB) ---");
    println!("{:>8} {:>14} {:>14} {:>12}", "array", "EDP", "latency",
             "energy");
    let mut prev: Option<f64> = None;
    for pe in [8usize, 16, 32, 64] {
        let hw = custom_config(&repo_root(), pe, 64.0, 512.0)?;
        let r = gradient::optimize(
            rt.as_ref(), &w, &hw, &gradient::GradientConfig::default(),
            budget)?;
        let trend = match prev {
            Some(p) if r.edp < p => "improving",
            Some(_) => "diminishing",
            None => "",
        };
        println!("{:>5}x{:<3} {:>14.4e} {:>14.4e} {:>12.4e}  {}",
                 pe, pe, r.edp, r.latency, r.energy, trend);
        prev = Some(r.edp);
    }

    println!("\n--- scratchpad sweep (32x32 PEs, L1 64 KB) ---");
    println!("{:>8} {:>14} {:>12}", "L2 KB", "EDP", "fused edges");
    for l2 in [32.0, 128.0, 512.0, 2048.0] {
        let hw = custom_config(&repo_root(), 32, 64.0, l2)?;
        let r = gradient::optimize(
            rt.as_ref(), &w, &hw, &gradient::GradientConfig::default(),
            budget)?;
        let fused = r.best.fuse.iter().filter(|&&f| f).count();
        println!("{:>8} {:>14.4e} {:>12}", l2, r.edp, fused);
    }
    println!("\nLarger scratchpads admit more (and larger) fusion \
              groups, the effect Table 1 shows between the small and \
              large Gemmini configurations.");
    Ok(())
}
