//! Serving demo: starts the coordinator TCP service, drives it with a
//! small batch of concurrent scheduling requests from client threads,
//! and reports per-request latency + service throughput — the
//! "scheduler-as-a-service" deployment mode.
//!
//! Run with:  cargo run --release --example serve

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

use fadiff::coordinator::{server, Coordinator};

fn request(addr: std::net::SocketAddr, body: &str) -> anyhow::Result<String> {
    let mut stream = TcpStream::connect(addr)?;
    stream.write_all(body.as_bytes())?;
    stream.write_all(b"\n")?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    Ok(line.trim().to_string())
}

fn main() -> anyhow::Result<()> {
    // bind on an ephemeral port and run the server in the background
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let coord = Coordinator::new(None, 2)?;
    let server_thread =
        std::thread::spawn(move || server::serve_on(listener, coord));

    // ping until ready
    for _ in 0..50 {
        if request(addr, r#"{"verb": "ping"}"#).is_ok() {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    println!("coordinator serving on {addr}");

    // fire a batch of concurrent optimization requests. "ga" keeps the
    // demo snappy; "fadiff" also serves everywhere (native multi-chain
    // backend — add "chains": N to size its parallel restart fan-out)
    let jobs = [
        ("resnet18", "large", 3.0),
        ("mobilenet", "large", 3.0),
        ("vgg16", "small", 3.0),
        ("gpt3", "large", 3.0),
    ];
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = jobs
        .iter()
        .map(|&(wl, cfg, secs)| {
            std::thread::spawn(move || {
                let body = format!(
                    r#"{{"verb": "optimize", "workload": "{wl}", "config": "{cfg}", "method": "ga", "seconds": {secs}, "seed": 7}}"#
                );
                let t = std::time::Instant::now();
                let resp = request(addr, &body);
                (wl, cfg, t.elapsed().as_secs_f64(), resp)
            })
        })
        .collect();
    for h in handles {
        let (wl, cfg, secs, resp) = h.join().unwrap();
        let resp = resp?;
        // pull the EDP out of the JSON response
        let j = fadiff::util::json::Json::parse(&resp)?;
        let edp = j.get_f64("full_model_edp")?;
        println!("  {wl:<10} {cfg:<6} -> EDP {edp:.3e}  \
                  (request latency {secs:.2}s)");
    }
    let wall = t0.elapsed().as_secs_f64();
    println!("batch of {} requests in {:.2}s on 2 workers \
              ({:.2} jobs/s)", jobs.len(), wall,
             jobs.len() as f64 / wall);

    // sweep: one request fans a method x workload x seed grid through
    // the same queue; same-(workload, config) cells share an eval cache
    let sweep = request(
        addr,
        r#"{"verb": "sweep", "workloads": ["resnet18", "mobilenet"], "methods": ["ga", "random"], "seeds": [7], "seconds": 2.0, "max_iters": 40}"#,
    )?;
    let j = fadiff::util::json::Json::parse(&sweep)?;
    println!("sweep: {} jobs, {} completed, {} failed",
             j.get_f64("jobs")?, j.get_f64("completed")?,
             j.get_f64("failed")?);

    // metrics + graceful shutdown (note the cross-job cache counters)
    println!("metrics: {}", request(addr, r#"{"verb": "metrics"}"#)?);
    let _ = request(addr, r#"{"verb": "shutdown"}"#)?;
    let _ = server_thread.join();
    println!("server shut down cleanly");
    Ok(())
}
