//! LLM scheduling: co-optimize mapping + fusion for the GPT-3 6.7B
//! decoder block (MHA + FFN) and quantify what fusion awareness buys
//! over layer-wise (DOSA-style) optimization — the paper's motivating
//! workload.
//!
//! Run with:  cargo run --release --example llm_scheduling

use fadiff::config::{load_config, repo_root};
use fadiff::runtime::Runtime;
use fadiff::search::{gradient, Budget};
use fadiff::workload::zoo;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load_if_available(&repo_root().join("artifacts"));
    let w = zoo::gpt3_6_7b();
    println!("workload: {} — one decoder block, replicated {}x",
             w.name, w.replicas);
    println!("  {} GEMM layers, {:.1} GMACs/block",
             w.len(), w.total_ops() / 1e9);
    for (i, l) in w.layers.iter().enumerate() {
        let fusible = if i < w.fusible.len() && w.fusible[i] {
            "-> fusible ->"
        } else {
            ""
        };
        println!("    {:>14}  M={:<5} K={:<6} C={:<6} batch={:<3} {}",
                 l.name, l.dims[3], l.dims[1], l.dims[2], l.dims[0],
                 fusible);
    }

    let budget = Budget { seconds: 15.0, max_iters: usize::MAX };
    for config in ["large", "small"] {
        let hw = load_config(&repo_root(), config)?;
        println!("\n=== {config}-Gemmini ({}x{} PEs, {} KB L2) ===",
                 hw.pe_rows, hw.pe_cols, hw.c2_bytes / 1024.0);

        let fadiff = gradient::optimize(
            rt.as_ref(), &w, &hw, &gradient::GradientConfig::default(),
            budget)?;
        let dosa = gradient::optimize(
            rt.as_ref(), &w, &hw, &gradient::GradientConfig::dosa(),
            budget)?;

        let scale = w.replicas * w.replicas;
        println!("  DOSA  (layer-wise): EDP {:.4e}", dosa.edp * scale);
        println!("  FADiff (fusion-aware): EDP {:.4e}",
                 fadiff.edp * scale);
        println!("  EDP reduction: {:.1}%",
                 (1.0 - fadiff.edp / dosa.edp) * 100.0);
        let fused: Vec<String> = fadiff
            .best
            .groups()
            .iter()
            .filter(|(a, b)| b > a)
            .map(|&(a, b)| {
                w.layers[a..=b]
                    .iter()
                    .map(|l| l.name.as_str())
                    .collect::<Vec<_>>()
                    .join("->")
            })
            .collect();
        println!("  fused: {}",
                 if fused.is_empty() { "none".into() }
                 else { fused.join(", ") });
    }
    println!("\n(The paper reports larger fusion gains on the large \
              configuration than the small one — the bigger scratchpad \
              keeps fused activations resident.)");
    Ok(())
}
