//! Validation of the native differentiable backend
//! (`costmodel::grad`): finite-difference gradient checks, end-to-end
//! native gradient search vs random search at equal eval budgets,
//! parallel multi-chain determinism (same seed + same chain count =>
//! bit-identical results at any worker-pool size) and the
//! multi-chain-beats-single-chain wall-clock property, and (when real
//! AOT artifacts are present) parity against the PJRT `fadiff_grad`
//! artifact.
//!
//! The finite-difference protocol (points, step sizes, tolerances) is
//! cross-validated offline against JAX autodiff of the identical f64
//! forward: the hand-derived reverse mode agrees with autodiff to
//! ~1e-13 vector relative error, and with central differences to
//! < 3e-8 at these settings — the 1e-6 bound asserted here has > 30x
//! margin.

use std::sync::Arc;

use fadiff::config::{load_config, repo_root};
use fadiff::costmodel;
use fadiff::costmodel::grad::{GradModel, GradScratch, SnapMode};
use fadiff::costmodel::WorkloadTables;
use fadiff::runtime::stage::WorkloadStage;
use fadiff::runtime::{HostTensor, Runtime, ART_GRAD};
use fadiff::search::{gradient, random, Budget, EvalCtx, SearchResult};
use fadiff::util::rng::Rng;
use fadiff::util::threadpool::ThreadPool;
use fadiff::workload::{Workload, NDIMS};

/// Deterministic test point: theta/sigma/gumbel drawn from the repo
/// PRNG at a fixed seed (the offline JAX cross-check replicates this
/// exact stream).
fn test_point(w: &Workload, tables: &WorkloadTables)
              -> (Vec<f64>, Vec<f64>, Vec<f64>) {
    let n_theta = w.len() * NDIMS * 4;
    let n_g = n_theta * tables.k_max();
    let mut rng = Rng::new(0xF00D);
    let theta: Vec<f64> =
        (0..n_theta).map(|_| rng.range(-1.0, 6.0)).collect();
    let sigma: Vec<f64> =
        (0..w.len() - 1).map(|_| rng.range(-2.0, 2.0)).collect();
    let gumbel: Vec<f64> = (0..n_g).map(|_| rng.gumbel()).collect();
    (theta, sigma, gumbel)
}

/// Vector relative error between an analytic gradient and central
/// finite differences of `loss` over every coordinate of `x`.
fn fd_vector_rel_err<F>(grad: &[f64], x: &[f64], mut loss: F) -> f64
where
    F: FnMut(&[f64]) -> f64,
{
    let (mut num, mut den) = (0.0, 0.0);
    for i in 0..x.len() {
        let h = 2e-6 * x[i].abs().max(1.0);
        let mut xp = x.to_vec();
        xp[i] += h;
        let mut xm = x.to_vec();
        xm[i] -= h;
        let fd = (loss(&xp) - loss(&xm)) / (2.0 * h);
        num += (grad[i] - fd) * (grad[i] - fd);
        den += fd * fd;
    }
    (num / den.max(1e-300)).sqrt()
}

#[test]
fn finite_differences_validate_theta_and_sigma_gradients() {
    let hw = load_config(&repo_root(), "large").unwrap();
    let w = fadiff::workload::zoo::vgg16();
    let tables = WorkloadTables::new(&w);
    let (theta, sigma, gumbel) = test_point(&w, &tables);

    for (tau, lam) in [(2.0, 0.1), (0.5, 1.0), (0.05, 10.0)] {
        // theta: the straight-through forward is piecewise-constant in
        // theta by design, so the soft forward (whose Jacobian is the
        // exact quantity the ST backward routes through) is what
        // finite differences can check
        let soft = GradModel::new(&w, &hw, &tables, 2.0, true,
                                  SnapMode::Soft);
        let mut sc = GradScratch::new();
        let mut gt = vec![0.0; soft.n_theta()];
        let mut gs = vec![0.0; soft.n_sigma()];
        soft.loss_and_grad(&theta, &sigma, &gumbel, tau, lam, &mut sc,
                           &mut gt, &mut gs);
        let rel = fd_vector_rel_err(&gt, &theta, |th| {
            let mut t = vec![0.0; soft.n_theta()];
            let mut s = vec![0.0; soft.n_sigma()];
            soft.loss_and_grad(th, &sigma, &gumbel, tau, lam, &mut sc,
                               &mut t, &mut s)
                .loss
        });
        assert!(rel < 1e-6,
                "theta fd mismatch at tau={tau} lam={lam}: {rel:.3e}");

        // sigma is exactly differentiable in the optimizer's own
        // straight-through mode (the snap does not depend on sigma)
        let st = GradModel::new(&w, &hw, &tables, 2.0, true,
                                SnapMode::Straight);
        let mut gt = vec![0.0; st.n_theta()];
        let mut gs = vec![0.0; st.n_sigma()];
        st.loss_and_grad(&theta, &sigma, &gumbel, tau, lam, &mut sc,
                         &mut gt, &mut gs);
        let rel = fd_vector_rel_err(&gs, &sigma, |sg| {
            let mut t = vec![0.0; st.n_theta()];
            let mut s = vec![0.0; st.n_sigma()];
            st.loss_and_grad(&theta, sg, &gumbel, tau, lam, &mut sc,
                             &mut t, &mut s)
                .loss
        });
        assert!(rel < 1e-6,
                "sigma fd mismatch at tau={tau} lam={lam}: {rel:.3e}");
    }
}

#[test]
fn native_gradient_beats_random_at_equal_eval_budget() {
    // the paper's core efficiency claim, on the always-on backend:
    // with the same number of cost-model evaluations, gradient descent
    // over the relaxation finds far better strategies than uniform
    // sampling of the same decoded space. (Offline replication of this
    // exact protocol shows 2.5-25x EDP margins across seeds.)
    let hw = load_config(&repo_root(), "large").unwrap();
    for w in [fadiff::workload::zoo::vgg16(),
              fadiff::workload::zoo::gpt3_6_7b()] {
        let cfg = gradient::GradientConfig {
            restarts: 1,
            ..Default::default()
        };
        let grad = gradient::optimize(None, &w, &hw, &cfg,
                                      Budget::iters(200))
            .unwrap();
        assert!(grad.evals > 0 && grad.edp.is_finite());
        costmodel::feasible(&grad.best, &w, &hw).unwrap();
        let rand = random::optimize(&w, &hw, 1,
                                    Budget::iters(grad.evals))
            .unwrap();
        assert!(grad.edp < rand.edp,
                "{}: native gradient {:.3e} must beat random {:.3e} \
                 at {} evals",
                w.name, grad.edp, rand.edp, grad.evals);
    }
}

#[test]
fn native_gradient_search_improves_over_trivial() {
    let hw = load_config(&repo_root(), "large").unwrap();
    let w = fadiff::workload::zoo::vgg16();
    let trivial = costmodel::evaluate(
        &fadiff::mapping::Strategy::trivial(&w), &w, &hw);
    let cfg = gradient::GradientConfig {
        restarts: 1,
        ..Default::default()
    };
    let r = gradient::optimize(None, &w, &hw, &cfg, Budget::iters(60))
        .unwrap();
    assert!(r.edp < trivial.edp * 0.01,
            "native gradient should crush trivial: {} vs {}", r.edp,
            trivial.edp);
    costmodel::feasible(&r.best, &w, &hw).unwrap();
    assert!(!r.trace.is_empty());
    for win in r.trace.windows(2) {
        assert!(win[1].best_edp <= win[0].best_edp);
        assert!(win[1].seconds >= win[0].seconds);
    }
}

#[test]
fn native_dosa_mode_never_fuses_and_completes() {
    let hw = load_config(&repo_root(), "large").unwrap();
    let w = fadiff::workload::zoo::gpt3_6_7b();
    let cfg = gradient::GradientConfig {
        restarts: 1,
        ..gradient::GradientConfig::dosa()
    };
    let r = gradient::optimize(None, &w, &hw, &cfg, Budget::iters(60))
        .unwrap();
    assert!(r.edp.is_finite());
    assert!(r.best.fuse.iter().all(|&f| !f), "DOSA must not fuse");
    costmodel::feasible(&r.best, &w, &hw).unwrap();
}

#[test]
fn native_fadiff_not_worse_than_native_dosa() {
    // joint fusion+mapping never loses to its own layer-wise ablation
    // (the greedy-fusion decode guarantees the comparison)
    let hw = load_config(&repo_root(), "large").unwrap();
    let w = fadiff::workload::zoo::gpt3_6_7b();
    let fadiff_cfg = gradient::GradientConfig {
        restarts: 1,
        ..Default::default()
    };
    let dosa_cfg = gradient::GradientConfig {
        restarts: 1,
        ..gradient::GradientConfig::dosa()
    };
    let rf = gradient::optimize(None, &w, &hw, &fadiff_cfg,
                                Budget::iters(80))
        .unwrap();
    let rd = gradient::optimize(None, &w, &hw, &dosa_cfg,
                                Budget::iters(80))
        .unwrap();
    assert!(rf.edp <= rd.edp * 1.02,
            "native FADiff {} should not lose to DOSA {}", rf.edp,
            rd.edp);
}

/// The timing-free fingerprint of a [`SearchResult`]: everything the
/// determinism contract covers (trace timestamps are wall-clock and
/// legitimately vary run-to-run).
fn fingerprint(r: &SearchResult) -> (u64, usize, usize, Vec<u64>) {
    (r.edp.to_bits(), r.iters, r.evals,
     r.trace.iter().map(|t| t.best_edp.to_bits()).collect())
}

#[test]
fn parallel_chains_bit_identical_across_pool_sizes() {
    // the multi-chain contract: same seed + same `chains` => the same
    // SearchResult no matter how many workers step the chains. Chains
    // are chain-local state machines with per-chain RNG streams and
    // the banked decodes are offered in fixed chain order, so pool
    // sizes 1, 2 and 8 (and the pool-less scoped path) must agree
    // bit-for-bit. An iteration budget keeps the lambda ramp (and the
    // cull/respawn schedule, which engages past 50% here) off the
    // wall clock.
    let hw = load_config(&repo_root(), "large").unwrap();
    let w = fadiff::workload::zoo::vgg16();
    let cfg = gradient::GradientConfig {
        chains: 4,
        seed: 0xC0FFEE,
        ..Default::default()
    };
    let budget = Budget::iters(60);
    let base = gradient::optimize(None, &w, &hw, &cfg, budget).unwrap();
    assert!(base.edp.is_finite());
    costmodel::feasible(&base.best, &w, &hw).unwrap();
    for pool_size in [1usize, 2, 8] {
        let ctx = EvalCtx {
            pool: Some(Arc::new(ThreadPool::new(pool_size))),
            ..Default::default()
        };
        let r = gradient::optimize_ctx(None, &w, &hw, &cfg, budget,
                                       &ctx)
            .unwrap();
        assert_eq!(r.best.mappings, base.best.mappings,
                   "mappings diverged at pool size {pool_size}");
        assert_eq!(r.best.fuse, base.best.fuse,
                   "fusion diverged at pool size {pool_size}");
        assert_eq!(fingerprint(&r), fingerprint(&base),
                   "result diverged at pool size {pool_size}");
    }
}

#[test]
fn different_chain_counts_explore_differently() {
    // sanity on the chain seeding: extra chains are real extra
    // trajectories, not copies — C=4 must do 4x the gradient steps of
    // C=1 under the same per-chain iteration schedule and can only
    // improve (or tie) the incumbent, since chain 0's stream is
    // shared. The superset argument needs chain 0 untouched by the
    // cull/respawn schedule, so the budget stays at 3 decode blocks
    // (30 iters / decode_every=10) — strictly below the 4-block
    // minimum at which the first cull can ever fire.
    let hw = load_config(&repo_root(), "large").unwrap();
    let w = fadiff::workload::zoo::vgg16();
    let budget = Budget::iters(30);
    let one = gradient::optimize(
        None, &w, &hw,
        &gradient::GradientConfig { chains: 1, ..Default::default() },
        budget)
        .unwrap();
    let four = gradient::optimize(
        None, &w, &hw,
        &gradient::GradientConfig { chains: 4, ..Default::default() },
        budget)
        .unwrap();
    assert_eq!(one.iters, 30);
    assert_eq!(four.iters, 4 * 30);
    assert!(four.edp <= one.edp,
            "a superset of chains regressed: {} > {}", four.edp,
            one.edp);
}

#[test]
fn multi_chain_beats_single_chain_at_equal_wall_clock() {
    // the tentpole claim: under the paper's equal-wall-clock protocol,
    // 8 parallel chains (full schedule each, cull/respawn on) reach a
    // best-loss at least as good as one chain on multiple zoo
    // workloads. The strict comparison needs real parallelism; on a
    // small runner (< 4 cores) the chains timeshare one or two cores
    // and the property is not guaranteed, so there we only require
    // both runs to complete feasibly.
    let hw = load_config(&repo_root(), "large").unwrap();
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    for w in [fadiff::workload::zoo::vgg16(),
              fadiff::workload::zoo::gpt3_6_7b()] {
        // equal-wall-clock races are inherently noisy (parallel test
        // neighbors steal cores mid-sample), so the probabilistic
        // claim gets two independent attempts — a true regression
        // loses both; a scheduling hiccup does not
        let budget = Budget::seconds(1.5);
        let mut won = false;
        let mut last = (f64::INFINITY, f64::INFINITY);
        for attempt in 0..2u64 {
            let single = gradient::optimize(
                None, &w, &hw,
                &gradient::GradientConfig { chains: 1,
                                            seed: 3 + attempt,
                                            ..Default::default() },
                budget)
                .unwrap();
            let multi = gradient::optimize(
                None, &w, &hw,
                &gradient::GradientConfig { chains: 8,
                                            seed: 3 + attempt,
                                            ..Default::default() },
                budget)
                .unwrap();
            costmodel::feasible(&single.best, &w, &hw).unwrap();
            costmodel::feasible(&multi.best, &w, &hw).unwrap();
            last = (multi.edp, single.edp);
            if multi.edp <= single.edp * 1.001 {
                won = true;
                break;
            }
        }
        if cores >= 4 {
            assert!(won,
                    "{}: C=8 ({:.4e}) lost to C=1 ({:.4e}) at equal \
                     wall-clock on {cores} cores in both attempts",
                    w.name, last.0, last.1);
        } else if !won {
            eprintln!(
                "{}: only {cores} cores — multi-vs-single strictness \
                 skipped (C=8 {:.4e}, C=1 {:.4e})",
                w.name, last.0, last.1
            );
        }
    }
}

#[test]
fn native_matches_pjrt_artifact_when_available() {
    // semantic parity of the two backends on one loss/gradient
    // evaluation. The artifact computes in f32 and JAX splits
    // subgradients at kinks where the native model picks a side, so
    // the comparison is necessarily loose; direction must agree.
    let Some(rt) =
        Runtime::load_if_available(&repo_root().join("artifacts"))
    else {
        eprintln!("skipping: PJRT runtime unavailable");
        return;
    };
    let hw = load_config(&repo_root(), "large").unwrap();
    let w = fadiff::workload::zoo::vgg16();
    let tables = WorkloadTables::new(&w);
    assert_eq!(tables.k_max(), rt.manifest.k_max,
               "native snap sets must mirror the artifact's K_MAX");
    let (theta, sigma, gumbel) = test_point(&w, &tables);
    let (tau, lam) = (1.0, 1.0);

    // native
    let model = GradModel::new(&w, &hw, &tables, 2.0, true,
                               SnapMode::Straight);
    let mut sc = GradScratch::new();
    let mut gt = vec![0.0; model.n_theta()];
    let mut gs = vec![0.0; model.n_sigma()];
    let out = model.loss_and_grad(&theta, &sigma, &gumbel, tau, lam,
                                  &mut sc, &mut gt, &mut gs);

    // PJRT: pad to the artifact's static shapes. Padding theta rows
    // stay 0 (2^0 = 1 -> no P_valid contribution) and padded gumbel
    // slots are masked by div_mask.
    let l_max = rt.manifest.l_max;
    let k_max = rt.manifest.k_max;
    let stage = WorkloadStage::new(&w, &hw, l_max, k_max).unwrap();
    let n_theta_pad = l_max * NDIMS * 4;
    let mut theta_pad = vec![0.0f32; n_theta_pad];
    theta_pad[..theta.len()]
        .copy_from_slice(&theta.iter().map(|&x| x as f32)
                              .collect::<Vec<f32>>());
    let mut sigma_pad = vec![0.0f32; l_max];
    for (i, &s) in sigma.iter().enumerate() {
        sigma_pad[i] = s as f32;
    }
    let mut gumbel_pad = vec![0.0f32; n_theta_pad * k_max];
    for (i, &g) in gumbel.iter().enumerate() {
        gumbel_pad[i] = g as f32;
    }
    let grad_art = rt.get(ART_GRAD).unwrap();
    let pjrt_out = grad_art
        .run(&[
            HostTensor::new(theta_pad),
            HostTensor::new(sigma_pad),
            stage.dims.clone(),
            stage.div.clone(),
            stage.div_mask.clone(),
            stage.layer_mask.clone(),
            stage.edge_mask.clone(),
            HostTensor::new(gumbel_pad),
            HostTensor::scalar(tau as f32),
            HostTensor::scalar(2.0),
            HostTensor::scalar(lam as f32),
            stage.hw.clone(),
        ])
        .unwrap();
    // outputs: loss, edp, energy, latency, pen, g_theta, g_sigma
    let rel = |a: f64, b: f64| (a - b).abs() / a.abs().max(1e-30);
    assert!(rel(out.edp, pjrt_out[1][0] as f64) < 5e-2,
            "edp: native {} pjrt {}", out.edp, pjrt_out[1][0]);
    assert!(rel(out.energy, pjrt_out[2][0] as f64) < 5e-2);
    assert!(rel(out.latency, pjrt_out[3][0] as f64) < 5e-2);
    // gradient direction agreement (cosine over the real layers)
    let g_pjrt: Vec<f64> = pjrt_out[5][..gt.len()]
        .iter()
        .map(|&x| x as f64)
        .collect();
    let dot: f64 = gt.iter().zip(&g_pjrt).map(|(a, b)| a * b).sum();
    let na: f64 = gt.iter().map(|a| a * a).sum::<f64>().sqrt();
    let nb: f64 = g_pjrt.iter().map(|b| b * b).sum::<f64>().sqrt();
    assert!(dot / (na * nb).max(1e-30) > 0.98,
            "theta gradient direction diverges: cos = {}",
            dot / (na * nb).max(1e-30));
}
