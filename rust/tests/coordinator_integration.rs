//! Integration: the coordinator service end-to-end — job queueing,
//! worker dispatch, metrics, and the TCP line protocol. Native methods
//! (GA / BO / random) score on the shared `EvalEngine` and need no AOT
//! artifacts; gradient jobs degrade to per-job errors without them.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::Ordering;

use fadiff::config::repo_root;
use fadiff::coordinator::{server, Coordinator, JobRequest, Method};
use fadiff::runtime::Runtime;
use fadiff::util::json::Json;

fn small_job(workload: &str, method: Method) -> JobRequest {
    JobRequest {
        workload: workload.into(),
        config: "large".into(),
        method,
        seconds: 1.5,
        max_iters: 200,
        seed: 5,
    }
}

#[test]
fn coordinator_runs_jobs_and_counts() {
    let coord = Coordinator::new(None, 2).unwrap();
    let r = coord.run(small_job("mobilenet", Method::Ga)).unwrap();
    assert!(r.edp.is_finite() && r.edp > 0.0);
    assert!(r.full_model_edp >= r.edp);
    assert!(r.iters > 0);
    assert_eq!(coord.metrics.completed.load(Ordering::SeqCst), 1);
    assert_eq!(coord.metrics.in_flight(), 0);
}

#[test]
fn coordinator_parallel_jobs_complete() {
    let coord = Coordinator::new(None, 2).unwrap();
    let handles: Vec<_> = ["resnet18", "vgg16", "mobilenet", "gpt3"]
        .iter()
        .map(|w| coord.submit(small_job(w, Method::Random)))
        .collect();
    for h in handles {
        let r = h.wait().unwrap().unwrap();
        assert!(r.edp.is_finite());
    }
    assert_eq!(coord.metrics.completed.load(Ordering::SeqCst), 4);
}

#[test]
fn coordinator_rejects_unknown_workload() {
    let coord = Coordinator::new(None, 1).unwrap();
    let err = coord.run(small_job("alexnet", Method::FADiff));
    assert!(err.is_err());
    assert_eq!(coord.metrics.failed.load(Ordering::SeqCst), 1);
}

#[test]
fn gradient_jobs_error_cleanly_without_artifacts() {
    if Runtime::load_if_available(&repo_root().join("artifacts")).is_some()
    {
        eprintln!("skipping: PJRT runtime present, degraded path untested");
        return;
    }
    let coord = Coordinator::new(None, 1).unwrap();
    let err = coord.run(small_job("resnet18", Method::FADiff));
    let msg = err.unwrap_err().to_string();
    assert!(msg.contains("artifacts"), "unexpected error: {msg}");
    assert_eq!(coord.metrics.failed.load(Ordering::SeqCst), 1);
    // the same coordinator still serves native methods afterwards
    let ok = coord.run(small_job("resnet18", Method::Random)).unwrap();
    assert!(ok.edp.is_finite());
}

#[test]
fn coordinator_runs_gradient_jobs_when_runtime_present() {
    if Runtime::load_if_available(&repo_root().join("artifacts")).is_none()
    {
        eprintln!("skipping: PJRT runtime unavailable");
        return;
    }
    let coord = Coordinator::new(None, 2).unwrap();
    let r = coord.run(small_job("mobilenet", Method::FADiff)).unwrap();
    assert!(r.edp.is_finite() && r.edp > 0.0);
    assert_eq!(coord.metrics.completed.load(Ordering::SeqCst), 1);
}

fn send(addr: std::net::SocketAddr, body: &str) -> String {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.write_all(body.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    line.trim().to_string()
}

#[test]
fn tcp_server_full_protocol() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let coord = Coordinator::new(None, 1).unwrap();
    let t = std::thread::spawn(move || server::serve_on(listener, coord));

    // ping
    let pong = Json::parse(&send(addr, r#"{"verb": "ping"}"#)).unwrap();
    assert_eq!(pong.get("pong").unwrap(), &Json::Bool(true));

    // optimize
    let resp = send(
        addr,
        r#"{"verb": "optimize", "workload": "mobilenet", "method": "random", "seconds": 1.0, "max_iters": 50, "seed": 2}"#,
    );
    let j = Json::parse(&resp).unwrap();
    assert_eq!(j.get("ok").unwrap(), &Json::Bool(true), "{resp}");
    assert!(j.get_f64("edp").unwrap() > 0.0);

    // bad requests are answered, not dropped
    let bad = Json::parse(
        &send(addr, r#"{"verb": "optimize", "method": "quantum"}"#))
        .unwrap();
    assert_eq!(bad.get("ok").unwrap(), &Json::Bool(false));
    let garbage = Json::parse(&send(addr, "not json at all")).unwrap();
    assert_eq!(garbage.get("ok").unwrap(), &Json::Bool(false));

    // metrics reflect the one successful job
    let m = Json::parse(&send(addr, r#"{"verb": "metrics"}"#)).unwrap();
    assert_eq!(m.get_f64("completed").unwrap(), 1.0);

    // graceful shutdown
    let s = Json::parse(&send(addr, r#"{"verb": "shutdown"}"#)).unwrap();
    assert_eq!(s.get("ok").unwrap(), &Json::Bool(true));
    t.join().unwrap().unwrap();
}

#[test]
fn method_parser_roundtrip() {
    for (name, m) in [
        ("fadiff", Method::FADiff),
        ("dosa", Method::Dosa),
        ("ga", Method::Ga),
        ("bo", Method::Bo),
        ("random", Method::Random),
    ] {
        assert_eq!(Method::parse(name).unwrap(), m);
        assert_eq!(Method::parse(m.name()).unwrap(), m);
    }
    assert!(Method::parse("sgd").is_err());
}
