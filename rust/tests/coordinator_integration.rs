//! Integration: the coordinator service end-to-end — job queueing,
//! worker dispatch, metrics, and the TCP line protocol. Every method
//! serves without AOT artifacts: GA / BO / random score on the shared
//! `EvalEngine`, and the gradient methods fall back to the native
//! differentiable backend when no PJRT runtime is present.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use fadiff::config::repo_root;
use fadiff::coordinator::{server, Coordinator, JobRequest, JobStatus,
                          Method};
use fadiff::runtime::Runtime;
use fadiff::util::json::Json;

fn small_job(workload: &str, method: Method) -> JobRequest {
    JobRequest {
        workload: workload.into(),
        config: "large".into(),
        method,
        seconds: 1.5,
        max_iters: 200,
        seed: 5,
        chains: 0,
        deadline_ms: 0,
        spec: None,
        force: false,
        prune: fadiff::search::PruneMode::On,
        warm_frac: 0.0,
    }
}

#[test]
fn coordinator_runs_jobs_and_counts() {
    let coord = Coordinator::new(None, 2).unwrap();
    let r = coord.run(small_job("mobilenet", Method::Ga)).unwrap();
    assert!(r.edp.is_finite() && r.edp > 0.0);
    assert!(r.full_model_edp >= r.edp);
    assert!(r.iters > 0);
    assert_eq!(coord.metrics.completed.load(Ordering::SeqCst), 1);
    assert_eq!(coord.metrics.in_flight(), 0);
}

#[test]
fn coordinator_parallel_jobs_complete() {
    let coord = Coordinator::new(None, 2).unwrap();
    let handles: Vec<_> = ["resnet18", "vgg16", "mobilenet", "gpt3"]
        .iter()
        .map(|w| coord.submit(small_job(w, Method::Random)))
        .collect();
    for h in handles {
        let r = h.wait().unwrap().unwrap();
        assert!(r.edp.is_finite());
    }
    assert_eq!(coord.metrics.completed.load(Ordering::SeqCst), 4);
}

#[test]
fn coordinator_rejects_unknown_workload() {
    let coord = Coordinator::new(None, 1).unwrap();
    let err = coord.run(small_job("alexnet", Method::FADiff));
    assert!(err.is_err());
    assert_eq!(coord.metrics.failed.load(Ordering::SeqCst), 1);
}

#[test]
fn gradient_jobs_run_natively_without_artifacts() {
    if Runtime::load_if_available(&repo_root().join("artifacts")).is_some()
    {
        eprintln!("skipping: PJRT runtime present, native path untested");
        return;
    }
    // the headline method no longer degrades away: FADiff and DOSA
    // jobs complete on the native differentiable backend
    let coord = Coordinator::new(None, 1).unwrap();
    for method in [Method::FADiff, Method::Dosa] {
        let r = coord.run(small_job("resnet18", method)).unwrap();
        assert!(r.edp.is_finite() && r.edp > 0.0);
        assert!(r.evals > 0, "decoded incumbents must be scored");
    }
    assert_eq!(coord.metrics.completed.load(Ordering::SeqCst), 2);
    assert_eq!(coord.metrics.failed.load(Ordering::SeqCst), 0);
}

#[test]
fn metrics_report_evaluator_throughput() {
    let coord = Coordinator::new(None, 1).unwrap();
    // before any job: counters exist and read zero
    let m0 = coord.metrics_json();
    let t0 = m0.get("throughput").unwrap();
    assert_eq!(t0.get_f64("evals_total").unwrap(), 0.0);
    let r = coord.run(small_job("mobilenet", Method::Ga)).unwrap();
    assert!(r.evals > 0);
    let m = coord.metrics_json();
    let tp = m.get("throughput").unwrap();
    assert_eq!(tp.get_f64("evals_total").unwrap(), r.evals as f64);
    assert!(tp.get_f64("evals_per_sec").unwrap() > 0.0);
    assert!(tp.get_f64("uptime_seconds").unwrap() > 0.0);
    // the flat counter is also in the plain metrics object
    assert_eq!(m.get_f64("evals").unwrap(), r.evals as f64);
}

#[test]
fn coordinator_runs_gradient_jobs_when_runtime_present() {
    if Runtime::load_if_available(&repo_root().join("artifacts")).is_none()
    {
        eprintln!("skipping: PJRT runtime unavailable");
        return;
    }
    let coord = Coordinator::new(None, 2).unwrap();
    let r = coord.run(small_job("mobilenet", Method::FADiff)).unwrap();
    assert!(r.edp.is_finite() && r.edp > 0.0);
    assert_eq!(coord.metrics.completed.load(Ordering::SeqCst), 1);
}

fn send(addr: std::net::SocketAddr, body: &str) -> String {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.write_all(body.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    line.trim().to_string()
}

/// Unwrap the v1 success envelope `{"protocol": 1, "ok": {...}}`,
/// returning the payload.
fn ok_payload(j: &Json) -> &Json {
    assert_eq!(j.get("protocol").unwrap().as_f64().unwrap(), 1.0,
               "{j:?}");
    assert!(j.get("error").is_err(),
            "expected success envelope, got {j:?}");
    j.get("ok").unwrap()
}

/// Unwrap the v1 error envelope, asserting the stable error code.
fn err_body<'j>(j: &'j Json, code: &str) -> &'j Json {
    assert_eq!(j.get("protocol").unwrap().as_f64().unwrap(), 1.0,
               "{j:?}");
    assert!(j.get("ok").is_err(),
            "expected error envelope, got {j:?}");
    let e = j.get("error").unwrap();
    assert_eq!(e.get("code").unwrap().as_str().unwrap(), code,
               "{j:?}");
    assert!(!e.get("message").unwrap().as_str().unwrap().is_empty());
    e
}

#[test]
fn tcp_server_full_protocol() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let coord = Coordinator::new(None, 1).unwrap();
    let t = std::thread::spawn(move || server::serve_on(listener, coord));

    // ping carries the protocol version and server uptime
    let pong = Json::parse(&send(addr, r#"{"verb": "ping"}"#)).unwrap();
    let p = ok_payload(&pong);
    assert_eq!(p.get("pong").unwrap(), &Json::Bool(true));
    assert_eq!(p.get_f64("protocol").unwrap(), 1.0);
    assert!(p.get_f64("uptime_seconds").unwrap() >= 0.0);

    // requests may pin the protocol version they expect
    let pinned =
        Json::parse(&send(addr, r#"{"verb": "ping", "v": 1}"#)).unwrap();
    assert_eq!(ok_payload(&pinned).get("pong").unwrap(),
               &Json::Bool(true));
    let wrong =
        Json::parse(&send(addr, r#"{"verb": "ping", "v": 2}"#)).unwrap();
    err_body(&wrong, "unsupported_version");

    // optimize
    let resp = send(
        addr,
        r#"{"verb": "optimize", "workload": "mobilenet", "method": "random", "seconds": 1.0, "max_iters": 50, "seed": 2}"#,
    );
    let j = Json::parse(&resp).unwrap();
    let r = ok_payload(&j);
    assert!(r.get_f64("edp").unwrap() > 0.0);

    // bad requests are answered with coded errors, not dropped
    let bad = Json::parse(
        &send(addr, r#"{"verb": "optimize", "method": "quantum"}"#))
        .unwrap();
    err_body(&bad, "bad_request");
    let garbage = Json::parse(&send(addr, "not json at all")).unwrap();
    err_body(&garbage, "bad_request");
    let missing = Json::parse(
        &send(addr, r#"{"verb": "optimize", "workload": "alexnet"}"#))
        .unwrap();
    err_body(&missing, "unknown_workload");

    // unknown verbs list the supported surface
    let unknown =
        Json::parse(&send(addr, r#"{"verb": "fry"}"#)).unwrap();
    let e = err_body(&unknown, "unknown_verb");
    let supported = e.get("supported").unwrap().as_arr().unwrap();
    assert!(supported.iter().any(|v| v.as_str().unwrap() == "optimize"));

    // metrics reflect the one successful job
    let m = Json::parse(&send(addr, r#"{"verb": "metrics"}"#)).unwrap();
    assert_eq!(ok_payload(&m).get_f64("completed").unwrap(), 1.0);

    // graceful shutdown
    let s = Json::parse(&send(addr, r#"{"verb": "shutdown"}"#)).unwrap();
    assert_eq!(ok_payload(&s).get("shutting_down").unwrap(),
               &Json::Bool(true));
    t.join().unwrap().unwrap();
}

/// Poll a tracked job until it reaches a terminal state.
fn wait_terminal(coord: &Coordinator, id: u64) -> JobStatus {
    let t0 = Instant::now();
    loop {
        let (status, _) = coord.job_status(id).expect("known job");
        if status.is_terminal() {
            return status;
        }
        assert!(t0.elapsed() < Duration::from_secs(30),
                "job {id} stuck in {status:?}");
        std::thread::sleep(Duration::from_millis(20));
    }
}

#[test]
fn tracked_jobs_report_status_and_results() {
    let coord = Coordinator::new(None, 1).unwrap();
    let id = coord.submit_tracked(small_job("mobilenet", Method::Random))
        .unwrap();
    assert_eq!(wait_terminal(&coord, id), JobStatus::Completed);
    let (_, result) = coord.job_status(id).unwrap();
    let r = result.unwrap().unwrap();
    assert!(r.edp.is_finite() && r.edp > 0.0);
    // failures land in the table too
    let bad = coord.submit_tracked(small_job("alexnet", Method::Random))
        .unwrap();
    assert_eq!(wait_terminal(&coord, bad), JobStatus::Failed);
    let (_, result) = coord.job_status(bad).unwrap();
    assert!(result.unwrap().unwrap_err().contains("unknown workload"));
    // unknown ids stay unknown
    assert!(coord.job_status(10_000).is_none());
    assert!(coord.cancel(10_000).is_none());
}

#[test]
fn cancel_resolves_queued_jobs_immediately() {
    let coord = Coordinator::new(None, 1).unwrap();
    // occupy the single worker...
    let blocker = coord.submit(JobRequest {
        seconds: 2.0,
        max_iters: usize::MAX,
        ..small_job("mobilenet", Method::Random)
    });
    // ...so this one queues behind it
    let id = coord.submit_tracked(small_job("vgg16", Method::Random))
        .unwrap();
    let cancelled = coord.cancel(id).unwrap();
    // cancel resolves without waiting for the blocker (cooperatively if
    // the worker had already picked the job up)
    assert_eq!(wait_terminal(&coord, id), JobStatus::Cancelled);
    assert!(matches!(cancelled, JobStatus::Cancelled
                                | JobStatus::Running));
    let _ = blocker.wait();
    assert_eq!(coord.metrics.cancelled.load(Ordering::SeqCst), 1);
    // cancelling a terminal job is a no-op
    assert_eq!(coord.cancel(id), Some(JobStatus::Cancelled));
    assert_eq!(coord.metrics.cancelled.load(Ordering::SeqCst), 1);
}

#[test]
fn cancel_stops_a_running_job_early() {
    let coord = Coordinator::new(None, 1).unwrap();
    // a job that would run for a very long time without cancellation
    let id = coord.submit_tracked(JobRequest {
        workload: "mobilenet".into(),
        config: "large".into(),
        method: Method::Random,
        seconds: 3600.0,
        max_iters: usize::MAX,
        seed: 3,
        chains: 0,
        deadline_ms: 0,
        spec: None,
        force: false,
        prune: fadiff::search::PruneMode::On,
        warm_frac: 0.0,
    }).unwrap();
    // wait until it is actually running
    let t0 = Instant::now();
    loop {
        let (status, _) = coord.job_status(id).unwrap();
        if status == JobStatus::Running {
            break;
        }
        assert!(t0.elapsed() < Duration::from_secs(30));
        std::thread::sleep(Duration::from_millis(10));
    }
    let t_cancel = Instant::now();
    coord.cancel(id).unwrap();
    assert_eq!(wait_terminal(&coord, id), JobStatus::Cancelled);
    assert!(t_cancel.elapsed() < Duration::from_secs(30),
            "cooperative cancel took too long");
    // the partial best-so-far is preserved as the job's result
    let (_, result) = coord.job_status(id).unwrap();
    let r = result.unwrap().expect("cancelled job keeps partial best");
    assert!(r.edp.is_finite() && r.edp > 0.0);
    assert_eq!(coord.metrics.cancelled.load(Ordering::SeqCst), 1);
    assert_eq!(coord.metrics.in_flight(), 0);
}

#[test]
fn deadline_cuts_a_long_job_keeping_best_so_far() {
    let coord = Coordinator::new(None, 1).unwrap();
    // a job that would run for an hour, bounded to a fraction of a
    // second: the deadline must cut it cooperatively
    let t0 = Instant::now();
    let id = coord.submit_tracked(JobRequest {
        seconds: 3600.0,
        max_iters: usize::MAX,
        deadline_ms: 300,
        ..small_job("mobilenet", Method::Random)
    }).unwrap();
    assert_eq!(wait_terminal(&coord, id), JobStatus::DeadlineExceeded);
    assert!(t0.elapsed() < Duration::from_secs(30),
            "deadline never fired");
    // like cancel, the cut keeps the best-so-far as the result
    let (_, result) = coord.job_status(id).unwrap();
    let r = result.unwrap().expect("cut job keeps partial best");
    assert!(r.edp.is_finite() && r.edp > 0.0);
    assert!(r.deadline_hit);
    assert_eq!(
        coord.metrics.deadline_exceeded.load(Ordering::SeqCst), 1);
    assert_eq!(coord.metrics.in_flight(), 0);
    // a generous deadline does not perturb a short job
    let ok = coord.run(JobRequest {
        deadline_ms: 3_600_000,
        ..small_job("mobilenet", Method::Random)
    }).unwrap();
    assert!(!ok.deadline_hit);
    assert_eq!(coord.metrics.completed.load(Ordering::SeqCst), 1);
}

#[test]
fn tcp_deadline_answers_coded_error_with_result() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let coord = Coordinator::new(None, 1).unwrap();
    let t = std::thread::spawn(move || server::serve_on(listener, coord));

    // a blocking optimize past its deadline answers the stable code,
    // with the best-so-far attached inside the error body
    let env = Json::parse(&send(
        addr,
        r#"{"verb": "optimize", "workload": "mobilenet", "method": "random", "seconds": 3600, "max_iters": 1000000000000, "deadline_ms": 300}"#,
    ))
    .unwrap();
    let e = err_body(&env, "deadline_exceeded");
    let r = e.get("result").unwrap();
    assert!(r.get_f64("edp").unwrap() > 0.0);
    assert_eq!(r.get("deadline_exceeded").unwrap(), &Json::Bool(true));

    // bad deadline values are parse-time errors
    let bad = Json::parse(&send(
        addr,
        r#"{"verb": "optimize", "deadline_ms": -5}"#,
    ))
    .unwrap();
    err_body(&bad, "bad_request");

    // metrics surface the cut in the supervision block
    let m = Json::parse(&send(addr, r#"{"verb": "metrics"}"#)).unwrap();
    let sup = ok_payload(&m).get("supervision").unwrap();
    assert_eq!(sup.get_f64("deadline_exceeded").unwrap(), 1.0);
    assert_eq!(ok_payload(&m).get_f64("in_flight").unwrap(), 0.0);

    let s = Json::parse(&send(addr, r#"{"verb": "shutdown"}"#)).unwrap();
    assert!(ok_payload(&s).get("shutting_down").is_ok());
    t.join().unwrap().unwrap();
}

#[test]
fn tcp_sweep_verb_serves_a_grid() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let coord = Coordinator::new(None, 2).unwrap();
    let t = std::thread::spawn(move || server::serve_on(listener, coord));

    let resp = send(
        addr,
        r#"{"verb": "sweep", "workloads": ["mobilenet", "resnet18"], "methods": ["random"], "seeds": [1, 2], "seconds": 3600, "max_iters": 24}"#,
    );
    let env = Json::parse(&resp).unwrap();
    let j = ok_payload(&env);
    assert_eq!(j.get_f64("jobs").unwrap(), 4.0);
    assert_eq!(j.get_f64("completed").unwrap(), 4.0);
    assert_eq!(j.get_f64("failed").unwrap(), 0.0);
    let results = j.get("results").unwrap().as_arr().unwrap();
    assert_eq!(results.len(), 4);
    for cell in results {
        let r = cell.get("ok").unwrap();
        assert!(r.get_f64("edp").unwrap() > 0.0);
        assert!(r.get("workload").unwrap().as_str().is_ok());
        assert!(r.get_f64("seed").unwrap() >= 1.0);
    }

    // two seeds per (workload, config) pair: the second shares the
    // pair's cache, so the metrics verb must show cross-job hits
    let m = Json::parse(&send(addr, r#"{"verb": "metrics"}"#)).unwrap();
    let m = ok_payload(&m);
    assert_eq!(m.get_f64("completed").unwrap(), 4.0);
    let cache = m.get("cache").unwrap();
    assert!(cache.get_f64("hits").unwrap() > 0.0, "{m:?}");
    assert_eq!(cache.get_f64("pairs").unwrap(), 2.0);

    let s = Json::parse(&send(addr, r#"{"verb": "shutdown"}"#)).unwrap();
    assert!(ok_payload(&s).get("shutting_down").is_ok());
    t.join().unwrap().unwrap();
}

#[test]
fn tcp_sweep_fadiff_chains_deterministic_with_grad_step_metrics() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let coord = Coordinator::new(None, 2).unwrap();
    let t = std::thread::spawn(move || server::serve_on(listener, coord));

    // identical-seed cells must produce identical results at every
    // chain count: the native multi-chain backend is deterministic
    // even with both cells running concurrently on the coordinator's
    // shared persistent pool (an iteration cap pins the annealing
    // schedule off the wall clock)
    let mut expected_steps = 0.0;
    for chains in [1usize, 4] {
        let body = format!(
            r#"{{"verb": "sweep", "workload": "mobilenet", "methods": ["fadiff"], "seeds": [9, 9], "seconds": 3600, "max_iters": 40, "chains": {chains}}}"#
        );
        let env = Json::parse(&send(addr, &body)).unwrap();
        let j = ok_payload(&env);
        assert_eq!(j.get_f64("completed").unwrap(), 2.0);
        let results = j.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 2);
        let edp0 = results[0].get("ok").unwrap().get_f64("edp").unwrap();
        let edp1 = results[1].get("ok").unwrap().get_f64("edp").unwrap();
        assert!(edp0 > 0.0 && edp0.is_finite());
        assert_eq!(edp0, edp1,
                   "identical-seed cells diverged at chains={chains}");
        for cell in results {
            assert_eq!(cell.get("ok").unwrap().get_f64("chains")
                           .unwrap(),
                       chains as f64);
        }

        // every chain runs the full 40-step schedule in both cells,
        // and the metrics verb's grad-step counter is monotone exact
        let m =
            Json::parse(&send(addr, r#"{"verb": "metrics"}"#)).unwrap();
        let tp = ok_payload(&m).get("throughput").unwrap();
        let steps = tp.get_f64("grad_steps_total").unwrap();
        expected_steps += 2.0 * chains as f64 * 40.0;
        assert_eq!(steps, expected_steps,
                   "grad_steps_total must count chain-steps exactly");
        assert!(tp.get_f64("grad_steps_per_sec").unwrap() > 0.0);
    }

    let s = Json::parse(&send(addr, r#"{"verb": "shutdown"}"#)).unwrap();
    assert!(ok_payload(&s).get("shutting_down").is_ok());
    t.join().unwrap().unwrap();
}

#[test]
fn tcp_submit_status_cancel_roundtrip() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let coord = Coordinator::new(None, 1).unwrap();
    let t = std::thread::spawn(move || server::serve_on(listener, coord));

    let sub = Json::parse(&send(
        addr,
        r#"{"verb": "submit", "workload": "mobilenet", "method": "random", "seconds": 3600, "max_iters": 1000000000000}"#,
    ))
    .unwrap();
    let id = ok_payload(&sub).get_f64("job_id").unwrap() as u64;

    let cancel = Json::parse(&send(
        addr,
        &format!(r#"{{"verb": "cancel", "job_id": {id}}}"#),
    ))
    .unwrap();
    assert!(ok_payload(&cancel).get("status").is_ok());

    // unknown ids answer job_not_found, not a generic error
    let nf = Json::parse(&send(
        addr,
        r#"{"verb": "status", "job_id": 999999}"#,
    ))
    .unwrap();
    err_body(&nf, "job_not_found");

    // poll until terminal; must be cancelled, fast
    let t0 = Instant::now();
    loop {
        let env = Json::parse(&send(
            addr,
            &format!(r#"{{"verb": "status", "job_id": {id}}}"#),
        ))
        .unwrap();
        let st = ok_payload(&env);
        let status = st.get("status").unwrap().as_str().unwrap()
            .to_string();
        if status == "cancelled" {
            break;
        }
        assert!(matches!(status.as_str(), "queued" | "running"),
                "unexpected status {status}");
        assert!(t0.elapsed() < Duration::from_secs(30),
                "cancel never landed");
        std::thread::sleep(Duration::from_millis(20));
    }

    let s = Json::parse(&send(addr, r#"{"verb": "shutdown"}"#)).unwrap();
    assert!(ok_payload(&s).get("shutting_down").is_ok());
    t.join().unwrap().unwrap();
}

/// A custom workload no zoo builder knows: tiny enough that every
/// search method finishes in milliseconds at a small iteration cap.
const INLINE_SPEC: &str = r#"{
    "name": "wire-custom",
    "layers": [
        {"name": "c1", "kind": "conv",
         "dims": [1, 16, 3, 32, 32, 3, 3]},
        {"name": "c2", "kind": "conv",
         "dims": [1, 16, 16, 32, 32, 3, 3]},
        {"name": "head", "kind": "fc",
         "dims": [1, 10, 16, 1, 1, 1, 1]}
    ],
    "blocked": [1]
}"#;

#[test]
fn tcp_inline_workload_spec_runs_every_method() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let coord = Coordinator::new(None, 2).unwrap();
    let t = std::thread::spawn(move || server::serve_on(listener, coord));

    // the inline spec round-trips through every search method
    for method in ["fadiff", "dosa", "ga", "bo", "random"] {
        let body = format!(
            r#"{{"verb": "optimize", "method": "{method}",
                 "seconds": 3600, "max_iters": 12, "seed": 4,
                 "workload_spec": {INLINE_SPEC}}}"#
        );
        let env = Json::parse(&send(addr, &body.replace('\n', " ")))
            .unwrap();
        let j = ok_payload(&env);
        assert_eq!(j.get("workload").unwrap().as_str().unwrap(),
                   "wire-custom", "{method}");
        assert!(j.get_f64("edp").unwrap() > 0.0, "{method}");
        assert!(j.get_f64("edp").unwrap().is_finite(), "{method}");
    }

    // a bad inline spec is a one-line coded error, never a queued job
    let bad = Json::parse(&send(
        addr,
        r#"{"verb": "optimize", "workload_spec": {"name": "x", "layers": []}}"#,
    ))
    .unwrap();
    let e = err_body(&bad, "spec_invalid");
    assert!(e.get("message").unwrap().as_str().unwrap()
        .contains("workload_spec"));

    let s = Json::parse(&send(addr, r#"{"verb": "shutdown"}"#)).unwrap();
    assert!(ok_payload(&s).get("shutting_down").is_ok());
    t.join().unwrap().unwrap();
}

#[test]
fn inline_specs_get_their_own_cache_pair() {
    let coord = Coordinator::new(None, 1).unwrap();
    let inline = fadiff::workload::spec::from_str(INLINE_SPEC).unwrap();
    let req = JobRequest {
        workload: inline.name.clone(),
        method: Method::Random,
        seconds: 3600.0,
        max_iters: 24,
        seed: 11,
        spec: Some(std::sync::Arc::new(inline)),
        ..Default::default()
    };
    let r1 = coord.run(req.clone()).unwrap();
    assert_eq!(coord.registry().len(), 1);
    let misses1 = coord.registry().misses();
    assert!(misses1 > 0);

    // the identical inline spec re-serves from the shared cache...
    let r2 = coord.run(req.clone()).unwrap();
    assert_eq!(r1.edp, r2.edp);
    assert_eq!(coord.registry().len(), 1,
               "identical specs must share one cache pair");
    assert_eq!(coord.registry().misses(), misses1,
               "repeat inline-spec job recomputed");

    // ...while a spec that merely SHARES THE NAME gets its own pair
    // (content fingerprint keying, not display-name keying)
    let mut other = fadiff::workload::spec::from_str(INLINE_SPEC)
        .unwrap();
    other.layers[0].dims[1] = 32;
    let req3 = JobRequest {
        spec: Some(std::sync::Arc::new(other)),
        ..req.clone()
    };
    let _ = coord.run(req3).unwrap();
    assert_eq!(coord.registry().len(), 2,
               "different content behind one name must not share");

    // and a zoo job keys by name, separate from both
    let _ = coord.run(small_job("mobilenet", Method::Random)).unwrap();
    assert_eq!(coord.registry().len(), 3);
}

#[test]
fn spec_file_workloads_serve_by_name() {
    // data/workloads/*.json stems are servable with no code changes —
    // the zoo-expansion contract
    let coord = Coordinator::new(None, 1).unwrap();
    let r = coord
        .run(small_job("llama7b-decode", Method::Random))
        .unwrap();
    assert!(r.edp.is_finite() && r.edp > 0.0);
    assert_eq!(r.request.workload, "llama7b-decode");
}

#[test]
fn tcp_workloads_verb_lists_and_describes() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let coord = Coordinator::new(None, 1).unwrap();
    let t = std::thread::spawn(move || server::serve_on(listener, coord));

    // list: zoo + spec files, with summary fields
    let env =
        Json::parse(&send(addr, r#"{"verb": "workloads"}"#)).unwrap();
    let j = ok_payload(&env);
    let rows = j.get("workloads").unwrap().as_arr().unwrap();
    assert!(j.get_f64("count").unwrap() >= 9.0, "{j:?}");
    let find = |name: &str| {
        rows.iter().find(|r| {
            r.get("name").map(|n| n.as_str().unwrap() == name)
                .unwrap_or(false)
        })
    };
    let vgg = find("vgg16").expect("vgg16 listed");
    assert_eq!(vgg.get("source").unwrap().as_str().unwrap(), "zoo");
    assert_eq!(vgg.get_f64("layers").unwrap(), 16.0);
    let llama = find("llama7b-decode").expect("llama listed");
    assert_eq!(llama.get("source").unwrap().as_str().unwrap(), "spec");
    assert_eq!(llama.get_f64("layers").unwrap(), 9.0);

    // describe: the canonical spec plus derived fields
    let d = Json::parse(&send(
        addr,
        r#"{"verb": "workloads", "describe": "bert-base-block"}"#,
    ))
    .unwrap();
    let w = ok_payload(&d).get("workload").unwrap();
    assert_eq!(w.get_f64("layer_count").unwrap(), 8.0);
    assert_eq!(w.get_f64("replicas").unwrap(), 12.0);
    assert!(w.get_f64("total_macs").unwrap() > 0.0);
    assert_eq!(w.get("layers").unwrap().as_arr().unwrap().len(), 8);
    assert_eq!(w.get("fingerprint").unwrap().as_str().unwrap().len(),
               16);

    // describe with an inline spec validates without running anything
    let v = Json::parse(&send(
        addr,
        &format!(r#"{{"verb": "workloads", "workload_spec": {}}}"#,
                 INLINE_SPEC.replace('\n', " ")),
    ))
    .unwrap();
    assert_eq!(ok_payload(&v).get("workload").unwrap()
        .get_f64("layer_count").unwrap(), 3.0);

    // unknown names error cleanly with the stable code
    let e = Json::parse(&send(
        addr,
        r#"{"verb": "workloads", "describe": "alexnet"}"#,
    ))
    .unwrap();
    err_body(&e, "unknown_workload");

    let s = Json::parse(&send(addr, r#"{"verb": "shutdown"}"#)).unwrap();
    assert!(ok_payload(&s).get("shutting_down").is_ok());
    t.join().unwrap().unwrap();
}

#[test]
fn method_parser_roundtrip() {
    for (name, m) in [
        ("fadiff", Method::FADiff),
        ("dosa", Method::Dosa),
        ("ga", Method::Ga),
        ("bo", Method::Bo),
        ("random", Method::Random),
    ] {
        assert_eq!(Method::parse(name).unwrap(), m);
        assert_eq!(Method::parse(m.name()).unwrap(), m);
    }
    assert!(Method::parse("sgd").is_err());
}
