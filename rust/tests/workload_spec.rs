//! The workload-spec subsystem end-to-end: the checked-in
//! `data/workloads/*.json` files are the source of truth for the model
//! zoo expansion, so (1) the zoo re-expressions must be
//! *bit-identical* to their builder functions, (2) every new spec must
//! parse, validate, and be searchable, and (3) the builder -> spec ->
//! parse round trip must be lossless.

use fadiff::config::{load_config, repo_root};
use fadiff::coordinator::resolve_workload;
use fadiff::costmodel;
use fadiff::mapping::Strategy;
use fadiff::search::{random, Budget, EvalCtx};
use fadiff::workload::{spec, zoo, Workload};

/// The zoo models and their spec-file stems (the five paper models
/// plus the exhaustively-enumerable micro trio).
fn zoo_pairs() -> Vec<(&'static str, Workload)> {
    vec![
        ("gpt3-6.7b", zoo::gpt3_6_7b()),
        ("vgg19", zoo::vgg19()),
        ("vgg16", zoo::vgg16()),
        ("mobilenet-v1", zoo::mobilenet_v1()),
        ("resnet18", zoo::resnet18()),
        ("micro-mlp", zoo::micro_mlp()),
        ("micro-gemm", zoo::micro_gemm()),
        ("micro-chain", zoo::micro_chain()),
    ]
}

/// The new scenario classes this zoo expansion adds as data.
const NEW_SPECS: [&str; 4] = [
    "llama7b-decode",
    "llama7b-prefill",
    "bert-base-block",
    "resnet50-bottleneck",
];

#[test]
fn checked_in_zoo_specs_are_bit_identical_to_builders() {
    let repo = repo_root();
    for (stem, built) in zoo_pairs() {
        let loaded = spec::load_named(&repo, stem)
            .unwrap_or_else(|| panic!("data/workloads/{stem}.json missing"))
            .unwrap_or_else(|e| panic!("{stem}: {e}"));
        assert_eq!(loaded, built,
                   "{stem}: spec file diverged from the zoo builder");
    }
}

#[test]
fn builder_to_spec_json_round_trip_is_lossless() {
    for (_, w) in zoo_pairs() {
        let text = spec::to_json(&w).compact();
        let back = spec::from_str(&text).unwrap();
        assert_eq!(back, w, "{} round trip", w.name);
        assert_eq!(spec::fingerprint(&back), spec::fingerprint(&w));
    }
}

#[test]
fn new_specs_parse_and_are_schedulable() {
    let repo = repo_root();
    let hw = load_config(&repo, "large").unwrap();
    for stem in NEW_SPECS {
        let w = spec::load_named(&repo, stem)
            .unwrap_or_else(|| panic!("data/workloads/{stem}.json missing"))
            .unwrap_or_else(|e| panic!("{stem}: {e}"));
        assert_eq!(w.name, stem, "file stem must match the spec name");
        assert!(!w.is_empty());
        assert_eq!(w.fusible.len(), w.len() - 1);
        // the trivial strategy must be feasible on the paper hardware
        costmodel::feasible(&Strategy::trivial(&w), &w, &hw)
            .unwrap_or_else(|e| panic!("{stem}: {e}"));
        // and a short real search must produce a finite schedule
        let r = random::optimize_ctx(&w, &hw, 3, Budget::iters(8),
                                     &EvalCtx::default())
            .unwrap();
        assert!(r.edp.is_finite() && r.edp > 0.0, "{stem}");
        costmodel::feasible(&r.best, &w, &hw).unwrap();
    }
}

#[test]
fn new_specs_cover_the_advertised_scenario_classes() {
    let repo = repo_root();
    let load = |stem: &str| {
        spec::load_named(&repo, stem).unwrap().unwrap()
    };

    // LLaMA decode: single-token (seq = 1) autoregressive GEMMs
    // against a long KV cache, full-model replication.
    let decode = load("llama7b-decode");
    assert_eq!(decode.len(), 9, "q/k/v + attn x2 + out + SwiGLU x3");
    assert_eq!(decode.replicas, 32.0);
    use fadiff::workload::{DIM_C, DIM_K, DIM_P};
    for l in &decode.layers {
        assert!(l.dims[DIM_P] == 1,
                "{}: decode GEMMs have one output row", l.name);
    }
    // only the scores -> context edge is fusible (everything else is a
    // parallel projection, residual join, or two-producer edge)
    let fusible: Vec<usize> = (0..decode.fusible.len())
        .filter(|&i| decode.fusible[i])
        .collect();
    assert_eq!(fusible, vec![3], "decode fusibility: {fusible:?}");

    // prefill shares the structure at seq = 2048
    let prefill = load("llama7b-prefill");
    assert_eq!(prefill.len(), decode.len());
    assert_eq!(prefill.layers[0].dims[DIM_P], 2048);
    assert!(prefill.total_ops() > 1000.0 * decode.total_ops(),
            "prefill must be orders of magnitude more work");

    // BERT-base block: 12 heads, d_model 768, same edge topology as
    // the GPT-3 block (scores->context and the FFN chain fuse)
    let bert = load("bert-base-block");
    assert_eq!(bert.len(), 8);
    assert_eq!(bert.replicas, 12.0);
    assert_eq!(bert.layers[6].dims[DIM_K], 3072, "FFN hidden");
    assert!(bert.fusible[3] && bert.fusible[5] && bert.fusible[6]);
    assert!(!bert.fusible[0] && !bert.fusible[4]);

    // ResNet-50 bottleneck stage: 1x1 -> 3x3 -> 1x1 chains fusible
    // inside each block, blocked across the residual joins
    let rn = load("resnet50-bottleneck");
    assert_eq!(rn.len(), 10);
    assert!(rn.fusible[0] && rn.fusible[1],
            "reduce -> conv3 -> expand must fuse");
    assert!(!rn.fusible[2] && !rn.fusible[3] && !rn.fusible[6],
            "projection / residual joins must not fuse");
    assert_eq!(rn.layers[0].dims[DIM_C], 64);
    assert_eq!(rn.layers[4].dims[DIM_C], 256,
               "block 2 consumes the expanded width");
}

#[test]
fn resolve_workload_reaches_zoo_and_spec_files() {
    // zoo names resolve to builders
    let w = resolve_workload("vgg16").unwrap();
    assert_eq!(w, zoo::vgg16());
    // spec-only names resolve through data/workloads/
    let w = resolve_workload("llama7b-decode").unwrap();
    assert_eq!(w.name, "llama7b-decode");
    // everything else is a one-line error naming both sources
    let err = resolve_workload("alexnet").unwrap_err().to_string();
    assert!(err.contains("alexnet") && err.contains("data/workloads"),
            "{err}");
}

#[test]
fn listed_specs_include_mirrors_and_new_classes() {
    let names = spec::list_spec_names(&repo_root());
    for (stem, _) in zoo_pairs() {
        assert!(names.iter().any(|n| n == stem), "{stem} not listed");
    }
    for stem in NEW_SPECS {
        assert!(names.iter().any(|n| n == stem), "{stem} not listed");
    }
    assert!(names.len() >= 9);
}

#[test]
fn fingerprints_are_distinct_across_the_whole_zoo() {
    let repo = repo_root();
    let mut seen = std::collections::HashMap::new();
    for name in spec::list_spec_names(&repo) {
        let w = spec::load_named(&repo, &name).unwrap().unwrap();
        let fp = spec::fingerprint(&w);
        assert_eq!(fp.len(), 16);
        if let Some(prev) = seen.insert(fp.clone(), name.clone()) {
            panic!("{name} and {prev} share fingerprint {fp}");
        }
    }
}

#[test]
fn cache_keys_track_content_for_mutable_sources() {
    use fadiff::coordinator::JobRequest;
    // zoo names key by name: builders are immutable in-process
    let zoo_req = JobRequest {
        workload: "vgg16".into(),
        ..Default::default()
    };
    assert_eq!(zoo_req.cache_key(&zoo::vgg16()), "vgg16");

    // spec-FILE workloads key by content fingerprint — editing the
    // file under a running server must invalidate its cache pair
    // instead of serving stale evaluations under the same name
    let loaded = resolve_workload("llama7b-decode").unwrap();
    let file_req = JobRequest {
        workload: "llama7b-decode".into(),
        ..Default::default()
    };
    let key = file_req.cache_key(&loaded);
    assert!(key.starts_with("spec:"), "{key}");
    let mut edited = loaded.clone();
    edited.layers[0].dims[1] *= 2;
    assert_ne!(file_req.cache_key(&edited), key,
               "changed file content must change the cache key");

    // inline specs likewise, even when named like a zoo model
    let masquerade = JobRequest {
        workload: "vgg16".into(),
        spec: Some(std::sync::Arc::new(edited.clone())),
        ..Default::default()
    };
    assert!(masquerade.cache_key(&edited).starts_with("spec:"));
}

#[test]
fn spec_file_name_must_match_stem() {
    let dir = std::env::temp_dir().join("fadiff_spec_stem_test");
    std::fs::create_dir_all(&dir).unwrap();
    let body = spec::to_json(&zoo::vgg16()).pretty();
    // stem "other" but declared name "vgg16": must be rejected, not
    // advertised under a name that then fails to resolve
    std::fs::write(dir.join("other.json"), &body).unwrap();
    let err = spec::load_named_from(&dir, "other")
        .expect("file exists")
        .unwrap_err()
        .to_string();
    assert!(err.contains("stem"), "{err}");
    // matching stem loads fine
    std::fs::write(dir.join("vgg16.json"), &body).unwrap();
    let w = spec::load_named_from(&dir, "vgg16")
        .expect("file exists")
        .expect("stem matches");
    assert_eq!(w, zoo::vgg16());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn oversized_spec_files_are_rejected() {
    let dir = std::env::temp_dir();
    let path = dir.join("fadiff_oversized_spec_test.json");
    let filler = "x".repeat(spec::MAX_SPEC_BYTES);
    std::fs::write(&path, format!("{{\"name\": \"{filler}\"}}")).unwrap();
    let err = spec::load_file(&path).unwrap_err().to_string();
    assert!(err.contains("cap"), "{err}");
    let _ = std::fs::remove_file(&path);
}
