//! Gap-regression battery: the branch-and-bound oracle versus every
//! search method, end to end through the coordinator.
//!
//! * **gap invariants** — on the exhaustively-solvable `micro-*` trio
//!   with fixed seeds, every baseline's measured optimality gap is
//!   finite and `>= 0`, and the certified exact EDP is `<=` every
//!   method's (no method can beat a certified optimum);
//! * **store/cache hygiene** (the audited incumbent/cache sweep,
//!   pinned): exact jobs recompute bit-identically across the
//!   coordinator's shared cross-job eval cache; *certified* results
//!   re-serve from the persistent store as certified hits; and
//!   *uncertified* results are never recorded, so a capped run can
//!   never masquerade as a stored optimum;
//! * **iteration-zero screening** — the screened batch path offers
//!   candidates from the very first batch (threshold-free against an
//!   empty incumbent), so a 1-iteration budget already returns a
//!   feasible result, bit-identical with pruning on or off.

use std::path::PathBuf;

use fadiff::config::{load_config, repo_root};
use fadiff::coordinator::{Coordinator, JobRequest, Method};
use fadiff::experiments::gap;
use fadiff::search::{compute_eval, random, Budget, EvalCtx,
                     PruneMode};
use fadiff::mapping::Strategy;
use fadiff::workload::zoo;

const MICRO: [&str; 3] = ["micro-mlp", "micro-gemm", "micro-chain"];

fn base(workload: &str) -> JobRequest {
    JobRequest {
        workload: workload.into(),
        config: "large".into(),
        seconds: 3600.0, // iteration-capped: deterministic per seed
        max_iters: 30,
        seed: 3,
        ..Default::default()
    }
}

fn exact_req(workload: &str) -> JobRequest {
    JobRequest { method: Method::Exact, ..base(workload) }
}

fn tmp_dir(tag: &str) -> PathBuf {
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .unwrap()
        .as_nanos();
    let d = std::env::temp_dir().join(format!(
        "fadiff_gap_{tag}_{}_{nanos}",
        std::process::id()
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}

// -------------------------------------------------------------------
// gap invariants on the micro trio
// -------------------------------------------------------------------

#[test]
fn micro_trio_gaps_are_finite_and_nonnegative() {
    for workload in MICRO {
        let rep = gap::measure(None, &base(workload), &[]).unwrap();
        assert_eq!(rep.workload, workload);
        assert!(rep.certified,
                "{workload}: the oracle must certify a micro model");
        assert!(rep.exact_edp.is_finite() && rep.exact_edp > 0.0);
        assert!(rep.nodes_expanded > 0);

        let names: Vec<&str> =
            rep.rows.iter().map(|r| r.method.as_str()).collect();
        assert_eq!(names, ["fadiff", "ga", "bo", "random"],
                   "{workload}: default baseline panel changed");
        for row in &rep.rows {
            assert!(row.edp.is_finite() && row.edp > 0.0,
                    "{workload}/{}: bogus EDP {}", row.method,
                    row.edp);
            assert!(row.gap.is_finite(),
                    "{workload}/{}: non-finite gap", row.method);
            assert!(row.gap >= 0.0,
                    "{workload}/{}: gap {} < 0 — method beat a \
                     certified optimum",
                    row.method, row.gap);
            assert!(row.edp >= rep.exact_edp,
                    "{workload}/{}: EDP {} below the certified \
                     optimum {}",
                    row.method, row.edp, rep.exact_edp);
            assert!(row.evals > 0,
                    "{workload}/{}: no evaluations recorded",
                    row.method);
        }
        let table = rep.render();
        assert!(table.contains(&format!("| {workload} |")),
                "{table}");
        assert!(!table.contains("uncertified"), "{table}");
    }
}

#[test]
fn gap_measure_is_deterministic_for_fixed_seeds() {
    let a = gap::measure(None, &base("micro-mlp"), &[]).unwrap();
    let b = gap::measure(None, &base("micro-mlp"), &[]).unwrap();
    assert_eq!(a.exact_edp.to_bits(), b.exact_edp.to_bits());
    for (ra, rb) in a.rows.iter().zip(&b.rows) {
        assert_eq!(ra.method, rb.method);
        assert_eq!(ra.edp.to_bits(), rb.edp.to_bits(),
                   "{}: baseline not deterministic", ra.method);
        assert_eq!(ra.gap.to_bits(), rb.gap.to_bits());
        assert_eq!(ra.evals, rb.evals);
    }
}

// -------------------------------------------------------------------
// store/cache hygiene for exact jobs (pinning the audited sweep)
// -------------------------------------------------------------------

#[test]
fn exact_jobs_recompute_bit_identically_over_the_shared_cache() {
    // no result store: the second identical request recomputes, but
    // through the cross-job eval-cache registry warmed by the first —
    // a stale incumbent or poisoned cache entry would break identity
    let coord = Coordinator::new(None, 1).unwrap();
    let r1 = coord.run(exact_req("micro-mlp")).unwrap();
    let r2 = coord.run(exact_req("micro-mlp")).unwrap();
    assert!(!r1.stored && !r2.stored,
            "no store was configured — nothing may be 'stored'");
    let e1 = r1.exact.expect("exact jobs must carry stats");
    let e2 = r2.exact.expect("exact jobs must carry stats");
    assert!(e1.certified && e2.certified);
    assert_eq!(r1.edp.to_bits(), r2.edp.to_bits(),
               "cache-warmed rerun diverged");
    assert_eq!(r1.energy.to_bits(), r2.energy.to_bits());
    assert_eq!(r1.latency.to_bits(), r2.latency.to_bits());
    assert_eq!(e1.nodes_expanded, e2.nodes_expanded,
               "search shape must not depend on cache state");
    assert_eq!(e1.pruned(), e2.pruned());
}

#[test]
fn certified_results_store_and_reserve_as_certified() {
    let dir = tmp_dir("store");
    let coord =
        Coordinator::new_with_store(None, 1, Some(dir.clone()))
            .unwrap();
    let r1 = coord.run(exact_req("micro-gemm")).unwrap();
    assert!(!r1.stored);
    assert!(r1.exact.unwrap().certified);

    // identical request: served from the store, still certified
    let r2 = coord.run(exact_req("micro-gemm")).unwrap();
    assert!(r2.stored, "identical request must hit the store");
    assert_eq!(r2.edp.to_bits(), r1.edp.to_bits());
    let e2 = r2.exact.expect("stored exact hits must carry stats");
    assert!(e2.certified,
            "only certified results are recorded, so a stored hit \
             re-serves as certified");

    // force: recompute past the store, bit-identical again
    let r3 = coord
        .run(JobRequest { force: true, ..exact_req("micro-gemm") })
        .unwrap();
    assert!(!r3.stored);
    assert_eq!(r3.edp.to_bits(), r1.edp.to_bits());
    drop(coord);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn uncertified_results_are_never_recorded_to_the_store() {
    let dir = tmp_dir("uncert");
    let coord =
        Coordinator::new_with_store(None, 1, Some(dir.clone()))
            .unwrap();
    // a 2-node budget trips the cap: feasible but uncertified
    let capped =
        JobRequest { max_iters: 2, ..exact_req("micro-mlp") };
    let r1 = coord.run(capped.clone()).unwrap();
    assert!(!r1.stored);
    assert!(!r1.exact.unwrap().certified,
            "a 2-iteration exact run must not certify");

    // the identical request must RECOMPUTE — an uncertified result
    // stored here would later re-serve as a certified optimum
    let r2 = coord.run(capped).unwrap();
    assert!(!r2.stored,
            "uncertified exact results must never be recorded");
    assert!(!r2.exact.unwrap().certified);
    assert_eq!(r2.edp.to_bits(), r1.edp.to_bits(),
               "capped runs are still deterministic");
    drop(coord);
    std::fs::remove_dir_all(&dir).ok();
}

// -------------------------------------------------------------------
// iteration-zero screening (pinning the incumbent-init audit)
// -------------------------------------------------------------------

#[test]
fn first_screened_batch_already_offers_candidates() {
    let hw = load_config(&repo_root(), "large").unwrap();
    let w = zoo::micro_mlp();
    let budget = Budget { seconds: 3600.0, max_iters: 1 };
    // one iteration, pruning on: the very first batch is screened
    // against an *empty* incumbent (threshold None) — nothing may be
    // pruned-by-threshold away from the offer path
    let on = EvalCtx { prune: PruneMode::On, ..Default::default() };
    let off =
        EvalCtx { prune: PruneMode::Off, ..Default::default() };
    let a = random::optimize_ctx(&w, &hw, 17, budget, &on).unwrap();
    let b = random::optimize_ctx(&w, &hw, 17, budget, &off).unwrap();
    assert!(a.edp.is_finite() && a.edp > 0.0,
            "a 1-iteration run must already hold a result");
    assert_eq!(a.edp.to_bits(), b.edp.to_bits(),
               "first-batch screening changed the result");
    assert_eq!(a.evals, b.evals,
               "first-batch screening miscounted evaluations");
    // the trivial strategy is offered at iteration zero, so no result
    // is ever worse than it
    let trivial = compute_eval(&Strategy::trivial(&w), &w, &hw);
    assert!(a.edp <= trivial.fitness(),
            "result {} worse than the iteration-zero trivial offer \
             {}",
            a.edp, trivial.fitness());
}
