//! Integration: the optimizers run end-to-end through the shared
//! `EvalEngine` and reproduce the paper's qualitative ordering on a
//! small budget — FADiff <= DOSA, and both gradient methods beat
//! GA/BO/random under equal (tiny) budgets.
//!
//! The gradient tests here exercise the PJRT-accelerated backend and
//! skip cleanly when the artifacts (or a real `xla` crate) are
//! unavailable; the always-on native gradient backend has its own
//! suite in `gradient_native.rs`. GA / BO / random run unconditionally.

use fadiff::config::{load_config, repo_root};
use fadiff::costmodel;
use fadiff::runtime::Runtime;
use fadiff::search::{bo, ga, gradient, random, Budget};
use fadiff::workload::zoo;

fn runtime() -> Option<Runtime> {
    let rt = Runtime::load_if_available(&repo_root().join("artifacts"));
    if rt.is_none() {
        eprintln!(
            "skipping: PJRT runtime unavailable (generate artifacts with \
             `make artifacts` and link a real xla crate)"
        );
    }
    rt
}

#[test]
fn native_methods_beat_trivial_and_stay_feasible() {
    let hw = load_config(&repo_root(), "large").unwrap();
    let w = zoo::resnet18();
    let trivial = costmodel::evaluate(
        &fadiff::mapping::Strategy::trivial(&w), &w, &hw);
    let budget = Budget { seconds: 2.0, max_iters: usize::MAX };

    let rga = ga::optimize(&w, &hw, &ga::GaConfig::default(), budget)
        .unwrap();
    let rbo = bo::optimize(&w, &hw, &bo::BoConfig::default(), budget)
        .unwrap();
    let rr = random::optimize(&w, &hw, 1, budget).unwrap();

    for (name, r) in [("ga", &rga), ("bo", &rbo), ("rand", &rr)] {
        assert!(r.edp.is_finite(), "{name} produced no result");
        assert!(r.edp < trivial.edp, "{name} should beat trivial");
        assert!(r.evals > 0, "{name} never evaluated");
        costmodel::feasible(&r.best, &w, &hw).unwrap();
        // the incumbent's native evaluation is reproducible bit-for-bit
        let check = costmodel::evaluate(&r.best, &w, &hw);
        assert_eq!(r.edp, check.edp, "{name} EDP mismatch");
    }
}

#[test]
fn random_search_scales_with_budget() {
    // more samples can only improve (or tie) the incumbent
    let hw = load_config(&repo_root(), "large").unwrap();
    let w = zoo::vgg16();
    let small = random::optimize(&w, &hw, 42, Budget::iters(32)).unwrap();
    let large = random::optimize(&w, &hw, 42, Budget::iters(256)).unwrap();
    assert!(large.edp <= small.edp,
            "larger budget regressed: {} > {}", large.edp, small.edp);
    assert!(large.evals > small.evals);
}

#[test]
fn gradient_search_improves_over_trivial() {
    let Some(rt) = runtime() else { return };
    let hw = load_config(&repo_root(), "large").unwrap();
    let w = zoo::vgg16();
    let trivial = costmodel::evaluate(
        &fadiff::mapping::Strategy::trivial(&w), &w, &hw);
    let cfg = gradient::GradientConfig {
        restarts: 1,
        ..Default::default()
    };
    let r = gradient::optimize(Some(&rt), &w, &hw, &cfg,
                                Budget::iters(60))
        .unwrap();
    assert!(r.edp < trivial.edp * 0.01,
            "gradient should crush trivial: {} vs {}", r.edp, trivial.edp);
    costmodel::feasible(&r.best, &w, &hw).unwrap();
    assert!(!r.trace.is_empty());
}

#[test]
fn fadiff_beats_or_matches_dosa() {
    let Some(rt) = runtime() else { return };
    let hw = load_config(&repo_root(), "large").unwrap();
    let w = zoo::gpt3_6_7b(); // fusion-friendly FFN pair
    let fadiff_cfg = gradient::GradientConfig {
        restarts: 1,
        ..Default::default()
    };
    let dosa_cfg = gradient::GradientConfig {
        restarts: 1,
        ..gradient::GradientConfig::dosa()
    };
    let rf = gradient::optimize(Some(&rt), &w, &hw, &fadiff_cfg,
                                Budget::iters(80))
        .unwrap();
    let rd = gradient::optimize(Some(&rt), &w, &hw, &dosa_cfg,
                                Budget::iters(80))
        .unwrap();
    // the paper's core claim, qualitatively: joint fusion+mapping never
    // loses to layer-wise
    assert!(rf.edp <= rd.edp * 1.02,
            "FADiff {} should not lose to DOSA {}", rf.edp, rd.edp);
    // and FADiff actually uses fusion on this workload
    assert!(rf.best.fuse.iter().any(|&f| f),
            "expected at least one fused edge");
    assert!(rd.best.fuse.iter().all(|&f| !f), "DOSA must not fuse");
}

#[test]
fn ga_and_bo_work_but_lag_gradient() {
    let Some(rt) = runtime() else { return };
    let hw = load_config(&repo_root(), "large").unwrap();
    let w = zoo::resnet18();
    // equal wall-clock for every method (the paper's comparison protocol)
    let budget = Budget { seconds: 3.0, max_iters: usize::MAX };

    let rg = gradient::optimize(
        Some(&rt), &w, &hw,
        &gradient::GradientConfig { restarts: 1, ..Default::default() },
        budget,
    )
    .unwrap();
    let rga = ga::optimize(&w, &hw, &ga::GaConfig::default(), budget)
        .unwrap();
    let rbo = bo::optimize(&w, &hw, &bo::BoConfig::default(), budget)
        .unwrap();

    // gradient dominates under equal budget (paper Fig 4's shape)
    assert!(rg.edp <= rga.edp,
            "gradient {} vs ga {}", rg.edp, rga.edp);
    assert!(rg.edp <= rbo.edp,
            "gradient {} vs bo {}", rg.edp, rbo.edp);
}

#[test]
fn traces_are_monotone_and_timestamped() {
    // native method: always runs (the same invariant is asserted for
    // the gradient path when PJRT is available, below)
    let hw = load_config(&repo_root(), "small").unwrap();
    let w = zoo::mobilenet_v1();
    let r = ga::optimize(&w, &hw, &ga::GaConfig::default(),
                         Budget::iters(8))
        .unwrap();
    for win in r.trace.windows(2) {
        assert!(win[1].best_edp <= win[0].best_edp);
        assert!(win[1].seconds >= win[0].seconds);
    }

    let Some(rt) = runtime() else { return };
    let rg = gradient::optimize(
        Some(&rt), &w, &hw,
        &gradient::GradientConfig { restarts: 1, ..Default::default() },
        Budget::iters(40),
    )
    .unwrap();
    for win in rg.trace.windows(2) {
        assert!(win[1].best_edp <= win[0].best_edp);
        assert!(win[1].seconds >= win[0].seconds);
    }
}

#[test]
fn small_config_tighter_than_large() {
    // same optimizer, small Gemmini must not beat large Gemmini
    let large = load_config(&repo_root(), "large").unwrap();
    let small = load_config(&repo_root(), "small").unwrap();
    let w = zoo::vgg16();
    // native check first: GA under a fixed seed/iteration budget
    let rl = ga::optimize(&w, &large, &ga::GaConfig::default(),
                          Budget::iters(10))
        .unwrap();
    let rs = ga::optimize(&w, &small, &ga::GaConfig::default(),
                          Budget::iters(10))
        .unwrap();
    assert!(rl.edp < rs.edp,
            "large {} should beat small {}", rl.edp, rs.edp);

    let Some(rt) = runtime() else { return };
    let cfg = gradient::GradientConfig { restarts: 1, ..Default::default() };
    let gl = gradient::optimize(Some(&rt), &w, &large, &cfg,
                                Budget::iters(60))
        .unwrap();
    let gs = gradient::optimize(Some(&rt), &w, &small, &cfg,
                                Budget::iters(60))
        .unwrap();
    assert!(gl.edp < gs.edp,
            "large {} should beat small {}", gl.edp, gs.edp);
}
