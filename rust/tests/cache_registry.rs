//! Property tests for the cross-job cache architecture: shared
//! [`EvalCache`]s, the coordinator's [`CacheRegistry`], and the
//! persistent-pool evaluation path.
//!
//! Pins the serving-layer guarantees: sharing a cache (or a pool)
//! never changes a single bit of any result, capacity bounds hold
//! under churn, and a warm coordinator really does serve repeated
//! `(workload, config)` jobs from cache.

use std::sync::Arc;

use fadiff::config::{load_config, repo_root};
use fadiff::coordinator::{Coordinator, JobRequest, Method};
use fadiff::mapping::decode::{decode, Relaxed};
use fadiff::mapping::Strategy;
use fadiff::search::{EvalCache, EvalEngine};
use fadiff::util::prop::{check, Config};
use fadiff::util::rng::Rng;
use fadiff::util::threadpool::ThreadPool;
use fadiff::workload::{zoo, NDIMS};

fn random_strategy(rng: &mut Rng, w: &fadiff::workload::Workload,
                   hw: &fadiff::config::HwConfig) -> Strategy {
    let mut relaxed = Relaxed::neutral(w);
    for l in 0..w.len() {
        for d in 0..NDIMS {
            for s in 0..4 {
                relaxed.theta[l][d][s] = rng.range(-1.0, 9.0);
            }
        }
    }
    for i in 0..relaxed.sigma.len() {
        relaxed.sigma[i] = rng.f64();
    }
    decode(&relaxed, w, hw)
}

#[test]
fn shared_cache_results_equal_fresh_engine_prop() {
    // ANY strategy population, split across two engines sharing one
    // cache (second engine sees a cache warmed by the first), must
    // score bit-for-bit identically to a fresh private-cache engine
    let hw = load_config(&repo_root(), "large").unwrap();
    let w = zoo::mobilenet_v1();
    check("shared-cache-equivalence", &Config { cases: 24, seed: 1234 },
          |rng, size| {
              let n = 2 + (size * 14.0) as usize;
              let pop: Vec<Strategy> = (0..n)
                  .map(|_| random_strategy(rng, &w, &hw))
                  .collect();
              let split = rng.below(pop.len().max(1)).max(1);
              (pop, split)
          },
          |(pop, split)| {
              let fresh = EvalEngine::new(&w, &hw);
              let want = fresh.eval_batch(pop);

              let cache = Arc::new(EvalCache::default());
              let first = EvalEngine::new(&w, &hw)
                  .with_shared_cache(Arc::clone(&cache));
              let a = first.eval_batch(&pop[..*split]);
              let second = EvalEngine::new(&w, &hw)
                  .with_shared_cache(Arc::clone(&cache));
              let b = second.eval_batch(pop); // overlaps the warm half

              if a[..] != want[..*split] {
                  return Err("first engine diverged".into());
              }
              if b != want {
                  return Err(
                      "warm shared-cache engine diverged".into());
              }
              Ok(())
          });
}

#[test]
fn cache_capacity_bound_holds_under_churn_prop() {
    let hw = load_config(&repo_root(), "large").unwrap();
    let w = zoo::vgg16();
    check("cache-capacity-churn", &Config { cases: 12, seed: 77 },
          |rng, size| {
              let cap = 2 + rng.below(6);
              let n = 8 + (size * 24.0) as usize;
              let pop: Vec<Strategy> = (0..n)
                  .map(|_| random_strategy(rng, &w, &hw))
                  .collect();
              (cap, pop)
          },
          |(cap, pop)| {
              let cache = Arc::new(EvalCache::new(*cap));
              let a = EvalEngine::new(&w, &hw)
                  .with_shared_cache(Arc::clone(&cache));
              let b = EvalEngine::new(&w, &hw)
                  .with_shared_cache(Arc::clone(&cache));
              for (i, s) in pop.iter().enumerate() {
                  let e = if i % 2 == 0 { &a } else { &b };
                  let _ = e.eval(s);
                  if cache.len() > *cap {
                      return Err(format!(
                          "cache grew to {} over capacity {}",
                          cache.len(), cap
                      ));
                  }
              }
              Ok(())
          });
}

#[test]
fn persistent_pool_batch_equals_serial_prop() {
    let hw = load_config(&repo_root(), "large").unwrap();
    let w = zoo::resnet18();
    let pool = Arc::new(ThreadPool::new(4));
    check("pool-equals-serial", &Config { cases: 16, seed: 4242 },
          |rng, size| {
              let n = 1 + (size * 23.0) as usize;
              (0..n)
                  .map(|_| random_strategy(rng, &w, &hw))
                  .collect::<Vec<_>>()
          },
          |pop| {
              let serial = EvalEngine::with_threads(&w, &hw, 1);
              let pooled = EvalEngine::new(&w, &hw)
                  .with_pool(Arc::clone(&pool));
              if serial.eval_batch(pop) != pooled.eval_batch(pop) {
                  return Err(
                      "pool batch != serial batch".into());
              }
              Ok(())
          });
}

#[test]
fn coordinator_serves_repeat_jobs_from_cache() {
    let coord = Coordinator::new(None, 2).unwrap();
    let req = JobRequest {
        workload: "mobilenet".into(),
        config: "large".into(),
        method: Method::Random,
        seconds: 3600.0,
        max_iters: 48,
        seed: 9,
        chains: 0,
        deadline_ms: 0,
        spec: None,
        force: false,
        prune: fadiff::search::PruneMode::On,
        warm_frac: 0.0,
    };
    let r1 = coord.run(req.clone()).unwrap();
    let hits1 = coord.registry().hits();
    let misses1 = coord.registry().misses();
    assert!(misses1 > 0);

    // identical job again: same seed => same candidates => all hits
    let r2 = coord.run(req.clone()).unwrap();
    assert_eq!(r1.edp, r2.edp, "cached result must be identical");
    assert_eq!(r1.energy, r2.energy);
    assert_eq!(r1.latency, r2.latency);
    assert_eq!(r1.groups, r2.groups);
    assert_eq!(coord.registry().misses(), misses1,
               "repeat job recomputed instead of hitting the cache");
    assert!(coord.registry().hits() > hits1,
            "repeat job produced no cross-job cache hits");

    // a different seed still reuses the pair's cache object
    let mut req3 = req.clone();
    req3.seed = 10;
    let _ = coord.run(req3).unwrap();
    assert_eq!(coord.registry().len(), 1,
               "same (workload, config) must share one cache");

    // a different config gets its own cache
    let mut req4 = req;
    req4.config = "small".into();
    let _ = coord.run(req4).unwrap();
    assert_eq!(coord.registry().len(), 2);
}

#[test]
fn pooled_coordinator_results_match_standalone_search() {
    // end-to-end determinism: the serving stack (shared cache +
    // persistent pool) must reproduce the standalone optimizer exactly
    let coord = Coordinator::new(None, 1).unwrap();
    let req = JobRequest {
        workload: "resnet18".into(),
        config: "large".into(),
        method: Method::Ga,
        seconds: 3600.0,
        max_iters: 4,
        seed: 21,
        chains: 0,
        deadline_ms: 0,
        spec: None,
        force: false,
        prune: fadiff::search::PruneMode::On,
        warm_frac: 0.0,
    };
    let served = coord.run(req).unwrap();

    let hw = load_config(&repo_root(), "large").unwrap();
    let w = zoo::resnet18();
    let standalone = fadiff::search::ga::optimize(
        &w, &hw,
        &fadiff::search::ga::GaConfig { seed: 21,
                                        ..Default::default() },
        fadiff::search::Budget::iters(4),
    )
    .unwrap();
    assert_eq!(served.edp, standalone.edp);
    assert_eq!(served.energy, standalone.energy);
    assert_eq!(served.latency, standalone.latency);
}
