//! Chaos battery: with deterministic fault injection armed across the
//! store, scheduler, thread pool, and job execution, the serving stack
//! must degrade exactly as designed — transient I/O retries, corrupt
//! blobs recompute cold, panics are contained to one job, stalls are
//! failed by the watchdog — and the coordinator/server must never
//! panic, never hang, and land every job on exactly one terminal
//! status, with every degradation counted in the metrics payload.
//!
//! Compiled only with `--features fault-injection`; the injection
//! registry is process-global, so every test holds
//! `fault::registry_lock()` for its full duration and disarms on drop.

#![cfg(feature = "fault-injection")]

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use fadiff::coordinator::{server, Coordinator, JobRequest, JobStatus,
                          Method};
use fadiff::util::fault::{self, Trigger};
use fadiff::util::json::Json;

struct DisarmOnDrop;
impl Drop for DisarmOnDrop {
    fn drop(&mut self) {
        fault::disarm_all();
    }
}

fn tmp_dir(tag: &str) -> PathBuf {
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .unwrap()
        .as_nanos();
    let d = std::env::temp_dir().join(format!(
        "fadiff_chaos_{tag}_{}_{nanos}",
        std::process::id()
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn job(seed: u64) -> JobRequest {
    JobRequest {
        workload: "mobilenet".into(),
        config: "large".into(),
        method: Method::Random,
        seconds: 3600.0, // iteration-capped: deterministic per seed
        max_iters: 40,
        seed,
        chains: 0,
        deadline_ms: 0,
        spec: None,
        force: false,
        prune: fadiff::search::PruneMode::On,
        warm_frac: 0.0,
    }
}

fn wait_terminal(coord: &Coordinator, id: u64) -> JobStatus {
    let t0 = Instant::now();
    loop {
        let (status, _) = coord.job_status(id).expect("known job");
        if status.is_terminal() {
            return status;
        }
        assert!(t0.elapsed() < Duration::from_secs(60),
                "job {id} stuck in {status:?}");
        std::thread::sleep(Duration::from_millis(20));
    }
}

#[test]
fn store_io_faults_retry_then_degrade_without_wrong_answers() {
    let _g = fault::registry_lock();
    let _d = DisarmOnDrop;
    let dir = tmp_dir("io");
    let cold = {
        let coord = Coordinator::new_with_store(
            None, 1, Some(dir.clone())).unwrap();
        coord.run(job(7)).unwrap()
    }; // drop flushes the store

    // one transient read failure: the retry budget absorbs it and the
    // warm answer is still served bit-exact
    let coord = Coordinator::new_with_store(
        None, 1, Some(dir.clone())).unwrap();
    fault::arm(fault::STORE_READ_IO, Trigger::OneShot, 0).unwrap();
    let warm = coord.run(job(7)).unwrap();
    assert!(warm.stored, "retry must recover the stored answer");
    assert_eq!(warm.edp.to_bits(), cold.edp.to_bits());
    let st = coord.store().unwrap();
    assert!(st.stats().io_retries.load(Ordering::SeqCst) >= 1,
            "transient failure must be counted as a retry");
    assert_eq!(st.stats().io_permanent.load(Ordering::SeqCst), 0);

    // every blob read corrupted: digest verification rejects them
    // all and the request degrades to a counted cold recompute —
    // never a wrong answer
    fault::disarm_all();
    fault::arm(fault::STORE_CORRUPT, Trigger::Always, 0).unwrap();
    let recomputed = coord.run(job(7)).unwrap();
    assert!(!recomputed.stored,
            "corruption must force a real recompute");
    assert_eq!(recomputed.edp.to_bits(), cold.edp.to_bits(),
               "recompute must reproduce the same numbers");
    assert!(st.stats().corrupt_skips.load(Ordering::SeqCst) >= 1);

    // persistent write failure: the job still completes (persistence
    // is best-effort) and the exhausted budget counts one permanent
    fault::disarm_all();
    fault::arm(fault::STORE_WRITE_IO, Trigger::Always, 0).unwrap();
    let unsaved = coord.run(job(8)).unwrap();
    assert!(unsaved.edp.is_finite() && unsaved.edp > 0.0);
    assert!(st.stats().io_permanent.load(Ordering::SeqCst) >= 1,
            "exhausted retries must count a permanent failure");
    drop(coord);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn panicking_job_is_contained_and_the_coordinator_keeps_serving() {
    let _g = fault::registry_lock();
    let _d = DisarmOnDrop;
    let coord = Coordinator::new(None, 1).unwrap();
    fault::arm(fault::JOB_PANIC, Trigger::OneShot, 0).unwrap();
    let id = coord.submit_tracked(job(1)).unwrap();
    assert_eq!(wait_terminal(&coord, id), JobStatus::Failed);
    let (_, result) = coord.job_status(id).unwrap();
    let msg = result.unwrap().unwrap_err();
    assert!(msg.contains("panicked"), "{msg}");
    assert_eq!(coord.metrics.job_panics.load(Ordering::SeqCst), 1);
    // the worker survived: the very next job completes normally
    let r = coord.run(job(2)).unwrap();
    assert!(r.edp.is_finite() && r.edp > 0.0);
    assert_eq!(coord.metrics.in_flight(), 0);
}

#[test]
fn scheduler_pass_panics_fall_back_to_identical_local_results() {
    let _g = fault::registry_lock();
    let _d = DisarmOnDrop;
    // baseline numbers from an unfaulted coordinator
    let baseline = Coordinator::new(None, 2).unwrap()
        .run(job(5)).unwrap();

    // every merge pass panics: waiters get empty replies and fall
    // back to local evaluation — same numbers, contained panics
    let coord = Coordinator::new(None, 2).unwrap();
    fault::arm(fault::SCHED_PANIC, Trigger::Always, 0).unwrap();
    let r = coord.run(job(5)).unwrap();
    assert_eq!(r.edp.to_bits(), baseline.edp.to_bits(),
               "local fallback must be bit-identical");
    let m = coord.metrics_json();
    let contained = m.get("supervision").unwrap()
        .get_f64("scheduler_panics_contained").unwrap();
    assert!(contained >= 1.0,
            "pass panics must be counted: {contained}");

    // a dropped batch (failed channel send) degrades the same way
    fault::disarm_all();
    let coord = Coordinator::new(None, 2).unwrap();
    fault::arm(fault::SCHED_DROP, Trigger::Always, 0).unwrap();
    let r = coord.run(job(5)).unwrap();
    assert_eq!(r.edp.to_bits(), baseline.edp.to_bits());
}

#[test]
fn watchdog_fails_stalled_jobs_instead_of_wedging_the_queue() {
    let _g = fault::registry_lock();
    let _d = DisarmOnDrop;
    let coord = Coordinator::new(None, 1).unwrap();
    coord.set_stall_ms(200);
    // every eval sleeps far past the stall threshold: no search
    // progress ever lands, so the watchdog must fail the job
    fault::arm(fault::EVAL_STALL, Trigger::Always, 1500).unwrap();
    let id = coord.submit_tracked(job(1)).unwrap();
    assert_eq!(wait_terminal(&coord, id), JobStatus::Failed);
    let (_, result) = coord.job_status(id).unwrap();
    let msg = result.unwrap().unwrap_err();
    assert!(msg.contains("watchdog"), "{msg}");
    assert!(coord.metrics.watchdog_kills.load(Ordering::SeqCst) >= 1);
    // the queue is not wedged: with injection gone the next job runs
    fault::disarm_all();
    coord.set_stall_ms(30_000);
    let r = coord.run(job(2)).unwrap();
    assert!(r.edp.is_finite() && r.edp > 0.0);
    assert_eq!(coord.metrics.in_flight(), 0);
}

// ---------------------------------------------------------------------
// over-the-wire chaos
// ---------------------------------------------------------------------

struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        Client { stream, reader }
    }

    fn request(&mut self, body: &str) -> Json {
        self.stream.write_all(body.as_bytes()).unwrap();
        self.stream.write_all(b"\n").unwrap();
        let mut line = String::new();
        self.reader.read_line(&mut line).unwrap();
        Json::parse(line.trim()).unwrap_or_else(|e| {
            panic!("unparseable response {line:?}: {e}")
        })
    }
}

fn ok_payload(j: &Json) -> &Json {
    assert!(j.get("error").is_err(),
            "expected success envelope, got {j:?}");
    j.get("ok").unwrap()
}

fn start_server(workers: usize)
                -> (std::net::SocketAddr,
                    std::thread::JoinHandle<anyhow::Result<()>>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let coord = Coordinator::new(None, workers).unwrap();
    let t = std::thread::spawn(move || server::serve_on(listener, coord));
    (addr, t)
}

#[test]
fn chaos_verb_arms_over_the_wire_and_metrics_count_fires() {
    let _g = fault::registry_lock();
    let _d = DisarmOnDrop;
    let (addr, t) = start_server(1);
    let mut cl = Client::connect(addr);

    // arm a harmless delay site over the wire
    let r = cl.request(
        r#"{"verb": "chaos", "action": "arm", "site": "eval.slow",
            "mode": "always", "delay_ms": 1}"#
            .replace('\n', " ")
            .as_str(),
    );
    let body = ok_payload(&r);
    assert_eq!(body.get("available").unwrap(), &Json::Bool(true));

    // a short job probes the armed site on every eval
    let o = cl.request(
        r#"{"verb": "optimize", "workload": "mobilenet",
            "method": "random", "seconds": 3600, "max_iters": 8,
            "seed": 3}"#
            .replace('\n', " ")
            .as_str(),
    );
    assert!(ok_payload(&o).get_f64("edp").unwrap() > 0.0);

    // status and metrics agree that the site fired
    let s = cl.request(r#"{"verb": "chaos", "action": "status"}"#);
    let armed = ok_payload(&s).get("armed").unwrap()
        .as_arr().unwrap().clone();
    let row = armed.iter()
        .find(|r| r.get("site").unwrap().as_str().unwrap()
                  == "eval.slow")
        .expect("armed site listed");
    assert!(row.get_f64("fires").unwrap() >= 1.0, "{row:?}");
    let m = cl.request(r#"{"verb": "metrics"}"#);
    let faults = ok_payload(&m).get("faults").unwrap();
    assert_eq!(faults.get("injection_enabled").unwrap(),
               &Json::Bool(true));
    let injected = faults.get("injected").unwrap();
    assert!(injected.get("eval.slow").unwrap()
        .get_f64("fires").unwrap() >= 1.0, "{m:?}");

    // reset disarms everything
    let r = cl.request(r#"{"verb": "chaos", "action": "reset"}"#);
    assert!(ok_payload(&r).get("armed").unwrap()
        .as_arr().unwrap().is_empty());
    assert!(fault::snapshot().is_empty());

    let s = cl.request(r#"{"verb": "shutdown"}"#);
    assert!(ok_payload(&s).get("shutting_down").is_ok());
    t.join().unwrap().unwrap();
}

#[test]
fn mixed_fault_battery_lands_every_job_on_one_terminal_status() {
    let _g = fault::registry_lock();
    let _d = DisarmOnDrop;
    let (addr, t) = start_server(2);
    let mut cl = Client::connect(addr);

    // a seeded probabilistic mix across the serving stack: panics,
    // dropped scheduler batches, slow evals — reproducible per seed
    fault::arm(fault::JOB_PANIC,
               Trigger::Probability { p: 0.25, seed: 42 }, 0)
        .unwrap();
    fault::arm(fault::SCHED_DROP,
               Trigger::Probability { p: 0.3, seed: 42 }, 0)
        .unwrap();
    fault::arm(fault::EVAL_SLOW,
               Trigger::Probability { p: 0.2, seed: 42 }, 2)
        .unwrap();
    fault::arm(fault::POOL_PANIC,
               Trigger::Probability { p: 0.05, seed: 42 }, 0)
        .unwrap();

    const JOBS: usize = 12;
    let mut ids = Vec::new();
    for i in 0..JOBS {
        let method = if i % 3 == 0 { "ga" } else { "random" };
        // every third job also carries a tight deadline
        let deadline = if i % 3 == 2 { 400 } else { 0 };
        let body = format!(
            "{{\"verb\": \"submit\", \"workload\": \"mobilenet\", \
             \"method\": \"{method}\", \"seconds\": 3600, \
             \"max_iters\": 300, \"seed\": {i}, \
             \"deadline_ms\": {deadline}}}"
        );
        let r = cl.request(&body);
        ids.push(ok_payload(&r).get_f64("job_id").unwrap() as u64);
        // the server must answer control traffic throughout
        let pong = cl.request(r#"{"verb": "ping"}"#);
        assert_eq!(ok_payload(&pong).get("pong").unwrap(),
                   &Json::Bool(true));
    }
    // cancel a few mid-flight
    for id in [ids[1], ids[5]] {
        let c = cl.request(
            &format!("{{\"verb\": \"cancel\", \"job_id\": {id}}}"));
        assert!(ok_payload(&c).get("status").is_ok());
    }

    // every job reaches exactly one terminal status, and that status
    // is stable once reached
    let t0 = Instant::now();
    for &id in &ids {
        let terminal = loop {
            let st = cl.request(
                &format!("{{\"verb\": \"status\", \
                          \"job_id\": {id}}}"));
            let status = ok_payload(&st).get("status").unwrap()
                .as_str().unwrap().to_string();
            match status.as_str() {
                "completed" | "failed" | "cancelled"
                | "deadline_exceeded" => break status,
                "queued" | "running" => {}
                other => panic!("job {id}: bad status {other}"),
            }
            assert!(t0.elapsed() < Duration::from_secs(120),
                    "job {id} never reached a terminal status");
            std::thread::sleep(Duration::from_millis(20));
        };
        let again = cl.request(
            &format!("{{\"verb\": \"status\", \"job_id\": {id}}}"));
        assert_eq!(ok_payload(&again).get("status").unwrap()
                       .as_str().unwrap(),
                   terminal, "terminal status changed");
    }

    // the books balance: every submission is accounted for and
    // nothing is left in flight
    let m = cl.request(r#"{"verb": "metrics"}"#);
    let body = ok_payload(&m);
    let done = body.get_f64("completed").unwrap()
        + body.get_f64("failed").unwrap()
        + body.get_f64("cancelled").unwrap()
        + body.get_f64("deadline_exceeded").unwrap();
    assert_eq!(done, JOBS as f64, "{m:?}");
    assert_eq!(body.get_f64("in_flight").unwrap(), 0.0, "{m:?}");

    fault::disarm_all();
    // with injection gone the server serves normally
    let o = cl.request(
        r#"{"verb": "optimize", "workload": "mobilenet",
            "method": "random", "seconds": 3600, "max_iters": 8,
            "seed": 99}"#
            .replace('\n', " ")
            .as_str(),
    );
    assert!(ok_payload(&o).get_f64("edp").unwrap() > 0.0);
    let s = cl.request(r#"{"verb": "shutdown"}"#);
    assert!(ok_payload(&s).get("shutting_down").is_ok());
    t.join().unwrap().unwrap();
}
