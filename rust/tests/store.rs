//! End-to-end tests of the persistent result store: a restarted
//! coordinator serves previously-solved requests warm and bit-exact,
//! corruption degrades to a counted cold recompute (never a stale or
//! wrong answer), a future on-disk format is never clobbered, and
//! concurrent jobs share one store safely.

use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::Arc;

use fadiff::coordinator::{Coordinator, JobRequest, Method};
use fadiff::util::json::Json;

fn tmp_dir(tag: &str) -> PathBuf {
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .unwrap()
        .as_nanos();
    let d = std::env::temp_dir().join(format!(
        "fadiff_store_{tag}_{}_{nanos}",
        std::process::id()
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn job(seed: u64) -> JobRequest {
    JobRequest {
        workload: "mobilenet".into(),
        config: "large".into(),
        method: Method::Random,
        seconds: 3600.0, // iteration-capped: deterministic per seed
        max_iters: 40,
        seed,
        chains: 0,
        deadline_ms: 0,
        spec: None,
        force: false,
        prune: fadiff::search::PruneMode::On,
        warm_frac: 0.0,
    }
}

fn coord_on(dir: &PathBuf) -> Coordinator {
    Coordinator::new_with_store(None, 1, Some(dir.clone())).unwrap()
}

#[test]
fn restart_serves_bit_identical_results_without_searching() {
    let dir = tmp_dir("warm");
    let cold = {
        let coord = coord_on(&dir);
        let r = coord.run(job(7)).unwrap();
        assert!(!r.stored, "first solve must be a real search");
        r
    }; // drop: shutdown flush persists the pair's eval segment too
    let coord = coord_on(&dir);
    let warm = coord.run(job(7)).unwrap();
    assert!(warm.stored, "a restarted coordinator must serve warm");
    assert_eq!(warm.edp.to_bits(), cold.edp.to_bits());
    assert_eq!(warm.energy.to_bits(), cold.energy.to_bits());
    assert_eq!(warm.latency.to_bits(), cold.latency.to_bits());
    assert_eq!(warm.fused_names, cold.fused_names);
    // effort reports the original run, not the (free) stored hit
    assert_eq!(warm.iters, cold.iters);
    assert_eq!(warm.evals, cold.evals);
    let st = coord.store().expect("store attached");
    assert_eq!(st.stats().result_hits.load(Ordering::SeqCst), 1);
    // force bypasses the stored answer but reproduces it exactly
    let forced =
        coord.run(JobRequest { force: true, ..job(7) }).unwrap();
    assert!(!forced.stored, "force must re-search");
    assert_eq!(forced.edp.to_bits(), cold.edp.to_bits());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn corrupt_result_blob_degrades_to_counted_cold_recompute() {
    let dir = tmp_dir("corrupt");
    let cold = {
        let coord = coord_on(&dir);
        coord.run(job(11)).unwrap()
    };
    // clobber every result blob: its content no longer matches the
    // digest it is named by
    let manifest =
        std::fs::read_to_string(dir.join("manifest.json")).unwrap();
    let j = Json::parse(&manifest).unwrap();
    let results = j.get("results").unwrap().as_obj().unwrap();
    assert!(!results.is_empty(), "the solve must have recorded");
    for meta in results.values() {
        let digest = meta.get("digest").unwrap().as_str().unwrap();
        std::fs::write(dir.join("blobs").join(digest),
                       "{\"kind\": \"garbage\"}")
            .unwrap();
    }
    let coord = coord_on(&dir);
    let again = coord.run(job(11)).unwrap();
    assert!(!again.stored, "a corrupt blob must never serve");
    assert_eq!(again.edp.to_bits(), cold.edp.to_bits(),
               "the cold recompute is deterministic");
    let st = coord.store().unwrap();
    assert!(st.stats().corrupt_skips.load(Ordering::SeqCst) >= 1,
            "the skip must be observable");
    drop(coord);
    // the recompute recorded fresh: a third process is warm again
    let coord = coord_on(&dir);
    let warm = coord.run(job(11)).unwrap();
    assert!(warm.stored, "recovery must re-persist the result");
    assert_eq!(warm.edp.to_bits(), cold.edp.to_bits());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn truncated_manifest_starts_empty_and_recovers() {
    let dir = tmp_dir("truncated");
    {
        let coord = coord_on(&dir);
        let _ = coord.run(job(5)).unwrap();
    }
    let path = dir.join("manifest.json");
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::write(&path, &text[..text.len() / 2]).unwrap();
    let coord = coord_on(&dir);
    let st = Arc::clone(coord.store().unwrap());
    assert!(st.writable(), "garbage manifest stays writable");
    assert!(st.stats().corrupt_skips.load(Ordering::SeqCst) >= 1);
    let r = coord.run(job(5)).unwrap();
    assert!(!r.stored, "a lost manifest serves cold");
    drop(coord);
    // and the fresh result re-persisted under a valid manifest
    let coord = coord_on(&dir);
    assert!(coord.run(job(5)).unwrap().stored);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn future_manifest_version_serves_cold_and_is_never_clobbered() {
    let dir = tmp_dir("future");
    {
        let coord = coord_on(&dir);
        let _ = coord.run(job(3)).unwrap();
    }
    let path = dir.join("manifest.json");
    let future = "{\"version\": 2, \"from_the_future\": true}";
    std::fs::write(&path, future).unwrap();
    let coord = coord_on(&dir);
    assert!(!coord.store().unwrap().writable());
    let r = coord.run(job(3)).unwrap();
    assert!(!r.stored, "an unknown manifest version serves cold");
    drop(coord); // the shutdown flush must not write either
    assert_eq!(std::fs::read_to_string(&path).unwrap(), future,
               "a future-format manifest must stay byte-identical");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn concurrent_jobs_share_one_store_and_flush_on_shutdown() {
    let dir = tmp_dir("concurrent");
    let coord = Coordinator::new_with_store(None, 4, Some(dir.clone()))
        .unwrap();
    let st = Arc::clone(coord.store().unwrap());
    // two distinct keys, each solved twice concurrently
    let rxs: Vec<_> = [21u64, 22, 21, 22]
        .into_iter()
        .map(|seed| (seed, coord.submit(job(seed))))
        .collect();
    let mut by_seed: Vec<(u64, f64)> = Vec::new();
    for (seed, rx) in rxs {
        let r = rx.wait().expect("worker alive").expect("job ok");
        by_seed.push((seed, r.edp));
    }
    for seed in [21u64, 22] {
        let edps: Vec<u64> = by_seed
            .iter()
            .filter(|(s, _)| *s == seed)
            .map(|(_, e)| e.to_bits())
            .collect();
        assert_eq!(edps.len(), 2);
        assert_eq!(edps[0], edps[1],
                   "same key must resolve identically");
    }
    assert!(st.stats().results_written.load(Ordering::SeqCst) >= 2,
            "both keys must persist");
    drop(coord);
    assert!(st.stats().flushes.load(Ordering::SeqCst) >= 1,
            "shutdown must flush the dirty eval segment");
    // a second coordinator is warm for both keys
    let coord = coord_on(&dir);
    assert!(coord.run(job(21)).unwrap().stored);
    assert!(coord.run(job(22)).unwrap().stored);
    let st2 = coord.store().unwrap();
    assert_eq!(st2.stats().result_hits.load(Ordering::SeqCst), 2);
    // a forced re-search builds real engines, so the pair's eval
    // cache hydrates from the flushed segment — and reproduces the
    // stored answer bit-for-bit
    let forced =
        coord.run(JobRequest { force: true, ..job(21) }).unwrap();
    assert!(!forced.stored);
    assert_eq!(forced.edp.to_bits(),
               by_seed.iter().find(|(s, _)| *s == 21).unwrap().1
                   .to_bits());
    assert!(st2.stats().hydrations.load(Ordering::SeqCst) >= 1,
            "the eval segment must hydrate on first engine use");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn store_metrics_report_manifest_and_blob_usage() {
    let dir = tmp_dir("metrics");
    let coord = coord_on(&dir);
    let _ = coord.run(job(2)).unwrap();
    let j = coord.store().unwrap().stats_json();
    assert_eq!(j.get("enabled").unwrap(), &Json::Bool(true));
    assert_eq!(j.get_f64("manifest_results").unwrap(), 1.0);
    assert!(j.get_f64("blob_count").unwrap() >= 1.0);
    assert!(j.get_f64("blob_bytes").unwrap() > 0.0);
    assert_eq!(j.get_f64("results_written").unwrap(), 1.0);
    // the metrics verb embeds the same block
    let m = coord.metrics_json();
    assert!(m.get("store").is_ok(), "metrics must carry the store");
    std::fs::remove_dir_all(&dir).ok();
}
