//! Integration: the experiment harnesses reproduce the paper's
//! qualitative results end to end (small budgets; the full-budget runs
//! are recorded in EXPERIMENTS.md).

use fadiff::config::{load_config, repo_root};
use fadiff::experiments::{fig3, fig4, validation};
use fadiff::runtime::Runtime;
use fadiff::sim::tilesim;
use fadiff::workload::zoo;

#[test]
fn validation_report_is_complete_and_strong() {
    let hw = load_config(&repo_root(), "large").unwrap();
    let r = validation::run(&hw, 30, 7);
    assert_eq!(r.per_op.len(), zoo::validation_operators().len());
    for o in &r.per_op {
        assert!(o.access_accuracy > 0.5, "{}: {}", o.name,
                o.access_accuracy);
        assert!(o.latency_rho > 0.5, "{}: {}", o.name, o.latency_rho);
    }
    let text = validation::render(&r);
    assert!(text.contains("**mean**"));
}

#[test]
fn validation_holds_on_small_config_too() {
    let hw = load_config(&repo_root(), "small").unwrap();
    let r = validation::run(&hw, 25, 13);
    assert!(r.mean_access_accuracy > 0.75,
            "accuracy {}", r.mean_access_accuracy);
    assert!(r.mean_energy_rho > 0.7, "rho {}", r.mean_energy_rho);
}

#[test]
fn fig3_both_panels_track_definesim() {
    let hw = load_config(&repo_root(), "large").unwrap();
    let (two, three) = fig3::run(&hw);
    for (name, p) in [("2-layer", &two), ("3-layer", &three)] {
        assert!(p.energy_corr > 0.7, "{name} energy {}", p.energy_corr);
        // z-scored series have matching lengths and finite values
        assert_eq!(p.z_energy.0.len(), p.z_energy.1.len());
        assert!(p.z_energy.0.iter().all(|v| v.is_finite()));
    }
}

fn runtime() -> Option<Runtime> {
    let rt = Runtime::load_if_available(&repo_root().join("artifacts"));
    if rt.is_none() {
        eprintln!("skipping: PJRT runtime unavailable");
    }
    rt
}

#[test]
fn fig4_trace_endpoints_ordered() {
    let Some(rt) = runtime() else { return };
    let hw = load_config(&repo_root(), "large").unwrap();
    let w = zoo::mobilenet_v1();
    let r = fig4::run(Some(&rt), &w, &hw, 2.5, 3).unwrap();
    let grad = r.methods[0].final_edp;
    assert!(grad <= r.methods[1].final_edp * 1.05, "GA beat gradient");
    assert!(grad <= r.methods[2].final_edp * 1.05, "BO beat gradient");
    // render produces a complete grid
    let text = fig4::render(&r);
    assert!(text.matches('\n').count() > 10);
}

#[test]
fn golden_simulator_agrees_on_optimized_strategies() {
    // the winning strategies (not just random ones) must stay in a sane
    // envelope of the independent simulator; GA's winners check the
    // native path unconditionally, gradient winners when PJRT exists
    let hw = load_config(&repo_root(), "large").unwrap();
    let w = zoo::vgg16();
    let rga = fadiff::search::ga::optimize(
        &w, &hw, &fadiff::search::ga::GaConfig::default(),
        fadiff::search::Budget::iters(6))
        .unwrap();
    let native_ga = fadiff::costmodel::evaluate(&rga.best, &w, &hw);
    let sim_ga = tilesim::simulate(&rga.best, &w, &hw);
    let ratio_ga = sim_ga.edp / native_ga.edp;
    assert!(ratio_ga > 0.05 && ratio_ga < 20.0,
            "sim/model EDP ratio {ratio_ga}");

    let Some(rt) = runtime() else { return };
    let r = fadiff::search::gradient::optimize(
        Some(&rt), &w, &hw,
        &fadiff::search::gradient::GradientConfig::default(),
        fadiff::search::Budget { seconds: 2.0, max_iters: usize::MAX },
    )
    .unwrap();
    let native = fadiff::costmodel::evaluate(&r.best, &w, &hw);
    let sim = tilesim::simulate(&r.best, &w, &hw);
    let ratio = sim.edp / native.edp;
    assert!(ratio > 0.05 && ratio < 20.0, "sim/model EDP ratio {ratio}");
    // simulator never sees MORE traffic than the pessimistic closed form
    for (lc, sl) in native.per_layer.iter().zip(&sim.per_layer) {
        assert!(sl.access[3] <= lc.access[3] * 1.0001,
                "sim DRAM > closed-form DRAM");
    }
}
