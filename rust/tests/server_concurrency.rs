//! Concurrency battery for the TCP serving layer: many client threads
//! firing mixed verbs at one warm server over persistent connections.
//!
//! Pins the sweep-serving guarantees: every response parses as one
//! JSON line, cross-job cache hit counters are monotone (and actually
//! nonzero when identical jobs repeat), all jobs are accounted for,
//! and shutdown joins every connection — including idle ones that
//! never send another byte.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

use fadiff::coordinator::{server, Coordinator};
use fadiff::util::json::Json;

struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        Client { stream, reader }
    }

    /// One request -> one parsed one-line response.
    fn request(&mut self, body: &str) -> Json {
        self.stream.write_all(body.as_bytes()).unwrap();
        self.stream.write_all(b"\n").unwrap();
        self.stream.flush().unwrap();
        let mut line = String::new();
        self.reader.read_line(&mut line).unwrap();
        assert!(line.ends_with('\n'), "unterminated response: {line:?}");
        Json::parse(line.trim()).unwrap_or_else(|e| {
            panic!("unparseable response {line:?}: {e}")
        })
    }
}

fn cache_hits(metrics: &Json) -> f64 {
    metrics.get("cache").unwrap().get_f64("hits").unwrap()
}

#[test]
fn concurrent_clients_mixed_verbs() {
    const CLIENTS: usize = 6;

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let coord = Coordinator::new(None, 2).unwrap();
    let server_thread =
        std::thread::spawn(move || server::serve_on(listener, coord));

    // an idle connection held open across the whole test: shutdown must
    // still join its handler thread
    let mut idle = Client::connect(addr);
    let pong = idle.request(r#"{"verb": "ping"}"#);
    assert_eq!(pong.get("pong").unwrap(), &Json::Bool(true));

    let workers: Vec<_> = (0..CLIENTS)
        .map(|c| {
            std::thread::spawn(move || {
                let mut cl = Client::connect(addr);

                // 1. ping
                let r = cl.request(r#"{"verb": "ping"}"#);
                assert_eq!(r.get("ok").unwrap(), &Json::Bool(true));

                // 2. metrics (baseline for monotonicity)
                let m0 = cl.request(r#"{"verb": "metrics"}"#);
                assert_eq!(m0.get("ok").unwrap(), &Json::Bool(true));
                let h0 = cache_hits(&m0);

                // 3. optimize — identical across clients, so the shared
                //    (workload, config) cache must produce cross-job hits
                let o = cl.request(
                    r#"{"verb": "optimize", "workload": "mobilenet",
                        "method": "random", "seconds": 3600,
                        "max_iters": 40, "seed": 11}"#
                        .replace('\n', " ")
                        .as_str(),
                );
                assert_eq!(o.get("ok").unwrap(), &Json::Bool(true),
                           "client {c}: {o:?}");
                assert!(o.get_f64("edp").unwrap() > 0.0);

                // 4. garbage interleaved — answered, not fatal
                let g = cl.request("not json at all");
                assert_eq!(g.get("ok").unwrap(), &Json::Bool(false));

                // 5. sweep: a 2-point grid through the same queue
                let s = cl.request(
                    r#"{"verb": "sweep", "workloads": ["mobilenet"],
                        "methods": ["random"], "seeds": [11, 12],
                        "seconds": 3600, "max_iters": 24}"#
                        .replace('\n', " ")
                        .as_str(),
                );
                assert_eq!(s.get("ok").unwrap(), &Json::Bool(true),
                           "client {c}: {s:?}");
                assert_eq!(s.get_f64("jobs").unwrap(), 2.0);
                assert_eq!(s.get_f64("completed").unwrap(), 2.0);
                assert_eq!(
                    s.get("results").unwrap().as_arr().unwrap().len(),
                    2
                );

                // 6. metrics again: hit counter is monotone from this
                //    client's point of view
                let m1 = cl.request(r#"{"verb": "metrics"}"#);
                let h1 = cache_hits(&m1);
                assert!(h1 >= h0,
                        "cache hits went backwards: {h1} < {h0}");
            })
        })
        .collect();
    for w in workers {
        w.join().unwrap();
    }

    // every job accounted for: per client 1 optimize + 2 sweep cells
    let mut cl = Client::connect(addr);
    let m = cl.request(r#"{"verb": "metrics"}"#);
    assert_eq!(m.get_f64("completed").unwrap(), (CLIENTS * 3) as f64);
    assert_eq!(m.get_f64("failed").unwrap(), 0.0);
    assert_eq!(m.get_f64("in_flight").unwrap(), 0.0);
    // identical jobs repeated across clients: the shared cache must
    // have produced real cross-job hits
    assert!(cache_hits(&m) > 0.0, "no cross-job cache hits: {m:?}");
    assert!(m.get("cache").unwrap().get_f64("pairs").unwrap() >= 1.0);

    // shutdown must terminate the server thread even though `idle` (and
    // `cl`) still hold open connections
    let s = cl.request(r#"{"verb": "shutdown"}"#);
    assert_eq!(s.get("ok").unwrap(), &Json::Bool(true));
    server_thread.join().unwrap().unwrap();
}
