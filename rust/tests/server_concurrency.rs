//! Concurrency battery for the TCP serving layer: many client threads
//! firing mixed verbs at one warm server over persistent connections.
//!
//! Pins the sweep-serving guarantees: every response parses as one
//! JSON line carrying the v1 envelope, cross-job cache hit counters
//! are monotone (and actually nonzero when identical jobs repeat),
//! all jobs are accounted for, and shutdown drains every connection —
//! including idle ones that never send another byte. Also exercises
//! the `status {"watch": true}` event stream end to end.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

use fadiff::coordinator::{server, Coordinator};
use fadiff::util::json::Json;

struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        Client { stream, reader }
    }

    /// One request -> one parsed one-line response.
    fn request(&mut self, body: &str) -> Json {
        self.stream.write_all(body.as_bytes()).unwrap();
        self.stream.write_all(b"\n").unwrap();
        self.stream.flush().unwrap();
        self.read_event()
    }

    /// Read one line (a watch event or a response) and parse it.
    fn read_event(&mut self) -> Json {
        let mut line = String::new();
        self.reader.read_line(&mut line).unwrap();
        assert!(line.ends_with('\n'), "unterminated response: {line:?}");
        Json::parse(line.trim()).unwrap_or_else(|e| {
            panic!("unparseable response {line:?}: {e}")
        })
    }
}

/// Unwrap a success envelope: `protocol` is 1, no `error`, return the
/// `ok` payload.
fn ok_payload(j: &Json) -> &Json {
    assert_eq!(j.get("protocol").unwrap().as_f64().unwrap(), 1.0,
               "{j:?}");
    assert!(j.get("error").is_err(),
            "expected success envelope, got {j:?}");
    j.get("ok").unwrap()
}

fn cache_hits(metrics: &Json) -> f64 {
    metrics.get("cache").unwrap().get_f64("hits").unwrap()
}

#[test]
fn concurrent_clients_mixed_verbs() {
    const CLIENTS: usize = 6;

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let coord = Coordinator::new(None, 2).unwrap();
    let server_thread =
        std::thread::spawn(move || server::serve_on(listener, coord));

    // an idle connection held open across the whole test: shutdown must
    // still drain it from the event loop
    let mut idle = Client::connect(addr);
    let pong = idle.request(r#"{"verb": "ping"}"#);
    assert_eq!(ok_payload(&pong).get("pong").unwrap(),
               &Json::Bool(true));

    let workers: Vec<_> = (0..CLIENTS)
        .map(|c| {
            std::thread::spawn(move || {
                let mut cl = Client::connect(addr);

                // 1. ping
                let r = cl.request(r#"{"verb": "ping"}"#);
                assert_eq!(ok_payload(&r).get("pong").unwrap(),
                           &Json::Bool(true));

                // 2. metrics (baseline for monotonicity)
                let m0 = cl.request(r#"{"verb": "metrics"}"#);
                let h0 = cache_hits(ok_payload(&m0));

                // 3. optimize — identical across clients, so the shared
                //    (workload, config) cache must produce cross-job hits
                let o = cl.request(
                    r#"{"verb": "optimize", "workload": "mobilenet",
                        "method": "random", "seconds": 3600,
                        "max_iters": 40, "seed": 11}"#
                        .replace('\n', " ")
                        .as_str(),
                );
                let body = ok_payload(&o);
                assert!(body.get_f64("edp").unwrap() > 0.0,
                        "client {c}: {o:?}");

                // 4. garbage interleaved — answered, not fatal
                let g = cl.request("not json at all");
                assert_eq!(
                    g.get("error").unwrap().get("code").unwrap()
                        .as_str().unwrap(),
                    "bad_request",
                    "client {c}: {g:?}"
                );

                // 5. sweep: a 2-point grid through the same queue
                let s = cl.request(
                    r#"{"verb": "sweep", "workloads": ["mobilenet"],
                        "methods": ["random"], "seeds": [11, 12],
                        "seconds": 3600, "max_iters": 24}"#
                        .replace('\n', " ")
                        .as_str(),
                );
                let grid = ok_payload(&s);
                assert_eq!(grid.get_f64("jobs").unwrap(), 2.0,
                           "client {c}: {s:?}");
                assert_eq!(grid.get_f64("completed").unwrap(), 2.0);
                let cells =
                    grid.get("results").unwrap().as_arr().unwrap();
                assert_eq!(cells.len(), 2);
                for cell in cells {
                    // every completed cell nests the success payload
                    assert!(
                        cell.get("ok").unwrap().get_f64("edp")
                            .unwrap() > 0.0,
                        "client {c}: {cell:?}"
                    );
                }

                // 6. metrics again: hit counter is monotone from this
                //    client's point of view
                let m1 = cl.request(r#"{"verb": "metrics"}"#);
                let h1 = cache_hits(ok_payload(&m1));
                assert!(h1 >= h0,
                        "cache hits went backwards: {h1} < {h0}");
            })
        })
        .collect();
    for w in workers {
        w.join().unwrap();
    }

    // every job accounted for: per client 1 optimize + 2 sweep cells
    let mut cl = Client::connect(addr);
    let m = cl.request(r#"{"verb": "metrics"}"#);
    let body = ok_payload(&m).clone();
    assert_eq!(body.get_f64("completed").unwrap(),
               (CLIENTS * 3) as f64);
    assert_eq!(body.get_f64("failed").unwrap(), 0.0);
    assert_eq!(body.get_f64("in_flight").unwrap(), 0.0);
    // identical jobs repeated across clients: the shared cache must
    // have produced real cross-job hits
    assert!(cache_hits(&body) > 0.0,
            "no cross-job cache hits: {m:?}");
    assert!(body.get("cache").unwrap().get_f64("pairs").unwrap()
            >= 1.0);
    // the fleet scheduler is live behind the server: its counters are
    // part of the metrics payload even when no passes merged
    let sched = body.get("scheduler").unwrap();
    assert!(sched.get_f64("passes").is_ok(), "{m:?}");

    // shutdown must terminate the server thread even though `idle` (and
    // `cl`) still hold open connections
    let s = cl.request(r#"{"verb": "shutdown"}"#);
    assert_eq!(ok_payload(&s).get("shutting_down").unwrap(),
               &Json::Bool(true));
    server_thread.join().unwrap().unwrap();
}

#[test]
fn watch_disconnect_releases_the_connection_slot() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let coord = Coordinator::new(None, 1).unwrap();
    let server_thread =
        std::thread::spawn(move || server::serve_on(listener, coord));

    let mut ctl = Client::connect(addr);
    let sub = ctl.request(
        r#"{"verb": "submit", "workload": "mobilenet",
            "method": "random", "seconds": 3600,
            "max_iters": 1000000000000, "seed": 3}"#
            .replace('\n', " ")
            .as_str(),
    );
    let id = ok_payload(&sub).get_f64("job_id").unwrap() as u64;

    // a watcher that reads one event and then vanishes mid-stream:
    // the event loop must notice the dead socket and reap its slot
    let mut watcher = Client::connect(addr);
    watcher
        .stream
        .write_all(
            format!(
                "{{\"verb\": \"status\", \"job_id\": {id}, \
                 \"watch\": true}}\n"
            )
            .as_bytes(),
        )
        .unwrap();
    let first = watcher.read_event();
    assert!(ok_payload(&first).get("event").is_ok(), "{first:?}");
    drop(watcher);

    // the running job keeps producing progress events, so the next
    // write to the dead watcher fails and closes it; conns_open must
    // fall back to just the control connection
    let t0 = std::time::Instant::now();
    loop {
        let m = ctl.request(r#"{"verb": "metrics"}"#);
        let open = ok_payload(&m).get_f64("conns_open").unwrap();
        if open <= 1.0 {
            break;
        }
        assert!(t0.elapsed() < std::time::Duration::from_secs(30),
                "dead watch connection never reaped: {open} open");
        std::thread::sleep(std::time::Duration::from_millis(20));
    }

    let c = ctl.request(
        &format!("{{\"verb\": \"cancel\", \"job_id\": {id}}}"));
    assert!(ok_payload(&c).get("status").is_ok());
    let s = ctl.request(r#"{"verb": "shutdown"}"#);
    assert!(ok_payload(&s).get("shutting_down").is_ok());
    server_thread.join().unwrap().unwrap();
}

#[test]
fn watch_streams_progress_to_a_terminal_event() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let coord = Coordinator::new(None, 1).unwrap();
    let server_thread =
        std::thread::spawn(move || server::serve_on(listener, coord));

    // submit a job sized to run long enough for progress events, while
    // a second connection watches it to completion
    let mut ctl = Client::connect(addr);
    let sub = ctl.request(
        r#"{"verb": "submit", "workload": "mobilenet",
            "method": "random", "seconds": 3600,
            "max_iters": 4000, "seed": 7}"#
            .replace('\n', " ")
            .as_str(),
    );
    let id = ok_payload(&sub).get_f64("job_id").unwrap() as u64;

    let mut watcher = Client::connect(addr);
    watcher
        .stream
        .write_all(
            format!(
                "{{\"verb\": \"status\", \"job_id\": {id}, \
                 \"watch\": true}}\n"
            )
            .as_bytes(),
        )
        .unwrap();

    let mut statuses: Vec<String> = Vec::new();
    let mut last_seq = 0.0_f64;
    let mut progress_events = 0usize;
    let done = loop {
        let ev = watcher.read_event();
        let body = ok_payload(&ev).clone();
        let kind = body.get("event").unwrap().as_str().unwrap()
            .to_string();
        assert_eq!(body.get_f64("job_id").unwrap(), id as f64);
        match kind.as_str() {
            "status" => {
                let s = body.get("status").unwrap().as_str().unwrap();
                // state transitions arrive in order, never repeated
                assert_ne!(statuses.last().map(String::as_str),
                           Some(s), "{ev:?}");
                statuses.push(s.to_string());
            }
            "progress" => {
                let seq = body.get_f64("seq").unwrap();
                assert!(seq > last_seq,
                        "progress seq not monotone: {ev:?}");
                last_seq = seq;
                progress_events += 1;
            }
            "done" => break body,
            other => panic!("unexpected event kind {other}: {ev:?}"),
        }
        assert!(statuses.len() + progress_events < 100_000,
                "watch stream never terminated");
    };

    // exactly one terminal event, carrying the full result payload
    assert_eq!(done.get("status").unwrap().as_str().unwrap(),
               "completed");
    let result = done.get("result").unwrap();
    assert!(result.get_f64("edp").unwrap() > 0.0);
    assert_eq!(result.get("workload").unwrap().as_str().unwrap(),
               "mobilenet");
    // status events report only live states; terminal states arrive
    // exclusively through the single `done` event
    for s in &statuses {
        assert!(s == "queued" || s == "running", "{statuses:?}");
    }

    // after `done` the stream returns to request/response mode
    let pong = watcher.request(r#"{"verb": "ping"}"#);
    assert_eq!(ok_payload(&pong).get("pong").unwrap(),
               &Json::Bool(true));

    let s = ctl.request(r#"{"verb": "shutdown"}"#);
    assert!(ok_payload(&s).get("shutting_down").is_ok());
    server_thread.join().unwrap().unwrap();
}
