//! Integration: the Rust runtime loads the AOT HLO artifacts, executes
//! them on PJRT, and the numbers agree with the native closed-form model
//! — the end-to-end L1/L2/L3 consistency proof.
//!
//! Every test here needs real artifacts + a PJRT-backed `xla` crate and
//! skips cleanly when they are absent (`make artifacts` is a build step,
//! not a repo artifact).

use fadiff::config::{load_config, repo_root};
use fadiff::costmodel;
use fadiff::mapping::decode::{decode, Relaxed};
use fadiff::mapping::Strategy;
use fadiff::runtime::{selftest, HostTensor, Runtime, ART_DETAIL, ART_EVAL,
                      ART_GRAD};
use fadiff::runtime::stage::WorkloadStage;
use fadiff::util::rng::Rng;
use fadiff::workload::zoo;

fn runtime() -> Option<Runtime> {
    let rt = Runtime::load_if_available(&repo_root().join("artifacts"));
    if rt.is_none() {
        eprintln!(
            "skipping: PJRT runtime unavailable — run `make artifacts` \
             and link a real xla crate"
        );
    }
    rt
}

#[test]
fn all_artifacts_compile() {
    let Some(rt) = runtime() else { return };
    let report = selftest(&rt).unwrap();
    assert_eq!(report.len(), 3, "{report:?}");
}

#[test]
fn detail_artifact_matches_native_costmodel() {
    let Some(rt) = runtime() else { return };
    let hw = load_config(&repo_root(), "large").unwrap();
    let mut rng = Rng::new(42);
    for w in zoo::table1_suite() {
        let stage =
            WorkloadStage::new(&w, &hw, rt.manifest.l_max,
                               rt.manifest.k_max)
                .unwrap();
        // a random decoded (therefore feasible) strategy
        let mut relaxed = Relaxed::neutral(&w);
        for l in 0..w.len() {
            for d in 0..7 {
                for s in 0..4 {
                    relaxed.theta[l][d][s] = rng.range(0.0, 8.0);
                }
            }
        }
        for i in 0..relaxed.sigma.len() {
            relaxed.sigma[i] = rng.f64();
        }
        let strat = decode(&relaxed, &w, &hw);

        let native = costmodel::evaluate(&strat, &w, &hw);
        let out = rt
            .execute(ART_DETAIL, &[
                stage.pack_factors(&strat),
                stage.pack_sigma(&strat),
                stage.dims.clone(),
                stage.layer_mask.clone(),
                stage.edge_mask.clone(),
                stage.hw.clone(),
            ])
            .unwrap();
        let (edp, energy, latency) =
            (out[0][0] as f64, out[1][0] as f64, out[2][0] as f64);
        let rel = |a: f64, b: f64| (a - b).abs() / b.abs().max(1e-30);
        // f32 artifact vs f64 native: keep a loose but meaningful bound
        assert!(rel(energy, native.energy) < 1e-3,
                "{}: energy {energy} vs {}", w.name, native.energy);
        assert!(rel(latency, native.latency) < 1e-3,
                "{}: latency {latency} vs {}", w.name, native.latency);
        assert!(rel(edp, native.edp) < 2e-3,
                "{}: edp {edp} vs {}", w.name, native.edp);
    }
}

#[test]
fn eval_artifact_batches_match_native() {
    let Some(rt) = runtime() else { return };
    let hw = load_config(&repo_root(), "small").unwrap();
    let w = zoo::vgg16();
    let stage = WorkloadStage::new(&w, &hw, rt.manifest.l_max,
                                   rt.manifest.k_max)
        .unwrap();
    let mut rng = Rng::new(7);
    let mut pop = Vec::new();
    for _ in 0..5 {
        let mut relaxed = Relaxed::neutral(&w);
        for l in 0..w.len() {
            for d in 0..7 {
                for s in 0..4 {
                    relaxed.theta[l][d][s] = rng.range(0.0, 6.0);
                }
            }
        }
        pop.push(decode(&relaxed, &w, &hw));
    }
    let (fac, sig) =
        stage.pack_population(&pop, rt.manifest.b_eval).unwrap();
    let out = rt
        .execute(ART_EVAL, &[
            fac,
            sig,
            stage.dims.clone(),
            stage.layer_mask.clone(),
            stage.edge_mask.clone(),
            stage.hw.clone(),
        ])
        .unwrap();
    for (i, s) in pop.iter().enumerate() {
        let native = costmodel::evaluate(s, &w, &hw);
        let edp = out[0][i] as f64;
        assert!((edp - native.edp).abs() / native.edp < 2e-3,
                "candidate {i}: {edp} vs {}", native.edp);
        // decoded strategies are feasible: violation == 0
        assert!(out[3][i] < 1e-6, "violation {}", out[3][i]);
    }
}

#[test]
fn grad_artifact_produces_finite_gradients() {
    let Some(rt) = runtime() else { return };
    let hw = load_config(&repo_root(), "large").unwrap();
    let w = zoo::resnet18();
    let stage = WorkloadStage::new(&w, &hw, rt.manifest.l_max,
                                   rt.manifest.k_max)
        .unwrap();
    let l = rt.manifest.l_max;
    let k = rt.manifest.k_max;
    let theta = HostTensor::new(vec![1.0f32; l * 7 * 4]);
    let sigma = HostTensor::new(vec![0.0f32; l]);
    let gumbel = HostTensor::new(vec![0.0f32; l * 7 * 4 * k]);
    let out = rt
        .execute(ART_GRAD, &[
            theta,
            sigma,
            stage.dims.clone(),
            stage.div.clone(),
            stage.div_mask.clone(),
            stage.layer_mask.clone(),
            stage.edge_mask.clone(),
            gumbel,
            HostTensor::scalar(1.0),   // tau
            HostTensor::scalar(0.05),  // alpha
            HostTensor::scalar(1.0),   // lambda
            stage.hw.clone(),
        ])
        .unwrap();
    let loss = out[0][0];
    assert!(loss.is_finite(), "loss {loss}");
    assert!(out[1][0] > 0.0, "edp {}", out[1][0]);
    let g_theta = &out[5];
    let g_sigma = &out[6];
    assert_eq!(g_theta.len(), l * 7 * 4);
    assert!(g_theta.iter().all(|g| g.is_finite()));
    assert!(g_sigma.iter().all(|g| g.is_finite()));
    // gradient on real layers must be non-trivial
    let norm: f32 = g_theta.iter().map(|g| g * g).sum::<f32>().sqrt();
    assert!(norm > 1e-6, "gradient identically zero");
    // fusible-edge sigma gradients push toward fusion (negative)
    let fusible = w.fusible.iter().filter(|&&f| f).count();
    assert!(fusible > 0);
    let neg = w
        .fusible
        .iter()
        .enumerate()
        .filter(|&(_, &f)| f)
        .filter(|&(i, _)| g_sigma[i] < 0.0)
        .count();
    assert!(neg * 2 >= fusible, "{neg}/{fusible} edges pull to fusion");
}

#[test]
fn execute_rejects_wrong_shapes() {
    let Some(rt) = runtime() else { return };
    let bad = vec![HostTensor::new(vec![0.0; 3])];
    assert!(rt.execute(ART_DETAIL, &bad).is_err());
    assert!(rt.execute("nonexistent", &[]).is_err());
}
