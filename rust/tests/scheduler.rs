//! Integration pins for the cross-job fleet scheduler: concurrent
//! jobs submitted through one [`Coordinator`] must produce results
//! bit-for-bit identical to running each job serially with
//! [`execute_job`] — merging evaluation batches across jobs changes
//! *where* candidates are computed, never what — and the merge must
//! actually happen (asserted through the `metrics` counters, with the
//! scheduler's hold/release hook making the coalescing window
//! deterministic).

use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use fadiff::coordinator::{execute_job, Coordinator, JobRequest,
                          JobResult, Method};

fn req(method: Method, seed: u64) -> JobRequest {
    JobRequest {
        workload: "mobilenet".into(),
        config: "large".into(),
        method,
        seconds: 3600.0, // iteration-capped: deterministic per seed
        max_iters: 30,
        seed,
        chains: 0,
        deadline_ms: 0,
        spec: None,
        force: false,
        prune: fadiff::search::PruneMode::On,
        warm_frac: 0.0,
    }
}

fn assert_bit_identical(serial: &JobResult, fleet: &JobResult) {
    let label = format!("{}/{} seed {}", serial.request.workload,
                        serial.request.method.name(),
                        serial.request.seed);
    assert_eq!(serial.edp.to_bits(), fleet.edp.to_bits(),
               "edp diverged for {label}: {} vs {}",
               serial.edp, fleet.edp);
    assert_eq!(serial.full_model_edp.to_bits(),
               fleet.full_model_edp.to_bits(), "{label}");
    assert_eq!(serial.energy.to_bits(), fleet.energy.to_bits(),
               "{label}");
    assert_eq!(serial.latency.to_bits(), fleet.latency.to_bits(),
               "{label}");
    assert_eq!(serial.groups, fleet.groups, "{label}");
    assert_eq!(serial.fused_names, fleet.fused_names, "{label}");
    assert_eq!(serial.iters, fleet.iters, "{label}");
    assert_eq!(serial.evals, fleet.evals, "{label}");
}

#[test]
fn merged_cross_job_passes_are_bit_identical_to_serial() {
    // three same-(workload, config) jobs — two methods, three seeds —
    // so their evaluation batches coalesce under one scheduler key
    let reqs = vec![
        req(Method::Random, 11),
        req(Method::Random, 22),
        req(Method::Ga, 33),
    ];

    // ground truth: each job alone, no coordinator, no shared cache,
    // no fleet — the plain CLI execution path
    let serial: Vec<JobResult> = reqs
        .iter()
        .map(|r| execute_job(None, r).expect("serial job"))
        .collect();

    // fleet path: all three run concurrently on one coordinator; the
    // held scheduler absorbs every job's first batch, so releasing it
    // forces at least one genuinely merged cross-job pass
    let coord = Coordinator::new(None, 3).unwrap();
    coord.scheduler().hold();
    let handles: Vec<_> =
        reqs.iter().map(|r| coord.submit(r.clone())).collect();
    let t0 = Instant::now();
    while coord.scheduler().stats().items.load(Ordering::Relaxed)
        < reqs.len() as u64
    {
        assert!(t0.elapsed() < Duration::from_secs(60),
                "jobs never reached the scheduler");
        std::thread::sleep(Duration::from_millis(5));
    }
    coord.scheduler().release();
    let fleet: Vec<JobResult> = handles
        .into_iter()
        .map(|h| h.wait().expect("worker alive").expect("fleet job"))
        .collect();

    for (s, f) in serial.iter().zip(&fleet) {
        assert_bit_identical(s, f);
    }

    // the merge really happened, and the wire metrics can prove it
    let m = coord.metrics_json();
    let sched = m.get("scheduler").unwrap();
    assert!(sched.get_f64("merged_passes").unwrap() >= 1.0,
            "no cross-job pass merged: {sched:?}");
    assert!(sched.get_f64("max_items_per_pass").unwrap()
            >= reqs.len() as f64,
            "held batches must coalesce into one pass: {sched:?}");
    assert!(sched.get_f64("candidates").unwrap() > 0.0);
    assert!(sched.get_f64("items").unwrap()
            >= sched.get_f64("merged_items").unwrap());
}

#[test]
fn repeated_merged_runs_are_reproducible() {
    // same request twice through two fresh coordinators: the fleet
    // path must be deterministic run to run, not just serial-matching
    let r = req(Method::Random, 7);
    let run = |r: &JobRequest| -> JobResult {
        let coord = Coordinator::new(None, 2).unwrap();
        coord.submit(r.clone()).wait().unwrap().unwrap()
    };
    assert_bit_identical(&run(&r), &run(&r));
}

#[test]
fn metrics_expose_queue_depth_and_capacity() {
    let coord = Coordinator::new(None, 1).unwrap();
    let m = coord.metrics_json();
    let q = m.get("queue").unwrap();
    assert_eq!(q.get_f64("depth").unwrap(), 0.0);
    assert_eq!(q.get_f64("capacity").unwrap(),
               fadiff::coordinator::DEFAULT_QUEUE_CAPACITY as f64);
    // capacity is clamped to at least one queued job
    coord.set_queue_capacity(0);
    assert_eq!(coord.queue_capacity(), 1);
    coord.set_queue_capacity(17);
    assert_eq!(coord.queue_capacity(), 17);
}
