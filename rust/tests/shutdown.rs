//! Process-level shutdown behavior of the `fadiff serve` binary:
//! SIGTERM must drain gracefully — the result store flushes its eval
//! segments and the process exits cleanly — while a hard SIGKILL must
//! never leave the store unreadable (atomic writes mean a killed child
//! loses at most the unflushed tail, not the store).

#![cfg(unix)]

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use fadiff::util::json::Json;

extern "C" {
    fn kill(pid: i32, sig: i32) -> i32;
}
const SIGTERM: i32 = 15;

fn tmp_dir(tag: &str) -> PathBuf {
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .unwrap()
        .as_nanos();
    let d = std::env::temp_dir().join(format!(
        "fadiff_shutdown_{tag}_{}_{nanos}",
        std::process::id()
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Reserve a free port by binding then dropping (racy in principle,
/// fine for a test that retries the connect).
fn free_addr() -> std::net::SocketAddr {
    TcpListener::bind("127.0.0.1:0").unwrap().local_addr().unwrap()
}

fn spawn_server(addr: &std::net::SocketAddr, store: &PathBuf)
                -> Child {
    Command::new(env!("CARGO_BIN_EXE_fadiff"))
        .args([
            "serve",
            "--addr", &addr.to_string(),
            "--workers", "1",
            "--store-dir", store.to_str().unwrap(),
        ])
        .stdin(Stdio::null())
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn fadiff serve")
}

/// Connect with retries while the child binds its listener.
fn connect(addr: std::net::SocketAddr) -> TcpStream {
    let t0 = Instant::now();
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return s,
            Err(e) => {
                assert!(t0.elapsed() < Duration::from_secs(30),
                        "server never came up: {e}");
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

fn request(addr: std::net::SocketAddr, body: &str) -> Json {
    let mut stream = connect(addr);
    stream.write_all(body.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
    let mut line = String::new();
    BufReader::new(stream).read_line(&mut line).unwrap();
    Json::parse(line.trim())
        .unwrap_or_else(|e| panic!("bad response {line:?}: {e}"))
}

fn wait_exit(child: &mut Child, secs: u64) -> std::process::ExitStatus {
    let t0 = Instant::now();
    loop {
        if let Some(status) = child.try_wait().unwrap() {
            return status;
        }
        assert!(t0.elapsed() < Duration::from_secs(secs),
                "child never exited");
        std::thread::sleep(Duration::from_millis(50));
    }
}

/// Run one tiny job so the store has a recorded result and a warm
/// eval-cache segment to flush.
fn run_one_job(addr: std::net::SocketAddr) {
    let r = request(
        addr,
        "{\"verb\": \"optimize\", \"workload\": \"mobilenet\", \
         \"method\": \"random\", \"seconds\": 3600, \
         \"max_iters\": 24, \"seed\": 7}",
    );
    let edp = r.get("ok").unwrap().get_f64("edp").unwrap();
    assert!(edp > 0.0, "{r:?}");
}

#[test]
fn sigterm_drains_and_flushes_the_store() {
    let dir = tmp_dir("sigterm");
    let addr = free_addr();
    let mut child = spawn_server(&addr, &dir);
    run_one_job(addr);

    unsafe {
        assert_eq!(kill(child.id() as i32, SIGTERM), 0);
    }
    let status = wait_exit(&mut child, 60);
    assert!(status.success(),
            "graceful drain must exit cleanly: {status:?}");

    // the flush proof: the manifest holds both the recorded result
    // and the pair's eval segment (only the drain path writes those)
    let manifest = std::fs::read_to_string(dir.join("manifest.json"))
        .expect("manifest written");
    assert!(manifest.contains("\"res:"),
            "result not flushed: {manifest}");
    assert!(manifest.contains("\"seg:"),
            "eval segment not flushed (no graceful drain): {manifest}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sigkill_never_corrupts_the_store() {
    let dir = tmp_dir("sigkill");
    let addr = free_addr();
    let mut child = spawn_server(&addr, &dir);
    run_one_job(addr);

    child.kill().unwrap(); // SIGKILL: no drain, no flush
    let _ = wait_exit(&mut child, 60);

    // atomic writes: whatever landed before the kill is readable, and
    // the recorded result survives (results persist at job end, not
    // at shutdown)
    let store = fadiff::coordinator::ResultStore::open(&dir)
        .expect("store reopens after SIGKILL");
    let manifest = std::fs::read_to_string(dir.join("manifest.json"))
        .unwrap();
    assert!(manifest.contains("\"res:"),
            "recorded result lost: {manifest}");
    drop(store);

    // and a fresh server on the same dir serves the result warm
    let addr2 = free_addr();
    let mut child2 = spawn_server(&addr2, &dir);
    let r = request(
        addr2,
        "{\"verb\": \"optimize\", \"workload\": \"mobilenet\", \
         \"method\": \"random\", \"seconds\": 3600, \
         \"max_iters\": 24, \"seed\": 7}",
    );
    let body = r.get("ok").unwrap();
    assert_eq!(body.get("stored").unwrap(), &Json::Bool(true),
               "{r:?}");
    unsafe {
        assert_eq!(kill(child2.id() as i32, SIGTERM), 0);
    }
    wait_exit(&mut child2, 60);
    std::fs::remove_dir_all(&dir).ok();
}
