//! Differential battery for the branch-and-bound exact mapper
//! (`search::exact`).
//!
//! An *independent* exhaustive enumerator — written cross-product
//! style, deliberately unlike the mapper's nested-quotient
//! generator — walks the complete divisor/fusion design space of the
//! tiny `micro-*` zoo models, scores every candidate through the
//! same eval kernel, and pins:
//!
//! * **oracle identity** — the certified B&B result is bit-identical
//!   (`f64::to_bits`) to the enumerated optimum on every micro model,
//!   with and without fusion enabled;
//! * **bound admissibility over the FULL space** — for *every*
//!   enumerated candidate the screen's energy/latency/EDP floors
//!   never exceed the exact kernel, and the capacity verdict agrees
//!   with the kernel bit-for-bit (prune_warmstart.rs samples this;
//!   here it is exhaustive);
//! * **prune/seed invariance** — `PruneMode::{On, Off, Full}` and
//!   warm-start seeds never change the certified result;
//! * **cap semantics** — tripping the node, per-layer-candidate, or
//!   frontier cap drops `certified` but still returns a feasible
//!   strategy no better than the true optimum;
//! * **determinism** — two identical runs agree bit-for-bit,
//!   statistics included.
//!
//! The micro models are exhaustively enumerable (~10^4..10^5
//! candidates) so the battery stays debug-build friendly.

use fadiff::config::{load_config, repo_root, HwConfig};
use fadiff::costmodel::bounds::{BoundsCtx, ScreenScratch};
use fadiff::mapping::{divisors, LayerMapping, Strategy, NSLOTS,
                      SLOT_S, SLOT_T0, SLOT_T1, SLOT_T2};
use fadiff::search::exact::{self, ExactConfig, ExactOutcome};
use fadiff::search::{compute_eval, Budget, Eval, EvalCtx, EvalEngine,
                     PruneMode};
use fadiff::workload::{zoo, Workload, DIM_C, DIM_K, NDIMS};

/// Strategies buffered per eval_batch call while streaming the space.
const CHUNK: usize = 512;

/// Safety rail: the micro models must stay exhaustively enumerable.
const MAX_SPACE: u64 = 250_000;

fn hw() -> HwConfig {
    load_config(&repo_root(), "large").unwrap()
}

fn wide_open() -> Budget {
    Budget { seconds: 3600.0, max_iters: usize::MAX }
}

// -------------------------------------------------------------------
// independent exhaustive enumerator
// -------------------------------------------------------------------

fn spatial_cap(d: usize, hw: &HwConfig) -> u64 {
    if d == DIM_K {
        hw.pe_cols as u64
    } else if d == DIM_C {
        hw.pe_rows as u64
    } else {
        1
    }
}

/// Every `[t0, t1, t2, s]` slot assignment for one dimension of
/// extent `n`: each factor a divisor of `n`, the product dividing `n`
/// (the DRAM co-factor absorbs the rest), the spatial slot capped.
/// Filtered cross product — not the mapper's nested quotients — but
/// the same set.
fn dim_list(n: u64, cap: u64) -> Vec<[u64; NSLOTS]> {
    let divs = divisors(n);
    let mut out = Vec::new();
    for &s in divs.iter().filter(|&&s| s <= cap) {
        for &t0 in &divs {
            for &t1 in &divs {
                for &t2 in &divs {
                    if n % (s * t0 * t1 * t2) == 0 {
                        let mut f = [1u64; NSLOTS];
                        f[SLOT_T0] = t0;
                        f[SLOT_T1] = t1;
                        f[SLOT_T2] = t2;
                        f[SLOT_S] = s;
                        out.push(f);
                    }
                }
            }
        }
    }
    out
}

/// Full cross product of one layer's per-dimension assignments.
fn layer_mappings(dims: &[usize; NDIMS], hw: &HwConfig)
                  -> Vec<LayerMapping> {
    let lists: Vec<Vec<[u64; NSLOTS]>> = (0..NDIMS)
        .map(|d| dim_list(dims[d] as u64, spatial_cap(d, hw)))
        .collect();
    let mut out = Vec::new();
    let mut idx = [0usize; NDIMS];
    loop {
        let mut m = LayerMapping::trivial();
        for d in 0..NDIMS {
            m.factors[d] = lists[d][idx[d]];
        }
        out.push(m);
        let mut d = 0;
        loop {
            idx[d] += 1;
            if idx[d] < lists[d].len() {
                break;
            }
            idx[d] = 0;
            d += 1;
            if d == NDIMS {
                return out;
            }
        }
    }
}

/// Every legal fuse vector (all subsets of the fusible edges).
fn fusion_masks(w: &Workload) -> Vec<Vec<bool>> {
    let edges = w.fusible.len();
    assert!(edges <= 8, "micro models must stay tiny");
    let mut out = Vec::new();
    'mask: for mask in 0u32..(1u32 << edges) {
        let mut fuse = vec![false; edges];
        for (i, f) in fuse.iter_mut().enumerate() {
            if mask & (1 << i) != 0 {
                if !w.fusible[i] {
                    continue 'mask;
                }
                *f = true;
            }
        }
        out.push(fuse);
    }
    out
}

/// Stream every strategy in the design space through `visit`,
/// returning the total count. Never materializes the space.
fn enumerate_all<F: FnMut(Strategy)>(w: &Workload, hw: &HwConfig,
                                     mut visit: F) -> u64 {
    let per_layer: Vec<Vec<LayerMapping>> = w
        .layers
        .iter()
        .map(|l| layer_mappings(&l.dims, hw))
        .collect();
    let masks = fusion_masks(w);
    let mut count = 0u64;
    let mut idx = vec![0usize; w.len()];
    loop {
        let mappings: Vec<LayerMapping> =
            (0..w.len()).map(|l| per_layer[l][idx[l]]).collect();
        for fuse in &masks {
            count += 1;
            assert!(count <= MAX_SPACE,
                    "{}: space no longer micro", w.name);
            visit(Strategy {
                mappings: mappings.clone(),
                fuse: fuse.clone(),
            });
        }
        let mut l = 0;
        loop {
            idx[l] += 1;
            if idx[l] < per_layer[l].len() {
                break;
            }
            idx[l] = 0;
            l += 1;
            if l == w.len() {
                return count;
            }
        }
    }
}

/// Result of one exhaustive sweep: candidate counts plus the
/// enumerated optimum (kernel-scored).
struct SpaceScan {
    count: u64,
    feasible: u64,
    best: Strategy,
    best_eval: Eval,
}

/// Enumerate + kernel-score the full space; along the way assert the
/// per-candidate contracts (validity of the emitted space, screen
/// admissibility, exact capacity verdict).
fn scan_space(w: &Workload, hw: &HwConfig) -> SpaceScan {
    let engine =
        EvalEngine::new(w, hw).with_cache_capacity(CHUNK);
    let bounds = BoundsCtx::new(w, hw);
    let mut scratch = ScreenScratch::new();

    let mut buf: Vec<Strategy> = Vec::with_capacity(CHUNK);
    let mut feasible = 0u64;
    let mut best: Option<(Strategy, Eval)> = None;

    let mut flush = |buf: &mut Vec<Strategy>,
                     best: &mut Option<(Strategy, Eval)>,
                     feasible: &mut u64| {
        let evals = engine.eval_batch(buf);
        for (s, e) in buf.iter().zip(&evals) {
            assert!(s.validate(w, hw.pe_rows as u64,
                               hw.pe_cols as u64)
                        .is_ok(),
                    "{}: enumerator left the legal space", w.name);
            let v = bounds.screen(s, &mut scratch);
            assert_eq!(v.capacity_infeasible, !e.feasible,
                       "{}: screen/kernel capacity disagreement",
                       w.name);
            if !e.feasible {
                continue;
            }
            *feasible += 1;
            assert!(v.energy_lb <= e.energy,
                    "{}: energy floor {} above exact {}", w.name,
                    v.energy_lb, e.energy);
            assert!(v.latency_lb <= e.latency,
                    "{}: latency floor {} above exact {}", w.name,
                    v.latency_lb, e.latency);
            assert!(v.edp_lb <= e.edp,
                    "{}: EDP floor {} above exact {}", w.name,
                    v.edp_lb, e.edp);
            let better = best
                .as_ref()
                .map_or(true, |(_, b)| e.edp < b.edp);
            if better {
                *best = Some((s.clone(), *e));
            }
        }
        buf.clear();
    };

    let count = enumerate_all(w, hw, |s| {
        buf.push(s);
        if buf.len() >= CHUNK {
            flush(&mut buf, &mut best, &mut feasible);
        }
    });
    flush(&mut buf, &mut best, &mut feasible);

    let (best, best_eval) =
        best.expect("micro space must contain a feasible strategy");
    SpaceScan { count, feasible, best, best_eval }
}

// -------------------------------------------------------------------
// oracle identity: certified B&B == enumerated optimum, bit for bit
// -------------------------------------------------------------------

fn run_exact(w: &Workload, hw: &HwConfig, cfg: &ExactConfig,
             ctx: &EvalCtx) -> ExactOutcome {
    exact::optimize(w, hw, cfg, &wide_open(), ctx).unwrap()
}

fn assert_certified_matches(w: &Workload, hw: &HwConfig,
                            scan: &SpaceScan) -> ExactOutcome {
    // the enumerated optimum reproduces its own numbers
    let eb = compute_eval(&scan.best, w, hw);
    assert!(eb.feasible);
    assert_eq!(eb.edp.to_bits(), scan.best_eval.edp.to_bits(),
               "{}: enumerator optimum is not reproducible", w.name);

    let out = run_exact(w, hw, &ExactConfig::default(),
                        &EvalCtx::default());
    assert!(out.stats.certified,
            "{}: mapper must certify a micro space", w.name);
    assert!(out.stats.space_complete, "{}: no subsampling expected",
            w.name);
    assert!(!out.stats.cap_hit, "{}: no cap expected", w.name);
    assert_eq!(out.result.edp.to_bits(),
               scan.best_eval.edp.to_bits(),
               "{}: certified EDP {} != enumerated optimum {} \
                ({} candidates, {} feasible)",
               w.name, out.result.edp, scan.best_eval.edp,
               scan.count, scan.feasible);
    // the returned strategy really produces the returned numbers
    let re = compute_eval(&out.result.best, w, hw);
    assert!(re.feasible, "{}: winner must be feasible", w.name);
    assert_eq!(re.edp.to_bits(), out.result.edp.to_bits(),
               "{}: result EDP is not its strategy's EDP", w.name);
    assert_eq!(re.energy.to_bits(), out.result.energy.to_bits());
    assert_eq!(re.latency.to_bits(), out.result.latency.to_bits());
    out
}

#[test]
fn exact_matches_exhaustive_on_micro_mlp() {
    let hw = hw();
    let w = zoo::micro_mlp();
    let scan = scan_space(&w, &hw);
    assert_certified_matches(&w, &hw, &scan);
}

#[test]
fn exact_matches_exhaustive_on_micro_gemm() {
    let hw = hw();
    let w = zoo::micro_gemm();
    let scan = scan_space(&w, &hw);
    assert_certified_matches(&w, &hw, &scan);
}

#[test]
fn exact_matches_exhaustive_on_micro_chain() {
    let hw = hw();
    let w = zoo::micro_chain();
    let scan = scan_space(&w, &hw);
    assert_certified_matches(&w, &hw, &scan);
}

#[test]
fn exact_matches_exhaustive_with_fusion_disabled() {
    // same oracle identity on the fusion-free restriction of the
    // space; its optimum can never beat the full space's
    let hw = hw();
    let full = zoo::micro_chain();
    let full_scan = scan_space(&full, &hw);
    let mut nofuse = full.clone();
    nofuse.fusible = vec![false; nofuse.fusible.len()];
    let scan = scan_space(&nofuse, &hw);
    assert!(scan.count < full_scan.count,
            "disabling fusion must shrink the space");
    let out = assert_certified_matches(&nofuse, &hw, &scan);
    assert!(out.result.best.fuse.iter().all(|&f| !f));
    assert!(full_scan.best_eval.edp <= scan.best_eval.edp,
            "a restricted space cannot beat the full space");
}

// -------------------------------------------------------------------
// prune-mode / warm-seed invariance
// -------------------------------------------------------------------

#[test]
fn prune_modes_and_seeds_never_change_the_certified_result() {
    let hw = hw();
    let w = zoo::micro_gemm();
    let base = run_exact(&w, &hw, &ExactConfig::default(),
                         &EvalCtx::default());
    assert!(base.stats.certified);

    for prune in [PruneMode::On, PruneMode::Off, PruneMode::Full] {
        let ctx = EvalCtx { prune, ..Default::default() };
        let out = run_exact(&w, &hw, &ExactConfig::default(), &ctx);
        assert!(out.stats.certified,
                "prune={}: certification lost", prune.name());
        assert_eq!(out.result.edp.to_bits(),
                   base.result.edp.to_bits(),
                   "prune={}: EDP diverged", prune.name());
        assert_eq!(out.result.energy.to_bits(),
                   base.result.energy.to_bits());
        assert_eq!(out.result.latency.to_bits(),
                   base.result.latency.to_bits());
    }

    // warm-start seeds only tighten the incumbent: the certified
    // optimum value is invariant even when a seed already attains it
    for seeds in [vec![Strategy::trivial(&w)],
                  vec![base.result.best.clone()]] {
        let ctx = EvalCtx {
            seeds,
            warm_frac: 1.0,
            ..Default::default()
        };
        let out = run_exact(&w, &hw, &ExactConfig::default(), &ctx);
        assert!(out.stats.certified, "seeded: certification lost");
        assert_eq!(out.result.edp.to_bits(),
                   base.result.edp.to_bits(),
                   "seeded: EDP diverged");
    }
}

// -------------------------------------------------------------------
// cap semantics: uncertified but feasible, never below the optimum
// -------------------------------------------------------------------

#[test]
fn caps_drop_certification_but_keep_a_feasible_bound() {
    let hw = hw();
    let w = zoo::micro_mlp();
    let scan = scan_space(&w, &hw);
    let opt = scan.best_eval.edp;

    // node cap: the queue cannot drain
    let cfg = ExactConfig { max_nodes: 2, ..Default::default() };
    let out = run_exact(&w, &hw, &cfg, &EvalCtx::default());
    assert!(out.stats.cap_hit, "node cap must trip");
    assert!(!out.stats.certified, "cap trip must drop certification");
    assert!(fadiff::costmodel::feasible(&out.result.best, &w, &hw)
                .is_ok(),
            "uncertified results must still be feasible");
    assert!(out.result.edp >= opt,
            "uncertified {} beat the true optimum {}",
            out.result.edp, opt);

    // per-layer candidate cap: deterministic subsampling
    let cfg = ExactConfig {
        max_layer_candidates: 2,
        ..Default::default()
    };
    let out = run_exact(&w, &hw, &cfg, &EvalCtx::default());
    assert!(!out.stats.space_complete,
            "subsampling must mark the space incomplete");
    assert!(!out.stats.certified);
    assert!(out.result.edp >= opt);

    // frontier cap: Pareto overflow
    let cfg = ExactConfig { max_frontier: 1, ..Default::default() };
    let out = run_exact(&w, &hw, &cfg, &EvalCtx::default());
    assert!(!out.stats.space_complete);
    assert!(!out.stats.certified);
    assert!(out.result.edp >= opt);

    // the budget's iteration bound is the same node cap
    let budget = Budget { seconds: 3600.0, max_iters: 2 };
    let out = exact::optimize(&w, &hw, &ExactConfig::default(),
                              &budget, &EvalCtx::default())
        .unwrap();
    assert!(!out.stats.certified,
            "a 2-iteration budget cannot certify");
    assert!(out.result.edp >= opt);
}

// -------------------------------------------------------------------
// determinism
// -------------------------------------------------------------------

#[test]
fn exact_is_deterministic_bit_for_bit() {
    let hw = hw();
    for w in [zoo::micro_gemm(), zoo::micro_chain()] {
        let a = run_exact(&w, &hw, &ExactConfig::default(),
                          &EvalCtx::default());
        let b = run_exact(&w, &hw, &ExactConfig::default(),
                          &EvalCtx::default());
        assert_eq!(a.result.edp.to_bits(), b.result.edp.to_bits(),
                   "{}: EDP not deterministic", w.name);
        assert_eq!(a.result.energy.to_bits(),
                   b.result.energy.to_bits());
        assert_eq!(a.result.latency.to_bits(),
                   b.result.latency.to_bits());
        assert_eq!(a.result.best.mappings, b.result.best.mappings,
                   "{}: winning mappings not deterministic", w.name);
        assert_eq!(a.result.best.fuse, b.result.best.fuse);
        assert_eq!(format!("{:?}", a.stats),
                   format!("{:?}", b.stats),
                   "{}: statistics not deterministic", w.name);
    }
}
