//! The bound-and-prune fast path and the warm-start mapping library:
//!
//! * admissibility — the screen's energy/latency/EDP floors never
//!   exceed the exact model, and its capacity verdict is bit-identical
//!   to the kernel's, across the whole zoo x both hw configs x random
//!   decoded candidates;
//! * bit-identity — the default-on pruned paths (random, gradient
//!   decode offers, BO) reproduce the unpruned `SearchResult`
//!   bit-for-bit (`f64::to_bits`), so pruning is a pure speedup;
//! * warm-start — library seeds are deterministic for a fixed library
//!   state, never worse than the seeds they start from, and flow
//!   end-to-end through a store-backed coordinator restart.

use std::path::PathBuf;
use std::sync::atomic::Ordering;

use fadiff::config::{load_config, repo_root};
use fadiff::coordinator::{Coordinator, JobRequest, MappingLibrary,
                          Method};
use fadiff::costmodel::bounds::{BoundsCtx, ScreenScratch};
use fadiff::costmodel::tables::WorkloadTables;
use fadiff::search::encoding::{dim, express_naive_with, express_with};
use fadiff::search::{bo, compute_eval, ga, gradient, random, Budget,
                     EvalCtx, PruneMode, SearchResult};
use fadiff::util::rng::Rng;
use fadiff::workload::zoo;

fn tmp_dir(tag: &str) -> PathBuf {
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .unwrap()
        .as_nanos();
    let d = std::env::temp_dir().join(format!(
        "fadiff_prune_{tag}_{}_{nanos}",
        std::process::id()
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}

// -------------------------------------------------------------------
// admissibility
// -------------------------------------------------------------------

#[test]
fn bounds_are_admissible_across_zoo_and_configs() {
    for config in ["large", "small"] {
        let hw = load_config(&repo_root(), config).unwrap();
        for w in zoo::table1_suite() {
            let bounds = BoundsCtx::new(&w, &hw);
            let tables = WorkloadTables::new(&w);
            let mut scratch = ScreenScratch::new();
            let mut rng = Rng::new(0xADA + w.len() as u64);
            let d = dim(&w);
            for i in 0..24 {
                let x: Vec<f64> =
                    (0..d).map(|_| rng.f64()).collect();
                let mut s = if i % 2 == 0 {
                    express_with(&x, &w, &hw, &tables)
                } else {
                    express_naive_with(&x, &w, &hw, &tables)
                };
                if i % 3 == 0 {
                    // stress the group-capacity replica: fuse every
                    // legal edge regardless of what decode repaired
                    s.fuse = w.fusible.clone();
                }
                let v = bounds.screen(&s, &mut scratch);
                let e = compute_eval(&s, &w, &hw);
                // the capacity screen is an exact replica, not a
                // bound: verdicts must agree bit-for-bit
                assert_eq!(v.capacity_infeasible, !e.feasible,
                           "{config}/{}: screen and kernel disagree \
                            on feasibility (sample {i})",
                           w.name);
                if !e.feasible {
                    continue;
                }
                assert!(v.energy_lb <= e.energy,
                        "{config}/{}: energy bound {} above exact {} \
                         (sample {i})",
                        w.name, v.energy_lb, e.energy);
                assert!(v.latency_lb <= e.latency,
                        "{config}/{}: latency bound {} above exact \
                         {} (sample {i})",
                        w.name, v.latency_lb, e.latency);
                assert!(v.edp_lb <= e.edp,
                        "{config}/{}: EDP bound {} above exact {} \
                         (sample {i})",
                        w.name, v.edp_lb, e.edp);
            }
        }
    }
}

// -------------------------------------------------------------------
// bit-identity of the default-on pruned paths
// -------------------------------------------------------------------

fn assert_bit_identical(on: &SearchResult, off: &SearchResult,
                        what: &str) {
    assert_eq!(on.edp.to_bits(), off.edp.to_bits(),
               "{what}: EDP diverged under pruning");
    assert_eq!(on.energy.to_bits(), off.energy.to_bits(),
               "{what}: energy diverged under pruning");
    assert_eq!(on.latency.to_bits(), off.latency.to_bits(),
               "{what}: latency diverged under pruning");
    assert_eq!(on.iters, off.iters,
               "{what}: iteration count diverged under pruning");
    assert_eq!(on.evals, off.evals,
               "{what}: eval count diverged under pruning");
    assert_eq!(on.best.mappings, off.best.mappings,
               "{what}: winning mappings diverged under pruning");
    assert_eq!(on.best.fuse, off.best.fuse,
               "{what}: winning fusion diverged under pruning");
}

fn on_off() -> (EvalCtx, EvalCtx) {
    let on = EvalCtx { prune: PruneMode::On, ..Default::default() };
    let off = EvalCtx { prune: PruneMode::Off, ..Default::default() };
    (on, off)
}

#[test]
fn random_search_is_bit_identical_under_pruning() {
    let hw = load_config(&repo_root(), "large").unwrap();
    let budget = Budget { seconds: 3600.0, max_iters: 400 };
    for w in [zoo::gpt3_6_7b(), zoo::resnet18()] {
        let (on, off) = on_off();
        let a = random::optimize_ctx(&w, &hw, 17, budget, &on)
            .unwrap();
        let b = random::optimize_ctx(&w, &hw, 17, budget, &off)
            .unwrap();
        assert_bit_identical(&a, &b, &format!("random/{}", w.name));
    }
}

#[test]
fn gradient_native_is_bit_identical_under_pruning() {
    let hw = load_config(&repo_root(), "large").unwrap();
    let w = zoo::gpt3_6_7b();
    let cfg = gradient::GradientConfig {
        seed: 5,
        chains: 1, // serial: the on/off comparison is order-free
        ..Default::default()
    };
    let budget = Budget { seconds: 3600.0, max_iters: 80 };
    let (on, off) = on_off();
    let a = gradient::optimize_ctx(None, &w, &hw, &cfg, budget, &on)
        .unwrap();
    let b = gradient::optimize_ctx(None, &w, &hw, &cfg, budget, &off)
        .unwrap();
    assert_bit_identical(&a, &b, "gradient-native/gpt3");
}

#[test]
fn bo_is_bit_identical_under_pruning() {
    let hw = load_config(&repo_root(), "large").unwrap();
    let w = zoo::gpt3_6_7b();
    let cfg = bo::BoConfig { seed: 3, ..Default::default() };
    let budget = Budget { seconds: 3600.0, max_iters: 24 };
    let (on, off) = on_off();
    let a = bo::optimize_ctx(&w, &hw, &cfg, budget, &on).unwrap();
    let b = bo::optimize_ctx(&w, &hw, &cfg, budget, &off).unwrap();
    assert_bit_identical(&a, &b, "bo/gpt3");
}

#[test]
fn ga_default_is_unpruned_and_full_mode_still_finds_feasible() {
    let hw = load_config(&repo_root(), "large").unwrap();
    let w = zoo::gpt3_6_7b();
    let cfg = ga::GaConfig { seed: 9, ..Default::default() };
    let budget = Budget { seconds: 3600.0, max_iters: 10 };
    let (on, off) = on_off();
    // GA's exact-fitness selection makes threshold pruning
    // trajectory-changing, so the default-on mode must not screen it
    let a = ga::optimize_ctx(&w, &hw, &cfg, budget, &on).unwrap();
    let b = ga::optimize_ctx(&w, &hw, &cfg, budget, &off).unwrap();
    assert_bit_identical(&a, &b, "ga-default/gpt3");
    // the opt-in full mode screens generations (bounds as pessimistic
    // fitness); it must still land on a feasible strategy
    let full =
        EvalCtx { prune: PruneMode::Full, ..Default::default() };
    let c = ga::optimize_ctx(&w, &hw, &cfg, budget, &full).unwrap();
    assert!(c.edp.is_finite() && c.edp > 0.0);
    assert!(fadiff::costmodel::feasible(&c.best, &w, &hw).is_ok());
}

// -------------------------------------------------------------------
// warm-start seeding at the search layer
// -------------------------------------------------------------------

#[test]
fn warm_seeding_is_deterministic_and_no_worse_than_its_seeds() {
    let hw = load_config(&repo_root(), "large").unwrap();
    let donor = zoo::vgg16();
    let target = zoo::vgg19();
    let budget = Budget { seconds: 3600.0, max_iters: 6 };

    // grow a library from a short GA run on the donor workload
    let lib = MappingLibrary::new();
    let cfg = ga::GaConfig { seed: 21, ..Default::default() };
    let donor_best =
        ga::optimize_ctx(&donor, &hw, &cfg, budget,
                         &EvalCtx::default())
            .unwrap();
    assert!(lib.record(&hw.fingerprint(), &donor, &hw,
                       &donor_best.best)
            > 0);

    // vgg19 shares vgg16's conv shapes: seeds must resolve
    let tables = WorkloadTables::new(&target);
    let seeds =
        lib.seeds_for(&hw.fingerprint(), &target, &hw, &tables);
    assert!(!seeds.is_empty(), "shared shapes must yield seeds");
    for s in &seeds {
        assert!(fadiff::costmodel::feasible(s, &target, &hw).is_ok(),
                "library seeds must be hardware-valid");
    }

    let warm_ctx = || EvalCtx {
        seeds: seeds.clone(),
        warm_frac: 0.5,
        ..Default::default()
    };
    let cfg2 = ga::GaConfig { seed: 33, ..Default::default() };
    let w1 = ga::optimize_ctx(&target, &hw, &cfg2, budget,
                              &warm_ctx())
        .unwrap();
    let w2 = ga::optimize_ctx(&target, &hw, &cfg2, budget,
                              &warm_ctx())
        .unwrap();
    assert_bit_identical(&w1, &w2, "ga-warm/vgg19");

    // seeds are offered to the incumbent before the search starts, so
    // the warm result can never be worse than its best seed
    let best_seed = seeds
        .iter()
        .map(|s| compute_eval(s, &target, &hw).fitness())
        .fold(f64::INFINITY, f64::min);
    assert!(w1.edp <= best_seed,
            "warm result {} worse than its own seed {best_seed}",
            w1.edp);

    // random search offers the same seeds
    let r = random::optimize_ctx(&target, &hw, 7,
                                 Budget { seconds: 3600.0,
                                          max_iters: 50 },
                                 &warm_ctx())
        .unwrap();
    assert!(r.edp <= best_seed);
}

// -------------------------------------------------------------------
// coordinator end-to-end: record, persist, restart, seed
// -------------------------------------------------------------------

fn job(seed: u64) -> JobRequest {
    JobRequest {
        workload: "mobilenet".into(),
        method: Method::Random,
        seconds: 3600.0, // iteration-capped: deterministic per seed
        max_iters: 40,
        seed,
        ..Default::default()
    }
}

#[test]
fn library_survives_restart_and_seeds_repeat_shape_jobs() {
    let dir = tmp_dir("warm");
    {
        let coord =
            Coordinator::new_with_store(None, 1, Some(dir.clone()))
                .unwrap();
        let r = coord.run(job(7)).unwrap();
        assert!(!r.stored);
        assert!(coord.library().entries() > 0,
                "completed jobs must record into the library");
        // the default-on prefilter screened this run's batches
        assert!(coord.prune_stats().bounded.load(Ordering::SeqCst)
                    > 0,
                "random jobs must route through the screen");
    } // drop: dirty library shard flushes alongside eval segments

    let coord =
        Coordinator::new_with_store(None, 1, Some(dir.clone()))
            .unwrap();
    assert_eq!(coord.library().entries(), 0,
               "shards hydrate lazily, per config, on first use");
    // same shapes, different seed (a fresh result key), warm-started
    let warm = coord
        .run(JobRequest { warm_frac: 1.0, ..job(8) })
        .unwrap();
    assert!(!warm.stored);
    assert!(warm.edp.is_finite() && warm.edp > 0.0);
    assert!(coord.library().entries() > 0,
            "the persisted shard must hydrate on job start");
    let stats = coord.library().stats();
    assert!(stats.seeds_served.load(Ordering::SeqCst) > 0,
            "a repeat-shape warm job must be served seeds");
    assert!(stats.exact_hits.load(Ordering::SeqCst) > 0,
            "identical shapes must resolve as exact hits");

    // the metrics payload surfaces both new blocks
    let m = coord.metrics_json();
    let prune = m.get("prune").unwrap();
    assert!(prune.get_f64("bounded").unwrap() >= 0.0);
    assert!(prune.get_f64("ratio").unwrap() >= 0.0);
    let lib = m.get("library").unwrap();
    assert!(lib.get_f64("entries").unwrap() > 0.0);
    assert!(lib.get_f64("seeds_served").unwrap() > 0.0);
    drop(coord);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn warm_seeding_defaults_off_and_preserves_cold_results() {
    // warm_frac = 0 must reproduce a library-free run bit-for-bit
    // even when the library has entries — seeding is strictly opt-in
    let dir = tmp_dir("optin");
    let cold = {
        let coord = Coordinator::new(None, 1).unwrap();
        coord.run(job(11)).unwrap()
    };
    {
        let coord =
            Coordinator::new_with_store(None, 1, Some(dir.clone()))
                .unwrap();
        // populate the library with a different seed's incumbents
        coord.run(job(12)).unwrap();
        let again = coord.run(job(11)).unwrap();
        assert!(!again.stored);
        assert_eq!(again.edp.to_bits(), cold.edp.to_bits(),
                   "default requests must not depend on library \
                    state");
        assert_eq!(coord.library().stats()
                       .seeds_served
                       .load(Ordering::SeqCst),
                   0);
    }
    std::fs::remove_dir_all(&dir).ok();
}
