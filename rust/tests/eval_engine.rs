//! Integration + property tests for `search::eval::EvalEngine` — the
//! batched, memoizing evaluation entry point of every search method.
//!
//! Pins the tentpole guarantees: (1) batched results are bit-for-bit
//! identical to single-candidate `costmodel::evaluate`, (2) parallel
//! and serial engines agree exactly, (3) cache hit/miss accounting is
//! deterministic.

use fadiff::config::{load_config, repo_root};
use fadiff::costmodel;
use fadiff::mapping::decode::{decode, Relaxed};
use fadiff::mapping::Strategy;
use fadiff::search::{ga, random, Budget, EvalEngine};
use fadiff::util::prop::{check, Config};
use fadiff::util::rng::Rng;
use fadiff::workload::{zoo, NDIMS};

fn random_strategy(rng: &mut Rng, w: &fadiff::workload::Workload,
                   hw: &fadiff::config::HwConfig) -> Strategy {
    let mut relaxed = Relaxed::neutral(w);
    for l in 0..w.len() {
        for d in 0..NDIMS {
            for s in 0..4 {
                relaxed.theta[l][d][s] = rng.range(-1.0, 9.0);
            }
        }
    }
    for i in 0..relaxed.sigma.len() {
        relaxed.sigma[i] = rng.f64();
    }
    decode(&relaxed, w, hw)
}

#[test]
fn batched_edp_matches_costmodel_bit_for_bit_prop() {
    // the tentpole equivalence property: for ANY decoded strategy on
    // ANY suite workload, the engine's numbers equal a direct
    // costmodel::evaluate call exactly (same code path, memoized)
    let hw = load_config(&repo_root(), "large").unwrap();
    let suite = zoo::table1_suite();
    check("engine-matches-costmodel", &Config { cases: 48, seed: 77 },
          |rng, _| {
              let wi = rng.below(suite.len());
              let s = random_strategy(rng, &suite[wi], &hw);
              (wi, s)
          },
          |(wi, s)| {
              let w = &suite[*wi];
              let engine = EvalEngine::new(w, &hw);
              let e = engine.eval(s);
              let r = costmodel::evaluate(s, w, &hw);
              if e.edp != r.edp || e.energy != r.energy
                  || e.latency != r.latency
              {
                  return Err(format!(
                      "{}: engine ({}, {}, {}) != costmodel ({}, {}, {})",
                      w.name, e.energy, e.latency, e.edp, r.energy,
                      r.latency, r.edp
                  ));
              }
              if e.feasible != costmodel::feasible(s, w, &hw).is_ok() {
                  return Err("feasibility flag mismatch".into());
              }
              Ok(())
          });
}

#[test]
fn parallel_and_serial_engines_agree_exactly() {
    let hw = load_config(&repo_root(), "large").unwrap();
    let w = zoo::vgg16();
    let mut rng = Rng::new(31);
    let pop: Vec<Strategy> =
        (0..40).map(|_| random_strategy(&mut rng, &w, &hw)).collect();
    let serial = EvalEngine::with_threads(&w, &hw, 1);
    let par = EvalEngine::with_threads(&w, &hw, 8);
    let a = serial.eval_batch(&pop);
    let b = par.eval_batch(&pop);
    assert_eq!(a, b, "thread count must not change results");
    // second pass: all hits, identical values
    let c = par.eval_batch(&pop);
    assert_eq!(b, c);
    assert_eq!(par.cache_misses() as usize,
               par.cache_len().min(pop.len()));
}

#[test]
fn cache_accounting_across_batches() {
    let hw = load_config(&repo_root(), "small").unwrap();
    let w = zoo::gpt3_6_7b();
    let engine = EvalEngine::new(&w, &hw);
    let mut rng = Rng::new(8);
    let unique: Vec<Strategy> =
        (0..6).map(|_| random_strategy(&mut rng, &w, &hw)).collect();
    // batch with each unique strategy twice
    let mut pop = unique.clone();
    pop.extend(unique.iter().cloned());
    let evals = engine.eval_batch(&pop);
    let uniq_keys = engine.cache_len();
    assert_eq!(engine.cache_misses() as usize, uniq_keys);
    assert_eq!(engine.cache_hits() as usize, pop.len() - uniq_keys);
    for i in 0..unique.len() {
        assert_eq!(evals[i], evals[i + unique.len()]);
    }
    // replay: every candidate hits
    let before = engine.cache_misses();
    engine.eval_batch(&pop);
    assert_eq!(engine.cache_misses(), before, "replay must not compute");
}

#[test]
fn searches_report_engine_consistent_results() {
    // end-to-end: the winners reported by engine-backed searches carry
    // exactly the native model's numbers for their best strategy
    let hw = load_config(&repo_root(), "large").unwrap();
    let w = zoo::mobilenet_v1();
    let rga = ga::optimize(&w, &hw, &ga::GaConfig::default(),
                           Budget::iters(5))
        .unwrap();
    let check_ga = costmodel::evaluate(&rga.best, &w, &hw);
    assert_eq!(rga.edp, check_ga.edp);
    assert_eq!(rga.energy, check_ga.energy);
    assert_eq!(rga.latency, check_ga.latency);

    let rr = random::optimize(&w, &hw, 3, Budget::iters(64)).unwrap();
    let check_r = costmodel::evaluate(&rr.best, &w, &hw);
    assert_eq!(rr.edp, check_r.edp);
}
