//! Robustness battery for `parse_request`/`handle`: hostile and broken
//! inputs must always produce a one-line `{"ok":false,...}` answer and
//! must never panic the server, kill the connection, or desynchronize
//! the line protocol.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};

use fadiff::coordinator::{server, Coordinator};
use fadiff::util::json::Json;

fn start_server() -> (std::net::SocketAddr,
                      std::thread::JoinHandle<anyhow::Result<()>>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let coord = Coordinator::new(None, 1).unwrap();
    let t = std::thread::spawn(move || server::serve_on(listener, coord));
    (addr, t)
}

fn shutdown_server(addr: std::net::SocketAddr,
                   t: std::thread::JoinHandle<anyhow::Result<()>>) {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.write_all(b"{\"verb\": \"shutdown\"}\n").unwrap();
    let mut line = String::new();
    BufReader::new(stream).read_line(&mut line).unwrap();
    t.join().unwrap().unwrap();
}

/// Send one line on a fresh connection, read one line back.
fn send_once(addr: std::net::SocketAddr, body: &[u8]) -> String {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.write_all(body).unwrap();
    stream.write_all(b"\n").unwrap();
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    line.trim().to_string()
}

fn assert_err_response(resp: &str) {
    let j = Json::parse(resp)
        .unwrap_or_else(|e| panic!("unparseable response {resp:?}: {e}"));
    assert_eq!(j.get("ok").unwrap(), &Json::Bool(false), "{resp}");
    assert!(j.get("error").unwrap().as_str().is_ok());
}

#[test]
fn malformed_requests_get_one_line_errors() {
    let (addr, t) = start_server();
    for bad in [
        "not json at all",
        "{\"verb\":",
        "{\"verb\": \"optimize\", \"method\": \"quantum\"}",
        "{\"verb\": 42}",
        "{\"verb\": \"frobnicate\"}",
        "[]",
        "[1, 2, 3]",
        "null",
        "123",
        "\"just a string\"",
        "{\"verb\": \"optimize\", \"workload\": \"not-a-net\"}",
        "{\"verb\": \"optimize\", \"config\": \"not-a-config\", \
         \"method\": \"random\", \"max_iters\": 1}",
        "{\"verb\": \"optimize\", \"seconds\": \"fast\"}",
        "{\"verb\": \"status\"}",
        "{\"verb\": \"status\", \"job_id\": 99999}",
        "{\"verb\": \"status\", \"job_id\": -3}",
        "{\"verb\": \"status\", \"job_id\": 7.9}",
        "{\"verb\": \"cancel\", \"job_id\": 1e300}",
        "{\"verb\": \"cancel\", \"job_id\": 424242}",
        "{\"verb\": \"sweep\", \"workloads\": []}",
        "{\"verb\": \"sweep\", \"methods\": [\"ga\", \"quantum\"]}",
        "{\"verb\": \"optimize\", \"workload_spec\": 42}",
        "{\"verb\": \"optimize\", \"workload_spec\": {\"name\": \"x\", \
         \"layers\": [{\"name\": \"a\", \"kind\": \"conv\", \
         \"dims\": [1, 2, 3]}]}}",
        "{\"verb\": \"workloads\", \"describe\": \"not-a-net\"}",
        "{\"verb\": \"workloads\", \"describe\": 42}",
    ] {
        assert_err_response(&send_once(addr, bad.as_bytes()));
    }
    shutdown_server(addr, t);
}

#[test]
fn oversized_inline_specs_are_rejected_at_parse() {
    // a spec over the layer cap must be a one-line error before any
    // job is queued — parse-time validation, like the chains cap
    let (addr, t) = start_server();
    let layers: Vec<String> = (0..65)
        .map(|i| {
            format!(
                "{{\"name\": \"l{i}\", \"kind\": \"fc\", \
                 \"dims\": [1, 8, 8, 1, 1, 1, 1]}}"
            )
        })
        .collect();
    let body = format!(
        "{{\"verb\": \"optimize\", \"method\": \"random\", \
         \"workload_spec\": {{\"name\": \"huge\", \
         \"layers\": [{}]}}}}",
        layers.join(",")
    );
    let resp = send_once(addr, body.as_bytes());
    assert_err_response(&resp);
    assert!(resp.contains("cap"), "{resp}");
    // the connection and the server survive; normal service resumes
    let pong = send_once(addr, b"{\"verb\": \"ping\"}");
    assert!(pong.contains("pong"), "{pong}");
    shutdown_server(addr, t);
}

#[test]
fn connection_survives_a_barrage_of_garbage() {
    let (addr, t) = start_server();
    let mut stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut ask = |body: &str| -> Json {
        stream.write_all(body.as_bytes()).unwrap();
        stream.write_all(b"\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        Json::parse(line.trim()).unwrap()
    };
    for _ in 0..3 {
        assert_eq!(ask("garbage").get("ok").unwrap(),
                   &Json::Bool(false));
        assert_eq!(ask("{\"verb\": \"nope\"}").get("ok").unwrap(),
                   &Json::Bool(false));
        // blank lines produce no response and do not desynchronize
        stream.write_all(b"\n   \n").unwrap();
        let pong = ask("{\"verb\": \"ping\"}");
        assert_eq!(pong.get("pong").unwrap(), &Json::Bool(true));
    }
    drop(stream);
    shutdown_server(addr, t);
}

#[test]
fn deeply_nested_payloads_are_rejected_not_fatal() {
    let (addr, t) = start_server();
    let deep_arr = format!("{}1{}", "[".repeat(50_000),
                           "]".repeat(50_000));
    assert_err_response(&send_once(addr, deep_arr.as_bytes()));
    let deep_obj =
        "{\"a\":".repeat(50_000) + "1" + &"}".repeat(50_000);
    assert_err_response(&send_once(addr, deep_obj.as_bytes()));
    // a verb wrapped in legal-but-deep junk still answers
    let mixed = format!(
        "{{\"verb\": \"ping\", \"junk\": {}1{}}}",
        "[".repeat(200), "]".repeat(200)
    );
    assert_err_response(&send_once(addr, mixed.as_bytes()));
    shutdown_server(addr, t);
}

#[test]
fn oversized_lines_are_answered_and_drained() {
    let (addr, t) = start_server();
    let mut stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    // 2 MiB of non-JSON on one line (over the 1 MiB cap)
    let huge = vec![b'a'; 2 * server::MAX_REQUEST_BYTES];
    stream.write_all(&huge).unwrap();
    stream.write_all(b"\n").unwrap();
    stream.flush().unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert_err_response(line.trim());
    assert!(line.contains("exceeds"), "{line}");
    // the same connection is immediately usable again
    stream.write_all(b"{\"verb\": \"ping\"}\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(line.trim()).unwrap();
    assert_eq!(j.get("pong").unwrap(), &Json::Bool(true));
    drop(stream);
    shutdown_server(addr, t);
}

#[test]
fn truncated_line_gets_an_answer_on_half_close() {
    let (addr, t) = start_server();
    let mut stream = TcpStream::connect(addr).unwrap();
    // no trailing newline, then half-close: the server must treat the
    // tail as a (broken) request and still answer on one line
    stream.write_all(b"{\"verb\": \"ping\"").unwrap();
    stream.flush().unwrap();
    stream.shutdown(Shutdown::Write).unwrap();
    let mut resp = String::new();
    BufReader::new(stream).read_to_string(&mut resp).unwrap();
    let first = resp.lines().next().unwrap_or("");
    assert_err_response(first);
    shutdown_server(addr, t);
}

#[test]
fn invalid_utf8_degrades_to_json_error() {
    let (addr, t) = start_server();
    let mut stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    stream.write_all(b"\xff\xfe\xfd{\"verb\": \"ping\"}\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert_err_response(line.trim());
    // connection still fine
    stream.write_all(b"{\"verb\": \"ping\"}\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert_eq!(Json::parse(line.trim()).unwrap().get("pong").unwrap(),
               &Json::Bool(true));
    drop(stream);
    shutdown_server(addr, t);
}

#[test]
fn sweep_with_failing_cells_reports_per_job_errors() {
    let (addr, t) = start_server();
    let resp = send_once(
        addr,
        b"{\"verb\": \"sweep\", \
           \"workloads\": [\"mobilenet\", \"not-a-net\"], \
           \"methods\": [\"random\"], \"seeds\": [1], \
           \"seconds\": 3600, \"max_iters\": 8}",
    );
    let j = Json::parse(&resp).unwrap();
    assert_eq!(j.get("ok").unwrap(), &Json::Bool(true), "{resp}");
    assert_eq!(j.get_f64("jobs").unwrap(), 2.0);
    assert_eq!(j.get_f64("completed").unwrap(), 1.0);
    assert_eq!(j.get_f64("failed").unwrap(), 1.0);
    let results = j.get("results").unwrap().as_arr().unwrap();
    let oks: Vec<bool> = results
        .iter()
        .map(|r| r.get("ok").unwrap() == &Json::Bool(true))
        .collect();
    assert!(oks.contains(&true) && oks.contains(&false));
    shutdown_server(addr, t);
}
