//! Robustness battery for the wire protocol: hostile and broken
//! inputs must always produce a one-line v1 error envelope
//! (`{"protocol":1,"error":{"code":...,"message":...}}`) and must
//! never panic the server, kill the connection, or desynchronize the
//! line protocol. Also covers the bounded-queue backpressure path
//! (`queue_full` + `retry_after_ms`).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};

use fadiff::coordinator::{server, Coordinator};
use fadiff::util::json::Json;

fn start_server() -> (std::net::SocketAddr,
                      std::thread::JoinHandle<anyhow::Result<()>>) {
    start_server_with(|_| {})
}

/// Start a server after applying `tune` to the coordinator (tests
/// shrink the queue capacity to force backpressure deterministically).
fn start_server_with(tune: impl FnOnce(&Coordinator))
                     -> (std::net::SocketAddr,
                         std::thread::JoinHandle<anyhow::Result<()>>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let coord = Coordinator::new(None, 1).unwrap();
    tune(&coord);
    let t = std::thread::spawn(move || server::serve_on(listener, coord));
    (addr, t)
}

fn shutdown_server(addr: std::net::SocketAddr,
                   t: std::thread::JoinHandle<anyhow::Result<()>>) {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.write_all(b"{\"verb\": \"shutdown\"}\n").unwrap();
    let mut line = String::new();
    BufReader::new(stream).read_line(&mut line).unwrap();
    t.join().unwrap().unwrap();
}

/// Send one line on a fresh connection, read one line back.
fn send_once(addr: std::net::SocketAddr, body: &[u8]) -> String {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.write_all(body).unwrap();
    stream.write_all(b"\n").unwrap();
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    line.trim().to_string()
}

/// Send one line on an existing connection, read one line back.
fn ask(stream: &mut TcpStream, reader: &mut BufReader<TcpStream>,
       body: &str) -> String {
    stream.write_all(body.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    line.trim().to_string()
}

/// Assert the v1 error envelope shape and return the error body.
fn assert_err_response(resp: &str) -> Json {
    let j = Json::parse(resp)
        .unwrap_or_else(|e| panic!("unparseable response {resp:?}: {e}"));
    assert_eq!(j.get("protocol").unwrap().as_f64().unwrap(), 1.0,
               "{resp}");
    assert!(j.get("ok").is_err(),
            "error envelopes must not carry ok: {resp}");
    let e = j.get("error").unwrap();
    let code = e.get("code").unwrap().as_str().unwrap();
    assert!(!code.is_empty()
            && code.chars()
                   .all(|c| c.is_ascii_lowercase() || c == '_'),
            "code must be stable snake_case: {resp}");
    assert!(!e.get("message").unwrap().as_str().unwrap().is_empty(),
            "{resp}");
    e.clone()
}

fn assert_err_code(resp: &str, code: &str) {
    let e = assert_err_response(resp);
    assert_eq!(e.get("code").unwrap().as_str().unwrap(), code,
               "{resp}");
}

fn assert_pong(resp: &str) {
    let j = Json::parse(resp).unwrap();
    assert_eq!(j.get("ok").unwrap().get("pong").unwrap(),
               &Json::Bool(true), "{resp}");
}

#[test]
fn malformed_requests_get_one_line_coded_errors() {
    let (addr, t) = start_server();
    for (bad, code) in [
        ("not json at all", "bad_request"),
        ("{\"verb\":", "bad_request"),
        ("{\"verb\": \"optimize\", \"method\": \"quantum\"}",
         "bad_request"),
        ("{\"verb\": 42}", "bad_request"),
        ("{\"verb\": \"frobnicate\"}", "unknown_verb"),
        ("[]", "bad_request"),
        ("[1, 2, 3]", "bad_request"),
        ("null", "bad_request"),
        ("123", "bad_request"),
        ("\"just a string\"", "bad_request"),
        ("{\"verb\": \"optimize\", \"workload\": \"not-a-net\"}",
         "unknown_workload"),
        ("{\"verb\": \"optimize\", \"seconds\": \"fast\"}",
         "bad_request"),
        ("{\"verb\": \"status\"}", "bad_request"),
        ("{\"verb\": \"status\", \"job_id\": 99999}", "job_not_found"),
        ("{\"verb\": \"status\", \"job_id\": -3}", "bad_request"),
        ("{\"verb\": \"status\", \"job_id\": 7.9}", "bad_request"),
        ("{\"verb\": \"status\", \"job_id\": 1, \"watch\": \"yes\"}",
         "bad_request"),
        ("{\"verb\": \"cancel\", \"job_id\": 1e300}", "bad_request"),
        ("{\"verb\": \"cancel\", \"job_id\": 424242}",
         "job_not_found"),
        ("{\"verb\": \"sweep\", \"workloads\": []}", "bad_request"),
        ("{\"verb\": \"sweep\", \"methods\": [\"ga\", \"quantum\"]}",
         "bad_request"),
        ("{\"verb\": \"optimize\", \"workload_spec\": 42}",
         "spec_invalid"),
        ("{\"verb\": \"optimize\", \"workload_spec\": {\"name\": \"x\", \
         \"layers\": [{\"name\": \"a\", \"kind\": \"conv\", \
         \"dims\": [1, 2, 3]}]}}", "spec_invalid"),
        ("{\"verb\": \"workloads\", \"describe\": \"not-a-net\"}",
         "unknown_workload"),
        ("{\"verb\": \"workloads\", \"describe\": 42}", "bad_request"),
        ("{\"verb\": \"ping\", \"v\": 0}", "unsupported_version"),
        ("{\"verb\": \"ping\", \"v\": \"one\"}", "bad_request"),
    ] {
        assert_err_code(&send_once(addr, bad.as_bytes()), code);
    }
    // a config the job runner cannot load fails the job, not parsing
    assert_err_code(
        &send_once(
            addr,
            b"{\"verb\": \"optimize\", \"config\": \"not-a-config\", \
               \"method\": \"random\", \"max_iters\": 1}",
        ),
        "internal",
    );
    shutdown_server(addr, t);
}

#[test]
fn oversized_inline_specs_are_rejected_at_parse() {
    // a spec over the layer cap must be a one-line error before any
    // job is queued — parse-time validation, like the chains cap
    let (addr, t) = start_server();
    let layers: Vec<String> = (0..65)
        .map(|i| {
            format!(
                "{{\"name\": \"l{i}\", \"kind\": \"fc\", \
                 \"dims\": [1, 8, 8, 1, 1, 1, 1]}}"
            )
        })
        .collect();
    let body = format!(
        "{{\"verb\": \"optimize\", \"method\": \"random\", \
         \"workload_spec\": {{\"name\": \"huge\", \
         \"layers\": [{}]}}}}",
        layers.join(",")
    );
    let resp = send_once(addr, body.as_bytes());
    assert_err_code(&resp, "too_large");
    assert!(resp.contains("cap"), "{resp}");
    // the connection and the server survive; normal service resumes
    assert_pong(&send_once(addr, b"{\"verb\": \"ping\"}"));
    shutdown_server(addr, t);
}

#[test]
fn connection_survives_a_barrage_of_garbage() {
    let (addr, t) = start_server();
    let mut stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    for _ in 0..3 {
        assert_err_code(&ask(&mut stream, &mut reader, "garbage"),
                        "bad_request");
        assert_err_code(
            &ask(&mut stream, &mut reader, "{\"verb\": \"nope\"}"),
            "unknown_verb",
        );
        // blank lines produce no response and do not desynchronize
        stream.write_all(b"\n   \n").unwrap();
        assert_pong(&ask(&mut stream, &mut reader,
                         "{\"verb\": \"ping\"}"));
    }
    drop(stream);
    shutdown_server(addr, t);
}

#[test]
fn deeply_nested_payloads_are_rejected_not_fatal() {
    let (addr, t) = start_server();
    let deep_arr = format!("{}1{}", "[".repeat(50_000),
                           "]".repeat(50_000));
    assert_err_code(&send_once(addr, deep_arr.as_bytes()),
                    "bad_request");
    let deep_obj =
        "{\"a\":".repeat(50_000) + "1" + &"}".repeat(50_000);
    assert_err_code(&send_once(addr, deep_obj.as_bytes()),
                    "bad_request");
    // a verb wrapped in legal-but-deep junk still answers
    let mixed = format!(
        "{{\"verb\": \"ping\", \"junk\": {}1{}}}",
        "[".repeat(200), "]".repeat(200)
    );
    assert_err_code(&send_once(addr, mixed.as_bytes()), "bad_request");
    shutdown_server(addr, t);
}

#[test]
fn oversized_lines_are_answered_and_drained() {
    let (addr, t) = start_server();
    let mut stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    // 2 MiB of non-JSON on one line (over the 1 MiB cap)
    let huge = vec![b'a'; 2 * server::MAX_REQUEST_BYTES];
    stream.write_all(&huge).unwrap();
    stream.write_all(b"\n").unwrap();
    stream.flush().unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert_err_code(line.trim(), "too_large");
    assert!(line.contains("exceeds"), "{line}");
    // the same connection is immediately usable again
    stream.write_all(b"{\"verb\": \"ping\"}\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert_pong(line.trim());
    // the drain is counted (metrics.faults, not just the error line)
    let m = Json::parse(&ask(&mut stream, &mut reader,
                             "{\"verb\": \"metrics\"}"))
        .unwrap();
    let drains = m.get("ok").unwrap()
        .get("faults").unwrap()
        .get_f64("oversized_drains").unwrap();
    assert!(drains >= 1.0, "oversized drain not counted: {drains}");
    drop(stream);
    shutdown_server(addr, t);
}

#[test]
fn truncated_line_gets_an_answer_on_half_close() {
    let (addr, t) = start_server();
    let mut stream = TcpStream::connect(addr).unwrap();
    // no trailing newline, then half-close: the server must treat the
    // tail as a (broken) request and still answer on one line
    stream.write_all(b"{\"verb\": \"ping\"").unwrap();
    stream.flush().unwrap();
    stream.shutdown(Shutdown::Write).unwrap();
    let mut resp = String::new();
    BufReader::new(stream).read_to_string(&mut resp).unwrap();
    let first = resp.lines().next().unwrap_or("");
    assert_err_code(first, "bad_request");
    shutdown_server(addr, t);
}

#[test]
fn invalid_utf8_degrades_to_json_error() {
    let (addr, t) = start_server();
    let mut stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    stream.write_all(b"\xff\xfe\xfd{\"verb\": \"ping\"}\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert_err_code(line.trim(), "bad_request");
    // connection still fine
    stream.write_all(b"{\"verb\": \"ping\"}\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert_pong(line.trim());
    drop(stream);
    shutdown_server(addr, t);
}

#[test]
fn sweep_with_failing_cells_reports_per_job_errors() {
    let (addr, t) = start_server();
    let resp = send_once(
        addr,
        b"{\"verb\": \"sweep\", \
           \"workloads\": [\"mobilenet\", \"not-a-net\"], \
           \"methods\": [\"random\"], \"seeds\": [1], \
           \"seconds\": 3600, \"max_iters\": 8}",
    );
    let env = Json::parse(&resp).unwrap();
    let j = env.get("ok").unwrap();
    assert_eq!(j.get_f64("jobs").unwrap(), 2.0);
    assert_eq!(j.get_f64("completed").unwrap(), 1.0);
    assert_eq!(j.get_f64("failed").unwrap(), 1.0);
    let results = j.get("results").unwrap().as_arr().unwrap();
    assert_eq!(results.len(), 2);
    // cells reuse the envelope shape: exactly one of ok/error each
    let ok_cell = results
        .iter()
        .find(|r| r.get("ok").is_ok())
        .expect("one completed cell");
    assert!(ok_cell.get("ok").unwrap().get_f64("edp").unwrap() > 0.0);
    let err_cell = results
        .iter()
        .find(|r| r.get("error").is_ok())
        .expect("one failed cell");
    let e = err_cell.get("error").unwrap();
    assert_eq!(e.get("code").unwrap().as_str().unwrap(),
               "unknown_workload");
    assert_eq!(e.get("workload").unwrap().as_str().unwrap(),
               "not-a-net");
    shutdown_server(addr, t);
}

#[test]
fn flooded_queue_answers_queue_full_with_retry_hint() {
    // capacity 1 on a 1-worker coordinator: one running + one queued
    // is the most the server will hold
    let (addr, t) = start_server_with(|c| c.set_queue_capacity(1));
    let mut stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let long_job = "{\"verb\": \"submit\", \"workload\": \"mobilenet\", \
                    \"method\": \"random\", \"seconds\": 3600, \
                    \"max_iters\": 1000000000000}";
    // first job: picked up by the lone worker shortly after queueing
    let a = Json::parse(&ask(&mut stream, &mut reader, long_job))
        .unwrap();
    let id_a = a.get("ok").unwrap().get_f64("job_id").unwrap() as u64;
    // wait for the worker to take it so the queue is empty again
    let t0 = std::time::Instant::now();
    loop {
        let st = Json::parse(&ask(
            &mut stream, &mut reader,
            &format!("{{\"verb\": \"status\", \"job_id\": {id_a}}}"),
        ))
        .unwrap();
        let s = st.get("ok").unwrap().get("status").unwrap()
            .as_str().unwrap().to_string();
        if s == "running" {
            break;
        }
        assert!(t0.elapsed() < std::time::Duration::from_secs(30),
                "job never started");
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    // second job fills the queue's single slot...
    let b = Json::parse(&ask(&mut stream, &mut reader, long_job))
        .unwrap();
    let id_b = b.get("ok").unwrap().get_f64("job_id").unwrap() as u64;
    // ...so the third submission must backpressure, with a hint
    let full = ask(&mut stream, &mut reader, long_job);
    assert_err_code(&full, "queue_full");
    let e = Json::parse(&full).unwrap().get("error").unwrap().clone();
    let retry = e.get_f64("retry_after_ms").unwrap();
    assert!((100.0..=10_000.0).contains(&retry), "{full}");
    assert_eq!(e.get_f64("queue_capacity").unwrap(), 1.0);
    // a sweep larger than the remaining room is rejected whole
    assert_err_code(
        &ask(&mut stream, &mut reader,
             "{\"verb\": \"sweep\", \"workload\": \"mobilenet\", \
              \"methods\": [\"random\"], \"seeds\": [1, 2, 3], \
              \"seconds\": 3600, \"max_iters\": 4}"),
        "queue_full",
    );
    // non-queueing verbs still serve under backpressure
    assert_pong(&ask(&mut stream, &mut reader, "{\"verb\": \"ping\"}"));
    // both rejections (the submit and the sweep) are counted
    let m = Json::parse(&ask(&mut stream, &mut reader,
                             "{\"verb\": \"metrics\"}"))
        .unwrap();
    let rejected = m.get("ok").unwrap()
        .get("faults").unwrap()
        .get_f64("queue_full_rejected").unwrap();
    assert!(rejected >= 2.0,
            "queue_full rejections not counted: {rejected}");
    // drain: cancel both jobs so shutdown is quick
    for id in [id_b, id_a] {
        let c = Json::parse(&ask(
            &mut stream, &mut reader,
            &format!("{{\"verb\": \"cancel\", \"job_id\": {id}}}"),
        ))
        .unwrap();
        assert!(c.get("ok").is_ok(), "{c:?}");
    }
    drop(stream);
    shutdown_server(addr, t);
}
