//! Cross-module property tests: the system-level invariants that hold
//! for ANY input (random workloads, random relaxed states, random
//! hardware geometries), plus failure-injection on the runtime loader.

use fadiff::config::{custom_config, load_config, repo_root};
use fadiff::costmodel;
use fadiff::mapping::decode::{decode, Relaxed};
use fadiff::mapping::{divisor_candidates, divisors, Strategy};
use fadiff::runtime::Manifest;
use fadiff::sim::tilesim;
use fadiff::util::prop::{check, ensure, Config};
use fadiff::util::rng::Rng;
use fadiff::workload::{zoo, Layer, LayerKind, Workload, NDIMS};

fn random_workload(rng: &mut Rng, size: f64) -> Workload {
    let n_layers = 2 + rng.below((10.0 * size) as usize + 1);
    let chans = [1usize, 3, 8, 16, 32, 64, 96, 128, 256];
    let spatial = [1usize, 7, 14, 28, 56, 112];
    let mut layers = Vec::new();
    let mut cin = *rng.choice(&chans);
    for i in 0..n_layers {
        let cout = *rng.choice(&chans);
        let sp = *rng.choice(&spatial);
        let rs = *rng.choice(&[1usize, 3, 5, 7]);
        layers.push(Layer::new(&format!("l{i}"), LayerKind::Conv,
                               [1, cout, cin, sp, sp, rs, rs]));
        cin = cout;
    }
    Workload::chain("random", layers, &[], 1.0)
}

#[test]
fn decode_feasible_on_random_workloads_and_geometries() {
    // the central guarantee: ANY relaxed state on ANY workload decodes
    // to a strategy that satisfies every hardware constraint, even on
    // hostile tiny geometries
    check("decode-universal-feasible", &Config { cases: 60, seed: 41 },
          |rng, size| {
              let w = random_workload(rng, size);
              let pe = *rng.choice(&[4usize, 8, 16, 32]);
              let l1 = *rng.choice(&[2.0f64, 8.0, 64.0]);
              let l2 = *rng.choice(&[4.0f64, 8.0, 512.0]);
              let mut relaxed = Relaxed::neutral(&w);
              for l in 0..w.len() {
                  for d in 0..NDIMS {
                      for s in 0..4 {
                          relaxed.theta[l][d][s] = rng.range(-3.0, 16.0);
                      }
                  }
              }
              for i in 0..relaxed.sigma.len() {
                  relaxed.sigma[i] = rng.f64();
              }
              (w, pe, l1, l2, relaxed)
          },
          |(w, pe, l1, l2, relaxed)| {
              let hw = custom_config(&repo_root(), *pe, *l1, *l2)
                  .map_err(|e| e.to_string())?;
              let s = decode(relaxed, w, &hw);
              costmodel::feasible(&s, w, &hw)
                  .map_err(|e| format!("{pe}x{pe}/{l1}KB/{l2}KB: {e}"))
          });
}

#[test]
fn simulator_never_exceeds_closed_form_anywhere() {
    // stationarity reuse can only REMOVE traffic relative to the
    // paper's Eq. (6) products — on any decoded mapping of any workload
    let hw = load_config(&repo_root(), "large").unwrap();
    check("sim-le-closed-form", &Config { cases: 60, seed: 43 },
          |rng, size| {
              let w = random_workload(rng, size);
              let mut relaxed = Relaxed::neutral(&w);
              for l in 0..w.len() {
                  for d in 0..NDIMS {
                      for s in 0..4 {
                          relaxed.theta[l][d][s] = rng.range(-1.0, 10.0);
                      }
                  }
              }
              (w, relaxed)
          },
          |(w, relaxed)| {
              let s = decode(relaxed, w, &hw);
              for i in 0..w.len() {
                  let cf = costmodel::components(&s.mappings[i],
                                                 &w.layers[i].dims);
                  let sim = tilesim::simulate_layer(&s.mappings[i],
                                                    &w.layers[i].dims);
                  ensure(sim.fill2_w <= cf.fill2_w * (1.0 + 1e-9),
                         format!("W fills: {} > {}", sim.fill2_w,
                                 cf.fill2_w))?;
                  ensure(sim.fill2_i <= cf.fill2_i * (1.0 + 1e-9),
                         "I fills exceed closed form")?;
                  ensure(sim.wb_o <= cf.wb0_o * (1.0 + 1e-9),
                         "O write-backs exceed closed form")?;
              }
              Ok(())
          });
}

#[test]
fn fusion_groups_partition_any_strategy() {
    check("groups-partition", &Config { cases: 80, seed: 47 },
          |rng, size| {
              let w = random_workload(rng, size);
              let mut s = Strategy::trivial(&w);
              for i in 0..s.fuse.len() {
                  s.fuse[i] = rng.chance(0.5);
              }
              (w.len(), s)
          },
          |(n, s)| {
              let groups = s.groups();
              let covered: usize =
                  groups.iter().map(|(a, b)| b - a + 1).sum();
              ensure(covered == *n, "groups do not cover all layers")?;
              for w2 in groups.windows(2) {
                  ensure(w2[0].1 + 1 == w2[1].0, "groups not contiguous")?;
              }
              Ok(())
          });
}

#[test]
fn divisor_candidates_always_sorted_dividing_bounded() {
    check("divisor-candidates", &Config { cases: 200, seed: 53 },
          |rng, size| {
              (1 + rng.below((30000.0 * size) as usize + 2) as u64,
               4 + rng.below(40))
          },
          |&(n, k)| {
              let c = divisor_candidates(n, k);
              ensure(c.len() <= k, "too many candidates")?;
              ensure(c[0] == 1 && *c.last().unwrap() == n,
                     "endpoints missing")?;
              for w in c.windows(2) {
                  ensure(w[0] < w[1], "not sorted")?;
              }
              for &d in &c {
                  ensure(n % d == 0, format!("{d} does not divide {n}"))?;
              }
              ensure(divisors(n).len() < k || c.len() == k,
                     "subsample did not fill k")?;
              Ok(())
          });
}

#[test]
fn energy_latency_monotone_in_epa_and_bandwidth() {
    // physics sanity on the cost model: worse memory -> no better cost
    let w = zoo::vgg16();
    let s = Strategy::trivial(&w);
    let base = load_config(&repo_root(), "large").unwrap();
    let r0 = costmodel::evaluate(&s, &w, &base);
    let mut worse = base.clone();
    worse.epa_dram *= 2.0;
    let r1 = costmodel::evaluate(&s, &w, &worse);
    assert!(r1.energy > r0.energy);
    assert!((r1.latency - r0.latency).abs() < 1e-9);
    let mut slower = base.clone();
    slower.bw_dram /= 2.0;
    let r2 = costmodel::evaluate(&s, &w, &slower);
    assert!(r2.latency >= r0.latency);
    assert!((r2.energy - r0.energy).abs() < 1e-9);
}

#[test]
fn runtime_failure_injection() {
    use std::io::Write;

    // missing directory
    assert!(Manifest::load(std::path::Path::new("/no/such/dir")).is_err());

    // corrupt manifest
    let dir = std::env::temp_dir().join("fadiff-test-corrupt");
    std::fs::create_dir_all(&dir).unwrap();
    let mut f = std::fs::File::create(dir.join("manifest.json")).unwrap();
    f.write_all(b"{ not json").unwrap();
    drop(f);
    assert!(Manifest::load(&dir).is_err());

    // manifest referencing a missing artifact file: loads, but artifact
    // compilation fails with a useful error
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"l_max": 32, "k_max": 32, "b_eval": 64, "nhw": 16,
            "ncomp": 16, "artifacts": {"ghost": {"file": "ghost.hlo.txt",
            "inputs": [], "outputs": []}}}"#,
    )
    .unwrap();
    let rt = fadiff::runtime::Runtime::load(&dir).unwrap();
    let err = match rt.get("ghost") {
        Err(e) => e.to_string(),
        Ok(_) => panic!("ghost artifact should not compile"),
    };
    assert!(err.contains("ghost"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn replica_scaling_is_quadratic_everywhere() {
    check("replica-quadratic", &Config { cases: 40, seed: 59 },
          |rng, size| {
              let mut w = random_workload(rng, size);
              w.replicas = (1 + rng.below(40)) as f64;
              w
          },
          |w| {
              let hw = load_config(&repo_root(), "large")
                  .map_err(|e| e.to_string())?;
              let s = Strategy::trivial(w);
              let r = costmodel::evaluate(&s, w, &hw);
              let full = costmodel::full_model_edp(&r, w);
              ensure((full - r.edp * w.replicas * w.replicas).abs()
                         / full.max(1e-30) < 1e-12,
                     "full-model EDP not replicas^2-scaled")
          });
}
