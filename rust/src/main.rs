//! `fadiff` — CLI for the FADiff scheduling optimizer.
//!
//! Subcommands:
//!   optimize   run one optimization job (workload x config x method)
//!   gap        exact oracle vs every baseline: measured optimality gaps
//!   workloads  list / describe servable workloads (zoo + spec files)
//!   table1     reproduce Table 1 (all workloads/configs/methods)
//!   fig3       reproduce Fig 3 (fusion trend vs DeFiNES-like baseline)
//!   fig4       reproduce Fig 4 (EDP vs optimization time)
//!   validate   reproduce Sec 4.2 (cost model vs golden simulator)
//!   selftest   compile all AOT artifacts and smoke the runtime
//!   serve      run the coordinator as a TCP service

use std::sync::atomic::Ordering;

use anyhow::{bail, Result};
use fadiff::config::repo_root;
use fadiff::coordinator::{self, Coordinator, JobRequest, Method};
use fadiff::experiments::{fig3, fig4, gap, table1, validation};
use fadiff::runtime::Runtime;
use fadiff::search::PruneMode;
use fadiff::util::cli::Args;
use fadiff::workload::{spec, zoo};

const HELP: &str = "\
fadiff — fusion-aware differentiable DNN scheduling (paper reproduction)

USAGE: fadiff <subcommand> [flags]

  optimize  --workload resnet18 --config large --method fadiff
            --seconds 10 --seed 1 --chains 8 --deadline-ms 0
            methods: fadiff | dosa | ga | bo | random | exact
            (exact is the branch-and-bound oracle: certified-optimal
            on small workloads, best-effort past its node budget)
            workloads: zoo names (gpt3 vgg19 vgg16 mobilenet resnet18)
            or any data/workloads/*.json spec stem (llama7b-decode,
            bert-base-block, ...); --workload-file my_model.json runs
            a custom JSON workload spec (see docs/protocol.md)
            (every method runs without AOT artifacts; when present,
            PJRT accelerates the gradient methods; --chains sets the
            native gradient backend's parallel chain count, 0 = auto)
            --store-dir DIR persists best results + eval caches: a
            repeat invocation answers warm from disk (re-verified);
            --force searches anyway and records improvements
            --prune on|off|full bound-and-prune screening (default on,
            bit-identical; full also screens GA, changing its
            trajectory); --warm-frac F seeds F of the population from
            the store's mapping library (needs --store-dir)
  gap       --workload micro-mlp --config large --seconds 5
            --max-iters N --seed 1 [--methods fadiff,ga,bo,random]
            run the exact oracle plus every baseline method and print
            each method's measured optimality gap (Table-1-style row)
  workloads [--describe name]   list servable workloads / show one
  table1    --seconds 30 --threads 4 --seed 1   (paper Table 1)
  fig3                                           (paper Figure 3)
  fig4      --workload resnet18 --seconds 10     (paper Figure 4)
  validate  --samples 60 --seed 11               (paper Sec 4.2)
  selftest                                       (compile artifacts)
  serve     --addr 127.0.0.1:7341 --workers 2    (TCP coordinator)
            --store-dir DIR persists results/caches across restarts
            --stall-ms 30000 watchdog threshold (0 disables); SIGINT/
            SIGTERM drain gracefully (jobs finish, store flushes)
            line-delimited JSON, v1 envelope — see docs/protocol.md
            (--deadline-ms on optimize bounds one job's wall clock;
            expired jobs answer deadline_exceeded with best-so-far)
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print!("{HELP}");
        std::process::exit(2);
    }
    let sub = argv[0].clone();
    let rest = &argv[1..];
    let code = match dispatch(&sub, rest) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn dispatch(sub: &str, rest: &[String]) -> Result<()> {
    let args = Args::parse(rest, &["verbose", "summary", "force"])?;
    match sub {
        "optimize" => cmd_optimize(&args),
        "gap" => cmd_gap(&args),
        "workloads" => cmd_workloads(&args),
        "table1" => cmd_table1(&args),
        "fig3" => cmd_fig3(&args),
        "fig4" => cmd_fig4(&args),
        "validate" | "validate-model" => cmd_validate(&args),
        "selftest" => cmd_selftest(),
        "serve" => cmd_serve(&args),
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(())
        }
        other => bail!("unknown subcommand {other:?}\n\n{HELP}"),
    }
}

fn cmd_optimize(args: &Args) -> Result<()> {
    let mut req = JobRequest {
        workload: args.get_or("workload", "resnet18"),
        config: args.get_or("config", "large"),
        method: Method::parse(&args.get_or("method", "fadiff"))?,
        seconds: args.get_f64("seconds", 10.0)?,
        max_iters: args.get_usize("max-iters", usize::MAX)?,
        seed: args.get_u64("seed", 1)?,
        chains: args.get_usize("chains", 0)?,
        deadline_ms: args.get_u64("deadline-ms", 0)?,
        spec: None,
        force: args.has("force"),
        prune: {
            let text = args.get_or("prune", "on");
            PruneMode::parse(&text).ok_or_else(|| {
                anyhow::anyhow!(
                    "--prune must be \"on\", \"off\", or \"full\" \
                     (got {text:?})"
                )
            })?
        },
        warm_frac: args.get_f64("warm-frac", 0.0)?,
    };
    if !(0.0..=1.0).contains(&req.warm_frac) {
        bail!("--warm-frac must be in [0, 1]");
    }
    if let Some(path) = args.get("workload-file") {
        let w = spec::load_file(std::path::Path::new(path))?;
        req.workload = w.name.clone();
        req.spec = Some(std::sync::Arc::new(w));
    }
    // only the gradient methods touch the PJRT runtime; probe (and
    // compile) it only for them so native methods start instantly
    let rt = match req.method {
        Method::FADiff | Method::Dosa => {
            Runtime::load_if_available(&repo_root().join("artifacts"))
        }
        _ => None,
    };
    // with --store-dir, repeat invocations are warm: an exact-key hit
    // is served from disk re-verified (unless --force re-searches)
    // and a fresh best records back for the next run
    let store = match args.get("store-dir") {
        Some(dir) => Some(std::sync::Arc::new(
            coordinator::ResultStore::open(
                std::path::Path::new(dir))?)),
        None => None,
    };
    // the mapping library rides the same store: this run records its
    // per-layer bests and a later --warm-frac run seeds from them
    let library = store.as_ref().map(|_| {
        std::sync::Arc::new(coordinator::MappingLibrary::new())
    });
    let ctx = coordinator::JobCtx {
        store: store.clone(),
        library: library.clone(),
        ..Default::default()
    };
    let r = coordinator::execute_job_ctx(rt.as_ref(), &req, &ctx)?;
    if let (Some(lib), Some(st)) = (&library, &store) {
        lib.flush(st);
    }
    println!("workload        : {}", r.request.workload);
    println!("config          : {}", r.request.config);
    println!("method          : {}", r.request.method.name());
    println!("EDP (replica)   : {:.4e} pJ*cycles", r.edp);
    println!("EDP (full model): {:.4e} pJ*cycles", r.full_model_edp);
    println!("energy          : {:.4e} pJ", r.energy);
    println!("latency         : {:.4e} cycles", r.latency);
    println!("iters / evals   : {} / {}", r.iters, r.evals);
    println!("wall time       : {:.2}s", r.wall_seconds);
    if r.stored {
        println!("served from     : result store (re-verified)");
    }
    if let Some(ex) = &r.exact {
        println!("certified       : {}",
                 if ex.certified { "yes (proven optimum)" }
                 else { "no (node/candidate cap tripped)" });
        println!("nodes exp / gen : {} / {}",
                 ex.nodes_expanded, ex.nodes_generated);
        println!("pruned b/i/d    : {} / {} / {}",
                 ex.pruned_bound, ex.pruned_infeasible,
                 ex.pruned_dominated);
    }
    if r.fused_names.is_empty() {
        println!("fusion groups   : none");
    } else {
        println!("fusion groups   :");
        for g in &r.fused_names {
            println!("  - {}", g.join(" -> "));
        }
    }
    Ok(())
}

fn cmd_gap(args: &Args) -> Result<()> {
    let base = JobRequest {
        workload: args.get_or("workload", "micro-mlp"),
        config: args.get_or("config", "large"),
        seconds: args.get_f64("seconds", 5.0)?,
        max_iters: args.get_usize("max-iters", usize::MAX)?,
        seed: args.get_u64("seed", 1)?,
        ..Default::default()
    };
    let methods: Vec<Method> = match args.get("methods") {
        None => Vec::new(), // measure() applies the default panel
        Some(list) => list
            .split(',')
            .map(|m| Method::parse(m.trim()))
            .collect::<Result<_>>()?,
    };
    // PJRT accelerates the gradient baselines when artifacts exist;
    // everything runs on the native backends otherwise
    let rt = Runtime::load_if_available(&repo_root().join("artifacts"));
    let rep = gap::measure(rt.as_ref(), &base, &methods)?;
    println!("exact EDP       : {:.4e} pJ*cycles ({})",
             rep.exact_edp,
             if rep.certified { "certified optimum" }
             else { "UNCERTIFIED — cap tripped" });
    println!("nodes expanded  : {}", rep.nodes_expanded);
    println!("subtrees pruned : {}", rep.pruned);
    println!("oracle wall time: {:.2}s", rep.exact_seconds);
    println!();
    print!("{}", rep.render());
    Ok(())
}

fn cmd_workloads(args: &Args) -> Result<()> {
    if let Some(name) = args.get("describe") {
        let w = coordinator::resolve_workload(name)?;
        println!("{}", spec::describe_json(&w).pretty());
        return Ok(());
    }
    println!("{:<22} {:>7} {:>9} {:>12}  source", "name", "layers",
             "replicas", "GMACs");
    for (name, source, outcome) in coordinator::workload_catalog() {
        match outcome {
            Ok(w) => println!("{:<22} {:>7} {:>9} {:>12.2}  {}", name,
                              w.len(), w.replicas,
                              w.total_ops() / 1e9, source),
            Err(e) => println!("{name:<22} INVALID: {e}"),
        }
    }
    Ok(())
}

fn cmd_table1(args: &Args) -> Result<()> {
    let seconds = args.get_f64("seconds", 30.0)?;
    let threads = args.get_usize("threads", 4)?;
    let seed = args.get_u64("seed", 1)?;
    let t = table1::run(&repo_root().join("artifacts"), seconds, threads,
                        seed)?;
    println!("{}", table1::render(&t));
    Ok(())
}

fn cmd_fig3(_args: &Args) -> Result<()> {
    let hw = fadiff::config::load_config(&repo_root(), "large")?;
    let (two, three) = fig3::run(&hw);
    println!("{}", fig3::render(&two));
    println!("{}", fig3::render(&three));
    Ok(())
}

fn cmd_fig4(args: &Args) -> Result<()> {
    // PJRT accelerates the gradient trace when available; the native
    // differentiable backend serves it otherwise
    let rt = Runtime::load_if_available(&repo_root().join("artifacts"));
    let hw = fadiff::config::load_config(&repo_root(), "large")?;
    let name = args.get_or("workload", "resnet18");
    let w = zoo::by_name(&name)
        .ok_or_else(|| anyhow::anyhow!("unknown workload {name:?}"))?;
    let seconds = args.get_f64("seconds", 10.0)?;
    let r = fig4::run(rt.as_ref(), &w, &hw, seconds,
                      args.get_u64("seed", 1)?)?;
    println!("{}", fig4::render(&r));
    Ok(())
}

fn cmd_validate(args: &Args) -> Result<()> {
    let hw = fadiff::config::load_config(
        &repo_root(), &args.get_or("config", "large"))?;
    let samples = args.get_usize("samples", 60)?;
    let seed = args.get_u64("seed", 11)?;
    let r = validation::run(&hw, samples, seed);
    println!("{}", validation::render(&r));
    Ok(())
}

fn cmd_selftest() -> Result<()> {
    let rt = Runtime::load_default()?;
    for line in fadiff::runtime::selftest(&rt)? {
        println!("{line}");
    }
    println!("selftest OK");
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let addr = args.get_or("addr", "127.0.0.1:7341");
    let workers = args.get_usize("workers", 2)?;
    let store_dir =
        args.get("store-dir").map(std::path::PathBuf::from);
    let coord = Coordinator::new_with_store(None, workers, store_dir)?;
    let stall_ms = args.get_u64(
        "stall-ms", fadiff::coordinator::DEFAULT_STALL_MS)?;
    coord.set_stall_ms(stall_ms);
    // a signal drains like the shutdown verb: jobs finish, the
    // result store flushes, then the process exits cleanly
    fadiff::coordinator::server::install_signal_handlers();
    let metrics = std::sync::Arc::clone(&coord.metrics);
    let result = fadiff::coordinator::server::serve(&addr, coord);
    eprintln!("served {} jobs total",
              metrics.submitted.load(Ordering::SeqCst));
    result
}
