//! Tile-walking golden simulator — the Timeloop-class reference the
//! differentiable model is validated against (paper Sec 4.2).
//!
//! Unlike the closed-form model (`crate::costmodel`, which multiplies
//! *all* outer temporal loops into every fetch count — the paper's
//! Eq. (6)), this simulator is **loop-order aware**: it fixes a concrete
//! loop order at every memory level and counts a tile re-fetch only when
//! a loop *relevant to that tensor* (or any loop outside it) advances —
//! i.e. single-buffered stationarity reuse, the way Timeloop's reuse
//! analysis works. The residual discrepancy between the two models is
//! exactly what the paper's "96% access-count accuracy" measures.
//!
//! A brute-force nested-loop walker validates the analytic counting on
//! small nests in the test suite.

use crate::config::HwConfig;
use crate::costmodel::{I_DIMS, O_DIMS, W_DIMS};
use crate::mapping::{LayerMapping, Strategy, SLOT_S};
use crate::workload::{Workload, DIM_C, DIM_K, NDIMS};

/// Loop order at every temporal level, outermost first. Reduction dims
/// (C, R, S) outermost, output dims inner, K innermost — the Gemmini
/// weight-stationary schedule the closed-form model assumes: outputs are
/// re-drained across reduction iterations (the paper's Eq. (10)
/// WriteCount) and weights are re-streamed per outer iteration (Eq. (6)).
/// The remaining divergence between simulator and closed form is the
/// input-refetch K co-factor — the gap the §4.2 accuracy metric measures.
pub const LOOP_ORDER: [usize; NDIMS] = [
    crate::workload::DIM_C,
    crate::workload::DIM_R,
    crate::workload::DIM_S,
    crate::workload::DIM_N,
    crate::workload::DIM_P,
    crate::workload::DIM_Q,
    crate::workload::DIM_K,
];

/// Per-layer simulated traffic (element counts).
#[derive(Clone, Copy, Debug, Default)]
pub struct SimTraffic {
    /// Input elements filled into L2.
    pub fill2_i: f64,
    /// Weight elements filled into L2.
    pub fill2_w: f64,
    /// Weight elements filled into the register file.
    pub fill0_w: f64,
    /// Input elements streamed through the PE array.
    pub read_pe_i: f64,
    /// Output accumulate/write-back traffic at L1.
    pub accwb_o: f64,
    /// Output elements drained from L1.
    pub wb_o: f64,
    /// Total MACs.
    pub ops: f64,
    /// Input-tile L2 footprint, elements (capacity accounting).
    pub s_i2: f64,
    /// Weight-tile L2 footprint, elements.
    pub s_w2: f64,
    /// Output-tile L1 footprint, elements.
    pub s_o1: f64,
}

/// Simulated per-layer cost.
#[derive(Clone, Copy, Debug, Default)]
pub struct SimLayer {
    /// Simulated traffic counts.
    pub traffic: SimTraffic,
    /// Element accesses at [L0, L1, L2, L3].
    pub access: [f64; 4],
    /// Cycles (roofline).
    pub latency: f64,
    /// pJ.
    pub energy: f64,
}

/// Whole-strategy simulation result.
#[derive(Clone, Debug)]
pub struct SimReport {
    /// Total energy, pJ.
    pub energy: f64,
    /// Total latency, cycles.
    pub latency: f64,
    /// `energy * latency`.
    pub edp: f64,
    /// Per-layer breakdown.
    pub per_layer: Vec<SimLayer>,
}

/// Trip counts of the temporal loops above (outside) a storage level.
/// `level` 0..=2: loops at levels `level+1..=3`; the DRAM level (3)
/// co-factor is derived from the dim size.
fn outer_trips(m: &LayerMapping, dims: &[usize; NDIMS], level: usize)
               -> Vec<[u64; NDIMS]> {
    // temporal trip counts per level: t1, t2, t3(derived)
    let mut per_level: Vec<[u64; NDIMS]> = Vec::new();
    for lv in (level + 1)..=3 {
        let mut trips = [1u64; NDIMS];
        for d in 0..NDIMS {
            if lv < 3 {
                trips[d] = m.factors[d][lv];
            } else {
                let inner: u64 = m.factors[d].iter().product();
                trips[d] = (dims[d] as u64) / inner.max(1);
            }
        }
        per_level.push(trips);
    }
    per_level.reverse(); // outermost (DRAM) first
    per_level
}

/// Count how many times a tensor tile buffered at `level` is (re)fetched,
/// given the fixed LOOP_ORDER at every outer level and single buffering:
/// the product of trip counts of every loop from the outermost down to
/// the innermost loop that indexes the tensor; loops strictly inside the
/// innermost relevant loop exploit stationarity (no refetch).
fn fetch_count(m: &LayerMapping, dims: &[usize; NDIMS], level: usize,
               tensor_dims: &[usize]) -> f64 {
    let levels = outer_trips(m, dims, level);
    // flatten: iterate levels outer->inner, and within each level follow
    // LOOP_ORDER; record trip count + relevance per loop
    let mut trips: Vec<(u64, bool)> = Vec::new();
    for lv in &levels {
        for &d in LOOP_ORDER.iter() {
            if lv[d] > 1 {
                trips.push((lv[d], tensor_dims.contains(&d)));
            }
        }
    }
    let innermost_relevant = trips.iter().rposition(|&(_, rel)| rel);
    match innermost_relevant {
        None => 1.0, // fully stationary: fetched once
        Some(pos) => trips[..=pos].iter().map(|&(t, _)| t as f64).product(),
    }
}

/// Same, but for the *write-back* of an output tile held at `level`:
/// the tile drains once per advance of any outer loop, except that pure
/// reduction loops (dims not indexing the output) inside the innermost
/// output-relevant loop accumulate in place.
fn write_count(m: &LayerMapping, dims: &[usize; NDIMS], level: usize)
               -> f64 {
    fetch_count(m, dims, level, &O_DIMS)
}

/// Walk one layer.
pub fn simulate_layer(m: &LayerMapping, dims: &[usize; NDIMS]) -> SimTraffic {
    let ext = |slots: std::ops::RangeInclusive<usize>, d: usize| -> f64 {
        let mut e = m.factors[d][SLOT_S] as f64;
        for s in slots {
            if s < SLOT_S {
                e *= m.factors[d][s] as f64;
            }
        }
        e
    };
    let tile = |upto: usize, ds: &[usize]| -> f64 {
        ds.iter().map(|&d| ext(0..=upto, d)).product()
    };

    let ops: f64 = dims.iter().map(|&d| d as f64).product();
    let sp_k = m.factors[DIM_K][SLOT_S] as f64;
    let sp_c = m.factors[DIM_C][SLOT_S] as f64;

    let s_w2 = tile(2, &W_DIMS);
    let s_i2 = tile(2, &I_DIMS);
    let s_w0 = tile(0, &W_DIMS);
    let s_o1 = tile(1, &O_DIMS);

    SimTraffic {
        fill2_i: s_i2 * fetch_count(m, dims, 2, &I_DIMS),
        fill2_w: s_w2 * fetch_count(m, dims, 2, &W_DIMS),
        fill0_w: s_w0 * fetch_count(m, dims, 0, &W_DIMS),
        read_pe_i: ops / sp_k.max(1.0),
        accwb_o: ops / sp_c.max(1.0),
        wb_o: s_o1 * write_count(m, dims, 1),
        ops,
        s_i2,
        s_w2,
        s_o1,
    }
}

/// Simulate a full strategy including depth-first fusion-group execution:
/// inside a group, intermediate outputs bypass DRAM (an L1->L2 copy
/// replaces the write-back; the consumer's input fill comes from L2).
pub fn simulate(s: &Strategy, w: &Workload, hw: &HwConfig) -> SimReport {
    let l = w.len();
    let mut per_layer = Vec::with_capacity(l);
    let (mut energy, mut latency) = (0.0, 0.0);
    for i in 0..l {
        let t = simulate_layer(&s.mappings[i], &w.layers[i].dims);
        let fused_out = i < l - 1 && s.fuse[i];
        let fused_in = i > 0 && s.fuse[i - 1];

        let wb3 = if fused_out { 0.0 } else { t.wb_o };
        let copy12 = if fused_out { t.wb_o } else { 0.0 };
        let fill2_i = if fused_in { 0.0 } else { t.fill2_i };

        let a3 = fill2_i + t.fill2_w + wb3;
        let a2 = fill2_i + t.fill2_w + t.fill0_w + t.read_pe_i + copy12;
        let a1 = t.accwb_o + t.wb_o;
        let a0 = t.fill0_w + t.ops;

        let pes = (s.mappings[i].pes() as f64).max(1.0);
        let eb = hw.element_bytes;
        let lat = (t.ops / pes)
            .max(a3 * eb / hw.bw_dram)
            .max(a2 * eb / hw.bw_l2)
            .max(a1 * eb / hw.bw_l1);
        let en = t.ops * hw.energy_per_mac
            + a3 * hw.epa_dram
            + a2 * hw.epa_l2
            + a1 * hw.epa_l1
            + a0 * hw.epa_reg;
        energy += en;
        latency += lat;
        per_layer.push(SimLayer {
            traffic: t,
            access: [a0, a1, a2, a3],
            latency: lat,
            energy: en,
        });
    }
    SimReport { energy, latency, edp: energy * latency, per_layer }
}

/// Brute-force nested-loop walker used to validate `fetch_count` on
/// small nests: literally iterates every outer loop iteration in
/// LOOP_ORDER and counts relevant-tuple changes under single buffering.
#[cfg(test)]
pub fn fetch_count_bruteforce(m: &LayerMapping, dims: &[usize; NDIMS],
                              level: usize, tensor_dims: &[usize]) -> f64 {
    let levels = outer_trips(m, dims, level);
    let mut loops: Vec<(usize, u64)> = Vec::new(); // (dim, trip)
    for lv in &levels {
        for &d in LOOP_ORDER.iter() {
            if lv[d] > 1 {
                loops.push((d, lv[d]));
            }
        }
    }
    let mut idx = vec![0u64; loops.len()];
    let mut fetches = 0u64;
    let mut last: Option<Vec<u64>> = None;
    loop {
        let key: Vec<u64> = idx
            .iter()
            .zip(&loops)
            .filter(|(_, (d, _))| tensor_dims.contains(d))
            .map(|(&i, _)| i)
            .collect();
        if last.as_ref() != Some(&key) {
            fetches += 1;
            last = Some(key);
        }
        // odometer increment (innermost fastest)
        let mut carry = true;
        for j in (0..loops.len()).rev() {
            if !carry {
                break;
            }
            idx[j] += 1;
            if idx[j] < loops[j].1 {
                carry = false;
            } else {
                idx[j] = 0;
            }
        }
        if carry {
            break;
        }
    }
    fetches as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{load_config, repo_root};
    use crate::mapping::{decode, SLOT_T1, SLOT_T2};
    use crate::util::prop::{check, ensure, Config};
    use crate::workload::zoo;

    fn hw() -> HwConfig {
        load_config(&repo_root(), "large").unwrap()
    }

    #[test]
    fn fetch_count_matches_bruteforce_prop() {
        let w = zoo::vgg16();
        check("tilesim-vs-bruteforce", &Config { cases: 40, seed: 21 },
              |r, _| {
                  let li = r.below(4); // small early layers
                  let mut m = LayerMapping::trivial();
                  let dims = w.layers[li].dims;
                  for d in 0..NDIMS {
                      let divs = crate::mapping::divisors(dims[d] as u64);
                      // small tiles only (keep brute force tractable)
                      let cands: Vec<u64> = divs
                          .iter()
                          .copied()
                          .filter(|&x| x <= 4)
                          .collect();
                      m.factors[d][SLOT_T1] = *r.choice(&cands);
                      m.factors[d][SLOT_T2] = *r.choice(&cands);
                  }
                  (li, m)
              },
              |(li, m)| {
                  let dims = &w.layers[*li].dims;
                  // keep total outer iterations tractable
                  let total: f64 = (0..NDIMS)
                      .map(|d| dims[d] as f64 / m.inner(d) as f64)
                      .product::<f64>()
                      * (0..NDIMS)
                          .map(|d| m.factors[d][SLOT_T2] as f64)
                          .product::<f64>();
                  if total > 250_000.0 {
                      return Ok(()); // skip oversized cases
                  }
                  for tensor in [&W_DIMS[..], &I_DIMS[..], &O_DIMS[..]] {
                      let fast = fetch_count(m, dims, 2, tensor);
                      let slow = fetch_count_bruteforce(m, dims, 2, tensor);
                      if (fast - slow).abs() > 0.5 {
                          return Err(format!(
                              "tensor {tensor:?}: analytic {fast} != \
                               bruteforce {slow} for {m:?}"
                          ));
                      }
                  }
                  Ok(())
              });
    }

    #[test]
    fn stationary_weight_fetched_once() {
        // Everything tiled at L2 => weights fetched exactly once.
        let w = zoo::vgg16();
        let dims = w.layers[1].dims;
        let mut m = LayerMapping::trivial();
        for d in 0..NDIMS {
            m.factors[d][SLOT_T2] = dims[d] as u64;
        }
        let t = simulate_layer(&m, &dims);
        assert_eq!(t.fill2_w, (64 * 64 * 9) as f64);
    }

    #[test]
    fn sim_never_exceeds_closed_form() {
        // The closed-form model multiplies ALL outer loops into every
        // fetch; the order-aware sim exploits stationarity, so sim fills
        // must be <= closed-form fills.
        let hw = hw();
        let w = zoo::vgg16();
        let mut rng = crate::util::rng::Rng::new(5);
        for _ in 0..30 {
            let li = rng.below(w.len());
            let dims = w.layers[li].dims;
            let mut relaxed = decode::Relaxed::neutral(&w);
            for d in 0..NDIMS {
                for s in 0..4 {
                    relaxed.theta[li][d][s] = rng.range(0.0, 6.0);
                }
            }
            let m = decode::decode_layer(&relaxed.theta[li], &dims, &hw);
            let sim = simulate_layer(&m, &dims);
            let cf = crate::costmodel::components(&m, &dims);
            assert!(sim.fill2_w <= cf.fill2_w * (1.0 + 1e-9),
                    "W: {} > {}", sim.fill2_w, cf.fill2_w);
            assert!(sim.fill2_i <= cf.fill2_i * (1.0 + 1e-9));
            assert!(sim.wb_o <= cf.wb0_o * (1.0 + 1e-9));
        }
    }

    #[test]
    fn fusion_removes_intermediate_dram() {
        let hw = hw();
        let w = zoo::vgg16();
        let mut s = crate::mapping::Strategy::trivial(&w);
        let base = simulate(&s, &w, &hw);
        s.fuse[0] = true;
        let fused = simulate(&s, &w, &hw);
        let dram = |r: &SimReport| -> f64 {
            r.per_layer.iter().map(|l| l.access[3]).sum()
        };
        assert!(dram(&fused) < dram(&base));
    }

    #[test]
    fn sim_totals_consistent() {
        let hw = hw();
        let w = zoo::resnet18();
        let s = crate::mapping::Strategy::trivial(&w);
        let r = simulate(&s, &w, &hw);
        let esum: f64 = r.per_layer.iter().map(|l| l.energy).sum();
        assert!((esum - r.energy).abs() / r.energy < 1e-12);
        assert!((r.edp - r.energy * r.latency).abs() / r.edp < 1e-12);
    }

    #[test]
    fn closed_form_and_sim_strongly_correlated() {
        // sanity floor for the validation experiment: rankings agree
        use crate::util::stats::spearman_rho;
        let hw = hw();
        let w = zoo::vgg16();
        let dims = w.layers[2].dims;
        let mut rng = crate::util::rng::Rng::new(11);
        let (mut xs, mut ys) = (Vec::new(), Vec::new());
        for _ in 0..40 {
            let mut relaxed = decode::Relaxed::neutral(&w);
            for d in 0..NDIMS {
                for sl in 0..4 {
                    relaxed.theta[2][d][sl] = rng.range(0.0, 7.0);
                }
            }
            let m = decode::decode_layer(&relaxed.theta[2], &dims, &hw);
            let sim = simulate_layer(&m, &dims);
            let cf = crate::costmodel::components(&m, &dims);
            xs.push(sim.fill2_i + sim.fill2_w + sim.wb_o);
            ys.push(cf.fill2_i + cf.fill2_w + cf.wb0_o);
        }
        let rho = spearman_rho(&xs, &ys);
        assert!(rho > 0.8, "rho = {rho}");
    }
}
