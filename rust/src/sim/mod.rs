//! Independent simulators: the tile-walking golden reference
//! (Timeloop-class, validates the differentiable model — paper Sec 4.2)
//! and the DeFiNES-like depth-first fusion baseline (Fig 3).

pub mod definesim;
pub mod tilesim;
