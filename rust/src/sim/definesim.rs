//! DeFiNES-like depth-first fusion baseline (paper ref [2], used as the
//! external reference for the Fig 3 trend validation).
//!
//! Structure follows DeFiNES's depth-first scheduling abstraction, which
//! is *deliberately different* from both the closed-form model and the
//! tile-walking simulator:
//!
//! * a fused stack executes output-tile by output-tile, depth first;
//! * the consumer's output tile is chosen, and required input tiles are
//!   back-propagated through the stack with R/S halo growth;
//! * DRAM traffic = first-layer input fills + last-layer output stores +
//!   per-layer weight streams; intermediates live entirely on chip;
//! * latency per tile = max(compute, DRAM stream) under LB (fully-flexible
//!   on-chip) assumptions; tiles pipeline without refill overlap.
//!
//! Because Fig 3 compares *Z-scored trends*, only relative movement
//! across tile-size sweeps matters — absolute constants differ from the
//! other models by design.

use crate::config::HwConfig;
use crate::workload::{Layer, DIM_C, DIM_K, DIM_N, DIM_P, DIM_Q, DIM_R,
                      DIM_S};

/// A depth-first schedule for a fused stack: the output tile of the LAST
/// layer in the stack, in (p, q) spatial extents.
#[derive(Clone, Copy, Debug)]
pub struct DfTile {
    /// Output-tile height (P extent).
    pub tp: usize,
    /// Output-tile width (Q extent).
    pub tq: usize,
}

/// Cost of one fused stack under a depth-first schedule.
#[derive(Clone, Copy, Debug, Default)]
pub struct DfCost {
    /// DRAM traffic, elements.
    pub dram_elems: f64,
    /// On-chip traffic, elements.
    pub onchip_elems: f64,
    /// Total MACs.
    pub macs: f64,
    /// Cycles (max of compute and DRAM stream per tile).
    pub latency: f64,
    /// pJ.
    pub energy: f64,
    /// Peak on-chip footprint (bytes) of the depth-first working set.
    pub peak_bytes: f64,
}

/// Evaluate a fused stack (1..=N layers, producer first) executing
/// depth-first with the given last-layer output tile.
pub fn evaluate_stack(stack: &[Layer], tile: DfTile, hw: &HwConfig)
                      -> DfCost {
    assert!(!stack.is_empty());
    let last = &stack[stack.len() - 1];
    let out_p = last.dims[DIM_P];
    let out_q = last.dims[DIM_Q];
    let tiles_p = out_p.div_ceil(tile.tp);
    let tiles_q = out_q.div_ceil(tile.tq);
    let n_tiles = (tiles_p * tiles_q) as f64 * last.dims[DIM_N] as f64;

    // Back-propagate tile extents through the stack (halo growth by
    // R-1 / S-1 per layer, stride-1 model).
    let mut tp = vec![0usize; stack.len() + 1];
    let mut tq = vec![0usize; stack.len() + 1];
    tp[stack.len()] = tile.tp.min(out_p);
    tq[stack.len()] = tile.tq.min(out_q);
    for i in (0..stack.len()).rev() {
        tp[i] = (tp[i + 1] + stack[i].dims[DIM_R] - 1)
            .min(stack[i].dims[DIM_P] + stack[i].dims[DIM_R] - 1);
        tq[i] = (tq[i + 1] + stack[i].dims[DIM_S] - 1)
            .min(stack[i].dims[DIM_Q] + stack[i].dims[DIM_S] - 1);
    }

    let first = &stack[0];
    // DRAM traffic per tile: first-layer input tile + last-layer output
    // tile; weights stream once per tile unless they fit resident.
    let in_tile =
        (tp[0] * tq[0] * first.dims[DIM_C]) as f64;
    let out_tile = (tp[stack.len()] * tq[stack.len()]
        * last.dims[DIM_K]) as f64;
    let weights_total: f64 = stack
        .iter()
        .map(|l| {
            (l.dims[DIM_K] * l.dims[DIM_C] * l.dims[DIM_R] * l.dims[DIM_S])
                as f64
        })
        .sum();
    let weights_bytes = weights_total * hw.element_bytes;

    // Working set: per-layer intermediate tiles + weights (if resident).
    let mut inter = 0.0f64;
    let mut macs = 0.0f64;
    for (i, l) in stack.iter().enumerate() {
        inter += (tp[i + 1] * tq[i + 1] * l.dims[DIM_K]) as f64;
        macs += (l.dims[DIM_K] * l.dims[DIM_C] * l.dims[DIM_R]
            * l.dims[DIM_S]) as f64
            * (tp[i + 1] * tq[i + 1]) as f64;
    }
    let weights_resident =
        weights_bytes + inter * hw.element_bytes <= hw.c2_bytes;
    let peak_bytes = inter * hw.element_bytes
        + if weights_resident { weights_bytes } else { 0.0 };

    let dram_per_tile = in_tile
        + out_tile
        + if weights_resident { 0.0 } else { weights_total };
    let dram_elems = dram_per_tile * n_tiles
        + if weights_resident { weights_total } else { 0.0 };
    let onchip_per_tile = inter * 2.0; // produce + consume
    let onchip_elems = onchip_per_tile * n_tiles;
    let total_macs = macs * n_tiles;

    // Latency: per-tile max(compute at full array, DRAM stream), summed.
    let eb = hw.element_bytes;
    let compute = macs / hw.n_pe();
    let stream = dram_per_tile * eb / hw.bw_dram;
    let latency = compute.max(stream) * n_tiles
        + if weights_resident {
            weights_bytes / hw.bw_dram
        } else {
            0.0
        };

    let energy = total_macs * hw.energy_per_mac
        + dram_elems * hw.epa_dram
        + onchip_elems * hw.epa_l2;

    DfCost {
        dram_elems,
        onchip_elems,
        macs: total_macs,
        latency,
        energy,
        peak_bytes,
    }
}

/// Sweep depth-first output-tile sizes for a stack, returning
/// (tile, cost) pairs — the Fig 3 x-axis.
pub fn sweep_tiles(stack: &[Layer], hw: &HwConfig) -> Vec<(DfTile, DfCost)> {
    let last = &stack[stack.len() - 1];
    let mut out = Vec::new();
    for &t in &[1usize, 2, 4, 7, 8, 14, 16, 28, 32, 56, 112, 224] {
        if t > last.dims[DIM_P].max(1) {
            continue;
        }
        let tile = DfTile { tp: t, tq: t };
        out.push((tile, evaluate_stack(stack, tile, hw)));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{load_config, repo_root};
    use crate::workload::{zoo, LayerKind};

    fn hw() -> HwConfig {
        load_config(&repo_root(), "large").unwrap()
    }

    fn stack2() -> Vec<Layer> {
        let w = zoo::vgg16();
        vec![w.layers[4].clone(), w.layers[5].clone()]
    }

    #[test]
    fn halo_grows_backward() {
        let s = stack2();
        let c_small = evaluate_stack(&s, DfTile { tp: 4, tq: 4 }, &hw());
        let c_big = evaluate_stack(&s, DfTile { tp: 28, tq: 28 }, &hw());
        // small tiles => relatively more halo => more DRAM per output
        let per_out_small = c_small.dram_elems / c_small.macs;
        let per_out_big = c_big.dram_elems / c_big.macs;
        assert!(per_out_small > per_out_big);
    }

    #[test]
    fn fused_stack_beats_sum_of_singles_on_dram() {
        let s = stack2();
        let hw = hw();
        let t = DfTile { tp: 14, tq: 14 };
        let fused = evaluate_stack(&s, t, &hw);
        let a = evaluate_stack(&s[..1], t, &hw);
        let b = evaluate_stack(&s[1..], t, &hw);
        assert!(fused.dram_elems < a.dram_elems + b.dram_elems);
    }

    #[test]
    fn sweep_is_nonempty_and_finite() {
        let s = stack2();
        let pts = sweep_tiles(&s, &hw());
        assert!(pts.len() >= 5);
        for (_, c) in pts {
            assert!(c.energy.is_finite() && c.latency.is_finite());
            assert!(c.energy > 0.0 && c.latency > 0.0);
        }
    }

    #[test]
    fn three_layer_stack_works() {
        let w = zoo::vgg16();
        let s = vec![w.layers[4].clone(), w.layers[5].clone(),
                     w.layers[6].clone()];
        let c = evaluate_stack(&s, DfTile { tp: 14, tq: 14 }, &hw());
        assert!(c.macs > 0.0 && c.peak_bytes > 0.0);
    }

    #[test]
    fn fc_stack_degenerates_gracefully() {
        let w = zoo::vgg16();
        let fc: Vec<Layer> = w
            .layers
            .iter()
            .filter(|l| l.kind == LayerKind::Fc)
            .take(2)
            .cloned()
            .collect();
        let c = evaluate_stack(&fc, DfTile { tp: 1, tq: 1 }, &hw());
        assert!(c.energy.is_finite());
    }
}
