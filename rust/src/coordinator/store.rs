//! Persistent content-addressed result store: warm service state that
//! survives coordinator restarts.
//!
//! The FADiff value proposition is amortized search — a strategy is
//! expensive to find once and cheap to reuse forever. This module makes
//! "forever" outlive the process: best-found [`SearchResult`]s and
//! eval-cache segments persist under a `--store-dir` root as
//! digest-named blobs (`blobs/<fnv1a64-of-content>`) indexed by a small
//! versioned JSON manifest (`manifest.json`), following the OCI
//! manifest/digest layout idiom.
//!
//! On-disk layout:
//!
//! ```text
//! <store-dir>/
//!   manifest.json        versioned index: key -> digest + metadata
//!   blobs/<16-hex>       content blobs, named by their own fnv1a64
//! ```
//!
//! Durability and integrity rules:
//!
//! * Every write is atomic: content goes to a temp file in the same
//!   directory and is `rename`d into place, so a crash mid-write can
//!   never leave a half blob or half manifest under the final name.
//! * Every blob read recomputes the digest and compares it to the file
//!   name; a truncated, corrupted, or swapped blob degrades to a cold
//!   miss (counted in [`StoreStats::corrupt_skips`]) — never a panic,
//!   never a stale answer.
//! * Keys embed *content fingerprints* ([`crate::workload::spec::
//!   fingerprint`] for the workload, [`HwConfig::fingerprint`] for the
//!   hardware), never display names, so editing a spec or a hardware
//!   config can never serve a result computed for different content.
//! * A manifest with an unknown `version` disables persistence for the
//!   session instead of clobbering a future format; an unparseable
//!   manifest starts empty (and writable — it was garbage, not future).
//!
//! Stored results are additionally *re-verified before being served*
//! (see `coordinator::execute_job_ctx`): the strategy is re-scored
//! through [`compute_eval`] and must reproduce the stored
//! energy/latency/EDP bit-for-bit, so even a digest-valid blob from a
//! drifted cost model is rejected rather than trusted.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{Context, Result};

use super::JobRequest;
use crate::config::HwConfig;
use crate::mapping::{LayerMapping, Strategy, NSLOTS};
use crate::search::eval::{compute_eval, Eval};
use crate::search::SearchResult;
use crate::util::json::{arr, num, obj, s, Json};
use crate::workload::{Workload, NDIMS};

/// Manifest format version this build reads and writes.
pub const MANIFEST_VERSION: u64 = 1;

const MANIFEST_FILE: &str = "manifest.json";
const BLOBS_DIR: &str = "blobs";

/// FNV-1a 64 over raw bytes, rendered as 16 lowercase hex digits —
/// the digest that names every blob (same construction as
/// [`crate::workload::spec::fingerprint`]).
pub fn fnv1a64(bytes: &[u8]) -> String {
    let mut hash: u64 = 0xCBF2_9CE4_8422_2325;
    for &b in bytes {
        hash ^= b as u64;
        hash = hash.wrapping_mul(0x0000_0100_0000_01B3);
    }
    format!("{hash:016x}")
}

/// An `f64` as its exact bit pattern in 16 hex digits. Floats round-trip
/// the store losslessly this way — the restart-warm property is
/// bit-identical, not approximately-equal.
pub fn bits_hex(x: f64) -> String {
    format!("{:016x}", x.to_bits())
}

pub(crate) fn parse_bits(text: &str) -> Option<f64> {
    if text.len() != 16 {
        return None;
    }
    u64::from_str_radix(text, 16).ok().map(f64::from_bits)
}

fn unix_now() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

/// Blob I/O attempts per operation (1 initial + bounded retries).
const IO_ATTEMPTS: u32 = 3;

/// Error kinds the OS reports for conditions that can clear on their
/// own — worth a bounded retry. Everything else (missing file,
/// permission denied, disk full, ...) is permanent for our purposes
/// and degrades immediately.
fn transient_io(kind: std::io::ErrorKind) -> bool {
    matches!(kind,
             std::io::ErrorKind::Interrupted
             | std::io::ErrorKind::TimedOut
             | std::io::ErrorKind::WouldBlock)
}

/// Store counters, surfaced by the `store` verb and under
/// `metrics.store`.
#[derive(Default)]
pub struct StoreStats {
    /// Stored results served after passing re-verification.
    pub result_hits: AtomicU64,
    /// Result lookups that found no manifest entry.
    pub result_misses: AtomicU64,
    /// Results written back (new keys + strict improvements).
    pub results_written: AtomicU64,
    /// Eval-cache segments hydrated into a registry pair.
    pub hydrations: AtomicU64,
    /// Dirty eval-cache segments flushed to disk.
    pub flushes: AtomicU64,
    /// Corrupt / unverifiable entries dropped (blob digest mismatch,
    /// parse failure, or failed re-verification).
    pub corrupt_skips: AtomicU64,
    /// Transient blob I/O failures that were retried (each backoff
    /// sleep counts once). Surfaced as
    /// `metrics.faults.store_io_retries`.
    pub io_retries: AtomicU64,
    /// Blob I/O operations that failed definitively — a non-transient
    /// error, or retries exhausted. The operation degrades to the
    /// counted cold-miss / skip paths, never a panic. Surfaced as
    /// `metrics.faults.store_io_permanent`.
    pub io_permanent: AtomicU64,
}

#[derive(Clone)]
struct ResultMeta {
    digest: String,
    edp_bits: u64,
    evals: u64,
    created_at: u64,
}

#[derive(Clone)]
struct SegmentMeta {
    digest: String,
    entries: u64,
    created_at: u64,
}

#[derive(Default)]
struct Manifest {
    results: BTreeMap<String, ResultMeta>,
    segments: BTreeMap<String, SegmentMeta>,
    /// Warm-start mapping-library shards, one per hardware config
    /// fingerprint (same metadata shape as eval-cache segments). The
    /// section is OPTIONAL on parse: manifests written before the
    /// library existed load with an empty library, not as corrupt.
    library: BTreeMap<String, SegmentMeta>,
}

enum ManifestLoad {
    Ready(Manifest),
    Future,
    Corrupt,
}

impl Manifest {
    fn to_json(&self) -> Json {
        let results: BTreeMap<String, Json> = self
            .results
            .iter()
            .map(|(k, v)| {
                (k.clone(),
                 obj(vec![
                     ("digest", s(&v.digest)),
                     ("edp", num(f64::from_bits(v.edp_bits))),
                     ("edp_bits",
                      s(&format!("{:016x}", v.edp_bits))),
                     ("evals", num(v.evals as f64)),
                     ("created_at", num(v.created_at as f64)),
                 ]))
            })
            .collect();
        let segments: BTreeMap<String, Json> = self
            .segments
            .iter()
            .map(|(k, v)| {
                (k.clone(),
                 obj(vec![
                     ("digest", s(&v.digest)),
                     ("entries", num(v.entries as f64)),
                     ("created_at", num(v.created_at as f64)),
                 ]))
            })
            .collect();
        let library: BTreeMap<String, Json> = self
            .library
            .iter()
            .map(|(k, v)| {
                (k.clone(),
                 obj(vec![
                     ("digest", s(&v.digest)),
                     ("entries", num(v.entries as f64)),
                     ("created_at", num(v.created_at as f64)),
                 ]))
            })
            .collect();
        obj(vec![
            ("version", num(MANIFEST_VERSION as f64)),
            ("results", Json::Obj(results)),
            ("segments", Json::Obj(segments)),
            ("library", Json::Obj(library)),
        ])
    }

    fn parse(text: &str) -> ManifestLoad {
        let Ok(j) = Json::parse(text) else {
            return ManifestLoad::Corrupt;
        };
        let Ok(version) = j.get_f64("version") else {
            return ManifestLoad::Corrupt;
        };
        if version != MANIFEST_VERSION as f64 {
            return ManifestLoad::Future;
        }
        let mut m = Manifest::default();
        let results = j.get("results").and_then(|r| r.as_obj());
        let Ok(results) = results else {
            return ManifestLoad::Corrupt;
        };
        for (key, v) in results {
            let meta = (|| {
                Some(ResultMeta {
                    digest: v.get("digest").ok()?.as_str().ok()?
                        .to_string(),
                    edp_bits: u64::from_str_radix(
                        v.get("edp_bits").ok()?.as_str().ok()?, 16)
                        .ok()?,
                    evals: v.get_f64("evals").ok()? as u64,
                    created_at: v.get_f64("created_at").ok()? as u64,
                })
            })();
            match meta {
                Some(meta) => m.results.insert(key.clone(), meta),
                None => return ManifestLoad::Corrupt,
            };
        }
        let segments = j.get("segments").and_then(|r| r.as_obj());
        let Ok(segments) = segments else {
            return ManifestLoad::Corrupt;
        };
        for (key, v) in segments {
            let meta = (|| {
                Some(SegmentMeta {
                    digest: v.get("digest").ok()?.as_str().ok()?
                        .to_string(),
                    entries: v.get_f64("entries").ok()? as u64,
                    created_at: v.get_f64("created_at").ok()? as u64,
                })
            })();
            match meta {
                Some(meta) => m.segments.insert(key.clone(), meta),
                None => return ManifestLoad::Corrupt,
            };
        }
        // optional: pre-library manifests simply have no such section
        if let Ok(library) =
            j.get("library").and_then(|r| r.as_obj())
        {
            for (key, v) in library {
                let meta = (|| {
                    Some(SegmentMeta {
                        digest: v.get("digest").ok()?.as_str().ok()?
                            .to_string(),
                        entries: v.get_f64("entries").ok()? as u64,
                        created_at: v.get_f64("created_at").ok()?
                            as u64,
                    })
                })();
                match meta {
                    Some(meta) => m.library.insert(key.clone(), meta),
                    None => return ManifestLoad::Corrupt,
                };
            }
        }
        ManifestLoad::Ready(m)
    }
}

/// A persisted best-found search result: the exact strategy (flattened
/// tiling factors + fusion bits) plus its bit-exact scores and the
/// search effort that produced it.
#[derive(Clone, Debug, PartialEq)]
pub struct StoredResult {
    /// Tiling factors, flattened layer-major as
    /// `mappings[l].factors[d][slot]` (the eval-cache key order).
    pub factors: Vec<u64>,
    /// Fusion bit per consecutive layer edge.
    pub fuse: Vec<bool>,
    /// Energy, pJ (per replica).
    pub energy: f64,
    /// Latency, cycles (per replica).
    pub latency: f64,
    /// `energy * latency` (per replica).
    pub edp: f64,
    /// Search iterations the original run executed.
    pub iters: usize,
    /// Candidate evaluations the original run spent.
    pub evals: usize,
}

impl StoredResult {
    /// Capture a finished [`SearchResult`] for persistence.
    pub fn of(r: &SearchResult) -> StoredResult {
        let n = r.best.mappings.len() * NDIMS * NSLOTS;
        let mut factors = Vec::with_capacity(n);
        for m in &r.best.mappings {
            for d in 0..NDIMS {
                for slot in 0..NSLOTS {
                    factors.push(m.factors[d][slot]);
                }
            }
        }
        StoredResult {
            factors,
            fuse: r.best.fuse.clone(),
            energy: r.energy,
            latency: r.latency,
            edp: r.edp,
            iters: r.iters,
            evals: r.evals,
        }
    }

    /// Rebuild the strategy; `None` when the flattened shape is
    /// inconsistent (a corrupt or foreign blob).
    pub fn strategy(&self) -> Option<Strategy> {
        strategy_from_parts(&self.factors, &self.fuse)
    }

    fn to_json(&self) -> Json {
        obj(vec![
            ("kind", s("result")),
            ("energy_bits", s(&bits_hex(self.energy))),
            ("latency_bits", s(&bits_hex(self.latency))),
            ("edp_bits", s(&bits_hex(self.edp))),
            ("factors",
             arr(self.factors.iter().map(|&f| num(f as f64))
                 .collect())),
            ("fuse",
             arr(self.fuse.iter().map(|&b| Json::Bool(b)).collect())),
            ("iters", num(self.iters as f64)),
            ("evals", num(self.evals as f64)),
        ])
    }

    fn from_json(j: &Json) -> Option<StoredResult> {
        if j.get("kind").ok()?.as_str().ok()? != "result" {
            return None;
        }
        let factors = j
            .get("factors")
            .ok()?
            .as_arr()
            .ok()?
            .iter()
            .map(|v| v.as_f64().ok().map(|x| x as u64))
            .collect::<Option<Vec<u64>>>()?;
        let fuse = j
            .get("fuse")
            .ok()?
            .as_arr()
            .ok()?
            .iter()
            .map(|v| match v {
                Json::Bool(b) => Some(*b),
                _ => None,
            })
            .collect::<Option<Vec<bool>>>()?;
        Some(StoredResult {
            factors,
            fuse,
            energy: parse_bits(
                j.get("energy_bits").ok()?.as_str().ok()?)?,
            latency: parse_bits(
                j.get("latency_bits").ok()?.as_str().ok()?)?,
            edp: parse_bits(j.get("edp_bits").ok()?.as_str().ok()?)?,
            iters: j.get_f64("iters").ok()? as usize,
            evals: j.get_f64("evals").ok()? as usize,
        })
    }
}

/// One persisted eval-cache entry: flattened factors, fusion bits, and
/// the memoized [`Eval`] (the cache's own key/value pair, exported).
pub type SegmentEntry = (Vec<u64>, Vec<bool>, Eval);

/// Rebuild a [`Strategy`] from the store's flattened form. `None` when
/// the factor count is not a whole number of layers or disagrees with
/// the fusion-edge count.
pub fn strategy_from_parts(factors: &[u64], fuse: &[bool])
                           -> Option<Strategy> {
    let per_layer = NDIMS * NSLOTS;
    if factors.is_empty() || factors.len() % per_layer != 0 {
        return None;
    }
    let layers = factors.len() / per_layer;
    if layers != fuse.len() + 1 {
        return None;
    }
    let mut mappings = Vec::with_capacity(layers);
    let mut it = factors.iter();
    for _ in 0..layers {
        let mut m = LayerMapping::trivial();
        for d in 0..NDIMS {
            for slot in 0..NSLOTS {
                m.factors[d][slot] = *it.next()?;
            }
        }
        mappings.push(m);
    }
    Some(Strategy { mappings, fuse: fuse.to_vec() })
}

/// Spot-check a hydration candidate against the live cost model: up to
/// four spread-out entries are re-scored through [`compute_eval`] and
/// must reproduce their stored [`Eval`] bit-for-bit. Catches blobs from
/// a different `(workload, hardware)` content or a drifted cost model
/// without paying a full re-evaluation of the segment.
pub fn verify_segment_sample(entries: &[SegmentEntry], w: &Workload,
                             hw: &HwConfig) -> bool {
    if entries.is_empty() {
        return false;
    }
    let n = entries.len();
    let picks = [0, n / 3, (2 * n) / 3, n - 1];
    let mut checked = [usize::MAX; 4];
    for (i, &idx) in picks.iter().enumerate() {
        if checked[..i].contains(&idx) {
            continue;
        }
        checked[i] = idx;
        let (factors, fuse, stored) = &entries[idx];
        let Some(strat) = strategy_from_parts(factors, fuse) else {
            return false;
        };
        if strat.mappings.len() != w.len() {
            return false;
        }
        let got = compute_eval(&strat, w, hw);
        let same = got.energy.to_bits() == stored.energy.to_bits()
            && got.latency.to_bits() == stored.latency.to_bits()
            && got.edp.to_bits() == stored.edp.to_bits()
            && got.feasible == stored.feasible;
        if !same {
            return false;
        }
    }
    true
}

fn segment_to_json(entries: &[&SegmentEntry]) -> Json {
    let items = entries
        .iter()
        .map(|(factors, fuse, e)| {
            obj(vec![
                ("f",
                 arr(factors.iter().map(|&x| num(x as f64))
                     .collect())),
                ("u",
                 arr(fuse.iter().map(|&b| Json::Bool(b)).collect())),
                ("e", s(&bits_hex(e.energy))),
                ("l", s(&bits_hex(e.latency))),
                ("d", s(&bits_hex(e.edp))),
                ("x", Json::Bool(e.feasible)),
            ])
        })
        .collect();
    obj(vec![("kind", s("segment")), ("entries", arr(items))])
}

fn segment_from_json(j: &Json) -> Option<Vec<SegmentEntry>> {
    if j.get("kind").ok()?.as_str().ok()? != "segment" {
        return None;
    }
    j.get("entries")
        .ok()?
        .as_arr()
        .ok()?
        .iter()
        .map(|item| {
            let factors = item
                .get("f")
                .ok()?
                .as_arr()
                .ok()?
                .iter()
                .map(|v| v.as_f64().ok().map(|x| x as u64))
                .collect::<Option<Vec<u64>>>()?;
            let fuse = item
                .get("u")
                .ok()?
                .as_arr()
                .ok()?
                .iter()
                .map(|v| match v {
                    Json::Bool(b) => Some(*b),
                    _ => None,
                })
                .collect::<Option<Vec<bool>>>()?;
            let e = Eval {
                energy: parse_bits(
                    item.get("e").ok()?.as_str().ok()?)?,
                latency: parse_bits(
                    item.get("l").ok()?.as_str().ok()?)?,
                edp: parse_bits(item.get("d").ok()?.as_str().ok()?)?,
                feasible: match item.get("x").ok()? {
                    Json::Bool(b) => *b,
                    _ => return None,
                },
            };
            Some((factors, fuse, e))
        })
        .collect()
}

/// The content-addressed on-disk store (see the module docs for the
/// layout and integrity rules). All methods are `&self` and internally
/// locked; a store is shared across workers behind one `Arc`.
pub struct ResultStore {
    root: PathBuf,
    manifest: Mutex<Manifest>,
    writable: bool,
    stats: StoreStats,
    tmp_seq: AtomicU64,
    retry_seq: AtomicU64,
}

impl ResultStore {
    /// Open (or initialize) a store rooted at `dir`, creating the
    /// directory tree as needed. A manifest written by a *newer* format
    /// version loads empty and disables persistence — this build never
    /// clobbers a future format; a garbage manifest loads empty and
    /// stays writable (counted as one corrupt skip).
    pub fn open(dir: &Path) -> Result<ResultStore> {
        std::fs::create_dir_all(dir.join(BLOBS_DIR)).with_context(
            || format!("creating result store under {dir:?}"))?;
        let stats = StoreStats::default();
        let mut writable = true;
        let path = dir.join(MANIFEST_FILE);
        let manifest = match std::fs::read_to_string(&path) {
            Err(_) => Manifest::default(), // fresh (or unreadable) dir
            Ok(text) => match Manifest::parse(&text) {
                ManifestLoad::Ready(m) => m,
                ManifestLoad::Future => {
                    eprintln!(
                        "[fadiff-store] {path:?} has an unknown \
                         manifest version; serving cold with \
                         persistence disabled"
                    );
                    writable = false;
                    Manifest::default()
                }
                ManifestLoad::Corrupt => {
                    eprintln!(
                        "[fadiff-store] {path:?} is unparseable; \
                         starting an empty manifest"
                    );
                    stats.corrupt_skips.fetch_add(1, Ordering::SeqCst);
                    Manifest::default()
                }
            },
        };
        Ok(ResultStore {
            root: dir.to_path_buf(),
            manifest: Mutex::new(manifest),
            writable,
            stats,
            tmp_seq: AtomicU64::new(0),
            retry_seq: AtomicU64::new(0),
        })
    }

    /// The store's root directory.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Whether this session persists writes (false when the on-disk
    /// manifest belongs to a newer format version).
    pub fn writable(&self) -> bool {
        self.writable
    }

    /// Store counters.
    pub fn stats(&self) -> &StoreStats {
        &self.stats
    }

    /// The manifest key of a best-found result: content fingerprints
    /// of the workload and hardware plus every result-relevant request
    /// parameter (method, seed, chains, iteration cap, and the exact
    /// bits of the time budget) — same key, same deterministic search,
    /// same answer.
    pub fn result_key(workload_fp: &str, config_fp: &str,
                      req: &JobRequest) -> String {
        // `prune: "full"` changes the GA trajectory, so its results
        // live under a distinct key. The default-on and off modes are
        // bit-identical to each other by construction and share the
        // unsuffixed key (pre-prune stored results stay servable).
        let prune = match req.prune {
            crate::search::PruneMode::Full => ":pfull",
            _ => "",
        };
        format!(
            "res:{workload_fp}:{config_fp}:{}:s{}:c{}:i{}:t{}{prune}",
            req.method.name(), req.seed, req.chains, req.max_iters,
            bits_hex(req.seconds)
        )
    }

    /// The manifest key of a pair's eval-cache segment. Budget and
    /// method independent: memoized cost-model scores are pure in
    /// `(workload, hardware)` content.
    pub fn segment_key(workload_fp: &str, config_fp: &str) -> String {
        format!("seg:{workload_fp}:{config_fp}")
    }

    /// The manifest key of a hardware config's warm-start mapping
    /// library shard. Workload independent on purpose: per-layer
    /// mappings transfer across workloads that share layer shapes,
    /// which is the library's whole point.
    pub fn library_key(config_fp: &str) -> String {
        format!("lib:{config_fp}")
    }

    /// Look up a stored result. `None` (and a counted miss) when the
    /// key is absent; a present-but-corrupt blob is dropped from the
    /// manifest, counted as a corrupt skip, and reported as `None`.
    /// Callers must re-verify the returned result against the live
    /// cost model before serving it (see `execute_job_ctx`).
    pub fn load_result(&self, key: &str) -> Option<StoredResult> {
        let meta = {
            let m = self.manifest.lock().unwrap();
            match m.results.get(key) {
                Some(meta) => meta.clone(),
                None => {
                    self.stats
                        .result_misses
                        .fetch_add(1, Ordering::SeqCst);
                    return None;
                }
            }
        };
        let parsed = self
            .read_blob(&meta.digest)
            .and_then(|text| Json::parse(&text).ok())
            .and_then(|j| StoredResult::from_json(&j));
        match parsed {
            Some(sr) => Some(sr),
            None => {
                self.reject_result(key);
                None
            }
        }
    }

    /// Drop a result entry that failed digest, parse, or
    /// re-verification checks (counted as a corrupt skip). The next
    /// request for the key recomputes cold and records fresh.
    pub fn reject_result(&self, key: &str) {
        self.stats.corrupt_skips.fetch_add(1, Ordering::SeqCst);
        let mut m = self.manifest.lock().unwrap();
        if let Some(old) = m.results.remove(key) {
            self.persist_manifest(&m);
            self.gc_blob(&m, &old.digest);
        }
    }

    /// Record a best-found result under `key`. Improvement-gated:
    /// an existing entry is only replaced by a strictly better EDP, so
    /// a short rerun can never overwrite a long run's incumbent.
    /// Returns whether anything was written.
    pub fn record_result(&self, key: &str, sr: &StoredResult) -> bool {
        if !self.writable {
            return false;
        }
        let text = sr.to_json().compact();
        let digest = fnv1a64(text.as_bytes());
        let mut m = self.manifest.lock().unwrap();
        if let Some(old) = m.results.get(key) {
            if !(sr.edp < f64::from_bits(old.edp_bits)) {
                return false;
            }
        }
        if self.write_blob(&digest, &text).is_err() {
            return false;
        }
        let old = m.results.insert(key.to_string(), ResultMeta {
            digest,
            edp_bits: sr.edp.to_bits(),
            evals: sr.evals as u64,
            created_at: unix_now(),
        });
        self.persist_manifest(&m);
        if let Some(old) = old {
            self.gc_blob(&m, &old.digest);
        }
        self.stats.results_written.fetch_add(1, Ordering::SeqCst);
        true
    }

    /// Load a pair's persisted eval-cache segment. A corrupt blob is
    /// dropped (counted) and reported as `None`. Callers must
    /// [`verify_segment_sample`] before hydrating a cache from it.
    pub fn load_segment(&self, key: &str) -> Option<Vec<SegmentEntry>> {
        let meta = {
            let m = self.manifest.lock().unwrap();
            m.segments.get(key)?.clone()
        };
        let parsed = self
            .read_blob(&meta.digest)
            .and_then(|text| Json::parse(&text).ok())
            .and_then(|j| segment_from_json(&j));
        match parsed {
            Some(entries) => Some(entries),
            None => {
                self.reject_segment(key);
                None
            }
        }
    }

    /// Drop a segment entry that failed digest, parse, or sample
    /// verification (counted as a corrupt skip).
    pub fn reject_segment(&self, key: &str) {
        self.stats.corrupt_skips.fetch_add(1, Ordering::SeqCst);
        let mut m = self.manifest.lock().unwrap();
        if let Some(old) = m.segments.remove(key) {
            self.persist_manifest(&m);
            self.gc_blob(&m, &old.digest);
        }
    }

    /// Persist a pair's eval-cache entries under `key` (one flush).
    /// Entries are sorted before serialization so identical cache
    /// contents always produce the identical blob; an unchanged digest
    /// skips the write entirely. Returns whether anything was written.
    pub fn save_segment(&self, key: &str, entries: &[SegmentEntry])
                        -> bool {
        if !self.writable || entries.is_empty() {
            return false;
        }
        let mut sorted: Vec<&SegmentEntry> = entries.iter().collect();
        sorted.sort_by(|a, b| (&a.0, &a.1).cmp(&(&b.0, &b.1)));
        let text = segment_to_json(&sorted).compact();
        let digest = fnv1a64(text.as_bytes());
        let mut m = self.manifest.lock().unwrap();
        if m.segments.get(key).map(|e| e.digest == digest)
            == Some(true)
        {
            return false;
        }
        if self.write_blob(&digest, &text).is_err() {
            return false;
        }
        let old = m.segments.insert(key.to_string(), SegmentMeta {
            digest,
            entries: sorted.len() as u64,
            created_at: unix_now(),
        });
        self.persist_manifest(&m);
        if let Some(old) = old {
            self.gc_blob(&m, &old.digest);
        }
        self.stats.flushes.fetch_add(1, Ordering::SeqCst);
        true
    }

    /// Load a hardware config's mapping-library shard as parsed JSON
    /// (the [`super::library::MappingLibrary`] owns the entry format).
    /// A corrupt blob is dropped (counted) and reported as `None`.
    pub fn load_library(&self, key: &str) -> Option<Json> {
        let meta = {
            let m = self.manifest.lock().unwrap();
            m.library.get(key)?.clone()
        };
        let parsed = self
            .read_blob(&meta.digest)
            .and_then(|text| Json::parse(&text).ok());
        match parsed {
            Some(j) => Some(j),
            None => {
                self.reject_library(key);
                None
            }
        }
    }

    /// Drop a library shard that failed digest or parse checks
    /// (counted as a corrupt skip).
    pub fn reject_library(&self, key: &str) {
        self.stats.corrupt_skips.fetch_add(1, Ordering::SeqCst);
        let mut m = self.manifest.lock().unwrap();
        if let Some(old) = m.library.remove(key) {
            self.persist_manifest(&m);
            self.gc_blob(&m, &old.digest);
        }
    }

    /// Persist a mapping-library shard under `key` (one flush, same
    /// digest-dedup as [`ResultStore::save_segment`]). Returns whether
    /// anything was written.
    pub fn save_library(&self, key: &str, shard: &Json,
                        entries: u64) -> bool {
        if !self.writable {
            return false;
        }
        let text = shard.compact();
        let digest = fnv1a64(text.as_bytes());
        let mut m = self.manifest.lock().unwrap();
        if m.library.get(key).map(|e| e.digest == digest)
            == Some(true)
        {
            return false;
        }
        if self.write_blob(&digest, &text).is_err() {
            return false;
        }
        let old = m.library.insert(key.to_string(), SegmentMeta {
            digest,
            entries,
            created_at: unix_now(),
        });
        self.persist_manifest(&m);
        if let Some(old) = old {
            self.gc_blob(&m, &old.digest);
        }
        self.stats.flushes.fetch_add(1, Ordering::SeqCst);
        true
    }

    /// The `store` verb payload / the `metrics.store` block: manifest
    /// entry counts, blob usage, and every [`StoreStats`] counter.
    pub fn stats_json(&self) -> Json {
        let (blob_count, blob_bytes) = self.blob_usage();
        let (results, segments, library) = {
            let m = self.manifest.lock().unwrap();
            (m.results.len(), m.segments.len(), m.library.len())
        };
        let c = |a: &AtomicU64| num(a.load(Ordering::SeqCst) as f64);
        obj(vec![
            ("enabled", Json::Bool(true)),
            ("dir", s(&self.root.display().to_string())),
            ("writable", Json::Bool(self.writable)),
            ("manifest_results", num(results as f64)),
            ("manifest_segments", num(segments as f64)),
            ("manifest_library", num(library as f64)),
            ("blob_count", num(blob_count as f64)),
            ("blob_bytes", num(blob_bytes as f64)),
            ("result_hits", c(&self.stats.result_hits)),
            ("result_misses", c(&self.stats.result_misses)),
            ("results_written", c(&self.stats.results_written)),
            ("hydrations", c(&self.stats.hydrations)),
            ("flushes", c(&self.stats.flushes)),
            ("corrupt_skips", c(&self.stats.corrupt_skips)),
            ("io_retries", c(&self.stats.io_retries)),
            ("io_permanent", c(&self.stats.io_permanent)),
        ])
    }

    fn blob_path(&self, digest: &str) -> PathBuf {
        self.root.join(BLOBS_DIR).join(digest)
    }

    /// Run a blob I/O operation with bounded retry on *transient*
    /// failures (see [`transient_io`]): up to [`IO_ATTEMPTS`] tries
    /// with exponential backoff plus a small deterministic jitter (a
    /// hash of a process-local sequence number, so concurrent
    /// retriers spread out without consulting a clock or an RNG).
    /// Non-transient errors and exhausted retries count one
    /// [`StoreStats::io_permanent`] and return the error — the caller
    /// degrades to its existing counted miss / skip path.
    fn with_io_retry<T>(&self,
                        mut f: impl FnMut() -> std::io::Result<T>)
                        -> std::io::Result<T> {
        let mut attempt: u32 = 0;
        loop {
            match f() {
                Ok(v) => return Ok(v),
                Err(e) if transient_io(e.kind())
                    && attempt + 1 < IO_ATTEMPTS =>
                {
                    self.stats
                        .io_retries
                        .fetch_add(1, Ordering::SeqCst);
                    let base = 1u64 << attempt; // 1ms, 2ms, ...
                    let seq = self
                        .retry_seq
                        .fetch_add(1, Ordering::SeqCst);
                    let jitter = (seq
                        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                        >> 32)
                        % (base + 1);
                    std::thread::sleep(
                        std::time::Duration::from_millis(
                            base + jitter,
                        ),
                    );
                    attempt += 1;
                }
                Err(e) => {
                    self.stats
                        .io_permanent
                        .fetch_add(1, Ordering::SeqCst);
                    return Err(e);
                }
            }
        }
    }

    /// Read a blob and verify its content hashes to its name.
    fn read_blob(&self, digest: &str) -> Option<String> {
        let path = self.blob_path(digest);
        let text = self
            .with_io_retry(|| {
                if crate::util::fault::fire(
                    crate::util::fault::STORE_READ_IO,
                ) {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::Interrupted,
                        "injected: store read I/O error",
                    ));
                }
                std::fs::read_to_string(&path)
            })
            .ok()?;
        // injected corruption lands *after* the read so the digest
        // check right below catches it — exercising the same counted
        // cold-recompute degradation a real corrupt blob takes
        let text = if crate::util::fault::fire(
            crate::util::fault::STORE_CORRUPT,
        ) {
            format!("{text}<injected-corruption>")
        } else {
            text
        };
        (fnv1a64(text.as_bytes()) == digest).then_some(text)
    }

    /// Write a blob under its digest name (atomic; a blob that already
    /// exists is content-identical by construction and left alone).
    fn write_blob(&self, digest: &str, text: &str)
                  -> std::io::Result<()> {
        let path = self.blob_path(digest);
        if path.exists() {
            return Ok(());
        }
        self.write_atomic(&path, text)
    }

    /// Delete a blob no longer referenced by any manifest entry —
    /// results, eval-cache segments, AND live mapping-library shards
    /// (a library blob must never be collected out from under its
    /// manifest entry).
    fn gc_blob(&self, m: &Manifest, digest: &str) {
        let referenced = m
            .results
            .values()
            .any(|e| e.digest == digest)
            || m.segments.values().any(|e| e.digest == digest)
            || m.library.values().any(|e| e.digest == digest);
        if !referenced {
            let _ = std::fs::remove_file(self.blob_path(digest));
        }
    }

    /// Serialize the manifest to disk (atomic). IO failure degrades to
    /// an in-memory-only manifest for this write, with a warning — the
    /// on-disk file keeps its previous consistent content.
    fn persist_manifest(&self, m: &Manifest) {
        if !self.writable {
            return;
        }
        let text = m.to_json().pretty();
        let path = self.root.join(MANIFEST_FILE);
        if let Err(e) = self.write_atomic(&path, &text) {
            eprintln!(
                "[fadiff-store] failed to persist {path:?}: {e}"
            );
        }
    }

    /// Write-temp + rename: the final name only ever holds complete
    /// content. The temp name embeds pid + a sequence number so
    /// concurrent writers (threads or processes) never collide.
    /// Transient failures retry with backoff (each attempt uses a
    /// fresh temp name); definitive failure surfaces to the caller,
    /// which keeps the previous consistent on-disk content.
    fn write_atomic(&self, path: &Path, content: &str)
                    -> std::io::Result<()> {
        self.with_io_retry(|| {
            if crate::util::fault::fire(
                crate::util::fault::STORE_WRITE_IO,
            ) {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::TimedOut,
                    "injected: store write I/O error",
                ));
            }
            let seq = self.tmp_seq.fetch_add(1, Ordering::SeqCst);
            let mut tmp = path.as_os_str().to_owned();
            tmp.push(format!(".tmp.{}.{seq}", std::process::id()));
            let tmp = PathBuf::from(tmp);
            std::fs::write(&tmp, content)?;
            match std::fs::rename(&tmp, path) {
                Ok(()) => Ok(()),
                Err(e) => {
                    let _ = std::fs::remove_file(&tmp);
                    Err(e)
                }
            }
        })
    }

    fn blob_usage(&self) -> (u64, u64) {
        let mut count = 0u64;
        let mut bytes = 0u64;
        if let Ok(rd) = std::fs::read_dir(self.root.join(BLOBS_DIR)) {
            for entry in rd.flatten() {
                if let Ok(md) = entry.metadata() {
                    if md.is_file() {
                        count += 1;
                        bytes += md.len();
                    }
                }
            }
        }
        (count, bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::zoo;

    fn tmp_store_dir(tag: &str) -> PathBuf {
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos();
        let dir = std::env::temp_dir().join(format!(
            "fadiff-store-unit-{tag}-{}-{nanos}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn sample_result(edp: f64) -> StoredResult {
        StoredResult {
            factors: vec![1; NDIMS * NSLOTS * 2],
            fuse: vec![true],
            energy: edp / 2.0,
            latency: 2.0,
            edp,
            iters: 7,
            evals: 11,
        }
    }

    #[test]
    fn fnv1a64_matches_reference_vectors() {
        // same construction as spec::fingerprint; empty input yields
        // the FNV-1a offset basis
        assert_eq!(fnv1a64(b""), "cbf29ce484222325");
        assert_eq!(fnv1a64(b"a"), "af63dc4c8601ec8c");
    }

    #[test]
    fn bits_roundtrip_is_exact_for_odd_floats() {
        for x in [0.0, -0.0, 1.5e301, f64::INFINITY, 3.1e-17] {
            let back = parse_bits(&bits_hex(x)).unwrap();
            assert_eq!(back.to_bits(), x.to_bits());
        }
        assert!(parse_bits("ff").is_none(), "length-checked");
        assert!(parse_bits("zz0000000000000f").is_none());
    }

    #[test]
    fn result_roundtrips_bit_exact_through_disk() {
        let dir = tmp_store_dir("roundtrip");
        let store = ResultStore::open(&dir).unwrap();
        let sr = sample_result(3.25e9);
        assert!(store.record_result("res:k", &sr));
        drop(store);
        let store = ResultStore::open(&dir).unwrap();
        let back = store.load_result("res:k").unwrap();
        assert_eq!(back, sr);
        assert_eq!(back.edp.to_bits(), sr.edp.to_bits());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn improvement_gate_keeps_the_better_incumbent() {
        let dir = tmp_store_dir("gate");
        let store = ResultStore::open(&dir).unwrap();
        assert!(store.record_result("k", &sample_result(10.0)));
        // equal and worse EDPs are refused
        assert!(!store.record_result("k", &sample_result(10.0)));
        assert!(!store.record_result("k", &sample_result(11.0)));
        assert!(store.record_result("k", &sample_result(9.0)));
        let back = store.load_result("k").unwrap();
        assert_eq!(back.edp, 9.0);
        assert_eq!(
            store.stats.results_written.load(Ordering::SeqCst), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_blob_degrades_to_counted_cold_miss() {
        let dir = tmp_store_dir("corrupt");
        let store = ResultStore::open(&dir).unwrap();
        let sr = sample_result(5.0);
        assert!(store.record_result("k", &sr));
        let digest = {
            let m = store.manifest.lock().unwrap();
            m.results.get("k").unwrap().digest.clone()
        };
        std::fs::write(store.blob_path(&digest), "truncated garb")
            .unwrap();
        assert!(store.load_result("k").is_none());
        assert_eq!(
            store.stats.corrupt_skips.load(Ordering::SeqCst), 1);
        // the entry was dropped: next lookup is a plain miss and a
        // fresh record repopulates it
        assert!(store.load_result("k").is_none());
        assert_eq!(
            store.stats.result_misses.load(Ordering::SeqCst), 1);
        assert!(store.record_result("k", &sr));
        assert_eq!(store.load_result("k").unwrap(), sr);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn future_manifest_version_disables_persistence_untouched() {
        let dir = tmp_store_dir("future");
        let future = "{\"version\": 2, \"from\": \"the future\"}";
        std::fs::write(dir.join(MANIFEST_FILE), future).unwrap();
        let store = ResultStore::open(&dir).unwrap();
        assert!(!store.writable());
        assert!(!store.record_result("k", &sample_result(1.0)));
        assert!(store.load_result("k").is_none());
        drop(store);
        let kept =
            std::fs::read_to_string(dir.join(MANIFEST_FILE)).unwrap();
        assert_eq!(kept, future, "future manifest must not be touched");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn garbage_manifest_starts_empty_but_writable() {
        let dir = tmp_store_dir("garbage");
        std::fs::write(dir.join(MANIFEST_FILE), "not json {{{")
            .unwrap();
        let store = ResultStore::open(&dir).unwrap();
        assert!(store.writable());
        assert_eq!(
            store.stats.corrupt_skips.load(Ordering::SeqCst), 1);
        assert!(store.record_result("k", &sample_result(1.0)));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn segment_roundtrip_is_order_independent_and_verifiable() {
        let dir = tmp_store_dir("segment");
        let store = ResultStore::open(&dir).unwrap();
        let w = zoo::by_name("mobilenet").unwrap();
        let hw = crate::config::load_config(
            &crate::config::repo_root(), "large").unwrap();
        let strat = Strategy::trivial(&w);
        let e = compute_eval(&strat, &w, &hw);
        let sr = StoredResult::of(&SearchResult {
            best: strat, edp: e.edp, energy: e.energy,
            latency: e.latency, trace: Vec::new(), iters: 0, evals: 1,
        });
        let entry: SegmentEntry =
            (sr.factors.clone(), sr.fuse.clone(), e);
        let key = ResultStore::segment_key("wfp", "cfp");
        assert!(store.save_segment(&key, &[entry.clone()]));
        // identical content, different call: digest-deduped, no flush
        assert!(!store.save_segment(&key, &[entry]));
        assert_eq!(store.stats.flushes.load(Ordering::SeqCst), 1);
        let back = store.load_segment(&key).unwrap();
        assert_eq!(back.len(), 1);
        assert!(verify_segment_sample(&back, &w, &hw));
        // a wrong-content segment fails sample verification
        let mut wrong = back.clone();
        wrong[0].2.energy += 1.0;
        assert!(!verify_segment_sample(&wrong, &w, &hw));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn transient_io_recovers_within_the_retry_budget() {
        let dir = tmp_store_dir("retry-ok");
        let store = ResultStore::open(&dir).unwrap();
        let mut failures_left = 2u32; // IO_ATTEMPTS - 1: recoverable
        let got = store.with_io_retry(|| {
            if failures_left > 0 {
                failures_left -= 1;
                return Err(std::io::Error::new(
                    std::io::ErrorKind::Interrupted,
                    "flaky",
                ));
            }
            Ok(42)
        });
        assert_eq!(got.unwrap(), 42);
        assert_eq!(store.stats.io_retries.load(Ordering::SeqCst), 2);
        assert_eq!(store.stats.io_permanent.load(Ordering::SeqCst), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn permanent_io_fails_immediately_without_retries() {
        let dir = tmp_store_dir("retry-perm");
        let store = ResultStore::open(&dir).unwrap();
        let got: std::io::Result<()> = store.with_io_retry(|| {
            Err(std::io::Error::new(
                std::io::ErrorKind::NotFound,
                "gone",
            ))
        });
        assert!(got.is_err());
        assert_eq!(store.stats.io_retries.load(Ordering::SeqCst), 0,
                   "NotFound is not transient — no retry");
        assert_eq!(store.stats.io_permanent.load(Ordering::SeqCst), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn exhausted_retries_count_one_permanent_failure() {
        let dir = tmp_store_dir("retry-exhaust");
        let store = ResultStore::open(&dir).unwrap();
        let mut calls = 0u32;
        let got: std::io::Result<()> = store.with_io_retry(|| {
            calls += 1;
            Err(std::io::Error::new(
                std::io::ErrorKind::TimedOut,
                "still timing out",
            ))
        });
        assert!(got.is_err());
        assert_eq!(calls, IO_ATTEMPTS);
        assert_eq!(store.stats.io_retries.load(Ordering::SeqCst),
                   (IO_ATTEMPTS - 1) as u64);
        assert_eq!(store.stats.io_permanent.load(Ordering::SeqCst), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn library_shard_roundtrips_and_blob_survives_churn() {
        let dir = tmp_store_dir("library");
        let store = ResultStore::open(&dir).unwrap();
        let key = ResultStore::library_key("cfp");
        let shard = obj(vec![("kind", s("library")), ("x", num(1.0))]);
        assert!(store.save_library(&key, &shard, 1));
        // identical content: digest-deduped, no second flush
        assert!(!store.save_library(&key, &shard, 1));
        assert_eq!(store.stats.flushes.load(Ordering::SeqCst), 1);
        // unrelated result churn (insert + reject runs the gc) must
        // never collect a live library blob
        assert!(store.record_result("res", &sample_result(5.0)));
        store.reject_result("res");
        drop(store);
        let store = ResultStore::open(&dir).unwrap();
        let back = store.load_library(&key).unwrap();
        assert_eq!(back.get_f64("x").unwrap(), 1.0);
        // replacing the shard collects the superseded blob only
        let shard2 =
            obj(vec![("kind", s("library")), ("x", num(2.0))]);
        assert!(store.save_library(&key, &shard2, 1));
        let (blob_count, _) = store.blob_usage();
        assert_eq!(blob_count, 1, "old shard blob collected");
        assert_eq!(store.load_library(&key).unwrap()
                       .get_f64("x").unwrap(), 2.0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_library_blob_degrades_to_counted_skip() {
        let dir = tmp_store_dir("library-corrupt");
        let store = ResultStore::open(&dir).unwrap();
        let key = ResultStore::library_key("cfp");
        let shard = obj(vec![("kind", s("library"))]);
        assert!(store.save_library(&key, &shard, 0));
        let digest = {
            let m = store.manifest.lock().unwrap();
            m.library.get(&key).unwrap().digest.clone()
        };
        std::fs::write(store.blob_path(&digest), "garbage").unwrap();
        assert!(store.load_library(&key).is_none());
        assert_eq!(
            store.stats.corrupt_skips.load(Ordering::SeqCst), 1);
        // the entry was dropped; a fresh save repopulates it
        assert!(store.save_library(&key, &shard, 0));
        assert!(store.load_library(&key).is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn pre_library_manifest_loads_with_empty_library() {
        let dir = tmp_store_dir("library-compat");
        let old = "{\"version\": 1, \"results\": {}, \
                    \"segments\": {}}";
        std::fs::write(dir.join(MANIFEST_FILE), old).unwrap();
        let store = ResultStore::open(&dir).unwrap();
        assert!(store.writable(), "old manifests are not corrupt");
        assert!(store.load_library("lib:any").is_none());
        assert_eq!(
            store.stats.corrupt_skips.load(Ordering::SeqCst), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn strategy_from_parts_rejects_inconsistent_shapes() {
        let per = NDIMS * NSLOTS;
        assert!(strategy_from_parts(&[], &[]).is_none());
        assert!(strategy_from_parts(&vec![1; per - 1], &[]).is_none());
        assert!(
            strategy_from_parts(&vec![1; per], &[true]).is_none(),
            "one layer cannot have a fusion edge"
        );
        let s =
            strategy_from_parts(&vec![1; 2 * per], &[true]).unwrap();
        assert_eq!(s.mappings.len(), 2);
        assert_eq!(s.fuse, vec![true]);
    }
}
