//! TCP front-end for the coordinator: a line-delimited JSON protocol.
//!
//! Request (one line):
//!   {"verb": "optimize", "workload": "resnet18", "config": "large",
//!    "method": "fadiff", "seconds": 5, "seed": 1}
//!   {"verb": "metrics"}
//!   {"verb": "ping"}
//!   {"verb": "shutdown"}
//!
//! Response (one line): {"ok": true, ...} or {"ok": false, "error": "..."}.
//! Each connection may send any number of requests; the server handles
//! connections on acceptor-spawned threads and forwards jobs to the
//! coordinator queue.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::Arc;

use anyhow::Result;

use crate::util::json::{num, obj, s as js, Json};

use super::{Coordinator, JobRequest, JobResult, Method, ShutdownFlag};

/// Parse one request line into a JobRequest (for the `optimize` verb).
pub fn parse_request(j: &Json) -> Result<JobRequest> {
    let mut req = JobRequest::default();
    if let Ok(w) = j.get("workload") {
        req.workload = w.as_str()?.to_string();
    }
    if let Ok(c) = j.get("config") {
        req.config = c.as_str()?.to_string();
    }
    if let Ok(m) = j.get("method") {
        req.method = Method::parse(m.as_str()?)?;
    }
    if let Ok(t) = j.get("seconds") {
        req.seconds = t.as_f64()?;
    }
    if let Ok(i) = j.get("max_iters") {
        req.max_iters = i.as_usize()?;
    }
    if let Ok(sd) = j.get("seed") {
        req.seed = sd.as_f64()? as u64;
    }
    Ok(req)
}

/// Serialize a JobResult for the wire.
pub fn result_to_json(r: &JobResult) -> Json {
    obj(vec![
        ("ok", Json::Bool(true)),
        ("workload", js(&r.request.workload)),
        ("config", js(&r.request.config)),
        ("method", js(r.request.method.name())),
        ("edp", num(r.edp)),
        ("full_model_edp", num(r.full_model_edp)),
        ("energy_pj", num(r.energy)),
        ("latency_cycles", num(r.latency)),
        ("fused_groups",
         Json::Arr(r.fused_names
             .iter()
             .map(|g| Json::Arr(g.iter().map(|n| js(n)).collect()))
             .collect())),
        ("iters", num(r.iters as f64)),
        ("evals", num(r.evals as f64)),
        ("wall_seconds", num(r.wall_seconds)),
    ])
}

fn error_json(msg: &str) -> Json {
    obj(vec![("ok", Json::Bool(false)), ("error", js(msg))])
}

/// Handle one client connection.
fn handle(stream: TcpStream, coord: &Coordinator, shutdown: &ShutdownFlag)
          -> Result<()> {
    let peer = stream.peer_addr()?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut stream = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client closed
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let response = match Json::parse(trimmed) {
            Err(e) => error_json(&format!("bad json: {e}")),
            Ok(j) => {
                let verb = j
                    .get("verb")
                    .and_then(|v| Ok(v.as_str()?.to_string()))
                    .unwrap_or_else(|_| "optimize".to_string());
                match verb.as_str() {
                    "ping" => obj(vec![("ok", Json::Bool(true)),
                                       ("pong", Json::Bool(true))]),
                    "metrics" => {
                        let mut m = coord.metrics.to_json();
                        if let Json::Obj(map) = &mut m {
                            map.insert("ok".into(), Json::Bool(true));
                        }
                        m
                    }
                    "shutdown" => {
                        shutdown.0.store(true, Ordering::SeqCst);
                        obj(vec![("ok", Json::Bool(true)),
                                 ("shutting_down", Json::Bool(true))])
                    }
                    "optimize" => match parse_request(&j) {
                        Err(e) => error_json(&e.to_string()),
                        Ok(req) => match coord.run(req) {
                            Ok(r) => result_to_json(&r),
                            Err(e) => error_json(&e.to_string()),
                        },
                    },
                    other => error_json(&format!("unknown verb {other:?}")),
                }
            }
        };
        let mut text = String::new();
        // compact single-line output: strip pretty newlines
        for ch in response.pretty().chars() {
            if ch != '\n' {
                text.push(ch);
            }
        }
        text.push('\n');
        stream.write_all(text.as_bytes())?;
        stream.flush()?;
        if shutdown.0.load(Ordering::SeqCst) {
            log_line(&format!("shutdown requested by {peer}"));
            return Ok(());
        }
    }
}

fn log_line(msg: &str) {
    eprintln!("[fadiff-serve] {msg}");
}

/// Run the server until a `shutdown` verb arrives. Returns the bound
/// address (useful with port 0 in tests via `bind_and_serve`).
pub fn serve(addr: &str, coord: Coordinator) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    serve_on(listener, coord)
}

/// Serve on an already-bound listener (lets tests pick port 0).
pub fn serve_on(listener: TcpListener, coord: Coordinator) -> Result<()> {
    let local = listener.local_addr()?;
    log_line(&format!("listening on {local} with {} workers",
                      coord.n_workers()));
    let coord = Arc::new(coord);
    let shutdown = ShutdownFlag::default();
    listener.set_nonblocking(true)?;
    let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
    loop {
        if shutdown.0.load(Ordering::SeqCst) {
            break;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                stream.set_nonblocking(false)?;
                let coord = Arc::clone(&coord);
                let flag = ShutdownFlag(Arc::clone(&shutdown.0));
                conns.push(std::thread::spawn(move || {
                    if let Err(e) = handle(stream, &coord, &flag) {
                        log_line(&format!("connection error: {e}"));
                    }
                }));
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(20));
            }
            Err(e) => return Err(e.into()),
        }
        conns.retain(|c| !c.is_finished());
    }
    for c in conns {
        let _ = c.join();
    }
    log_line("server stopped");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_request_defaults_and_overrides() {
        let j = Json::parse(
            r#"{"workload": "vgg16", "method": "ga", "seconds": 2.5}"#)
            .unwrap();
        let r = parse_request(&j).unwrap();
        assert_eq!(r.workload, "vgg16");
        assert_eq!(r.method, Method::Ga);
        assert_eq!(r.seconds, 2.5);
        assert_eq!(r.config, "large"); // default
    }

    #[test]
    fn parse_request_rejects_bad_method() {
        let j = Json::parse(r#"{"method": "quantum"}"#).unwrap();
        assert!(parse_request(&j).is_err());
    }
}
