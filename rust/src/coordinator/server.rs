//! TCP front-end for the coordinator: a line-delimited JSON protocol
//! (protocol version 1) served by a single-threaded event loop.
//!
//! The complete wire reference (every verb, parameter, limit, error
//! code and the streaming `watch` mode, with example request/response
//! lines) lives in `docs/protocol.md`; the short form:
//!
//!   {"verb": "optimize", "workload": "resnet18", "config": "large",
//!    "method": "fadiff", "seconds": 5, "seed": 1, "chains": 8}
//!   {"verb": "sweep", "workloads": ["resnet18", "vgg16"],
//!    "methods": ["ga", "random"], "seeds": [1, 2], "seconds": 5}
//!   {"verb": "gap", "workload": "micro-mlp", "seconds": 5}
//!                    (exact oracle vs every baseline, measured gaps)
//!   {"verb": "submit", "workload": "gpt3", "method": "ga",
//!    "seconds": 120}
//!   {"verb": "status", "job_id": 7}
//!   {"verb": "status", "job_id": 7, "watch": true}   (event stream)
//!   {"verb": "cancel", "job_id": 7}
//!   {"verb": "workloads"}                       (list the zoo + specs)
//!   {"verb": "workloads", "describe": "vgg16"}  (full description)
//!   {"verb": "metrics"}
//!   {"verb": "ping"}
//!   {"verb": "chaos", "action": "arm", "site": "eval.slow", ...}
//!   {"verb": "shutdown"}
//!
//! Job-submitting verbs accept `deadline_ms`: a cooperative per-job
//! execution deadline. An expired job ends with the stable
//! `deadline_exceeded` code/status, keeping its best-so-far (like
//! `cancel`). The `chaos` verb inspects and — in builds with the
//! `fault-injection` feature — arms the deterministic fault-injection
//! registry ([`crate::util::fault`]).
//!
//! # Response envelope (v1)
//!
//! Every response is exactly one of two shapes, serialized with
//! [`Json::compact`] so payload content can never break the framing:
//!
//!   {"protocol": 1, "ok": { ...verb payload... }}
//!   {"protocol": 1, "error": {"code": "<stable_code>",
//!                             "message": "human text", ...context}}
//!
//! `code` is a stable snake_case identifier (see [`ErrorCode`]) meant
//! for programmatic dispatch; `message` is human-prose and may change
//! between releases. Requests may pin the protocol with `"v": 1`; a
//! version this server does not speak answers `unsupported_version`.
//!
//! # Event loop
//!
//! The server runs one nonblocking accept/read/poll loop instead of a
//! thread per connection: reads and writes never block, long verbs
//! (`optimize`, `sweep`, `status` watch streams) park their connection
//! in a pending state that is polled cooperatively each tick, and the
//! coordinator's workers do the actual optimization. A bounded job
//! queue backpressures floods: past [`super::Coordinator::queue_capacity`]
//! queued jobs, job-submitting verbs answer `queue_full` with a
//! `retry_after_ms` hint instead of queueing unboundedly.
//!
//! `optimize` holds the requesting connection until its job finishes;
//! `submit` returns a job id immediately for long jobs (poll with
//! `status`, stream with `status {"watch": true}`, stop with
//! `cancel`). `sweep` fans a method x workload x seed grid through the
//! queue and aggregates every outcome in one response. All jobs share
//! the coordinator's cross-job evaluation caches, persistent pool and
//! fleet scheduler, so repeated and concurrent work is served warm.
//!
//! Robustness: requests are size-capped (oversized lines are answered
//! with a `too_large` error and drained), depth-capped (see
//! [`crate::util::json::MAX_PARSE_DEPTH`]), tolerated when malformed
//! or truncated (one-line `bad_request`, connection stays usable), and
//! the loop polls the shutdown flag so `serve_on` always terminates.

use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::experiments::gap::GapReport;
use crate::search::PruneMode;
use crate::util::json::{arr, num, obj, s as js, Json};
use crate::util::threadpool::{OneShot, Poll};
use crate::workload::spec;

use super::{resolve_workload, workload_catalog, Coordinator, JobRequest,
            JobResult, JobStatus, Method, ShutdownFlag};

/// The wire-protocol version this server speaks; every response
/// carries it as `"protocol"`, and requests may pin it with `"v"`.
pub const PROTOCOL_VERSION: u64 = 1;

/// Requests larger than this (one line, bytes) are rejected without
/// buffering the excess.
pub const MAX_REQUEST_BYTES: usize = 1 << 20;

/// Upper bound on the method x workload x seed grid of one `sweep`.
pub const MAX_SWEEP_JOBS: usize = 256;

/// Upper bound on the per-request parallel chain count: each chain
/// allocates ~100 KB of SoA state on a large workload, so an
/// unclamped value would let one request OOM the server.
pub const MAX_CHAINS: usize = 256;

/// Upper bound on concurrently served connections; accepts past it
/// are answered with one `queue_full` line and closed.
const MAX_CONNS: usize = 1024;

/// Event-loop sleep when a full tick found no work.
const IDLE_SLEEP: Duration = Duration::from_millis(2);

/// Minimum spacing of `progress` events on one watch stream (status
/// changes and the terminal event are never rate-limited).
const WATCH_PROGRESS_EVERY: Duration = Duration::from_millis(25);

/// Every verb this server answers, sorted (the `unknown_verb` error
/// lists these so clients can discover the surface).
pub const SUPPORTED_VERBS: [&str; 12] = [
    "cancel", "chaos", "gap", "metrics", "optimize", "ping",
    "shutdown", "status", "store", "submit", "sweep", "workloads",
];

// ---------------------------------------------------------------------
// error codes + the single response constructor
// ---------------------------------------------------------------------

/// Stable machine-readable error identifiers (the `code` field of
/// every error envelope). Strings are part of the wire contract:
/// never renumber or rename, only append.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorCode {
    /// Malformed JSON, wrong field types, unknown methods, bad ids.
    BadRequest,
    /// The `verb` is not one of [`SUPPORTED_VERBS`].
    UnknownVerb,
    /// `workload` names neither a zoo model nor a spec file.
    UnknownWorkload,
    /// An inline `workload_spec` failed validation.
    SpecInvalid,
    /// A size cap was exceeded (request line, spec bytes, sweep grid,
    /// chains).
    TooLarge,
    /// The bounded job queue is full; retry after `retry_after_ms`.
    QueueFull,
    /// `job_id` was never issued or has been pruned.
    JobNotFound,
    /// The server is draining after a `shutdown` verb.
    ShuttingDown,
    /// The request pinned a protocol version this server lacks.
    UnsupportedVersion,
    /// The job was cancelled (via the `cancel` verb).
    Cancelled,
    /// The job or server failed internally; `message` has the cause.
    Internal,
    /// The job's cooperative `deadline_ms` expired; the error carries
    /// the best-so-far under `result`.
    DeadlineExceeded,
}

impl ErrorCode {
    /// The stable snake_case wire name.
    pub fn as_str(&self) -> &'static str {
        match self {
            ErrorCode::BadRequest => "bad_request",
            ErrorCode::UnknownVerb => "unknown_verb",
            ErrorCode::UnknownWorkload => "unknown_workload",
            ErrorCode::SpecInvalid => "spec_invalid",
            ErrorCode::TooLarge => "too_large",
            ErrorCode::QueueFull => "queue_full",
            ErrorCode::JobNotFound => "job_not_found",
            ErrorCode::ShuttingDown => "shutting_down",
            ErrorCode::UnsupportedVersion => "unsupported_version",
            ErrorCode::Cancelled => "cancelled",
            ErrorCode::Internal => "internal",
            ErrorCode::DeadlineExceeded => "deadline_exceeded",
        }
    }
}

/// A protocol-level error: stable code + human message + optional
/// extra context fields that land next to them in the envelope.
#[derive(Debug)]
pub struct WireError {
    /// Machine-readable identifier.
    pub code: ErrorCode,
    /// Human-readable cause (free to change between releases).
    pub message: String,
    /// Extra context fields (e.g. `retry_after_ms`, `supported`).
    pub extra: Vec<(&'static str, Json)>,
}

impl WireError {
    /// A bare code + message error.
    pub fn new(code: ErrorCode, message: impl Into<String>) -> WireError {
        WireError { code, message: message.into(), extra: Vec::new() }
    }

    /// Attach one extra context field.
    pub fn with(mut self, key: &'static str, value: Json) -> WireError {
        self.extra.push((key, value));
        self
    }

    fn bad(message: impl Into<String>) -> WireError {
        WireError::new(ErrorCode::BadRequest, message)
    }

    /// The `{"code": ..., "message": ..., ...extras}` body this error
    /// serializes to — the one place that layout exists, shared by the
    /// top-level error envelope ([`Response::err`]) and the per-cell
    /// error entries of a `sweep` response.
    pub fn body(&self) -> Json {
        let mut fields = vec![
            ("code", js(self.code.as_str())),
            ("message", js(&self.message)),
        ];
        for (k, v) in &self.extra {
            fields.push((k, v.clone()));
        }
        obj(fields)
    }
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.message)
    }
}

/// The single constructor of every wire response: both envelope shapes
/// come from here, so no verb can drift off-protocol.
pub struct Response;

impl Response {
    /// `{"protocol": 1, "ok": <payload>}`
    pub fn ok(payload: Json) -> Json {
        obj(vec![
            ("protocol", num(PROTOCOL_VERSION as f64)),
            ("ok", payload),
        ])
    }

    /// `{"protocol": 1, "error": {"code": ..., "message": ..., ...}}`
    pub fn err(e: &WireError) -> Json {
        obj(vec![
            ("protocol", num(PROTOCOL_VERSION as f64)),
            ("error", e.body()),
        ])
    }
}

type WireResult<T> = std::result::Result<T, WireError>;

fn field<T>(r: Result<T>) -> WireResult<T> {
    r.map_err(|e| WireError::bad(e.to_string()))
}

/// Classify an inline-spec failure: size caps are `too_large`,
/// everything else is `spec_invalid`.
///
/// Both caps are matched on *sentinel-anchored* shapes — the byte cap
/// by its fixed head, the layer cap by a digit head plus the exact cap
/// tail — never by substring search over the whole message. Spec
/// messages embed user-controlled text (layer names render `{:?}`-
/// quoted, so a name can never terminate the message unquoted), and a
/// crafted name containing "exceeds the cap" must stay `spec_invalid`.
fn spec_error(e: anyhow::Error) -> WireError {
    let msg = e.to_string();
    // the byte cap fires before spec parsing, so its head is fixed:
    // "workload_spec of <n> bytes exceeds the cap of <cap>"
    let byte_cap = msg.starts_with("workload_spec of ");
    // the layer cap is wrapped by parse_inline:
    // "workload_spec: <n> layers exceed the cap of <cap>"
    let layer_cap = msg
        .strip_prefix("workload_spec: ")
        .is_some_and(|inner| {
            inner.as_bytes().first().is_some_and(|b| {
                b.is_ascii_digit()
            }) && inner.ends_with(&format!(
                "layers exceed the cap of {}",
                spec::MAX_SPEC_LAYERS
            ))
        });
    let code = if byte_cap || layer_cap {
        ErrorCode::TooLarge
    } else {
        ErrorCode::SpecInvalid
    };
    WireError::new(code, msg)
}

/// Classify a job-outcome error string for `optimize` replies.
///
/// Matches are anchored to the non-user-controlled head of each
/// message: cancellation is the exact literal the job layer produces,
/// and the unknown-workload head ends at the opening quote of the
/// `{:?}`-rendered name — a workload *named* "job cancelled" reports
/// `unknown_workload`, and a crafted message embedding either phrase
/// deeper in user text stays `internal`.
fn job_error(msg: &str) -> WireError {
    let code = if msg == "job cancelled" {
        ErrorCode::Cancelled
    } else if msg.starts_with("unknown workload ") {
        ErrorCode::UnknownWorkload
    } else {
        ErrorCode::Internal
    };
    WireError::new(code, msg)
}

// ---------------------------------------------------------------------
// request parsing
// ---------------------------------------------------------------------

/// Parse one request line into a JobRequest (for the `optimize` /
/// `submit` verbs; also supplies the per-job defaults of `sweep`).
pub fn parse_request(j: &Json) -> WireResult<JobRequest> {
    let mut req = JobRequest::default();
    if let Ok(w) = j.get("workload") {
        req.workload = field(w.as_str())?.to_string();
    }
    if let Ok(c) = j.get("config") {
        req.config = field(c.as_str())?.to_string();
    }
    if let Ok(m) = j.get("method") {
        req.method = field(Method::parse(field(m.as_str())?))?;
    }
    if let Ok(t) = j.get("seconds") {
        req.seconds = field(t.as_f64())?;
    }
    if let Ok(i) = j.get("max_iters") {
        req.max_iters = field(i.as_usize())?;
    }
    if let Ok(sd) = j.get("seed") {
        req.seed = field(sd.as_f64())? as u64;
    }
    if let Ok(c) = j.get("chains") {
        req.chains = field(c.as_usize())?;
        if req.chains > MAX_CHAINS {
            return Err(WireError::new(
                ErrorCode::TooLarge,
                format!("chains {} exceeds the cap of {MAX_CHAINS}",
                        req.chains),
            ));
        }
    }
    if let Ok(d) = j.get("deadline_ms") {
        let x = field(d.as_f64())?;
        // same integer-representability bound as job ids: a deadline
        // a client could not have meant exactly is a bad request
        if !(x.is_finite()
            && x >= 0.0
            && x.fract() == 0.0
            && x <= 9_007_199_254_740_992.0)
        {
            return Err(WireError::bad(
                "deadline_ms must be a non-negative integer",
            ));
        }
        req.deadline_ms = x as u64;
    }
    if let Ok(spec_j) = j.get("workload_spec") {
        // size-capped and fully validated at parse time, like `chains`:
        // a bad spec is a one-line error before any job is queued
        let w = spec::parse_inline(spec_j).map_err(spec_error)?;
        req.workload = w.name.clone();
        req.spec = Some(Arc::new(w));
    }
    if let Ok(f) = j.get("force") {
        match f {
            Json::Bool(b) => req.force = *b,
            _ => {
                return Err(WireError::bad("force must be a boolean"))
            }
        }
    }
    if let Ok(p) = j.get("prune") {
        req.prune = PruneMode::parse(field(p.as_str())?)
            .ok_or_else(|| {
                WireError::bad(
                    "prune must be \"on\", \"off\", or \"full\"",
                )
            })?;
    }
    if let Ok(wf) = j.get("warm_frac") {
        let x = field(wf.as_f64())?;
        if !(x.is_finite() && (0.0..=1.0).contains(&x)) {
            return Err(WireError::bad(
                "warm_frac must be a number in [0, 1]",
            ));
        }
        req.warm_frac = x;
    }
    Ok(req)
}

fn parse_str_list(j: &Json, key: &str, default: &str)
                  -> WireResult<Vec<String>> {
    match j.get(key) {
        Err(_) => Ok(vec![default.to_string()]),
        Ok(v) => field(v.as_arr())?
            .iter()
            .map(|x| Ok(field(x.as_str())?.to_string()))
            .collect(),
    }
}

/// Expand a `sweep` request into its method x workload x seed grid.
/// Scalar fields (`config`, `seconds`, `max_iters`, and the singular
/// `workload`/`method`/`seed`) provide the shared defaults.
pub fn parse_sweep(j: &Json) -> WireResult<Vec<JobRequest>> {
    let base = parse_request(j)?;
    if base.spec.is_some() && j.get("workloads").is_ok() {
        return Err(WireError::bad(
            "a sweep takes either an inline workload_spec (applied \
             to every cell) or a workloads list, not both",
        ));
    }
    let workloads = parse_str_list(j, "workloads", &base.workload)?;
    let methods: Vec<Method> = match j.get("methods") {
        Err(_) => vec![base.method],
        Ok(v) => field(v.as_arr())?
            .iter()
            .map(|x| field(Method::parse(field(x.as_str())?)))
            .collect::<WireResult<_>>()?,
    };
    let seeds: Vec<u64> = match j.get("seeds") {
        Err(_) => vec![base.seed],
        Ok(v) => field(v.as_arr())?
            .iter()
            .map(|x| Ok(field(x.as_f64())? as u64))
            .collect::<WireResult<_>>()?,
    };
    let grid = (workloads.len() as u128)
        .saturating_mul(methods.len() as u128)
        .saturating_mul(seeds.len() as u128);
    if grid == 0 {
        return Err(WireError::bad(
            "empty sweep grid (workloads/methods/seeds)",
        ));
    }
    if grid > MAX_SWEEP_JOBS as u128 {
        return Err(WireError::new(
            ErrorCode::TooLarge,
            format!("sweep grid of {grid} jobs exceeds the cap of \
                     {MAX_SWEEP_JOBS}"),
        ));
    }
    let mut reqs = Vec::with_capacity(grid as usize);
    for w in &workloads {
        for m in &methods {
            for &seed in &seeds {
                reqs.push(JobRequest {
                    workload: w.clone(),
                    config: base.config.clone(),
                    method: *m,
                    seconds: base.seconds,
                    max_iters: base.max_iters,
                    seed,
                    chains: base.chains,
                    deadline_ms: base.deadline_ms,
                    spec: base.spec.clone(),
                    force: base.force,
                    prune: base.prune,
                    warm_frac: base.warm_frac,
                });
            }
        }
    }
    Ok(reqs)
}

/// Expand a `gap` request into its job list: the exact oracle first,
/// then each baseline method (default: fadiff, ga, bo, random), all
/// sharing the base request's workload / config / budget / seed.
pub fn parse_gap(j: &Json) -> WireResult<Vec<JobRequest>> {
    let base = parse_request(j)?;
    let methods: Vec<Method> = match j.get("methods") {
        Err(_) => crate::experiments::gap::BASELINES.to_vec(),
        Ok(v) => field(v.as_arr())?
            .iter()
            .map(|x| field(Method::parse(field(x.as_str())?)))
            .collect::<WireResult<_>>()?,
    };
    if methods.is_empty() {
        return Err(WireError::bad("empty gap methods list"));
    }
    if methods.contains(&Method::Exact) {
        return Err(WireError::bad(
            "gap baselines must not include \"exact\" (the oracle \
             always runs)",
        ));
    }
    if methods.len() + 1 > MAX_SWEEP_JOBS {
        return Err(WireError::new(
            ErrorCode::TooLarge,
            format!("gap grid of {} jobs exceeds the cap of \
                     {MAX_SWEEP_JOBS}", methods.len() + 1),
        ));
    }
    let mut reqs = Vec::with_capacity(methods.len() + 1);
    reqs.push(JobRequest {
        method: Method::Exact,
        ..base.clone()
    });
    for m in methods {
        reqs.push(JobRequest { method: m, ..base.clone() });
    }
    Ok(reqs)
}

fn get_job_id(j: &Json) -> WireResult<u64> {
    let x = field(j.get("job_id").and_then(|v| v.as_f64()))?;
    // 2^53: past here f64 can't represent every integer, so the id
    // could not have come from a response we handed out
    if !(x.is_finite()
        && x >= 0.0
        && x.fract() == 0.0
        && x <= 9_007_199_254_740_992.0)
    {
        return Err(WireError::bad(
            "job_id must be a non-negative integer",
        ));
    }
    Ok(x as u64)
}

/// Resolve every distinct named workload of a request batch up front,
/// so `unknown_workload` is a pre-queue error instead of a burned job.
fn validate_workloads(reqs: &[JobRequest]) -> WireResult<()> {
    let mut seen: Vec<&str> = Vec::new();
    for r in reqs {
        if r.spec.is_some() || seen.contains(&r.workload.as_str()) {
            continue;
        }
        seen.push(&r.workload);
        resolve_workload(&r.workload).map_err(|e| {
            WireError::new(ErrorCode::UnknownWorkload, e.to_string())
                .with("workload", js(&r.workload))
        })?;
    }
    Ok(())
}

/// Enforce the bounded job queue before enqueueing `incoming` jobs:
/// past capacity the verb answers `queue_full` with a retry hint
/// scaled to the backlog per worker.
fn check_capacity(coord: &Coordinator, incoming: usize)
                  -> WireResult<()> {
    let depth = coord.queue_depth();
    let capacity = coord.queue_capacity();
    if depth + incoming <= capacity {
        return Ok(());
    }
    coord
        .metrics()
        .queue_full_rejected
        .fetch_add(1, Ordering::SeqCst);
    let per_worker = depth / coord.n_workers().max(1);
    let retry_ms = ((per_worker as u64) * 250).clamp(100, 10_000);
    Err(WireError::new(
        ErrorCode::QueueFull,
        format!("job queue is full ({depth} queued, capacity \
                 {capacity}); retry later"),
    )
    .with("retry_after_ms", num(retry_ms as f64))
    .with("queue_depth", num(depth as f64))
    .with("queue_capacity", num(capacity as f64)))
}

// ---------------------------------------------------------------------
// verb payloads
// ---------------------------------------------------------------------

/// Serialize a JobResult as a wire payload (the `ok` body of
/// `optimize` responses; also nested in `status` results, watch `done`
/// events, and `sweep` cells).
pub fn result_to_json(r: &JobResult) -> Json {
    let mut rows = vec![
        ("workload", js(&r.request.workload)),
        ("config", js(&r.request.config)),
        ("method", js(r.request.method.name())),
        ("seed", num(r.request.seed as f64)),
        ("chains", num(r.request.chains as f64)),
        ("edp", num(r.edp)),
        ("full_model_edp", num(r.full_model_edp)),
        ("energy_pj", num(r.energy)),
        ("latency_cycles", num(r.latency)),
        ("fused_groups",
         Json::Arr(r.fused_names
             .iter()
             .map(|g| Json::Arr(g.iter().map(|n| js(n)).collect()))
             .collect())),
        ("iters", num(r.iters as f64)),
        ("evals", num(r.evals as f64)),
        ("wall_seconds", num(r.wall_seconds)),
        ("stored", Json::Bool(r.stored)),
    ];
    // only-when-true keeps every pre-deadline response byte-identical
    if r.deadline_hit {
        rows.push(("deadline_exceeded", Json::Bool(true)));
    }
    // only for exact-method results, so every other method's payload
    // stays byte-identical
    if let Some(ex) = &r.exact {
        rows.push(("certified", Json::Bool(ex.certified)));
        rows.push(("exact", obj(vec![
            ("space_complete", Json::Bool(ex.space_complete)),
            ("cap_hit", Json::Bool(ex.cap_hit)),
            ("layer_candidates", num(ex.layer_candidates as f64)),
            ("frontier", num(ex.frontier as f64)),
            ("nodes_generated", num(ex.nodes_generated as f64)),
            ("nodes_expanded", num(ex.nodes_expanded as f64)),
            ("pruned_bound", num(ex.pruned_bound as f64)),
            ("pruned_infeasible", num(ex.pruned_infeasible as f64)),
            ("pruned_dominated", num(ex.pruned_dominated as f64)),
            ("leaves", num(ex.leaves as f64)),
        ])));
    }
    obj(rows)
}

/// Aggregate a finished `gap` grid into its wire response: the
/// oracle's full result (certification flag and tree statistics
/// included), one row per baseline with its measured optimality gap
/// (`edp / exact_edp - 1`), and the rendered Table-1-style markdown
/// row. An oracle failure fails the whole verb — there is nothing to
/// measure against; baseline failures report inside their row so one
/// broken method never sinks its siblings.
fn gap_response(
    outcomes: &[std::result::Result<JobResult, WireError>]) -> Json {
    let exact = match outcomes.first() {
        Some(Ok(r)) => r,
        Some(Err(e)) => return Response::err(e),
        None => {
            return Response::err(&WireError::new(
                ErrorCode::Internal,
                "empty gap grid",
            ))
        }
    };
    let mut rows = Vec::new();
    let mut oks: Vec<JobResult> = Vec::new();
    for entry in &outcomes[1..] {
        match entry {
            Ok(r) => {
                rows.push(obj(vec![
                    ("method", js(r.request.method.name())),
                    ("edp", num(r.edp)),
                    ("gap", num(r.edp / exact.edp - 1.0)),
                    ("evals", num(r.evals as f64)),
                    ("wall_seconds", num(r.wall_seconds)),
                ]));
                oks.push(r.clone());
            }
            Err(e) => rows.push(obj(vec![("error", e.body())])),
        }
    }
    let markdown = GapReport::from_results(exact, &oks)
        .map(|rep| rep.render())
        .unwrap_or_default();
    Response::ok(obj(vec![
        ("workload", js(&exact.request.workload)),
        ("config", js(&exact.request.config)),
        ("certified",
         Json::Bool(exact.exact.map_or(false, |e| e.certified))),
        ("exact", result_to_json(exact)),
        ("rows", arr(rows)),
        ("markdown", js(&markdown)),
    ]))
}

/// The `workloads` verb: list every servable workload (zoo builders +
/// checked-in spec files, via the shared
/// [`super::workload_catalog`]), or — with `describe` (a name) or an
/// inline `workload_spec` — return one workload's full description
/// (the canonical spec plus derived summary fields).
fn run_workloads(j: &Json) -> Json {
    if let Ok(spec_j) = j.get("workload_spec") {
        // describe-an-inline-spec doubles as a validation endpoint
        return match spec::parse_inline(spec_j) {
            Err(e) => Response::err(&spec_error(e)),
            Ok(w) => Response::ok(obj(vec![
                ("workload", spec::describe_json(&w)),
            ])),
        };
    }
    if let Ok(name_j) = j.get("describe") {
        let name = match name_j.as_str() {
            Err(_) => {
                return Response::err(&WireError::bad(
                    "describe must be a string",
                ))
            }
            Ok(n) => n,
        };
        return match resolve_workload(name) {
            Err(e) => Response::err(
                &WireError::new(ErrorCode::UnknownWorkload,
                                e.to_string())
                    .with("workload", js(name)),
            ),
            Ok(w) => Response::ok(obj(vec![
                ("workload", spec::describe_json(&w)),
            ])),
        };
    }
    let rows = workload_catalog()
        .into_iter()
        .map(|(name, source, outcome)| match outcome {
            Ok(w) => obj(vec![
                ("name", js(&name)),
                ("source", js(source)),
                ("layers", num(w.len() as f64)),
                ("replicas", num(w.replicas)),
                ("total_macs", num(w.total_ops())),
            ]),
            // a broken checked-in file should be visible, not hidden
            Err(e) => obj(vec![
                ("name", js(&name)),
                ("source", js(source)),
                ("error", js(&e.to_string())),
            ]),
        })
        .collect::<Vec<_>>();
    Response::ok(obj(vec![
        ("count", num(rows.len() as f64)),
        ("workloads", arr(rows)),
    ]))
}

/// The registry view shared by every `chaos` action: whether the
/// build can inject at all, the site names, and the armed sites with
/// their live call/fire counters.
fn chaos_status() -> Json {
    use crate::util::fault;
    let armed = fault::snapshot()
        .into_iter()
        .map(|s| {
            obj(vec![
                ("site", js(&s.site)),
                ("mode", js(&s.mode)),
                ("calls", num(s.calls as f64)),
                ("fires", num(s.fires as f64)),
                ("delay_ms", num(s.delay_ms as f64)),
            ])
        })
        .collect::<Vec<_>>();
    obj(vec![
        ("available", Json::Bool(fault::available())),
        ("sites",
         Json::Arr(fault::SITES.iter().map(|s| js(s)).collect())),
        ("armed", arr(armed)),
    ])
}

/// The `chaos` verb: inspect (`status`, the default), `arm` one
/// injection site, or `reset` (disarm everything). Arming requires a
/// build with the `fault-injection` cargo feature; status/reset work
/// everywhere so probes can always ask what a server is capable of.
fn run_chaos(j: &Json) -> Json {
    use crate::util::fault;
    let action = match j.get("action") {
        Err(_) => "status",
        Ok(a) => match a.as_str() {
            Ok(s) => s,
            Err(_) => {
                return Response::err(&WireError::bad(
                    "action must be a string",
                ))
            }
        },
    };
    match action {
        "status" => Response::ok(chaos_status()),
        "reset" => {
            fault::disarm_all();
            Response::ok(chaos_status())
        }
        "arm" => {
            if !fault::available() {
                return Response::err(&WireError::bad(
                    "fault injection is not compiled into this build \
                     (enable the `fault-injection` cargo feature)",
                ));
            }
            let site = match j.get("site").and_then(|s| s.as_str()) {
                Err(_) => {
                    return Response::err(&WireError::bad(
                        "arm requires a site string",
                    ))
                }
                Ok(s) => s.to_string(),
            };
            let mode = match j.get("mode") {
                Err(_) => "oneshot".to_string(),
                Ok(m) => match m.as_str() {
                    Ok(s) => s.to_string(),
                    Err(_) => {
                        return Response::err(&WireError::bad(
                            "mode must be a string",
                        ))
                    }
                },
            };
            let p = j.get("p").and_then(|v| v.as_f64()).unwrap_or(1.0);
            let seed = j
                .get("seed")
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0) as u64;
            let delay_ms = j
                .get("delay_ms")
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0) as u64;
            let trigger = match mode.as_str() {
                "oneshot" => fault::Trigger::OneShot,
                "always" => fault::Trigger::Always,
                "prob" => fault::Trigger::Probability { p, seed },
                other => {
                    return Response::err(&WireError::bad(format!(
                        "unknown chaos mode {other:?} (expected \
                         oneshot, always, or prob)"
                    )))
                }
            };
            if let Err(e) = fault::arm(&site, trigger, delay_ms) {
                return Response::err(&WireError::bad(e));
            }
            log_line(&format!(
                "chaos: armed site {site:?} mode {mode}"
            ));
            Response::ok(chaos_status())
        }
        other => Response::err(&WireError::bad(format!(
            "unknown chaos action {other:?} (expected status, arm, \
             or reset)"
        ))),
    }
}

// ---------------------------------------------------------------------
// pending (multi-tick) connection work
// ---------------------------------------------------------------------

/// A parked `optimize`: its job is in the queue / on a worker; the
/// connection polls the handle each tick.
struct JobWait {
    rx: OneShot<std::result::Result<JobResult, String>>,
}

/// A parked `sweep`: every cell is queued; completed handles drain
/// front-to-back so `results` keeps grid order.
struct SweepWait {
    #[allow(clippy::type_complexity)]
    pending: VecDeque<(JobRequest,
                       OneShot<std::result::Result<JobResult,
                                                   String>>)>,
    results: Vec<Json>,
    jobs: usize,
    completed: usize,
    failed: usize,
}

/// A parked `gap`: the exact oracle job (always the queue's front)
/// plus its baseline jobs; outcomes drain front-to-back like a sweep,
/// and the reply is assembled once every job is terminal.
struct GapWait {
    #[allow(clippy::type_complexity)]
    pending: VecDeque<(JobRequest,
                       OneShot<std::result::Result<JobResult,
                                                   String>>)>,
    outcomes: Vec<std::result::Result<JobResult, WireError>>,
}

/// A live `status {"watch": true}` stream.
struct WatchWait {
    job_id: u64,
    last_seq: u64,
    last_status: Option<JobStatus>,
    last_progress: Option<Instant>,
}

/// What a connection is doing between ticks.
enum Mode {
    /// Waiting for (or mid-way through reading) the next request line.
    Idle,
    /// Blocked on one `optimize` job.
    Job(JobWait),
    /// Blocked on a `sweep` grid.
    Sweep(SweepWait),
    /// Blocked on a `gap` comparison (oracle + baselines).
    Gap(GapWait),
    /// Streaming watch events for a tracked job.
    Watch(WatchWait),
}

/// One dispatched request: either an immediate reply line or a parked
/// mode the event loop keeps polling.
enum Step {
    Reply(Json),
    Enter(Mode),
}

fn reply_err(e: WireError) -> Step {
    Step::Reply(Response::err(&e))
}

// ---------------------------------------------------------------------
// dispatch
// ---------------------------------------------------------------------

/// Turn one request line into a [`Step`]. Total: every input —
/// malformed, unknown, oversized grids, floods — maps to a JSON answer
/// or a parked mode, never a dropped connection or a panic.
fn dispatch(line: &str, coord: &Coordinator, shutdown: &ShutdownFlag)
            -> Step {
    let j = match Json::parse(line) {
        Err(e) => {
            return reply_err(WireError::bad(format!("bad json: {e}")))
        }
        Ok(j) => j,
    };
    if j.as_obj().is_err() {
        return reply_err(WireError::bad(
            "request must be a JSON object",
        ));
    }
    if shutdown.0.load(Ordering::SeqCst) {
        return reply_err(WireError::new(
            ErrorCode::ShuttingDown,
            "server is shutting down",
        ));
    }
    // a request may pin the protocol version it expects
    if let Ok(v) = j.get("v") {
        match v.as_f64() {
            Err(_) => {
                return reply_err(WireError::bad("v must be a number"))
            }
            Ok(x) if x == PROTOCOL_VERSION as f64 => {}
            Ok(x) => {
                return reply_err(
                    WireError::new(
                        ErrorCode::UnsupportedVersion,
                        format!("this server speaks protocol \
                                 {PROTOCOL_VERSION}, not {x}"),
                    )
                    .with("protocol",
                          num(PROTOCOL_VERSION as f64)),
                );
            }
        }
    }
    let verb = match j.get("verb") {
        Err(_) => "optimize".to_string(),
        Ok(v) => match v.as_str() {
            Ok(s) => s.to_string(),
            Err(_) => {
                return reply_err(WireError::bad(
                    "verb must be a string",
                ))
            }
        },
    };
    match verb.as_str() {
        "ping" => Step::Reply(Response::ok(obj(vec![
            ("pong", Json::Bool(true)),
            ("protocol", num(PROTOCOL_VERSION as f64)),
            ("uptime_seconds", num(coord.uptime_seconds())),
        ]))),
        "metrics" => Step::Reply(Response::ok(coord.metrics_json())),
        "shutdown" => {
            shutdown.0.store(true, Ordering::SeqCst);
            log_line("shutdown requested");
            Step::Reply(Response::ok(obj(vec![
                ("shutting_down", Json::Bool(true)),
            ])))
        }
        "optimize" => {
            let req = match parse_request(&j)
                .and_then(|req| validate_workloads(
                    std::slice::from_ref(&req)).map(|()| req))
                .and_then(|req| check_capacity(coord, 1).map(|()| req))
            {
                Err(e) => return reply_err(e),
                Ok(req) => req,
            };
            Step::Enter(Mode::Job(JobWait { rx: coord.submit(req) }))
        }
        "submit" => {
            let req = match parse_request(&j)
                .and_then(|req| validate_workloads(
                    std::slice::from_ref(&req)).map(|()| req))
                .and_then(|req| check_capacity(coord, 1).map(|()| req))
            {
                Err(e) => return reply_err(e),
                Ok(req) => req,
            };
            match coord.submit_tracked(req) {
                // a saturated job table is backpressure, like the queue
                Err(e) => {
                    coord
                        .metrics()
                        .queue_full_rejected
                        .fetch_add(1, Ordering::SeqCst);
                    reply_err(WireError::new(
                        ErrorCode::QueueFull,
                        e.to_string(),
                    )
                    .with("retry_after_ms", num(1000.0)))
                }
                Ok(id) => Step::Reply(Response::ok(obj(vec![
                    ("job_id", num(id as f64)),
                    ("status", js("queued")),
                ]))),
            }
        }
        "status" => {
            let id = match get_job_id(&j) {
                Err(e) => return reply_err(e),
                Ok(id) => id,
            };
            let watch = match j.get("watch") {
                Err(_) => false,
                Ok(Json::Bool(b)) => *b,
                Ok(_) => {
                    return reply_err(WireError::bad(
                        "watch must be a boolean",
                    ))
                }
            };
            // single lookup: a second one after the existence check
            // could race a job-table eviction and panic (the old
            // check-then-unwrap pattern did exactly that)
            let Some((status, result)) = coord.job_status(id) else {
                return reply_err(
                    WireError::new(ErrorCode::JobNotFound,
                                   format!("unknown job id {id}"))
                        .with("job_id", num(id as f64)),
                );
            };
            if watch {
                return Step::Enter(Mode::Watch(WatchWait {
                    job_id: id,
                    last_seq: 0,
                    last_status: None,
                    last_progress: None,
                }));
            }
            let mut fields = vec![
                ("job_id", num(id as f64)),
                ("status", js(status.name())),
            ];
            match result {
                Some(Ok(r)) => {
                    fields.push(("result", result_to_json(&r)))
                }
                Some(Err(e)) => fields.push(("error", js(&e))),
                None => {}
            }
            Step::Reply(Response::ok(obj(fields)))
        }
        "cancel" => {
            let id = match get_job_id(&j) {
                Err(e) => return reply_err(e),
                Ok(id) => id,
            };
            match coord.cancel(id) {
                None => reply_err(
                    WireError::new(ErrorCode::JobNotFound,
                                   format!("unknown job id {id}"))
                        .with("job_id", num(id as f64)),
                ),
                Some(status) => Step::Reply(Response::ok(obj(vec![
                    ("job_id", num(id as f64)),
                    ("status", js(status.name())),
                ]))),
            }
        }
        // a sweep aggregates per-cell outcomes instead of pre-resolving
        // workload names: one broken cell reports inside the grid
        // response and never sinks its siblings
        "sweep" => {
            let reqs = match parse_sweep(&j).and_then(|r| {
                check_capacity(coord, r.len()).map(|()| r)
            }) {
                Err(e) => return reply_err(e),
                Ok(r) => r,
            };
            let jobs = reqs.len();
            // fan the whole grid into the queue first, then collect:
            // the grid runs at full worker parallelism, and
            // same-(workload, config) cells share one evaluation cache
            // and merge in the fleet scheduler
            let pending = reqs
                .into_iter()
                .map(|req| (req.clone(), coord.submit(req)))
                .collect();
            Step::Enter(Mode::Sweep(SweepWait {
                pending,
                results: Vec::with_capacity(jobs),
                jobs,
                completed: 0,
                failed: 0,
            }))
        }
        // gap: the exact oracle plus every baseline on one workload,
        // queued together (full worker parallelism, shared eval
        // cache); the reply reports each method's measured gap
        "gap" => {
            let reqs = match parse_gap(&j)
                .and_then(|r| validate_workloads(&r).map(|()| r))
                .and_then(|r| {
                    check_capacity(coord, r.len()).map(|()| r)
                }) {
                Err(e) => return reply_err(e),
                Ok(r) => r,
            };
            let pending = reqs
                .into_iter()
                .map(|req| (req.clone(), coord.submit(req)))
                .collect();
            Step::Enter(Mode::Gap(GapWait {
                pending,
                outcomes: Vec::new(),
            }))
        }
        "store" => {
            let payload = match coord.store() {
                Some(st) => st.stats_json(),
                None => obj(vec![("enabled", Json::Bool(false))]),
            };
            Step::Reply(Response::ok(obj(vec![
                ("store", payload),
                // runtime view of the warm-start mapping library (the
                // persisted shard counts live under store above)
                ("library", coord.library().stats_json()),
            ])))
        }
        "workloads" => Step::Reply(run_workloads(&j)),
        "chaos" => Step::Reply(run_chaos(&j)),
        other => reply_err(
            WireError::new(ErrorCode::UnknownVerb,
                           format!("unknown verb {other:?}"))
                .with("supported",
                      arr(SUPPORTED_VERBS
                          .iter()
                          .map(|v| js(v))
                          .collect())),
        ),
    }
}

// ---------------------------------------------------------------------
// the event loop
// ---------------------------------------------------------------------

fn is_retry(kind: std::io::ErrorKind) -> bool {
    matches!(
        kind,
        std::io::ErrorKind::WouldBlock
            | std::io::ErrorKind::TimedOut
            | std::io::ErrorKind::Interrupted
    )
}

/// `read_until(b'\n')` with a hard cap on retained bytes: at most
/// `MAX_REQUEST_BYTES + 1` bytes stay in `buf`; the excess of an
/// oversized line is consumed and dropped as it streams in, so a fast
/// client cannot balloon server memory by never sending a newline. A
/// newline discovered in the dropped region is still appended, so
/// callers always see oversized lines terminate. Mirrors `read_until`'s
/// contract otherwise: `Ok(0)` = EOF with nothing consumed, trailing
/// bytes without `\n` = EOF mid-line, `Err(WouldBlock)` = no data right
/// now on the nonblocking stream (bytes read so far remain in `buf`).
fn read_line_capped<R: BufRead>(reader: &mut R, buf: &mut Vec<u8>)
                                -> std::io::Result<usize> {
    let mut total = 0usize;
    loop {
        let (consumed, done) = {
            let available = reader.fill_buf()?;
            if available.is_empty() {
                return Ok(total); // EOF
            }
            let newline = available.iter().position(|&b| b == b'\n');
            let take = newline.map_or(available.len(), |i| i + 1);
            let room =
                (MAX_REQUEST_BYTES + 1).saturating_sub(buf.len());
            let keep = take.min(room);
            buf.extend_from_slice(&available[..keep]);
            if keep < take && newline.is_some() {
                buf.push(b'\n'); // line ended inside the dropped region
            }
            (take, newline.is_some())
        };
        reader.consume(consumed);
        total += consumed;
        if done {
            return Ok(total);
        }
    }
}

/// One client connection in the event loop.
struct Conn {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
    /// Partial request line accumulated across ticks.
    buf: Vec<u8>,
    /// Pending outbound bytes ([`Conn::sent`] already written).
    out: Vec<u8>,
    sent: usize,
    /// True while draining the tail of an answered oversized line.
    discarding: bool,
    /// The client half-closed (EOF mid-line): answer, flush, close.
    half_closed: bool,
    /// Close once `out` drains.
    close_after_flush: bool,
    closed: bool,
    mode: Mode,
}

impl Conn {
    fn new(stream: TcpStream) -> std::io::Result<Conn> {
        stream.set_nonblocking(true)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Conn {
            stream,
            reader,
            buf: Vec::new(),
            out: Vec::new(),
            sent: 0,
            discarding: false,
            half_closed: false,
            close_after_flush: false,
            closed: false,
            mode: Mode::Idle,
        })
    }

    fn push_line(&mut self, j: &Json) {
        let mut text = j.compact();
        text.push('\n');
        self.out.extend_from_slice(text.as_bytes());
    }

    /// Write as much pending output as the socket accepts.
    fn flush(&mut self) -> bool {
        let mut wrote = false;
        while self.sent < self.out.len() {
            match self.stream.write(&self.out[self.sent..]) {
                Ok(0) => {
                    self.closed = true;
                    return wrote;
                }
                Ok(n) => {
                    self.sent += n;
                    wrote = true;
                }
                Err(e) if e.kind()
                    == std::io::ErrorKind::Interrupted => {}
                Err(e) if is_retry(e.kind()) => return wrote,
                Err(_) => {
                    self.closed = true;
                    return wrote;
                }
            }
        }
        if self.sent == self.out.len() && self.sent > 0 {
            self.out.clear();
            self.sent = 0;
        }
        wrote
    }

    /// A request/answer cycle finished with the connection idle again:
    /// close when the client half-closed or the server is draining.
    fn finish_cycle(&mut self, shutdown: &ShutdownFlag) {
        if self.half_closed || shutdown.0.load(Ordering::SeqCst) {
            self.close_after_flush = true;
        }
    }

    /// One event-loop visit. Returns true when any progress was made
    /// (so the loop only sleeps on fully idle ticks).
    fn tick(&mut self, coord: &Coordinator, shutdown: &ShutdownFlag)
            -> bool {
        if self.closed {
            return false;
        }
        let mut activity = self.flush();
        if self.closed || !self.out.is_empty() {
            // backpressured (or dead) writer: try again next tick
            return activity;
        }
        if self.close_after_flush {
            self.closed = true;
            return true;
        }
        match self.mode {
            Mode::Idle => {
                if shutdown.0.load(Ordering::SeqCst) {
                    // draining: no new requests on idle connections
                    self.closed = true;
                    return true;
                }
                activity |= self.read_step(coord, shutdown);
            }
            _ => activity |= self.poll_step(coord, shutdown),
        }
        activity
    }

    /// Try to complete one request line and dispatch it.
    fn read_step(&mut self, coord: &Coordinator,
                 shutdown: &ShutdownFlag) -> bool {
        match read_line_capped(&mut self.reader, &mut self.buf) {
            Err(e) if is_retry(e.kind()) => {
                // partial line so far; bound the buffer while waiting
                if !self.discarding
                    && self.buf.len() > MAX_REQUEST_BYTES
                {
                    coord
                        .metrics()
                        .oversized_drains
                        .fetch_add(1, Ordering::SeqCst);
                    self.push_line(&Response::err(&too_large_line()));
                    self.discarding = true;
                    self.buf.clear();
                    return true;
                }
                if self.discarding {
                    self.buf.clear();
                }
                return false;
            }
            Err(_) => {
                self.closed = true;
                return true;
            }
            // EOF: done, unless a stalled partial line is pending —
            // that truncated tail deserves its one-line answer below
            Ok(0) if self.buf.is_empty() || self.discarding => {
                self.closed = true;
                return true;
            }
            Ok(_) => {}
        }
        let complete = self.buf.last() == Some(&b'\n');
        if self.discarding {
            if complete {
                // oversized line finally ended; resume normal service
                self.discarding = false;
                self.buf.clear();
                return true;
            }
            // EOF while draining
            self.closed = true;
            return true;
        }
        if !complete {
            if self.buf.is_empty() {
                self.closed = true;
                return true;
            }
            self.half_closed = true; // EOF mid-line: answer then close
        }
        if self.buf.len() > MAX_REQUEST_BYTES {
            coord
                .metrics()
                .oversized_drains
                .fetch_add(1, Ordering::SeqCst);
            self.push_line(&Response::err(&too_large_line()));
            self.buf.clear();
            self.finish_cycle(shutdown);
            return true;
        }
        // raw bytes, not String: invalid UTF-8 must degrade to a JSON
        // error (via lossy decode), never desynchronize the connection
        let line =
            String::from_utf8_lossy(&self.buf).trim().to_string();
        self.buf.clear();
        if line.is_empty() {
            if self.half_closed {
                self.closed = true;
            }
            return true;
        }
        match dispatch(&line, coord, shutdown) {
            Step::Reply(json) => {
                self.push_line(&json);
                self.finish_cycle(shutdown);
            }
            Step::Enter(mode) => self.mode = mode,
        }
        true
    }

    /// Advance a parked mode (job / sweep / watch).
    fn poll_step(&mut self, coord: &Coordinator,
                 shutdown: &ShutdownFlag) -> bool {
        let mode = std::mem::replace(&mut self.mode, Mode::Idle);
        let (next, wrote) = match mode {
            Mode::Idle => (Mode::Idle, false),
            Mode::Job(wait) => self.poll_job(wait),
            Mode::Sweep(wait) => self.poll_sweep(wait),
            Mode::Gap(wait) => self.poll_gap(wait),
            Mode::Watch(wait) => self.poll_watch(coord, wait),
        };
        let finished = matches!(next, Mode::Idle);
        self.mode = next;
        if finished {
            self.finish_cycle(shutdown);
        }
        wrote
    }

    fn poll_job(&mut self, wait: JobWait) -> (Mode, bool) {
        match wait.rx.try_poll() {
            Poll::Empty => (Mode::Job(wait), false),
            // a deadline cut is an error envelope (stable code) that
            // still carries the best-so-far under `result`
            Poll::Ready(Ok(r)) if r.deadline_hit => {
                self.push_line(&Response::err(
                    &WireError::new(
                        ErrorCode::DeadlineExceeded,
                        format!("deadline_ms {} expired; returning \
                                 best-so-far",
                                r.request.deadline_ms),
                    )
                    .with("result", result_to_json(&r)),
                ));
                (Mode::Idle, true)
            }
            Poll::Ready(Ok(r)) => {
                self.push_line(&Response::ok(result_to_json(&r)));
                (Mode::Idle, true)
            }
            Poll::Ready(Err(msg)) => {
                self.push_line(&Response::err(&job_error(&msg)));
                (Mode::Idle, true)
            }
            Poll::Dead => {
                self.push_line(&Response::err(&WireError::new(
                    ErrorCode::Internal,
                    "worker dropped the job",
                )));
                (Mode::Idle, true)
            }
        }
    }

    fn poll_sweep(&mut self, mut wait: SweepWait) -> (Mode, bool) {
        // drain front-to-back so the results array keeps grid order
        while let Some((_, rx)) = wait.pending.front() {
            let entry = match rx.try_poll() {
                Poll::Empty => break,
                // a deadline-cut cell counts as failed but keeps its
                // best-so-far inside the error body
                Poll::Ready(Ok(r)) if r.deadline_hit => {
                    wait.failed += 1;
                    let e = WireError::new(
                        ErrorCode::DeadlineExceeded,
                        format!("deadline_ms {} expired; returning \
                                 best-so-far",
                                r.request.deadline_ms),
                    )
                    .with("result", result_to_json(&r));
                    obj(vec![("error", e.body())])
                }
                Poll::Ready(Ok(r)) => {
                    wait.completed += 1;
                    obj(vec![("ok", result_to_json(&r))])
                }
                outcome => {
                    wait.failed += 1;
                    let msg = match outcome {
                        Poll::Ready(Err(e)) => e,
                        _ => "worker dropped the job".to_string(),
                    };
                    let (req, _) = wait.pending.front().unwrap();
                    let e = job_error(&msg)
                        .with("workload", js(&req.workload))
                        .with("config", js(&req.config))
                        .with("method", js(req.method.name()))
                        .with("seed", num(req.seed as f64));
                    obj(vec![("error", e.body())])
                }
            };
            wait.results.push(entry);
            wait.pending.pop_front();
        }
        if !wait.pending.is_empty() {
            return (Mode::Sweep(wait), false);
        }
        self.push_line(&Response::ok(obj(vec![
            ("jobs", num(wait.jobs as f64)),
            ("completed", num(wait.completed as f64)),
            ("failed", num(wait.failed as f64)),
            ("results", arr(wait.results)),
        ])));
        (Mode::Idle, true)
    }

    fn poll_gap(&mut self, mut wait: GapWait) -> (Mode, bool) {
        // drain front-to-back: the oracle's outcome stays first, the
        // baselines keep request order
        while let Some((_, rx)) = wait.pending.front() {
            let entry = match rx.try_poll() {
                Poll::Empty => break,
                // a deadline-cut job is not a fair gap measurement:
                // it reports as a per-method error, best-so-far
                // attached, like a sweep cell
                Poll::Ready(Ok(r)) if r.deadline_hit => {
                    let e = WireError::new(
                        ErrorCode::DeadlineExceeded,
                        format!("deadline_ms {} expired; returning \
                                 best-so-far",
                                r.request.deadline_ms),
                    )
                    .with("result", result_to_json(&r));
                    Err(e)
                }
                Poll::Ready(Ok(r)) => Ok(r),
                outcome => {
                    let msg = match outcome {
                        Poll::Ready(Err(e)) => e,
                        _ => "worker dropped the job".to_string(),
                    };
                    let (req, _) = wait.pending.front().unwrap();
                    Err(job_error(&msg)
                        .with("method", js(req.method.name())))
                }
            };
            wait.outcomes.push(entry);
            wait.pending.pop_front();
        }
        if !wait.pending.is_empty() {
            return (Mode::Gap(wait), false);
        }
        self.push_line(&gap_response(&wait.outcomes));
        (Mode::Idle, true)
    }

    /// Emit watch-stream events: a `status` event per state change,
    /// rate-limited `progress` events as the incumbent improves, and
    /// exactly one terminal `done` event carrying the outcome.
    fn poll_watch(&mut self, coord: &Coordinator, mut wait: WatchWait)
                  -> (Mode, bool) {
        let Some((status, result)) = coord.job_status(wait.job_id)
        else {
            // pruned mid-watch (table pressure): terminal error event
            self.push_line(&Response::err(
                &WireError::new(
                    ErrorCode::JobNotFound,
                    format!("job {} pruned mid-watch", wait.job_id),
                )
                .with("job_id", num(wait.job_id as f64)),
            ));
            return (Mode::Idle, true);
        };
        let mut wrote = false;
        if status.is_terminal() {
            let mut fields = vec![
                ("event", js("done")),
                ("job_id", num(wait.job_id as f64)),
                ("status", js(status.name())),
            ];
            match result {
                Some(Ok(r)) => {
                    fields.push(("result", result_to_json(&r)))
                }
                Some(Err(e)) => fields.push(("error", js(&e))),
                None => {}
            }
            self.push_line(&Response::ok(obj(fields)));
            return (Mode::Idle, true);
        }
        if wait.last_status != Some(status) {
            wait.last_status = Some(status);
            self.push_line(&Response::ok(obj(vec![
                ("event", js("status")),
                ("job_id", num(wait.job_id as f64)),
                ("status", js(status.name())),
            ])));
            wrote = true;
        }
        if let Some(snap) = coord.job_progress(wait.job_id) {
            let due = wait
                .last_progress
                .map_or(true,
                        |t| t.elapsed() >= WATCH_PROGRESS_EVERY);
            if snap.seq != wait.last_seq && due {
                wait.last_seq = snap.seq;
                wait.last_progress = Some(Instant::now());
                let mut fields = vec![
                    ("event", js("progress")),
                    ("job_id", num(wait.job_id as f64)),
                    ("seq", num(snap.seq as f64)),
                    ("evals", num(snap.evals as f64)),
                    ("iters", num(snap.iters as f64)),
                ];
                if let Some(edp) = snap.best_edp {
                    fields.push(("best_edp", num(edp)));
                }
                self.push_line(&Response::ok(obj(fields)));
                wrote = true;
            }
        }
        (Mode::Watch(wait), wrote)
    }
}

fn too_large_line() -> WireError {
    WireError::new(
        ErrorCode::TooLarge,
        format!("request line exceeds {MAX_REQUEST_BYTES} bytes"),
    )
}

fn log_line(msg: &str) {
    eprintln!("[fadiff-serve] {msg}");
}

/// Latched by the SIGINT/SIGTERM handler; the event loop converts it
/// into an orderly drain on its next iteration (the same path the
/// `shutdown` verb takes, so the store flush and worker joins run).
static SIGNAL_SHUTDOWN: std::sync::atomic::AtomicBool =
    std::sync::atomic::AtomicBool::new(false);

/// Async-signal-safe handler body: a single relaxed store.
#[cfg(unix)]
extern "C" fn on_termination_signal(_sig: i32) {
    SIGNAL_SHUTDOWN.store(true, Ordering::Relaxed);
}

/// Install best-effort SIGINT/SIGTERM handlers that turn a kill into
/// a graceful drain (jobs finish, the result store flushes) instead
/// of an abrupt exit. No-op on non-unix platforms; only the `serve`
/// binary path calls this — in-process test servers are unaffected.
pub fn install_signal_handlers() {
    #[cfg(unix)]
    {
        extern "C" {
            fn signal(signum: i32, handler: usize) -> usize;
        }
        const SIGINT: i32 = 2;
        const SIGTERM: i32 = 15;
        unsafe {
            signal(SIGINT, on_termination_signal as usize);
            signal(SIGTERM, on_termination_signal as usize);
        }
    }
}

/// Run the server until a `shutdown` verb arrives.
pub fn serve(addr: &str, coord: Coordinator) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    serve_on(listener, coord)
}

/// Serve on an already-bound listener (lets tests pick port 0): one
/// nonblocking event loop owns every connection; no thread per client.
/// In-flight jobs (and the queued backlog) complete before shutdown
/// finishes — their connections stay polled until terminal.
pub fn serve_on(listener: TcpListener, coord: Coordinator)
                -> Result<()> {
    let local = listener.local_addr()?;
    log_line(&format!("listening on {local} with {} workers",
                      coord.n_workers()));
    let shutdown = ShutdownFlag::default();
    listener.set_nonblocking(true)?;
    let mut conns: Vec<Conn> = Vec::new();
    loop {
        if SIGNAL_SHUTDOWN.load(Ordering::Relaxed)
            && !shutdown.0.swap(true, Ordering::SeqCst)
        {
            log_line("termination signal received; draining");
        }
        let shutting = shutdown.0.load(Ordering::SeqCst);
        let mut activity = false;
        if !shutting {
            // accept in bounded bursts so a connect flood cannot
            // starve the established connections
            for _ in 0..64 {
                match listener.accept() {
                    Ok((stream, peer)) => {
                        activity = true;
                        if conns.len() >= MAX_CONNS {
                            coord
                                .metrics()
                                .queue_full_rejected
                                .fetch_add(1, Ordering::SeqCst);
                            reject_conn(stream, peer);
                            continue;
                        }
                        match Conn::new(stream) {
                            Ok(c) => conns.push(c),
                            Err(e) => log_line(&format!(
                                "accept setup failed: {e}"
                            )),
                        }
                    }
                    Err(ref e)
                        if e.kind()
                            == std::io::ErrorKind::WouldBlock =>
                    {
                        break
                    }
                    Err(e) => return Err(e.into()),
                }
            }
        }
        for conn in &mut conns {
            activity |= conn.tick(&coord, &shutdown);
        }
        conns.retain(|c| !c.closed);
        coord
            .metrics()
            .conns_open
            .store(conns.len() as u64, Ordering::SeqCst);
        if shutting && conns.is_empty() {
            break;
        }
        if !activity {
            std::thread::sleep(IDLE_SLEEP);
        }
    }
    // dropping the coordinator joins the workers after the queued
    // backlog drains
    drop(coord);
    log_line("server stopped");
    Ok(())
}

/// Best-effort one-line rejection of a connection over [`MAX_CONNS`].
fn reject_conn(mut stream: TcpStream, peer: SocketAddr) {
    log_line(&format!("rejecting {peer}: connection limit"));
    let e = WireError::new(
        ErrorCode::QueueFull,
        format!("connection limit of {MAX_CONNS} reached"),
    )
    .with("retry_after_ms", num(1000.0));
    let mut text = Response::err(&e).compact();
    text.push('\n');
    let _ = stream.write_all(text.as_bytes());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_request_defaults_and_overrides() {
        let j = Json::parse(
            r#"{"workload": "vgg16", "method": "ga", "seconds": 2.5}"#)
            .unwrap();
        let r = parse_request(&j).unwrap();
        assert_eq!(r.workload, "vgg16");
        assert_eq!(r.method, Method::Ga);
        assert_eq!(r.seconds, 2.5);
        assert_eq!(r.config, "large"); // default
        assert_eq!(r.chains, 0); // default: method decides
        let j = Json::parse(r#"{"method": "fadiff", "chains": 4}"#)
            .unwrap();
        assert_eq!(parse_request(&j).unwrap().chains, 4);
    }

    #[test]
    fn parse_request_caps_chains_with_too_large() {
        // an absurd chain count is a one-line error, not a giant
        // ChainBatch allocation (remote-OOM guard)
        for body in [r#"{"chains": 257}"#, r#"{"chains": 1e18}"#] {
            let j = Json::parse(body).unwrap();
            let err = parse_request(&j).unwrap_err();
            assert_eq!(err.code, ErrorCode::TooLarge, "{body}");
            assert!(err.message.contains("cap"),
                    "{body}: {}", err.message);
        }
        let j = Json::parse(r#"{"chains": 256}"#).unwrap();
        assert_eq!(parse_request(&j).unwrap().chains, 256);
    }

    #[test]
    fn parse_request_rejects_bad_method() {
        let j = Json::parse(r#"{"method": "quantum"}"#).unwrap();
        assert_eq!(parse_request(&j).unwrap_err().code,
                   ErrorCode::BadRequest);
    }

    #[test]
    fn parse_request_rejects_wrong_types() {
        for body in [
            r#"{"workload": 7}"#,
            r#"{"seconds": "fast"}"#,
            r#"{"max_iters": "many"}"#,
            r#"{"method": [1]}"#,
        ] {
            let j = Json::parse(body).unwrap();
            let err = parse_request(&j).unwrap_err();
            assert_eq!(err.code, ErrorCode::BadRequest, "{body}");
        }
    }

    #[test]
    fn parse_sweep_expands_full_grid() {
        let j = Json::parse(
            r#"{"verb": "sweep", "workloads": ["resnet18", "vgg16"],
                "methods": ["ga", "random"], "seeds": [1, 2, 3],
                "config": "small", "seconds": 0.5, "max_iters": 10,
                "chains": 4}"#)
            .unwrap();
        let reqs = parse_sweep(&j).unwrap();
        assert_eq!(reqs.len(), 2 * 2 * 3);
        assert!(reqs.iter().all(|r| r.config == "small"));
        assert!(reqs.iter().all(|r| r.max_iters == 10));
        assert!(reqs.iter().all(|r| r.chains == 4),
                "chains is a shared sweep default");
        let firsts: Vec<_> = reqs
            .iter()
            .map(|r| (r.workload.as_str(), r.method, r.seed))
            .collect();
        assert!(firsts.contains(&(("vgg16"), Method::Random, 3)));
    }

    #[test]
    fn parse_sweep_singular_defaults() {
        let j = Json::parse(
            r#"{"verb": "sweep", "workload": "mobilenet",
                "method": "random"}"#)
            .unwrap();
        let reqs = parse_sweep(&j).unwrap();
        assert_eq!(reqs.len(), 1);
        assert_eq!(reqs[0].workload, "mobilenet");
        assert_eq!(reqs[0].method, Method::Random);
    }

    #[test]
    fn parse_sweep_caps_grid_size_with_too_large() {
        let seeds: Vec<String> =
            (0..300).map(|i| i.to_string()).collect();
        let j = Json::parse(&format!(
            r#"{{"verb": "sweep", "seeds": [{}]}}"#,
            seeds.join(",")
        ))
        .unwrap();
        let err = parse_sweep(&j).unwrap_err();
        assert_eq!(err.code, ErrorCode::TooLarge);
        assert!(err.message.contains("cap"), "{}", err.message);
    }

    #[test]
    fn parse_gap_defaults_and_rejections() {
        let j = Json::parse(
            r#"{"verb": "gap", "workload": "micro-mlp",
                "max_iters": 64, "seed": 5}"#)
            .unwrap();
        let reqs = parse_gap(&j).unwrap();
        assert_eq!(reqs.len(),
                   1 + crate::experiments::gap::BASELINES.len());
        assert_eq!(reqs[0].method, Method::Exact,
                   "the oracle is always the grid's front");
        assert!(reqs.iter().all(|r| r.workload == "micro-mlp"
                                && r.seed == 5
                                && r.max_iters == 64));
        // explicit baseline list
        let j = Json::parse(
            r#"{"verb": "gap", "methods": ["ga", "random"]}"#)
            .unwrap();
        let reqs = parse_gap(&j).unwrap();
        assert_eq!(reqs.len(), 3);
        assert_eq!(reqs[1].method, Method::Ga);
        assert_eq!(reqs[2].method, Method::Random);
        // bad baseline lists are one-line errors
        for body in [
            r#"{"verb": "gap", "methods": []}"#,
            r#"{"verb": "gap", "methods": ["exact"]}"#,
            r#"{"verb": "gap", "methods": ["quantum"]}"#,
        ] {
            let j = Json::parse(body).unwrap();
            assert_eq!(parse_gap(&j).unwrap_err().code,
                       ErrorCode::BadRequest, "{body}");
        }
        let many: Vec<String> =
            (0..MAX_SWEEP_JOBS).map(|_| "\"ga\"".into()).collect();
        let j = Json::parse(&format!(
            r#"{{"verb": "gap", "methods": [{}]}}"#,
            many.join(",")
        ))
        .unwrap();
        assert_eq!(parse_gap(&j).unwrap_err().code,
                   ErrorCode::TooLarge);
    }

    /// A hand-built JobResult for gap_response tests; exact-method
    /// results carry certified stats like a real oracle run.
    fn gap_jr(method: Method, edp: f64) -> JobResult {
        JobResult {
            request: JobRequest {
                workload: "micro-mlp".into(),
                method,
                ..Default::default()
            },
            edp,
            full_model_edp: edp,
            energy: 1.0,
            latency: edp,
            groups: Vec::new(),
            fused_names: Vec::new(),
            iters: 1,
            evals: 1,
            wall_seconds: 0.0,
            stored: false,
            deadline_hit: false,
            exact: match method {
                Method::Exact => {
                    Some(crate::search::exact::ExactStats {
                        certified: true,
                        space_complete: true,
                        ..Default::default()
                    })
                }
                _ => None,
            },
        }
    }

    #[test]
    fn gap_response_reports_rows_and_markdown() {
        let outcomes = vec![
            Ok(gap_jr(Method::Exact, 100.0)),
            Ok(gap_jr(Method::Ga, 150.0)),
            Err(WireError::new(ErrorCode::Internal, "boom")
                .with("method", js("bo"))),
        ];
        let resp = gap_response(&outcomes);
        let body = resp.get("ok").unwrap();
        assert_eq!(body.get("certified").unwrap(), &Json::Bool(true));
        let ex = body.get("exact").unwrap();
        assert_eq!(ex.get("certified").unwrap(), &Json::Bool(true));
        assert!(ex.get("exact").is_ok(),
                "oracle payload carries its tree statistics");
        let rows = body.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].get("method").unwrap().as_str().unwrap(),
                   "ga");
        assert!((rows[0].get_f64("gap").unwrap() - 0.5).abs() < 1e-12);
        assert!(rows[1].get("error").is_ok(),
                "a failed baseline reports inside its row");
        let md = body.get("markdown").unwrap().as_str().unwrap();
        assert!(md.contains("| micro-mlp |")
                && md.contains("+50.00%"), "{md}");
    }

    #[test]
    fn gap_response_oracle_failure_fails_the_verb() {
        let outcomes = vec![
            Err(WireError::new(ErrorCode::Internal, "exact died")),
            Ok(gap_jr(Method::Ga, 1.0)),
        ];
        let resp = gap_response(&outcomes);
        let e = resp.get("error").unwrap();
        assert_eq!(e.get("code").unwrap().as_str().unwrap(),
                   "internal");
    }

    const SPEC_BODY: &str = r#"{"name": "custom-mlp",
        "layers": [
            {"name": "fc1", "kind": "fc",
             "dims": [1, 256, 784, 1, 1, 1, 1]},
            {"name": "fc2", "kind": "fc",
             "dims": [1, 10, 256, 1, 1, 1, 1]}
        ]}"#;

    #[test]
    fn parse_request_accepts_inline_workload_spec() {
        let j = Json::parse(&format!(
            r#"{{"method": "random", "workload_spec": {SPEC_BODY}}}"#
        ))
        .unwrap();
        let r = parse_request(&j).unwrap();
        let w = Arc::clone(r.spec.as_ref().expect("inline spec parsed"));
        assert_eq!(w.name, "custom-mlp");
        assert_eq!(r.workload, "custom-mlp", "display name follows spec");
        assert_eq!(w.len(), 2);
        assert!(r.cache_key(&w).starts_with("spec:"),
                "inline specs must not key caches by display name");
    }

    #[test]
    fn parse_request_rejects_bad_inline_specs_as_spec_invalid() {
        for body in [
            r#"{"workload_spec": {"name": "x", "layers": []}}"#,
            r#"{"workload_spec": {"layers": [1]}}"#,
            r#"{"workload_spec": "vgg16"}"#,
            r#"{"workload_spec": {"name": "x", "layers": [
                {"name": "a", "kind": "fc",
                 "dims": [1, 8, 8, 1, 1, 1, 1, 1]}]}}"#,
        ] {
            let j = Json::parse(body).unwrap();
            let err = parse_request(&j).unwrap_err();
            assert_eq!(err.code, ErrorCode::SpecInvalid, "{body}");
        }
    }

    #[test]
    fn parse_sweep_carries_inline_spec_to_every_cell() {
        let j = Json::parse(&format!(
            r#"{{"verb": "sweep", "methods": ["random", "ga"],
                 "seeds": [1, 2], "workload_spec": {SPEC_BODY}}}"#
        ))
        .unwrap();
        let reqs = parse_sweep(&j).unwrap();
        assert_eq!(reqs.len(), 4);
        for r in &reqs {
            assert_eq!(r.workload, "custom-mlp");
            assert!(r.spec.is_some());
        }
        // spec + workloads list is ambiguous and must be rejected
        let j = Json::parse(&format!(
            r#"{{"verb": "sweep", "workloads": ["vgg16"],
                 "workload_spec": {SPEC_BODY}}}"#
        ))
        .unwrap();
        let err = parse_sweep(&j).unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest);
        assert!(err.message.contains("not both"), "{}", err.message);
    }

    #[test]
    fn parse_sweep_rejects_empty_and_bad_lists() {
        let empty = Json::parse(
            r#"{"verb": "sweep", "workloads": []}"#).unwrap();
        assert!(parse_sweep(&empty).is_err());
        let bad = Json::parse(
            r#"{"verb": "sweep", "methods": ["ga", "quantum"]}"#)
            .unwrap();
        assert!(parse_sweep(&bad).is_err());
        let wrong_type = Json::parse(
            r#"{"verb": "sweep", "workloads": "resnet18"}"#).unwrap();
        assert!(parse_sweep(&wrong_type).is_err());
    }

    #[test]
    fn envelope_shapes_are_versioned_and_exclusive() {
        let ok = Response::ok(obj(vec![("x", num(1.0))]));
        assert_eq!(ok.get("protocol").unwrap().as_f64().unwrap(), 1.0);
        assert!(ok.get("ok").is_ok());
        assert!(ok.get("error").is_err());
        let err = Response::err(
            &WireError::new(ErrorCode::QueueFull, "full")
                .with("retry_after_ms", num(250.0)),
        );
        assert_eq!(err.get("protocol").unwrap().as_f64().unwrap(),
                   1.0);
        assert!(err.get("ok").is_err());
        let body = err.get("error").unwrap();
        assert_eq!(body.get("code").unwrap().as_str().unwrap(),
                   "queue_full");
        assert_eq!(body.get("message").unwrap().as_str().unwrap(),
                   "full");
        assert_eq!(
            body.get("retry_after_ms").unwrap().as_f64().unwrap(),
            250.0
        );
    }

    #[test]
    fn error_codes_are_stable_snake_case() {
        for (code, name) in [
            (ErrorCode::BadRequest, "bad_request"),
            (ErrorCode::UnknownVerb, "unknown_verb"),
            (ErrorCode::UnknownWorkload, "unknown_workload"),
            (ErrorCode::SpecInvalid, "spec_invalid"),
            (ErrorCode::TooLarge, "too_large"),
            (ErrorCode::QueueFull, "queue_full"),
            (ErrorCode::JobNotFound, "job_not_found"),
            (ErrorCode::ShuttingDown, "shutting_down"),
            (ErrorCode::UnsupportedVersion, "unsupported_version"),
            (ErrorCode::Cancelled, "cancelled"),
            (ErrorCode::Internal, "internal"),
            (ErrorCode::DeadlineExceeded, "deadline_exceeded"),
        ] {
            assert_eq!(code.as_str(), name);
        }
    }

    #[test]
    fn parse_request_validates_deadline_ms() {
        let j = Json::parse(r#"{"deadline_ms": 1500}"#).unwrap();
        assert_eq!(parse_request(&j).unwrap().deadline_ms, 1500);
        let j = Json::parse(r#"{"workload": "vgg16"}"#).unwrap();
        assert_eq!(parse_request(&j).unwrap().deadline_ms, 0,
                   "absent deadline means none");
        for body in [
            r#"{"deadline_ms": -1}"#,
            r#"{"deadline_ms": 1.5}"#,
            r#"{"deadline_ms": 1e300}"#,
            r#"{"deadline_ms": "soon"}"#,
        ] {
            let j = Json::parse(body).unwrap();
            let err = parse_request(&j).unwrap_err();
            assert_eq!(err.code, ErrorCode::BadRequest, "{body}");
        }
    }

    #[test]
    fn sweep_cells_inherit_the_deadline() {
        let j = Json::parse(
            r#"{"verb": "sweep", "methods": ["random", "ga"],
                "deadline_ms": 2000}"#)
            .unwrap();
        for r in parse_sweep(&j).unwrap() {
            assert_eq!(r.deadline_ms, 2000);
        }
    }

    #[test]
    fn deadline_cut_results_flag_only_when_hit() {
        let mut r = JobResult {
            request: JobRequest::default(),
            edp: 1.0,
            full_model_edp: 1.0,
            energy: 1.0,
            latency: 1.0,
            groups: Vec::new(),
            fused_names: Vec::new(),
            iters: 0,
            evals: 0,
            wall_seconds: 0.0,
            stored: false,
            deadline_hit: false,
            exact: None,
        };
        let clean = result_to_json(&r);
        assert!(clean.get("deadline_exceeded").is_err(),
                "field must be absent (byte-identical) when unused");
        r.deadline_hit = true;
        let cut = result_to_json(&r);
        assert_eq!(cut.get("deadline_exceeded").unwrap(),
                   &Json::Bool(true));
    }

    #[test]
    fn chaos_status_reports_availability_and_sites() {
        let j = Json::parse(r#"{"verb": "chaos"}"#).unwrap();
        let resp = run_chaos(&j);
        let body = resp.get("ok").unwrap();
        let avail = body.get("available").unwrap();
        assert_eq!(avail,
                   &Json::Bool(cfg!(feature = "fault-injection")));
        let sites = match body.get("sites").unwrap() {
            Json::Arr(v) => v.len(),
            other => panic!("sites not an array: {other:?}"),
        };
        assert_eq!(sites, crate::util::fault::SITES.len());
        // unknown actions are a bad_request, not a panic
        let j = Json::parse(
            r#"{"verb": "chaos", "action": "explode"}"#).unwrap();
        let err = run_chaos(&j);
        assert!(err.get("error").is_ok());
    }

    #[cfg(not(feature = "fault-injection"))]
    #[test]
    fn chaos_arm_requires_the_feature() {
        let j = Json::parse(
            r#"{"verb": "chaos", "action": "arm",
                "site": "eval.slow"}"#)
            .unwrap();
        let resp = run_chaos(&j);
        let body = resp.get("error").unwrap();
        assert_eq!(body.get("code").unwrap().as_str().unwrap(),
                   "bad_request");
        assert!(body.get("message").unwrap().as_str().unwrap()
            .contains("fault-injection"));
    }

    #[cfg(feature = "fault-injection")]
    #[test]
    fn chaos_arm_and_reset_round_trip() {
        use crate::util::fault;
        // the registry is process-global and other lib tests share
        // this process: serialize with every other arming test
        let _g = fault::registry_lock();
        fault::disarm_all();
        let j = Json::parse(
            r#"{"verb": "chaos", "action": "arm",
                "site": "eval.slow", "mode": "prob",
                "p": 0.5, "seed": 7}"#)
            .unwrap();
        let resp = run_chaos(&j);
        let body = resp.get("ok").unwrap();
        let armed = match body.get("armed").unwrap() {
            Json::Arr(v) => v.clone(),
            other => panic!("armed not an array: {other:?}"),
        };
        assert!(armed.iter().any(|row| {
            row.get("site").unwrap().as_str().unwrap() == "eval.slow"
        }));
        let j = Json::parse(
            r#"{"verb": "chaos", "action": "reset"}"#).unwrap();
        let resp = run_chaos(&j);
        let body = resp.get("ok").unwrap();
        assert!(matches!(body.get("armed").unwrap(),
                         Json::Arr(v) if v.is_empty()));
        assert!(fault::snapshot().is_empty());
        // arming an unknown site reports the known list
        let j = Json::parse(
            r#"{"verb": "chaos", "action": "arm",
                "site": "no.such.site"}"#)
            .unwrap();
        assert!(run_chaos(&j).get("error").is_ok());
    }

    #[test]
    fn job_errors_classify_by_cause() {
        assert_eq!(job_error("job cancelled").code,
                   ErrorCode::Cancelled);
        assert_eq!(job_error("unknown workload \"zzz\"").code,
                   ErrorCode::UnknownWorkload);
        assert_eq!(job_error("disk on fire").code,
                   ErrorCode::Internal);
    }

    #[test]
    fn validate_workloads_flags_unknown_names() {
        let bad = JobRequest {
            workload: "no-such-model".into(),
            ..Default::default()
        };
        let err =
            validate_workloads(std::slice::from_ref(&bad)).unwrap_err();
        assert_eq!(err.code, ErrorCode::UnknownWorkload);
        let good = JobRequest::default(); // resnet18
        assert!(validate_workloads(std::slice::from_ref(&good)).is_ok());
    }

    #[test]
    fn job_error_is_not_fooled_by_embedded_user_text() {
        // a workload *named* "job cancelled": the {:?}-quoted name sits
        // after the anchored head, so the class stays unknown_workload
        let e = job_error(
            "unknown workload \"job cancelled\" (not a zoo model or a \
             data/workloads/*.json spec)");
        assert_eq!(e.code, ErrorCode::UnknownWorkload);
        // either phrase embedded deeper in a message is not a match
        assert_eq!(
            job_error("stage failed: job cancelled by peer").code,
            ErrorCode::Internal);
        assert_eq!(
            job_error("io error in unknown workload scan").code,
            ErrorCode::Internal);
        // and the cancellation literal must match exactly, not by
        // prefix
        assert_eq!(job_error("job cancelled the lease").code,
                   ErrorCode::Internal);
    }

    #[test]
    fn spec_error_caps_match_on_shape_not_substring() {
        use anyhow::anyhow;
        let byte_cap = spec_error(anyhow!(
            "workload_spec of 99999 bytes exceeds the cap of 65536"));
        assert_eq!(byte_cap.code, ErrorCode::TooLarge);
        let layer_cap = spec_error(anyhow!(
            "workload_spec: {} layers exceed the cap of {}",
            spec::MAX_SPEC_LAYERS + 1,
            spec::MAX_SPEC_LAYERS));
        assert_eq!(layer_cap.code, ErrorCode::TooLarge);
        // a layer *named* like the cap message: the {:?}-quoted name
        // breaks both the digit head and the unquoted tail, so the
        // class stays spec_invalid instead of too_large
        let forged = spec_error(anyhow!(
            "workload_spec: duplicate layer name \"9 layers exceed \
             the cap of {}\"",
            spec::MAX_SPEC_LAYERS));
        assert_eq!(forged.code, ErrorCode::SpecInvalid);
        // plain validation failures stay spec_invalid too
        let plain = spec_error(anyhow!(
            "workload_spec: dims must have 7 entries"));
        assert_eq!(plain.code, ErrorCode::SpecInvalid);
    }

    #[test]
    fn parse_request_parses_force_flag() {
        assert!(!parse_request(&Json::parse("{}").unwrap())
            .unwrap()
            .force);
        let j = Json::parse(r#"{"force": true}"#).unwrap();
        assert!(parse_request(&j).unwrap().force);
        let j = Json::parse(r#"{"force": "yes"}"#).unwrap();
        assert_eq!(parse_request(&j).unwrap_err().code,
                   ErrorCode::BadRequest);
        // sweeps inherit the flag into every cell
        let j = Json::parse(
            r#"{"verb": "sweep", "seeds": [1, 2], "force": true}"#)
            .unwrap();
        assert!(parse_sweep(&j).unwrap().iter().all(|r| r.force));
    }

    #[test]
    fn parse_request_validates_prune_mode() {
        assert_eq!(parse_request(&Json::parse("{}").unwrap())
                       .unwrap()
                       .prune,
                   PruneMode::On);
        for (text, want) in [("on", PruneMode::On),
                             ("off", PruneMode::Off),
                             ("full", PruneMode::Full)] {
            let j = Json::parse(&format!(r#"{{"prune": "{text}"}}"#))
                .unwrap();
            assert_eq!(parse_request(&j).unwrap().prune, want);
        }
        for bad in [r#"{"prune": "sometimes"}"#, r#"{"prune": true}"#,
                    r#"{"prune": 1}"#] {
            let j = Json::parse(bad).unwrap();
            assert_eq!(parse_request(&j).unwrap_err().code,
                       ErrorCode::BadRequest,
                       "{bad} must be rejected");
        }
        // sweeps inherit the mode into every cell
        let j = Json::parse(
            r#"{"verb": "sweep", "seeds": [1, 2], "prune": "full"}"#)
            .unwrap();
        assert!(parse_sweep(&j)
            .unwrap()
            .iter()
            .all(|r| r.prune == PruneMode::Full));
    }

    #[test]
    fn parse_request_validates_warm_frac() {
        let defaulted =
            parse_request(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(defaulted.warm_frac, 0.0);
        let j = Json::parse(r#"{"warm_frac": 0.25}"#).unwrap();
        assert_eq!(parse_request(&j).unwrap().warm_frac, 0.25);
        for bad in [r#"{"warm_frac": -0.1}"#, r#"{"warm_frac": 1.5}"#,
                    r#"{"warm_frac": "half"}"#,
                    r#"{"warm_frac": 1e400}"#] {
            let j = Json::parse(bad).unwrap();
            assert_eq!(parse_request(&j).unwrap_err().code,
                       ErrorCode::BadRequest,
                       "{bad} must be rejected");
        }
        // sweeps inherit the fraction into every cell
        let j = Json::parse(
            r#"{"verb": "sweep", "seeds": [1, 2], "warm_frac": 0.5}"#)
            .unwrap();
        assert!(parse_sweep(&j)
            .unwrap()
            .iter()
            .all(|r| r.warm_frac == 0.5));
    }

    fn error_code_of(step: Step) -> String {
        match step {
            Step::Reply(j) => j
                .get("error")
                .and_then(|e| e.get("code"))
                .and_then(|c| c.as_str())
                .map(str::to_string)
                .unwrap_or_default(),
            Step::Enter(_) => "<parked>".to_string(),
        }
    }

    #[test]
    fn status_of_a_pruned_job_is_job_not_found_not_a_panic() {
        let coord = Coordinator::new(None, 1).unwrap();
        let shutdown = ShutdownFlag::default();
        let id = coord
            .submit_tracked(JobRequest {
                method: Method::Random,
                seconds: 0.0,
                max_iters: 1,
                ..Default::default()
            })
            .unwrap();
        let _ = coord.cancel(id);
        coord.forget_job(id); // simulate table pruning after the check
        let step = dispatch(
            &format!(r#"{{"verb": "status", "job_id": {id}}}"#),
            &coord,
            &shutdown,
        );
        assert_eq!(error_code_of(step), "job_not_found");
    }

    #[test]
    fn status_never_panics_while_jobs_are_pruned_concurrently() {
        use std::sync::atomic::{AtomicBool, AtomicU64};
        // pre-fix, `status` looked the job up twice (existence check,
        // then unwrap); a prune landing between the two panicked the
        // dispatcher. Hammer that window from a churn thread.
        let coord = Arc::new(Coordinator::new(None, 1).unwrap());
        let shutdown = ShutdownFlag::default();
        let published = Arc::new(AtomicU64::new(0));
        let stop = Arc::new(AtomicBool::new(false));
        let flipper = {
            let coord = Arc::clone(&coord);
            let published = Arc::clone(&published);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                for _ in 0..1500 {
                    let Ok(id) = coord.submit_tracked(JobRequest {
                        method: Method::Random,
                        seconds: 0.0,
                        max_iters: 1,
                        ..Default::default()
                    }) else {
                        continue;
                    };
                    let _ = coord.cancel(id);
                    published.store(id, Ordering::SeqCst);
                    coord.forget_job(id);
                }
                stop.store(true, Ordering::SeqCst);
            })
        };
        while !stop.load(Ordering::SeqCst) {
            let id = published.load(Ordering::SeqCst);
            let step = dispatch(
                &format!(r#"{{"verb": "status", "job_id": {id}}}"#),
                &coord,
                &shutdown,
            );
            // every outcome is a reply (found or job_not_found) —
            // never a panic
            assert!(matches!(step, Step::Reply(_)));
        }
        flipper.join().unwrap();
    }
}
