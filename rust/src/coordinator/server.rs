//! TCP front-end for the coordinator: a line-delimited JSON protocol.
//!
//! The complete wire reference (every verb, parameter, limit and error
//! shape, with example request/response lines) lives in
//! `docs/protocol.md`; the short form:
//!
//!   {"verb": "optimize", "workload": "resnet18", "config": "large",
//!    "method": "fadiff", "seconds": 5, "seed": 1, "chains": 8}
//!   {"verb": "sweep", "workloads": ["resnet18", "vgg16"],
//!    "methods": ["ga", "random"], "seeds": [1, 2], "seconds": 5}
//!   {"verb": "submit", "workload": "gpt3", "method": "ga",
//!    "seconds": 120}
//!   {"verb": "status", "job_id": 7}
//!   {"verb": "cancel", "job_id": 7}
//!   {"verb": "workloads"}                       (list the zoo + specs)
//!   {"verb": "workloads", "describe": "vgg16"}  (full description)
//!   {"verb": "metrics"}
//!   {"verb": "ping"}
//!   {"verb": "shutdown"}
//!
//! `chains` (optional, default 0 = method default) sets the parallel
//! chain count of the gradient methods' native backend; it applies to
//! `optimize`/`submit` and to every cell of a `sweep`. GA / BO /
//! random ignore it.
//!
//! `workload` accepts zoo names and `data/workloads/*.json` spec
//! stems; alternatively `workload_spec` carries a full inline workload
//! document (the JSON DSL of [`crate::workload::spec`]), validated and
//! size-capped at parse time, on `optimize` / `submit` / `sweep`
//! (where it applies to every cell and excludes a `workloads` list).
//!
//! Response (one line): {"ok":true,...} or {"ok":false,"error":"..."},
//! serialized with [`Json::compact`] so payload content can never break
//! the framing. Each connection may send any number of requests; the
//! server handles connections on acceptor-spawned threads and forwards
//! jobs to the coordinator queue.
//!
//! `optimize` blocks the requesting connection until its job finishes;
//! `submit` returns a job id immediately for long jobs (poll with
//! `status`, stop with `cancel`). `sweep` fans a method x workload x
//! seed grid through the queue and aggregates every outcome in one
//! response. All jobs share the coordinator's cross-job evaluation
//! caches and persistent pool, so repeated work is served warm.
//!
//! Robustness: requests are size-capped (oversized lines are answered
//! with an error and drained), depth-capped (see
//! [`crate::util::json::MAX_PARSE_DEPTH`]), tolerated when malformed or
//! truncated (one-line error, connection stays usable), and reads poll
//! the shutdown flag so `serve_on` can always join every connection.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::util::json::{arr, num, obj, s as js, Json};
use crate::workload::spec;

use super::{resolve_workload, workload_catalog, Coordinator,
            JobRequest, JobResult, Method, ShutdownFlag};

/// Requests larger than this (one line, bytes) are rejected without
/// buffering the excess.
pub const MAX_REQUEST_BYTES: usize = 1 << 20;

/// Upper bound on the method x workload x seed grid of one `sweep`.
pub const MAX_SWEEP_JOBS: usize = 256;

/// Upper bound on the per-request parallel chain count: each chain
/// allocates ~100 KB of SoA state on a large workload, so an
/// unclamped value would let one request OOM the server.
pub const MAX_CHAINS: usize = 256;

/// How often blocked reads wake to poll the shutdown flag.
const READ_POLL: Duration = Duration::from_millis(150);

/// Parse one request line into a JobRequest (for the `optimize` /
/// `submit` verbs; also supplies the per-job defaults of `sweep`).
pub fn parse_request(j: &Json) -> Result<JobRequest> {
    let mut req = JobRequest::default();
    if let Ok(w) = j.get("workload") {
        req.workload = w.as_str()?.to_string();
    }
    if let Ok(c) = j.get("config") {
        req.config = c.as_str()?.to_string();
    }
    if let Ok(m) = j.get("method") {
        req.method = Method::parse(m.as_str()?)?;
    }
    if let Ok(t) = j.get("seconds") {
        req.seconds = t.as_f64()?;
    }
    if let Ok(i) = j.get("max_iters") {
        req.max_iters = i.as_usize()?;
    }
    if let Ok(sd) = j.get("seed") {
        req.seed = sd.as_f64()? as u64;
    }
    if let Ok(c) = j.get("chains") {
        req.chains = c.as_usize()?;
        if req.chains > MAX_CHAINS {
            bail!("chains {} exceeds the cap of {MAX_CHAINS}",
                  req.chains);
        }
    }
    if let Ok(spec_j) = j.get("workload_spec") {
        // size-capped and fully validated at parse time, like `chains`:
        // a bad spec is a one-line error before any job is queued
        let w = spec::parse_inline(spec_j)?;
        req.workload = w.name.clone();
        req.spec = Some(Arc::new(w));
    }
    Ok(req)
}

fn parse_str_list(j: &Json, key: &str, default: &str)
                  -> Result<Vec<String>> {
    match j.get(key) {
        Err(_) => Ok(vec![default.to_string()]),
        Ok(v) => {
            let items = v.as_arr()?;
            items
                .iter()
                .map(|x| Ok(x.as_str()?.to_string()))
                .collect()
        }
    }
}

/// Expand a `sweep` request into its method x workload x seed grid.
/// Scalar fields (`config`, `seconds`, `max_iters`, and the singular
/// `workload`/`method`/`seed`) provide the shared defaults.
pub fn parse_sweep(j: &Json) -> Result<Vec<JobRequest>> {
    let base = parse_request(j)?;
    if base.spec.is_some() && j.get("workloads").is_ok() {
        bail!("a sweep takes either an inline workload_spec (applied \
               to every cell) or a workloads list, not both");
    }
    let workloads = parse_str_list(j, "workloads", &base.workload)?;
    let methods: Vec<Method> = match j.get("methods") {
        Err(_) => vec![base.method],
        Ok(v) => v
            .as_arr()?
            .iter()
            .map(|x| Method::parse(x.as_str()?))
            .collect::<Result<_>>()?,
    };
    let seeds: Vec<u64> = match j.get("seeds") {
        Err(_) => vec![base.seed],
        Ok(v) => v
            .as_arr()?
            .iter()
            .map(|x| Ok(x.as_f64()? as u64))
            .collect::<Result<_>>()?,
    };
    let grid = (workloads.len() as u128)
        .saturating_mul(methods.len() as u128)
        .saturating_mul(seeds.len() as u128);
    if grid == 0 {
        bail!("empty sweep grid (workloads/methods/seeds)");
    }
    if grid > MAX_SWEEP_JOBS as u128 {
        bail!("sweep grid of {grid} jobs exceeds the cap of \
               {MAX_SWEEP_JOBS}");
    }
    let mut reqs = Vec::with_capacity(grid as usize);
    for w in &workloads {
        for m in &methods {
            for &seed in &seeds {
                reqs.push(JobRequest {
                    workload: w.clone(),
                    config: base.config.clone(),
                    method: *m,
                    seconds: base.seconds,
                    max_iters: base.max_iters,
                    seed,
                    chains: base.chains,
                    spec: base.spec.clone(),
                });
            }
        }
    }
    Ok(reqs)
}

/// The result payload minus the envelope's `ok` flag (shared by
/// `optimize` responses, `status` results, and `sweep` entries).
fn result_fields(r: &JobResult) -> Vec<(&'static str, Json)> {
    vec![
        ("workload", js(&r.request.workload)),
        ("config", js(&r.request.config)),
        ("method", js(r.request.method.name())),
        ("seed", num(r.request.seed as f64)),
        ("chains", num(r.request.chains as f64)),
        ("edp", num(r.edp)),
        ("full_model_edp", num(r.full_model_edp)),
        ("energy_pj", num(r.energy)),
        ("latency_cycles", num(r.latency)),
        ("fused_groups",
         Json::Arr(r.fused_names
             .iter()
             .map(|g| Json::Arr(g.iter().map(|n| js(n)).collect()))
             .collect())),
        ("iters", num(r.iters as f64)),
        ("evals", num(r.evals as f64)),
        ("wall_seconds", num(r.wall_seconds)),
    ]
}

/// Serialize a JobResult for the wire.
pub fn result_to_json(r: &JobResult) -> Json {
    let mut fields = vec![("ok", Json::Bool(true))];
    fields.extend(result_fields(r));
    obj(fields)
}

fn error_json(msg: &str) -> Json {
    obj(vec![("ok", Json::Bool(false)), ("error", js(msg))])
}

fn get_job_id(j: &Json) -> Result<u64> {
    let x = j.get("job_id")?.as_f64()?;
    if !(x.is_finite() && x >= 0.0 && x.fract() == 0.0) {
        bail!("job_id must be a non-negative integer");
    }
    Ok(x as u64)
}

/// The `workloads` verb: list every servable workload (zoo builders +
/// checked-in spec files, via the shared
/// [`super::workload_catalog`]), or — with `describe` (a name) or an
/// inline `workload_spec` — return one workload's full description
/// (the canonical spec plus derived summary fields).
fn run_workloads(j: &Json) -> Json {
    if let Ok(spec_j) = j.get("workload_spec") {
        // describe-an-inline-spec doubles as a validation endpoint
        return match spec::parse_inline(spec_j) {
            Err(e) => error_json(&e.to_string()),
            Ok(w) => obj(vec![
                ("ok", Json::Bool(true)),
                ("workload", spec::describe_json(&w)),
            ]),
        };
    }
    if let Ok(name_j) = j.get("describe") {
        let name = match name_j.as_str() {
            Err(_) => return error_json("describe must be a string"),
            Ok(n) => n,
        };
        return match resolve_workload(name) {
            Err(e) => error_json(&e.to_string()),
            Ok(w) => obj(vec![
                ("ok", Json::Bool(true)),
                ("workload", spec::describe_json(&w)),
            ]),
        };
    }
    let rows = workload_catalog()
        .into_iter()
        .map(|(name, source, outcome)| match outcome {
            Ok(w) => obj(vec![
                ("name", js(&name)),
                ("source", js(source)),
                ("layers", num(w.len() as f64)),
                ("replicas", num(w.replicas)),
                ("total_macs", num(w.total_ops())),
            ]),
            // a broken checked-in file should be visible, not hidden
            Err(e) => obj(vec![
                ("name", js(&name)),
                ("source", js(source)),
                ("error", js(&e.to_string())),
            ]),
        })
        .collect::<Vec<_>>();
    obj(vec![
        ("ok", Json::Bool(true)),
        ("count", num(rows.len() as f64)),
        ("workloads", arr(rows)),
    ])
}

fn run_sweep(j: &Json, coord: &Coordinator) -> Json {
    let reqs = match parse_sweep(j) {
        Err(e) => return error_json(&e.to_string()),
        Ok(r) => r,
    };
    let jobs = reqs.len();
    // fan the whole grid into the queue first, then collect: the grid
    // runs at full worker parallelism, and same-(workload, config)
    // cells share one evaluation cache
    let handles: Vec<_> = reqs
        .into_iter()
        .map(|req| (req.clone(), coord.submit(req)))
        .collect();
    let mut results = Vec::with_capacity(jobs);
    let mut completed = 0usize;
    let mut failed = 0usize;
    for (req, h) in handles {
        let entry = match h.wait() {
            Some(Ok(r)) => {
                completed += 1;
                result_to_json(&r)
            }
            outcome => {
                failed += 1;
                let msg = match outcome {
                    Some(Err(e)) => e,
                    _ => "worker dropped the job".to_string(),
                };
                obj(vec![
                    ("ok", Json::Bool(false)),
                    ("workload", js(&req.workload)),
                    ("config", js(&req.config)),
                    ("method", js(req.method.name())),
                    ("seed", num(req.seed as f64)),
                    ("error", js(&msg)),
                ])
            }
        };
        results.push(entry);
    }
    obj(vec![
        ("ok", Json::Bool(true)),
        ("jobs", num(jobs as f64)),
        ("completed", num(completed as f64)),
        ("failed", num(failed as f64)),
        ("results", arr(results)),
    ])
}

/// Compute the one-line response for one request line. Total: every
/// input — malformed, unknown, oversized grids, failing jobs — maps to
/// a JSON answer, never a dropped connection or a panic.
fn respond(line: &str, coord: &Coordinator, shutdown: &ShutdownFlag)
           -> Json {
    let j = match Json::parse(line) {
        Err(e) => return error_json(&format!("bad json: {e}")),
        Ok(j) => j,
    };
    if j.as_obj().is_err() {
        return error_json("request must be a JSON object");
    }
    let verb = match j.get("verb") {
        Err(_) => "optimize".to_string(),
        Ok(v) => match v.as_str() {
            Ok(s) => s.to_string(),
            Err(_) => return error_json("verb must be a string"),
        },
    };
    match verb.as_str() {
        "ping" => obj(vec![("ok", Json::Bool(true)),
                           ("pong", Json::Bool(true))]),
        "metrics" => {
            let mut m = coord.metrics_json();
            if let Json::Obj(map) = &mut m {
                map.insert("ok".into(), Json::Bool(true));
            }
            m
        }
        "shutdown" => {
            shutdown.0.store(true, Ordering::SeqCst);
            obj(vec![("ok", Json::Bool(true)),
                     ("shutting_down", Json::Bool(true))])
        }
        "optimize" => match parse_request(&j) {
            Err(e) => error_json(&e.to_string()),
            Ok(req) => match coord.run(req) {
                Ok(r) => result_to_json(&r),
                Err(e) => error_json(&e.to_string()),
            },
        },
        "submit" => match parse_request(&j)
            .and_then(|req| coord.submit_tracked(req))
        {
            Err(e) => error_json(&e.to_string()),
            Ok(id) => obj(vec![
                ("ok", Json::Bool(true)),
                ("job_id", num(id as f64)),
                ("status", js("queued")),
            ]),
        },
        "status" => match get_job_id(&j) {
            Err(e) => error_json(&e.to_string()),
            Ok(id) => match coord.job_status(id) {
                None => error_json(&format!("unknown job id {id}")),
                Some((status, result)) => {
                    let mut fields = vec![
                        ("ok", Json::Bool(true)),
                        ("job_id", num(id as f64)),
                        ("status", js(status.name())),
                    ];
                    match result {
                        Some(Ok(r)) => fields
                            .push(("result", obj(result_fields(&r)))),
                        Some(Err(e)) => fields.push(("error", js(&e))),
                        None => {}
                    }
                    obj(fields)
                }
            },
        },
        "cancel" => match get_job_id(&j) {
            Err(e) => error_json(&e.to_string()),
            Ok(id) => match coord.cancel(id) {
                None => error_json(&format!("unknown job id {id}")),
                Some(status) => obj(vec![
                    ("ok", Json::Bool(true)),
                    ("job_id", num(id as f64)),
                    ("status", js(status.name())),
                ]),
            },
        },
        "sweep" => run_sweep(&j, coord),
        "workloads" => run_workloads(&j),
        other => error_json(&format!("unknown verb {other:?}")),
    }
}

fn write_response(stream: &mut TcpStream, j: &Json) -> Result<()> {
    let mut text = j.compact();
    text.push('\n');
    stream.write_all(text.as_bytes())?;
    stream.flush()?;
    Ok(())
}

fn is_retry(kind: std::io::ErrorKind) -> bool {
    matches!(
        kind,
        std::io::ErrorKind::WouldBlock
            | std::io::ErrorKind::TimedOut
            | std::io::ErrorKind::Interrupted
    )
}

/// `read_until(b'\n')` with a hard cap on retained bytes: at most
/// `MAX_REQUEST_BYTES + 1` bytes stay in `buf`; the excess of an
/// oversized line is consumed and dropped as it streams in, so a fast
/// client cannot balloon server memory by never sending a newline. A
/// newline discovered in the dropped region is still appended, so
/// callers always see oversized lines terminate. Mirrors `read_until`'s
/// contract otherwise: `Ok(0)` = EOF with nothing consumed, trailing
/// bytes without `\n` = EOF mid-line, `Err(WouldBlock/TimedOut)` = no
/// data before the read timeout (bytes read so far remain in `buf`).
fn read_line_capped<R: BufRead>(reader: &mut R, buf: &mut Vec<u8>)
                                -> std::io::Result<usize> {
    let mut total = 0usize;
    loop {
        let (consumed, done) = {
            let available = reader.fill_buf()?;
            if available.is_empty() {
                return Ok(total); // EOF
            }
            let newline = available.iter().position(|&b| b == b'\n');
            let take = newline.map_or(available.len(), |i| i + 1);
            let room =
                (MAX_REQUEST_BYTES + 1).saturating_sub(buf.len());
            let keep = take.min(room);
            buf.extend_from_slice(&available[..keep]);
            if keep < take && newline.is_some() {
                buf.push(b'\n'); // line ended inside the dropped region
            }
            (take, newline.is_some())
        };
        reader.consume(consumed);
        total += consumed;
        if done {
            return Ok(total);
        }
    }
}

/// Handle one client connection.
fn handle(stream: TcpStream, coord: &Coordinator, shutdown: &ShutdownFlag)
          -> Result<()> {
    let peer = stream.peer_addr()?;
    // short read timeout: blocked reads wake to poll the shutdown flag,
    // so serve_on can join this thread even under idle clients
    stream.set_read_timeout(Some(READ_POLL))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut stream = stream;
    // raw bytes, not String: invalid UTF-8 must degrade to a JSON error
    // (via lossy decode), never desynchronize or kill the connection
    let mut buf: Vec<u8> = Vec::new();
    // true while draining the tail of an already-answered oversized line
    let mut discarding = false;
    loop {
        if shutdown.0.load(Ordering::SeqCst) {
            return Ok(());
        }
        match read_line_capped(&mut reader, &mut buf) {
            Err(e) if is_retry(e.kind()) => {
                // partial line so far; bound the buffer while waiting
                if !discarding && buf.len() > MAX_REQUEST_BYTES {
                    write_response(
                        &mut stream,
                        &error_json(&format!(
                            "request line exceeds {MAX_REQUEST_BYTES} \
                             bytes"
                        )),
                    )?;
                    discarding = true;
                }
                if discarding {
                    buf.clear();
                }
                continue;
            }
            Err(e) => return Err(e.into()),
            // EOF: done, unless a stalled partial line is still pending
            // — that truncated tail deserves its one-line answer below
            Ok(0) if buf.is_empty() || discarding => return Ok(()),
            Ok(_) => {}
        }
        let complete = buf.last() == Some(&b'\n');
        if discarding {
            if complete {
                // oversized line finally ended; resume normal service
                discarding = false;
                buf.clear();
                continue;
            }
            // EOF while draining
            return Ok(());
        }
        if !complete && buf.is_empty() {
            return Ok(());
        }
        let response = if buf.len() > MAX_REQUEST_BYTES {
            error_json(&format!(
                "request line exceeds {MAX_REQUEST_BYTES} bytes"
            ))
        } else {
            let line = String::from_utf8_lossy(&buf);
            let trimmed = line.trim().to_string();
            if trimmed.is_empty() {
                buf.clear();
                if complete {
                    continue;
                }
                return Ok(());
            }
            respond(&trimmed, coord, shutdown)
        };
        buf.clear();
        write_response(&mut stream, &response)?;
        if !complete {
            // half-closed client: the truncated tail was answered
            return Ok(());
        }
        if shutdown.0.load(Ordering::SeqCst) {
            log_line(&format!("shutdown requested by {peer}"));
            return Ok(());
        }
    }
}

fn log_line(msg: &str) {
    eprintln!("[fadiff-serve] {msg}");
}

/// Run the server until a `shutdown` verb arrives. Returns the bound
/// address (useful with port 0 in tests via `bind_and_serve`).
pub fn serve(addr: &str, coord: Coordinator) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    serve_on(listener, coord)
}

/// Serve on an already-bound listener (lets tests pick port 0).
pub fn serve_on(listener: TcpListener, coord: Coordinator) -> Result<()> {
    let local = listener.local_addr()?;
    log_line(&format!("listening on {local} with {} workers",
                      coord.n_workers()));
    let coord = Arc::new(coord);
    let shutdown = ShutdownFlag::default();
    listener.set_nonblocking(true)?;
    let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
    loop {
        if shutdown.0.load(Ordering::SeqCst) {
            break;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                stream.set_nonblocking(false)?;
                let coord = Arc::clone(&coord);
                let flag = ShutdownFlag(Arc::clone(&shutdown.0));
                conns.push(std::thread::spawn(move || {
                    if let Err(e) = handle(stream, &coord, &flag) {
                        log_line(&format!("connection error: {e}"));
                    }
                }));
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(20));
            }
            Err(e) => return Err(e.into()),
        }
        conns.retain(|c| !c.is_finished());
    }
    // every handler polls the shutdown flag at its read timeout, so
    // these joins complete even when clients hold connections open
    for c in conns {
        let _ = c.join();
    }
    log_line("server stopped");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_request_defaults_and_overrides() {
        let j = Json::parse(
            r#"{"workload": "vgg16", "method": "ga", "seconds": 2.5}"#)
            .unwrap();
        let r = parse_request(&j).unwrap();
        assert_eq!(r.workload, "vgg16");
        assert_eq!(r.method, Method::Ga);
        assert_eq!(r.seconds, 2.5);
        assert_eq!(r.config, "large"); // default
        assert_eq!(r.chains, 0); // default: method decides
        let j = Json::parse(r#"{"method": "fadiff", "chains": 4}"#)
            .unwrap();
        assert_eq!(parse_request(&j).unwrap().chains, 4);
    }

    #[test]
    fn parse_request_caps_chains() {
        // an absurd chain count is a one-line error, not a giant
        // ChainBatch allocation (remote-OOM guard)
        for body in [r#"{"chains": 257}"#, r#"{"chains": 1e18}"#] {
            let j = Json::parse(body).unwrap();
            let err = parse_request(&j).unwrap_err().to_string();
            assert!(err.contains("cap"), "{body}: {err}");
        }
        let j = Json::parse(r#"{"chains": 256}"#).unwrap();
        assert_eq!(parse_request(&j).unwrap().chains, 256);
    }

    #[test]
    fn parse_request_rejects_bad_method() {
        let j = Json::parse(r#"{"method": "quantum"}"#).unwrap();
        assert!(parse_request(&j).is_err());
    }

    #[test]
    fn parse_request_rejects_wrong_types() {
        for body in [
            r#"{"workload": 7}"#,
            r#"{"seconds": "fast"}"#,
            r#"{"max_iters": "many"}"#,
            r#"{"method": [1]}"#,
        ] {
            let j = Json::parse(body).unwrap();
            assert!(parse_request(&j).is_err(), "{body}");
        }
    }

    #[test]
    fn parse_sweep_expands_full_grid() {
        let j = Json::parse(
            r#"{"verb": "sweep", "workloads": ["resnet18", "vgg16"],
                "methods": ["ga", "random"], "seeds": [1, 2, 3],
                "config": "small", "seconds": 0.5, "max_iters": 10,
                "chains": 4}"#)
            .unwrap();
        let reqs = parse_sweep(&j).unwrap();
        assert_eq!(reqs.len(), 2 * 2 * 3);
        assert!(reqs.iter().all(|r| r.config == "small"));
        assert!(reqs.iter().all(|r| r.max_iters == 10));
        assert!(reqs.iter().all(|r| r.chains == 4),
                "chains is a shared sweep default");
        let firsts: Vec<_> = reqs
            .iter()
            .map(|r| (r.workload.as_str(), r.method, r.seed))
            .collect();
        assert!(firsts.contains(&(("vgg16"), Method::Random, 3)));
    }

    #[test]
    fn parse_sweep_singular_defaults() {
        let j = Json::parse(
            r#"{"verb": "sweep", "workload": "mobilenet",
                "method": "random"}"#)
            .unwrap();
        let reqs = parse_sweep(&j).unwrap();
        assert_eq!(reqs.len(), 1);
        assert_eq!(reqs[0].workload, "mobilenet");
        assert_eq!(reqs[0].method, Method::Random);
    }

    #[test]
    fn parse_sweep_caps_grid_size() {
        let seeds: Vec<String> =
            (0..300).map(|i| i.to_string()).collect();
        let j = Json::parse(&format!(
            r#"{{"verb": "sweep", "seeds": [{}]}}"#,
            seeds.join(",")
        ))
        .unwrap();
        let err = parse_sweep(&j).unwrap_err().to_string();
        assert!(err.contains("cap"), "{err}");
    }

    const SPEC_BODY: &str = r#"{"name": "custom-mlp",
        "layers": [
            {"name": "fc1", "kind": "fc",
             "dims": [1, 256, 784, 1, 1, 1, 1]},
            {"name": "fc2", "kind": "fc",
             "dims": [1, 10, 256, 1, 1, 1, 1]}
        ]}"#;

    #[test]
    fn parse_request_accepts_inline_workload_spec() {
        let j = Json::parse(&format!(
            r#"{{"method": "random", "workload_spec": {SPEC_BODY}}}"#
        ))
        .unwrap();
        let r = parse_request(&j).unwrap();
        let w = Arc::clone(r.spec.as_ref().expect("inline spec parsed"));
        assert_eq!(w.name, "custom-mlp");
        assert_eq!(r.workload, "custom-mlp", "display name follows spec");
        assert_eq!(w.len(), 2);
        assert!(r.cache_key(&w).starts_with("spec:"),
                "inline specs must not key caches by display name");
    }

    #[test]
    fn parse_request_rejects_bad_inline_specs() {
        for body in [
            r#"{"workload_spec": {"name": "x", "layers": []}}"#,
            r#"{"workload_spec": {"layers": [1]}}"#,
            r#"{"workload_spec": "vgg16"}"#,
            r#"{"workload_spec": {"name": "x", "layers": [
                {"name": "a", "kind": "fc",
                 "dims": [1, 8, 8, 1, 1, 1, 1, 1]}]}}"#,
        ] {
            let j = Json::parse(body).unwrap();
            assert!(parse_request(&j).is_err(), "{body}");
        }
    }

    #[test]
    fn parse_sweep_carries_inline_spec_to_every_cell() {
        let j = Json::parse(&format!(
            r#"{{"verb": "sweep", "methods": ["random", "ga"],
                 "seeds": [1, 2], "workload_spec": {SPEC_BODY}}}"#
        ))
        .unwrap();
        let reqs = parse_sweep(&j).unwrap();
        assert_eq!(reqs.len(), 4);
        for r in &reqs {
            assert_eq!(r.workload, "custom-mlp");
            assert!(r.spec.is_some());
        }
        // spec + workloads list is ambiguous and must be rejected
        let j = Json::parse(&format!(
            r#"{{"verb": "sweep", "workloads": ["vgg16"],
                 "workload_spec": {SPEC_BODY}}}"#
        ))
        .unwrap();
        let err = parse_sweep(&j).unwrap_err().to_string();
        assert!(err.contains("not both"), "{err}");
    }

    #[test]
    fn parse_sweep_rejects_empty_and_bad_lists() {
        let empty = Json::parse(
            r#"{"verb": "sweep", "workloads": []}"#).unwrap();
        assert!(parse_sweep(&empty).is_err());
        let bad = Json::parse(
            r#"{"verb": "sweep", "methods": ["ga", "quantum"]}"#)
            .unwrap();
        assert!(parse_sweep(&bad).is_err());
        let wrong_type = Json::parse(
            r#"{"verb": "sweep", "workloads": "resnet18"}"#).unwrap();
        assert!(parse_sweep(&wrong_type).is_err());
    }
}
