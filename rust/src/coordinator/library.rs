//! Persistent warm-start mapping library (the DNNFuser-style transfer
//! lever): best-known per-layer mappings + fusion decisions, keyed by
//! [`crate::workload::Layer::shape_fingerprint`] under one shard per
//! hardware-config fingerprint.
//!
//! Every completed feasible job *records* its winning strategy layer
//! by layer (improvement-gated on the per-layer EDP contribution in
//! its fusion context, so a worse rerun never clobbers a better
//! incumbent). Jobs that opt in via `warm_frac > 0` get *seed*
//! strategies assembled from the shard — an exact-shape composite plus
//! a nearest-shape composite — which the search methods inject into
//! their starting populations/chains in deterministic order. For a
//! fixed library state seeding is a pure function of the request, so
//! warm results stay reproducible.
//!
//! Seeding is OPT-IN per request (default `warm_frac = 0`) because the
//! library is process-global mutable state: a default-on seed would
//! make two identical requests answer differently depending on which
//! unrelated jobs completed first, breaking the serving layer's
//! same-key-same-answer determinism contract. Recording is always on —
//! it never affects any in-flight result.
//!
//! Persistence rides the content-addressed [`super::store`]: one blob
//! per hardware config under the manifest's optional `library`
//! section, loaded lazily per config and flushed on the coordinator's
//! graceful shutdown like eval-cache segments (the CLI, which has no
//! long-lived process, flushes right after its single job).

use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::config::HwConfig;
use crate::costmodel::tables::WorkloadTables;
use crate::costmodel::{components, layer_cost};
use crate::mapping::{LayerMapping, Strategy, NSLOTS, SLOT_S};
use crate::util::json::{arr, num, obj, s, Json};
use crate::workload::{LayerKind, Workload, DIM_C, DIM_K, NDIMS};

use super::store::{bits_hex, parse_bits, ResultStore};

/// Library counters, surfaced as `metrics.library` and in the `store`
/// verb payload.
#[derive(Debug, Default)]
pub struct LibraryStats {
    /// Per-layer entries accepted past the improvement gate.
    pub records: AtomicU64,
    /// Seed strategies handed to searches.
    pub seeds_served: AtomicU64,
    /// Layers resolved from an exact shape-fingerprint match.
    pub exact_hits: AtomicU64,
    /// Layers resolved from a nearest-shape (same kind) match.
    pub nearest_hits: AtomicU64,
}

/// Best-known mapping for one layer shape within one hw config.
#[derive(Clone, Debug, PartialEq)]
pub struct LibEntry {
    /// Operator class (nearest-match never crosses kinds).
    pub kind: LayerKind,
    /// The shape the mapping was found for.
    pub dims: [usize; NDIMS],
    /// Tiling factors, `factors[dim][slot]`.
    pub factors: [[u64; NSLOTS]; NDIMS],
    /// Whether the layer's output edge was fused in the winning
    /// strategy.
    pub fuse_out: bool,
    /// Per-layer EDP contribution (energy * latency of this layer in
    /// its original fusion context) — the improvement-gate key.
    pub score: f64,
}

impl LibEntry {
    fn to_json(&self, fp: u64) -> Json {
        let mut flat = Vec::with_capacity(NDIMS * NSLOTS);
        for d in 0..NDIMS {
            for slot in 0..NSLOTS {
                flat.push(num(self.factors[d][slot] as f64));
            }
        }
        obj(vec![
            ("fp", s(&format!("{fp:016x}"))),
            ("op", s(self.kind.name())),
            ("dims",
             arr(self.dims.iter().map(|&d| num(d as f64)).collect())),
            ("factors", arr(flat)),
            ("fuse_out", Json::Bool(self.fuse_out)),
            ("score_bits", s(&bits_hex(self.score))),
        ])
    }

    fn from_json(j: &Json) -> Option<(u64, LibEntry)> {
        let fp = u64::from_str_radix(
            j.get("fp").ok()?.as_str().ok()?, 16).ok()?;
        let kind = LayerKind::parse(j.get("op").ok()?.as_str().ok()?)?;
        let dims_v = j.get("dims").ok()?.as_arr().ok()?;
        if dims_v.len() != NDIMS {
            return None;
        }
        let mut dims = [0usize; NDIMS];
        for (d, v) in dims_v.iter().enumerate() {
            dims[d] = v.as_f64().ok()? as usize;
        }
        let flat = j.get("factors").ok()?.as_arr().ok()?;
        if flat.len() != NDIMS * NSLOTS {
            return None;
        }
        let mut factors = [[1u64; NSLOTS]; NDIMS];
        for d in 0..NDIMS {
            for slot in 0..NSLOTS {
                factors[d][slot] =
                    flat[d * NSLOTS + slot].as_f64().ok()? as u64;
            }
        }
        let fuse_out = match j.get("fuse_out").ok()? {
            Json::Bool(b) => *b,
            _ => return None,
        };
        let score =
            parse_bits(j.get("score_bits").ok()?.as_str().ok()?)?;
        Some((fp, LibEntry { kind, dims, factors, fuse_out, score }))
    }
}

type Shard = BTreeMap<u64, LibEntry>;

/// The process-global warm-start library: `config fingerprint ->
/// shape fingerprint -> best entry`. All methods are `&self` and
/// internally locked; the coordinator shares one behind an `Arc`.
#[derive(Default)]
pub struct MappingLibrary {
    shards: Mutex<BTreeMap<String, Shard>>,
    /// Config fps whose in-memory shard is ahead of disk.
    dirty: Mutex<BTreeSet<String>>,
    /// Config fps already merged from the store (lazy, once).
    loaded: Mutex<BTreeSet<String>>,
    stats: LibraryStats,
}

impl MappingLibrary {
    /// An empty library.
    pub fn new() -> MappingLibrary {
        MappingLibrary::default()
    }

    /// Library counters.
    pub fn stats(&self) -> &LibraryStats {
        &self.stats
    }

    /// Total entries across all config shards.
    pub fn entries(&self) -> usize {
        self.shards.lock().unwrap().values().map(Shard::len).sum()
    }

    /// Merge a config's persisted shard into memory (once per config;
    /// later calls are free). In-memory entries win score ties and
    /// strict improvements — a memory entry beating disk re-marks the
    /// shard dirty so the improvement flushes.
    pub fn ensure_loaded(&self, config_fp: &str,
                         store: Option<&ResultStore>) {
        {
            let mut loaded = self.loaded.lock().unwrap();
            if !loaded.insert(config_fp.to_string()) {
                return;
            }
        }
        let Some(store) = store else { return };
        let Some(j) =
            store.load_library(&ResultStore::library_key(config_fp))
        else {
            return;
        };
        let Some(parsed) = parse_shard(&j) else {
            store.reject_library(&ResultStore::library_key(config_fp));
            return;
        };
        let mut shards = self.shards.lock().unwrap();
        let shard = shards.entry(config_fp.to_string()).or_default();
        // conservative: any pre-existing in-memory entry may beat or
        // extend the disk shard, so the merge result must flush
        let memory_ahead = !shard.is_empty();
        for (fp, entry) in parsed {
            match shard.get(&fp) {
                Some(mine) if mine.score <= entry.score => {}
                _ => {
                    shard.insert(fp, entry);
                }
            }
        }
        drop(shards);
        if memory_ahead {
            self.dirty.lock().unwrap().insert(config_fp.to_string());
        }
    }

    /// Record a completed strategy layer by layer. Improvement-gated
    /// per shape on the layer's EDP contribution in its fusion
    /// context. Returns how many entries improved.
    pub fn record(&self, config_fp: &str, w: &Workload, hw: &HwConfig,
                  strategy: &Strategy) -> usize {
        let l = w.len();
        if strategy.mappings.len() != l
            || strategy.fuse.len() != l.saturating_sub(1)
        {
            return 0;
        }
        let mut improved = 0usize;
        let mut shards = self.shards.lock().unwrap();
        let shard = shards.entry(config_fp.to_string()).or_default();
        for i in 0..l {
            let m = &strategy.mappings[i];
            let c = components(m, &w.layers[i].dims);
            let sig_out = i < l - 1 && strategy.fuse[i];
            let sig_in = i > 0 && strategy.fuse[i - 1];
            let lc = layer_cost(&c, sig_out as u8 as f64,
                                sig_in as u8 as f64, hw);
            let score = lc.energy * lc.latency;
            if !score.is_finite() {
                continue;
            }
            let fp = w.layers[i].shape_fingerprint();
            if shard.get(&fp).is_some_and(|old| old.score <= score) {
                continue;
            }
            shard.insert(fp, LibEntry {
                kind: w.layers[i].kind,
                dims: w.layers[i].dims,
                factors: m.factors,
                fuse_out: sig_out,
                score,
            });
            improved += 1;
        }
        drop(shards);
        if improved > 0 {
            self.stats
                .records
                .fetch_add(improved as u64, Ordering::SeqCst);
            self.dirty.lock().unwrap().insert(config_fp.to_string());
        }
        improved
    }

    /// Assemble warm-start seeds for a workload: an exact-shape
    /// composite (layers without a match stay trivial) and, when any
    /// layer had to fall back, a nearest-shape composite whose foreign
    /// factors snap to the target layer's divisors. Deterministic for
    /// a fixed library state; empty when nothing matches.
    pub fn seeds_for(&self, config_fp: &str, w: &Workload,
                     hw: &HwConfig, tables: &WorkloadTables)
                     -> Vec<Strategy> {
        let shards = self.shards.lock().unwrap();
        let Some(shard) = shards.get(config_fp) else {
            return Vec::new();
        };
        if shard.is_empty() {
            return Vec::new();
        }
        let l = w.len();
        let exact: Vec<Option<&LibEntry>> = w
            .layers
            .iter()
            .map(|layer| shard.get(&layer.shape_fingerprint()))
            .collect();
        let exact_hits =
            exact.iter().filter(|e| e.is_some()).count();
        let mut seeds = Vec::new();
        if exact_hits > 0 {
            seeds.push(compose(w, &exact, |_, e| {
                LayerMapping { factors: e.factors }
            }));
            self.stats
                .exact_hits
                .fetch_add(exact_hits as u64, Ordering::SeqCst);
        }
        if exact_hits < l {
            // nearest composite: exact where available, otherwise the
            // closest same-kind shape (log-dim distance, fingerprint
            // tie-break), snapped onto this layer's divisor tables
            let mut resolved: Vec<Option<&LibEntry>> = exact.clone();
            let mut nearest_hits = 0u64;
            for (i, slot) in resolved.iter_mut().enumerate() {
                if slot.is_some() {
                    continue;
                }
                if let Some(e) = nearest(shard, &w.layers[i].kind,
                                         &w.layers[i].dims) {
                    *slot = Some(e);
                    nearest_hits += 1;
                }
            }
            if nearest_hits > 0 {
                seeds.push(compose(w, &resolved, |i, e| {
                    snap_mapping(e, i, w, hw, tables)
                }));
                self.stats
                    .nearest_hits
                    .fetch_add(nearest_hits, Ordering::SeqCst);
            }
        }
        self.stats
            .seeds_served
            .fetch_add(seeds.len() as u64, Ordering::SeqCst);
        seeds
    }

    /// Flush every dirty shard to the store. Returns shards written
    /// (digest-unchanged shards count zero). Called from the
    /// coordinator's graceful shutdown and by the CLI after its job.
    pub fn flush(&self, store: &ResultStore) -> usize {
        let dirty: Vec<String> = {
            let mut d = self.dirty.lock().unwrap();
            std::mem::take(&mut *d).into_iter().collect()
        };
        let mut written = 0usize;
        for config_fp in dirty {
            let (json, entries) = {
                let shards = self.shards.lock().unwrap();
                match shards.get(&config_fp) {
                    Some(shard) if !shard.is_empty() => {
                        (shard_to_json(shard), shard.len() as u64)
                    }
                    _ => continue,
                }
            };
            if store.save_library(&ResultStore::library_key(&config_fp),
                                  &json, entries) {
                written += 1;
            }
        }
        written
    }

    /// The `metrics.library` block.
    pub fn stats_json(&self) -> Json {
        let c = |a: &AtomicU64| num(a.load(Ordering::SeqCst) as f64);
        obj(vec![
            ("entries", num(self.entries() as f64)),
            ("records", c(&self.stats.records)),
            ("seeds_served", c(&self.stats.seeds_served)),
            ("exact_hits", c(&self.stats.exact_hits)),
            ("nearest_hits", c(&self.stats.nearest_hits)),
        ])
    }
}

/// Build a full strategy from per-layer entry picks: matched layers
/// map through `mapping`, unmatched layers stay trivial; edge `i`
/// fuses when the producer's library entry says so and the edge is
/// fusible in this workload.
fn compose(w: &Workload, picks: &[Option<&LibEntry>],
           mapping: impl Fn(usize, &LibEntry) -> LayerMapping)
           -> Strategy {
    let mappings: Vec<LayerMapping> = picks
        .iter()
        .enumerate()
        .map(|(i, pick)| match pick {
            Some(e) => mapping(i, e),
            None => LayerMapping::trivial(),
        })
        .collect();
    let fuse: Vec<bool> = (0..w.fusible.len())
        .map(|i| {
            w.fusible[i]
                && picks[i].map(|e| e.fuse_out).unwrap_or(false)
        })
        .collect();
    Strategy { mappings, fuse }
}

/// Closest same-kind entry by symmetric log2 dim distance, shape
/// fingerprint as the deterministic tie-break (BTreeMap iteration is
/// already fingerprint-ordered).
fn nearest<'a>(shard: &'a Shard, kind: &LayerKind,
               dims: &[usize; NDIMS]) -> Option<&'a LibEntry> {
    let mut best: Option<(f64, &LibEntry)> = None;
    for e in shard.values() {
        if e.kind != *kind {
            continue;
        }
        let dist: f64 = (0..NDIMS)
            .map(|d| {
                let a = (dims[d] as f64).max(1.0).log2();
                let b = (e.dims[d] as f64).max(1.0).log2();
                (a - b).abs()
            })
            .sum();
        if best.as_ref().map(|(b, _)| dist < *b).unwrap_or(true) {
            best = Some((dist, e));
        }
    }
    best.map(|(_, e)| e)
}

/// Transfer a foreign-shape entry onto layer `l`: every factor snaps
/// to the nearest divisor of the target dim (spatial slots also clamp
/// to the PE array), and any dim whose slot product fails to divide
/// falls back to DRAM-only — the same naive legalization the GA
/// expression uses, so transferred seeds are always hardware-valid.
fn snap_mapping(e: &LibEntry, l: usize, w: &Workload, hw: &HwConfig,
                tables: &WorkloadTables) -> LayerMapping {
    let mut m = LayerMapping::trivial();
    for d in 0..NDIMS {
        let n = w.layers[l].dims[d] as u64;
        let divs = &tables.dim(l, d).divisors;
        for slot in 0..NSLOTS {
            let target = e.factors[d][slot].max(1) as f64;
            let limit = if slot == SLOT_S {
                match d {
                    DIM_K => hw.pe_cols as u64,
                    DIM_C => hw.pe_rows as u64,
                    _ => 1,
                }
            } else {
                u64::MAX
            };
            m.factors[d][slot] = divs
                .iter()
                .copied()
                .filter(|&f| f <= limit)
                .min_by(|&a, &b| {
                    let da = (a as f64 - target).abs();
                    let db = (b as f64 - target).abs();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap_or(1);
        }
        if n % m.inner(d) != 0 || m.inner(d) > n {
            let sp = m.factors[d][SLOT_S];
            m.factors[d] = [1, 1, 1, if n % sp == 0 { sp } else { 1 }];
        }
    }
    m
}

fn shard_to_json(shard: &Shard) -> Json {
    let items = shard
        .iter()
        .map(|(&fp, e)| e.to_json(fp))
        .collect();
    obj(vec![("kind", s("library")), ("entries", arr(items))])
}

fn parse_shard(j: &Json) -> Option<Vec<(u64, LibEntry)>> {
    if j.get("kind").ok()?.as_str().ok()? != "library" {
        return None;
    }
    j.get("entries")
        .ok()?
        .as_arr()
        .ok()?
        .iter()
        .map(LibEntry::from_json)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{load_config, repo_root};
    use crate::costmodel;
    use crate::workload::zoo;

    fn hw() -> HwConfig {
        load_config(&repo_root(), "large").unwrap()
    }

    #[test]
    fn record_gates_on_per_layer_improvement() {
        let lib = MappingLibrary::new();
        let hw = hw();
        let w = zoo::mobilenet_v1();
        let s = Strategy::trivial(&w);
        let first = lib.record("cfp", &w, &hw, &s);
        assert!(first > 0);
        // identical strategy: nothing improves
        assert_eq!(lib.record("cfp", &w, &hw, &s), 0);
        assert_eq!(lib.stats.records.load(Ordering::SeqCst),
                   first as u64);
        // shared shapes dedup: entries <= distinct fingerprints
        let distinct: BTreeSet<u64> = w
            .layers
            .iter()
            .map(|l| l.shape_fingerprint())
            .collect();
        assert_eq!(lib.entries(), distinct.len());
    }

    #[test]
    fn exact_seed_reproduces_recorded_mappings() {
        let lib = MappingLibrary::new();
        let hw = hw();
        let w = zoo::gpt3_6_7b();
        // record a non-trivial strategy: trivial plus one real tile
        let mut s = Strategy::trivial(&w);
        s.mappings[0].factors[DIM_K][SLOT_S] = 2;
        assert!(lib.record("cfp", &w, &hw, &s) > 0);
        let tables = WorkloadTables::new(&w);
        let seeds = lib.seeds_for("cfp", &w, &hw, &tables);
        assert_eq!(seeds.len(), 1, "all layers exact -> one seed");
        assert_eq!(seeds[0].mappings[0].factors[DIM_K][SLOT_S], 2);
        assert_eq!(seeds[0].mappings.len(), w.len());
        assert_eq!(seeds[0].fuse.len(), w.fusible.len());
        assert!(lib.stats.seeds_served.load(Ordering::SeqCst) >= 1);
        assert!(lib.stats.exact_hits.load(Ordering::SeqCst)
                >= w.len() as u64);
        // seeds must be evaluable (valid arity, hardware-valid tiles)
        costmodel::feasible(&seeds[0], &w, &hw).unwrap();
    }

    #[test]
    fn exact_seed_transfers_across_related_workloads() {
        let lib = MappingLibrary::new();
        let hw = hw();
        // library learned vgg16; vgg19 shares most conv shapes
        let w16 = zoo::vgg16();
        assert!(lib.record("cfp", &w16, &hw, &Strategy::trivial(&w16))
                > 0);
        let w19 = zoo::vgg19();
        let tables = WorkloadTables::new(&w19);
        let seeds = lib.seeds_for("cfp", &w19, &hw, &tables);
        assert!(!seeds.is_empty(), "shared shapes must seed");
        assert!(lib.stats.exact_hits.load(Ordering::SeqCst) > 0);
        for seed in &seeds {
            costmodel::feasible(seed, &w19, &hw).unwrap();
        }
        // a disjoint hw shard serves nothing
        assert!(lib.seeds_for("other", &w19, &hw, &tables).is_empty());
    }

    #[test]
    fn nearest_seed_transfers_across_shapes_and_stays_valid() {
        let lib = MappingLibrary::new();
        let hw = hw();
        // library learned mobilenet; resnet18 shares NO layer shapes,
        // so every resolved layer goes through the nearest-shape snap
        let wm = zoo::mobilenet_v1();
        let mut s = Strategy::trivial(&wm);
        s.mappings[0].factors[DIM_K][SLOT_S] = 4;
        assert!(lib.record("cfp", &wm, &hw, &s) > 0);
        let wr = zoo::resnet18();
        let tables = WorkloadTables::new(&wr);
        let seeds = lib.seeds_for("cfp", &wr, &hw, &tables);
        assert_eq!(seeds.len(), 1, "no exact matches -> nearest only");
        assert_eq!(lib.stats.exact_hits.load(Ordering::SeqCst), 0);
        assert!(lib.stats.nearest_hits.load(Ordering::SeqCst) > 0);
        costmodel::feasible(&seeds[0], &wr, &hw).unwrap();
        // the transferred spatial-K tile survived the snap on a dim
        // it divides
        assert!(seeds[0]
            .mappings
            .iter()
            .any(|m| m.factors[DIM_K][SLOT_S] == 4));
    }

    #[test]
    fn shard_json_roundtrips_bit_exact() {
        let lib = MappingLibrary::new();
        let hw = hw();
        let w = zoo::resnet18();
        lib.record("cfp", &w, &hw, &Strategy::trivial(&w));
        let shards = lib.shards.lock().unwrap();
        let shard = shards.get("cfp").unwrap();
        let back = parse_shard(&Json::parse(
            &shard_to_json(shard).compact()).unwrap()).unwrap();
        assert_eq!(back.len(), shard.len());
        for (fp, entry) in back {
            let orig = shard.get(&fp).unwrap();
            assert_eq!(&entry, orig);
            assert_eq!(entry.score.to_bits(), orig.score.to_bits());
        }
    }
}
