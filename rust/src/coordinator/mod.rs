//! The scheduling coordinator: a job service that accepts deployment
//! optimization requests (workload x hardware x method x budget) and
//! dispatches them to a pool of worker threads, each owning a private
//! PJRT runtime (the xla crate's client is `Rc`-based and must not cross
//! threads).
//!
//! This is the L3 "production" face of FADiff: a long-running process
//! (`fadiff serve`) or an embedded library (`Coordinator::new`) that
//! turns DNN deployment requests into hardware-valid strategies, with
//! queueing, metrics, and graceful shutdown. Python never runs here —
//! workers execute the AOT artifacts.
//!
//! The PJRT runtime is OPTIONAL for every method: GA / BO / random
//! score through [`crate::search::EvalEngine`], and the gradient
//! methods (FADiff / DOSA) run on the pure-Rust differentiable model
//! (`costmodel::grad`) whenever the AOT artifacts are absent — the
//! runtime, when present, only accelerates their inner loop. The
//! `metrics` verb therefore lists every method as served
//! unconditionally.
//!
//! # Sweep-serving architecture
//!
//! The coordinator is built to serve many jobs from one warm process:
//!
//! * **Shared cross-job caches** — a [`CacheRegistry`] hands every job
//!   the memoized [`crate::search::EvalCache`] for its
//!   `(workload, config)` pair, so repeated and concurrent jobs reuse
//!   each other's cost-model evaluations (hit/miss/eviction counters
//!   surface via [`Coordinator::metrics_json`] / the `metrics` verb).
//! * **Persistent evaluation pool** — one
//!   [`crate::util::threadpool::ThreadPool`] (scoped-submit API) backs
//!   every engine's batch scoring, replacing per-batch thread
//!   spawn/join on the hot path.
//! * **Tracked jobs** — [`Coordinator::submit_tracked`] returns a job
//!   id usable with [`Coordinator::job_status`] and
//!   [`Coordinator::cancel`]; cancellation is cooperative (queued jobs
//!   are dropped before they start, running native jobs stop at the
//!   next batch boundary and report their best-so-far).
//! * **Sweeps** — the server's `sweep` verb fans a method x workload x
//!   seed grid through the same queue and aggregates the results.

pub mod library;
pub mod metrics;
pub mod registry;
pub mod scheduler;
pub mod server;
pub mod store;

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::config::{load_config, repo_root, HwConfig};
use crate::costmodel;
use crate::runtime::Runtime;
use crate::costmodel::tables::WorkloadTables;
use crate::search::{bo, exact, ga, gradient, random, Budget,
                    Deadline, EvalBackend, EvalCtx, FleetHandle,
                    ProgressSnapshot, PruneMode, PruneStats,
                    SearchProgress, SearchResult};
use crate::util::fault;
use crate::util::json::Json;
use crate::util::threadpool::{oneshot, OneShot, OneShotSender,
                              ThreadPool};
use crate::workload::{spec, zoo, Workload};

pub use library::MappingLibrary;
pub use metrics::Metrics;
pub use registry::CacheRegistry;
pub use scheduler::FleetScheduler;
pub use store::ResultStore;

/// Default bound on queued-but-not-started jobs. The server answers
/// `queue_full` (with a `retry_after_ms` hint) instead of queueing
/// past it — bounded-latency backpressure instead of unbounded memory
/// growth on a flooded service.
pub const DEFAULT_QUEUE_CAPACITY: usize = 512;

/// Optimization method selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// The paper's joint mapping + fusion gradient search.
    FADiff,
    /// Layer-wise gradient ablation (no fusion; MICRO'23 DOSA-like).
    Dosa,
    /// Genetic-algorithm baseline.
    Ga,
    /// Bayesian-optimization baseline.
    Bo,
    /// Uniform random search (sanity floor).
    Random,
    /// Branch-and-bound exact mapper ([`crate::search::exact`]):
    /// certified-optimal on small-to-medium workloads, budget-capped
    /// (and then uncertified) on larger ones.
    Exact,
}

impl Method {
    /// Parse a protocol/CLI method name (aliases included).
    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "fadiff" | "gradient" => Method::FADiff,
            "dosa" | "layerwise" => Method::Dosa,
            "ga" | "genetic" => Method::Ga,
            "bo" | "bayesian" => Method::Bo,
            "random" | "rand" => Method::Random,
            "exact" | "bnb" => Method::Exact,
            other => return Err(anyhow!("unknown method {other:?}")),
        })
    }

    /// Canonical wire name.
    pub fn name(&self) -> &'static str {
        match self {
            Method::FADiff => "fadiff",
            Method::Dosa => "dosa",
            Method::Ga => "ga",
            Method::Bo => "bo",
            Method::Random => "random",
            Method::Exact => "exact",
        }
    }
}

/// A deployment-optimization request.
#[derive(Clone, Debug)]
pub struct JobRequest {
    /// Workload name: a zoo model, a `data/workloads/*.json` spec
    /// stem, or (when [`JobRequest::spec`] is set) the inline spec's
    /// own name, kept for display.
    pub workload: String,
    /// Hardware configuration name (`data/hw_configs.json`).
    pub config: String,
    /// Search method to run.
    pub method: Method,
    /// Wall-clock budget in seconds.
    pub seconds: f64,
    /// Iteration cap (see [`crate::search::Budget`] for how the two
    /// bounds interact on the gradient methods).
    pub max_iters: usize,
    /// PRNG seed — same seed, same request, same result.
    pub seed: u64,
    /// Parallel chain count for the gradient methods' native backend
    /// (`0` = the method default — one chain per configured restart).
    /// Ignored by GA / BO / random.
    pub chains: usize,
    /// Cooperative per-job deadline in milliseconds, measured from
    /// the moment a worker starts executing the job (`0` = none).
    /// Unlike `seconds` — which the search treats as its time budget
    /// — an expired deadline ends the job with the distinct terminal
    /// status `deadline_exceeded` (stable wire code of the same
    /// name), keeping the best-so-far like a cancel does. Partial
    /// (deadline-cut) results are never recorded to the persistent
    /// store.
    pub deadline_ms: u64,
    /// Inline custom workload (the protocol's `workload_spec`
    /// parameter / the CLI's `--workload-file`). When set it overrides
    /// the `workload` name lookup entirely; evaluation caches key on
    /// the spec's content fingerprint (see [`JobRequest::cache_key`]).
    pub spec: Option<Arc<Workload>>,
    /// Bypass the persistent result store's exact-key hit for this
    /// job: search fresh even when a stored result exists (the fresh
    /// result still records back on improvement). The protocol's
    /// `force` parameter / the CLI's `--force` switch; meaningless
    /// without a store.
    pub force: bool,
    /// Bound-and-prune screening mode for the evaluation fast path
    /// (the protocol's `prune` parameter). [`PruneMode::On`] (the
    /// default) skips the full cost-model kernel for candidates whose
    /// admissible lower bound already meets the incumbent — on the
    /// paths where that is bit-identical to an unscreened run (random
    /// search, gradient decode offers, BO's capacity-only screen).
    /// [`PruneMode::Off`] disables screening entirely.
    /// [`PruneMode::Full`] additionally screens GA generations, where
    /// pruned candidates take their bound as pessimistic fitness —
    /// this *changes the GA trajectory*, so Full results are stored
    /// under a distinct result key.
    pub prune: PruneMode,
    /// Fraction of the search's starting population/chains seeded
    /// from the coordinator's warm-start mapping library (`0.0`, the
    /// default, disables seeding; recording into the library is
    /// always on). Seeds come from best-known per-layer mappings for
    /// this hardware config, matched by exact layer-shape fingerprint
    /// first and nearest same-kind shape otherwise, and are offered
    /// to the incumbent deterministically before the search starts.
    pub warm_frac: f64,
}

impl Default for JobRequest {
    fn default() -> Self {
        JobRequest {
            workload: "resnet18".into(),
            config: "large".into(),
            method: Method::FADiff,
            seconds: 10.0,
            max_iters: usize::MAX,
            seed: 0xFAD1FF,
            chains: 0,
            deadline_ms: 0,
            spec: None,
            force: false,
            prune: PruneMode::On,
            warm_frac: 0.0,
        }
    }
}

impl JobRequest {
    /// The workload half of this job's evaluation-cache key, given the
    /// workload the job actually resolved to. Zoo names key by name
    /// (builders are immutable in-process); everything *mutable* —
    /// inline specs and `data/workloads/*.json` files, which can
    /// change under a running server — keys by content fingerprint as
    /// `spec:<fingerprint>`, so (a) two different specs can never
    /// share one [`crate::search::EvalCache`] even when they share a
    /// display name, (b) editing a spec file invalidates its cache
    /// pair instead of serving stale evaluations, and (c) a spec can
    /// never collide with a zoo name (`:` is not valid there).
    pub fn cache_key(&self, resolved: &Workload) -> String {
        if self.spec.is_none() && zoo::by_name(&self.workload).is_some()
        {
            self.workload.clone()
        } else {
            format!("spec:{}", spec::fingerprint(resolved))
        }
    }
}

/// The outcome handed back to the requester.
#[derive(Clone, Debug)]
pub struct JobResult {
    /// The request this result answers.
    pub request: JobRequest,
    /// Per-replica EDP (pJ * cycles).
    pub edp: f64,
    /// Full-model EDP (replica^2-scaled, Table-1 units).
    pub full_model_edp: f64,
    /// Energy, pJ (per replica).
    pub energy: f64,
    /// Latency, cycles (per replica).
    pub latency: f64,
    /// Fusion groups as (start, end) inclusive layer ranges.
    pub groups: Vec<(usize, usize)>,
    /// Layer names per fused group of size > 1.
    pub fused_names: Vec<Vec<String>>,
    /// Search iterations executed.
    pub iters: usize,
    /// Candidate evaluations (cache hits included).
    pub evals: usize,
    /// Wall-clock job duration.
    pub wall_seconds: f64,
    /// Whether this result was served from the persistent result
    /// store (re-verified against the live cost model, no search run);
    /// `iters`/`evals` then report the original search's effort.
    pub stored: bool,
    /// Whether the job's cooperative `deadline_ms` expired before the
    /// search finished: the result is the best-so-far at the cut, the
    /// job's terminal status is `deadline_exceeded`, and nothing was
    /// recorded to the persistent store.
    pub deadline_hit: bool,
    /// Branch-and-bound statistics, present exactly when the request's
    /// method is [`Method::Exact`]. `stats.certified` is the
    /// certification flag: `true` means the returned mapping is the
    /// proven optimum of the full design space, `false` means a node
    /// or candidate cap tripped and the result is best-effort. Stored
    /// hits report a certified default (only certified exact results
    /// are ever recorded).
    pub exact: Option<crate::search::exact::ExactStats>,
}

/// Lifecycle of a tracked job (see [`Coordinator::submit_tracked`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobStatus {
    /// Accepted, waiting for a worker.
    Queued,
    /// Executing on a worker.
    Running,
    /// Finished successfully (result available).
    Completed,
    /// Finished with an error (message available).
    Failed,
    /// Stopped by a cancel request (partial best kept when running).
    Cancelled,
    /// Stopped by its own `deadline_ms` expiring (partial best kept,
    /// like a cancel; never recorded to the persistent store).
    DeadlineExceeded,
}

impl JobStatus {
    /// Canonical wire name.
    pub fn name(&self) -> &'static str {
        match self {
            JobStatus::Queued => "queued",
            JobStatus::Running => "running",
            JobStatus::Completed => "completed",
            JobStatus::Failed => "failed",
            JobStatus::Cancelled => "cancelled",
            JobStatus::DeadlineExceeded => "deadline_exceeded",
        }
    }

    /// Whether the job can still change state.
    pub fn is_terminal(&self) -> bool {
        matches!(self, JobStatus::Completed | JobStatus::Failed
                       | JobStatus::Cancelled
                       | JobStatus::DeadlineExceeded)
    }
}

struct TrackedJob {
    status: JobStatus,
    cancel: Arc<AtomicBool>,
    progress: Arc<SearchProgress>,
    result: Option<Result<JobResult, String>>,
}

/// Bound on tracked jobs. Terminal entries beyond it are pruned oldest
/// first; when the table is full of *live* (queued/running) jobs, new
/// tracked submissions are rejected — backpressure instead of unbounded
/// memory growth on a flooded server.
const MAX_TRACKED_JOBS: usize = 1024;

#[derive(Default)]
struct JobTable {
    next: AtomicU64,
    jobs: Mutex<HashMap<u64, TrackedJob>>,
}

impl JobTable {
    /// Register a new queued job; `None` when the table is saturated
    /// with live jobs (the caller should reject the submission).
    fn insert(&self, cancel: Arc<AtomicBool>,
              progress: Arc<SearchProgress>) -> Option<u64> {
        let mut jobs = self.jobs.lock().unwrap();
        if jobs.len() >= MAX_TRACKED_JOBS {
            let mut terminal: Vec<u64> = jobs
                .iter()
                .filter(|(_, j)| j.status.is_terminal())
                .map(|(&id, _)| id)
                .collect();
            if jobs.len() - terminal.len() >= MAX_TRACKED_JOBS {
                return None; // every slot holds a live job
            }
            terminal.sort_unstable();
            let excess = jobs.len() + 1 - MAX_TRACKED_JOBS;
            for old in terminal.into_iter().take(excess) {
                jobs.remove(&old);
            }
        }
        let id = self.next.fetch_add(1, Ordering::SeqCst) + 1;
        jobs.insert(id, TrackedJob { status: JobStatus::Queued, cancel,
                                     progress, result: None });
        Some(id)
    }

    fn set_running(&self, id: u64) {
        if let Some(j) = self.jobs.lock().unwrap().get_mut(&id) {
            if !j.status.is_terminal() {
                j.status = JobStatus::Running;
            }
        }
    }

    /// Move a job to a terminal state; returns false if it already was
    /// terminal (so metrics count each job exactly once).
    fn finish(&self, id: u64, status: JobStatus,
              result: Result<JobResult, String>) -> bool {
        let mut jobs = self.jobs.lock().unwrap();
        match jobs.get_mut(&id) {
            Some(j) if !j.status.is_terminal() => {
                j.status = status;
                j.result = Some(result);
                true
            }
            _ => false,
        }
    }

    #[allow(clippy::type_complexity)]
    fn status(&self, id: u64)
              -> Option<(JobStatus, Option<Result<JobResult, String>>)> {
        self.jobs
            .lock()
            .unwrap()
            .get(&id)
            .map(|j| (j.status, j.result.clone()))
    }

    fn cancel_flag(&self, id: u64) -> Option<Arc<AtomicBool>> {
        self.jobs
            .lock()
            .unwrap()
            .get(&id)
            .map(|j| Arc::clone(&j.cancel))
    }

    fn progress(&self, id: u64) -> Option<Arc<SearchProgress>> {
        self.jobs
            .lock()
            .unwrap()
            .get(&id)
            .map(|j| Arc::clone(&j.progress))
    }

    /// Test hook: drop an entry outright, as pruning would.
    #[cfg(test)]
    fn remove(&self, id: u64) {
        self.jobs.lock().unwrap().remove(&id);
    }
}

struct Envelope {
    req: JobRequest,
    reply: Option<OneShotSender<Result<JobResult, String>>>,
    job_id: Option<u64>,
    cancel: Arc<AtomicBool>,
    progress: Arc<SearchProgress>,
}

/// Default watchdog stall threshold, milliseconds: a *running* job
/// whose search-progress counters stay frozen this long is failed
/// definitively instead of wedging its queue slot forever.
/// Deliberately conservative — a legitimate first batch on a starved
/// machine takes seconds, not half a minute. Override per coordinator
/// with [`Coordinator::set_stall_ms`] (`0` disables the watchdog).
pub const DEFAULT_STALL_MS: u64 = 30_000;

/// Best-effort human-readable panic payload, sanitized for the wire:
/// control characters flattened to spaces, length capped.
pub(crate) fn panic_message(p: Box<dyn std::any::Any + Send>)
                            -> String {
    let raw = if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    };
    raw.chars()
        .map(|c| if c.is_control() { ' ' } else { c })
        .take(240)
        .collect()
}

fn stall_message(threshold_ms: u64) -> String {
    format!(
        "eval stalled: no search progress for {threshold_ms} ms \
         (failed by the watchdog)"
    )
}

struct Supervised {
    job_id: Option<u64>,
    progress: Arc<SearchProgress>,
    cancel: Arc<AtomicBool>,
    stalled: Arc<AtomicBool>,
    last_seq: u64,
    last_evals: u64,
    last_change: std::time::Instant,
}

/// The watchdog's view of every job currently executing on a worker:
/// entries register at job start and deregister at job end; the
/// `fadiff-watchdog` thread scans them and fails any job whose
/// progress counters stay frozen past the stall threshold (setting
/// its cooperative cancel flag so the search also stops at its next
/// poll, once whatever wedged it lets go).
struct Supervisor {
    next: AtomicU64,
    running: Mutex<HashMap<u64, Supervised>>,
    stall_ms: AtomicU64,
    stop: AtomicBool,
}

impl Supervisor {
    fn new() -> Supervisor {
        Supervisor {
            next: AtomicU64::new(0),
            running: Mutex::new(HashMap::new()),
            stall_ms: AtomicU64::new(DEFAULT_STALL_MS),
            stop: AtomicBool::new(false),
        }
    }

    /// Track one starting job; returns the deregistration token and
    /// the per-job stall latch the worker checks after execution.
    fn register(&self, job_id: Option<u64>,
                progress: &Arc<SearchProgress>,
                cancel: &Arc<AtomicBool>)
                -> (u64, Arc<AtomicBool>) {
        let token = self.next.fetch_add(1, Ordering::SeqCst);
        let stalled = Arc::new(AtomicBool::new(false));
        self.running.lock().unwrap().insert(token, Supervised {
            job_id,
            progress: Arc::clone(progress),
            cancel: Arc::clone(cancel),
            stalled: Arc::clone(&stalled),
            last_seq: 0,
            last_evals: 0,
            last_change: std::time::Instant::now(),
        });
        (token, stalled)
    }

    fn deregister(&self, token: u64) {
        self.running.lock().unwrap().remove(&token);
    }

    /// One watchdog sweep: refresh per-job progress marks, fail any
    /// job frozen past the threshold. Failing is definitive for
    /// tracked jobs — the job table transitions immediately, even if
    /// the wedged worker thread only returns (or never does) later;
    /// its own late finish is then a counted no-op.
    fn scan(&self, jobs: &JobTable, metrics: &Metrics) {
        let threshold = self.stall_ms.load(Ordering::SeqCst);
        if threshold == 0 {
            return; // watchdog disabled
        }
        let now = std::time::Instant::now();
        let mut running = self.running.lock().unwrap();
        for entry in running.values_mut() {
            let snap = entry.progress.snapshot();
            if snap.seq != entry.last_seq
                || snap.evals != entry.last_evals
            {
                entry.last_seq = snap.seq;
                entry.last_evals = snap.evals;
                entry.last_change = now;
                continue;
            }
            let frozen_ms = now
                .saturating_duration_since(entry.last_change)
                .as_millis() as u64;
            if frozen_ms < threshold
                || entry.stalled.load(Ordering::SeqCst)
            {
                continue;
            }
            entry.stalled.store(true, Ordering::SeqCst);
            entry.cancel.store(true, Ordering::SeqCst);
            metrics.watchdog_kills.fetch_add(1, Ordering::SeqCst);
            if let Some(id) = entry.job_id {
                if jobs.finish(id, JobStatus::Failed,
                               Err(stall_message(threshold)))
                {
                    metrics.failed.fetch_add(1, Ordering::SeqCst);
                }
            }
        }
    }
}

/// The coordinator: queue + worker pool + shared caches + the fleet
/// scheduler + metrics.
pub struct Coordinator {
    tx: Option<Sender<Envelope>>,
    workers: Vec<JoinHandle<()>>,
    /// Service counters (shared with the TCP server's `metrics` verb).
    pub metrics: Arc<Metrics>,
    registry: Arc<CacheRegistry>,
    eval_pool: Arc<ThreadPool>,
    scheduler: Arc<FleetScheduler>,
    store: Option<Arc<ResultStore>>,
    /// Fleet-wide bound-and-prune counters (the `metrics` verb's
    /// `prune` block): aggregated across every job's screened batches.
    prune_stats: Arc<PruneStats>,
    /// The warm-start mapping library: best-known per-layer mappings
    /// keyed by hardware config + layer-shape fingerprint. Every
    /// feasible completed job records into it; requests with
    /// `warm_frac > 0` seed from it.
    library: Arc<MappingLibrary>,
    jobs: Arc<JobTable>,
    queue_depth: Arc<AtomicUsize>,
    queue_capacity: AtomicUsize,
    supervisor: Arc<Supervisor>,
    watchdog: Option<JoinHandle<()>>,
    started: std::time::Instant,
}

impl Coordinator {
    /// Spawn `n_workers` workers, each loading its own PJRT runtime
    /// from `artifacts_dir` (defaults to `<repo>/artifacts`). Missing
    /// artifacts cost nothing but the PJRT acceleration: gradient jobs
    /// fall back to the native differentiable backend.
    pub fn new(artifacts_dir: Option<PathBuf>, n_workers: usize)
               -> Result<Coordinator> {
        Coordinator::new_with_store(artifacts_dir, n_workers, None)
    }

    /// [`Coordinator::new`] with a persistent result store rooted at
    /// `store_dir` (the CLI's `--store-dir`): results and eval-cache
    /// segments persist there, so a restarted (or second) coordinator
    /// on the same directory serves previously-solved requests warm.
    pub fn new_with_store(artifacts_dir: Option<PathBuf>,
                          n_workers: usize,
                          store_dir: Option<PathBuf>)
                          -> Result<Coordinator> {
        let dir = artifacts_dir
            .unwrap_or_else(|| repo_root().join("artifacts"));
        let store = match store_dir {
            Some(sd) => Some(Arc::new(ResultStore::open(&sd)?)),
            None => None,
        };
        // Same usability contract as tests/benches: artifacts must
        // exist AND compile (a stub xla crate fails here too). Under a
        // real backend this deliberately spends one grad-artifact
        // compile at construction so the degraded-mode warning is
        // accurate; the probed runtime cannot be reused by the workers
        // (the real PJRT client is not Send).
        if Runtime::load_if_available(&dir).is_none() {
            eprintln!(
                "[fadiff-coord] PJRT runtime unavailable under {dir:?}; \
                 gradient methods run on the native differentiable \
                 backend (all methods remain served)"
            );
        }
        let (tx, rx) = channel::<Envelope>();
        let rx = Arc::new(Mutex::new(rx));
        let metrics = Arc::new(Metrics::default());
        let registry =
            Arc::new(CacheRegistry::with_store(
                registry::DEFAULT_REGISTRY_CAPACITY,
                store.clone()));
        let jobs = Arc::new(JobTable::default());
        // one persistent evaluation pool shared by every worker's
        // engines: batches scoped-submit here instead of spawning
        // threads per call
        let eval_threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .min(16);
        let eval_pool = Arc::new(ThreadPool::new(eval_threads));
        // the cross-job fleet scheduler: every job's engine sends its
        // cache-miss batches here, where same-(workload, config) items
        // from concurrent jobs coalesce into shared kernel passes
        let scheduler =
            Arc::new(FleetScheduler::new(Arc::clone(&eval_pool)));
        let queue_depth = Arc::new(AtomicUsize::new(0));
        let supervisor = Arc::new(Supervisor::new());
        let prune_stats = Arc::new(PruneStats::default());
        let library = Arc::new(MappingLibrary::new());
        let workers = (0..n_workers.max(1))
            .map(|i| {
                let rx = Arc::clone(&rx);
                let dir = dir.clone();
                let metrics = Arc::clone(&metrics);
                let registry = Arc::clone(&registry);
                let eval_pool = Arc::clone(&eval_pool);
                let scheduler = Arc::clone(&scheduler);
                let store = store.clone();
                let prune_stats = Arc::clone(&prune_stats);
                let library = Arc::clone(&library);
                let jobs = Arc::clone(&jobs);
                let queue_depth = Arc::clone(&queue_depth);
                let supervisor = Arc::clone(&supervisor);
                std::thread::Builder::new()
                    .name(format!("fadiff-coord-{i}"))
                    .spawn(move || {
                        worker_loop(&dir, &rx, &metrics, &registry,
                                    &eval_pool, &scheduler, &store,
                                    &prune_stats, &library, &jobs,
                                    &queue_depth, &supervisor)
                    })
                    .expect("spawn coordinator worker")
            })
            .collect();
        // the watchdog: scans running jobs' progress counters and
        // fails any job frozen past the stall threshold
        let watchdog = {
            let supervisor = Arc::clone(&supervisor);
            let jobs = Arc::clone(&jobs);
            let metrics = Arc::clone(&metrics);
            std::thread::Builder::new()
                .name("fadiff-watchdog".into())
                .spawn(move || {
                    while !supervisor.stop.load(Ordering::SeqCst) {
                        supervisor.scan(&jobs, &metrics);
                        std::thread::sleep(
                            std::time::Duration::from_millis(25),
                        );
                    }
                })
                .expect("spawn watchdog")
        };
        Ok(Coordinator {
            tx: Some(tx),
            workers,
            metrics,
            registry,
            eval_pool,
            scheduler,
            store,
            prune_stats,
            library,
            jobs,
            queue_depth,
            queue_capacity: AtomicUsize::new(DEFAULT_QUEUE_CAPACITY),
            supervisor,
            watchdog: Some(watchdog),
            started: std::time::Instant::now(),
        })
    }

    fn enqueue(&self, req: JobRequest,
               reply: Option<OneShotSender<Result<JobResult, String>>>,
               job_id: Option<u64>, cancel: Arc<AtomicBool>,
               progress: Arc<SearchProgress>) {
        self.metrics.submitted.fetch_add(1, Ordering::SeqCst);
        self.queue_depth.fetch_add(1, Ordering::SeqCst);
        self.tx
            .as_ref()
            .expect("coordinator shut down")
            .send(Envelope { req, reply, job_id, cancel, progress })
            .expect("workers alive");
    }

    /// Submit a job; returns a handle to wait on.
    pub fn submit(&self, req: JobRequest)
                  -> OneShot<Result<JobResult, String>> {
        let (tx, rx) = oneshot();
        self.enqueue(req, Some(tx), None,
                     Arc::new(AtomicBool::new(false)),
                     Arc::new(SearchProgress::new()));
        rx
    }

    /// Submit a tracked job: returns a job id for
    /// [`Coordinator::job_status`] / [`Coordinator::cancel`] (the
    /// server's `submit` / `status` / `cancel` verbs). Errors when the
    /// job table is saturated with live jobs (cancel or drain first).
    pub fn submit_tracked(&self, req: JobRequest) -> Result<u64> {
        let cancel = Arc::new(AtomicBool::new(false));
        let progress = Arc::new(SearchProgress::new());
        let id = self
            .jobs
            .insert(Arc::clone(&cancel), Arc::clone(&progress))
            .ok_or_else(|| {
                anyhow!(
                    "job table full ({MAX_TRACKED_JOBS} live jobs); \
                     cancel or await existing jobs first"
                )
            })?;
        self.enqueue(req, None, Some(id), cancel, progress);
        Ok(id)
    }

    /// Status (and, once terminal, the outcome) of a tracked job.
    /// `None` for ids never issued or pruned.
    #[allow(clippy::type_complexity)]
    pub fn job_status(&self, id: u64)
                      -> Option<(JobStatus,
                                 Option<Result<JobResult, String>>)> {
        self.jobs.status(id)
    }

    /// Request cancellation of a tracked job. Queued jobs are resolved
    /// immediately; running jobs stop cooperatively at their next batch
    /// boundary (their partial best is kept as the result). Returns the
    /// job's status after the request, or `None` for unknown ids.
    pub fn cancel(&self, id: u64) -> Option<JobStatus> {
        let flag = self.jobs.cancel_flag(id)?;
        flag.store(true, Ordering::SeqCst);
        let (status, _) = self.jobs.status(id)?;
        match status {
            JobStatus::Queued => {
                // resolve now so callers are not stuck behind whatever
                // is ahead in the queue; the worker that later drains
                // the envelope sees the terminal state and skips it
                if self.jobs.finish(id, JobStatus::Cancelled,
                                    Err("job cancelled".into())) {
                    self.metrics
                        .cancelled
                        .fetch_add(1, Ordering::SeqCst);
                }
                Some(JobStatus::Cancelled)
            }
            other => Some(other),
        }
    }

    /// Submit and block for the result.
    pub fn run(&self, req: JobRequest) -> Result<JobResult> {
        self.submit(req)
            .wait()
            .ok_or_else(|| anyhow!("worker dropped the job"))?
            .map_err(|e| anyhow!(e))
    }

    /// Number of job workers this coordinator runs.
    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// The cross-job cache registry (shared `(workload, config)`
    /// evaluation caches).
    pub fn registry(&self) -> &Arc<CacheRegistry> {
        &self.registry
    }

    /// The persistent evaluation pool batches score on.
    pub fn eval_pool(&self) -> &Arc<ThreadPool> {
        &self.eval_pool
    }

    /// The cross-job fleet scheduler (merge counters, test hooks).
    pub fn scheduler(&self) -> &Arc<FleetScheduler> {
        &self.scheduler
    }

    /// The persistent result store, when serving with `--store-dir`.
    pub fn store(&self) -> Option<&Arc<ResultStore>> {
        self.store.as_ref()
    }

    /// Fleet-wide bound-and-prune counters (test hooks; the `metrics`
    /// verb's `prune` block).
    pub fn prune_stats(&self) -> &Arc<PruneStats> {
        &self.prune_stats
    }

    /// The warm-start mapping library (test hooks; the `metrics`
    /// verb's `library` block).
    pub fn library(&self) -> &Arc<MappingLibrary> {
        &self.library
    }

    /// Jobs queued but not yet picked up by a worker.
    pub fn queue_depth(&self) -> usize {
        self.queue_depth.load(Ordering::SeqCst)
    }

    /// The bound the server enforces before enqueueing
    /// (`queue_full` past it).
    pub fn queue_capacity(&self) -> usize {
        self.queue_capacity.load(Ordering::SeqCst)
    }

    /// Override the queue bound (min 1; tests shrink it to force
    /// `queue_full` deterministically).
    pub fn set_queue_capacity(&self, capacity: usize) {
        self.queue_capacity
            .store(capacity.max(1), Ordering::SeqCst);
    }

    /// Live progress of a tracked job (the `watch` stream's source).
    /// `None` for ids never issued or pruned.
    pub fn job_progress(&self, id: u64) -> Option<ProgressSnapshot> {
        self.jobs.progress(id).map(|p| p.snapshot())
    }

    /// The watchdog's stall threshold, milliseconds (`0` = disabled).
    pub fn stall_ms(&self) -> u64 {
        self.supervisor.stall_ms.load(Ordering::SeqCst)
    }

    /// Override the watchdog's stall threshold: a running job whose
    /// search progress stays frozen `ms` milliseconds is failed
    /// definitively (`0` disables the watchdog; tests shrink it to
    /// trip on injected stalls deterministically).
    pub fn set_stall_ms(&self, ms: u64) {
        self.supervisor.stall_ms.store(ms, Ordering::SeqCst);
    }

    /// Service counters (shared with the serving front-end, which
    /// bumps the connection-level fault counters directly).
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Test hook: make a tracked id unknown, as table pruning would
    /// (races the server's `status` verb in the TOCTOU regression
    /// test).
    #[cfg(test)]
    pub(crate) fn forget_job(&self, id: u64) {
        self.jobs.remove(id);
    }

    /// Seconds since this coordinator started serving.
    pub fn uptime_seconds(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Service metrics + cache-registry stats + evaluator throughput
    /// as one JSON object (the `metrics` verb payload).
    pub fn metrics_json(&self) -> Json {
        use crate::util::json::{num, obj};
        let mut j = self.metrics.to_json();
        if let Json::Obj(map) = &mut j {
            map.insert("cache".into(), self.registry.stats_json());
            map.insert("scheduler".into(),
                       self.scheduler.stats_json());
            map.insert(
                "queue".into(),
                obj(vec![
                    ("depth", num(self.queue_depth() as f64)),
                    ("capacity", num(self.queue_capacity() as f64)),
                ]),
            );
            map.insert(
                "eval_pool_threads".into(),
                Json::Num(self.eval_pool.size() as f64),
            );
            map.insert("workers".into(),
                       Json::Num(self.n_workers() as f64));
            map.insert(
                "store".into(),
                match &self.store {
                    Some(st) => st.stats_json(),
                    None => obj(vec![
                        ("enabled", Json::Bool(false)),
                    ]),
                },
            );
            let bounded =
                self.prune_stats.bounded.load(Ordering::Relaxed);
            let pruned = self.prune_stats.pruned();
            map.insert(
                "prune".into(),
                obj(vec![
                    ("bounded", num(bounded as f64)),
                    ("pruned_above",
                     num(self
                         .prune_stats
                         .pruned_above
                         .load(Ordering::Relaxed)
                         as f64)),
                    ("pruned_infeasible",
                     num(self
                         .prune_stats
                         .pruned_infeasible
                         .load(Ordering::Relaxed)
                         as f64)),
                    ("evaluated",
                     num(self
                         .prune_stats
                         .evaluated
                         .load(Ordering::Relaxed)
                         as f64)),
                    ("ratio",
                     num(pruned as f64 / (bounded as f64).max(1.0))),
                ]),
            );
            map.insert("library".into(), self.library.stats_json());
            let ex_jobs =
                self.metrics.exact_jobs.load(Ordering::SeqCst);
            let ex_nodes =
                self.metrics.exact_nodes.load(Ordering::SeqCst);
            let ex_pruned =
                self.metrics.exact_pruned.load(Ordering::SeqCst);
            map.insert(
                "exact".into(),
                obj(vec![
                    ("jobs", num(ex_jobs as f64)),
                    ("certified",
                     num(self
                         .metrics
                         .exact_certified
                         .load(Ordering::SeqCst)
                         as f64)),
                    ("nodes_expanded", num(ex_nodes as f64)),
                    ("pruned", num(ex_pruned as f64)),
                    ("prune_ratio",
                     num(ex_pruned as f64
                         / ((ex_nodes + ex_pruned) as f64).max(1.0))),
                ]),
            );
            map.insert(
                "supervision".into(),
                obj(vec![
                    ("deadline_exceeded",
                     num(self
                         .metrics
                         .deadline_exceeded
                         .load(Ordering::SeqCst)
                         as f64)),
                    ("job_panics_contained",
                     num(self.metrics.job_panics.load(Ordering::SeqCst)
                         as f64)),
                    ("watchdog_kills",
                     num(self
                         .metrics
                         .watchdog_kills
                         .load(Ordering::SeqCst)
                         as f64)),
                    ("scheduler_panics_contained",
                     num(self.scheduler.panics_contained() as f64)),
                    ("stall_ms", num(self.stall_ms() as f64)),
                ]),
            );
            let injected = Json::Obj(
                fault::snapshot()
                    .into_iter()
                    .map(|s| {
                        (s.site.clone(), obj(vec![
                            ("mode", Json::Str(s.mode)),
                            ("calls", num(s.calls as f64)),
                            ("fires", num(s.fires as f64)),
                            ("delay_ms", num(s.delay_ms as f64)),
                        ]))
                    })
                    .collect(),
            );
            let (io_retries, io_permanent) = match &self.store {
                Some(st) => (
                    st.stats().io_retries.load(Ordering::SeqCst),
                    st.stats().io_permanent.load(Ordering::SeqCst),
                ),
                None => (0, 0),
            };
            map.insert(
                "faults".into(),
                obj(vec![
                    ("injection_enabled",
                     Json::Bool(fault::available())),
                    ("oversized_drains",
                     num(self
                         .metrics
                         .oversized_drains
                         .load(Ordering::SeqCst)
                         as f64)),
                    ("queue_full_rejected",
                     num(self
                         .metrics
                         .queue_full_rejected
                         .load(Ordering::SeqCst)
                         as f64)),
                    ("store_io_retries", num(io_retries as f64)),
                    ("store_io_permanent", num(io_permanent as f64)),
                    ("injected", injected),
                ]),
            );
            map.insert(
                "conns_open".into(),
                num(self.metrics.conns_open.load(Ordering::SeqCst)
                    as f64),
            );
            let uptime = self.uptime_seconds();
            let evals = self.metrics.evals.load(Ordering::SeqCst);
            let gsteps =
                self.metrics.grad_steps.load(Ordering::SeqCst);
            map.insert(
                "throughput".into(),
                obj(vec![
                    ("evals_total", num(evals as f64)),
                    ("evals_per_sec",
                     num(evals as f64 / uptime.max(1e-9))),
                    ("grad_steps_total", num(gsteps as f64)),
                    ("grad_steps_per_sec",
                     num(gsteps as f64 / uptime.max(1e-9))),
                    ("uptime_seconds", num(uptime)),
                ]),
            );
        }
        j
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.supervisor.stop.store(true, Ordering::SeqCst);
        if let Some(wd) = self.watchdog.take() {
            let _ = wd.join();
        }
        // workers are quiesced: flush dirty eval-cache segments and
        // dirty mapping-library shards so the next process on this
        // store dir starts warm
        self.registry.flush_all();
        if let Some(st) = &self.store {
            self.library.flush(st);
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(dir: &std::path::Path,
               rx: &Arc<Mutex<Receiver<Envelope>>>,
               metrics: &Arc<Metrics>, registry: &Arc<CacheRegistry>,
               eval_pool: &Arc<ThreadPool>,
               scheduler: &Arc<FleetScheduler>,
               store: &Option<Arc<ResultStore>>,
               prune_stats: &Arc<PruneStats>,
               library: &Arc<MappingLibrary>, jobs: &Arc<JobTable>,
               queue_depth: &Arc<AtomicUsize>,
               supervisor: &Arc<Supervisor>) {
    // One PJRT runtime per worker; artifacts compile lazily on the
    // first gradient job so native-only service pays no startup
    // compiles (the accurate degraded-mode warning is emitted once by
    // Coordinator::new's load_if_available probe). A stub xla crate
    // passes this manifest gate and fails the per-job compile with its
    // own actionable message.
    let rt = Runtime::load(dir).ok();
    loop {
        let job = {
            let guard = rx.lock().unwrap();
            guard.recv()
        };
        let Envelope { req, reply, job_id, cancel, progress } =
            match job {
                Ok(j) => j,
                Err(_) => break,
            };
        queue_depth.fetch_sub(1, Ordering::SeqCst);
        // cancelled while queued: never start it
        if cancel.load(Ordering::SeqCst) {
            let transitioned = job_id.map_or(true, |id| {
                jobs.finish(id, JobStatus::Cancelled,
                            Err("job cancelled".into()))
            });
            if transitioned {
                metrics.cancelled.fetch_add(1, Ordering::SeqCst);
            }
            if let Some(reply) = reply {
                reply.send(Err("job cancelled".into()));
            }
            continue;
        }
        metrics.started.fetch_add(1, Ordering::SeqCst);
        if let Some(id) = job_id {
            jobs.set_running(id);
        }
        // the job's cooperative deadline starts when execution does
        // (queue time does not count against it)
        let deadline = (req.deadline_ms > 0)
            .then(|| Deadline::in_ms(req.deadline_ms));
        let ctx = JobCtx {
            registry: Some(registry.as_ref()),
            pool: Some(Arc::clone(eval_pool)),
            cancel: Some(Arc::clone(&cancel)),
            fleet: Some(Arc::clone(scheduler)),
            progress: Some(Arc::clone(&progress)),
            store: store.clone(),
            prune_stats: Some(Arc::clone(prune_stats)),
            library: Some(Arc::clone(library)),
            deadline: deadline.clone(),
        };
        let (token, stall_latch) =
            supervisor.register(job_id, &progress, &cancel);
        // panic containment: a panicking job answers `internal` with
        // its sanitized panic message; this worker thread survives
        // and keeps draining the queue
        let out = match std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                execute_job_ctx(rt.as_ref(), &req, &ctx)
            }),
        ) {
            Ok(r) => r.map_err(|e| e.to_string()),
            Err(p) => {
                metrics.job_panics.fetch_add(1, Ordering::SeqCst);
                Err(format!("job panicked: {}", panic_message(p)))
            }
        };
        supervisor.deregister(token);
        if let Ok(r) = &out {
            // a stored result reports the *original* run's effort —
            // nothing was evaluated now, so throughput counters skip it
            if !r.stored {
                metrics
                    .evals
                    .fetch_add(r.evals as u64, Ordering::SeqCst);
                // for the gradient methods `iters` counts inner
                // gradient steps (summed across parallel chains)
                if matches!(r.request.method,
                            Method::FADiff | Method::Dosa)
                {
                    metrics
                        .grad_steps
                        .fetch_add(r.iters as u64, Ordering::SeqCst);
                }
                // the branch-and-bound mapper reports how much of
                // the tree it walked and whether the result is a
                // certified optimum
                if let Some(ex) = &r.exact {
                    metrics
                        .exact_jobs
                        .fetch_add(1, Ordering::SeqCst);
                    if ex.certified {
                        metrics
                            .exact_certified
                            .fetch_add(1, Ordering::SeqCst);
                    }
                    metrics
                        .exact_nodes
                        .fetch_add(ex.nodes_expanded,
                                   Ordering::SeqCst);
                    metrics
                        .exact_pruned
                        .fetch_add(ex.pruned(), Ordering::SeqCst);
                }
            }
        }
        let was_cancelled = cancel.load(Ordering::SeqCst);
        let stalled = stall_latch.load(Ordering::SeqCst);
        // a watchdog-stalled job is failed even if the worker's call
        // eventually returned Ok: the table may already hold the
        // definitive failure, and a late success must not contradict
        // what `status` callers were told
        let out = if stalled {
            out.and_then(|_| {
                Err(stall_message(
                    supervisor.stall_ms.load(Ordering::SeqCst),
                ))
            })
        } else {
            out
        };
        let status = if stalled {
            JobStatus::Failed
        } else if was_cancelled {
            JobStatus::Cancelled
        } else if out.is_err() {
            JobStatus::Failed
        } else if deadline.as_ref().is_some_and(|d| d.was_hit()) {
            // the deadline cut the search short: terminal status says
            // so, the payload still carries the best-so-far
            JobStatus::DeadlineExceeded
        } else {
            JobStatus::Completed
        };
        let transitioned = job_id.map_or(true, |id| {
            jobs.finish(id, status, out.clone())
        });
        if transitioned {
            match status {
                JobStatus::Completed => {
                    metrics.completed.fetch_add(1, Ordering::SeqCst)
                }
                JobStatus::Failed => {
                    metrics.failed.fetch_add(1, Ordering::SeqCst)
                }
                JobStatus::DeadlineExceeded => metrics
                    .deadline_exceeded
                    .fetch_add(1, Ordering::SeqCst),
                _ => metrics.cancelled.fetch_add(1, Ordering::SeqCst),
            };
        }
        if let Some(reply) = reply {
            reply.send(out);
        }
    }
}

/// Serving context for one job execution: where to find the shared
/// per-`(workload, config)` caches, the persistent evaluation pool,
/// the cooperative cancel flag, the cross-job fleet scheduler, and the
/// live progress sink. `JobCtx::default()` (what the CLI uses)
/// reproduces standalone behavior exactly.
#[derive(Default)]
pub struct JobCtx<'c> {
    /// Cross-job cache registry (shared per-pair evaluation caches).
    pub registry: Option<&'c CacheRegistry>,
    /// Persistent evaluation pool for batch scoring.
    pub pool: Option<Arc<ThreadPool>>,
    /// Cooperative cancellation flag.
    pub cancel: Option<Arc<AtomicBool>>,
    /// Cross-job fleet scheduler: when set, the job's engines send
    /// their cache-miss batches through it so concurrent jobs on the
    /// same `(workload, config)` pair share kernel passes.
    pub fleet: Option<Arc<FleetScheduler>>,
    /// Live progress sink for `status {"watch": true}` streams.
    pub progress: Option<Arc<SearchProgress>>,
    /// Persistent result store: exact-key result hits are served from
    /// it (re-verified), improvements record back, and the pair's eval
    /// cache hydrates from its persisted segment.
    pub store: Option<Arc<ResultStore>>,
    /// Shared bound-and-prune counters: when set, the job's screened
    /// batches aggregate into them (the `metrics` verb's `prune`
    /// block). Counters never affect results.
    pub prune_stats: Option<Arc<PruneStats>>,
    /// The warm-start mapping library: feasible completed jobs record
    /// their per-layer mappings into it, and requests with
    /// `warm_frac > 0` draw seeds from it. With a store present the
    /// library lazily hydrates each hardware config's shard from disk.
    pub library: Option<Arc<MappingLibrary>>,
    /// Cooperative per-job deadline: the search's stop seam polls it
    /// alongside the cancel flag; when it expires the job ends
    /// `deadline_exceeded` keeping its best-so-far. When `None` and
    /// the request sets `deadline_ms`, [`execute_job_ctx`] derives one
    /// at call time (the CLI path).
    pub deadline: Option<Deadline>,
}

impl JobCtx<'_> {
    fn eval_ctx(&self, req: &JobRequest, resolved: &Arc<Workload>,
                hw: &Arc<HwConfig>) -> EvalCtx {
        let cache_key = req.cache_key(resolved);
        EvalCtx {
            cache: self
                .registry
                .map(|r| r.cache_for_job(&cache_key, &req.config,
                                         resolved, hw)),
            pool: self.pool.clone(),
            cancel: self.cancel.clone(),
            fleet: self.fleet.as_ref().map(|s| FleetHandle {
                backend: Arc::clone(s) as Arc<dyn EvalBackend>,
                w: Arc::clone(resolved),
                hw: Arc::clone(hw),
                // the same identity the cache registry keys on: merge
                // exactly when an eval cache could be shared
                key: format!("{cache_key}\u{0}{}", req.config),
            }),
            progress: self.progress.clone(),
            deadline: self.deadline.clone(),
            prune: req.prune,
            prune_stats: self.prune_stats.clone(),
            // seeds are assembled by `execute_job_ctx` once the
            // library shard for this config is loaded
            seeds: Vec::new(),
            warm_frac: req.warm_frac,
        }
    }
}

/// Resolve a workload name: built-in zoo models first
/// ([`zoo::by_name`]), then the checked-in spec files under
/// `data/workloads/` ([`spec::load_named`]) — so dropping a JSON file
/// there serves a new scenario without a rebuild.
pub fn resolve_workload(name: &str) -> Result<Workload> {
    if let Some(w) = zoo::by_name(name) {
        return Ok(w);
    }
    match spec::load_named(&repo_root(), name) {
        Some(r) => r,
        None => Err(anyhow!(
            "unknown workload {name:?} (not a zoo model or a \
             data/workloads/*.json spec)"
        )),
    }
}

/// Everything servable, as `(name, source, load outcome)` rows: the
/// zoo builders (source `"zoo"`) followed by the `data/workloads/`
/// spec files (source `"spec"`, excluding stems a zoo builder shadows
/// in resolution). Broken spec files surface as their `Err` instead
/// of being hidden. The single listing consumed by both the server's
/// `workloads` verb and the CLI's `workloads` subcommand, so the two
/// can never diverge.
#[allow(clippy::type_complexity)]
pub fn workload_catalog()
    -> Vec<(String, &'static str, Result<Workload>)> {
    let mut rows = Vec::new();
    for name in zoo::names() {
        if let Some(w) = zoo::by_name(name) {
            rows.push((name.to_string(), "zoo", Ok(w)));
        }
    }
    let repo = repo_root();
    for name in spec::list_spec_names(&repo) {
        if zoo::by_name(&name).is_some() {
            continue; // the zoo builder shadows the file in resolution
        }
        if let Some(r) = spec::load_named(&repo, &name) {
            rows.push((name, "spec", r));
        }
    }
    rows
}

/// Run one job on a given (optional) runtime; also used directly by
/// the CLI. GA/BO/random score through the search-owned
/// [`crate::search::EvalEngine`] and never touch the runtime; the
/// gradient methods use it as an accelerator when present and run the
/// native differentiable model otherwise.
pub fn execute_job(rt: Option<&Runtime>, req: &JobRequest)
                   -> Result<JobResult> {
    execute_job_ctx(rt, req, &JobCtx::default())
}

/// Reconstruct and re-verify a stored result against the live cost
/// model: the strategy must decode, be feasible, and reproduce the
/// stored energy/latency/EDP bit-for-bit. `None` means "do not trust
/// it" — the caller drops the entry and searches cold.
fn stored_job_result(sr: &store::StoredResult, req: &JobRequest,
                     w: &Workload, hw: &HwConfig,
                     t0: std::time::Instant) -> Option<JobResult> {
    let strat = sr.strategy()?;
    if strat.mappings.len() != w.len() {
        return None;
    }
    let e = crate::search::eval::compute_eval(&strat, w, hw);
    let same = e.feasible
        && e.energy.to_bits() == sr.energy.to_bits()
        && e.latency.to_bits() == sr.latency.to_bits()
        && e.edp.to_bits() == sr.edp.to_bits();
    if !same {
        return None;
    }
    let groups = strat.groups();
    let fused_names = groups
        .iter()
        .filter(|(a, b)| b > a)
        .map(|&(a, b)| {
            w.layers[a..=b].iter().map(|l| l.name.clone()).collect()
        })
        .collect();
    Some(JobResult {
        request: req.clone(),
        edp: sr.edp,
        full_model_edp: sr.edp * w.replicas * w.replicas,
        energy: sr.energy,
        latency: sr.latency,
        groups,
        fused_names,
        iters: sr.iters,
        evals: sr.evals,
        wall_seconds: t0.elapsed().as_secs_f64(),
        stored: true,
        deadline_hit: false,
        // only certified exact results are recorded, so a stored hit
        // for the exact method is certified by construction
        exact: match req.method {
            Method::Exact => Some(exact::ExactStats {
                certified: true,
                space_complete: true,
                ..Default::default()
            }),
            _ => None,
        },
    })
}

/// [`execute_job`] with a serving context: native methods pick up the
/// shared cache for the job's `(workload, config)` pair, batch on the
/// persistent pool, and poll the cancel flag between batches. With a
/// store in the context, an exact-key stored result short-circuits the
/// search entirely (unless the request sets `force`), and a fresh
/// result records back on improvement.
pub fn execute_job_ctx(rt: Option<&Runtime>, req: &JobRequest,
                       ctx: &JobCtx) -> Result<JobResult> {
    if fault::fire(fault::JOB_PANIC) {
        panic!("injected: job panic");
    }
    let t0 = std::time::Instant::now();
    let w_arc: Arc<Workload> = match &req.spec {
        Some(inline) => Arc::clone(inline),
        None => Arc::new(resolve_workload(&req.workload)?),
    };
    let w: &Workload = &w_arc;
    let hw_arc = Arc::new(load_config(&repo_root(), &req.config)?);
    let hw: &HwConfig = &hw_arc;
    let store_key = ctx.store.as_ref().map(|_| {
        ResultStore::result_key(&spec::fingerprint(w),
                                &hw.fingerprint(), req)
    });
    if let (Some(st), Some(key), false) =
        (&ctx.store, &store_key, req.force)
    {
        if let Some(sr) = st.load_result(key) {
            match stored_job_result(&sr, req, w, hw, t0) {
                Some(jr) => {
                    st.stats()
                        .result_hits
                        .fetch_add(1, Ordering::SeqCst);
                    return Ok(jr);
                }
                // digest-valid but unreproducible (e.g. a cost-model
                // drift): drop it and fall through to a cold search
                None => st.reject_result(key),
            }
        }
    }
    let budget = Budget { seconds: req.seconds, max_iters: req.max_iters };
    let mut ectx = ctx.eval_ctx(req, &w_arc, &hw_arc);
    if let Some(lib) = &ctx.library {
        // hydrate this config's shard before any record/seed touches
        // it (a persisted shard merges under the in-memory one,
        // improvement-gated per fingerprint)
        let config_fp = hw.fingerprint();
        lib.ensure_loaded(&config_fp, ctx.store.as_deref());
        if req.warm_frac > 0.0 {
            let tables = WorkloadTables::new(w);
            ectx.seeds = lib.seeds_for(&config_fp, w, hw, &tables);
        }
    }
    // the CLI path has no worker to start the clock, so the deadline
    // begins here; server jobs carry one from their worker already
    if ectx.deadline.is_none() && req.deadline_ms > 0 {
        ectx.deadline = Some(Deadline::in_ms(req.deadline_ms));
    }
    let deadline = ectx.deadline.clone();
    let mut exact_stats: Option<exact::ExactStats> = None;
    let r: SearchResult = match req.method {
        Method::FADiff => gradient::optimize_ctx(
            rt, w, &hw,
            &gradient::GradientConfig { seed: req.seed,
                                        chains: req.chains,
                                        ..Default::default() },
            budget, &ectx)?,
        Method::Dosa => gradient::optimize_ctx(
            rt, w, &hw,
            &gradient::GradientConfig {
                seed: req.seed,
                chains: req.chains,
                ..gradient::GradientConfig::dosa()
            },
            budget, &ectx)?,
        Method::Ga => ga::optimize_ctx(
            w, &hw, &ga::GaConfig { seed: req.seed, ..Default::default() },
            budget, &ectx)?,
        Method::Bo => bo::optimize_ctx(
            w, &hw, &bo::BoConfig { seed: req.seed, ..Default::default() },
            budget, &ectx)?,
        Method::Random => random::optimize_ctx(w, &hw, req.seed, budget,
                                               &ectx)?,
        Method::Exact => {
            let out = exact::optimize(w, &hw,
                                      &exact::ExactConfig::default(),
                                      &budget, &ectx)?;
            exact_stats = Some(out.stats);
            out.result
        }
    };
    // final safety: the result must be hardware-valid
    costmodel::feasible(&r.best, w, &hw)
        .map_err(|e| anyhow!("coordinator produced invalid strategy: {e}"))?;
    // a cancelled or deadline-cut job's partial best is served to its
    // caller but never recorded — neither to the result store (the
    // stored incumbent for a key must always be a full run of that
    // key's budget) nor to the mapping library (same rule)
    let cancelled = ctx
        .cancel
        .as_ref()
        .is_some_and(|c| c.load(Ordering::SeqCst));
    let cut = deadline.as_ref().is_some_and(|d| d.was_hit());
    // an uncertified exact result (node/candidate cap tripped) is
    // best-effort, but a stored hit for the exact method is served as
    // certified — so only certified runs may record under that key
    let certified_ok = exact_stats.map_or(true, |e| e.certified);
    if let (Some(st), Some(key)) = (&ctx.store, &store_key) {
        if !cancelled && !cut && certified_ok {
            st.record_result(key, &store::StoredResult::of(&r));
        }
    }
    if let Some(lib) = &ctx.library {
        if !cancelled && !cut {
            lib.record(&hw.fingerprint(), w, hw, &r.best);
        }
    }
    let groups = r.best.groups();
    let fused_names = groups
        .iter()
        .filter(|(a, b)| b > a)
        .map(|&(a, b)| {
            w.layers[a..=b].iter().map(|l| l.name.clone()).collect()
        })
        .collect();
    Ok(JobResult {
        request: req.clone(),
        edp: r.edp,
        full_model_edp: r.full_model_edp(w),
        energy: r.energy,
        latency: r.latency,
        groups,
        fused_names,
        iters: r.iters,
        evals: r.evals,
        wall_seconds: t0.elapsed().as_secs_f64(),
        stored: false,
        deadline_hit: deadline
            .as_ref()
            .is_some_and(|d| d.was_hit()),
        exact: exact_stats,
    })
}

/// Graceful-shutdown flag shared with the TCP server.
pub struct ShutdownFlag(
    /// Set to true to stop accepting and join every connection.
    pub Arc<AtomicBool>,
);

impl Default for ShutdownFlag {
    fn default() -> Self {
        ShutdownFlag(Arc::new(AtomicBool::new(false)))
    }
}
