//! The scheduling coordinator: a job service that accepts deployment
//! optimization requests (workload x hardware x method x budget) and
//! dispatches them to a pool of worker threads, each owning a private
//! PJRT runtime (the xla crate's client is `Rc`-based and must not cross
//! threads).
//!
//! This is the L3 "production" face of FADiff: a long-running process
//! (`fadiff serve`) or an embedded library (`Coordinator::new`) that
//! turns DNN deployment requests into hardware-valid strategies, with
//! queueing, metrics, and graceful shutdown. Python never runs here —
//! workers execute the AOT artifacts.
//!
//! The PJRT runtime is OPTIONAL: all native methods (GA / BO / random)
//! score through [`crate::search::EvalEngine`] and serve even when the
//! AOT artifacts are absent; only the gradient methods (FADiff / DOSA)
//! require a runtime and fail per-job with an actionable error without
//! one.

pub mod metrics;
pub mod server;

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::config::{load_config, repo_root};
use crate::costmodel;
use crate::runtime::Runtime;
use crate::search::{bo, ga, gradient, random, Budget, SearchResult};
use crate::util::threadpool::{oneshot, OneShot, OneShotSender};
use crate::workload::zoo;

pub use metrics::Metrics;

/// Optimization method selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    FADiff,
    Dosa,
    Ga,
    Bo,
    Random,
}

impl Method {
    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "fadiff" | "gradient" => Method::FADiff,
            "dosa" | "layerwise" => Method::Dosa,
            "ga" | "genetic" => Method::Ga,
            "bo" | "bayesian" => Method::Bo,
            "random" | "rand" => Method::Random,
            other => return Err(anyhow!("unknown method {other:?}")),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::FADiff => "fadiff",
            Method::Dosa => "dosa",
            Method::Ga => "ga",
            Method::Bo => "bo",
            Method::Random => "random",
        }
    }
}

/// A deployment-optimization request.
#[derive(Clone, Debug)]
pub struct JobRequest {
    pub workload: String,
    pub config: String,
    pub method: Method,
    pub seconds: f64,
    pub max_iters: usize,
    pub seed: u64,
}

impl Default for JobRequest {
    fn default() -> Self {
        JobRequest {
            workload: "resnet18".into(),
            config: "large".into(),
            method: Method::FADiff,
            seconds: 10.0,
            max_iters: usize::MAX,
            seed: 0xFAD1FF,
        }
    }
}

/// The outcome handed back to the requester.
#[derive(Clone, Debug)]
pub struct JobResult {
    pub request: JobRequest,
    /// Per-replica EDP (pJ * cycles).
    pub edp: f64,
    /// Full-model EDP (replica^2-scaled, Table-1 units).
    pub full_model_edp: f64,
    pub energy: f64,
    pub latency: f64,
    /// Fusion groups as (start, end) inclusive layer ranges.
    pub groups: Vec<(usize, usize)>,
    /// Layer names per fused group of size > 1.
    pub fused_names: Vec<Vec<String>>,
    pub iters: usize,
    pub evals: usize,
    pub wall_seconds: f64,
}

type Envelope = (JobRequest, OneShotSender<Result<JobResult, String>>);

/// The coordinator: queue + worker pool + metrics.
pub struct Coordinator {
    tx: Option<Sender<Envelope>>,
    workers: Vec<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
}

impl Coordinator {
    /// Spawn `n_workers` workers, each loading its own PJRT runtime
    /// from `artifacts_dir` (defaults to `<repo>/artifacts`). Missing
    /// artifacts degrade the service to native methods only.
    pub fn new(artifacts_dir: Option<PathBuf>, n_workers: usize)
               -> Result<Coordinator> {
        let dir = artifacts_dir
            .unwrap_or_else(|| repo_root().join("artifacts"));
        // Same usability contract as tests/benches: artifacts must
        // exist AND compile (a stub xla crate fails here too). Under a
        // real backend this deliberately spends one grad-artifact
        // compile at construction so the degraded-mode warning is
        // accurate; the probed runtime cannot be reused by the workers
        // (the real PJRT client is not Send).
        if Runtime::load_if_available(&dir).is_none() {
            eprintln!(
                "[fadiff-coord] PJRT runtime unavailable under {dir:?}; \
                 serving native methods (ga/bo/random) only"
            );
        }
        let (tx, rx) = channel::<Envelope>();
        let rx = Arc::new(Mutex::new(rx));
        let metrics = Arc::new(Metrics::default());
        let workers = (0..n_workers.max(1))
            .map(|i| {
                let rx = Arc::clone(&rx);
                let dir = dir.clone();
                let metrics = Arc::clone(&metrics);
                std::thread::Builder::new()
                    .name(format!("fadiff-coord-{i}"))
                    .spawn(move || worker_loop(&dir, &rx, &metrics))
                    .expect("spawn coordinator worker")
            })
            .collect();
        Ok(Coordinator { tx: Some(tx), workers, metrics })
    }

    /// Submit a job; returns a handle to wait on.
    pub fn submit(&self, req: JobRequest)
                  -> OneShot<Result<JobResult, String>> {
        let (tx, rx) = oneshot();
        self.metrics.submitted.fetch_add(1, Ordering::SeqCst);
        self.tx
            .as_ref()
            .expect("coordinator shut down")
            .send((req, tx))
            .expect("workers alive");
        rx
    }

    /// Submit and block for the result.
    pub fn run(&self, req: JobRequest) -> Result<JobResult> {
        self.submit(req)
            .wait()
            .ok_or_else(|| anyhow!("worker dropped the job"))?
            .map_err(|e| anyhow!(e))
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(dir: &std::path::Path,
               rx: &Arc<Mutex<Receiver<Envelope>>>,
               metrics: &Arc<Metrics>) {
    // One PJRT runtime per worker; artifacts compile lazily on the
    // first gradient job so native-only service pays no startup
    // compiles (the accurate degraded-mode warning is emitted once by
    // Coordinator::new's load_if_available probe). A stub xla crate
    // passes this manifest gate and fails the per-job compile with its
    // own actionable message.
    let rt = Runtime::load(dir).ok();
    loop {
        let job = {
            let guard = rx.lock().unwrap();
            guard.recv()
        };
        let (req, reply) = match job {
            Ok(j) => j,
            Err(_) => break,
        };
        metrics.started.fetch_add(1, Ordering::SeqCst);
        let out = execute_job(rt.as_ref(), &req);
        match &out {
            Ok(_) => metrics.completed.fetch_add(1, Ordering::SeqCst),
            Err(_) => metrics.failed.fetch_add(1, Ordering::SeqCst),
        };
        reply.send(out.map_err(|e| e.to_string()));
    }
}

/// Require a runtime for the gradient methods.
fn need_rt<'r>(rt: Option<&'r Runtime>, method: Method)
               -> Result<&'r Runtime> {
    rt.ok_or_else(|| {
        anyhow!(
            "method {:?} needs the AOT artifacts and a PJRT-backed xla \
             crate (run `make artifacts`); native methods ga/bo/random \
             remain available",
            method
        )
    })
}

/// Run one job on a given (optional) runtime; also used directly by the
/// CLI. Native methods score through the search-owned
/// [`crate::search::EvalEngine`] and never touch the runtime.
pub fn execute_job(rt: Option<&Runtime>, req: &JobRequest)
                   -> Result<JobResult> {
    let w = zoo::by_name(&req.workload)
        .ok_or_else(|| anyhow!("unknown workload {:?}", req.workload))?;
    let hw = load_config(&repo_root(), &req.config)?;
    let budget = Budget { seconds: req.seconds, max_iters: req.max_iters };
    let t0 = std::time::Instant::now();
    let r: SearchResult = match req.method {
        Method::FADiff => gradient::optimize(
            need_rt(rt, req.method)?, &w, &hw,
            &gradient::GradientConfig { seed: req.seed,
                                        ..Default::default() },
            budget)?,
        Method::Dosa => gradient::optimize(
            need_rt(rt, req.method)?, &w, &hw,
            &gradient::GradientConfig {
                seed: req.seed,
                ..gradient::GradientConfig::dosa()
            },
            budget)?,
        Method::Ga => ga::optimize(
            &w, &hw, &ga::GaConfig { seed: req.seed, ..Default::default() },
            budget)?,
        Method::Bo => bo::optimize(
            &w, &hw, &bo::BoConfig { seed: req.seed, ..Default::default() },
            budget)?,
        Method::Random => random::optimize(&w, &hw, req.seed, budget)?,
    };
    // final safety: the result must be hardware-valid
    costmodel::feasible(&r.best, &w, &hw)
        .map_err(|e| anyhow!("coordinator produced invalid strategy: {e}"))?;
    let groups = r.best.groups();
    let fused_names = groups
        .iter()
        .filter(|(a, b)| b > a)
        .map(|&(a, b)| {
            w.layers[a..=b].iter().map(|l| l.name.clone()).collect()
        })
        .collect();
    Ok(JobResult {
        request: req.clone(),
        edp: r.edp,
        full_model_edp: r.full_model_edp(&w),
        energy: r.energy,
        latency: r.latency,
        groups,
        fused_names,
        iters: r.iters,
        evals: r.evals,
        wall_seconds: t0.elapsed().as_secs_f64(),
    })
}

/// Graceful-shutdown flag shared with the TCP server.
pub struct ShutdownFlag(pub Arc<AtomicBool>);

impl Default for ShutdownFlag {
    fn default() -> Self {
        ShutdownFlag(Arc::new(AtomicBool::new(false)))
    }
}
