//! Cross-job evaluation-cache sharing: one memoized [`EvalCache`] per
//! `(workload, config)` pair, owned by the coordinator and handed to
//! every job's `EvalEngine`.
//!
//! This is what makes a warm serving process cheap: identical and
//! concurrent jobs on the same pair stop re-paying the cost-model bill
//! — the second `optimize` of a `(workload, config)` the process has
//! already seen resolves duplicate candidates from the shared cache, and
//! the `metrics` verb surfaces the hit/miss/eviction counters so the
//! effect is observable from the wire.
//!
//! The registry itself is bounded: beyond `capacity` distinct pairs the
//! least-recently-used pair is dropped (its counters are folded into
//! retired totals so service-lifetime stats stay monotone). Engines
//! already holding the evicted `Arc` keep using it safely; it simply
//! stops being handed to new jobs.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::store::{self, ResultStore};
use crate::config::HwConfig;
use crate::search::EvalCache;
use crate::util::json::{num, obj, Json};
use crate::workload::{spec, Workload};

/// Default bound on distinct `(workload, config)` caches. Each cache is
/// itself bounded (see [`crate::search::eval::DEFAULT_CACHE_CAPACITY`]),
/// so this caps worst-case memory at capacity x cache-bound entries.
pub const DEFAULT_REGISTRY_CAPACITY: usize = 32;

struct Entry {
    cache: Arc<EvalCache>,
    last_used: u64,
    /// Persistent-segment key, once known (set by the job path, which
    /// has the resolved workload/hardware to fingerprint).
    seg_key: Option<String>,
    /// `cache.misses()` at hydration / last flush: the cache is dirty
    /// (worth flushing) exactly when misses have grown past this.
    base_misses: u64,
}

/// Bounded LRU map of `(workload, config)` -> shared [`EvalCache`].
///
/// With a [`ResultStore`] attached, pairs hydrate from their persisted
/// eval-cache segment on first use and flush dirty segments on LRU
/// eviction and at coordinator shutdown ([`CacheRegistry::flush_all`])
/// — a restarted process starts warm instead of cold.
pub struct CacheRegistry {
    capacity: usize,
    entries: Mutex<HashMap<(String, String), Entry>>,
    clock: AtomicU64,
    store: Option<Arc<ResultStore>>,
    // counters folded in from evicted pairs so totals stay monotone
    retired_hits: AtomicU64,
    retired_misses: AtomicU64,
    retired_evictions: AtomicU64,
    evicted_pairs: AtomicU64,
}

impl CacheRegistry {
    /// Registry bounded at `capacity` distinct pairs (min 1), with no
    /// persistence.
    pub fn new(capacity: usize) -> CacheRegistry {
        CacheRegistry::with_store(capacity, None)
    }

    /// Registry bounded at `capacity` distinct pairs (min 1) that
    /// hydrates from / flushes to `store` when one is given.
    pub fn with_store(capacity: usize,
                      store: Option<Arc<ResultStore>>)
                      -> CacheRegistry {
        CacheRegistry {
            capacity: capacity.max(1),
            entries: Mutex::new(HashMap::new()),
            clock: AtomicU64::new(0),
            store,
            retired_hits: AtomicU64::new(0),
            retired_misses: AtomicU64::new(0),
            retired_evictions: AtomicU64::new(0),
            evicted_pairs: AtomicU64::new(0),
        }
    }

    /// The shared cache for `(workload, config)`, created on first use.
    /// Marks the pair most-recently-used; may evict the LRU pair when
    /// the registry is at capacity. Never hydrates (callers without
    /// the resolved workload cannot verify a segment); the job path
    /// uses [`CacheRegistry::cache_for_job`].
    pub fn cache_for(&self, workload: &str, config: &str)
                     -> Arc<EvalCache> {
        self.cache_for_inner(workload, config, None)
    }

    /// [`CacheRegistry::cache_for`] for the job execution path: on
    /// first use of a pair, its persisted eval-cache segment (keyed by
    /// the *content* fingerprints of `w` and `hw`) is loaded,
    /// sample-verified against the live cost model, and preloaded into
    /// the fresh cache — a failed verification drops the segment and
    /// starts cold instead of serving foreign or drifted evaluations.
    pub fn cache_for_job(&self, workload: &str, config: &str,
                         w: &Workload, hw: &HwConfig)
                         -> Arc<EvalCache> {
        let seg_key = self.store.as_ref().map(|_| {
            ResultStore::segment_key(&spec::fingerprint(w),
                                     &hw.fingerprint())
        });
        self.cache_for_inner(workload, config,
                             seg_key.map(|k| (k, w, hw)))
    }

    fn cache_for_inner(&self, workload: &str, config: &str,
                       hydrate: Option<(String, &Workload,
                                        &HwConfig)>)
                       -> Arc<EvalCache> {
        let stamp = self.clock.fetch_add(1, Ordering::SeqCst) + 1;
        let key = (workload.to_string(), config.to_string());
        let mut entries = self.entries.lock().unwrap();
        if let Some(e) = entries.get_mut(&key) {
            e.last_used = stamp;
            if e.seg_key.is_none() {
                // created via cache_for; adopt the segment key so the
                // pair still flushes on eviction/shutdown
                e.seg_key = hydrate.map(|(k, _, _)| k);
            }
            return Arc::clone(&e.cache);
        }
        if entries.len() >= self.capacity {
            let lru = entries
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone());
            if let Some(k) = lru {
                if let Some(mut e) = entries.remove(&k) {
                    self.flush_entry(&mut e);
                    self.retired_hits
                        .fetch_add(e.cache.hits(), Ordering::Relaxed);
                    self.retired_misses
                        .fetch_add(e.cache.misses(), Ordering::Relaxed);
                    self.retired_evictions
                        .fetch_add(e.cache.evictions(),
                                   Ordering::Relaxed);
                    self.evicted_pairs.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        let cache = Arc::new(EvalCache::default());
        let mut seg_key = None;
        if let (Some(store), Some((sk, w, hw))) =
            (&self.store, hydrate)
        {
            if let Some(seg) = store.load_segment(&sk) {
                if store::verify_segment_sample(&seg, w, hw) {
                    cache.preload(seg);
                    store
                        .stats()
                        .hydrations
                        .fetch_add(1, Ordering::SeqCst);
                } else {
                    store.reject_segment(&sk);
                }
            }
            seg_key = Some(sk);
        }
        entries.insert(key, Entry { cache: Arc::clone(&cache),
                                    last_used: stamp,
                                    seg_key,
                                    base_misses: 0 });
        cache
    }

    /// Flush a pair's eval cache to its persistent segment if it is
    /// dirty (has computed anything since hydration / its last flush).
    fn flush_entry(&self, e: &mut Entry) {
        let (Some(store), Some(seg_key)) = (&self.store, &e.seg_key)
        else {
            return;
        };
        let misses = e.cache.misses();
        if misses <= e.base_misses {
            return; // nothing new computed since the last flush
        }
        let exported = e.cache.export_entries();
        if !exported.is_empty()
            && store.save_segment(seg_key, &exported)
        {
            e.base_misses = misses;
        }
    }

    /// Flush every dirty pair to the store (coordinator shutdown).
    /// No-op without a store.
    pub fn flush_all(&self) {
        if self.store.is_none() {
            return;
        }
        let mut entries = self.entries.lock().unwrap();
        for e in entries.values_mut() {
            self.flush_entry(e);
        }
    }

    /// The attached persistent store, if any.
    pub fn store(&self) -> Option<&Arc<ResultStore>> {
        self.store.as_ref()
    }

    /// Distinct pairs currently registered.
    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    /// Whether no pair has been registered yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Configured pair bound.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Service-lifetime cache hits (live pairs + retired pairs).
    pub fn hits(&self) -> u64 {
        self.fold(|c| c.hits())
            + self.retired_hits.load(Ordering::Relaxed)
    }

    /// Service-lifetime unique computations.
    pub fn misses(&self) -> u64 {
        self.fold(|c| c.misses())
            + self.retired_misses.load(Ordering::Relaxed)
    }

    /// Service-lifetime entries dropped by per-cache capacity churn.
    pub fn evictions(&self) -> u64 {
        self.fold(|c| c.evictions())
            + self.retired_evictions.load(Ordering::Relaxed)
    }

    /// Pairs dropped by registry-level LRU eviction.
    pub fn evicted_pairs(&self) -> u64 {
        self.evicted_pairs.load(Ordering::Relaxed)
    }

    /// Strategies currently memoized across all live pairs.
    pub fn cached_strategies(&self) -> usize {
        self.fold(|c| c.len() as u64) as usize
    }

    fn fold(&self, f: impl Fn(&EvalCache) -> u64) -> u64 {
        self.entries
            .lock()
            .unwrap()
            .values()
            .map(|e| f(&e.cache))
            .sum()
    }

    /// The `cache` block of the `metrics` verb.
    pub fn stats_json(&self) -> Json {
        obj(vec![
            ("pairs", num(self.len() as f64)),
            ("strategies", num(self.cached_strategies() as f64)),
            ("hits", num(self.hits() as f64)),
            ("misses", num(self.misses() as f64)),
            ("evictions", num(self.evictions() as f64)),
            ("evicted_pairs", num(self.evicted_pairs() as f64)),
        ])
    }
}

impl Default for CacheRegistry {
    fn default() -> Self {
        CacheRegistry::new(DEFAULT_REGISTRY_CAPACITY)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_pair_same_cache_different_pair_different() {
        let r = CacheRegistry::new(8);
        let a1 = r.cache_for("resnet18", "large");
        let a2 = r.cache_for("resnet18", "large");
        let b = r.cache_for("resnet18", "small");
        let c = r.cache_for("vgg16", "large");
        assert!(Arc::ptr_eq(&a1, &a2), "same pair must share one cache");
        assert!(!Arc::ptr_eq(&a1, &b));
        assert!(!Arc::ptr_eq(&a1, &c));
        assert_eq!(r.len(), 3);
    }

    #[test]
    fn lru_eviction_keeps_recently_used_pairs() {
        let r = CacheRegistry::new(2);
        let a = r.cache_for("w1", "c");
        let _b = r.cache_for("w2", "c");
        let _a_again = r.cache_for("w1", "c"); // refresh w1
        let _c = r.cache_for("w3", "c"); // evicts w2 (LRU)
        assert_eq!(r.len(), 2);
        assert_eq!(r.evicted_pairs(), 1);
        // w1 survived: same Arc comes back
        let a2 = r.cache_for("w1", "c");
        assert!(Arc::ptr_eq(&a, &a2), "recently-used pair was evicted");
    }

    #[test]
    fn capacity_is_respected_under_churn() {
        let r = CacheRegistry::new(4);
        for i in 0..50 {
            let _ = r.cache_for(&format!("w{i}"), "large");
            assert!(r.len() <= 4);
        }
        assert_eq!(r.evicted_pairs(), 46);
    }

    #[test]
    fn stats_json_has_all_counters() {
        let r = CacheRegistry::default();
        let _ = r.cache_for("resnet18", "large");
        let j = r.stats_json();
        for key in ["pairs", "strategies", "hits", "misses", "evictions",
                    "evicted_pairs"] {
            assert!(j.get(key).is_ok(), "missing {key}");
        }
        assert_eq!(j.get_f64("pairs").unwrap(), 1.0);
    }
}
