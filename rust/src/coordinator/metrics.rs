//! Coordinator metrics: lock-free counters surfaced on the CLI and the
//! TCP server's `metrics` verb.

use std::sync::atomic::{AtomicU64, Ordering};

/// Service counters.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Jobs accepted into the queue.
    pub submitted: AtomicU64,
    /// Jobs a worker began executing.
    pub started: AtomicU64,
    /// Jobs that finished successfully.
    pub completed: AtomicU64,
    /// Jobs that finished with an error.
    pub failed: AtomicU64,
    /// Jobs that ended because a `cancel` arrived (whether they were
    /// still queued or already running).
    pub cancelled: AtomicU64,
    /// Cumulative candidate evaluations reported by finished jobs
    /// (`JobResult::evals`, i.e. candidates offered to each search's
    /// incumbent — memoization-cache hits included; gradient jobs
    /// count their decode refreshes, not inner gradient steps). The
    /// coordinator divides by uptime for the `metrics` verb's
    /// `throughput.evals_per_sec` ("since start", so idle time
    /// dilutes the rate by design).
    pub evals: AtomicU64,
    /// Cumulative inner gradient steps reported by finished FADiff /
    /// DOSA jobs (`JobResult::iters`, summed across their parallel
    /// chains). Surfaced as `throughput.grad_steps_total` /
    /// `grad_steps_per_sec` in the `metrics` verb — the direct
    /// quality-per-second lever of the multi-chain optimizer.
    pub grad_steps: AtomicU64,
    /// Jobs that ended because their cooperative `deadline_ms`
    /// expired (a terminal outcome distinct from `completed` /
    /// `failed` / `cancelled`; the job keeps its best-so-far).
    pub deadline_exceeded: AtomicU64,
    /// Job executions that panicked and were contained by the worker's
    /// `catch_unwind` (the job answers `internal`, the worker keeps
    /// serving). Surfaced as
    /// `supervision.job_panics_contained`.
    pub job_panics: AtomicU64,
    /// Jobs the watchdog failed definitively after their evals made
    /// no progress past the stall threshold. Surfaced as
    /// `supervision.watchdog_kills`.
    pub watchdog_kills: AtomicU64,
    /// Oversized request lines the event loop answered `too_large`
    /// and drained instead of queueing. Surfaced as
    /// `faults.oversized_drains`.
    pub oversized_drains: AtomicU64,
    /// Requests rejected with `queue_full` (queue at capacity or the
    /// connection table saturated). Surfaced as
    /// `faults.queue_full_rejected`.
    pub queue_full_rejected: AtomicU64,
    /// Gauge: connections the event loop currently holds open
    /// (refreshed once per loop sweep; watch streams included).
    pub conns_open: AtomicU64,
    /// Branch-and-bound exact jobs that finished (stored hits
    /// excluded — they report the original run's effort). Surfaced as
    /// `exact.jobs` in the `metrics` verb.
    pub exact_jobs: AtomicU64,
    /// Of those, how many returned a certified optimum (no node or
    /// candidate cap tripped). Surfaced as `exact.certified`.
    pub exact_certified: AtomicU64,
    /// Cumulative search-tree nodes the exact mapper expanded across
    /// finished jobs. Surfaced as `exact.nodes_expanded`.
    pub exact_nodes: AtomicU64,
    /// Cumulative subtrees pruned (bound + infeasible + dominance)
    /// across finished exact jobs. Surfaced as `exact.pruned`.
    pub exact_pruned: AtomicU64,
}

impl Metrics {
    /// Jobs accepted but not finished (every terminal outcome —
    /// completed, failed, cancelled, deadline-exceeded — leaves the
    /// flight count).
    pub fn in_flight(&self) -> u64 {
        let s = self.submitted.load(Ordering::SeqCst);
        let c = self.completed.load(Ordering::SeqCst)
            + self.failed.load(Ordering::SeqCst)
            + self.cancelled.load(Ordering::SeqCst)
            + self.deadline_exceeded.load(Ordering::SeqCst);
        s.saturating_sub(c)
    }

    /// Render as a one-line summary.
    pub fn summary(&self) -> String {
        format!(
            "submitted={} started={} completed={} failed={} \
             cancelled={} in_flight={}",
            self.submitted.load(Ordering::SeqCst),
            self.started.load(Ordering::SeqCst),
            self.completed.load(Ordering::SeqCst),
            self.failed.load(Ordering::SeqCst),
            self.cancelled.load(Ordering::SeqCst),
            self.in_flight()
        )
    }

    /// Render as JSON (merged with the cache-registry stats by
    /// [`super::Coordinator::metrics_json`] for the `metrics` verb).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{num, obj};
        obj(vec![
            ("submitted",
             num(self.submitted.load(Ordering::SeqCst) as f64)),
            ("started", num(self.started.load(Ordering::SeqCst) as f64)),
            ("completed",
             num(self.completed.load(Ordering::SeqCst) as f64)),
            ("failed", num(self.failed.load(Ordering::SeqCst) as f64)),
            ("cancelled",
             num(self.cancelled.load(Ordering::SeqCst) as f64)),
            ("deadline_exceeded",
             num(self.deadline_exceeded.load(Ordering::SeqCst)
                 as f64)),
            ("in_flight", num(self.in_flight() as f64)),
            ("evals", num(self.evals.load(Ordering::SeqCst) as f64)),
            ("grad_steps",
             num(self.grad_steps.load(Ordering::SeqCst) as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn in_flight_accounting() {
        let m = Metrics::default();
        m.submitted.fetch_add(3, Ordering::SeqCst);
        m.completed.fetch_add(1, Ordering::SeqCst);
        m.failed.fetch_add(1, Ordering::SeqCst);
        assert_eq!(m.in_flight(), 1);
        assert!(m.summary().contains("in_flight=1"));
    }

    #[test]
    fn deadline_exceeded_is_terminal_for_in_flight() {
        let m = Metrics::default();
        m.submitted.fetch_add(2, Ordering::SeqCst);
        m.completed.fetch_add(1, Ordering::SeqCst);
        m.deadline_exceeded.fetch_add(1, Ordering::SeqCst);
        assert_eq!(m.in_flight(), 0,
                   "a deadline-exceeded job left the flight count");
    }
}
