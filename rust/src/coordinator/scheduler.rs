//! The cross-job fleet scheduler: merges evaluation batches from
//! concurrent jobs into shared kernel passes on the persistent pool.
//!
//! Why: the ROADMAP's serving goal is N concurrent small jobs costing
//! ~1 big job. Every search method already routes scoring through
//! [`crate::search::EvalEngine`], and the engine already folds
//! duplicates and cache hits — but each job still ran its *own* pool
//! pass per batch, so N concurrent jobs paid N pass set-ups and fought
//! each other for workers in small, fragmented batches. The scheduler
//! gives the coordinator one merge point instead: engines built with a
//! [`FleetHandle`] enqueue `(candidates, reply)` work items here, a
//! single scheduler thread drains whatever is pending, coalesces items
//! with the same `(workload, config)` key into one
//! [`crate::costmodel::batch`] pass over the shared
//! [`crate::util::threadpool::ThreadPool`], and routes each job back
//! exactly its slice of the results.
//!
//! Bit-identity: merging changes *where* candidates are computed, never
//! what is computed. Every candidate runs
//! [`crate::search::eval::compute_eval`] — the same function the
//! engine's local path runs — each candidate independently, with
//! per-thread scratch, and replies preserve submission order. So a
//! merged pass is bit-for-bit identical to per-job serial evaluation at
//! any pool size and any interleaving (pinned by
//! `rust/tests/scheduler.rs`).
//!
//! Observability: the `metrics` verb surfaces [`FleetScheduler::
//! stats_json`] — passes, items, merged passes, the largest merge —
//! so cross-job coalescing is visible from the wire.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::search::eval::{compute_eval, Eval, EvalBackend, FleetHandle};
use crate::mapping::Strategy;
use crate::util::json::{num, obj, Json};
use crate::util::threadpool::{oneshot, OneShotSender, ThreadPool};

/// One job's pending evaluation batch: the coalescing key, the
/// candidates, and where to send their scores. The workload/hardware
/// ride along as `Arc`s inside the handle snapshot so the scheduler
/// thread can compute after the submitting engine's borrows are gone.
struct WorkItem {
    key: String,
    handle: FleetHandle,
    strategies: Vec<Strategy>,
    reply: OneShotSender<Vec<Eval>>,
}

/// Lock-free merge counters (surfaced under `"scheduler"` in the
/// `metrics` verb).
#[derive(Default)]
pub struct SchedStats {
    /// Kernel passes executed.
    pub passes: AtomicU64,
    /// Passes that merged work items from >= 2 submissions.
    pub merged_passes: AtomicU64,
    /// Work items accepted — counted at enqueue, so a held scheduler
    /// (see [`FleetScheduler::hold`]) still reports arrivals and a
    /// test can wait for N items before releasing.
    pub items: AtomicU64,
    /// Work items that shared their pass with at least one other item.
    pub merged_items: AtomicU64,
    /// Candidates scored.
    pub candidates: AtomicU64,
    /// Largest number of items ever coalesced into one pass.
    pub max_items_per_pass: AtomicU64,
    /// Passes that panicked and were contained: the group's reply
    /// senders drop, every waiting engine receives an empty vector and
    /// falls back to its bit-identical local path, and the scheduler
    /// thread keeps draining. Surfaced here and as
    /// `supervision.scheduler_panics_contained` in the `metrics` verb.
    pub panics_contained: AtomicU64,
}

impl SchedStats {
    fn max_update(slot: &AtomicU64, v: u64) {
        slot.fetch_max(v, Ordering::Relaxed);
    }

    /// Count one arrival, then run `send`. The increment happens
    /// *before* the send: a successful send makes the item visible to
    /// the scheduler thread immediately, so the [`SchedStats::items`]
    /// contract ("counted at enqueue") requires the counter to already
    /// include it — incrementing after the send (the old order) let a
    /// test wait for N arrivals, release the scheduler, and still race
    /// the count. A failed send undoes the increment, so shutdown
    /// never inflates arrivals.
    fn send_counted(&self, send: impl FnOnce() -> bool) -> bool {
        self.items.fetch_add(1, Ordering::Relaxed);
        let sent = send();
        if !sent {
            self.items.fetch_sub(1, Ordering::Relaxed);
        }
        sent
    }

    /// The `scheduler` block of the `metrics` verb.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("passes",
             num(self.passes.load(Ordering::Relaxed) as f64)),
            ("merged_passes",
             num(self.merged_passes.load(Ordering::Relaxed) as f64)),
            ("items", num(self.items.load(Ordering::Relaxed) as f64)),
            ("merged_items",
             num(self.merged_items.load(Ordering::Relaxed) as f64)),
            ("candidates",
             num(self.candidates.load(Ordering::Relaxed) as f64)),
            ("max_items_per_pass",
             num(self.max_items_per_pass.load(Ordering::Relaxed)
                 as f64)),
            ("panics_contained",
             num(self.panics_contained.load(Ordering::Relaxed)
                 as f64)),
        ])
    }
}

/// The coordinator-owned scheduler: one thread draining work items,
/// coalescing same-key items into shared pool passes.
pub struct FleetScheduler {
    tx: Mutex<Option<Sender<WorkItem>>>,
    thread: Mutex<Option<JoinHandle<()>>>,
    stats: Arc<SchedStats>,
    hold: Arc<AtomicBool>,
}

impl FleetScheduler {
    /// Spawn the scheduler thread; passes run on `pool` (the
    /// coordinator's persistent evaluation pool — the scheduler thread
    /// itself is *not* a pool worker, so scoped submission into the
    /// pool cannot deadlock on its own slot).
    pub fn new(pool: Arc<ThreadPool>) -> FleetScheduler {
        let (tx, rx) = channel::<WorkItem>();
        let stats = Arc::new(SchedStats::default());
        let hold = Arc::new(AtomicBool::new(false));
        let thread = {
            let stats = Arc::clone(&stats);
            let hold = Arc::clone(&hold);
            std::thread::Builder::new()
                .name("fadiff-fleet-sched".into())
                .spawn(move || scheduler_loop(&rx, &pool, &stats, &hold))
                .expect("spawn fleet scheduler")
        };
        FleetScheduler {
            tx: Mutex::new(Some(tx)),
            thread: Mutex::new(Some(thread)),
            stats,
            hold,
        }
    }

    /// Merge counters.
    pub fn stats(&self) -> &SchedStats {
        &self.stats
    }

    /// The `scheduler` block of the `metrics` verb.
    pub fn stats_json(&self) -> Json {
        self.stats.to_json()
    }

    /// Scheduler passes that panicked and were contained (see
    /// [`SchedStats::panics_contained`]).
    pub fn panics_contained(&self) -> u64 {
        self.stats.panics_contained.load(Ordering::Relaxed)
    }

    /// Test/bench hook: park the scheduler *after* draining — items
    /// keep accumulating but no pass runs until [`FleetScheduler::
    /// release`]. Lets a test submit N concurrent jobs and force their
    /// first batches into one deterministic merged pass.
    pub fn hold(&self) {
        self.hold.store(true, Ordering::SeqCst);
    }

    /// Resume coalesced processing after [`FleetScheduler::hold`].
    pub fn release(&self) {
        self.hold.store(false, Ordering::SeqCst);
    }
}

impl EvalBackend for FleetScheduler {
    /// Enqueue one batch and block for its scores. Returns an empty
    /// vector when the scheduler is shutting down — the engine then
    /// computes locally (same numbers, no merging).
    fn eval_candidates(&self, handle: &FleetHandle,
                       strategies: Vec<Strategy>) -> Vec<Eval> {
        if strategies.is_empty() {
            return Vec::new();
        }
        // injected channel drop: behave exactly as a shutting-down
        // scheduler — the engine computes the batch locally instead
        if crate::util::fault::fire(crate::util::fault::SCHED_DROP) {
            return Vec::new();
        }
        let (reply, rx) = oneshot();
        let item = WorkItem {
            key: handle.key.clone(),
            handle: handle.clone(),
            strategies,
            reply,
        };
        let sent = self.stats.send_counted(|| {
            match &*self.tx.lock().unwrap() {
                Some(tx) => tx.send(item).is_ok(),
                None => false,
            }
        });
        if !sent {
            return Vec::new();
        }
        rx.wait().unwrap_or_default()
    }
}

impl Drop for FleetScheduler {
    fn drop(&mut self) {
        self.hold.store(false, Ordering::SeqCst);
        drop(self.tx.lock().unwrap().take());
        if let Some(t) = self.thread.lock().unwrap().take() {
            let _ = t.join();
        }
    }
}

fn scheduler_loop(rx: &Receiver<WorkItem>, pool: &Arc<ThreadPool>,
                  stats: &SchedStats, hold: &AtomicBool) {
    loop {
        // block for the first pending item...
        let first = match rx.recv() {
            Ok(i) => i,
            Err(_) => break, // coordinator dropped — drain done
        };
        let mut batch = vec![first];
        // ...then opportunistically drain everything else already
        // queued: this is the merge window. Items submitted while a
        // previous pass was running coalesce here.
        while let Ok(item) = rx.try_recv() {
            batch.push(item);
        }
        // test hook: keep absorbing items without processing
        while hold.load(Ordering::SeqCst) {
            std::thread::sleep(std::time::Duration::from_millis(1));
            while let Ok(item) = rx.try_recv() {
                batch.push(item);
            }
        }
        run_passes(batch, pool, stats);
    }
}

/// Group the drained items by key (same `(workload, config)` pair) and
/// run one shared pool pass per group, then split each pass's results
/// back into per-item slices in submission order.
fn run_passes(batch: Vec<WorkItem>, pool: &Arc<ThreadPool>,
              stats: &SchedStats) {
    // stable grouping: first-arrival order of keys, and items keep
    // their submission order within a group
    let mut order: Vec<String> = Vec::new();
    let mut groups: HashMap<String, Vec<WorkItem>> = HashMap::new();
    for item in batch {
        if !groups.contains_key(&item.key) {
            order.push(item.key.clone());
        }
        groups.entry(item.key.clone()).or_default().push(item);
    }
    for key in order {
        let group = groups.remove(&key).expect("grouped");
        // contain a panicking pass: dropping the group drops its reply
        // senders, every waiting engine gets an empty vector and falls
        // back to the bit-identical local path, and this thread lives
        // on to serve the remaining groups
        let caught = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                run_one_pass(group, pool, stats);
            }),
        );
        if let Err(p) = caught {
            stats.panics_contained.fetch_add(1, Ordering::Relaxed);
            eprintln!(
                "fleet scheduler: contained pass panic for key \
                 {key:?}: {}",
                crate::coordinator::panic_message(p)
            );
        }
    }
}

fn run_one_pass(group: Vec<WorkItem>, pool: &Arc<ThreadPool>,
                stats: &SchedStats) {
    if crate::util::fault::fire(crate::util::fault::SCHED_PANIC) {
        panic!("injected: scheduler pass panic");
    }
    let n_items = group.len() as u64;
    stats.passes.fetch_add(1, Ordering::Relaxed);
    if n_items >= 2 {
        stats.merged_passes.fetch_add(1, Ordering::Relaxed);
        stats.merged_items.fetch_add(n_items, Ordering::Relaxed);
    }
    SchedStats::max_update(&stats.max_items_per_pass, n_items);
    // flatten to (item, candidate) tasks — one shared kernel pass over
    // the whole merged population
    let mut tasks: Vec<(usize, usize)> = Vec::new();
    for (i, item) in group.iter().enumerate() {
        for c in 0..item.strategies.len() {
            tasks.push((i, c));
        }
    }
    stats.candidates.fetch_add(tasks.len() as u64, Ordering::Relaxed);
    // scoped_map preserves task order, and compute_eval is exactly the
    // engine's local computation — per-candidate independence is what
    // makes the merged pass bit-identical to per-job evaluation
    let evals: Vec<Eval> = pool.scoped_map(tasks, |(i, c)| {
        let item = &group[i];
        compute_eval(&item.strategies[c], &item.handle.w,
                     &item.handle.hw)
    });
    // split back per item (tasks are grouped by item, in order)
    let mut cursor = 0usize;
    for item in group {
        let n = item.strategies.len();
        let slice = evals[cursor..cursor + n].to_vec();
        cursor += n;
        item.reply.send(slice);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{load_config, repo_root};
    use crate::search::EvalEngine;
    use crate::util::rng::Rng;
    use crate::mapping::decode::{decode, Relaxed};
    use crate::workload::zoo;

    fn random_pop(w: &crate::workload::Workload,
                  hw: &crate::config::HwConfig, n: usize, seed: u64)
                  -> Vec<Strategy> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                let mut r = Relaxed::neutral(w);
                for l in 0..w.len() {
                    for d in 0..7 {
                        for s in 0..4 {
                            r.theta[l][d][s] = rng.range(0.0, 7.0);
                        }
                    }
                }
                for i in 0..r.sigma.len() {
                    r.sigma[i] = rng.f64();
                }
                decode(&r, w, hw)
            })
            .collect()
    }

    fn handle_for(sched: &Arc<FleetScheduler>,
                  w: &crate::workload::Workload,
                  hw: &crate::config::HwConfig, key: &str)
                  -> FleetHandle {
        FleetHandle {
            backend: Arc::clone(sched) as Arc<dyn EvalBackend>,
            w: Arc::new(w.clone()),
            hw: Arc::new(hw.clone()),
            key: key.to_string(),
        }
    }

    #[test]
    fn single_item_matches_local_engine() {
        let hw = load_config(&repo_root(), "large").unwrap();
        let w = zoo::mobilenet_v1();
        let pop = random_pop(&w, &hw, 16, 5);
        let expect = EvalEngine::new(&w, &hw).eval_batch(&pop);
        let pool = Arc::new(ThreadPool::new(4));
        let sched = Arc::new(FleetScheduler::new(pool));
        let h = handle_for(&sched, &w, &hw, "mobilenet\0large");
        let got = sched.eval_candidates(&h, pop.clone());
        assert_eq!(got, expect);
        assert_eq!(sched.stats().passes.load(Ordering::Relaxed), 1);
        assert_eq!(sched.stats().merged_passes.load(Ordering::Relaxed),
                   0);
    }

    #[test]
    fn held_items_merge_into_one_pass_bit_identically() {
        let hw = load_config(&repo_root(), "large").unwrap();
        let w = zoo::mobilenet_v1();
        let pop_a = random_pop(&w, &hw, 9, 41);
        let pop_b = random_pop(&w, &hw, 13, 42);
        let exp_a = EvalEngine::new(&w, &hw).eval_batch(&pop_a);
        let exp_b = EvalEngine::new(&w, &hw).eval_batch(&pop_b);
        let pool = Arc::new(ThreadPool::new(4));
        let sched = Arc::new(FleetScheduler::new(pool));
        sched.hold();
        let ha = handle_for(&sched, &w, &hw, "k\0large");
        let hb = handle_for(&sched, &w, &hw, "k\0large");
        let sa = Arc::clone(&sched);
        let sb = Arc::clone(&sched);
        let pa = pop_a.clone();
        let pb = pop_b.clone();
        let ta = std::thread::spawn(move || sa.eval_candidates(&ha, pa));
        let tb = std::thread::spawn(move || sb.eval_candidates(&hb, pb));
        // let both items reach the parked scheduler, then release
        std::thread::sleep(std::time::Duration::from_millis(60));
        sched.release();
        assert_eq!(ta.join().unwrap(), exp_a);
        assert_eq!(tb.join().unwrap(), exp_b);
        let st = sched.stats();
        assert_eq!(st.merged_passes.load(Ordering::Relaxed), 1,
                   "both items must share one pass");
        assert_eq!(st.merged_items.load(Ordering::Relaxed), 2);
        assert_eq!(st.max_items_per_pass.load(Ordering::Relaxed), 2);
        assert_eq!(st.candidates.load(Ordering::Relaxed),
                   (pop_a.len() + pop_b.len()) as u64);
    }

    #[test]
    fn different_keys_never_share_a_pass() {
        let hw = load_config(&repo_root(), "large").unwrap();
        let wa = zoo::mobilenet_v1();
        let wb = zoo::resnet18();
        let pop_a = random_pop(&wa, &hw, 4, 7);
        let pop_b = random_pop(&wb, &hw, 4, 8);
        let exp_a = EvalEngine::new(&wa, &hw).eval_batch(&pop_a);
        let exp_b = EvalEngine::new(&wb, &hw).eval_batch(&pop_b);
        let pool = Arc::new(ThreadPool::new(2));
        let sched = Arc::new(FleetScheduler::new(pool));
        sched.hold();
        let ha = handle_for(&sched, &wa, &hw, "a\0large");
        let hb = handle_for(&sched, &wb, &hw, "b\0large");
        let sa = Arc::clone(&sched);
        let sb = Arc::clone(&sched);
        let pa = pop_a.clone();
        let pb = pop_b.clone();
        let ta = std::thread::spawn(move || sa.eval_candidates(&ha, pa));
        let tb = std::thread::spawn(move || sb.eval_candidates(&hb, pb));
        std::thread::sleep(std::time::Duration::from_millis(60));
        sched.release();
        assert_eq!(ta.join().unwrap(), exp_a);
        assert_eq!(tb.join().unwrap(), exp_b);
        let st = sched.stats();
        assert_eq!(st.passes.load(Ordering::Relaxed), 2);
        assert_eq!(st.merged_passes.load(Ordering::Relaxed), 0,
                   "distinct pairs must not merge");
    }

    #[test]
    fn items_are_counted_at_enqueue_not_after() {
        let stats = SchedStats::default();
        let seen_during_send = std::cell::Cell::new(u64::MAX);
        let sent = stats.send_counted(|| {
            // the arrival must already be in the counter while the
            // send runs (pre-fix, the increment came after the send
            // and this observed 0)
            seen_during_send.set(stats.items.load(Ordering::Relaxed));
            true
        });
        assert!(sent);
        assert_eq!(seen_during_send.get(), 1,
                   "arrival must be counted at enqueue, not after");
        assert_eq!(stats.items.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn failed_send_restores_the_item_count() {
        let stats = SchedStats::default();
        assert!(!stats.send_counted(|| false));
        assert_eq!(stats.items.load(Ordering::Relaxed), 0,
                   "a rejected item is not an arrival");
    }

    #[test]
    fn empty_submission_answers_immediately() {
        let pool = Arc::new(ThreadPool::new(1));
        let sched = Arc::new(FleetScheduler::new(pool));
        let hw = load_config(&repo_root(), "large").unwrap();
        let w = zoo::mobilenet_v1();
        let h = handle_for(&sched, &w, &hw, "e\0large");
        assert!(sched.eval_candidates(&h, Vec::new()).is_empty());
        assert_eq!(sched.stats().passes.load(Ordering::Relaxed), 0);
    }
}
