//! Cost-model validation (paper Sec 4.2): the differentiable closed-form
//! model vs the independent tile-walking golden simulator, over the
//! diverse single-layer operator set (standard / depthwise / pointwise /
//! large-kernel convolutions, FC, attention GEMM).
//!
//! Reports the paper's three metrics: access-count prediction accuracy,
//! and Kendall tau / Spearman rho ranking consistency for latency and
//! energy (paper: 96% accuracy; latency tau = 1.0; energy tau = 0.78,
//! rho = 0.92).

use crate::config::HwConfig;
use crate::costmodel;
use crate::mapping::decode::{decode_layer, Relaxed};
use crate::sim::tilesim;
use crate::util::rng::Rng;
use crate::util::stats::{accuracy, kendall_tau, spearman_rho};
use crate::workload::{zoo, NDIMS};

/// Validation metrics per operator.
#[derive(Clone, Debug)]
pub struct OperatorValidation {
    /// Operator name.
    pub name: String,
    /// Access-count prediction accuracy vs the simulator, in [0, 1].
    pub access_accuracy: f64,
    /// Kendall tau of latency ranking.
    pub latency_tau: f64,
    /// Spearman rho of latency ranking.
    pub latency_rho: f64,
    /// Kendall tau of energy ranking.
    pub energy_tau: f64,
    /// Spearman rho of energy ranking.
    pub energy_rho: f64,
}

/// Aggregate report.
#[derive(Clone, Debug)]
pub struct ValidationReport {
    /// Per-operator metrics.
    pub per_op: Vec<OperatorValidation>,
    /// Mean access-count accuracy across operators.
    pub mean_access_accuracy: f64,
    /// Mean latency Kendall tau.
    pub mean_latency_tau: f64,
    /// Mean latency Spearman rho.
    pub mean_latency_rho: f64,
    /// Mean energy Kendall tau.
    pub mean_energy_tau: f64,
    /// Mean energy Spearman rho.
    pub mean_energy_rho: f64,
}

/// Run the validation sweep: `samples` random mappings per operator.
pub fn run(hw: &HwConfig, samples: usize, seed: u64) -> ValidationReport {
    let mut rng = Rng::new(seed);
    let mut per_op = Vec::new();
    for layer in zoo::validation_operators() {
        let mut cf_access = Vec::new();
        let mut sim_access = Vec::new();
        let mut cf_lat = Vec::new();
        let mut sim_lat = Vec::new();
        let mut cf_en = Vec::new();
        let mut sim_en = Vec::new();
        for _ in 0..samples {
            let mut relaxed = Relaxed {
                theta: vec![[[0.0; 4]; NDIMS]],
                sigma: vec![],
            };
            for d in 0..NDIMS {
                let cap = (layer.dims[d] as f64).log2().max(0.0);
                for s in 0..4 {
                    relaxed.theta[0][d][s] = rng.range(-0.5, cap + 0.5);
                }
            }
            let m = decode_layer(&relaxed.theta[0], &layer.dims, hw);
            let cf = costmodel::components(&m, &layer.dims);
            let sim = tilesim::simulate_layer(&m, &layer.dims);
            // compare aggregate inter-memory traffic (fills + write-back)
            cf_access.push(cf.fill2_i + cf.fill2_w + cf.fill0_w + cf.wb0_o);
            sim_access.push(
                sim.fill2_i + sim.fill2_w + sim.fill0_w + sim.wb_o);
            let lc = costmodel::layer_cost(&cf, 0.0, 0.0, hw);
            cf_lat.push(lc.latency);
            cf_en.push(lc.energy);
            // simulated cost via the same hw constants, sim traffic
            let a3 = sim.fill2_i + sim.fill2_w + sim.wb_o;
            let a2 = sim.fill2_i + sim.fill2_w + sim.fill0_w
                + sim.read_pe_i;
            let a1 = sim.accwb_o + sim.wb_o;
            let a0 = sim.fill0_w + sim.ops;
            let pes = (m.pes() as f64).max(1.0);
            let eb = hw.element_bytes;
            sim_lat.push((sim.ops / pes)
                .max(a3 * eb / hw.bw_dram)
                .max(a2 * eb / hw.bw_l2)
                .max(a1 * eb / hw.bw_l1));
            sim_en.push(sim.ops * hw.energy_per_mac
                + a3 * hw.epa_dram
                + a2 * hw.epa_l2
                + a1 * hw.epa_l1
                + a0 * hw.epa_reg);
        }
        per_op.push(OperatorValidation {
            name: layer.name.clone(),
            access_accuracy: accuracy(&cf_access, &sim_access),
            latency_tau: kendall_tau(&cf_lat, &sim_lat),
            latency_rho: spearman_rho(&cf_lat, &sim_lat),
            energy_tau: kendall_tau(&cf_en, &sim_en),
            energy_rho: spearman_rho(&cf_en, &sim_en),
        });
    }
    let mean = |f: &dyn Fn(&OperatorValidation) -> f64| -> f64 {
        per_op.iter().map(|o| f(o)).sum::<f64>() / per_op.len() as f64
    };
    ValidationReport {
        mean_access_accuracy: mean(&|o| o.access_accuracy),
        mean_latency_tau: mean(&|o| o.latency_tau),
        mean_latency_rho: mean(&|o| o.latency_rho),
        mean_energy_tau: mean(&|o| o.energy_tau),
        mean_energy_rho: mean(&|o| o.energy_rho),
        per_op,
    }
}

/// Render as a markdown table (CLI + EXPERIMENTS.md).
pub fn render(r: &ValidationReport) -> String {
    let mut out = String::new();
    out.push_str(
        "| operator | access acc | lat tau | lat rho | en tau | en rho |\n");
    out.push_str(
        "|---|---|---|---|---|---|\n");
    for o in &r.per_op {
        out.push_str(&format!(
            "| {} | {:.3} | {:.3} | {:.3} | {:.3} | {:.3} |\n",
            o.name, o.access_accuracy, o.latency_tau, o.latency_rho,
            o.energy_tau, o.energy_rho));
    }
    out.push_str(&format!(
        "| **mean** | **{:.3}** | **{:.3}** | **{:.3}** | **{:.3}** | \
         **{:.3}** |\n",
        r.mean_access_accuracy, r.mean_latency_tau, r.mean_latency_rho,
        r.mean_energy_tau, r.mean_energy_rho));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{load_config, repo_root};

    #[test]
    fn validation_reproduces_paper_shape() {
        let hw = load_config(&repo_root(), "large").unwrap();
        let r = run(&hw, 40, 11);
        assert_eq!(r.per_op.len(), 12);
        // paper-shape targets (measured values recorded in
        // EXPERIMENTS.md): high access accuracy, strong latency ranking
        // (rho near 1), energy tau/rho in the paper's 0.78/0.92 band
        assert!(r.mean_access_accuracy > 0.80,
                "accuracy {}", r.mean_access_accuracy);
        assert!(r.mean_latency_tau > 0.75,
                "lat tau {}", r.mean_latency_tau);
        assert!(r.mean_latency_rho > 0.9,
                "lat rho {}", r.mean_latency_rho);
        assert!(r.mean_energy_tau > 0.6, "en tau {}", r.mean_energy_tau);
        assert!(r.mean_energy_rho > 0.75, "en rho {}", r.mean_energy_rho);
    }
}
