//! Fig 4 reproduction: EDP vs optimization time for GA, BO and the
//! gradient method under the same wall-clock budget (large-Gemmini).

use anyhow::Result;

use crate::config::HwConfig;
use crate::runtime::Runtime;
use crate::search::{bo, ga, gradient, Budget, TracePoint};
use crate::workload::Workload;

/// One method's convergence trace.
#[derive(Clone, Debug)]
pub struct MethodTrace {
    /// Method name.
    pub method: String,
    /// Best full-model EDP at budget exhaustion.
    pub final_edp: f64,
    /// Incumbent-improvement trace.
    pub trace: Vec<TracePoint>,
}

/// The full figure: one trace per method.
#[derive(Clone, Debug)]
pub struct Fig4Report {
    /// Workload the traces were collected on.
    pub workload: String,
    /// Shared wall-clock budget per method.
    pub budget_seconds: f64,
    /// One trace per method.
    pub methods: Vec<MethodTrace>,
}

/// Run all three methods with the same budget and seed base. The
/// gradient trace uses PJRT when `rt` is `Some` and the native
/// differentiable backend otherwise.
pub fn run(rt: Option<&Runtime>, w: &Workload, hw: &HwConfig,
           seconds: f64, seed: u64) -> Result<Fig4Report> {
    let budget = Budget { seconds, max_iters: usize::MAX };

    let rg = gradient::optimize(
        rt, w, hw,
        &gradient::GradientConfig { seed, ..Default::default() },
        budget)?;
    let rga = ga::optimize(
        w, hw, &ga::GaConfig { seed, ..Default::default() }, budget)?;
    let rbo = bo::optimize(
        w, hw, &bo::BoConfig { seed, ..Default::default() }, budget)?;

    Ok(Fig4Report {
        workload: w.name.clone(),
        budget_seconds: seconds,
        methods: vec![
            MethodTrace { method: "gradient (FADiff)".into(),
                          final_edp: rg.edp, trace: rg.trace },
            MethodTrace { method: "GA".into(), final_edp: rga.edp,
                          trace: rga.trace },
            MethodTrace { method: "BO".into(), final_edp: rbo.edp,
                          trace: rbo.trace },
        ],
    })
}

/// Best-EDP-so-far sampled on a common time grid (for plotting/tables).
pub fn sample_grid(t: &[TracePoint], grid: &[f64]) -> Vec<f64> {
    grid.iter()
        .map(|&g| {
            t.iter()
                .filter(|p| p.seconds <= g)
                .map(|p| p.best_edp)
                .fold(f64::INFINITY, f64::min)
        })
        .collect()
}

/// Render as a markdown time-series table.
pub fn render(r: &Fig4Report) -> String {
    let grid: Vec<f64> = (1..=10)
        .map(|i| r.budget_seconds * i as f64 / 10.0)
        .collect();
    let mut out = format!(
        "workload {} — best EDP vs time (budget {:.1}s)\n",
        r.workload, r.budget_seconds);
    out.push_str("| t (s) |");
    for m in &r.methods {
        out.push_str(&format!(" {} |", m.method));
    }
    out.push('\n');
    out.push_str("|---|");
    for _ in &r.methods {
        out.push_str("---|");
    }
    out.push('\n');
    let series: Vec<Vec<f64>> = r
        .methods
        .iter()
        .map(|m| sample_grid(&m.trace, &grid))
        .collect();
    for (i, g) in grid.iter().enumerate() {
        out.push_str(&format!("| {g:.1} |"));
        for s in &series {
            if s[i].is_finite() {
                out.push_str(&format!(" {:.3e} |", s[i]));
            } else {
                out.push_str(" - |");
            }
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{load_config, repo_root};
    use crate::workload::zoo;

    #[test]
    fn fig4_gradient_dominates() {
        let Some(rt) =
            Runtime::load_if_available(&repo_root().join("artifacts"))
        else {
            eprintln!("skipping: PJRT runtime unavailable");
            return;
        };
        let hw = load_config(&repo_root(), "large").unwrap();
        let w = zoo::resnet18();
        let r = run(Some(&rt), &w, &hw, 3.0, 99).unwrap();
        assert_eq!(r.methods.len(), 3);
        let grad = r.methods[0].final_edp;
        for m in &r.methods[1..] {
            assert!(grad <= m.final_edp * 1.05,
                    "gradient {grad} vs {} {}", m.method, m.final_edp);
        }
    }

    #[test]
    fn sample_grid_is_monotone() {
        let t = vec![
            TracePoint { seconds: 0.1, best_edp: 10.0, iter: 1 },
            TracePoint { seconds: 0.5, best_edp: 5.0, iter: 2 },
            TracePoint { seconds: 0.9, best_edp: 2.0, iter: 3 },
        ];
        let g = sample_grid(&t, &[0.2, 0.6, 1.0]);
        assert_eq!(g, vec![10.0, 5.0, 2.0]);
    }
}
