//! Measured optimality gaps against the branch-and-bound oracle: run
//! [`crate::search::exact`] on one workload, then every requested
//! baseline method under the same budget, and report each method's
//! distance from the certified optimum as a Table-1-style markdown
//! row. This turns the paper's *relative* Table 1 comparison into an
//! *absolute* one on the workloads small enough to solve exactly (the
//! `micro-*` zoo trio and similar): instead of "FADiff beats GA", the
//! row says how far each method lands from the true optimum.
//!
//! The report is produced in two ways that must agree:
//! * synchronously by [`measure`] (the CLI `gap` subcommand and the
//!   `gap_report` example), and
//! * from already-collected [`JobResult`]s by
//!   [`GapReport::from_results`] (the server's `gap` verb, which fans
//!   the same jobs through the coordinator queue).

use anyhow::{anyhow, Result};

use crate::coordinator::{execute_job, JobRequest, JobResult, Method};
use crate::runtime::Runtime;

/// Baseline methods of the gap comparison, in column order.
pub const BASELINES: [Method; 4] =
    [Method::FADiff, Method::Ga, Method::Bo, Method::Random];

/// One baseline method's distance from the exact optimum.
#[derive(Clone, Debug)]
pub struct GapRow {
    /// Canonical method name ([`Method::name`]).
    pub method: String,
    /// The method's best per-replica EDP.
    pub edp: f64,
    /// Relative optimality gap, `edp / exact_edp - 1` (`0.0` means
    /// the method found the optimum; always `>= 0` when the oracle is
    /// certified).
    pub gap: f64,
    /// Candidate evaluations the method spent.
    pub evals: usize,
    /// Wall-clock seconds the method's job took.
    pub wall_seconds: f64,
}

/// The full gap report for one `(workload, config)` pair.
#[derive(Clone, Debug)]
pub struct GapReport {
    /// Workload name.
    pub workload: String,
    /// Hardware configuration name.
    pub config: String,
    /// The oracle's per-replica EDP.
    pub exact_edp: f64,
    /// Whether the oracle proved optimality (no cap tripped). An
    /// uncertified report is still rendered, but its gaps are lower
    /// bounds on the truth and may even be negative.
    pub certified: bool,
    /// Search-tree nodes the oracle expanded.
    pub nodes_expanded: u64,
    /// Subtrees the oracle pruned (bound + infeasible + dominance).
    pub pruned: u64,
    /// Wall-clock seconds the oracle took.
    pub exact_seconds: f64,
    /// One row per baseline method, in request order.
    pub rows: Vec<GapRow>,
}

impl GapReport {
    /// Assemble a report from an already-executed exact job plus its
    /// baseline jobs (the server path). The exact job must carry
    /// [`JobResult::exact`] stats — i.e. its request really used
    /// [`Method::Exact`].
    pub fn from_results(exact: &JobResult, baselines: &[JobResult])
                        -> Result<GapReport> {
        let stats = exact.exact.ok_or_else(|| {
            anyhow!("gap report needs an exact-method result")
        })?;
        let rows = baselines
            .iter()
            .map(|r| GapRow {
                method: r.request.method.name().to_string(),
                edp: r.edp,
                gap: r.edp / exact.edp - 1.0,
                evals: r.evals,
                wall_seconds: r.wall_seconds,
            })
            .collect();
        Ok(GapReport {
            workload: exact.request.workload.clone(),
            config: exact.request.config.clone(),
            exact_edp: exact.edp,
            certified: stats.certified,
            nodes_expanded: stats.nodes_expanded,
            pruned: stats.pruned(),
            exact_seconds: exact.wall_seconds,
            rows,
        })
    }

    /// The markdown table header matching [`GapReport::row`], for the
    /// given method columns.
    pub fn header(methods: &[String]) -> String {
        let mut top = String::from("| model | exact EDP |");
        let mut rule = String::from("|---|---|");
        for m in methods {
            top.push_str(&format!(" {m} |"));
            rule.push_str("---|");
        }
        format!("{top}\n{rule}\n")
    }

    /// One Table-1-style markdown row: the certified optimum followed
    /// by each method's measured gap.
    pub fn row(&self) -> String {
        let mark = if self.certified { "" } else { " (uncertified)" };
        let mut out = format!("| {} | {:.2e}{mark} |",
                              self.workload, self.exact_edp);
        for r in &self.rows {
            out.push_str(&format!(" +{:.2}% |", r.gap * 100.0));
        }
        out.push('\n');
        out
    }

    /// Header plus this report's row — the self-contained table the
    /// CLI prints.
    pub fn render(&self) -> String {
        let methods: Vec<String> =
            self.rows.iter().map(|r| r.method.clone()).collect();
        format!("{}{}", GapReport::header(&methods), self.row())
    }
}

/// Run the whole experiment synchronously: the oracle first, then each
/// baseline with the same budget and seed. `methods` defaults to
/// [`BASELINES`] when empty. Each job goes through
/// [`execute_job`], so the CLI and server paths share one execution
/// seam (and with `rt = None` the gradient methods use the native
/// differentiable backend).
pub fn measure(rt: Option<&Runtime>, base: &JobRequest,
               methods: &[Method]) -> Result<GapReport> {
    let exact = execute_job(rt, &JobRequest {
        method: Method::Exact,
        ..base.clone()
    })?;
    let methods: Vec<Method> = if methods.is_empty() {
        BASELINES.to_vec()
    } else {
        methods.to_vec()
    };
    let mut results = Vec::with_capacity(methods.len());
    for m in methods {
        results.push(execute_job(rt, &JobRequest {
            method: m,
            ..base.clone()
        })?);
    }
    GapReport::from_results(&exact, &results)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_renders_certified_row_with_gaps() {
        let mut exact = JobResult {
            request: JobRequest {
                workload: "micro-mlp".into(),
                method: Method::Exact,
                ..Default::default()
            },
            edp: 100.0,
            full_model_edp: 100.0,
            energy: 10.0,
            latency: 10.0,
            groups: Vec::new(),
            fused_names: Vec::new(),
            iters: 5,
            evals: 5,
            wall_seconds: 0.1,
            stored: false,
            deadline_hit: false,
            exact: Some(crate::search::exact::ExactStats {
                certified: true,
                space_complete: true,
                nodes_expanded: 7,
                pruned_bound: 3,
                ..Default::default()
            }),
        };
        let mut ga = exact.clone();
        ga.request.method = Method::Ga;
        ga.edp = 125.0;
        ga.exact = None;
        let rep = GapReport::from_results(&exact, &[ga.clone()])
            .unwrap();
        assert!(rep.certified);
        assert_eq!(rep.nodes_expanded, 7);
        assert_eq!(rep.pruned, 3);
        assert!((rep.rows[0].gap - 0.25).abs() < 1e-12);
        let table = rep.render();
        assert!(table.contains("| micro-mlp |"), "{table}");
        assert!(table.contains("+25.00%"), "{table}");
        assert!(!table.contains("uncertified"), "{table}");

        // an uncertified oracle is flagged in the rendered row
        if let Some(st) = &mut exact.exact {
            st.certified = false;
        }
        let rep = GapReport::from_results(&exact, &[ga]).unwrap();
        assert!(rep.row().contains("uncertified"));
    }

    #[test]
    fn from_results_requires_an_exact_result() {
        let plain = JobResult {
            request: JobRequest::default(),
            edp: 1.0,
            full_model_edp: 1.0,
            energy: 1.0,
            latency: 1.0,
            groups: Vec::new(),
            fused_names: Vec::new(),
            iters: 0,
            evals: 0,
            wall_seconds: 0.0,
            stored: false,
            deadline_hit: false,
            exact: None,
        };
        assert!(GapReport::from_results(&plain, &[]).is_err());
    }
}
