//! Fig 3 reproduction: Z-score-normalized latency & energy trends of our
//! fused cost model vs the DeFiNES-like depth-first baseline, for
//! two-layer and three-layer fusion stacks, swept over on-chip tile
//! sizes.
//!
//! The paper validates *trend agreement* (Z-scored curves overlap), not
//! absolute numbers; we additionally report the Pearson correlation of
//! the normalized series.

use crate::config::HwConfig;
use crate::mapping::{LayerMapping, Strategy, SLOT_S, SLOT_T0, SLOT_T1,
                     SLOT_T2};
use crate::search::EvalEngine;
use crate::sim::definesim::{self, DfTile};
use crate::util::stats::{pearson, zscore};
use crate::workload::{zoo, Layer, DIM_C, DIM_K, DIM_N, DIM_P, DIM_Q,
                      DIM_R, DIM_S};

/// One swept point.
#[derive(Clone, Debug)]
pub struct TrendPoint {
    /// Output-tile edge size of the sweep point.
    pub tile: usize,
    /// Closed-form model latency, cycles.
    pub ours_latency: f64,
    /// Closed-form model energy, pJ.
    pub ours_energy: f64,
    /// DeFiNES-like baseline latency, cycles.
    pub df_latency: f64,
    /// DeFiNES-like baseline energy, pJ.
    pub df_energy: f64,
}

/// One panel of Fig 3 (two-layer or three-layer fusion).
#[derive(Clone, Debug)]
pub struct TrendReport {
    /// Fused-stack depth of this panel.
    pub stack_len: usize,
    /// Swept points in tile order.
    pub points: Vec<TrendPoint>,
    /// Pearson correlation of the z-scored latency trends.
    pub latency_corr: f64,
    /// Pearson correlation of the z-scored energy trends.
    pub energy_corr: f64,
    /// Z-scored latency series in sweep order: (ours, definesim).
    pub z_latency: (Vec<f64>, Vec<f64>),
    /// Z-scored energy series in sweep order: (ours, definesim).
    pub z_energy: (Vec<f64>, Vec<f64>),
}

/// Build a fused strategy whose L2 residency matches a depth-first
/// output-tile of `t x t`: spatial dims tiled to t on chip, channels
/// resident, everything else at DRAM.
fn strategy_for_tile(stack: &[Layer], t: usize, hw: &HwConfig) -> Strategy {
    let mut mappings = Vec::new();
    for l in stack {
        let mut m = LayerMapping::trivial();
        for (d, ext) in [(DIM_P, t), (DIM_Q, t)] {
            let n = l.dims[d] as u64;
            // largest divisor of n that is <= requested tile extent
            let f = crate::mapping::divisors(n)
                .into_iter()
                .filter(|&x| x <= ext as u64)
                .max()
                .unwrap_or(1);
            m.factors[d][SLOT_T1] = f;
        }
        // channels resident at L2; filters at L0-adjacent levels
        for d in [DIM_C, DIM_K] {
            let n = l.dims[d] as u64;
            let sp_cap = if d == DIM_K {
                hw.pe_cols as u64
            } else {
                hw.pe_rows as u64
            };
            let sp = crate::mapping::divisors(n)
                .into_iter()
                .filter(|&x| x <= sp_cap)
                .max()
                .unwrap_or(1);
            m.factors[d][SLOT_S] = sp;
            m.factors[d][SLOT_T2] = n / sp;
        }
        for d in [DIM_R, DIM_S, DIM_N] {
            m.factors[d][SLOT_T0] = l.dims[d] as u64;
        }
        mappings.push(m);
    }
    Strategy { mappings, fuse: vec![true; stack.len() - 1] }
}

/// Run one panel over a conv stack. The whole tile sweep scores as one
/// parallel batch on the [`EvalEngine`].
pub fn run_panel(stack: &[Layer], hw: &HwConfig) -> TrendReport {
    let w = crate::workload::Workload::chain("fig3", stack.to_vec(), &[],
                                             1.0);
    let engine = EvalEngine::new(&w, hw);
    let sweep = definesim::sweep_tiles(stack, hw);
    let strategies: Vec<Strategy> = sweep
        .iter()
        .map(|(tile, _)| strategy_for_tile(stack, tile.tp, hw))
        .collect();
    let ours = engine.eval_batch(&strategies);
    let mut points = Vec::new();
    for ((tile, df), e) in sweep.iter().zip(&ours) {
        points.push(TrendPoint {
            tile: tile.tp,
            ours_latency: e.latency,
            ours_energy: e.energy,
            df_latency: df.latency,
            df_energy: df.energy,
        });
        let _ = DfTile { tp: tile.tp, tq: tile.tq };
    }
    let zl_ours = zscore(&points.iter().map(|p| p.ours_latency)
                         .collect::<Vec<_>>());
    let zl_df = zscore(&points.iter().map(|p| p.df_latency)
                       .collect::<Vec<_>>());
    let ze_ours = zscore(&points.iter().map(|p| p.ours_energy)
                         .collect::<Vec<_>>());
    let ze_df = zscore(&points.iter().map(|p| p.df_energy)
                       .collect::<Vec<_>>());
    TrendReport {
        stack_len: stack.len(),
        latency_corr: pearson(&zl_ours, &zl_df),
        energy_corr: pearson(&ze_ours, &ze_df),
        z_latency: (zl_ours, zl_df),
        z_energy: (ze_ours, ze_df),
        points,
    }
}

/// The two Fig 3 panels on VGG16 conv3 stacks (paper uses conv chains).
pub fn run(hw: &HwConfig) -> (TrendReport, TrendReport) {
    let w = zoo::vgg16();
    let two = [w.layers[4].clone(), w.layers[5].clone()];
    let three =
        [w.layers[4].clone(), w.layers[5].clone(), w.layers[6].clone()];
    (run_panel(&two, hw), run_panel(&three, hw))
}

/// Render a panel as a markdown table + correlation line.
pub fn render(r: &TrendReport) -> String {
    let mut out = String::new();
    out.push_str(&format!("{}-layer fusion: latency corr {:.3}, \
                           energy corr {:.3}\n",
                          r.stack_len, r.latency_corr, r.energy_corr));
    out.push_str(
        "| tile | z-lat ours | z-lat DF | z-en ours | z-en DF |\n");
    out.push_str("|---|---|---|---|---|\n");
    for (i, p) in r.points.iter().enumerate() {
        out.push_str(&format!(
            "| {} | {:+.2} | {:+.2} | {:+.2} | {:+.2} |\n",
            p.tile, r.z_latency.0[i], r.z_latency.1[i],
            r.z_energy.0[i], r.z_energy.1[i]));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{load_config, repo_root};

    #[test]
    fn fig3_trends_match_definesim() {
        let hw = load_config(&repo_root(), "large").unwrap();
        let (two, three) = run(&hw);
        assert!(two.points.len() >= 5);
        assert!(three.points.len() >= 5);
        // paper claim: Z-scored trends closely match for both panels
        assert!(two.energy_corr > 0.7, "2-layer energy {}", two.energy_corr);
        assert!(three.energy_corr > 0.7,
                "3-layer energy {}", three.energy_corr);
        assert!(two.latency_corr > 0.5,
                "2-layer latency {}", two.latency_corr);
    }

    #[test]
    fn strategies_for_tiles_are_valid() {
        let hw = load_config(&repo_root(), "large").unwrap();
        let w = zoo::vgg16();
        let stack = [w.layers[4].clone(), w.layers[5].clone()];
        for t in [4usize, 14, 56] {
            let s = strategy_for_tile(&stack, t, &hw);
            let wl = crate::workload::Workload::chain(
                "t", stack.to_vec(), &[], 1.0);
            s.validate(&wl, hw.pe_rows as u64, hw.pe_cols as u64)
                .unwrap();
        }
    }
}
