//! Table 1 reproduction: full-model EDP of DOSA (layer-wise gradient),
//! BO, GA and FADiff across the five workloads and both Gemmini
//! configurations, under equal per-cell time budgets. Cells run in
//! parallel on the coordinator's thread pool.

use anyhow::Result;

use crate::config::{load_config, repo_root, HwConfig};
use crate::runtime::Runtime;
use crate::search::{bo, ga, gradient, Budget};
use crate::util::stats::geomean;
use crate::workload::{zoo, Workload};

/// Methods of the Table-1 comparison, in column order.
pub const METHODS: [&str; 4] = ["DOSA", "BO", "GA", "FADiff"];

/// One table cell.
#[derive(Clone, Debug)]
pub struct Cell {
    /// Workload name (row).
    pub workload: String,
    /// Hardware configuration name (panel).
    pub config: String,
    /// Method name (column).
    pub method: String,
    /// Full-model EDP (replica-scaled).
    pub edp: f64,
    /// Wall-clock time the cell's search took.
    pub seconds: f64,
}

/// The reproduced table.
#[derive(Clone, Debug)]
pub struct Table1 {
    /// Every (workload, config, method) cell.
    pub cells: Vec<Cell>,
    /// Per-cell search budget.
    pub budget_seconds: f64,
}

impl Table1 {
    /// Look up one cell.
    pub fn get(&self, workload: &str, config: &str, method: &str)
               -> Option<&Cell> {
        self.cells.iter().find(|c| {
            c.workload == workload && c.config == config
                && c.method == method
        })
    }

    /// Geomean EDP of one (config, method) column.
    pub fn column_geomean(&self, config: &str, method: &str) -> f64 {
        geomean(
            &self
                .cells
                .iter()
                .filter(|c| c.config == config && c.method == method)
                .map(|c| c.edp)
                .collect::<Vec<_>>(),
        )
    }

    /// Average FADiff improvement over DOSA on a config (paper headline:
    /// ~18% large, ~13% small, ~15% overall).
    pub fn improvement_vs_dosa(&self, config: &str) -> f64 {
        1.0 - self.column_geomean(config, "FADiff")
            / self.column_geomean(config, "DOSA")
    }
}

fn run_cell(rt: Option<&Runtime>, w: &Workload, hw: &HwConfig,
            method: &str, seconds: f64, seed: u64) -> Result<f64> {
    let budget = Budget { seconds, max_iters: usize::MAX };
    let r = match method {
        m @ ("FADiff" | "DOSA") => {
            let base = if m == "FADiff" {
                gradient::GradientConfig::default()
            } else {
                gradient::GradientConfig::dosa()
            };
            gradient::optimize(
                rt, w, hw,
                &gradient::GradientConfig { seed, ..base },
                budget)?
        }
        "GA" => ga::optimize(
            w, hw, &ga::GaConfig { seed, ..Default::default() }, budget)?,
        "BO" => bo::optimize(
            w, hw, &bo::BoConfig { seed, ..Default::default() }, budget)?,
        other => anyhow::bail!("unknown method {other}"),
    };
    Ok(r.full_model_edp(w))
}

/// Run the whole table. `threads` parallelizes over cells; each cell gets
/// the same `seconds` budget (the paper's equal-time protocol). The
/// GA/BO cells score on [`crate::search::EvalEngine`]; the gradient
/// columns (DOSA / FADiff) use the AOT artifacts via PJRT when
/// available and the native differentiable backend otherwise, so the
/// full table is produced in every environment.
///
/// Note: each native cell's engine also parallelizes internally (up to
/// the machine's cores), so cells x engine threads can oversubscribe
/// the CPU and add noise to the equal-time comparison — keep `threads`
/// small (<= cores/4) when cell-to-cell timing fidelity matters.
///
/// The xla crate's PJRT client is `Rc`-based (neither `Send` nor `Sync`),
/// so each worker thread constructs its own [`Runtime`] and compiles the
/// artifacts once; jobs are pulled from a shared atomic cursor.
pub fn run(artifacts_dir: &std::path::Path, seconds: f64, threads: usize,
           seed: u64) -> Result<Table1> {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Mutex;

    // One probe compile decides whether workers load PJRT runtimes.
    // The probed runtime cannot be handed to the workers (the real PJRT
    // client is not Send), so each worker reloads below; with a real
    // backend that costs one extra grad-artifact compile total.
    let have_rt = Runtime::load_if_available(artifacts_dir).is_some();
    if !have_rt {
        eprintln!(
            "[table1] PJRT runtime unavailable — DOSA and FADiff \
             columns run on the native differentiable backend"
        );
    }
    let repo = repo_root();
    let mut jobs = Vec::new();
    for cfg_name in ["large", "small"] {
        let hw = load_config(&repo, cfg_name)?;
        for w in zoo::table1_suite() {
            for method in METHODS {
                jobs.push((w.clone(), hw.clone(), method.to_string()));
            }
        }
    }
    let n = jobs.len();
    let jobs: Vec<_> = jobs.into_iter().map(Some).map(Mutex::new).collect();
    let results: Vec<Mutex<Option<Cell>>> =
        (0..n).map(|_| Mutex::new(None)).collect();
    let cursor = AtomicUsize::new(0);
    let threads = threads.clamp(1, n);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| {
                // one PJRT runtime per worker thread (when available)
                let rt = if have_rt {
                    Runtime::load(artifacts_dir).ok()
                } else {
                    None
                };
                loop {
                    let i = cursor.fetch_add(1, Ordering::SeqCst);
                    if i >= n {
                        break;
                    }
                    let (w, hw, method) =
                        jobs[i].lock().unwrap().take().unwrap();
                    let t0 = std::time::Instant::now();
                    let edp = run_cell(rt.as_ref(), &w, &hw, &method,
                                       seconds, seed)
                        .unwrap_or(f64::INFINITY);
                    *results[i].lock().unwrap() = Some(Cell {
                        workload: w.name.clone(),
                        config: hw.name.clone(),
                        method,
                        edp,
                        seconds: t0.elapsed().as_secs_f64(),
                    });
                }
            });
        }
    });
    let cells = results
        .into_iter()
        .map(|m| m.into_inner().unwrap().unwrap())
        .collect();
    Ok(Table1 { cells, budget_seconds: seconds })
}

/// Render in the paper's layout (methods x configs as columns).
pub fn render(t: &Table1) -> String {
    let mut out = String::new();
    for config in ["large", "small"] {
        out.push_str(&format!("\n**{config}-Gemmini** (equal budget \
                               {:.0}s/cell)\n\n", t.budget_seconds));
        out.push_str("| model | DOSA [8] | BO [15] | GA [16] | FADiff |\n");
        out.push_str("|---|---|---|---|---|\n");
        for w in zoo::table1_suite() {
            out.push_str(&format!("| {} |", w.name));
            for m in METHODS {
                match t.get(&w.name, config, m) {
                    Some(c) => out.push_str(&format!(" {:.2e} |", c.edp)),
                    None => out.push_str(" - |"),
                }
            }
            out.push('\n');
        }
        out.push_str("| **geomean** |");
        for m in METHODS {
            out.push_str(&format!(" {:.2e} |", t.column_geomean(config, m)));
        }
        out.push('\n');
        out.push_str(&format!(
            "\nFADiff vs DOSA improvement ({config}): {:.1}%\n",
            t.improvement_vs_dosa(config) * 100.0));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_smoke_single_workload_ordering() {
        // tiny-budget sanity run on one workload x one config: FADiff
        // must beat GA and BO and not lose to DOSA.
        let Some(rt) =
            Runtime::load_if_available(&repo_root().join("artifacts"))
        else {
            eprintln!("skipping: PJRT runtime unavailable");
            return;
        };
        let hw = load_config(&repo_root(), "large").unwrap();
        let w = zoo::vgg16();
        let mut edps = std::collections::BTreeMap::new();
        for m in METHODS {
            edps.insert(m,
                        run_cell(Some(&rt), &w, &hw, m, 2.5, 3).unwrap());
        }
        assert!(edps["FADiff"] <= edps["DOSA"] * 1.02,
                "{edps:?}");
        assert!(edps["FADiff"] < edps["GA"], "{edps:?}");
        assert!(edps["FADiff"] < edps["BO"], "{edps:?}");
    }

    #[test]
    fn native_cells_run_without_runtime() {
        // every cell runs without artifacts: GA/BO score on the
        // EvalEngine, the gradient columns fall back to the native
        // differentiable backend
        let hw = load_config(&repo_root(), "large").unwrap();
        let w = zoo::mobilenet_v1();
        let trivial = crate::costmodel::evaluate(
            &crate::mapping::Strategy::trivial(&w), &w, &hw);
        for m in ["GA", "BO", "DOSA", "FADiff"] {
            let edp = run_cell(None, &w, &hw, m, 1.0, 7).unwrap();
            assert!(edp.is_finite() && edp > 0.0, "{m}: {edp}");
            assert!(edp < trivial.edp * w.replicas * w.replicas,
                    "{m} should beat trivial");
        }
    }
}
