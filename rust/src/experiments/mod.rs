//! Experiment harnesses regenerating every table and figure of the
//! paper's evaluation (Sec 4): cost-model validation (Sec 4.2), Table 1,
//! Fig 3 (fusion trend vs DeFiNES-like), Fig 4 (EDP vs time) — plus
//! the measured-optimality-gap report against the branch-and-bound
//! oracle ([`gap`]), which the paper's relative comparison lacks.

pub mod fig3;
pub mod fig4;
pub mod gap;
pub mod table1;
pub mod validation;
