//! Bayesian-optimization baseline (Snoek et al., paper ref [15]; the
//! "learning-based" representative of Sec 4.3.1).
//!
//! BO operates on the same continuous encoding as the gradient search
//! (normalized log2 tiling factors + fusion logits) and decodes through
//! the identical projection, so all methods share one search space. A GP
//! with RBF kernel models log-EDP; candidates maximize expected
//! improvement over a random + local-perturbation pool. The O(N^3)
//! Cholesky refit per observation is precisely the scalability wall the
//! paper's Sec 1 attributes to BO.
//!
//! Model evaluations route through the incumbent's [`super::EvalEngine`]:
//! the initial design scores as one parallel batch and acquisition
//! re-proposals of already-seen points resolve from the memoization
//! cache instead of re-running the cost model.

use anyhow::Result;

use crate::config::HwConfig;
use crate::util::rng::Rng;
use crate::workload::Workload;

use super::encoding::{dim, express_with};
use super::gp::Gp;
use super::{Budget, EvalCtx, Incumbent, Screened, SearchResult};

/// BO hyper-parameters.
#[derive(Clone, Debug)]
pub struct BoConfig {
    /// Random observations before the first GP fit.
    pub init_samples: usize,
    /// Acquisition pool size per iteration.
    pub candidates_per_iter: usize,
    /// RBF kernel lengthscale (unit-cube space).
    pub lengthscale: f64,
    /// Observation noise added to the kernel diagonal.
    pub noise: f64,
    /// PRNG seed.
    pub seed: u64,
    /// Cap on GP observations (keeps the O(N^3) refit bounded; oldest
    /// low-quality points are dropped beyond this).
    pub max_observations: usize,
}

impl Default for BoConfig {
    fn default() -> Self {
        BoConfig {
            init_samples: 12,
            candidates_per_iter: 256,
            lengthscale: 0.35,
            noise: 1e-4,
            seed: 0xB0,
            max_observations: 160,
        }
    }
}

/// log-EDP observation target; infeasible decodes cannot occur (decode
/// repairs), but guard anyway.
fn log_y(edp: f64) -> f64 {
    if edp.is_finite() {
        edp.ln()
    } else {
        1e3
    }
}

/// Run BO under a budget.
pub fn optimize(w: &Workload, hw: &HwConfig, cfg: &BoConfig,
                budget: Budget) -> Result<SearchResult> {
    optimize_ctx(w, hw, cfg, budget, &EvalCtx::default())
}

/// Run BO with a serving-layer context (shared cache / persistent pool
/// / cancellation). Identical results for an empty context.
pub fn optimize_ctx(w: &Workload, hw: &HwConfig, cfg: &BoConfig,
                    budget: Budget, ctx: &EvalCtx)
                    -> Result<SearchResult> {
    let d = dim(w);
    let mut rng = Rng::new(cfg.seed);
    let mut inc = Incumbent::with_ctx(w, hw, ctx);
    inc.offer(&crate::mapping::Strategy::trivial(w), 0);
    if !ctx.seeds.is_empty() {
        inc.offer_seeds(&ctx.seeds);
    }

    let mut xs: Vec<Vec<f64>> = Vec::new();
    let mut ys: Vec<f64> = Vec::new();
    let mut iter = 0usize;

    // initial design: uniform random, decoded + scored as one batch.
    // Screening is capacity-only (no EDP threshold): every exact eval
    // feeds the GP, and a screen-infeasible candidate contributes the
    // same 1e3 sentinel the kernel's infeasible verdict would, so the
    // observation stream is bit-identical either way.
    let init = cfg.init_samples.min(budget.max_iters);
    let design: Vec<Vec<f64>> = (0..init)
        .map(|_| (0..d).map(|_| rng.f64()).collect())
        .collect();
    let tables = std::sync::Arc::clone(inc.engine.tables());
    let scored: Vec<_> = if ctx.prune.enabled() {
        inc.engine.eval_population_screened(
            &design, |x| express_with(x, w, hw, &tables), None,
            ctx.prune_stats())
    } else {
        inc.engine
            .eval_population(&design,
                             |x| express_with(x, w, hw, &tables))
            .into_iter()
            .map(|(s, e)| (s, Screened::Exact(e)))
            .collect()
    };
    for (x, (s, sc)) in design.into_iter().zip(scored) {
        if inc.cancelled() || inc.elapsed() > budget.seconds {
            break;
        }
        iter += 1;
        let edp = inc.offer_screened(&s, sc, iter);
        xs.push(x);
        ys.push(log_y(edp));
    }

    while !inc.stopped(&budget) && iter < budget.max_iters {
        iter += 1;
        // bound the O(N^3) refit
        if xs.len() > cfg.max_observations {
            // drop the worst half of the oldest third
            let cut = xs.len() / 3;
            let mut idx: Vec<usize> = (0..cut).collect();
            idx.sort_by(|&a, &b| ys[b].partial_cmp(&ys[a]).unwrap());
            let mut remove: Vec<usize> = idx[..cut / 2].to_vec();
            remove.sort_unstable_by(|a, b| b.cmp(a));
            for i in remove {
                xs.remove(i);
                ys.remove(i);
            }
        }
        let next_x: Vec<f64> =
            match Gp::fit(&xs, &ys, cfg.lengthscale, cfg.noise) {
                Some(gp) => {
                    let best_y =
                        ys.iter().cloned().fold(f64::INFINITY, f64::min);
                    let best_x = xs[ys
                        .iter()
                        .enumerate()
                        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .unwrap()
                        .0]
                        .clone();
                    // acquisition: random pool + local perturbations of
                    // the best observation
                    let mut best_cand: Option<(f64, Vec<f64>)> = None;
                    for c in 0..cfg.candidates_per_iter {
                        let x: Vec<f64> = if c % 2 == 0 {
                            (0..d).map(|_| rng.f64()).collect()
                        } else {
                            best_x
                                .iter()
                                .map(|&v| {
                                    (v + rng.normal() * 0.08)
                                        .clamp(0.0, 1.0)
                                })
                                .collect()
                        };
                        let ei = gp.expected_improvement(&x, best_y);
                        if best_cand
                            .as_ref()
                            .map_or(true, |(b, _)| ei > *b)
                        {
                            best_cand = Some((ei, x));
                        }
                    }
                    best_cand.unwrap().1
                }
                // degenerate kernel: fall back to random sampling
                None => (0..d).map(|_| rng.f64()).collect(),
            };
        let s = express_with(&next_x, w, hw, &tables);
        let edp = if ctx.prune.enabled() {
            let sc = inc.engine.eval_batch_screened(
                std::slice::from_ref(&s), None, ctx.prune_stats())[0];
            inc.offer_screened(&s, sc, iter)
        } else {
            let e = inc.engine.eval(&s);
            inc.offer_eval(&s, e, iter)
        };
        inc.note_iters(iter);
        xs.push(next_x);
        ys.push(log_y(edp));
    }
    Ok(inc.finish(iter))
}
