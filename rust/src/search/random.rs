//! Uniform random search — the sanity floor every real method must beat.
//!
//! Samples are drawn serially (cheap) but decoded and scored in parallel
//! batches on the incumbent's [`super::EvalEngine`]; duplicate decodes
//! resolve from the memoization cache.

use anyhow::Result;

use crate::config::HwConfig;
use crate::mapping::decode::{decode_with, Relaxed};
use crate::util::rng::Rng;
use crate::workload::{Workload, NDIMS};

use super::{Budget, EvalCtx, Incumbent, SearchResult};

/// Candidates decoded + evaluated per engine batch.
const BATCH: usize = 64;

fn sample(rng: &mut Rng, w: &Workload) -> Relaxed {
    let mut relaxed = Relaxed::neutral(w);
    for l in 0..w.len() {
        for d in 0..NDIMS {
            let cap = (w.layers[l].dims[d] as f64).log2().max(0.0);
            for s in 0..4 {
                relaxed.theta[l][d][s] = rng.range(-0.5, cap + 0.5);
            }
        }
    }
    for i in 0..relaxed.sigma.len() {
        relaxed.sigma[i] = rng.f64();
    }
    relaxed
}

/// Sample uniformly in the relaxed space, decode, keep the best.
pub fn optimize(w: &Workload, hw: &HwConfig, seed: u64, budget: Budget)
                -> Result<SearchResult> {
    optimize_ctx(w, hw, seed, budget, &EvalCtx::default())
}

/// Random search with a serving-layer context (shared cache /
/// persistent pool / cancellation).
pub fn optimize_ctx(w: &Workload, hw: &HwConfig, seed: u64,
                    budget: Budget, ctx: &EvalCtx)
                    -> Result<SearchResult> {
    let mut rng = Rng::new(seed);
    let mut inc = Incumbent::with_ctx(w, hw, ctx);
    inc.offer(&crate::mapping::Strategy::trivial(w), 0);
    if !ctx.seeds.is_empty() {
        inc.offer_seeds(&ctx.seeds);
    }
    let tables = std::sync::Arc::clone(inc.engine.tables());
    let mut iter = 0usize;
    while !inc.stopped(&budget) && iter < budget.max_iters {
        let b = BATCH.min(budget.max_iters - iter).max(1);
        let samples: Vec<Relaxed> =
            (0..b).map(|_| sample(&mut rng, w)).collect();
        if ctx.prune.enabled() {
            // bound-and-prune fast path: candidates whose admissible
            // EDP floor meets the incumbent at batch start skip the
            // exact kernel — bit-identical to the unpruned path
            // because exact >= bound >= incumbent means no improvement
            let scored = inc.engine.eval_population_screened(
                &samples,
                |r| decode_with(r, w, hw, &tables),
                inc.best_edp(),
                ctx.prune_stats(),
            );
            for (s, sc) in &scored {
                if inc.stopped(&budget) {
                    break;
                }
                iter += 1;
                inc.offer_screened(s, *sc, iter);
            }
        } else {
            let scored = inc
                .engine
                .eval_population(&samples,
                                 |r| decode_with(r, w, hw, &tables));
            for (s, e) in &scored {
                // keep the old per-candidate budget granularity: never
                // record results past the deadline (the batch
                // evaluation itself may overrun by at most one batch)
                if inc.stopped(&budget) {
                    break;
                }
                iter += 1;
                inc.offer_eval(s, *e, iter);
            }
        }
        inc.note_iters(iter);
    }
    Ok(inc.finish(iter))
}
