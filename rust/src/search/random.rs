//! Uniform random search — the sanity floor every real method must beat.

use anyhow::Result;

use crate::config::HwConfig;
use crate::mapping::decode::{decode, Relaxed};
use crate::util::rng::Rng;
use crate::workload::{Workload, NDIMS};

use super::{Budget, Incumbent, SearchResult};

/// Sample uniformly in the relaxed space, decode, keep the best.
pub fn optimize(w: &Workload, hw: &HwConfig, seed: u64, budget: Budget)
                -> Result<SearchResult> {
    let mut rng = Rng::new(seed);
    let mut inc = Incumbent::new(w, hw);
    inc.offer(&crate::mapping::Strategy::trivial(w), 0);
    let mut iter = 0usize;
    while inc.elapsed() < budget.seconds && iter < budget.max_iters {
        iter += 1;
        let mut relaxed = Relaxed::neutral(w);
        for l in 0..w.len() {
            for d in 0..NDIMS {
                let cap = (w.layers[l].dims[d] as f64).log2().max(0.0);
                for s in 0..4 {
                    relaxed.theta[l][d][s] = rng.range(-0.5, cap + 0.5);
                }
            }
        }
        for i in 0..relaxed.sigma.len() {
            relaxed.sigma[i] = rng.f64();
        }
        inc.offer(&decode(&relaxed, w, hw), iter);
    }
    Ok(inc.finish(iter))
}
