//! The batched evaluation engine — the single entry point every search
//! strategy and experiment harness uses to score candidate strategies.
//!
//! GA/BO/random search, the multi-chain gradient optimizer's banked
//! decode offers ([`crate::search::gradient`] routes every chain's
//! threshold + fusion-greedy snapshots through one
//! [`EvalEngine::eval_population`] pass per block), and the
//! Table-1/Fig-3/Fig-4 harnesses spend nearly all of their time in
//! the analytical cost model (paper Eqs. 4-19). [`EvalEngine`] makes
//! that hot path fast three ways:
//!
//! * **Parallel batch scoring** — whole candidate populations decode and
//!   evaluate concurrently, either on per-call scoped threads
//!   ([`crate::util::threadpool::par_map`], the standalone default) or
//!   on a persistent [`crate::util::threadpool::ThreadPool`] via its
//!   scoped-submit API ([`EvalEngine::with_pool`], the serving path —
//!   no spawn/join per batch; `perf_hotpath` reports the ratio).
//! * **Keyed memoization** — a bounded `(strategy) -> (energy, latency,
//!   EDP)` cache per `(workload, hardware)` pair, held in a shareable
//!   [`EvalCache`]. GA elitism, BO acquisition re-proposals and
//!   duplicate random decodes stop paying for re-evaluation;
//!   batch-internal duplicates are computed once. The coordinator hands
//!   engines a shared cache per `(workload, config)`
//!   ([`crate::coordinator::CacheRegistry`]), so repeated and
//!   concurrent jobs on the same pair reuse each other's work across
//!   job and connection boundaries.
//! * **Single-pass allocation-free scoring** — each candidate runs the
//!   [`crate::costmodel::batch`] kernel: components once per layer,
//!   feasibility folded into the same pass, per-thread reusable SoA
//!   scratch. The pre-batch path computed components twice (feasible +
//!   evaluate) and allocated four vectors per candidate.
//!
//! Results are bit-for-bit identical to calling
//! [`crate::costmodel::evaluate`] + [`crate::costmodel::feasible`]
//! directly — the batch kernel runs exactly that math per candidate, it
//! only changes *where* and *how often* it runs (pinned by the property
//! tests in `rust/tests/eval_engine.rs`).
//!
//! Each engine also owns the shared [`WorkloadTables`] of its workload
//! (divisor/prime memoization); decode-and-score callers
//! ([`EvalEngine::eval_population`]) fetch them via
//! [`EvalEngine::tables`] so candidate decoding stops re-factoring
//! dimension sizes.

use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::config::HwConfig;
use crate::costmodel::bounds::{BoundsCtx, ScreenScratch};
use crate::costmodel::{batch, WorkloadTables};
use crate::mapping::{Strategy, NSLOTS};
use crate::util::threadpool::{par_map, ThreadPool};
use crate::workload::{Workload, NDIMS};

thread_local! {
    /// Per-thread scratch for the batch kernel: engine scoring is
    /// allocation-free on every worker after the first candidate.
    static EVAL_SCRATCH: RefCell<batch::SoaScratch> =
        RefCell::new(batch::SoaScratch::new());
}

/// The raw per-candidate computation behind every scoring path: the
/// single-pass batch kernel (feasibility + closed-form evaluation over
/// a per-thread reusable scratch — zero allocation per candidate).
/// Capacity-infeasible strategies still get real energy/latency numbers
/// (fig3 relies on that); strategies with the wrong arity cannot be
/// indexed by the cost model at all and come back as plain infeasible
/// instead of panicking.
///
/// Public so the coordinator's fleet scheduler
/// ([`crate::coordinator::scheduler`]) runs *exactly* this function per
/// merged candidate — cross-job merging changes where candidates are
/// computed, never what is computed, which is what makes merged batches
/// bit-identical to per-job serial evaluation.
pub fn compute_eval(s: &Strategy, w: &Workload, hw: &HwConfig) -> Eval {
    // chaos probe (`eval.slow` / `eval.stall`): an inline no-op
    // unless the fault-injection feature is compiled in AND a site is
    // armed — the hot path stays branch-free in production builds
    crate::util::fault::maybe_stall();
    if s.mappings.len() != w.len()
        || s.fuse.len() != w.len().saturating_sub(1)
    {
        return Eval {
            energy: f64::INFINITY,
            latency: f64::INFINITY,
            edp: f64::INFINITY,
            feasible: false,
        };
    }
    EVAL_SCRATCH.with(|sc| {
        let sm = batch::eval_into(s, w, hw, &mut sc.borrow_mut());
        Eval {
            energy: sm.energy,
            latency: sm.latency,
            edp: sm.edp,
            feasible: sm.feasible,
        }
    })
}

/// Where an engine sends cache-miss candidates when it is part of a
/// fleet: the coordinator's cross-job scheduler implements this by
/// coalescing batches from concurrent jobs into shared kernel passes.
///
/// Contract: the returned vector has exactly one [`Eval`] per submitted
/// strategy, in submission order, each computed by [`compute_eval`] for
/// the handle's `(workload, hardware)` pair. An implementation that is
/// shutting down may return a short (or empty) vector — the engine then
/// falls back to computing locally.
pub trait EvalBackend: Send + Sync {
    /// Score `strategies` for the pair identified by `handle`.
    fn eval_candidates(&self, handle: &FleetHandle,
                       strategies: Vec<Strategy>) -> Vec<Eval>;
}

/// One job's ticket into a shared [`EvalBackend`]: the backend plus the
/// owned `(workload, hardware)` pair it scores against and the
/// coalescing key (the coordinator uses `cache_key + config`, so two
/// jobs merge exactly when they could share an eval cache).
///
/// The handle's `w`/`hw` must describe the same pair as the engine it
/// is installed on ([`EvalEngine::with_fleet`]) — the coordinator
/// builds both from one resolution, enforcing this by construction.
#[derive(Clone)]
pub struct FleetHandle {
    /// The shared scheduler (or any other batch-merging backend).
    pub backend: Arc<dyn EvalBackend>,
    /// Owned workload — the backend computes on worker threads that
    /// outlive the engine's borrows.
    pub w: Arc<Workload>,
    /// Owned hardware config, same reasoning.
    pub hw: Arc<HwConfig>,
    /// Coalescing key: work items with equal keys may merge into one
    /// kernel pass.
    pub key: String,
}

/// Default bound on cached entries; the cache is cleared wholesale when
/// it fills (simple, predictable memory ceiling). Keys are exact
/// (layers x 7 x 4 factors, a few KB each), so 8192 entries is roughly
/// 30-60 MB per engine — sized so several concurrent engines (table1
/// cells, coordinator workers) stay modest.
pub const DEFAULT_CACHE_CAPACITY: usize = 8_192;

/// One scored candidate. `edp = energy * latency` always holds (also for
/// infeasible strategies — use [`Eval::feasible`] to gate on validity;
/// [`super::Incumbent::offer_eval`] does exactly that).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Eval {
    /// Total energy, pJ.
    pub energy: f64,
    /// Total latency, cycles.
    pub latency: f64,
    /// `energy * latency`.
    pub edp: f64,
    /// Whether the candidate satisfies every hard constraint.
    pub feasible: bool,
}

impl Eval {
    /// EDP if feasible, `f64::INFINITY` otherwise — the fitness value
    /// searches minimize.
    pub fn fitness(&self) -> f64 {
        if self.feasible {
            self.edp
        } else {
            f64::INFINITY
        }
    }
}

/// Outcome of one candidate in a screened (bound-and-prune) batch.
///
/// The prefilter never invents numbers: `Exact` carries the same
/// [`Eval`] the unscreened path would have produced, and the two pruned
/// arms only ever report candidates that provably could not have beaten
/// the threshold (`Pruned`, admissible bound) or that the kernel is
/// guaranteed to reject (`Infeasible`, exact capacity replica).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Screened {
    /// Fully evaluated (cache hit or batch-kernel computation).
    Exact(Eval),
    /// The admissible EDP lower bound already met the threshold; the
    /// exact cost can only be worse. Carries the bound for callers
    /// that want a pessimistic fitness (GA's `prune: "full"` mode).
    Pruned {
        /// Admissible lower bound on the candidate's EDP.
        bound_edp: f64,
    },
    /// The kernel's capacity checks are guaranteed to fail; the exact
    /// path would have scored this candidate infeasible.
    Infeasible {
        /// Admissible lower bound on the candidate's EDP.
        bound_edp: f64,
    },
}

/// Lock-free counters for the bound-and-prune prefilter. One instance
/// is shared process-wide by the coordinator (every job's `EvalCtx`
/// carries it) and rendered as the `metrics.prune` block.
#[derive(Debug, Default)]
pub struct PruneStats {
    /// Candidates that went through the screen (cache hits bypass it).
    pub bounded: AtomicU64,
    /// Candidates pruned because their bound met the threshold.
    pub pruned_above: AtomicU64,
    /// Candidates pruned as capacity-infeasible by the exact replica.
    pub pruned_infeasible: AtomicU64,
    /// Candidates that produced an `Exact` result (hits + kernel runs).
    pub evaluated: AtomicU64,
}

impl PruneStats {
    /// Total pruned (threshold + capacity).
    pub fn pruned(&self) -> u64 {
        self.pruned_above.load(Ordering::Relaxed)
            + self.pruned_infeasible.load(Ordering::Relaxed)
    }
}

/// Exact memoization key: every tiling factor plus the fusion bits.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
struct StrategyKey {
    factors: Vec<u64>,
    fuse: Vec<bool>,
}

impl StrategyKey {
    fn of(s: &Strategy) -> StrategyKey {
        let mut factors =
            Vec::with_capacity(s.mappings.len() * NDIMS * NSLOTS);
        for m in &s.mappings {
            for d in 0..NDIMS {
                for sl in 0..NSLOTS {
                    factors.push(m.factors[d][sl]);
                }
            }
        }
        StrategyKey { factors, fuse: s.fuse.clone() }
    }
}

/// The memoization store of an [`EvalEngine`]: a bounded strategy ->
/// [`Eval`] map plus lock-free hit/miss/eviction counters.
///
/// An `EvalCache` is valid for exactly one `(workload, hardware)` pair —
/// the key encodes tiling factors and fusion bits only. Wrap it in an
/// [`Arc`] and hand it to several engines via
/// [`EvalEngine::with_shared_cache`] to share memoized results across
/// searches/jobs, but **only** among engines built for that same pair
/// (the coordinator's `CacheRegistry` enforces this by construction).
pub struct EvalCache {
    capacity: usize,
    map: Mutex<HashMap<StrategyKey, Eval>>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl EvalCache {
    /// An empty cache bounded at `capacity` entries (min 1). When full
    /// it is cleared wholesale (simple, predictable memory ceiling);
    /// each entry dropped that way counts as one eviction.
    pub fn new(capacity: usize) -> EvalCache {
        EvalCache {
            capacity: capacity.max(1),
            map: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// Cache hits so far (across every sharing engine).
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Unique cost-model computations so far.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Entries dropped by capacity-triggered wholesale clears.
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Entries currently cached (always <= the capacity bound).
    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    /// Whether nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Configured entry bound.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Drop all entries (counters are kept; not counted as evictions).
    pub fn clear(&self) {
        self.map.lock().unwrap().clear();
    }

    fn insert_bounded(&self, map: &mut HashMap<StrategyKey, Eval>,
                      key: StrategyKey, e: Eval) {
        if map.len() >= self.capacity {
            self.evictions
                .fetch_add(map.len() as u64, Ordering::Relaxed);
            map.clear();
        }
        map.insert(key, e);
    }

    /// Export every memoized entry as raw `(factors, fuse, eval)`
    /// parts — the persistence format of the coordinator's result
    /// store. Order is unspecified (callers sort before hashing).
    pub fn export_entries(&self) -> Vec<(Vec<u64>, Vec<bool>, Eval)> {
        self.map
            .lock()
            .unwrap()
            .iter()
            .map(|(k, e)| (k.factors.clone(), k.fuse.clone(), *e))
            .collect()
    }

    /// Seed the cache from persisted `(factors, fuse, eval)` parts
    /// (a store segment). Hydration is not a lookup: the hit/miss
    /// counters are untouched, and the capacity bound still applies.
    pub fn preload(&self,
                   entries: Vec<(Vec<u64>, Vec<bool>, Eval)>) {
        let mut map = self.map.lock().unwrap();
        for (factors, fuse, e) in entries {
            self.insert_bounded(&mut map,
                                StrategyKey { factors, fuse }, e);
        }
    }
}

impl Default for EvalCache {
    fn default() -> Self {
        EvalCache::new(DEFAULT_CACHE_CAPACITY)
    }
}

/// Parallel, memoizing evaluator for one `(workload, hardware)` pair.
pub struct EvalEngine<'a> {
    w: &'a Workload,
    hw: &'a HwConfig,
    threads: usize,
    cache: Arc<EvalCache>,
    pool: Option<Arc<ThreadPool>>,
    fleet: Option<FleetHandle>,
    tables: Arc<WorkloadTables>,
    bounds: BoundsCtx,
}

impl<'a> EvalEngine<'a> {
    /// Engine sized to the machine (capped — the cost model is
    /// memory-light, oversubscription buys nothing).
    pub fn new(w: &'a Workload, hw: &'a HwConfig) -> EvalEngine<'a> {
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .min(16);
        EvalEngine::with_threads(w, hw, threads)
    }

    /// Engine with an explicit worker count (1 = fully serial; results
    /// are identical at any thread count).
    pub fn with_threads(w: &'a Workload, hw: &'a HwConfig, threads: usize)
                        -> EvalEngine<'a> {
        EvalEngine {
            w,
            hw,
            threads: threads.max(1),
            cache: Arc::new(EvalCache::default()),
            pool: None,
            fleet: None,
            tables: Arc::new(WorkloadTables::new(w)),
            bounds: BoundsCtx::new(w, hw),
        }
    }

    /// Override the cache bound (entries, not bytes) by swapping in a
    /// fresh private cache of that capacity.
    pub fn with_cache_capacity(mut self, capacity: usize) -> EvalEngine<'a> {
        self.cache = Arc::new(EvalCache::new(capacity));
        self
    }

    /// Memoize through `cache` instead of a private one. The cache must
    /// belong to this engine's exact `(workload, hardware)` pair — see
    /// [`EvalCache`]. Sharing one cache across concurrent engines is
    /// safe (internally locked) and is how the coordinator lets
    /// repeated/concurrent jobs reuse each other's evaluations.
    pub fn with_shared_cache(mut self, cache: Arc<EvalCache>)
                             -> EvalEngine<'a> {
        self.cache = cache;
        self
    }

    /// Run batch computations on a persistent pool (scoped submit)
    /// instead of spawning scoped threads per call. Results are
    /// identical; only spawn/join overhead disappears.
    pub fn with_pool(mut self, pool: Arc<ThreadPool>) -> EvalEngine<'a> {
        self.pool = Some(pool);
        self
    }

    /// Route cache-miss computation through a fleet backend instead of
    /// this engine's own threads: the coordinator installs its
    /// cross-job scheduler here so concurrent jobs on the same
    /// `(workload, config)` pair share kernel passes. The handle must
    /// describe this engine's exact pair. Decoding
    /// ([`EvalEngine::eval_population`]'s closure) and memoization stay
    /// local; only the miss-set scoring is delegated.
    pub fn with_fleet(mut self, fleet: FleetHandle) -> EvalEngine<'a> {
        self.fleet = Some(fleet);
        self
    }

    /// The memoization store (shared or private).
    pub fn cache(&self) -> &Arc<EvalCache> {
        &self.cache
    }

    /// The shared workload tables (divisor/prime memoization). Decode
    /// closures handed to [`EvalEngine::eval_population`] should use
    /// these (`decode_with`, `express_with`, ...) instead of
    /// re-factoring dimension sizes per candidate.
    pub fn tables(&self) -> &Arc<WorkloadTables> {
        &self.tables
    }

    /// The workload this engine scores against.
    pub fn workload(&self) -> &'a Workload {
        self.w
    }

    /// The hardware configuration this engine scores against.
    pub fn hw(&self) -> &'a HwConfig {
        self.hw
    }

    /// Worker count used for batch scoring (scoped-thread path).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Cache hits so far (includes batch-internal duplicate folding).
    /// With a shared cache this counts across every sharing engine.
    pub fn cache_hits(&self) -> u64 {
        self.cache.hits()
    }

    /// Unique cost-model computations so far.
    pub fn cache_misses(&self) -> u64 {
        self.cache.misses()
    }

    /// Entries currently cached.
    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }

    /// Drop all cached results (hit/miss counters are kept).
    pub fn clear_cache(&self) {
        self.cache.clear();
    }

    /// Per-candidate computation: [`compute_eval`] on this engine's
    /// pair.
    fn compute(&self, s: &Strategy) -> Eval {
        compute_eval(s, self.w, self.hw)
    }

    /// Compute the given cache-miss strategies (indices into `pop`,
    /// keyed off `todo`): through the fleet backend when installed, on
    /// this engine's own threads otherwise. A backend answering with
    /// the wrong arity (it is shutting down) falls back to local
    /// computation — the job still completes with identical numbers.
    fn compute_misses(&self, pop: &[Strategy], todo: &[usize])
                      -> Vec<Eval> {
        if let Some(fleet) = &self.fleet {
            let batch: Vec<Strategy> =
                todo.iter().map(|&i| pop[i].clone()).collect();
            let evals = fleet.backend.eval_candidates(fleet, batch);
            if evals.len() == todo.len() {
                return evals;
            }
        }
        self.run_indexed(todo.to_vec(), |i| self.compute(&pop[i]))
    }

    /// Run the heavy per-index closure over `n` indices: persistent
    /// pool when configured, per-call scoped threads otherwise.
    fn run_indexed<R, F>(&self, idx: Vec<usize>, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        match &self.pool {
            Some(pool) => pool.scoped_map(idx, f),
            None => par_map(idx, self.threads, f),
        }
    }

    /// Score one strategy (cache-aware).
    pub fn eval(&self, s: &Strategy) -> Eval {
        let key = StrategyKey::of(s);
        if let Some(e) = self.cache.map.lock().unwrap().get(&key) {
            self.cache.hits.fetch_add(1, Ordering::Relaxed);
            return *e;
        }
        self.cache.misses.fetch_add(1, Ordering::Relaxed);
        let e = match &self.fleet {
            Some(fleet) => {
                let evals = fleet
                    .backend
                    .eval_candidates(fleet, vec![s.clone()]);
                evals.first().copied()
                    .unwrap_or_else(|| self.compute(s))
            }
            None => self.compute(s),
        };
        let mut map = self.cache.map.lock().unwrap();
        self.cache.insert_bounded(&mut map, key, e);
        e
    }

    /// Score a whole population. Cached and batch-duplicate candidates
    /// are not recomputed; the remaining misses evaluate in parallel.
    /// Output order matches input order.
    pub fn eval_batch(&self, pop: &[Strategy]) -> Vec<Eval> {
        let mut out: Vec<Option<Eval>> = vec![None; pop.len()];
        // indices (into `pop`) that need computing, their keys, and
        // duplicate -> representative links (positions into `todo`)
        let mut todo: Vec<usize> = Vec::new();
        let mut keys: Vec<StrategyKey> = Vec::new();
        let mut alias: Vec<(usize, usize)> = Vec::new();
        {
            let map = self.cache.map.lock().unwrap();
            let mut seen: HashMap<StrategyKey, usize> = HashMap::new();
            for (i, s) in pop.iter().enumerate() {
                let key = StrategyKey::of(s);
                if let Some(e) = map.get(&key) {
                    self.cache.hits.fetch_add(1, Ordering::Relaxed);
                    out[i] = Some(*e);
                    continue;
                }
                if let Some(&pos) = seen.get(&key) {
                    self.cache.hits.fetch_add(1, Ordering::Relaxed);
                    alias.push((i, pos));
                    continue;
                }
                seen.insert(key.clone(), todo.len());
                todo.push(i);
                keys.push(key);
            }
        }
        self.cache
            .misses
            .fetch_add(todo.len() as u64, Ordering::Relaxed);
        let computed: Vec<Eval> = self.compute_misses(pop, &todo);
        {
            let mut map = self.cache.map.lock().unwrap();
            for (pos, &i) in todo.iter().enumerate() {
                out[i] = Some(computed[pos]);
                self.cache.insert_bounded(&mut map, keys[pos].clone(),
                                          computed[pos]);
            }
        }
        for (i, pos) in alias {
            out[i] = Some(computed[pos]);
        }
        out.into_iter().map(|e| e.expect("every candidate scored"))
            .collect()
    }

    /// Decode AND score a population in parallel: `decode` runs on the
    /// worker threads (it is usually as hot as evaluation), then the
    /// decoded strategies go through [`EvalEngine::eval_batch`].
    pub fn eval_population<G, F>(&self, genomes: &[G], decode: F)
                                 -> Vec<(Strategy, Eval)>
    where
        G: Sync,
        F: Fn(&G) -> Strategy + Sync,
    {
        let idx: Vec<usize> = (0..genomes.len()).collect();
        let strategies: Vec<Strategy> =
            self.run_indexed(idx, |i| decode(&genomes[i]));
        let evals = self.eval_batch(&strategies);
        strategies.into_iter().zip(evals).collect()
    }

    /// [`EvalEngine::eval_batch`] behind the bound-and-prune prefilter.
    ///
    /// Each candidate is first looked up in the cache (hits bypass the
    /// screen and come back `Exact` unconditionally), then screened by
    /// [`BoundsCtx`]: capacity-infeasible candidates and — when a
    /// `threshold` is given — candidates whose admissible EDP bound
    /// already reaches it skip the kernel entirely. Survivors go
    /// through exactly the unscreened compute path (dedup, fleet
    /// routing, cache insert), so their `Exact` results are
    /// bit-identical to [`EvalEngine::eval_batch`]'s.
    ///
    /// Pruned candidates are **not** inserted into the (possibly
    /// shared) cache and touch no hit/miss counters — the cache only
    /// ever holds kernel-exact results.
    pub fn eval_batch_screened(&self, pop: &[Strategy],
                               threshold: Option<f64>,
                               stats: Option<&PruneStats>)
                               -> Vec<Screened> {
        let layers = self.w.len();
        let mut out: Vec<Option<Screened>> = vec![None; pop.len()];
        let mut todo: Vec<usize> = Vec::new();
        let mut keys: Vec<StrategyKey> = Vec::new();
        let mut alias: Vec<(usize, usize)> = Vec::new();
        let mut scratch = ScreenScratch::new();
        let mut bounded = 0u64;
        let mut pruned_above = 0u64;
        let mut pruned_infeasible = 0u64;
        let mut exact = 0u64;
        {
            let map = self.cache.map.lock().unwrap();
            let mut seen: HashMap<StrategyKey, usize> = HashMap::new();
            for (i, s) in pop.iter().enumerate() {
                let key = StrategyKey::of(s);
                if let Some(e) = map.get(&key) {
                    self.cache.hits.fetch_add(1, Ordering::Relaxed);
                    exact += 1;
                    out[i] = Some(Screened::Exact(*e));
                    continue;
                }
                // screen before dedup; wrong-arity candidates cannot
                // be bounded and fall through to the kernel's own
                // arity guard (plain infeasible, same as unscreened)
                if s.mappings.len() == layers
                    && s.fuse.len() == layers.saturating_sub(1)
                {
                    let v = self.bounds.screen(s, &mut scratch);
                    bounded += 1;
                    if v.capacity_infeasible {
                        pruned_infeasible += 1;
                        out[i] = Some(Screened::Infeasible {
                            bound_edp: v.edp_lb,
                        });
                        continue;
                    }
                    if threshold.is_some_and(|t| v.edp_lb >= t) {
                        pruned_above += 1;
                        out[i] = Some(Screened::Pruned {
                            bound_edp: v.edp_lb,
                        });
                        continue;
                    }
                }
                if let Some(&pos) = seen.get(&key) {
                    self.cache.hits.fetch_add(1, Ordering::Relaxed);
                    exact += 1;
                    alias.push((i, pos));
                    continue;
                }
                seen.insert(key.clone(), todo.len());
                todo.push(i);
                keys.push(key);
            }
        }
        self.cache
            .misses
            .fetch_add(todo.len() as u64, Ordering::Relaxed);
        exact += todo.len() as u64;
        let computed: Vec<Eval> = self.compute_misses(pop, &todo);
        {
            let mut map = self.cache.map.lock().unwrap();
            for (pos, &i) in todo.iter().enumerate() {
                out[i] = Some(Screened::Exact(computed[pos]));
                self.cache.insert_bounded(&mut map, keys[pos].clone(),
                                          computed[pos]);
            }
        }
        for (i, pos) in alias {
            out[i] = Some(Screened::Exact(computed[pos]));
        }
        if let Some(st) = stats {
            st.bounded.fetch_add(bounded, Ordering::Relaxed);
            st.pruned_above
                .fetch_add(pruned_above, Ordering::Relaxed);
            st.pruned_infeasible
                .fetch_add(pruned_infeasible, Ordering::Relaxed);
            st.evaluated.fetch_add(exact, Ordering::Relaxed);
        }
        out.into_iter().map(|e| e.expect("every candidate screened"))
            .collect()
    }

    /// [`EvalEngine::eval_population`] behind the prefilter: decode in
    /// parallel, then [`EvalEngine::eval_batch_screened`].
    pub fn eval_population_screened<G, F>(&self, genomes: &[G],
                                          decode: F,
                                          threshold: Option<f64>,
                                          stats: Option<&PruneStats>)
                                          -> Vec<(Strategy, Screened)>
    where
        G: Sync,
        F: Fn(&G) -> Strategy + Sync,
    {
        let idx: Vec<usize> = (0..genomes.len()).collect();
        let strategies: Vec<Strategy> =
            self.run_indexed(idx, |i| decode(&genomes[i]));
        let screened =
            self.eval_batch_screened(&strategies, threshold, stats);
        strategies.into_iter().zip(screened).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{load_config, repo_root};
    use crate::costmodel;
    use crate::mapping::decode::{decode, Relaxed};
    use crate::util::rng::Rng;
    use crate::workload::zoo;

    fn random_pop(w: &Workload, hw: &HwConfig, n: usize, seed: u64)
                  -> Vec<Strategy> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                let mut relaxed = Relaxed::neutral(w);
                for l in 0..w.len() {
                    for d in 0..NDIMS {
                        for s in 0..4 {
                            relaxed.theta[l][d][s] = rng.range(0.0, 7.0);
                        }
                    }
                }
                for i in 0..relaxed.sigma.len() {
                    relaxed.sigma[i] = rng.f64();
                }
                decode(&relaxed, w, hw)
            })
            .collect()
    }

    // NOTE: bit-for-bit equivalence vs costmodel::evaluate and
    // parallel-vs-serial agreement live in rust/tests/eval_engine.rs
    // (property tests); the unit tests here cover only the engine's own
    // mechanics (cache accounting, capacity bound, arity guard).

    #[test]
    fn cache_hit_miss_accounting() {
        let hw = load_config(&repo_root(), "large").unwrap();
        let w = zoo::vgg16();
        let engine = EvalEngine::new(&w, &hw);
        let s = Strategy::trivial(&w);
        let a = engine.eval(&s);
        assert_eq!(engine.cache_misses(), 1);
        assert_eq!(engine.cache_hits(), 0);
        let b = engine.eval(&s);
        assert_eq!(engine.cache_misses(), 1);
        assert_eq!(engine.cache_hits(), 1);
        assert_eq!(a, b);
        // a batch full of duplicates computes exactly once more
        let mut s2 = Strategy::trivial(&w);
        s2.fuse[0] = true;
        let pop = vec![s2.clone(), s2.clone(), s.clone(), s2];
        let evals = engine.eval_batch(&pop);
        assert_eq!(engine.cache_misses(), 2, "one new unique candidate");
        assert_eq!(engine.cache_hits(), 1 + 3);
        assert_eq!(evals[0], evals[1]);
        assert_eq!(evals[2], a);
    }

    #[test]
    fn infeasible_candidates_flagged() {
        let hw = load_config(&repo_root(), "large").unwrap();
        let w = zoo::vgg16();
        let engine = EvalEngine::new(&w, &hw);
        let mut s = Strategy::trivial(&w);
        s.mappings[0].factors[1][3] = 64; // spatial K > 32 columns
        let e = engine.eval(&s);
        assert!(!e.feasible);
        assert!(e.fitness().is_infinite());
        assert!(e.edp.is_finite(), "raw EDP still reported");
    }

    #[test]
    fn wrong_arity_strategy_is_infeasible_not_panicking() {
        let hw = load_config(&repo_root(), "large").unwrap();
        let w = zoo::vgg16();
        let engine = EvalEngine::new(&w, &hw);
        // a strategy for a different workload (8 layers vs 16) cannot
        // be indexed by the cost model; it must score as infeasible
        let other = zoo::gpt3_6_7b();
        let e = engine.eval(&Strategy::trivial(&other));
        assert!(!e.feasible);
        assert!(e.fitness().is_infinite());
    }

    #[test]
    fn cache_capacity_bounds_entries() {
        let hw = load_config(&repo_root(), "large").unwrap();
        let w = zoo::vgg16();
        let engine = EvalEngine::new(&w, &hw).with_cache_capacity(4);
        for s in random_pop(&w, &hw, 10, 21) {
            engine.eval(&s);
        }
        assert!(engine.cache_len() <= 4);
        assert!(engine.cache().evictions() > 0,
                "capacity churn must be visible in the counter");
    }

    #[test]
    fn shared_cache_carries_results_between_engines() {
        let hw = load_config(&repo_root(), "large").unwrap();
        let w = zoo::vgg16();
        let cache = std::sync::Arc::new(EvalCache::default());
        let pop = random_pop(&w, &hw, 6, 33);
        let first = EvalEngine::new(&w, &hw)
            .with_shared_cache(std::sync::Arc::clone(&cache));
        let a = first.eval_batch(&pop);
        let misses_after_first = cache.misses();
        // a brand-new engine on the same cache sees only hits
        let second = EvalEngine::new(&w, &hw)
            .with_shared_cache(std::sync::Arc::clone(&cache));
        let b = second.eval_batch(&pop);
        assert_eq!(a, b);
        assert_eq!(cache.misses(), misses_after_first,
                   "second engine must not recompute");
        assert!(cache.hits() >= pop.len() as u64);
    }

    #[test]
    fn pooled_engine_matches_scoped_engine() {
        let hw = load_config(&repo_root(), "large").unwrap();
        let w = zoo::mobilenet_v1();
        let pop = random_pop(&w, &hw, 24, 90);
        let scoped = EvalEngine::with_threads(&w, &hw, 4);
        let pool = std::sync::Arc::new(
            crate::util::threadpool::ThreadPool::new(4));
        let pooled = EvalEngine::new(&w, &hw).with_pool(pool);
        assert_eq!(scoped.eval_batch(&pop), pooled.eval_batch(&pop));
    }

    #[test]
    fn fleet_backend_receives_misses_and_matches_local() {
        struct Recorder {
            batches: Mutex<Vec<usize>>,
        }
        impl EvalBackend for Recorder {
            fn eval_candidates(&self, h: &FleetHandle,
                               strategies: Vec<Strategy>)
                               -> Vec<Eval> {
                self.batches.lock().unwrap().push(strategies.len());
                strategies
                    .iter()
                    .map(|s| compute_eval(s, &h.w, &h.hw))
                    .collect()
            }
        }
        let hw = load_config(&repo_root(), "large").unwrap();
        let w = zoo::mobilenet_v1();
        let pop = random_pop(&w, &hw, 12, 77);
        let plain = EvalEngine::new(&w, &hw);
        let expect = plain.eval_batch(&pop);
        let backend = Arc::new(Recorder { batches: Mutex::new(vec![]) });
        let handle = FleetHandle {
            backend: backend.clone(),
            w: Arc::new(w.clone()),
            hw: Arc::new(hw.clone()),
            key: "test".into(),
        };
        let fleet = EvalEngine::new(&w, &hw).with_fleet(handle);
        assert_eq!(fleet.eval_batch(&pop), expect,
                   "fleet routing must be bit-identical");
        let sizes = backend.batches.lock().unwrap().clone();
        assert_eq!(sizes.iter().sum::<usize>(), pop.len(),
                   "every miss went through the backend");
        // second pass is all cache hits: the backend sees nothing
        let before = sizes.len();
        assert_eq!(fleet.eval_batch(&pop), expect);
        assert_eq!(backend.batches.lock().unwrap().len(), before);
        // single-candidate path routes too (fresh engine, cold cache)
        let handle2 = FleetHandle {
            backend: backend.clone(),
            w: Arc::new(w.clone()),
            hw: Arc::new(hw.clone()),
            key: "test".into(),
        };
        let single = EvalEngine::new(&w, &hw).with_fleet(handle2);
        assert_eq!(single.eval(&pop[0]), expect[0]);
    }

    #[test]
    fn fleet_backend_short_answer_falls_back_locally() {
        struct Dud;
        impl EvalBackend for Dud {
            fn eval_candidates(&self, _h: &FleetHandle,
                               _s: Vec<Strategy>) -> Vec<Eval> {
                Vec::new() // a shutting-down scheduler
            }
        }
        let hw = load_config(&repo_root(), "large").unwrap();
        let w = zoo::mobilenet_v1();
        let pop = random_pop(&w, &hw, 6, 13);
        let expect = EvalEngine::new(&w, &hw).eval_batch(&pop);
        let handle = FleetHandle {
            backend: Arc::new(Dud),
            w: Arc::new(w.clone()),
            hw: Arc::new(hw.clone()),
            key: "dud".into(),
        };
        let engine = EvalEngine::new(&w, &hw).with_fleet(handle);
        assert_eq!(engine.eval_batch(&pop), expect,
                   "short backend answer must fall back, not corrupt");
    }

    #[test]
    fn screened_batch_without_threshold_matches_unscreened() {
        let hw = load_config(&repo_root(), "large").unwrap();
        let w = zoo::mobilenet_v1();
        let pop = random_pop(&w, &hw, 20, 41);
        let plain = EvalEngine::new(&w, &hw);
        let expect = plain.eval_batch(&pop);
        let engine = EvalEngine::new(&w, &hw);
        let stats = PruneStats::default();
        let screened =
            engine.eval_batch_screened(&pop, None, Some(&stats));
        for (sc, e) in screened.iter().zip(&expect) {
            match sc {
                Screened::Exact(got) => assert_eq!(got, e),
                other => {
                    // only capacity-infeasible candidates may skip the
                    // kernel without a threshold — and then the exact
                    // path must agree they are infeasible
                    assert!(matches!(other, Screened::Infeasible { .. }));
                    assert!(!e.feasible);
                }
            }
        }
        assert_eq!(stats.bounded.load(Ordering::Relaxed),
                   pop.len() as u64);
        assert_eq!(stats.pruned_above.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn screened_batch_prunes_above_threshold_and_skips_cache() {
        let hw = load_config(&repo_root(), "large").unwrap();
        let w = zoo::mobilenet_v1();
        let pop = random_pop(&w, &hw, 16, 55);
        let engine = EvalEngine::new(&w, &hw);
        // an absurdly low threshold: everything screenable is pruned
        let stats = PruneStats::default();
        let screened =
            engine.eval_batch_screened(&pop, Some(1e-30), Some(&stats));
        assert!(screened.iter().all(|sc| !matches!(
            sc, Screened::Exact(_))));
        assert!(stats.pruned() >= 1);
        assert_eq!(engine.cache_len(), 0,
                   "pruned candidates must never enter the cache");
        assert_eq!(engine.cache_misses(), 0);
        // pruned bounds really are admissible for these candidates
        for (sc, s) in screened.iter().zip(&pop) {
            let exact = costmodel::evaluate(s, &w, &hw);
            match sc {
                Screened::Pruned { bound_edp }
                | Screened::Infeasible { bound_edp } => {
                    assert!(*bound_edp <= exact.edp);
                }
                Screened::Exact(_) => unreachable!(),
            }
        }
    }

    #[test]
    fn eval_population_decodes_and_scores() {
        let hw = load_config(&repo_root(), "large").unwrap();
        let w = zoo::mobilenet_v1();
        let engine = EvalEngine::new(&w, &hw);
        let mut rng = Rng::new(5);
        let genomes: Vec<Vec<f64>> = (0..8)
            .map(|_| {
                (0..crate::search::encoding::dim(&w))
                    .map(|_| rng.f64())
                    .collect()
            })
            .collect();
        let scored = engine.eval_population(&genomes, |g| {
            crate::search::encoding::express_naive(g, &w, &hw)
        });
        assert_eq!(scored.len(), 8);
        for (s, e) in &scored {
            assert!(e.feasible, "naive legalization must be feasible");
            let r = costmodel::evaluate(s, &w, &hw);
            assert_eq!(e.edp, r.edp);
        }
    }
}
