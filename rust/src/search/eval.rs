//! The batched evaluation engine — the single entry point every search
//! strategy and experiment harness uses to score candidate strategies.
//!
//! GA/BO/random search and the Table-1/Fig-3/Fig-4 harnesses spend
//! nearly all of their time in the analytical cost model (paper
//! Eqs. 4-19). [`EvalEngine`] makes that hot path fast two ways:
//!
//! * **Parallel batch scoring** — whole candidate populations decode and
//!   evaluate concurrently on the crate's scoped worker substrate
//!   ([`crate::util::threadpool::par_map`]), one logical chunk per
//!   candidate with work-stealing across `threads` workers.
//! * **Keyed memoization** — a bounded `(strategy) -> (energy, latency,
//!   EDP)` cache per `(workload, hardware)` pair. GA elitism, BO
//!   acquisition re-proposals and duplicate random decodes stop paying
//!   for re-evaluation; batch-internal duplicates are computed once.
//!
//! Results are bit-for-bit identical to calling
//! [`crate::costmodel::evaluate`] directly: the engine runs exactly that
//! code per candidate, it only changes *where* and *how often* it runs.
//!
//! Batches currently run on scoped threads (`par_map`) spawned per
//! call; for small populations the spawn/join overhead is measurable
//! against the ~ms of decode+eval work. Moving to a persistent
//! [`crate::util::threadpool::ThreadPool`] is a known follow-up once
//! the pool grows a scoped-submit API — `perf_hotpath` tracks whether
//! it matters.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::config::HwConfig;
use crate::costmodel;
use crate::mapping::{Strategy, NSLOTS};
use crate::util::threadpool::par_map;
use crate::workload::{Workload, NDIMS};

/// Default bound on cached entries; the cache is cleared wholesale when
/// it fills (simple, predictable memory ceiling). Keys are exact
/// (layers x 7 x 4 factors, a few KB each), so 8192 entries is roughly
/// 30-60 MB per engine — sized so several concurrent engines (table1
/// cells, coordinator workers) stay modest.
pub const DEFAULT_CACHE_CAPACITY: usize = 8_192;

/// One scored candidate. `edp = energy * latency` always holds (also for
/// infeasible strategies — use [`Eval::feasible`] to gate on validity;
/// [`super::Incumbent::offer_eval`] does exactly that).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Eval {
    pub energy: f64,
    pub latency: f64,
    pub edp: f64,
    pub feasible: bool,
}

impl Eval {
    /// EDP if feasible, `f64::INFINITY` otherwise — the fitness value
    /// searches minimize.
    pub fn fitness(&self) -> f64 {
        if self.feasible {
            self.edp
        } else {
            f64::INFINITY
        }
    }
}

/// Exact memoization key: every tiling factor plus the fusion bits.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
struct StrategyKey {
    factors: Vec<u64>,
    fuse: Vec<bool>,
}

impl StrategyKey {
    fn of(s: &Strategy) -> StrategyKey {
        let mut factors =
            Vec::with_capacity(s.mappings.len() * NDIMS * NSLOTS);
        for m in &s.mappings {
            for d in 0..NDIMS {
                for sl in 0..NSLOTS {
                    factors.push(m.factors[d][sl]);
                }
            }
        }
        StrategyKey { factors, fuse: s.fuse.clone() }
    }
}

/// Parallel, memoizing evaluator for one `(workload, hardware)` pair.
pub struct EvalEngine<'a> {
    w: &'a Workload,
    hw: &'a HwConfig,
    threads: usize,
    cache_capacity: usize,
    cache: Mutex<HashMap<StrategyKey, Eval>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl<'a> EvalEngine<'a> {
    /// Engine sized to the machine (capped — the cost model is
    /// memory-light, oversubscription buys nothing).
    pub fn new(w: &'a Workload, hw: &'a HwConfig) -> EvalEngine<'a> {
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .min(16);
        EvalEngine::with_threads(w, hw, threads)
    }

    /// Engine with an explicit worker count (1 = fully serial; results
    /// are identical at any thread count).
    pub fn with_threads(w: &'a Workload, hw: &'a HwConfig, threads: usize)
                        -> EvalEngine<'a> {
        EvalEngine {
            w,
            hw,
            threads: threads.max(1),
            cache_capacity: DEFAULT_CACHE_CAPACITY,
            cache: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Override the cache bound (entries, not bytes).
    pub fn with_cache_capacity(mut self, capacity: usize) -> EvalEngine<'a> {
        self.cache_capacity = capacity.max(1);
        self
    }

    pub fn workload(&self) -> &'a Workload {
        self.w
    }

    pub fn hw(&self) -> &'a HwConfig {
        self.hw
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Cache hits so far (includes batch-internal duplicate folding).
    pub fn cache_hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Unique cost-model computations so far.
    pub fn cache_misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Entries currently cached.
    pub fn cache_len(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Drop all cached results (hit/miss counters are kept).
    pub fn clear_cache(&self) {
        self.cache.lock().unwrap().clear();
    }

    /// The raw per-candidate computation: feasibility check + closed-form
    /// evaluation. Capacity-infeasible strategies still get real
    /// energy/latency numbers (fig3 relies on that); strategies with the
    /// wrong arity cannot be indexed by the cost model at all and come
    /// back as plain infeasible instead of panicking.
    fn compute(&self, s: &Strategy) -> Eval {
        if s.mappings.len() != self.w.len()
            || s.fuse.len() != self.w.len().saturating_sub(1)
        {
            return Eval {
                energy: f64::INFINITY,
                latency: f64::INFINITY,
                edp: f64::INFINITY,
                feasible: false,
            };
        }
        let feasible = costmodel::feasible(s, self.w, self.hw).is_ok();
        let r = costmodel::evaluate(s, self.w, self.hw);
        Eval { energy: r.energy, latency: r.latency, edp: r.edp, feasible }
    }

    fn insert_bounded(&self, cache: &mut HashMap<StrategyKey, Eval>,
                      key: StrategyKey, e: Eval) {
        if cache.len() >= self.cache_capacity {
            cache.clear();
        }
        cache.insert(key, e);
    }

    /// Score one strategy (cache-aware).
    pub fn eval(&self, s: &Strategy) -> Eval {
        let key = StrategyKey::of(s);
        if let Some(e) = self.cache.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return *e;
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let e = self.compute(s);
        let mut cache = self.cache.lock().unwrap();
        self.insert_bounded(&mut cache, key, e);
        e
    }

    /// Score a whole population. Cached and batch-duplicate candidates
    /// are not recomputed; the remaining misses evaluate in parallel.
    /// Output order matches input order.
    pub fn eval_batch(&self, pop: &[Strategy]) -> Vec<Eval> {
        let mut out: Vec<Option<Eval>> = vec![None; pop.len()];
        // indices (into `pop`) that need computing, their keys, and
        // duplicate -> representative links (positions into `todo`)
        let mut todo: Vec<usize> = Vec::new();
        let mut keys: Vec<StrategyKey> = Vec::new();
        let mut alias: Vec<(usize, usize)> = Vec::new();
        {
            let cache = self.cache.lock().unwrap();
            let mut seen: HashMap<StrategyKey, usize> = HashMap::new();
            for (i, s) in pop.iter().enumerate() {
                let key = StrategyKey::of(s);
                if let Some(e) = cache.get(&key) {
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    out[i] = Some(*e);
                    continue;
                }
                if let Some(&pos) = seen.get(&key) {
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    alias.push((i, pos));
                    continue;
                }
                seen.insert(key.clone(), todo.len());
                todo.push(i);
                keys.push(key);
            }
        }
        self.misses.fetch_add(todo.len() as u64, Ordering::Relaxed);
        let computed: Vec<Eval> =
            par_map(todo.clone(), self.threads, |i| self.compute(&pop[i]));
        {
            let mut cache = self.cache.lock().unwrap();
            for (pos, &i) in todo.iter().enumerate() {
                out[i] = Some(computed[pos]);
                self.insert_bounded(&mut cache, keys[pos].clone(),
                                    computed[pos]);
            }
        }
        for (i, pos) in alias {
            out[i] = Some(computed[pos]);
        }
        out.into_iter().map(|e| e.expect("every candidate scored"))
            .collect()
    }

    /// Decode AND score a population in parallel: `decode` runs on the
    /// worker threads (it is usually as hot as evaluation), then the
    /// decoded strategies go through [`EvalEngine::eval_batch`].
    pub fn eval_population<G, F>(&self, genomes: &[G], decode: F)
                                 -> Vec<(Strategy, Eval)>
    where
        G: Sync,
        F: Fn(&G) -> Strategy + Sync,
    {
        let idx: Vec<usize> = (0..genomes.len()).collect();
        let strategies: Vec<Strategy> =
            par_map(idx, self.threads, |i| decode(&genomes[i]));
        let evals = self.eval_batch(&strategies);
        strategies.into_iter().zip(evals).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{load_config, repo_root};
    use crate::mapping::decode::{decode, Relaxed};
    use crate::util::rng::Rng;
    use crate::workload::zoo;

    fn random_pop(w: &Workload, hw: &HwConfig, n: usize, seed: u64)
                  -> Vec<Strategy> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                let mut relaxed = Relaxed::neutral(w);
                for l in 0..w.len() {
                    for d in 0..NDIMS {
                        for s in 0..4 {
                            relaxed.theta[l][d][s] = rng.range(0.0, 7.0);
                        }
                    }
                }
                for i in 0..relaxed.sigma.len() {
                    relaxed.sigma[i] = rng.f64();
                }
                decode(&relaxed, w, hw)
            })
            .collect()
    }

    // NOTE: bit-for-bit equivalence vs costmodel::evaluate and
    // parallel-vs-serial agreement live in rust/tests/eval_engine.rs
    // (property tests); the unit tests here cover only the engine's own
    // mechanics (cache accounting, capacity bound, arity guard).

    #[test]
    fn cache_hit_miss_accounting() {
        let hw = load_config(&repo_root(), "large").unwrap();
        let w = zoo::vgg16();
        let engine = EvalEngine::new(&w, &hw);
        let s = Strategy::trivial(&w);
        let a = engine.eval(&s);
        assert_eq!(engine.cache_misses(), 1);
        assert_eq!(engine.cache_hits(), 0);
        let b = engine.eval(&s);
        assert_eq!(engine.cache_misses(), 1);
        assert_eq!(engine.cache_hits(), 1);
        assert_eq!(a, b);
        // a batch full of duplicates computes exactly once more
        let mut s2 = Strategy::trivial(&w);
        s2.fuse[0] = true;
        let pop = vec![s2.clone(), s2.clone(), s.clone(), s2];
        let evals = engine.eval_batch(&pop);
        assert_eq!(engine.cache_misses(), 2, "one new unique candidate");
        assert_eq!(engine.cache_hits(), 1 + 3);
        assert_eq!(evals[0], evals[1]);
        assert_eq!(evals[2], a);
    }

    #[test]
    fn infeasible_candidates_flagged() {
        let hw = load_config(&repo_root(), "large").unwrap();
        let w = zoo::vgg16();
        let engine = EvalEngine::new(&w, &hw);
        let mut s = Strategy::trivial(&w);
        s.mappings[0].factors[1][3] = 64; // spatial K > 32 columns
        let e = engine.eval(&s);
        assert!(!e.feasible);
        assert!(e.fitness().is_infinite());
        assert!(e.edp.is_finite(), "raw EDP still reported");
    }

    #[test]
    fn wrong_arity_strategy_is_infeasible_not_panicking() {
        let hw = load_config(&repo_root(), "large").unwrap();
        let w = zoo::vgg16();
        let engine = EvalEngine::new(&w, &hw);
        // a strategy for a different workload (8 layers vs 16) cannot
        // be indexed by the cost model; it must score as infeasible
        let other = zoo::gpt3_6_7b();
        let e = engine.eval(&Strategy::trivial(&other));
        assert!(!e.feasible);
        assert!(e.fitness().is_infinite());
    }

    #[test]
    fn cache_capacity_bounds_entries() {
        let hw = load_config(&repo_root(), "large").unwrap();
        let w = zoo::vgg16();
        let engine = EvalEngine::new(&w, &hw).with_cache_capacity(4);
        for s in random_pop(&w, &hw, 10, 21) {
            engine.eval(&s);
        }
        assert!(engine.cache_len() <= 4);
    }

    #[test]
    fn eval_population_decodes_and_scores() {
        let hw = load_config(&repo_root(), "large").unwrap();
        let w = zoo::mobilenet_v1();
        let engine = EvalEngine::new(&w, &hw);
        let mut rng = Rng::new(5);
        let genomes: Vec<Vec<f64>> = (0..8)
            .map(|_| {
                (0..crate::search::encoding::dim(&w))
                    .map(|_| rng.f64())
                    .collect()
            })
            .collect();
        let scored = engine.eval_population(&genomes, |g| {
            crate::search::encoding::express_naive(g, &w, &hw)
        });
        assert_eq!(scored.len(), 8);
        for (s, e) in &scored {
            assert!(e.feasible, "naive legalization must be feasible");
            let r = costmodel::evaluate(s, &w, &hw);
            assert_eq!(e.edp, r.edp);
        }
    }
}
