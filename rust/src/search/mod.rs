//! Search algorithms over the joint mapping x fusion space:
//!
//! * [`gradient`] — FADiff itself: constrained gradient descent (Adam)
//!   over the continuous relaxation, with tau/lambda annealing and
//!   decode-time repair. Runs natively everywhere on the pure-Rust
//!   differentiable model (`costmodel::grad`), as `C` *parallel
//!   chains* in one SoA batch — restarts step concurrently on the
//!   worker threads (deterministic per-chain RNG streams; results are
//!   bit-identical at any pool size) and their decode offers score in
//!   one batched engine pass. The AOT `fadiff_grad` artifact on PJRT
//!   is an optional accelerator of the same math. DOSA (layer-wise,
//!   MICRO'23) is the same engine with fusion disabled.
//! * [`ga`] — the heuristic baseline (tournament GA, paper ref [16]).
//! * [`bo`] — the learning-based baseline (GP + expected improvement,
//!   paper ref [15]) on top of [`gp`].
//! * [`random`] — uniform random sampling (sanity floor).
//! * [`exact`] — the branch-and-bound oracle: certified-optimal
//!   mapping for small-to-medium workloads, driven by the admissible
//!   bounds of `costmodel::bounds` plus dominance rules, reporting
//!   the measured optimality gap of every other method.
//!
//! All native candidate scoring flows through [`eval::EvalEngine`] — the
//! batched, multi-threaded, memoizing evaluator of the analytical cost
//! model. The [`Incumbent`] owns one engine per search, so every
//! `offer()` is cache-aware and population-based searches batch through
//! [`eval::EvalEngine::eval_batch`] / `eval_population`.
//!
//! Each native method also exposes an `optimize_ctx` entry point taking
//! an [`EvalCtx`] — the seam the coordinator uses to inject a shared
//! cross-job [`EvalCache`], a persistent worker pool, and a cooperative
//! cancellation flag without changing standalone behavior.

pub mod bo;
pub mod encoding;
pub mod eval;
pub mod exact;
pub mod ga;
pub mod gp;
pub mod gradient;
pub mod random;

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::HwConfig;
use crate::mapping::Strategy;
use crate::util::threadpool::ThreadPool;
use crate::workload::Workload;

pub use eval::{compute_eval, Eval, EvalBackend, EvalCache, EvalEngine,
               FleetHandle, PruneStats, Screened};

/// Policy for the bound-and-prune prefilter
/// ([`EvalEngine::eval_batch_screened`]).
///
/// `On` is the default and is *result-invariant*: it only skips kernel
/// work for candidates that provably could not have improved the
/// incumbent (admissible bound) or that the kernel provably rejects
/// (exact capacity replica), so random/gradient/BO results stay
/// bit-identical to `Off`. `Full` additionally lets GA selection see
/// pruned candidates' bounds as pessimistic fitness — faster
/// generations, but a *different* (still valid) GA trajectory.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PruneMode {
    /// Result-invariant pruning (the default).
    #[default]
    On,
    /// No screening: every candidate runs the full kernel.
    Off,
    /// `On`, plus GA uses bounds as pessimistic fitness for pruned
    /// candidates (documented as changing the GA trajectory).
    Full,
}

impl PruneMode {
    /// Parse a protocol-level mode name.
    pub fn parse(text: &str) -> Option<PruneMode> {
        match text {
            "on" => Some(PruneMode::On),
            "off" => Some(PruneMode::Off),
            "full" => Some(PruneMode::Full),
            _ => None,
        }
    }

    /// Stable wire name.
    pub fn name(&self) -> &'static str {
        match self {
            PruneMode::On => "on",
            PruneMode::Off => "off",
            PruneMode::Full => "full",
        }
    }

    /// Whether the screened evaluation path is active at all.
    pub fn enabled(&self) -> bool {
        !matches!(self, PruneMode::Off)
    }
}

/// Live, lock-free progress of one running search, shared between the
/// search loop (writer) and the serving layer (reader — the `status`
/// verb's `watch` stream polls this). All fields are monotone per run;
/// `seq` bumps whenever something watch-worthy changed (a new best
/// incumbent or an iteration-count update), so a poller can cheaply
/// detect "anything new since last look".
#[derive(Default)]
pub struct SearchProgress {
    seq: AtomicU64,
    // f64::INFINITY.to_bits() until the first feasible incumbent
    best_edp_bits: AtomicU64,
    evals: AtomicU64,
    iters: AtomicU64,
}

/// One consistent-enough read of a [`SearchProgress`] (fields are read
/// individually; they are each monotone, which is all watchers need).
#[derive(Clone, Copy, Debug)]
pub struct ProgressSnapshot {
    /// Change counter at read time.
    pub seq: u64,
    /// Best feasible EDP so far, if any incumbent exists yet.
    pub best_edp: Option<f64>,
    /// Candidates offered so far.
    pub evals: u64,
    /// Iterations executed so far.
    pub iters: u64,
}

impl SearchProgress {
    /// Fresh progress: no incumbent, zero counters.
    pub fn new() -> SearchProgress {
        SearchProgress {
            seq: AtomicU64::new(0),
            best_edp_bits: AtomicU64::new(f64::INFINITY.to_bits()),
            evals: AtomicU64::new(0),
            iters: AtomicU64::new(0),
        }
    }

    /// Publish a new best feasible EDP (bumps `seq`).
    pub fn record_best(&self, edp: f64) {
        self.best_edp_bits.store(edp.to_bits(), Ordering::Relaxed);
        self.seq.fetch_add(1, Ordering::Release);
    }

    /// Publish the offered-candidate count (no `seq` bump — evals move
    /// too fast to be individually watch-worthy).
    pub fn record_evals(&self, evals: u64) {
        self.evals.store(evals, Ordering::Relaxed);
    }

    /// Publish the iteration count (bumps `seq` — one event per
    /// generation/block is the natural streaming granularity).
    pub fn record_iters(&self, iters: u64) {
        self.iters.store(iters, Ordering::Relaxed);
        self.seq.fetch_add(1, Ordering::Release);
    }

    /// Read the current state.
    pub fn snapshot(&self) -> ProgressSnapshot {
        let bits = self.best_edp_bits.load(Ordering::Relaxed);
        let edp = f64::from_bits(bits);
        ProgressSnapshot {
            seq: self.seq.load(Ordering::Acquire),
            best_edp: if edp.is_finite() { Some(edp) } else { None },
            evals: self.evals.load(Ordering::Relaxed),
            iters: self.iters.load(Ordering::Relaxed),
        }
    }
}

/// A cooperative per-job deadline, enforced through the same polling
/// seam as cancellation: every native search checks it between
/// batches (via [`Incumbent::stopped`] and the gradient methods'
/// per-step `ChainStop`) and finishes with its best-so-far once
/// expired. The `hit` latch records that *some* poll observed expiry,
/// so the serving layer can distinguish a deadline-terminated job
/// (terminal status `deadline_exceeded`) from a normal completion —
/// even when the final poll raced the finish line.
#[derive(Clone)]
pub struct Deadline {
    /// Absolute instant past which the job must stop.
    pub at: Instant,
    /// Latched true by the first poll that observes expiry.
    pub hit: Arc<AtomicBool>,
}

impl Deadline {
    /// A deadline `ms` milliseconds from now.
    pub fn in_ms(ms: u64) -> Deadline {
        Deadline {
            at: Instant::now() + Duration::from_millis(ms),
            hit: Arc::new(AtomicBool::new(false)),
        }
    }

    /// Whether the deadline has passed; latches `hit` on the first
    /// `true` observation.
    pub fn expired(&self) -> bool {
        if self.hit.load(Ordering::SeqCst) {
            return true;
        }
        if Instant::now() >= self.at {
            self.hit.store(true, Ordering::SeqCst);
            return true;
        }
        false
    }

    /// Whether any poll has observed expiry (no clock read; the
    /// after-the-fact classification check).
    pub fn was_hit(&self) -> bool {
        self.hit.load(Ordering::SeqCst)
    }
}

/// Cross-job evaluation context handed to the `optimize_ctx` entry
/// points by a serving layer: an optional shared memoization cache
/// (must match the job's `(workload, hardware)` pair — see
/// [`EvalCache`]), an optional persistent worker pool for batch
/// scoring, an optional cooperative cancellation flag polled by the
/// search loops, an optional fleet backend (the coordinator's
/// cross-job batch scheduler) and an optional live progress sink (the
/// `watch` stream). `EvalCtx::default()` reproduces the standalone
/// behavior exactly (private cache, scoped threads, no cancel, no
/// fleet, no progress).
#[derive(Clone, Default)]
pub struct EvalCtx {
    /// Shared memoization cache for the job's `(workload, hw)` pair.
    pub cache: Option<Arc<EvalCache>>,
    /// Persistent worker pool for batch scoring.
    pub pool: Option<Arc<ThreadPool>>,
    /// Cooperative cancellation flag, polled between batches.
    pub cancel: Option<Arc<AtomicBool>>,
    /// Fleet ticket: engines built from this context send cache-miss
    /// batches through the shared cross-job scheduler.
    pub fleet: Option<FleetHandle>,
    /// Live progress sink read by `status {"watch": true}` streams.
    pub progress: Option<Arc<SearchProgress>>,
    /// Cooperative per-job deadline, polled at the same batch
    /// boundaries as `cancel`. Expired jobs keep their best-so-far
    /// and terminate with status `deadline_exceeded`.
    pub deadline: Option<Deadline>,
    /// Bound-and-prune policy for the engine's screened batch path.
    pub prune: PruneMode,
    /// Shared prefilter counters (the coordinator's `metrics.prune`).
    pub prune_stats: Option<Arc<PruneStats>>,
    /// Warm-start seed strategies (assembled from the coordinator's
    /// mapping library in a deterministic order). Offered to the
    /// incumbent before the search starts and injected into a
    /// `warm_frac` fraction of GA populations / gradient chains.
    pub seeds: Vec<Strategy>,
    /// Fraction (0..=1) of GA genomes / gradient chains initialized
    /// from `seeds`. 0 disables warm-starting (the default — results
    /// then never depend on library state).
    pub warm_frac: f64,
}

impl EvalCtx {
    /// Build the engine this context prescribes for `(w, hw)`.
    pub fn engine<'a>(&self, w: &'a Workload, hw: &'a HwConfig)
                      -> EvalEngine<'a> {
        let mut engine = EvalEngine::new(w, hw);
        if let Some(cache) = &self.cache {
            engine = engine.with_shared_cache(Arc::clone(cache));
        }
        if let Some(pool) = &self.pool {
            engine = engine.with_pool(Arc::clone(pool));
        }
        if let Some(fleet) = &self.fleet {
            engine = engine.with_fleet(fleet.clone());
        }
        engine
    }

    /// The shared prefilter counters, if the serving layer installed
    /// any (searches pass this straight to the screened batch calls).
    pub fn prune_stats(&self) -> Option<&PruneStats> {
        self.prune_stats.as_deref()
    }

    /// How many of `n` population/chain slots to initialize from the
    /// warm-start seeds (`ceil(warm_frac * n)`, capped at `n`; 0 when
    /// seeding is disabled or no seeds exist).
    pub fn seed_slots(&self, n: usize) -> usize {
        if self.seeds.is_empty() || self.warm_frac <= 0.0 {
            return 0;
        }
        ((self.warm_frac * n as f64).ceil() as usize).min(n)
    }
}

/// Common search budget: wall-clock (the paper compares equal time) and
/// an iteration cap as a secondary bound.
///
/// For the gradient searches the two bounds have distinct roles: a
/// finite `max_iters` owns the lambda-annealing schedule (keeping
/// iteration-budgeted runs bit-deterministic), while `seconds` is the
/// timeout — and under a pure seconds budget (`max_iters` unbounded)
/// the wall clock drives the ramp instead. See
/// `gradient::ramp_progress` for the full contract.
#[derive(Clone, Copy, Debug)]
pub struct Budget {
    /// Wall-clock bound, seconds (may be infinite).
    pub seconds: f64,
    /// Iteration bound (may be `usize::MAX`).
    pub max_iters: usize,
}

impl Budget {
    /// A pure wall-clock budget (unbounded iterations).
    pub fn seconds(seconds: f64) -> Budget {
        Budget { seconds, max_iters: usize::MAX }
    }

    /// A pure iteration budget (no time limit) — the deterministic
    /// form: identical requests produce bit-identical results.
    pub fn iters(max_iters: usize) -> Budget {
        Budget { seconds: f64::INFINITY, max_iters }
    }
}

/// One point of the optimization trace (Fig 4: EDP vs time).
#[derive(Clone, Copy, Debug)]
pub struct TracePoint {
    /// Seconds since the search started.
    pub seconds: f64,
    /// Best feasible EDP at that moment.
    pub best_edp: f64,
    /// Iteration counter at that moment.
    pub iter: usize,
}

/// Search outcome: best feasible strategy + its native evaluation +
/// the convergence trace.
#[derive(Clone, Debug)]
pub struct SearchResult {
    /// The best feasible strategy found.
    pub best: Strategy,
    /// Its EDP (pJ * cycles, per replica).
    pub edp: f64,
    /// Its energy, pJ.
    pub energy: f64,
    /// Its latency, cycles.
    pub latency: f64,
    /// Incumbent-improvement trace (Fig 4).
    pub trace: Vec<TracePoint>,
    /// Iterations executed (gradient methods: inner steps, summed
    /// across parallel chains).
    pub iters: usize,
    /// Candidates offered to the incumbent (cache hits included).
    pub evals: usize,
}

impl SearchResult {
    /// EDP scaled to the full model (replica^2).
    pub fn full_model_edp(&self, w: &Workload) -> f64 {
        self.edp * w.replicas * w.replicas
    }
}

/// Incumbent tracker shared by all searches: keeps the best *feasible*
/// strategy and the (time, edp) trace. Owns the search's [`EvalEngine`],
/// so offers are memoized and callers can batch-score populations via
/// `inc.engine`.
pub struct Incumbent<'a> {
    /// The search's evaluation engine (batch scoring + memoization).
    pub engine: EvalEngine<'a>,
    start: Instant,
    cancel: Option<Arc<AtomicBool>>,
    deadline: Option<Deadline>,
    progress: Option<Arc<SearchProgress>>,
    /// Best feasible `(strategy, edp, energy, latency)` so far.
    pub best: Option<(Strategy, f64, f64, f64)>,
    /// Improvement trace (one point per new best).
    pub trace: Vec<TracePoint>,
    /// Candidates offered so far.
    pub evals: usize,
}

impl<'a> Incumbent<'a> {
    /// Incumbent with a default-configured engine.
    pub fn new(w: &'a Workload, hw: &'a HwConfig) -> Incumbent<'a> {
        Incumbent::with_engine(EvalEngine::new(w, hw))
    }

    /// Wrap an explicitly-configured engine (thread count, cache size).
    pub fn with_engine(engine: EvalEngine<'a>) -> Incumbent<'a> {
        Incumbent { engine, start: Instant::now(), cancel: None,
                    deadline: None, progress: None, best: None,
                    trace: Vec::new(), evals: 0 }
    }

    /// Incumbent + engine as prescribed by a serving-layer [`EvalCtx`]
    /// (shared cache, persistent pool, cancellation flag, fleet
    /// backend, progress sink).
    pub fn with_ctx(w: &'a Workload, hw: &'a HwConfig, ctx: &EvalCtx)
                    -> Incumbent<'a> {
        let mut inc = Incumbent::with_engine(ctx.engine(w, hw));
        inc.cancel = ctx.cancel.clone();
        inc.deadline = ctx.deadline.clone();
        inc.progress = ctx.progress.clone();
        inc
    }

    /// Publish the current iteration count to any live `watch` stream.
    /// Searches call this once per generation / decode block — cheap
    /// (two atomic stores), a no-op standalone.
    pub fn note_iters(&self, iters: usize) {
        if let Some(p) = &self.progress {
            p.record_iters(iters as u64);
        }
    }

    /// Seconds since the search started.
    pub fn elapsed(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Whether a serving layer has requested this search stop early.
    pub fn cancelled(&self) -> bool {
        self.cancel
            .as_ref()
            .is_some_and(|c| c.load(Ordering::SeqCst))
    }

    /// Whether the job's cooperative deadline (if any) has passed.
    pub fn deadline_expired(&self) -> bool {
        self.deadline.as_ref().is_some_and(|d| d.expired())
    }

    /// The loop condition every native search polls between batches:
    /// budget exhausted, deadline expired, or cancellation requested.
    /// On `true` the search finishes immediately with its
    /// best-so-far.
    pub fn stopped(&self, budget: &Budget) -> bool {
        self.cancelled() || self.deadline_expired()
            || self.elapsed() >= budget.seconds
    }

    /// Evaluate through the engine; record if feasible and better.
    /// Returns the EDP (infinite when infeasible).
    pub fn offer(&mut self, s: &Strategy, iter: usize) -> f64 {
        let e = self.engine.eval(s);
        self.offer_eval(s, e, iter)
    }

    /// Best feasible EDP so far — the screened path's prune threshold
    /// (a candidate whose admissible bound reaches this cannot improve
    /// the incumbent).
    pub fn best_edp(&self) -> Option<f64> {
        self.best.as_ref().map(|&(_, edp, _, _)| edp)
    }

    /// Offer warm-start seeds (iter 0, fixed order) before a search
    /// begins: the incumbent starts from the best library-known
    /// strategy instead of cold. No-op when `seeds` is empty.
    pub fn offer_seeds(&mut self, seeds: &[Strategy]) {
        for s in seeds {
            self.offer(s, 0);
        }
    }

    /// Record one outcome of a screened batch. `Exact` results go
    /// through [`Incumbent::offer_eval`]; pruned candidates count as
    /// offered evals (keeping counters identical to the unscreened
    /// path) but by construction cannot improve the incumbent, so no
    /// kernel work or trace update happens for them.
    pub fn offer_screened(&mut self, s: &Strategy, sc: Screened,
                          iter: usize) -> f64 {
        match sc {
            Screened::Exact(e) => self.offer_eval(s, e, iter),
            Screened::Pruned { .. } | Screened::Infeasible { .. } => {
                self.evals += 1;
                if let Some(p) = &self.progress {
                    p.record_evals(self.evals as u64);
                }
                f64::INFINITY
            }
        }
    }

    /// Record an already-scored candidate (the batched path: score the
    /// population via `self.engine`, then offer the results in order).
    pub fn offer_eval(&mut self, s: &Strategy, e: Eval, iter: usize)
                      -> f64 {
        self.evals += 1;
        if let Some(p) = &self.progress {
            p.record_evals(self.evals as u64);
        }
        if !e.feasible {
            return f64::INFINITY;
        }
        let better = self
            .best
            .as_ref()
            .map_or(true, |&(_, best_edp, _, _)| e.edp < best_edp);
        if better {
            self.best = Some((s.clone(), e.edp, e.energy, e.latency));
            self.trace.push(TracePoint {
                seconds: self.elapsed(),
                best_edp: e.edp,
                iter,
            });
            if let Some(p) = &self.progress {
                p.record_best(e.edp);
            }
        }
        e.edp
    }

    /// Finish; seeds with the always-feasible trivial strategy if no
    /// feasible candidate was ever offered.
    pub fn finish(mut self, iters: usize) -> SearchResult {
        if self.best.is_none() {
            let s = Strategy::trivial(self.engine.workload());
            self.offer(&s, iters);
        }
        let evals = self.evals;
        let (best, edp, energy, latency) = self.best.unwrap();
        SearchResult { best, edp, energy, latency, trace: self.trace,
                       iters, evals }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{load_config, repo_root};
    use crate::workload::zoo;

    #[test]
    fn incumbent_tracks_best() {
        let w = zoo::vgg16();
        let hw = load_config(&repo_root(), "large").unwrap();
        let mut inc = Incumbent::new(&w, &hw);
        let s = Strategy::trivial(&w);
        let edp1 = inc.offer(&s, 0);
        assert!(edp1.is_finite());
        // fusing a legal edge on the trivial mapping improves EDP
        let mut s2 = s.clone();
        s2.fuse[0] = true;
        let edp2 = inc.offer(&s2, 1);
        assert!(edp2 < edp1);
        let r = inc.finish(2);
        assert_eq!(r.edp, edp2);
        assert_eq!(r.trace.len(), 2);
        assert!(r.trace[0].best_edp >= r.trace[1].best_edp);
    }

    #[test]
    fn infeasible_offer_is_rejected() {
        let w = zoo::vgg16();
        let hw = load_config(&repo_root(), "large").unwrap();
        let mut inc = Incumbent::new(&w, &hw);
        let mut s = Strategy::trivial(&w);
        s.mappings[0].factors[1][3] = 64; // spatial overflow
        assert!(inc.offer(&s, 0).is_infinite());
        let r = inc.finish(1); // falls back to trivial
        assert!(r.edp.is_finite());
    }

    #[test]
    fn progress_publishes_incumbent_and_counts() {
        let w = zoo::vgg16();
        let hw = load_config(&repo_root(), "large").unwrap();
        let progress = Arc::new(SearchProgress::new());
        let ctx = EvalCtx { progress: Some(Arc::clone(&progress)),
                            ..Default::default() };
        let mut inc = Incumbent::with_ctx(&w, &hw, &ctx);
        let snap0 = progress.snapshot();
        assert!(snap0.best_edp.is_none());
        assert_eq!(snap0.evals, 0);
        let s = Strategy::trivial(&w);
        let edp = inc.offer(&s, 0);
        inc.note_iters(1);
        let snap1 = progress.snapshot();
        assert_eq!(snap1.best_edp, Some(edp));
        assert_eq!(snap1.evals, 1);
        assert_eq!(snap1.iters, 1);
        assert!(snap1.seq > snap0.seq, "watch-worthy changes bump seq");
        // an infeasible offer moves evals but not the incumbent
        let mut bad = s.clone();
        bad.mappings[0].factors[1][3] = 64;
        inc.offer(&bad, 1);
        let snap2 = progress.snapshot();
        assert_eq!(snap2.best_edp, Some(edp));
        assert_eq!(snap2.evals, 2);
    }

    #[test]
    fn deadline_stops_the_loop_and_latches_hit() {
        let w = zoo::vgg16();
        let hw = load_config(&repo_root(), "large").unwrap();
        let ctx = EvalCtx { deadline: Some(Deadline::in_ms(1)),
                            ..Default::default() };
        let inc = Incumbent::with_ctx(&w, &hw, &ctx);
        let budget = Budget::seconds(1e9);
        assert!(!ctx.deadline.as_ref().unwrap().was_hit());
        std::thread::sleep(Duration::from_millis(5));
        assert!(inc.stopped(&budget),
                "expired deadline stops the search loop");
        assert!(ctx.deadline.as_ref().unwrap().was_hit(),
                "the poll latched the hit flag for the supervisor");
        // without a deadline the same budget keeps running
        let free = Incumbent::new(&w, &hw);
        assert!(!free.stopped(&budget));
    }

    #[test]
    fn repeat_offers_hit_the_engine_cache() {
        let w = zoo::vgg16();
        let hw = load_config(&repo_root(), "large").unwrap();
        let mut inc = Incumbent::new(&w, &hw);
        let s = Strategy::trivial(&w);
        inc.offer(&s, 0);
        inc.offer(&s, 1);
        inc.offer(&s, 2);
        assert_eq!(inc.engine.cache_misses(), 1);
        assert_eq!(inc.engine.cache_hits(), 2);
        assert_eq!(inc.evals, 3, "offers still count as evals");
    }
}
