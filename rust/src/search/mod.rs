//! Search algorithms over the joint mapping x fusion space:
//!
//! * [`gradient`] — FADiff itself: constrained gradient descent (Adam)
//!   over the continuous relaxation, driving the AOT `fadiff_grad`
//!   artifact through PJRT, with tau/lambda annealing and decode-time
//!   repair. DOSA (layer-wise, MICRO'23) is the same engine with fusion
//!   disabled.
//! * [`ga`] — the heuristic baseline (tournament GA, paper ref [16]).
//! * [`bo`] — the learning-based baseline (GP + expected improvement,
//!   paper ref [15]) on top of [`gp`].
//! * [`random`] — uniform random sampling (sanity floor).

pub mod bo;
pub mod encoding;
pub mod ga;
pub mod gp;
pub mod gradient;
pub mod random;

use std::time::Instant;

use crate::config::HwConfig;
use crate::costmodel;
use crate::mapping::Strategy;
use crate::workload::Workload;

/// Common search budget: wall-clock (the paper compares equal time) and
/// an iteration cap as a secondary bound.
#[derive(Clone, Copy, Debug)]
pub struct Budget {
    pub seconds: f64,
    pub max_iters: usize,
}

impl Budget {
    pub fn seconds(seconds: f64) -> Budget {
        Budget { seconds, max_iters: usize::MAX }
    }

    pub fn iters(max_iters: usize) -> Budget {
        Budget { seconds: f64::INFINITY, max_iters }
    }
}

/// One point of the optimization trace (Fig 4: EDP vs time).
#[derive(Clone, Copy, Debug)]
pub struct TracePoint {
    pub seconds: f64,
    pub best_edp: f64,
    pub iter: usize,
}

/// Search outcome: best feasible strategy + its native evaluation +
/// the convergence trace.
#[derive(Clone, Debug)]
pub struct SearchResult {
    pub best: Strategy,
    pub edp: f64,
    pub energy: f64,
    pub latency: f64,
    pub trace: Vec<TracePoint>,
    pub iters: usize,
    pub evals: usize,
}

impl SearchResult {
    /// EDP scaled to the full model (replica^2).
    pub fn full_model_edp(&self, w: &Workload) -> f64 {
        self.edp * w.replicas * w.replicas
    }
}

/// Incumbent tracker shared by all searches: keeps the best *feasible*
/// strategy and the (time, edp) trace.
pub struct Incumbent<'a> {
    w: &'a Workload,
    hw: &'a HwConfig,
    start: Instant,
    pub best: Option<(Strategy, f64, f64, f64)>,
    pub trace: Vec<TracePoint>,
    pub evals: usize,
}

impl<'a> Incumbent<'a> {
    pub fn new(w: &'a Workload, hw: &'a HwConfig) -> Incumbent<'a> {
        Incumbent { w, hw, start: Instant::now(), best: None,
                    trace: Vec::new(), evals: 0 }
    }

    pub fn elapsed(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Evaluate natively; record if feasible and better. Returns the EDP
    /// (infinite when infeasible).
    pub fn offer(&mut self, s: &Strategy, iter: usize) -> f64 {
        self.evals += 1;
        if costmodel::feasible(s, self.w, self.hw).is_err() {
            return f64::INFINITY;
        }
        let r = costmodel::evaluate(s, self.w, self.hw);
        let better = self
            .best
            .as_ref()
            .map_or(true, |&(_, best_edp, _, _)| r.edp < best_edp);
        if better {
            self.best = Some((s.clone(), r.edp, r.energy, r.latency));
            self.trace.push(TracePoint {
                seconds: self.elapsed(),
                best_edp: r.edp,
                iter,
            });
        }
        r.edp
    }

    /// Finish; seeds with the always-feasible trivial strategy if no
    /// feasible candidate was ever offered.
    pub fn finish(mut self, iters: usize) -> SearchResult {
        if self.best.is_none() {
            let s = Strategy::trivial(self.w);
            self.offer(&s, iters);
        }
        let evals = self.evals;
        let (best, edp, energy, latency) = self.best.unwrap();
        SearchResult { best, edp, energy, latency, trace: self.trace,
                       iters, evals }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{load_config, repo_root};
    use crate::workload::zoo;

    #[test]
    fn incumbent_tracks_best() {
        let w = zoo::vgg16();
        let hw = load_config(&repo_root(), "large").unwrap();
        let mut inc = Incumbent::new(&w, &hw);
        let s = Strategy::trivial(&w);
        let edp1 = inc.offer(&s, 0);
        assert!(edp1.is_finite());
        // fusing a legal edge on the trivial mapping improves EDP
        let mut s2 = s.clone();
        s2.fuse[0] = true;
        let edp2 = inc.offer(&s2, 1);
        assert!(edp2 < edp1);
        let r = inc.finish(2);
        assert_eq!(r.edp, edp2);
        assert_eq!(r.trace.len(), 2);
        assert!(r.trace[0].best_edp >= r.trace[1].best_edp);
    }

    #[test]
    fn infeasible_offer_is_rejected() {
        let w = zoo::vgg16();
        let hw = load_config(&repo_root(), "large").unwrap();
        let mut inc = Incumbent::new(&w, &hw);
        let mut s = Strategy::trivial(&w);
        s.mappings[0].factors[1][3] = 64; // spatial overflow
        assert!(inc.offer(&s, 0).is_infinite());
        let r = inc.finish(1); // falls back to trivial
        assert!(r.edp.is_finite());
    }
}
