//! Shared continuous encoding of the joint mapping x fusion space used
//! by the black-box baselines (GA, BO, random): a unit-cube vector per
//! strategy, decoded through the same projection/repair pipeline as the
//! gradient search — all methods explore the identical design space
//! (the paper's "same search spaces" protocol, Sec 4.3.1).

use crate::config::HwConfig;
use crate::costmodel::WorkloadTables;
use crate::mapping::decode::{decode_with, Relaxed};
use crate::mapping::Strategy;
use crate::workload::{Workload, NDIMS};

/// Vector dimensionality for a workload.
pub fn dim(w: &Workload) -> usize {
    w.len() * NDIMS * 4 + w.fusible.len()
}

/// Decode a unit-cube vector into a hardware-valid strategy
/// (standalone: builds the workload tables for this one call).
pub fn express(x: &[f64], w: &Workload, hw: &HwConfig) -> Strategy {
    express_with(x, w, hw, &WorkloadTables::new(w))
}

/// [`express`] over shared precomputed tables (the BO hot path — one
/// [`WorkloadTables`] per search instead of one factorization sweep
/// per candidate; `EvalEngine::tables` provides it).
pub fn express_with(x: &[f64], w: &Workload, hw: &HwConfig,
                    tables: &WorkloadTables) -> Strategy {
    let mut relaxed = Relaxed::neutral(w);
    for l in 0..w.len() {
        for d in 0..NDIMS {
            let cap = (w.layers[l].dims[d] as f64).log2().max(0.0);
            for s in 0..4 {
                let u = x[(l * NDIMS + d) * 4 + s].clamp(0.0, 1.0);
                relaxed.theta[l][d][s] = u * (cap + 0.5) - 0.25;
            }
        }
    }
    let base = w.len() * NDIMS * 4;
    for i in 0..relaxed.sigma.len() {
        relaxed.sigma[i] = x[base + i].clamp(0.0, 1.0);
    }
    decode_with(&relaxed, w, hw, tables)
}


/// Inverse of the unit-cube encoding for a hardware-valid strategy:
/// a genome that re-expresses (through [`express_naive`]) to the same
/// strategy, because every stored factor is an exact divisor of its
/// dim and the nearest-divisor snap at distance zero is unique. Used
/// to inject warm-start library seeds into GA populations.
pub fn encode_strategy(s: &Strategy, w: &Workload) -> Vec<f64> {
    let mut x = vec![0.0f64; dim(w)];
    for l in 0..w.len().min(s.mappings.len()) {
        for d in 0..NDIMS {
            let cap = (w.layers[l].dims[d] as f64).log2().max(0.0);
            for slot in 0..4 {
                let f = s.mappings[l].factors[d][slot].max(1) as f64;
                let u = (f.log2() + 0.25) / (cap + 0.5);
                x[(l * NDIMS + d) * 4 + slot] = u.clamp(0.0, 1.0);
            }
        }
    }
    let base = w.len() * NDIMS * 4;
    for i in 0..w.fusible.len() {
        let on = s.fuse.get(i).copied().unwrap_or(false);
        x[base + i] = if on { 1.0 } else { 0.0 };
    }
    x
}

/// Naive legalization used by the heuristic GA baseline: the same
/// unit-cube genes, but WITHOUT FADiff's snap-then-trim decode and
/// sigma-ordered capacity repair (those embody the paper's contribution
/// and would launder its advantage into the baseline). Each slot snaps
/// to the nearest divisor independently; a dimension whose slot product
/// overflows is reset to DRAM-only; a layer that overflows a buffer is
/// reset to the trivial mapping; a fusion group that overflows drops all
/// its edges.
pub fn express_naive(x: &[f64], w: &Workload, hw: &HwConfig) -> Strategy {
    express_naive_with(x, w, hw, &WorkloadTables::new(w))
}

/// [`express_naive`] over shared precomputed tables (the GA hot path).
pub fn express_naive_with(x: &[f64], w: &Workload, hw: &HwConfig,
                          tables: &WorkloadTables) -> Strategy {
    use crate::mapping::{LayerMapping, SLOT_S};
    use crate::workload::{DIM_C, DIM_K};

    let mut mappings = Vec::with_capacity(w.len());
    for l in 0..w.len() {
        let mut m = LayerMapping::trivial();
        for d in 0..NDIMS {
            let n = w.layers[l].dims[d] as u64;
            let divs = &tables.dim(l, d).divisors;
            let cap = (n as f64).log2().max(0.0);
            for s in 0..4 {
                let u = x[(l * NDIMS + d) * 4 + s].clamp(0.0, 1.0);
                let target = (u * (cap + 0.5) - 0.25).exp2();
                let limit = if s == SLOT_S {
                    match d {
                        DIM_K => hw.pe_cols as u64,
                        DIM_C => hw.pe_rows as u64,
                        _ => 1,
                    }
                } else {
                    u64::MAX
                };
                m.factors[d][s] = divs
                    .iter()
                    .copied()
                    .filter(|&f| f <= limit)
                    .min_by(|&a, &b| {
                        let da = (a as f64 - target).abs();
                        let db = (b as f64 - target).abs();
                        da.partial_cmp(&db).unwrap()
                    })
                    .unwrap_or(1);
            }
            // naive overflow handling: product must divide n, else DRAM
            if n % m.inner(d) != 0 || m.inner(d) > n {
                let sp = m.factors[d][SLOT_S];
                m.factors[d] = [1, 1, 1, if n % sp == 0 { sp } else { 1 }];
            }
        }
        // per-layer capacity: reset to trivial when overflowing
        let c = crate::costmodel::components(&m, &w.layers[l].dims);
        if (c.s_w2 + c.s_i2) * hw.element_bytes > hw.c2_bytes
            || c.s_o1 * hw.acc_bytes > hw.c1_bytes
        {
            m = LayerMapping::trivial();
        }
        mappings.push(m);
    }
    let base = w.len() * NDIMS * 4;
    let mut fuse: Vec<bool> = (0..w.fusible.len())
        .map(|i| w.fusible[i] && x[base + i] > 0.5)
        .collect();
    // naive group repair: drop every edge of an overflowing group
    loop {
        let s = Strategy { mappings: mappings.clone(), fuse: fuse.clone() };
        let mut bad = None;
        for (a, b) in s.groups() {
            if a == b {
                continue;
            }
            let req: f64 = (a..=b)
                .map(|i| {
                    let c = crate::costmodel::components(
                        &mappings[i], &w.layers[i].dims);
                    (c.s_w2 + c.s_i2) * hw.element_bytes
                })
                .sum();
            if req > hw.c2_bytes {
                bad = Some((a, b));
                break;
            }
        }
        match bad {
            None => break,
            Some((a, b)) => {
                for i in a..b {
                    fuse[i] = false;
                }
            }
        }
    }
    Strategy { mappings, fuse }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{load_config, repo_root};
    use crate::util::rng::Rng;
    use crate::workload::zoo;

    #[test]
    fn express_naive_always_feasible() {
        let hw = load_config(&repo_root(), "large").unwrap();
        let mut rng = Rng::new(23);
        for w in zoo::table1_suite() {
            let d = dim(&w);
            for _ in 0..10 {
                let x: Vec<f64> = (0..d).map(|_| rng.f64()).collect();
                let s = express_naive(&x, &w, &hw);
                crate::costmodel::feasible(&s, &w, &hw).unwrap();
            }
        }
    }

    #[test]
    fn encode_strategy_roundtrips_through_naive_expression() {
        let hw = load_config(&repo_root(), "large").unwrap();
        let mut rng = Rng::new(7);
        for w in zoo::table1_suite() {
            let x: Vec<f64> = (0..dim(&w)).map(|_| rng.f64()).collect();
            let s = express_naive(&x, &w, &hw);
            let s2 = express_naive(&encode_strategy(&s, &w), &w, &hw);
            assert_eq!(s.mappings, s2.mappings, "{}", w.name);
            assert_eq!(s.fuse, s2.fuse, "{}", w.name);
        }
    }

    #[test]
    fn express_always_feasible() {
        let hw = load_config(&repo_root(), "large").unwrap();
        let mut rng = Rng::new(17);
        for w in zoo::table1_suite() {
            let d = dim(&w);
            for _ in 0..10 {
                let x: Vec<f64> = (0..d).map(|_| rng.f64()).collect();
                let s = express(&x, &w, &hw);
                crate::costmodel::feasible(&s, &w, &hw).unwrap();
            }
        }
    }
}
