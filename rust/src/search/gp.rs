//! Gaussian-process substrate for the BO baseline: RBF kernel, Cholesky
//! factorization, posterior mean/variance, log expected improvement.
//! Hand-rolled dense linear algebra (no external crates offline).

/// Dense symmetric positive-definite solver via Cholesky.
pub struct Cholesky {
    l: Vec<f64>,
    n: usize,
}

impl Cholesky {
    /// Factor A (row-major n x n). Returns None if not SPD.
    pub fn new(a: &[f64], n: usize) -> Option<Cholesky> {
        let mut l = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..=i {
                let mut sum = a[i * n + j];
                for k in 0..j {
                    sum -= l[i * n + k] * l[j * n + k];
                }
                if i == j {
                    if sum <= 0.0 {
                        return None;
                    }
                    l[i * n + i] = sum.sqrt();
                } else {
                    l[i * n + j] = sum / l[j * n + j];
                }
            }
        }
        Some(Cholesky { l, n })
    }

    /// Solve A x = b.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.n;
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut sum = b[i];
            for k in 0..i {
                sum -= self.l[i * n + k] * y[k];
            }
            y[i] = sum / self.l[i * n + i];
        }
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut sum = y[i];
            for k in (i + 1)..n {
                sum -= self.l[k * n + i] * x[k];
            }
            x[i] = sum / self.l[i * n + i];
        }
        x
    }

    /// Solve L v = b (forward substitution only).
    pub fn forward(&self, b: &[f64]) -> Vec<f64> {
        let n = self.n;
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut sum = b[i];
            for k in 0..i {
                sum -= self.l[i * n + k] * y[k];
            }
            y[i] = sum / self.l[i * n + i];
        }
        y
    }
}

/// RBF (squared-exponential) kernel.
pub fn rbf(a: &[f64], b: &[f64], lengthscale: f64, variance: f64) -> f64 {
    let mut d2 = 0.0;
    for i in 0..a.len() {
        let d = a[i] - b[i];
        d2 += d * d;
    }
    variance * (-0.5 * d2 / (lengthscale * lengthscale)).exp()
}

/// A fitted GP posterior over observed (x, y) pairs.
pub struct Gp {
    xs: Vec<Vec<f64>>,
    alpha: Vec<f64>,
    chol: Cholesky,
    lengthscale: f64,
    variance: f64,
    y_mean: f64,
    y_std: f64,
}

impl Gp {
    /// Fit with fixed hyper-parameters + jitter; y standardized
    /// internally.
    pub fn fit(xs: &[Vec<f64>], ys: &[f64], lengthscale: f64,
               noise: f64) -> Option<Gp> {
        let n = xs.len();
        if n == 0 {
            return None;
        }
        let y_mean = ys.iter().sum::<f64>() / n as f64;
        let y_std = (ys.iter().map(|y| (y - y_mean).powi(2)).sum::<f64>()
            / n as f64)
            .sqrt()
            .max(1e-12);
        let yn: Vec<f64> = ys.iter().map(|y| (y - y_mean) / y_std).collect();
        let variance = 1.0;
        let mut k = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                k[i * n + j] = rbf(&xs[i], &xs[j], lengthscale, variance);
                if i == j {
                    k[i * n + j] += noise + 1e-8;
                }
            }
        }
        let chol = Cholesky::new(&k, n)?;
        let alpha = chol.solve(&yn);
        Some(Gp {
            xs: xs.to_vec(),
            alpha,
            chol,
            lengthscale,
            variance,
            y_mean,
            y_std,
        })
    }

    /// Posterior mean and variance at x (in original y units).
    pub fn predict(&self, x: &[f64]) -> (f64, f64) {
        let n = self.xs.len();
        let kx: Vec<f64> = (0..n)
            .map(|i| rbf(&self.xs[i], x, self.lengthscale, self.variance))
            .collect();
        let mean_n: f64 =
            kx.iter().zip(&self.alpha).map(|(a, b)| a * b).sum();
        let v = self.chol.forward(&kx);
        let var_n = (self.variance - v.iter().map(|x| x * x).sum::<f64>())
            .max(1e-12);
        (mean_n * self.y_std + self.y_mean,
         var_n * self.y_std * self.y_std)
    }

    /// Expected improvement (minimization) at x given the best observed y.
    pub fn expected_improvement(&self, x: &[f64], best: f64) -> f64 {
        let (mu, var) = self.predict(x);
        let sd = var.sqrt();
        if sd < 1e-12 {
            return 0.0;
        }
        let z = (best - mu) / sd;
        let (pdf, cdf) = phi(z);
        (best - mu) * cdf + sd * pdf
    }
}

/// Standard normal pdf + cdf (Abramowitz–Stegun erf approximation).
fn phi(z: f64) -> (f64, f64) {
    let pdf = (-0.5 * z * z).exp() / (2.0 * std::f64::consts::PI).sqrt();
    let cdf = 0.5 * (1.0 + erf(z / std::f64::consts::SQRT_2));
    (pdf, cdf)
}

fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t
            - 0.284496736)
            * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cholesky_solves_spd() {
        // A = [[4,2],[2,3]], b = [2, 5] => x = [-0.5, 2]
        let a = [4.0, 2.0, 2.0, 3.0];
        let ch = Cholesky::new(&a, 2).unwrap();
        let x = ch.solve(&[2.0, 5.0]);
        assert!((x[0] + 0.5).abs() < 1e-12, "{x:?}");
        assert!((x[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = [1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        assert!(Cholesky::new(&a, 2).is_none());
    }

    #[test]
    fn gp_interpolates_observations() {
        let xs: Vec<Vec<f64>> =
            vec![vec![0.0], vec![0.5], vec![1.0], vec![1.5]];
        let ys = vec![1.0, 0.2, -0.3, 0.4];
        let gp = Gp::fit(&xs, &ys, 0.4, 1e-6).unwrap();
        for (x, y) in xs.iter().zip(&ys) {
            let (mu, var) = gp.predict(x);
            assert!((mu - y).abs() < 1e-2, "{mu} vs {y}");
            assert!(var < 1e-2);
        }
        // far away reverts to prior with much higher variance than at
        // the observations (variance is in original y units)
        let (_, var_near) = gp.predict(&xs[0]);
        let (_, var_far) = gp.predict(&[10.0]);
        assert!(var_far > 10.0 * var_near, "{var_far} vs {var_near}");
    }

    #[test]
    fn ei_positive_where_uncertain_zero_where_known_bad() {
        let xs = vec![vec![0.0], vec![1.0]];
        let ys = vec![0.0, 1.0];
        let gp = Gp::fit(&xs, &ys, 0.3, 1e-6).unwrap();
        let ei_mid = gp.expected_improvement(&[0.5], 0.0);
        let ei_known = gp.expected_improvement(&[1.0], 0.0);
        assert!(ei_mid > ei_known);
        assert!(ei_mid > 0.0);
    }

    #[test]
    fn erf_reference_values() {
        // Abramowitz–Stegun 7.1.26 is accurate to ~1.5e-7
        assert!((erf(0.0)).abs() < 1e-6);
        assert!((erf(1.0) - 0.8427).abs() < 1e-3);
        assert!((erf(-1.0) + 0.8427).abs() < 1e-3);
    }
}
