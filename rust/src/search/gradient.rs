//! The FADiff optimizer (paper Sec 3.3): constrained gradient descent
//! on the continuous relaxation.
//!
//! Per step: sample Gumbel noise, evaluate loss + gradients of the
//! relaxed cost model, apply an Adam update. The Gumbel-Softmax
//! temperature anneals `tau0 -> tau_min` geometrically and the penalty
//! weight lambda ramps up, exactly as Sec 3.1.1/3.3 describe. The
//! incumbent is refreshed by decoding the relaxed state (Sec 3.1's
//! continuous-to-discrete projection + capacity repair) and evaluating
//! natively through the search's `EvalEngine`.
//!
//! Two interchangeable backends compute the loss/gradient step:
//!
//! * **Native** (the default, always available) — the pure-Rust
//!   forward + reverse model in [`crate::costmodel::grad`], f64, zero
//!   allocation per step. Selected whenever no runtime is passed.
//!   Restarts run as **parallel chains**: `C` independent Adam chains
//!   (one per restart, or [`GradientConfig::chains`]) live in a single
//!   SoA `ChainBatch` and step concurrently across the worker
//!   threads — each chain gets the *full* iteration schedule instead
//!   of `budget / restarts`, with deterministic per-chain RNG streams
//!   (`seed ^ splitmix(chain)`), so results are bit-identical for any
//!   worker count. Incumbent refresh is batched: every chain banks its
//!   relaxed snapshot and one engine pass decodes + scores all of them
//!   (threshold + fusion-greedy variants) in a single SoA sweep. Once
//!   the lambda ramp passes [`CULL_RAMP_THRESHOLD`], the worst half of
//!   the chains (by most recent relaxed loss) periodically respawn as
//!   jittered clones of the best chain — a cheap exploit/explore
//!   schedule that costs nothing serial.
//! * **PJRT** (optional accelerator) — the AOT `fadiff_grad` artifact
//!   executed via PJRT, exactly as before: serial round-robin restarts
//!   splitting the budget. Callers probe it with
//!   [`Runtime::load_if_available`] and pass `Some(rt)`; environments
//!   without artifacts pass `None` and lose nothing but the
//!   accelerator.
//!
//! The DOSA baseline (layer-wise gradient, MICRO'23 [8]) is this same
//! engine with `fuse_enabled = false`: sigma is pinned to 0 via the edge
//! mask, which makes the loss separable per layer — i.e. exactly
//! layer-independent mapping search.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::config::HwConfig;
use crate::costmodel::grad::{GradModel, SnapMode};
use crate::costmodel::tables::WorkloadTables;
use crate::mapping::decode::{decode_with, fusion_greedy, Relaxed};
use crate::mapping::Strategy;
use crate::runtime::stage::WorkloadStage;
use crate::runtime::{HostTensor, Runtime, ART_GRAD};
use crate::util::rng::{GumbelPool, Rng};
use crate::util::threadpool::par_map;
use crate::workload::{Workload, NDIMS};

use super::{Budget, Deadline, EvalCtx, Incumbent, SearchResult};

/// Lambda-ramp progress after which the chain cull/respawn schedule
/// engages (the exploit phase of the native multi-chain optimizer).
pub const CULL_RAMP_THRESHOLD: f64 = 0.5;

/// Decode blocks between cull/respawn passes.
const CULL_EVERY_BLOCKS: usize = 4;

/// Respawn jitter scale (log2-space theta / logit-space sigma).
const RESPAWN_JITTER: f64 = 0.3;

/// Hyper-parameters of the gradient search.
#[derive(Clone, Debug)]
pub struct GradientConfig {
    /// Adam learning rate for theta (log2 tiling factors).
    pub lr: f64,
    /// Adam learning rate for the fusion logits.
    pub lr_sigma: f64,
    /// Initial Gumbel-Softmax temperature.
    pub tau0: f64,
    /// Temperature floor.
    pub tau_min: f64,
    /// Geometric tau decay per step.
    pub tau_decay: f64,
    /// Proximity sharpness of the snap logits (Eq. 1).
    pub alpha: f64,
    /// Penalty weight at ramp start.
    pub lambda0: f64,
    /// Penalty weight at full ramp.
    pub lambda_max: f64,
    /// Steps between incumbent refresh (decode + native eval).
    pub decode_every: usize,
    /// PRNG seed (chain 0 uses it verbatim; chain c derives its own
    /// stream — see `chain_seed`).
    pub seed: u64,
    /// false => DOSA mode (no fusion, layer-wise objective).
    pub fuse_enabled: bool,
    /// Adam first-moment decay.
    pub beta1: f64,
    /// Adam second-moment decay.
    pub beta2: f64,
    /// Restart count. The native backend runs one *parallel chain* per
    /// restart, each with the full iteration schedule (which is why the
    /// default is now 8 — parallel chains are nearly free on a
    /// multicore); the PJRT backend keeps the historical serial
    /// round-robin budget split.
    pub restarts: usize,
    /// Explicit parallel-chain count for the native backend. `0` (the
    /// default) derives the count from `restarts`; any positive value
    /// overrides it. Exposed as the coordinator's `chains` parameter.
    pub chains: usize,
}

impl Default for GradientConfig {
    fn default() -> Self {
        GradientConfig {
            lr: 0.08,
            lr_sigma: 0.15,
            tau0: 2.0,
            tau_min: 0.05,
            tau_decay: 0.995,
            alpha: 2.0,
            lambda0: 0.1,
            lambda_max: 10.0,
            decode_every: 10,
            seed: 0xFAD1FF,
            fuse_enabled: true,
            beta1: 0.9,
            beta2: 0.999,
            restarts: 8,
            chains: 0,
        }
    }
}

impl GradientConfig {
    /// The DOSA (layer-wise) ablation of this optimizer.
    pub fn dosa() -> GradientConfig {
        GradientConfig { fuse_enabled: false, ..Default::default() }
    }

    /// Effective native chain count: `chains` when set, else one chain
    /// per restart.
    pub fn chain_count(&self) -> usize {
        if self.chains > 0 { self.chains } else { self.restarts.max(1) }
    }
}

struct Adam {
    m: Vec<f64>,
    v: Vec<f64>,
    t: usize,
    beta1: f64,
    beta2: f64,
}

impl Adam {
    fn new(n: usize, beta1: f64, beta2: f64) -> Adam {
        Adam { m: vec![0.0; n], v: vec![0.0; n], t: 0, beta1, beta2 }
    }

    fn step(&mut self, params: &mut [f64], grads: &[f64], lr: f64) {
        self.t += 1;
        adam_update(params, grads, &mut self.m, &mut self.v, self.t,
                    lr, self.beta1, self.beta2);
    }
}

/// One bias-corrected Adam update over borrowed moment buffers (the
/// chain batch stores moments as SoA strides, so the update is a free
/// function shared with the legacy [`Adam`] holder).
#[allow(clippy::too_many_arguments)]
fn adam_update(params: &mut [f64], grads: &[f64], m: &mut [f64],
               v: &mut [f64], t: usize, lr: f64, beta1: f64,
               beta2: f64) {
    let b1c = 1.0 - beta1.powi(t as i32);
    let b2c = 1.0 - beta2.powi(t as i32);
    for i in 0..params.len() {
        let g = grads[i];
        if !g.is_finite() {
            continue;
        }
        m[i] = beta1 * m[i] + (1.0 - beta1) * g;
        v[i] = beta2 * v[i] + (1.0 - beta2) * g * g;
        let mhat = m[i] / b1c;
        let vhat = v[i] / b2c;
        params[i] -= lr * mhat / (vhat.sqrt() + 1e-8);
    }
}

/// Initialize theta near hardware-sensible priors: spatial at the array
/// limits, modest on-chip temporal tiles, rest at DRAM.
fn init_theta(w: &Workload, hw: &HwConfig, rng: &mut Rng, l_max: usize)
              -> Vec<f64> {
    use crate::workload::{DIM_C, DIM_K};
    let mut theta = vec![0.0f64; l_max * NDIMS * 4];
    for (l, layer) in w.layers.iter().enumerate() {
        for d in 0..NDIMS {
            let n = layer.dims[d] as f64;
            let cap = n.log2();
            for s in 0..4 {
                let base = match (d, s) {
                    (DIM_K, 3) => (hw.pe_cols as f64).log2(),
                    (DIM_C, 3) => (hw.pe_rows as f64).log2(),
                    (_, 3) => 0.0,
                    (_, 2) => (cap / 3.0).min(4.0), // L2 tile
                    (_, 1) => (cap / 4.0).min(2.0),
                    _ => (cap / 6.0).min(1.0),
                };
                let jitter = rng.normal() * 0.35;
                theta[(l * NDIMS + d) * 4 + s] =
                    (base + jitter).clamp(-1.0, cap.max(0.0) + 0.5);
            }
        }
    }
    theta
}

/// Penalty-ramp progress in [0, 1]. An explicit iteration cap defines
/// the annealing schedule alone — mixing in wall-clock progress would
/// make iteration-budgeted runs (and identical-seed serving jobs)
/// timing-dependent, breaking the multi-chain determinism contract.
/// Under *pure* seconds budgets `max_iters` is unbounded, the
/// iteration fraction stays ~0 and the lambda ramp of Sec 3.1.1 would
/// never engage (penalties stuck at `lambda0` for the whole run), so
/// there the wall-clock fraction drives it instead.
///
/// Contract for mixed budgets (both bounds finite): `max_iters` owns
/// the annealing schedule and `seconds` acts as a plain timeout. Set
/// `max_iters` near the step count you expect to complete; a cap set
/// orders of magnitude above what the timeout allows leaves the ramp
/// partly unengaged when the clock fires first. (The alternative —
/// blending wall-clock in — was rejected: once the clock feeds
/// lambda, two identical iteration-bound requests diverge bit-wise at
/// step 0, and when the clock genuinely binds the run is
/// timing-dependent either way. Decodes always repair to feasible
/// strategies regardless of how far the ramp got.)
fn ramp_progress(it: usize, per_restart: usize, elapsed: f64,
                 budget: &Budget) -> f64 {
    let by_iter = it as f64 / per_restart.max(1) as f64;
    let by_time = if budget.max_iters == usize::MAX
        && budget.seconds.is_finite()
    {
        elapsed / budget.seconds.max(1e-9)
    } else {
        0.0
    };
    by_iter.max(by_time).min(1.0)
}

/// Tau at a given lockstep step index: `tau0 * decay^it`, floored at
/// `tau_min`. A pure function of the step so respawned chains stay on
/// the shared annealing schedule.
fn tau_at(cfg: &GradientConfig, it: usize) -> f64 {
    (cfg.tau0 * cfg.tau_decay.powi(it.min(i32::MAX as usize) as i32))
        .max(cfg.tau_min)
}

/// Clamp parameters into the numerically safe box the optimizer
/// explores (theta per-dim capped at the problem size, sigma bounded).
fn clamp_params(theta: &mut [f64], sigma: &mut [f64], w: &Workload) {
    for (l, layer) in w.layers.iter().enumerate() {
        for d in 0..NDIMS {
            let cap = (layer.dims[d] as f64).log2().max(0.0) + 0.5;
            for s in 0..4 {
                let idx = (l * NDIMS + d) * 4 + s;
                theta[idx] = theta[idx].clamp(-2.0, cap);
            }
        }
    }
    for s in sigma.iter_mut() {
        *s = s.clamp(-8.0, 8.0);
    }
}

/// Deterministic per-chain seed stream: SplitMix-mixed chain id XORed
/// onto the search seed (chain 0 keeps the seed itself, preserving the
/// historical single-restart trajectory).
fn chain_seed(seed: u64, chain: usize) -> u64 {
    seed ^ (chain as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Shared stop/ramp context polled by the chain workers: wall-clock
/// budget, cooperative cancellation and deadline (the serving
/// layer's `EvalCtx` seam), and the lambda-ramp progress.
struct ChainStop {
    start: Instant,
    budget: Budget,
    cancel: Option<Arc<AtomicBool>>,
    deadline: Option<Deadline>,
}

impl ChainStop {
    fn new(budget: Budget, ctx: &EvalCtx) -> ChainStop {
        ChainStop {
            start: Instant::now(),
            budget,
            cancel: ctx.cancel.clone(),
            deadline: ctx.deadline.clone(),
        }
    }

    fn elapsed(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    fn stopped(&self) -> bool {
        self.cancel
            .as_ref()
            .is_some_and(|c| c.load(Ordering::SeqCst))
            || self.deadline.as_ref().is_some_and(|d| d.expired())
            || self.elapsed() >= self.budget.seconds
    }

    fn ramp(&self, it: usize, per_chain: usize) -> f64 {
        ramp_progress(it, per_chain, self.elapsed(), &self.budget)
    }
}

/// SoA state of `C` concurrent Adam chains. Every per-chain buffer
/// (theta, sigma logits, first/second Adam moments, gradient and
/// Gumbel scratch) is a contiguous stride of one flat vector; the
/// strides are carved into disjoint [`ChainView`]s for the worker
/// threads each block, so chains mutate in parallel with no locks and
/// no allocation per step.
struct ChainBatch {
    c_n: usize,
    n_theta: usize,
    n_sigma: usize,
    theta: Vec<f64>,
    sigma: Vec<f64>,
    m_t: Vec<f64>,
    v_t: Vec<f64>,
    m_s: Vec<f64>,
    v_s: Vec<f64>,
    g_theta: Vec<f64>,
    g_sigma: Vec<f64>,
    gumbel: Vec<f64>,
    adam_t: Vec<usize>,
    /// Relaxed loss at each chain's most recent step (the cull key).
    last_loss: Vec<f64>,
    rng: Vec<Rng>,
}

/// One chain's disjoint mutable window into the [`ChainBatch`] SoA
/// buffers, moved onto a worker thread for a block of steps.
struct ChainView<'a> {
    theta: &'a mut [f64],
    sigma: &'a mut [f64],
    m_t: &'a mut [f64],
    v_t: &'a mut [f64],
    m_s: &'a mut [f64],
    v_s: &'a mut [f64],
    g_theta: &'a mut [f64],
    g_sigma: &'a mut [f64],
    gumbel: &'a mut [f64],
    adam_t: &'a mut usize,
    last_loss: &'a mut f64,
    rng: &'a mut Rng,
}

/// Split `v` into `c_n` disjoint mutable strides of `n` elements.
fn carve(mut v: &mut [f64], n: usize, c_n: usize)
         -> Vec<&mut [f64]> {
    let mut out = Vec::with_capacity(c_n);
    for _ in 0..c_n {
        let (head, tail) = v.split_at_mut(n);
        out.push(head);
        v = tail;
    }
    out
}

impl ChainBatch {
    /// Initialize `c_n` chains: theta from the hardware prior under
    /// each chain's own seed stream, sigma mostly-unfused (~0.12 — a
    /// 0.5 init inflates the soft group-footprint scan and distorts
    /// mappings on small scratchpads even when fusion is eventually
    /// rejected).
    fn new(w: &Workload, hw: &HwConfig, cfg: &GradientConfig,
           model: &GradModel<'_>, c_n: usize) -> ChainBatch {
        let n_theta = model.n_theta();
        let n_sigma = model.n_sigma();
        let n_gumbel = model.n_gumbel();
        let mut theta = Vec::with_capacity(c_n * n_theta);
        let mut rng = Vec::with_capacity(c_n);
        for c in 0..c_n {
            let mut r = Rng::new(chain_seed(cfg.seed, c));
            theta.extend(init_theta(w, hw, &mut r, w.len()));
            rng.push(r);
        }
        ChainBatch {
            c_n,
            n_theta,
            n_sigma,
            theta,
            sigma: vec![-2.0; c_n * n_sigma],
            m_t: vec![0.0; c_n * n_theta],
            v_t: vec![0.0; c_n * n_theta],
            m_s: vec![0.0; c_n * n_sigma],
            v_s: vec![0.0; c_n * n_sigma],
            g_theta: vec![0.0; c_n * n_theta],
            g_sigma: vec![0.0; c_n * n_sigma],
            gumbel: vec![0.0; c_n * n_gumbel],
            adam_t: vec![0; c_n],
            last_loss: vec![f64::INFINITY; c_n],
            rng,
        }
    }

    fn theta_of(&self, c: usize) -> &[f64] {
        &self.theta[c * self.n_theta..(c + 1) * self.n_theta]
    }

    fn sigma_of(&self, c: usize) -> &[f64] {
        &self.sigma[c * self.n_sigma..(c + 1) * self.n_sigma]
    }

    /// Carve the SoA buffers into one disjoint view per chain.
    fn views(&mut self) -> Vec<ChainView<'_>> {
        let c_n = self.c_n;
        let n_gumbel = self.gumbel.len() / c_n.max(1);
        let mut theta = carve(&mut self.theta, self.n_theta, c_n);
        let mut sigma = carve(&mut self.sigma, self.n_sigma, c_n);
        let mut m_t = carve(&mut self.m_t, self.n_theta, c_n);
        let mut v_t = carve(&mut self.v_t, self.n_theta, c_n);
        let mut m_s = carve(&mut self.m_s, self.n_sigma, c_n);
        let mut v_s = carve(&mut self.v_s, self.n_sigma, c_n);
        let mut g_theta = carve(&mut self.g_theta, self.n_theta, c_n);
        let mut g_sigma = carve(&mut self.g_sigma, self.n_sigma, c_n);
        let mut gumbel = carve(&mut self.gumbel, n_gumbel, c_n);
        let mut adam_t: Vec<&mut usize> =
            self.adam_t.iter_mut().collect();
        let mut last_loss: Vec<&mut f64> =
            self.last_loss.iter_mut().collect();
        let mut rng: Vec<&mut Rng> = self.rng.iter_mut().collect();
        let mut out = Vec::with_capacity(c_n);
        for _ in 0..c_n {
            out.push(ChainView {
                theta: theta.pop().unwrap(),
                sigma: sigma.pop().unwrap(),
                m_t: m_t.pop().unwrap(),
                v_t: v_t.pop().unwrap(),
                m_s: m_s.pop().unwrap(),
                v_s: v_s.pop().unwrap(),
                g_theta: g_theta.pop().unwrap(),
                g_sigma: g_sigma.pop().unwrap(),
                gumbel: gumbel.pop().unwrap(),
                adam_t: adam_t.pop().unwrap(),
                last_loss: last_loss.pop().unwrap(),
                rng: rng.pop().unwrap(),
            });
        }
        out.reverse();
        out
    }

    /// Exploit/explore schedule: the worst half of the chains (by most
    /// recent relaxed loss, index-tie-broken) respawn as jittered
    /// clones of the best chain. Adam moments reset; the perturbation
    /// draws from each respawned chain's own RNG stream, so the
    /// outcome is identical for any worker count.
    fn cull_and_respawn(&mut self, w: &Workload) {
        let c_n = self.c_n;
        if c_n < 2 {
            return;
        }
        let mut order: Vec<usize> = (0..c_n).collect();
        order.sort_by(|&a, &b| {
            self.last_loss[a]
                .partial_cmp(&self.last_loss[b])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        let best = order[0];
        let nt = self.n_theta;
        let ns = self.n_sigma;
        for &c in &order[c_n - c_n / 2..] {
            self.theta.copy_within(best * nt..(best + 1) * nt, c * nt);
            self.sigma.copy_within(best * ns..(best + 1) * ns, c * ns);
            for buf in [&mut self.m_t, &mut self.v_t] {
                buf[c * nt..(c + 1) * nt].fill(0.0);
            }
            for buf in [&mut self.m_s, &mut self.v_s] {
                buf[c * ns..(c + 1) * ns].fill(0.0);
            }
            self.adam_t[c] = 0;
            let rng = &mut self.rng[c];
            for x in &mut self.theta[c * nt..(c + 1) * nt] {
                *x += rng.normal() * RESPAWN_JITTER;
            }
            for x in &mut self.sigma[c * ns..(c + 1) * ns] {
                *x += rng.normal() * RESPAWN_JITTER;
            }
            clamp_params(&mut self.theta[c * nt..(c + 1) * nt],
                         &mut self.sigma[c * ns..(c + 1) * ns], w);
            self.last_loss[c] = self.last_loss[best];
        }
    }
}

/// Advance one chain by up to `block` steps (fewer when the budget or
/// a cancellation stops it mid-block). Entirely chain-local: the only
/// shared state is immutable (model, Gumbel table) or monotone (the
/// stop flag), so results are bit-identical for any worker count. The
/// loss/gradient evaluation runs over a per-worker-thread scratch
/// ([`GradModel::loss_and_grad_pooled`]) — zero allocation per step.
#[allow(clippy::too_many_arguments)]
fn step_chain_block(view: &mut ChainView<'_>, model: &GradModel<'_>,
                    gumbel_pool: &GumbelPool, w: &Workload,
                    cfg: &GradientConfig, stop: &ChainStop,
                    start_it: usize, block: usize,
                    per_chain_iters: usize) -> usize {
    let mut done = 0usize;
    for k in 0..block {
        let it = start_it + k;
        if it >= per_chain_iters || stop.stopped() {
            break;
        }
        gumbel_pool.fill_f64(view.rng, view.gumbel);
        let tau = tau_at(cfg, it);
        let progress = stop.ramp(it, per_chain_iters);
        let lambda =
            cfg.lambda0 + (cfg.lambda_max - cfg.lambda0) * progress;
        let out = model.loss_and_grad_pooled(view.theta, view.sigma,
                                             view.gumbel, tau, lambda,
                                             view.g_theta,
                                             view.g_sigma);
        *view.adam_t += 1;
        adam_update(view.theta, view.g_theta, view.m_t, view.v_t,
                    *view.adam_t, cfg.lr, cfg.beta1, cfg.beta2);
        if cfg.fuse_enabled {
            adam_update(view.sigma, view.g_sigma, view.m_s, view.v_s,
                        *view.adam_t, cfg.lr_sigma, cfg.beta1,
                        cfg.beta2);
        }
        clamp_params(view.theta, view.sigma, w);
        *view.last_loss = out.loss;
        done += 1;
    }
    done
}

/// Bank every chain's relaxed snapshot and refresh the incumbent in
/// one batched engine pass: the threshold decode plus (in fusion mode)
/// the fusion-greedy variant per chain all decode on the worker
/// threads and score in a single `EvalEngine` SoA sweep, then the
/// offers land in fixed chain order — one deterministic trace
/// regardless of worker count.
#[allow(clippy::too_many_arguments)]
fn offer_chain_decodes(batch: &ChainBatch, w: &Workload, hw: &HwConfig,
                       cfg: &GradientConfig, inc: &mut Incumbent<'_>,
                       iter: usize, tables: &Arc<WorkloadTables>,
                       ctx: &EvalCtx) {
    let mut variants: Vec<Relaxed> =
        Vec::with_capacity(2 * batch.c_n);
    for c in 0..batch.c_n {
        let relaxed = relaxed_from(batch.theta_of(c), batch.sigma_of(c),
                                   w, cfg);
        let greedy = if cfg.fuse_enabled {
            Some(fusion_greedy(&relaxed, w))
        } else {
            None
        };
        variants.push(relaxed);
        if let Some(g) = greedy {
            variants.push(g);
        }
    }
    if ctx.prune.enabled() {
        // decode offers never feed back into the chain state, so
        // pruning candidates whose admissible bound meets the
        // incumbent leaves the search trajectory bit-identical
        let scored = inc.engine.eval_population_screened(
            &variants, |r| decode_with(r, w, hw, tables),
            inc.best_edp(), ctx.prune_stats());
        for (s, sc) in scored {
            inc.offer_screened(&s, sc, iter);
        }
    } else {
        let scored = inc.engine.eval_population(&variants, |r| {
            decode_with(r, w, hw, tables)
        });
        for (s, e) in scored {
            inc.offer_eval(&s, e, iter);
        }
    }
}

/// Overwrite chain `c`'s relaxed state with a warm-start strategy:
/// theta = log2(factor) (the decode snap reproduces the factors
/// exactly, they are divisors) and fusion logits pushed to +-2.0 so
/// the seeded decisions survive the 0.5 sigmoid threshold.
fn seed_chain(batch: &mut ChainBatch, c: usize, s: &Strategy,
              w: &Workload) {
    let nt = batch.n_theta;
    let ns = batch.n_sigma;
    let theta = &mut batch.theta[c * nt..(c + 1) * nt];
    let sigma = &mut batch.sigma[c * ns..(c + 1) * ns];
    for l in 0..w.len().min(s.mappings.len()) {
        for d in 0..NDIMS {
            for slot in 0..4 {
                let f = s.mappings[l].factors[d][slot].max(1) as f64;
                theta[(l * NDIMS + d) * 4 + slot] = f.log2();
            }
        }
    }
    for (i, logit) in
        sigma.iter_mut().enumerate().take(w.fusible.len())
    {
        let on = s.fuse.get(i).copied().unwrap_or(false);
        *logit = if on { 2.0 } else { -2.0 };
    }
    clamp_params(theta, sigma, w);
}

/// Run the FADiff (or DOSA) gradient search. `rt` selects the backend:
/// `Some` runs the AOT artifact on PJRT, `None` runs the pure-Rust
/// differentiable model — same optimizer, same annealing, same decode.
pub fn optimize(rt: Option<&Runtime>, w: &Workload, hw: &HwConfig,
                cfg: &GradientConfig, budget: Budget)
                -> Result<SearchResult> {
    optimize_ctx(rt, w, hw, cfg, budget, &EvalCtx::default())
}

/// [`optimize`] with a serving-layer context (shared cache / persistent
/// pool / cooperative cancellation for the incumbent refreshes).
pub fn optimize_ctx(rt: Option<&Runtime>, w: &Workload, hw: &HwConfig,
                    cfg: &GradientConfig, budget: Budget, ctx: &EvalCtx)
                    -> Result<SearchResult> {
    match rt {
        Some(rt) => optimize_pjrt(rt, w, hw, cfg, budget, ctx),
        None => optimize_native(w, hw, cfg, budget, ctx),
    }
}

/// The native backend: `C` parallel Adam chains over the pure-Rust
/// differentiable model. Chains step concurrently in lockstep blocks
/// of `decode_every` iterations (on the serving layer's persistent
/// pool when the context carries one, on scoped threads otherwise);
/// between blocks the main thread batches all chains' decode offers
/// through the engine and, late in the lambda ramp, respawns the worst
/// half of the chains from the best one.
fn optimize_native(w: &Workload, hw: &HwConfig, cfg: &GradientConfig,
                   budget: Budget, ctx: &EvalCtx)
                   -> Result<SearchResult> {
    let c_n = cfg.chain_count();
    let stop = ChainStop::new(budget, ctx);
    let gumbel_pool = GumbelPool::new(cfg.seed ^ 0x6789, 16);
    let mut inc = Incumbent::with_ctx(w, hw, ctx);
    inc.offer(&crate::mapping::Strategy::trivial(w), 0);

    let tables = Arc::clone(inc.engine.tables());
    let model = GradModel::new(w, hw, &tables, cfg.alpha,
                               cfg.fuse_enabled, SnapMode::Straight);
    let mut batch = ChainBatch::new(w, hw, cfg, &model, c_n);
    // warm-start: the first seed_slots chains restart from library
    // incumbents instead of the hardware prior (rng streams already
    // drawn, so unseeded chains are unchanged)
    let slots = ctx.seed_slots(c_n);
    if slots > 0 {
        inc.offer_seeds(&ctx.seeds);
        for c in 0..slots {
            seed_chain(&mut batch, c, &ctx.seeds[c % ctx.seeds.len()],
                       w);
        }
    }
    let per_chain_iters = budget.max_iters.max(1);
    let block = cfg.decode_every.max(1);
    let threads = inc.engine.threads().min(c_n);
    let mut it = 0usize; // lockstep per-chain step index
    let mut total_iters = 0usize;
    let mut blocks_done = 0usize;

    while it < per_chain_iters && !inc.stopped(&budget) {
        let todo = block.min(per_chain_iters - it);
        let start_it = it;
        let step = |mut view| {
            step_chain_block(&mut view, &model, &gumbel_pool, w, cfg,
                             &stop, start_it, todo, per_chain_iters)
        };
        let views = batch.views();
        let counts: Vec<usize> = match &ctx.pool {
            Some(pool) => pool.scoped_map(views, step),
            None => par_map(views, threads, step),
        };
        total_iters += counts.iter().sum::<usize>();
        it += todo;
        offer_chain_decodes(&batch, w, hw, cfg, &mut inc, total_iters,
                            &tables, ctx);
        inc.note_iters(total_iters);
        blocks_done += 1;
        if it < per_chain_iters
            && !inc.stopped(&budget)
            && stop.ramp(it, per_chain_iters) >= CULL_RAMP_THRESHOLD
            && blocks_done % CULL_EVERY_BLOCKS == 0
        {
            batch.cull_and_respawn(w);
        }
    }
    Ok(inc.finish(total_iters))
}

/// The PJRT backend: one artifact call per step for loss + gradients.
/// Rust stages `theta`/`sigma_logit` (workload constants are staged
/// once — ~150 KB of host copies per step otherwise; §Perf).
fn optimize_pjrt(rt: &Runtime, w: &Workload, hw: &HwConfig,
                 cfg: &GradientConfig, budget: Budget, ctx: &EvalCtx)
                 -> Result<SearchResult> {
    let l_max = rt.manifest.l_max;
    let k_max = rt.manifest.k_max;
    let stage = WorkloadStage::new(w, hw, l_max, k_max)?;
    let grad_art = rt.get(ART_GRAD)?;
    let mut rng = Rng::new(cfg.seed);
    let gumbel_pool = GumbelPool::new(cfg.seed ^ 0x6789, 16);
    let mut inc = Incumbent::with_ctx(w, hw, ctx);

    // always have a baseline incumbent
    inc.offer(&crate::mapping::Strategy::trivial(w), 0);

    let n_theta = l_max * NDIMS * 4;
    let mut total_iters = 0usize;

    // edge mask: zeroed in DOSA mode
    let edge_mask = if cfg.fuse_enabled {
        stage.edge_mask.clone()
    } else {
        HostTensor::new(vec![0.0; l_max])
    };

    // Pre-stage every workload-constant operand as a PJRT literal ONCE.
    let lit_dims = grad_art.stage_input(2, &stage.dims)?;
    let lit_div = grad_art.stage_input(3, &stage.div)?;
    let lit_div_mask = grad_art.stage_input(4, &stage.div_mask)?;
    let lit_layer_mask = grad_art.stage_input(5, &stage.layer_mask)?;
    let lit_edge_mask = grad_art.stage_input(6, &edge_mask)?;
    let lit_alpha =
        grad_art.stage_input(9, &HostTensor::scalar(cfg.alpha as f32))?;
    let lit_hw = grad_art.stage_input(11, &stage.hw)?;

    let per_restart_iters = budget.max_iters
        .saturating_div(cfg.restarts.max(1))
        .max(1);

    // step-output copies land in reusable buffers (re-collecting
    // fresh Vecs every step was measurable allocation churn)
    let mut g_theta = vec![0.0f64; n_theta];
    let mut g_sigma = vec![0.0f64; l_max];

    for restart in 0..cfg.restarts.max(1) {
        let mut theta = init_theta(w, hw, &mut rng, l_max);
        // see ChainBatch::new for the sigma init rationale
        let mut sigma = vec![-2.0f64; l_max];
        let mut adam_t = Adam::new(n_theta, cfg.beta1, cfg.beta2);
        let mut adam_s = Adam::new(l_max, cfg.beta1, cfg.beta2);
        let mut tau = cfg.tau0;

        let mut theta_f32 = vec![0.0f32; n_theta];
        let mut sigma_f32 = vec![0.0f32; l_max];
        let mut gumbel = vec![0.0f32; n_theta * k_max];

        for it in 0..per_restart_iters {
            if inc.stopped(&budget) {
                break;
            }
            total_iters += 1;
            // stage step inputs (reuse buffers)
            for i in 0..n_theta {
                theta_f32[i] = theta[i] as f32;
            }
            for i in 0..l_max {
                sigma_f32[i] = sigma[i] as f32;
            }
            gumbel_pool.fill(&mut rng, &mut gumbel);
            let progress = ramp_progress(it, per_restart_iters,
                                         inc.elapsed(), &budget);
            let lambda = cfg.lambda0
                + (cfg.lambda_max - cfg.lambda0) * progress;

            // stage only the step-varying operands
            let lit_theta = xla::Literal::vec1(&theta_f32)
                .reshape(&[l_max as i64, 7, 4])
                .map_err(|e| anyhow::anyhow!("theta reshape: {e:?}"))?;
            let lit_sigma = xla::Literal::vec1(&sigma_f32);
            let lit_gumbel = xla::Literal::vec1(&gumbel)
                .reshape(&[l_max as i64, 7, 4, k_max as i64])
                .map_err(|e| anyhow::anyhow!("gumbel reshape: {e:?}"))?;
            let lit_tau = xla::Literal::scalar(tau as f32);
            let lit_lam = xla::Literal::scalar(lambda as f32);
            let out = grad_art.run_literals(&[
                &lit_theta, &lit_sigma, &lit_dims, &lit_div,
                &lit_div_mask, &lit_layer_mask, &lit_edge_mask,
                &lit_gumbel, &lit_tau, &lit_alpha, &lit_lam, &lit_hw,
            ])?;
            for (dst, &src) in g_theta.iter_mut().zip(out[5].iter()) {
                *dst = src as f64;
            }
            for (dst, &src) in g_sigma.iter_mut().zip(out[6].iter()) {
                *dst = src as f64;
            }

            adam_t.step(&mut theta, &g_theta, cfg.lr);
            if cfg.fuse_enabled {
                adam_s.step(&mut sigma, &g_sigma, cfg.lr_sigma);
            }
            clamp_params(&mut theta, &mut sigma, w);
            tau = (tau * cfg.tau_decay).max(cfg.tau_min);

            if it % cfg.decode_every == 0 || it + 1 == per_restart_iters {
                offer_decodes(&theta, &sigma, w, hw, cfg, &mut inc,
                              total_iters);
                inc.note_iters(total_iters);
            }
        }
        // final decode of this restart
        offer_decodes(&theta, &sigma, w, hw, cfg, &mut inc, total_iters);
        let _ = restart;
        if inc.stopped(&budget) {
            break;
        }
    }
    Ok(inc.finish(total_iters))
}

/// Decode the relaxed state two ways and offer both to the incumbent:
/// (1) sigma thresholded at 0.5 (the paper's post-optimization
/// discretization), and (2) fusion-greedy — every fusible edge on, with
/// the capacity repair cutting lowest-sigma edges first. The sigma
/// values learned by the gradient still order the greedy variant's cut
/// priority; keeping the better feasible decode makes the fusion-aware
/// search never lose to its own layer-wise ablation. (The PJRT serial
/// path; the native chains batch the same two variants per chain
/// through [`offer_chain_decodes`].)
fn offer_decodes(theta: &[f64], sigma: &[f64], w: &Workload, hw: &HwConfig,
                 cfg: &GradientConfig, inc: &mut Incumbent, iter: usize) {
    let tables = std::sync::Arc::clone(inc.engine.tables());
    let relaxed = relaxed_from(theta, sigma, w, cfg);
    inc.offer(&decode_with(&relaxed, w, hw, &tables), iter);
    if cfg.fuse_enabled {
        let greedy = fusion_greedy(&relaxed, w);
        inc.offer(&decode_with(&greedy, w, hw, &tables), iter);
    }
}

fn relaxed_from(theta: &[f64], sigma: &[f64], w: &Workload,
                cfg: &GradientConfig) -> Relaxed {
    let mut relaxed = Relaxed::neutral(w);
    for l in 0..w.len() {
        for d in 0..NDIMS {
            for s in 0..4 {
                relaxed.theta[l][d][s] = theta[(l * NDIMS + d) * 4 + s];
            }
        }
    }
    for i in 0..relaxed.sigma.len() {
        relaxed.sigma[i] = if cfg.fuse_enabled {
            1.0 / (1.0 + (-sigma[i]).exp())
        } else {
            0.0
        };
    }
    relaxed
}
