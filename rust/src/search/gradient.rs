//! The FADiff optimizer (paper Sec 3.3): constrained gradient descent
//! on the continuous relaxation.
//!
//! Per step: sample Gumbel noise, evaluate loss + gradients of the
//! relaxed cost model, apply an Adam update. The Gumbel-Softmax
//! temperature anneals `tau0 -> tau_min` geometrically and the penalty
//! weight lambda ramps up, exactly as Sec 3.1.1/3.3 describe. The
//! incumbent is refreshed by decoding the relaxed state (Sec 3.1's
//! continuous-to-discrete projection + capacity repair) and evaluating
//! natively through the search's `EvalEngine`.
//!
//! Two interchangeable backends compute the loss/gradient step:
//!
//! * **Native** (the default, always available) — the pure-Rust
//!   forward + reverse model in [`crate::costmodel::grad`], f64, zero
//!   allocation per step. Selected whenever no runtime is passed.
//! * **PJRT** (optional accelerator) — the AOT `fadiff_grad` artifact
//!   executed via PJRT, exactly as before. Callers probe it with
//!   [`Runtime::load_if_available`] and pass `Some(rt)`; environments
//!   without artifacts pass `None` and lose nothing but the
//!   accelerator.
//!
//! The DOSA baseline (layer-wise gradient, MICRO'23 [8]) is this same
//! engine with `fuse_enabled = false`: sigma is pinned to 0 via the edge
//! mask, which makes the loss separable per layer — i.e. exactly
//! layer-independent mapping search.

use anyhow::Result;

use crate::config::HwConfig;
use crate::costmodel::grad::{GradModel, GradScratch, SnapMode};
use crate::mapping::decode::{decode_with, Relaxed};
use crate::runtime::stage::WorkloadStage;
use crate::runtime::{HostTensor, Runtime, ART_GRAD};
use crate::util::rng::{GumbelPool, Rng};
use crate::workload::{Workload, NDIMS};

use super::{Budget, EvalCtx, Incumbent, SearchResult};

/// Hyper-parameters of the gradient search.
#[derive(Clone, Debug)]
pub struct GradientConfig {
    pub lr: f64,
    pub lr_sigma: f64,
    pub tau0: f64,
    pub tau_min: f64,
    /// Geometric tau decay per step.
    pub tau_decay: f64,
    pub alpha: f64,
    pub lambda0: f64,
    pub lambda_max: f64,
    /// Steps between incumbent refresh (decode + native eval).
    pub decode_every: usize,
    pub seed: u64,
    /// false => DOSA mode (no fusion, layer-wise objective).
    pub fuse_enabled: bool,
    /// Adam moments.
    pub beta1: f64,
    pub beta2: f64,
    /// Random restarts share the budget round-robin.
    pub restarts: usize,
}

impl Default for GradientConfig {
    fn default() -> Self {
        GradientConfig {
            lr: 0.08,
            lr_sigma: 0.15,
            tau0: 2.0,
            tau_min: 0.05,
            tau_decay: 0.995,
            alpha: 2.0,
            lambda0: 0.1,
            lambda_max: 10.0,
            decode_every: 10,
            seed: 0xFAD1FF,
            fuse_enabled: true,
            beta1: 0.9,
            beta2: 0.999,
            restarts: 2,
        }
    }
}

impl GradientConfig {
    /// The DOSA (layer-wise) ablation of this optimizer.
    pub fn dosa() -> GradientConfig {
        GradientConfig { fuse_enabled: false, ..Default::default() }
    }
}

struct Adam {
    m: Vec<f64>,
    v: Vec<f64>,
    t: usize,
    beta1: f64,
    beta2: f64,
}

impl Adam {
    fn new(n: usize, beta1: f64, beta2: f64) -> Adam {
        Adam { m: vec![0.0; n], v: vec![0.0; n], t: 0, beta1, beta2 }
    }

    fn step(&mut self, params: &mut [f64], grads: &[f64], lr: f64) {
        self.t += 1;
        let b1c = 1.0 - self.beta1.powi(self.t as i32);
        let b2c = 1.0 - self.beta2.powi(self.t as i32);
        for i in 0..params.len() {
            let g = grads[i];
            if !g.is_finite() {
                continue;
            }
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * g;
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * g * g;
            let mhat = self.m[i] / b1c;
            let vhat = self.v[i] / b2c;
            params[i] -= lr * mhat / (vhat.sqrt() + 1e-8);
        }
    }
}

/// Initialize theta near hardware-sensible priors: spatial at the array
/// limits, modest on-chip temporal tiles, rest at DRAM.
fn init_theta(w: &Workload, hw: &HwConfig, rng: &mut Rng, l_max: usize)
              -> Vec<f64> {
    use crate::workload::{DIM_C, DIM_K};
    let mut theta = vec![0.0f64; l_max * NDIMS * 4];
    for (l, layer) in w.layers.iter().enumerate() {
        for d in 0..NDIMS {
            let n = layer.dims[d] as f64;
            let cap = n.log2();
            for s in 0..4 {
                let base = match (d, s) {
                    (DIM_K, 3) => (hw.pe_cols as f64).log2(),
                    (DIM_C, 3) => (hw.pe_rows as f64).log2(),
                    (_, 3) => 0.0,
                    (_, 2) => (cap / 3.0).min(4.0), // L2 tile
                    (_, 1) => (cap / 4.0).min(2.0),
                    _ => (cap / 6.0).min(1.0),
                };
                let jitter = rng.normal() * 0.35;
                theta[(l * NDIMS + d) * 4 + s] =
                    (base + jitter).clamp(-1.0, cap.max(0.0) + 0.5);
            }
        }
    }
    theta
}

/// Penalty-ramp progress in [0, 1]: fraction of the iteration budget
/// consumed, or of the wall-clock budget — whichever is further along.
/// Under pure seconds budgets `max_iters` is effectively unbounded, so
/// the iteration fraction alone stays ~0 and the lambda ramp of
/// Sec 3.1.1 would never engage (penalties stuck at `lambda0` for the
/// whole run); the wall-clock fraction drives it there instead.
fn ramp_progress(it: usize, per_restart: usize, inc: &Incumbent,
                 budget: &Budget) -> f64 {
    let by_iter = it as f64 / per_restart.max(1) as f64;
    let by_time = if budget.seconds.is_finite() {
        inc.elapsed() / budget.seconds.max(1e-9)
    } else {
        0.0
    };
    by_iter.max(by_time).min(1.0)
}

/// Clamp parameters into the numerically safe box the optimizer
/// explores (theta per-dim capped at the problem size, sigma bounded).
fn clamp_params(theta: &mut [f64], sigma: &mut [f64], w: &Workload) {
    for (l, layer) in w.layers.iter().enumerate() {
        for d in 0..NDIMS {
            let cap = (layer.dims[d] as f64).log2().max(0.0) + 0.5;
            for s in 0..4 {
                let idx = (l * NDIMS + d) * 4 + s;
                theta[idx] = theta[idx].clamp(-2.0, cap);
            }
        }
    }
    for s in sigma.iter_mut() {
        *s = s.clamp(-8.0, 8.0);
    }
}

/// Run the FADiff (or DOSA) gradient search. `rt` selects the backend:
/// `Some` runs the AOT artifact on PJRT, `None` runs the pure-Rust
/// differentiable model — same optimizer, same annealing, same decode.
pub fn optimize(rt: Option<&Runtime>, w: &Workload, hw: &HwConfig,
                cfg: &GradientConfig, budget: Budget)
                -> Result<SearchResult> {
    optimize_ctx(rt, w, hw, cfg, budget, &EvalCtx::default())
}

/// [`optimize`] with a serving-layer context (shared cache / persistent
/// pool / cooperative cancellation for the incumbent refreshes).
pub fn optimize_ctx(rt: Option<&Runtime>, w: &Workload, hw: &HwConfig,
                    cfg: &GradientConfig, budget: Budget, ctx: &EvalCtx)
                    -> Result<SearchResult> {
    match rt {
        Some(rt) => optimize_pjrt(rt, w, hw, cfg, budget, ctx),
        None => optimize_native(w, hw, cfg, budget, ctx),
    }
}

/// The native backend: Adam over the pure-Rust differentiable model.
fn optimize_native(w: &Workload, hw: &HwConfig, cfg: &GradientConfig,
                   budget: Budget, ctx: &EvalCtx)
                   -> Result<SearchResult> {
    let mut rng = Rng::new(cfg.seed);
    let gumbel_pool = GumbelPool::new(cfg.seed ^ 0x6789, 16);
    let mut inc = Incumbent::with_ctx(w, hw, ctx);
    inc.offer(&crate::mapping::Strategy::trivial(w), 0);

    let tables = std::sync::Arc::clone(inc.engine.tables());
    let model = GradModel::new(w, hw, &tables, cfg.alpha,
                               cfg.fuse_enabled, SnapMode::Straight);
    let n_theta = model.n_theta();
    let n_sigma = model.n_sigma();
    let mut scratch = GradScratch::new();
    let mut g_theta = vec![0.0f64; n_theta];
    let mut g_sigma = vec![0.0f64; n_sigma];
    let mut gumbel = vec![0.0f64; model.n_gumbel()];
    let mut total_iters = 0usize;

    let per_restart_iters = budget.max_iters
        .saturating_div(cfg.restarts.max(1))
        .max(1);

    for _restart in 0..cfg.restarts.max(1) {
        let mut theta = init_theta(w, hw, &mut rng, w.len());
        // start mostly-unfused (sigma ~= 0.12): a 0.5 init inflates the
        // soft group-footprint scan and distorts mappings on small
        // scratchpads even when fusion is eventually rejected
        let mut sigma = vec![-2.0f64; n_sigma];
        let mut adam_t = Adam::new(n_theta, cfg.beta1, cfg.beta2);
        let mut adam_s = Adam::new(n_sigma, cfg.beta1, cfg.beta2);
        let mut tau = cfg.tau0;

        for it in 0..per_restart_iters {
            if inc.stopped(&budget) {
                break;
            }
            total_iters += 1;
            gumbel_pool.fill_f64(&mut rng, &mut gumbel);
            let progress =
                ramp_progress(it, per_restart_iters, &inc, &budget);
            let lambda = cfg.lambda0
                + (cfg.lambda_max - cfg.lambda0) * progress;

            model.loss_and_grad(&theta, &sigma, &gumbel, tau, lambda,
                                &mut scratch, &mut g_theta,
                                &mut g_sigma);
            adam_t.step(&mut theta, &g_theta, cfg.lr);
            if cfg.fuse_enabled {
                adam_s.step(&mut sigma, &g_sigma, cfg.lr_sigma);
            }
            clamp_params(&mut theta, &mut sigma, w);
            tau = (tau * cfg.tau_decay).max(cfg.tau_min);

            if it % cfg.decode_every == 0 || it + 1 == per_restart_iters
            {
                offer_decodes(&theta, &sigma, w, hw, cfg, &mut inc,
                              total_iters);
            }
        }
        // final decode of this restart
        offer_decodes(&theta, &sigma, w, hw, cfg, &mut inc, total_iters);
        if inc.stopped(&budget) {
            break;
        }
    }
    Ok(inc.finish(total_iters))
}

/// The PJRT backend: one artifact call per step for loss + gradients.
/// Rust stages `theta`/`sigma_logit` (workload constants are staged
/// once — ~150 KB of host copies per step otherwise; §Perf).
fn optimize_pjrt(rt: &Runtime, w: &Workload, hw: &HwConfig,
                 cfg: &GradientConfig, budget: Budget, ctx: &EvalCtx)
                 -> Result<SearchResult> {
    let l_max = rt.manifest.l_max;
    let k_max = rt.manifest.k_max;
    let stage = WorkloadStage::new(w, hw, l_max, k_max)?;
    let grad_art = rt.get(ART_GRAD)?;
    let mut rng = Rng::new(cfg.seed);
    let gumbel_pool = GumbelPool::new(cfg.seed ^ 0x6789, 16);
    let mut inc = Incumbent::with_ctx(w, hw, ctx);

    // always have a baseline incumbent
    inc.offer(&crate::mapping::Strategy::trivial(w), 0);

    let n_theta = l_max * NDIMS * 4;
    let mut total_iters = 0usize;

    // edge mask: zeroed in DOSA mode
    let edge_mask = if cfg.fuse_enabled {
        stage.edge_mask.clone()
    } else {
        HostTensor::new(vec![0.0; l_max])
    };

    // Pre-stage every workload-constant operand as a PJRT literal ONCE.
    let lit_dims = grad_art.stage_input(2, &stage.dims)?;
    let lit_div = grad_art.stage_input(3, &stage.div)?;
    let lit_div_mask = grad_art.stage_input(4, &stage.div_mask)?;
    let lit_layer_mask = grad_art.stage_input(5, &stage.layer_mask)?;
    let lit_edge_mask = grad_art.stage_input(6, &edge_mask)?;
    let lit_alpha =
        grad_art.stage_input(9, &HostTensor::scalar(cfg.alpha as f32))?;
    let lit_hw = grad_art.stage_input(11, &stage.hw)?;

    let per_restart_iters = budget.max_iters
        .saturating_div(cfg.restarts.max(1))
        .max(1);

    for restart in 0..cfg.restarts.max(1) {
        let mut theta = init_theta(w, hw, &mut rng, l_max);
        // see optimize_native for the sigma init rationale
        let mut sigma = vec![-2.0f64; l_max];
        let mut adam_t = Adam::new(n_theta, cfg.beta1, cfg.beta2);
        let mut adam_s = Adam::new(l_max, cfg.beta1, cfg.beta2);
        let mut tau = cfg.tau0;

        let mut theta_f32 = vec![0.0f32; n_theta];
        let mut sigma_f32 = vec![0.0f32; l_max];
        let mut gumbel = vec![0.0f32; n_theta * k_max];

        for it in 0..per_restart_iters {
            if inc.stopped(&budget) {
                break;
            }
            total_iters += 1;
            // stage step inputs (reuse buffers)
            for i in 0..n_theta {
                theta_f32[i] = theta[i] as f32;
            }
            for i in 0..l_max {
                sigma_f32[i] = sigma[i] as f32;
            }
            gumbel_pool.fill(&mut rng, &mut gumbel);
            let progress =
                ramp_progress(it, per_restart_iters, &inc, &budget);
            let lambda = cfg.lambda0
                + (cfg.lambda_max - cfg.lambda0) * progress;

            // stage only the step-varying operands
            let lit_theta = xla::Literal::vec1(&theta_f32)
                .reshape(&[l_max as i64, 7, 4])
                .map_err(|e| anyhow::anyhow!("theta reshape: {e:?}"))?;
            let lit_sigma = xla::Literal::vec1(&sigma_f32);
            let lit_gumbel = xla::Literal::vec1(&gumbel)
                .reshape(&[l_max as i64, 7, 4, k_max as i64])
                .map_err(|e| anyhow::anyhow!("gumbel reshape: {e:?}"))?;
            let lit_tau = xla::Literal::scalar(tau as f32);
            let lit_lam = xla::Literal::scalar(lambda as f32);
            let out = grad_art.run_literals(&[
                &lit_theta, &lit_sigma, &lit_dims, &lit_div,
                &lit_div_mask, &lit_layer_mask, &lit_edge_mask,
                &lit_gumbel, &lit_tau, &lit_alpha, &lit_lam, &lit_hw,
            ])?;
            let g_theta: Vec<f64> =
                out[5].iter().map(|&x| x as f64).collect();
            let g_sigma: Vec<f64> =
                out[6].iter().map(|&x| x as f64).collect();

            adam_t.step(&mut theta, &g_theta, cfg.lr);
            if cfg.fuse_enabled {
                adam_s.step(&mut sigma, &g_sigma, cfg.lr_sigma);
            }
            clamp_params(&mut theta, &mut sigma, w);
            tau = (tau * cfg.tau_decay).max(cfg.tau_min);

            if it % cfg.decode_every == 0 || it + 1 == per_restart_iters {
                offer_decodes(&theta, &sigma, w, hw, cfg, &mut inc,
                              total_iters);
            }
        }
        // final decode of this restart
        offer_decodes(&theta, &sigma, w, hw, cfg, &mut inc, total_iters);
        let _ = restart;
        if inc.stopped(&budget) {
            break;
        }
    }
    Ok(inc.finish(total_iters))
}

/// Decode the relaxed state two ways and offer both to the incumbent:
/// (1) sigma thresholded at 0.5 (the paper's post-optimization
/// discretization), and (2) fusion-greedy — every fusible edge on, with
/// the capacity repair cutting lowest-sigma edges first. The sigma
/// values learned by the gradient still order the greedy variant's cut
/// priority; keeping the better feasible decode makes the fusion-aware
/// search never lose to its own layer-wise ablation.
fn offer_decodes(theta: &[f64], sigma: &[f64], w: &Workload, hw: &HwConfig,
                 cfg: &GradientConfig, inc: &mut Incumbent, iter: usize) {
    let tables = std::sync::Arc::clone(inc.engine.tables());
    let relaxed = relaxed_from(theta, sigma, w, cfg);
    inc.offer(&decode_with(&relaxed, w, hw, &tables), iter);
    if cfg.fuse_enabled {
        let mut greedy = relaxed.clone();
        for (i, s) in greedy.sigma.iter_mut().enumerate() {
            if w.fusible[i] {
                // keep ordering information, lift above the threshold
                *s = 0.51 + 0.49 * *s;
            }
        }
        inc.offer(&decode_with(&greedy, w, hw, &tables), iter);
    }
}

fn relaxed_from(theta: &[f64], sigma: &[f64], w: &Workload,
                cfg: &GradientConfig) -> Relaxed {
    let mut relaxed = Relaxed::neutral(w);
    for l in 0..w.len() {
        for d in 0..NDIMS {
            for s in 0..4 {
                relaxed.theta[l][d][s] = theta[(l * NDIMS + d) * 4 + s];
            }
        }
    }
    for i in 0..relaxed.sigma.len() {
        relaxed.sigma[i] = if cfg.fuse_enabled {
            1.0 / (1.0 + (-sigma[i]).exp())
        } else {
            0.0
        };
    }
    relaxed
}
