//! Genetic-algorithm baseline (paper ref [16]; Sec 4.3.1's heuristic
//! representative).
//!
//! Operates on the shared continuous unit-cube encoding
//! (`search::encoding`) so every method explores the identical design
//! space (the paper's "same search spaces" protocol): tournament
//! selection, uniform layer-granularity crossover, Gaussian + reset
//! mutation, elitism. Every genome decodes through the same
//! projection/repair pipeline as the gradient search, so all candidates
//! are hardware-valid and fitness is simply the native closed-form EDP.
//!
//! Each generation decodes and scores as one batch on the incumbent's
//! [`super::EvalEngine`]: candidates evaluate in parallel and elitism /
//! crossover duplicates resolve from the memoization cache instead of
//! re-running the cost model.

use anyhow::Result;

use crate::config::HwConfig;
use crate::util::rng::Rng;
use crate::workload::{Workload, NDIMS};

use super::encoding::{dim, encode_strategy, express_naive_with};
use super::{Budget, EvalCtx, Incumbent, PruneMode, Screened,
            SearchResult};

/// GA hyper-parameters.
#[derive(Clone, Debug)]
pub struct GaConfig {
    /// Individuals per generation.
    pub population: usize,
    /// Tournament size for parent selection.
    pub tournament: usize,
    /// Probability a child is produced by crossover.
    pub crossover_rate: f64,
    /// Per-gene mutation probability.
    pub mutation_rate: f64,
    /// Std-dev of the Gaussian gene perturbation (unit-cube space).
    pub mutation_sigma: f64,
    /// Top individuals copied unchanged into the next generation.
    pub elitism: usize,
    /// PRNG seed.
    pub seed: u64,
}

impl Default for GaConfig {
    fn default() -> Self {
        GaConfig {
            population: 48,
            tournament: 3,
            crossover_rate: 0.85,
            mutation_rate: 0.10,
            mutation_sigma: 0.15,
            elitism: 2,
            seed: 0xBEEF,
        }
    }
}

/// Run the GA under a budget.
pub fn optimize(w: &Workload, hw: &HwConfig, cfg: &GaConfig,
                budget: Budget) -> Result<SearchResult> {
    optimize_ctx(w, hw, cfg, budget, &EvalCtx::default())
}

/// Run the GA with a serving-layer context (shared cache / persistent
/// pool / cancellation). Identical results for an empty context.
pub fn optimize_ctx(w: &Workload, hw: &HwConfig, cfg: &GaConfig,
                    budget: Budget, ctx: &EvalCtx)
                    -> Result<SearchResult> {
    let d = dim(w);
    let genes_per_layer = NDIMS * 4;
    let mut rng = Rng::new(cfg.seed);
    let mut inc = Incumbent::with_ctx(w, hw, ctx);
    inc.offer(&crate::mapping::Strategy::trivial(w), 0);

    let mut pop: Vec<Vec<f64>> = (0..cfg.population)
        .map(|_| (0..d).map(|_| rng.f64()).collect())
        .collect();
    // warm-start: overwrite the first seed_slots genomes with library
    // incumbents AFTER drawing the full random population, so the rng
    // stream (and thus every unseeded run) is byte-for-byte unchanged
    let slots = ctx.seed_slots(cfg.population);
    if slots > 0 {
        inc.offer_seeds(&ctx.seeds);
        for i in 0..slots {
            let seed = &ctx.seeds[i % ctx.seeds.len()];
            pop[i] = encode_strategy(seed, w);
        }
    }
    let mut fitness = vec![f64::INFINITY; pop.len()];
    let mut gen = 0usize;

    let full_prune = ctx.prune == PruneMode::Full;
    let tables = std::sync::Arc::clone(inc.engine.tables());
    while gen < budget.max_iters && !inc.stopped(&budget) {
        gen += 1;
        // decode + score the whole generation in parallel (cache folds
        // elites and crossover duplicates)
        if full_prune {
            // prune: "full" — pruned individuals take their admissible
            // bound as a pessimistic fitness instead of the exact EDP.
            // Selection pressure on them weakens, so the GA trajectory
            // can differ from the unpruned run (documented opt-in).
            let scored = inc.engine.eval_population_screened(
                &pop,
                |g| express_naive_with(g, w, hw, &tables),
                inc.best_edp(),
                ctx.prune_stats(),
            );
            for (i, (s, sc)) in scored.iter().enumerate() {
                let offered = inc.offer_screened(s, *sc, gen);
                fitness[i] = match *sc {
                    Screened::Pruned { bound_edp } => bound_edp,
                    _ => offered,
                };
            }
        } else {
            let scored = inc.engine.eval_population(
                &pop, |g| express_naive_with(g, w, hw, &tables));
            for (i, (s, e)) in scored.iter().enumerate() {
                fitness[i] = inc.offer_eval(s, *e, gen);
            }
        }
        inc.note_iters(gen);
        if inc.stopped(&budget) {
            break;
        }
        // next generation
        let mut order: Vec<usize> = (0..pop.len()).collect();
        order.sort_by(|&a, &b| {
            fitness[a].partial_cmp(&fitness[b]).unwrap()
        });
        let mut next: Vec<Vec<f64>> = order[..cfg.elitism.min(pop.len())]
            .iter()
            .map(|&i| pop[i].clone())
            .collect();
        while next.len() < cfg.population {
            let pick = |rng: &mut Rng| -> usize {
                let mut best = rng.below(pop.len());
                for _ in 1..cfg.tournament {
                    let c = rng.below(pop.len());
                    if fitness[c] < fitness[best] {
                        best = c;
                    }
                }
                best
            };
            let a = pick(&mut rng);
            let b = pick(&mut rng);
            let mut child = pop[a].clone();
            if rng.chance(cfg.crossover_rate) {
                // uniform crossover at layer granularity (+ fusion tail)
                for l in 0..w.len() {
                    if rng.chance(0.5) {
                        let lo = l * genes_per_layer;
                        let hi = lo + genes_per_layer;
                        child[lo..hi].copy_from_slice(&pop[b][lo..hi]);
                    }
                }
                let base = w.len() * genes_per_layer;
                for i in base..d {
                    if rng.chance(0.5) {
                        child[i] = pop[b][i];
                    }
                }
            }
            // mutation: mostly local Gaussian, occasionally full reset
            for gene in child.iter_mut() {
                if rng.chance(cfg.mutation_rate) {
                    *gene = if rng.chance(0.2) {
                        rng.f64()
                    } else {
                        (*gene + rng.normal() * cfg.mutation_sigma)
                            .clamp(0.0, 1.0)
                    };
                }
            }
            next.push(child);
        }
        pop = next;
    }
    Ok(inc.finish(gen))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{load_config, repo_root};
    use crate::costmodel;
    use crate::workload::zoo;

    #[test]
    fn ga_improves_over_generations() {
        let hw = load_config(&repo_root(), "large").unwrap();
        let w = zoo::mobilenet_v1();
        let trivial = costmodel::evaluate(
            &crate::mapping::Strategy::trivial(&w), &w, &hw);
        let r = optimize(&w, &hw, &GaConfig::default(),
                         Budget::iters(15))
            .unwrap();
        assert!(r.edp < trivial.edp, "{} !< {}", r.edp, trivial.edp);
        costmodel::feasible(&r.best, &w, &hw).unwrap();
        assert!(r.trace.len() >= 2, "GA never improved");
    }
}
