//! Best-first branch-and-bound *exact* mapper over the divisor/fusion
//! design space — the correctness oracle for every other search
//! method ("Fast and Fusiest" / "Turbo-Charged Mapper", arXiv
//! 2602.15166 / 2602.15172).
//!
//! The mapper enumerates, per layer, every valid tiling assignment
//! (ordered divisor splits across the T0/T1/T2 temporal slots and the
//! spatially-capped S slot; the DRAM co-factor is derived) and every
//! per-edge fusion decision, organized as a search tree assigning
//! layers left to right. Partial assignments carry exact per-layer
//! energy/latency partial sums — accumulated in the same order as
//! `costmodel::evaluate`, so completed leaves reproduce the kernel's
//! numbers bit for bit — and subtrees are cut by three prune rules:
//!
//! * **admissible bounds** — partial sum plus per-layer suffix
//!   floors, scaled by [`ROUNDING_SLACK`] (the same slack the
//!   screened eval path uses) so reassociation noise can never prune
//!   the optimum;
//! * **capacity infeasibility** — an exact replica of the kernel's
//!   accumulator and fusion-group L2 checks; the open group's running
//!   sum is monotone, so a partial overflow condemns the subtree;
//! * **dominance** — within a layer, a candidate whose exact energy
//!   and latency (under every reachable fusion signature) and L2
//!   footprint are all `<=` another's makes the other redundant;
//!   across partial assignments, equal `(depth, open-edge)` states
//!   are ordered componentwise by (energy, latency, open-group
//!   bytes). Dominance always compares *exact* costs — dominance by a
//!   lower bound would be unsound.
//!
//! Leaves are scored through the incumbent's engine (screened exactly
//! like `random`/`gradient` candidates), so the returned
//! [`SearchResult`] is bit-identical to what exhaustive enumeration
//! through the same engine would select. When neither subsampling nor
//! a node/time cap fired, the result is *certified* optimal up to the
//! documented 1e-12 bound slack — see `docs/exact.md`.

use std::cmp::Ordering;
use std::collections::{BinaryHeap, HashMap};

use anyhow::Result;

use crate::config::HwConfig;
use crate::costmodel::bounds::ROUNDING_SLACK;
use crate::costmodel::{components, layer_cost};
use crate::mapping::{divisors, LayerMapping, Strategy, NSLOTS, SLOT_S,
                     SLOT_T0, SLOT_T1, SLOT_T2};
use crate::workload::{Workload, DIM_C, DIM_K, NDIMS};

use super::{Budget, EvalCtx, Incumbent, Screened, SearchResult};

/// Leaves buffered between engine batches (mirrors `random`'s block).
const LEAF_BATCH: usize = 64;

/// Partial-assignment states kept per `(depth, open-edge)` dominance
/// key; past the cap new states are still *checked* (sound) but no
/// longer stored (bounded memory).
const DOM_KEEP: usize = 1024;

/// Caps bounding the exact mapper's enumeration and search effort.
#[derive(Clone, Copy, Debug)]
pub struct ExactConfig {
    /// Node budget: heap pops (expansions) and queued nodes are each
    /// capped here; tripping it yields an uncertified (but still
    /// best-feasible-seen) result.
    pub max_nodes: u64,
    /// Per-layer candidate cross-product cap. A layer above it has
    /// its per-dimension assignment lists deterministically
    /// subsampled (first/last kept, even stride), which drops the
    /// certification flag.
    pub max_layer_candidates: u64,
    /// Per-layer Pareto-frontier size cap; overflow drops the
    /// certification flag.
    pub max_frontier: usize,
}

impl Default for ExactConfig {
    fn default() -> ExactConfig {
        ExactConfig {
            max_nodes: 2_000_000,
            max_layer_candidates: 100_000,
            max_frontier: 512,
        }
    }
}

/// Node/prune/expansion statistics of one branch-and-bound run.
#[derive(Clone, Copy, Debug, Default)]
pub struct ExactStats {
    /// The search proved its result optimal over the full space (no
    /// subsampling, no cap, no budget trip).
    pub certified: bool,
    /// The enumerated space was complete (no per-layer subsampling or
    /// frontier overflow).
    pub space_complete: bool,
    /// The node/arena cap tripped before the queue drained.
    pub cap_hit: bool,
    /// Per-layer tiling candidates enumerated (pre-filter, summed
    /// over layers).
    pub layer_candidates: u64,
    /// Candidates surviving the per-layer Pareto filter (all layers).
    pub frontier: u64,
    /// Nodes pushed onto the best-first queue (root included).
    pub nodes_generated: u64,
    /// Nodes popped and expanded.
    pub nodes_expanded: u64,
    /// Children cut by the admissible bound (leaf pre-prunes
    /// included).
    pub pruned_bound: u64,
    /// Candidates/children cut by the accumulator or group-capacity
    /// replica.
    pub pruned_infeasible: u64,
    /// Candidates/children cut by a dominance rule (frontier-cap
    /// overflow drops included).
    pub pruned_dominated: u64,
    /// Complete strategies handed to the engine for exact scoring.
    pub leaves: u64,
}

impl ExactStats {
    /// Total cuts across the three prune classes.
    pub fn pruned(&self) -> u64 {
        self.pruned_bound + self.pruned_infeasible
            + self.pruned_dominated
    }
}

/// An exact-mapper outcome: the search result plus its statistics.
#[derive(Clone, Debug)]
pub struct ExactOutcome {
    /// Best feasible strategy found — the proven optimum when
    /// `stats.certified`.
    pub result: SearchResult,
    /// Node/prune/certification statistics.
    pub stats: ExactStats,
}

/// Factor slots of one dimension, indexed by the `SLOT_*` constants.
type DimAssign = [u64; NSLOTS];

/// Every `[t0, t1, t2, s]` assignment whose inner product divides
/// `n`, with the spatial slot capped at `s_cap` (nested divisor
/// splits of successive quotients; the DRAM co-factor absorbs the
/// rest — exactly the space `Strategy::validate` accepts).
fn dim_assignments(n: u64, s_cap: u64) -> Vec<DimAssign> {
    let mut out = Vec::new();
    for &s in divisors(n).iter().filter(|&&d| d <= s_cap) {
        for &t0 in &divisors(n / s) {
            for &t1 in &divisors(n / (s * t0)) {
                for &t2 in &divisors(n / (s * t0 * t1)) {
                    let mut f = [1u64; NSLOTS];
                    f[SLOT_T0] = t0;
                    f[SLOT_T1] = t1;
                    f[SLOT_T2] = t2;
                    f[SLOT_S] = s;
                    out.push(f);
                }
            }
        }
    }
    out
}

/// Deterministic even-stride subsample keeping the first and last
/// entries (the all-ones and most-split assignments).
fn subsample(v: &[DimAssign], keep: usize) -> Vec<DimAssign> {
    if v.len() <= keep {
        return v.to_vec();
    }
    if keep <= 1 {
        return vec![v[0]];
    }
    let last = v.len() - 1;
    (0..keep).map(|i| v[i * last / (keep - 1)]).collect()
}

/// One surviving per-layer tiling candidate: its mapping, its exact
/// per-signature costs, and its fusion-group footprint.
struct Cand {
    m: LayerMapping,
    /// Exact energy under fusion signature `[sig_in][sig_out]`
    /// (unreachable signatures hold infinity and are never read).
    e: [[f64; 2]; 2],
    /// Exact latency, same indexing.
    l: [[f64; 2]; 2],
    /// Fusion-group L2 footprint, bytes (the group-capacity operand).
    l2_bytes: f64,
}

/// Per-layer candidate frontier plus its admissible cost floors.
struct LayerSpace {
    cands: Vec<Cand>,
    /// Minimum energy over candidates x reachable signatures. Equal
    /// to the full enumeration's minimum: a dominated candidate is
    /// componentwise `>=` its dominator.
    min_e: f64,
    /// Minimum latency, ditto.
    min_l: f64,
}

/// Reachable incoming-edge fusion signatures of layer `i`.
fn sig_in_opts(w: &Workload, i: usize) -> Vec<bool> {
    if i > 0 && w.fusible[i - 1] {
        vec![false, true]
    } else {
        vec![false]
    }
}

/// Reachable outgoing-edge fusion signatures of layer `i`.
fn sig_out_opts(w: &Workload, i: usize) -> Vec<bool> {
    if i + 1 < w.len() && w.fusible[i] {
        vec![false, true]
    } else {
        vec![false]
    }
}

fn sig(b: bool) -> f64 {
    if b {
        1.0
    } else {
        0.0
    }
}

/// Whether `a` makes `b` redundant: no complete strategy using `b`
/// can beat the same strategy with `a` substituted — exact energy and
/// latency under every reachable signature, and the group footprint,
/// are all `<=`.
fn dominates(a: &Cand, b: &Cand, si: &[bool], so: &[bool]) -> bool {
    if a.l2_bytes > b.l2_bytes {
        return false;
    }
    for &i in si {
        for &o in so {
            let (i, o) = (i as usize, o as usize);
            if a.e[i][o] > b.e[i][o] || a.l[i][o] > b.l[i][o] {
                return false;
            }
        }
    }
    true
}

/// Enumerate layer `i`'s tiling assignments, drop
/// accumulator-infeasible and dominated ones, and compute the
/// admissible floors. The returned flag is false when the space had
/// to be subsampled or the frontier cap dropped candidates.
fn build_layer_space(w: &Workload, hw: &HwConfig, i: usize,
                     cfg: &ExactConfig, stats: &mut ExactStats)
                     -> (LayerSpace, bool) {
    let dims = &w.layers[i].dims;
    let si = sig_in_opts(w, i);
    let so = sig_out_opts(w, i);
    let mut lists: Vec<Vec<DimAssign>> = (0..NDIMS)
        .map(|d| {
            let cap = if d == DIM_K {
                hw.pe_cols as u64
            } else if d == DIM_C {
                hw.pe_rows as u64
            } else {
                1
            };
            dim_assignments(dims[d] as u64, cap)
        })
        .collect();
    // shrink the largest per-dimension list until the cross product
    // fits the budget (deterministic; keeps the extremes)
    let mut complete = true;
    loop {
        let total: f64 =
            lists.iter().map(|v| v.len() as f64).product();
        if total <= cfg.max_layer_candidates as f64 {
            break;
        }
        let d = (0..NDIMS)
            .max_by_key(|&d| lists[d].len())
            .unwrap_or(0);
        if lists[d].len() <= 1 {
            break;
        }
        lists[d] = subsample(&lists[d], (lists[d].len() + 1) / 2);
        complete = false;
    }
    // odometer over the per-dimension lists
    let mut raw: Vec<Cand> = Vec::new();
    let mut idx = [0usize; NDIMS];
    'cands: loop {
        let mut m = LayerMapping::trivial();
        for d in 0..NDIMS {
            m.factors[d] = lists[d][idx[d]];
        }
        stats.layer_candidates += 1;
        let c = components(&m, dims);
        if c.s_o1 * hw.acc_bytes > hw.c1_bytes {
            // accumulator overflow: infeasible in any strategy
            stats.pruned_infeasible += 1;
        } else {
            let mut e = [[f64::INFINITY; 2]; 2];
            let mut l = [[f64::INFINITY; 2]; 2];
            for &s_i in &si {
                for &s_o in &so {
                    let lc = layer_cost(&c, sig(s_o), sig(s_i), hw);
                    e[s_i as usize][s_o as usize] = lc.energy;
                    l[s_i as usize][s_o as usize] = lc.latency;
                }
            }
            let l2_bytes = (c.s_w2 + c.s_i2) * hw.element_bytes;
            raw.push(Cand { m, e, l, l2_bytes });
        }
        for d in 0..NDIMS {
            idx[d] += 1;
            if idx[d] < lists[d].len() {
                continue 'cands;
            }
            idx[d] = 0;
        }
        break;
    }
    // Pareto filter. Scanning in ascending total-cost order means a
    // kept candidate can never be dominated by a later one, so one
    // pass yields the mutually-undominated frontier.
    let score = |c: &Cand| -> f64 {
        let mut t = c.l2_bytes;
        for &s_i in &si {
            for &s_o in &so {
                t += c.e[s_i as usize][s_o as usize]
                    + c.l[s_i as usize][s_o as usize];
            }
        }
        t
    };
    raw.sort_by(|a, b| score(a).total_cmp(&score(b)));
    let mut cands: Vec<Cand> = Vec::new();
    for c in raw {
        if cands.iter().any(|a| dominates(a, &c, &si, &so)) {
            stats.pruned_dominated += 1;
            continue;
        }
        if cands.len() >= cfg.max_frontier {
            // undominated but over the cap: the floors below stay
            // admissible over the *searched* space, and the dropped
            // flag downgrades certification
            stats.pruned_dominated += 1;
            complete = false;
            continue;
        }
        cands.push(c);
    }
    stats.frontier += cands.len() as u64;
    let mut min_e = f64::INFINITY;
    let mut min_l = f64::INFINITY;
    for c in &cands {
        for &s_i in &si {
            for &s_o in &so {
                min_e = min_e.min(c.e[s_i as usize][s_o as usize]);
                min_l = min_l.min(c.l[s_i as usize][s_o as usize]);
            }
        }
    }
    (LayerSpace { cands, min_e, min_l }, complete)
}

/// One partial assignment: layers `0..depth` mapped, with running
/// exact cost sums and the open fusion group's footprint.
#[derive(Clone, Copy)]
struct Node {
    /// Arena index of the parent (the root points at itself).
    parent: u32,
    /// Frontier index of layer `depth - 1`'s chosen candidate.
    cand: u32,
    /// Whether layer `depth - 1` fuses into layer `depth`.
    fused_out: bool,
    /// Layers assigned so far.
    depth: u16,
    /// Exact energy partial sum (kernel accumulation order).
    e: f64,
    /// Exact latency partial sum.
    l: f64,
    /// Open fusion group's accumulated L2 bytes (0 when closed).
    open: f64,
}

/// Best-first queue entry: the smallest `(bound, seq)` pops first.
struct HeapItem {
    bound: f64,
    seq: u64,
    node: u32,
}

impl PartialEq for HeapItem {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for HeapItem {}

impl PartialOrd for HeapItem {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for HeapItem {
    // reversed: BinaryHeap is a max-heap and the smallest bound
    // (ties: oldest entry) must surface
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .bound
            .total_cmp(&self.bound)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Mutable branch-and-bound state: the arena tree, the best-first
/// queue, the dominance table, and the leaf buffer.
struct Bnb<'a> {
    w: &'a Workload,
    spaces: &'a [LayerSpace],
    suf_e: &'a [f64],
    suf_l: &'a [f64],
    c2_bytes: f64,
    node_cap: u64,
    arena: Vec<Node>,
    heap: BinaryHeap<HeapItem>,
    seq: u64,
    dom: HashMap<(u16, bool), Vec<[f64; 3]>>,
    leaves: Vec<Strategy>,
    leaf_edp: Vec<f64>,
    stats: ExactStats,
}

impl Bnb<'_> {
    /// Componentwise dominance over equal `(depth, open-edge)`
    /// partial states; stores the new state (bounded per key) when it
    /// survives. A dominated state's every completion costs at least
    /// as much as the dominator's matching completion and is feasible
    /// only if it is, so cutting it preserves the optimum value.
    fn dominated_or_insert(&mut self, key: (u16, bool), e: f64,
                           l: f64, open: f64) -> bool {
        let states = self.dom.entry(key).or_default();
        if states
            .iter()
            .any(|s| s[0] <= e && s[1] <= l && s[2] <= open)
        {
            return true;
        }
        if states.len() < DOM_KEEP {
            states.push([e, l, open]);
        }
        false
    }

    /// Expand one popped node: try every (candidate, fusion) choice
    /// for its next layer, pruning by capacity, bound, and dominance.
    /// `inc_edp` is the incumbent EDP at pop time (admissible to use
    /// even if a buffered leaf would lower it).
    fn expand(&mut self, idx: u32, inc_edp: f64) {
        let node = self.arena[idx as usize];
        let spaces = self.spaces;
        let i = node.depth as usize;
        let si = usize::from(i > 0 && node.fused_out);
        let last = i + 1 == self.w.len();
        let fuse_ok = !last && self.w.fusible[i];
        for ci in 0..spaces[i].cands.len() {
            for fo in [false, true] {
                if fo && !fuse_ok {
                    continue;
                }
                let c = &spaces[i].cands[ci];
                let so = usize::from(fo);
                let e2 = node.e + c.e[si][so];
                let l2 = node.l + c.l[si][so];
                let open2 = node.open + c.l2_bytes;
                if open2 > self.c2_bytes {
                    // the group's running sum already overflows; it
                    // can only grow (monotone), so the close-time
                    // check is doomed too
                    self.stats.pruned_infeasible += 1;
                    continue;
                }
                if last {
                    // exact leaf value — identical accumulation to
                    // the kernel; slack guards only the engine edge
                    let edp = e2 * l2;
                    if edp * ROUNDING_SLACK >= inc_edp {
                        self.stats.pruned_bound += 1;
                        continue;
                    }
                    let s = self.leaf_strategy(idx, ci);
                    self.leaves.push(s);
                    self.leaf_edp.push(edp);
                } else {
                    let bound = (e2 + self.suf_e[i + 1])
                        * (l2 + self.suf_l[i + 1])
                        * ROUNDING_SLACK;
                    if bound >= inc_edp {
                        self.stats.pruned_bound += 1;
                        continue;
                    }
                    let open_next = if fo { open2 } else { 0.0 };
                    let key = (node.depth + 1, fo);
                    if self.dominated_or_insert(key, e2, l2,
                                                open_next) {
                        self.stats.pruned_dominated += 1;
                        continue;
                    }
                    if self.stats.nodes_generated >= self.node_cap
                        || self.arena.len() >= u32::MAX as usize
                    {
                        self.stats.cap_hit = true;
                        return;
                    }
                    self.arena.push(Node {
                        parent: idx,
                        cand: ci as u32,
                        fused_out: fo,
                        depth: node.depth + 1,
                        e: e2,
                        l: l2,
                        open: open_next,
                    });
                    self.seq += 1;
                    self.stats.nodes_generated += 1;
                    self.heap.push(HeapItem {
                        bound,
                        seq: self.seq,
                        node: (self.arena.len() - 1) as u32,
                    });
                }
            }
        }
    }

    /// Reconstruct the complete strategy of a leaf: the parent
    /// chain's choices plus candidate `ci` (unfused) at the last
    /// layer.
    fn leaf_strategy(&self, parent: u32, ci: usize) -> Strategy {
        let l = self.w.len();
        let mut s = Strategy::trivial(self.w);
        s.mappings[l - 1] = self.spaces[l - 1].cands[ci].m.clone();
        let mut at = parent;
        loop {
            let n = &self.arena[at as usize];
            if n.depth == 0 {
                break;
            }
            let layer = (n.depth - 1) as usize;
            s.mappings[layer] =
                self.spaces[layer].cands[n.cand as usize].m.clone();
            s.fuse[layer] = n.fused_out;
            at = n.parent;
        }
        s
    }
}

/// Debug invariant: a leaf reaching the engine is feasible by
/// construction, and the kernel EDP reproduces the tree's partial-sum
/// accumulation bit for bit (what certification relies on).
fn debug_assert_leaf(sc: &Screened, expect: f64) {
    if let Screened::Exact(e) = sc {
        debug_assert!(e.feasible, "b&b leaf scored infeasible");
        debug_assert!(
            e.edp.to_bits() == expect.to_bits(),
            "b&b partial sums diverged from the kernel: {} vs {}",
            e.edp,
            expect
        );
    }
}

/// Score the buffered complete strategies through the incumbent's
/// engine — screened exactly like the other searches' batches when
/// pruning is enabled — and offer each.
fn flush_leaves(inc: &mut Incumbent<'_>, ctx: &EvalCtx,
                buf: &mut Vec<Strategy>, expect: &mut Vec<f64>,
                stats: &mut ExactStats, iter: usize) {
    if buf.is_empty() {
        return;
    }
    stats.leaves += buf.len() as u64;
    if ctx.prune.enabled() {
        let thr = inc.best_edp();
        let scored = inc.engine.eval_batch_screened(
            &buf[..], thr, ctx.prune_stats());
        for ((s, sc), exp) in
            buf.iter().zip(scored).zip(expect.iter())
        {
            debug_assert_leaf(&sc, *exp);
            inc.offer_screened(s, sc, iter);
        }
    } else {
        let evals = inc.engine.eval_batch(&buf[..]);
        for ((s, e), exp) in
            buf.iter().zip(evals).zip(expect.iter())
        {
            debug_assert_leaf(&Screened::Exact(e), *exp);
            inc.offer_eval(s, e, iter);
        }
    }
    buf.clear();
    expect.clear();
}

/// Run the branch-and-bound exact search under `budget` and `cfg`.
///
/// Deterministic for iteration-only budgets ([`Budget::iters`]): the
/// tree walk is single-threaded and the engine's parallel batch
/// scoring is bit-deterministic; the RNG seed plays no role. The
/// result is the proven optimum iff `stats.certified`; otherwise a
/// cap or the budget tripped first and the result is the best
/// feasible strategy encountered.
pub fn optimize(w: &Workload, hw: &HwConfig, cfg: &ExactConfig,
                budget: &Budget, ctx: &EvalCtx)
                -> Result<ExactOutcome> {
    let l = w.len();
    let mut stats = ExactStats::default();
    let mut inc = Incumbent::with_ctx(w, hw, ctx);
    inc.offer(&Strategy::trivial(w), 0);
    if !ctx.seeds.is_empty() {
        // a warm incumbent only tightens pruning; certification and
        // the returned optimum value are seed-independent
        inc.offer_seeds(&ctx.seeds);
    }

    // per-layer exact-cost Pareto frontiers + admissible floors
    let mut spaces: Vec<LayerSpace> = Vec::with_capacity(l);
    let mut space_complete = true;
    for i in 0..l {
        if inc.stopped(budget) {
            return Ok(ExactOutcome {
                result: inc.finish(0),
                stats,
            });
        }
        let (space, complete) =
            build_layer_space(w, hw, i, cfg, &mut stats);
        space_complete &= complete;
        spaces.push(space);
    }

    // suffix floors: the cheapest possible completion of layers i..
    let mut suf_e = vec![0.0f64; l + 1];
    let mut suf_l = vec![0.0f64; l + 1];
    for i in (0..l).rev() {
        suf_e[i] = spaces[i].min_e + suf_e[i + 1];
        suf_l[i] = spaces[i].min_l + suf_l[i + 1];
    }

    let node_cap = (budget.max_iters as u64).min(cfg.max_nodes);
    let mut bnb = Bnb {
        w,
        spaces: &spaces,
        suf_e: &suf_e,
        suf_l: &suf_l,
        c2_bytes: hw.c2_bytes,
        node_cap,
        arena: vec![Node {
            parent: 0,
            cand: 0,
            fused_out: false,
            depth: 0,
            e: 0.0,
            l: 0.0,
            open: 0.0,
        }],
        heap: BinaryHeap::new(),
        seq: 0,
        dom: HashMap::new(),
        leaves: Vec::new(),
        leaf_edp: Vec::new(),
        stats,
    };
    bnb.stats.nodes_generated = 1;
    bnb.heap.push(HeapItem {
        bound: suf_e[0] * suf_l[0] * ROUNDING_SLACK,
        seq: 0,
        node: 0,
    });

    let mut pops: u64 = 0;
    let mut search_complete = false;
    loop {
        if bnb.leaves.len() >= LEAF_BATCH {
            flush_leaves(&mut inc, ctx, &mut bnb.leaves,
                         &mut bnb.leaf_edp, &mut bnb.stats,
                         pops as usize);
        }
        if inc.stopped(budget) {
            break;
        }
        if pops >= node_cap {
            bnb.stats.cap_hit = true;
            break;
        }
        let inc_edp = inc.best_edp().unwrap_or(f64::INFINITY);
        let top = match bnb.heap.peek() {
            Some(t) => t.bound,
            None => f64::INFINITY,
        };
        if top >= inc_edp {
            if !bnb.leaves.is_empty() {
                // pending leaves can only lower the incumbent, which
                // keeps this exit condition true — settle them, then
                // conclude on the next pass
                flush_leaves(&mut inc, ctx, &mut bnb.leaves,
                             &mut bnb.leaf_edp, &mut bnb.stats,
                             pops as usize);
                continue;
            }
            // every queued subtree is bounded at or above the final
            // incumbent: the incumbent is optimal over the space
            search_complete = true;
            break;
        }
        let item = bnb.heap.pop().expect("peeked a non-empty heap");
        pops += 1;
        bnb.stats.nodes_expanded += 1;
        inc.note_iters(pops as usize);
        bnb.expand(item.node, inc_edp);
        if bnb.stats.cap_hit {
            break;
        }
    }
    flush_leaves(&mut inc, ctx, &mut bnb.leaves, &mut bnb.leaf_edp,
                 &mut bnb.stats, pops as usize);

    bnb.stats.space_complete = space_complete;
    bnb.stats.certified =
        space_complete && search_complete && !bnb.stats.cap_hit;
    let stats = bnb.stats;
    Ok(ExactOutcome { result: inc.finish(pops as usize), stats })
}
