//! Mapping representation and divisor machinery.
//!
//! A [`LayerMapping`] holds the integer tiling factors of one layer in
//! the paper's factorized form: temporal factors at L0/L1/L2 plus the
//! spatial factor at the PE array; the DRAM (L3) temporal factor is the
//! exact co-factor so that the per-dimension product always equals the
//! problem size. A [`Strategy`] adds the binary fusion decisions.

pub mod decode;

use crate::workload::{Workload, DIM_C, DIM_K, NDIMS};

// Factor slots (mirror `python/compile/constants.py`).

/// Innermost (register-level) temporal factor slot.
pub const SLOT_T0: usize = 0;
/// L1-level temporal factor slot.
pub const SLOT_T1: usize = 1;
/// L2-level temporal factor slot.
pub const SLOT_T2: usize = 2;
/// Spatial (PE-array) factor slot.
pub const SLOT_S: usize = 3;
/// Number of factor slots per dimension.
pub const NSLOTS: usize = 4;

/// Integer tiling factors of one layer: `factors[d][slot]`.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerMapping {
    /// `factors[dim][slot]`; the DRAM co-factor is derived, not stored.
    pub factors: [[u64; NSLOTS]; NDIMS],
}

impl LayerMapping {
    /// The trivial mapping: everything iterated at DRAM.
    pub fn trivial() -> LayerMapping {
        LayerMapping { factors: [[1; NSLOTS]; NDIMS] }
    }

    /// Derived DRAM temporal factor for dim `d` of full size `n`.
    /// Integer-exact by construction for decoded mappings.
    pub fn t3(&self, d: usize, n: u64) -> f64 {
        let inner: u64 = self.factors[d].iter().product();
        n as f64 / inner as f64
    }

    /// Product of the sub-DRAM factors of dim `d`.
    pub fn inner(&self, d: usize) -> u64 {
        self.factors[d].iter().product()
    }

    /// Effective PEs = spatial K x spatial C.
    pub fn pes(&self) -> u64 {
        self.factors[DIM_K][SLOT_S] * self.factors[DIM_C][SLOT_S]
    }

    /// As an [7][4] f32 block for AOT staging.
    pub fn to_f32(&self) -> [[f32; NSLOTS]; NDIMS] {
        let mut out = [[1.0; NSLOTS]; NDIMS];
        for d in 0..NDIMS {
            for s in 0..NSLOTS {
                out[d][s] = self.factors[d][s] as f32;
            }
        }
        out
    }
}

/// A full deployment strategy: one mapping per layer plus the binary
/// fusion decision on every consecutive edge.
#[derive(Clone, Debug)]
pub struct Strategy {
    /// One tiling mapping per layer.
    pub mappings: Vec<LayerMapping>,
    /// `fuse[i]` — layers i and i+1 execute as one fusion group.
    pub fuse: Vec<bool>,
}

impl Strategy {
    /// All-trivial, no-fusion strategy for a workload.
    pub fn trivial(w: &Workload) -> Strategy {
        Strategy {
            mappings: vec![LayerMapping::trivial(); w.len()],
            fuse: vec![false; w.len().saturating_sub(1)],
        }
    }

    /// Fusion groups as [start, end] (inclusive) layer-index ranges.
    pub fn groups(&self) -> Vec<(usize, usize)> {
        let l = self.mappings.len();
        let mut out = Vec::new();
        let mut start = 0;
        for i in 0..l {
            let fused_next = i < l - 1 && self.fuse[i];
            if !fused_next {
                out.push((start, i));
                start = i + 1;
            }
        }
        out
    }

    /// Validity: every factor divides its dim (with exact DRAM
    /// co-factor), and spatial stays within the PE array.
    pub fn validate(&self, w: &Workload, pe_rows: u64, pe_cols: u64)
                    -> Result<(), String> {
        if self.mappings.len() != w.len() {
            return Err("mapping count != layer count".into());
        }
        for (l, m) in self.mappings.iter().enumerate() {
            for d in 0..NDIMS {
                let n = w.layers[l].dims[d] as u64;
                let inner = m.inner(d);
                if inner == 0 || n % inner != 0 {
                    return Err(format!(
                        "layer {l} dim {d}: inner product {inner} does \
                         not divide {n}"
                    ));
                }
            }
            if m.factors[DIM_K][SLOT_S] > pe_cols {
                return Err(format!("layer {l}: spatial K exceeds cols"));
            }
            if m.factors[DIM_C][SLOT_S] > pe_rows {
                return Err(format!("layer {l}: spatial C exceeds rows"));
            }
            for d in 0..NDIMS {
                if d != DIM_K && d != DIM_C && m.factors[d][SLOT_S] != 1 {
                    return Err(format!(
                        "layer {l}: spatial factor on non-K/C dim {d}"
                    ));
                }
            }
        }
        for (i, &f) in self.fuse.iter().enumerate() {
            if f && !w.fusible[i] {
                return Err(format!("edge {i} fused but not fusible"));
            }
        }
        Ok(())
    }
}

/// All divisors of n, ascending.
pub fn divisors(n: u64) -> Vec<u64> {
    let mut small = Vec::new();
    let mut big = Vec::new();
    let mut i = 1;
    while i * i <= n {
        if n % i == 0 {
            small.push(i);
            if i != n / i {
                big.push(n / i);
            }
        }
        i += 1;
    }
    big.reverse();
    small.extend(big);
    small
}

/// Divisor candidates log-subsampled to `k_max`, mirroring
/// `python/tests/conftest.py::divisors` (keeps 1 and n; interior evenly
/// subsampled by index).
pub fn divisor_candidates(n: u64, k_max: usize) -> Vec<u64> {
    let ds = divisors(n);
    if ds.len() <= k_max {
        return ds;
    }
    let mut idx: Vec<usize> = (0..k_max)
        .map(|i| {
            ((i as f64) * (ds.len() - 1) as f64 / (k_max - 1) as f64)
                .round() as usize
        })
        .collect();
    idx.dedup();
    idx.into_iter().map(|i| ds[i]).collect()
}

/// Smallest prime factor of `n` (`n` for primes, 1 for `n <= 1`).
/// Allocation-free — the decode capacity-repair loop calls this per
/// demotion, where materializing the full factorization was pure churn.
pub fn smallest_prime_factor(n: u64) -> u64 {
    if n <= 1 {
        return 1;
    }
    let mut p = 2;
    while p * p <= n {
        if n % p == 0 {
            return p;
        }
        p += 1;
    }
    n
}

/// Prime factorization as (prime, multiplicity) pairs.
pub fn prime_factors(mut n: u64) -> Vec<(u64, u32)> {
    let mut out = Vec::new();
    let mut p = 2;
    while p * p <= n {
        if n % p == 0 {
            let mut m = 0;
            while n % p == 0 {
                n /= p;
                m += 1;
            }
            out.push((p, m));
        }
        p += 1;
    }
    if n > 1 {
        out.push((n, 1));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, ensure, Config};
    use crate::workload::zoo;

    #[test]
    fn divisors_of_12() {
        assert_eq!(divisors(12), vec![1, 2, 3, 4, 6, 12]);
        assert_eq!(divisors(1), vec![1]);
        assert_eq!(divisors(17), vec![1, 17]);
    }

    #[test]
    fn candidates_subsample_keeps_endpoints() {
        let c = divisor_candidates(25088, 8);
        assert!(c.len() <= 8);
        assert_eq!(*c.first().unwrap(), 1);
        assert_eq!(*c.last().unwrap(), 25088);
        assert!(c.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn smallest_prime_factor_matches_factorization() {
        for n in 1..2000u64 {
            let expect = prime_factors(n)
                .first()
                .map(|&(p, _)| p)
                .unwrap_or(1);
            assert_eq!(smallest_prime_factor(n), expect, "n={n}");
        }
    }

    #[test]
    fn prime_factors_roundtrip_prop() {
        check("prime-factor-product", &Config::default(),
              |r, size| 1 + r.below((65536.0 * size) as usize + 2) as u64,
              |&n| {
                  let product: u64 = prime_factors(n)
                      .iter()
                      .map(|&(p, m)| p.pow(m))
                      .product();
                  ensure(product == n.max(1),
                         format!("{n} factored wrong"))
              });
    }

    #[test]
    fn divisors_all_divide_prop() {
        check("divisors-divide", &Config::default(),
              |r, size| 1 + r.below((4096.0 * size) as usize + 2) as u64,
              |&n| {
                  for d in divisors(n) {
                      if n % d != 0 {
                          return Err(format!("{d} !| {n}"));
                      }
                  }
                  Ok(())
              });
    }

    #[test]
    fn groups_partition_layers() {
        let w = zoo::vgg16();
        let mut s = Strategy::trivial(&w);
        // fuse a couple of legal edges
        s.fuse[0] = true;
        s.fuse[4] = true;
        let groups = s.groups();
        let covered: usize = groups.iter().map(|(a, b)| b - a + 1).sum();
        assert_eq!(covered, w.len());
        assert_eq!(groups[0], (0, 1));
        // groups must be contiguous and ordered
        for win in groups.windows(2) {
            assert_eq!(win[0].1 + 1, win[1].0);
        }
    }

    #[test]
    fn trivial_strategy_validates() {
        for w in zoo::table1_suite() {
            let s = Strategy::trivial(&w);
            s.validate(&w, 32, 32).unwrap();
        }
    }

    #[test]
    fn validate_catches_bad_divisor() {
        let w = zoo::vgg16();
        let mut s = Strategy::trivial(&w);
        s.mappings[0].factors[DIM_K][SLOT_T0] = 5; // 64 % 5 != 0
        assert!(s.validate(&w, 32, 32).is_err());
    }

    #[test]
    fn validate_catches_spatial_overflow() {
        let w = zoo::vgg16();
        let mut s = Strategy::trivial(&w);
        s.mappings[0].factors[DIM_K][SLOT_S] = 64; // > 32 cols
        assert!(s.validate(&w, 32, 32).is_err());
    }

    #[test]
    fn validate_catches_illegal_fusion() {
        let w = zoo::resnet18();
        let mut s = Strategy::trivial(&w);
        let bad = w.fusible.iter().position(|&f| !f).unwrap();
        s.fuse[bad] = true;
        assert!(s.validate(&w, 32, 32).is_err());
    }
}
