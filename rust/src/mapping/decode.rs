//! Continuous-to-discrete decoding (paper Sec 3.1 / end of Sec 3.3).
//!
//! After gradient convergence the relaxed parameters are decoded into
//! integer tiling factors and binary fusion decisions:
//!
//! 1. **Prime allocation** — for each (layer, dim) the prime powers of
//!    the problem size are distributed greedily across the factor slots
//!    so each slot tracks its continuous target `2^theta` as closely as
//!    possible *while the product exactly divides the dimension* (the
//!    leftover becomes the DRAM co-factor). This guarantees
//!    divisibility by construction — stronger than nearest-divisor
//!    rounding, which can produce non-dividing products.
//! 2. **Spatial capping** — spatial targets are clamped to the PE array
//!    geometry before allocation.
//! 3. **Capacity repair** — if a decoded layer overflows the scratchpad
//!    or accumulator, factors are demoted from L2/L1 toward DRAM until it
//!    fits; if a fusion group overflows the scratchpad, the weakest
//!    (smallest sigma) edge in the group is cut. Repair preserves
//!    divisibility (it only moves whole primes between slots).

use crate::config::HwConfig;
use crate::costmodel;
use crate::costmodel::tables::{DimTable, WorkloadTables};
use crate::mapping::{divisors, prime_factors, smallest_prime_factor,
                     LayerMapping, Strategy, NSLOTS, SLOT_S, SLOT_T1,
                     SLOT_T2};
use crate::workload::{Workload, DIM_C, DIM_K, NDIMS};

/// Continuous optimization state to decode (log2-space theta, sigmoid'd
/// sigma in [0,1]).
#[derive(Clone, Debug)]
pub struct Relaxed {
    /// `theta[l][d][slot]` in log2 space.
    pub theta: Vec<[[f64; NSLOTS]; NDIMS]>,
    /// `sigma[i]` in [0, 1] for edge i -> i+1.
    pub sigma: Vec<f64>,
}

impl Relaxed {
    /// A neutral starting point: all factors ~1, sigma 0.5.
    pub fn neutral(w: &Workload) -> Relaxed {
        Relaxed {
            theta: vec![[[0.0; NSLOTS]; NDIMS]; w.len()],
            sigma: vec![0.5; w.len().saturating_sub(1)],
        }
    }
}

/// Decode one dimension: snap each slot to the divisor of `n` nearest to
/// its continuous target in log space (exactly the Gumbel-Softmax argmax
/// the optimizer's straight-through forward evaluated, at zero noise),
/// then *trim* excess primes until the slot product divides `n` — so the
/// decoded point stays as close as possible to what the gradient search
/// actually scored. Slot caps bound the snap (u64::MAX = unbounded).
fn allocate_primes(n: u64, targets: [f64; NSLOTS], caps: [u64; NSLOTS])
                   -> [u64; NSLOTS] {
    allocate_primes_from(&divisors(n), &prime_factors(n), targets, caps)
}

/// [`allocate_primes`] over precomputed divisor/prime tables (the
/// shared [`WorkloadTables`] hands these out, so batch decoding stops
/// re-factoring the same dimension sizes per candidate).
fn allocate_primes_from(divs: &[u64], primes: &[(u64, u32)],
                        targets: [f64; NSLOTS], caps: [u64; NSLOTS])
                        -> [u64; NSLOTS] {
    let mut fac = [1u64; NSLOTS];
    for s in 0..NSLOTS {
        let t = targets[s].max(1.0).ln();
        fac[s] = divs
            .iter()
            .copied()
            .filter(|&d| d <= caps[s])
            .min_by(|&a, &b| {
                let da = ((a as f64).ln() - t).abs();
                let db = ((b as f64).ln() - t).abs();
                da.partial_cmp(&db).unwrap()
            })
            .unwrap_or(1);
    }
    // Trim: for every prime of n, the slots may jointly use at most its
    // multiplicity in n. Remove excess from the slot whose factor is
    // furthest ABOVE its target (least harm), preferring temporal slots.
    for &(p, mp) in primes {
        let mult = |f: u64| -> u32 {
            let mut f = f;
            let mut c = 0;
            while f % p == 0 {
                f /= p;
                c += 1;
            }
            c
        };
        let mut total: u32 = fac.iter().map(|&f| mult(f)).sum();
        while total > mp {
            // pick the slot with p available whose log-excess over target
            // is largest
            let s = (0..NSLOTS)
                .filter(|&s| fac[s] % p == 0)
                .max_by(|&a, &b| {
                    let ea = (fac[a] as f64).ln()
                        - targets[a].max(1.0).ln();
                    let eb = (fac[b] as f64).ln()
                        - targets[b].max(1.0).ln();
                    ea.partial_cmp(&eb).unwrap()
                })
                .expect("some slot must hold prime p");
            fac[s] /= p;
            total -= 1;
        }
    }
    fac
}

/// Decode one layer's theta block into a legal mapping (standalone:
/// factors the dimension sizes itself; batch callers go through
/// [`decode_with`] and the shared tables instead).
pub fn decode_layer(theta: &[[f64; NSLOTS]; NDIMS], dims: &[usize; NDIMS],
                    hw: &HwConfig) -> LayerMapping {
    let mut m = LayerMapping::trivial();
    for d in 0..NDIMS {
        let n = dims[d] as u64;
        if n == 1 {
            continue;
        }
        m.factors[d] = allocate_slots(theta, d, n, hw, &divisors(n),
                                      &prime_factors(n));
    }
    m
}

/// [`decode_layer`] over the shared per-workload tables.
fn decode_layer_with(theta: &[[f64; NSLOTS]; NDIMS], l: usize,
                     hw: &HwConfig, tables: &WorkloadTables)
                     -> LayerMapping {
    let mut m = LayerMapping::trivial();
    for d in 0..NDIMS {
        let dt: &DimTable = tables.dim(l, d);
        if dt.n == 1 {
            continue;
        }
        m.factors[d] =
            allocate_slots(theta, d, dt.n, hw, &dt.divisors, &dt.primes);
    }
    m
}

/// Shared slot allocation for one dimension (targets + caps + snap).
fn allocate_slots(theta: &[[f64; NSLOTS]; NDIMS], d: usize, n: u64,
                  hw: &HwConfig, divs: &[u64],
                  primes: &[(u64, u32)]) -> [u64; NSLOTS] {
    let mut targets = [0.0; NSLOTS];
    for s in 0..NSLOTS {
        targets[s] = theta[d][s].exp2().clamp(1.0, n as f64);
    }
    let mut caps = [u64::MAX; NSLOTS];
    caps[SLOT_S] = match d {
        DIM_K => hw.pe_cols as u64,
        DIM_C => hw.pe_rows as u64,
        _ => 1,
    };
    if caps[SLOT_S] == 1 {
        targets[SLOT_S] = 1.0;
    }
    allocate_primes_from(divs, primes, targets, caps)
}

/// Demote one prime from the given slot toward DRAM (returns false when
/// the slot is already 1). Used by capacity repair.
fn demote_slot(m: &mut LayerMapping, d: usize, slot: usize) -> bool {
    let f = m.factors[d][slot];
    if f <= 1 {
        return false;
    }
    m.factors[d][slot] /= smallest_prime_factor(f);
    true
}

/// Shrink a layer's on-chip residency until scratchpad + accumulator fit.
fn repair_layer(m: &mut LayerMapping, dims: &[usize; NDIMS], hw: &HwConfig) {
    for _ in 0..256 {
        let c = costmodel::components(m, dims);
        let l2 = (c.s_w2 + c.s_i2) * hw.element_bytes;
        let l1 = c.s_o1 * hw.acc_bytes;
        if l2 <= hw.c2_bytes && l1 <= hw.c1_bytes {
            return;
        }
        // demote the dim with the largest L2-resident extent first,
        // preferring the outermost on-chip temporal level (T2, then T1)
        let mut done = false;
        for slot in [SLOT_T2, SLOT_T1] {
            let d_max = (0..NDIMS)
                .filter(|&d| m.factors[d][slot] > 1)
                .max_by(|&a, &b| {
                    m.factors[a][slot].cmp(&m.factors[b][slot])
                });
            if let Some(d) = d_max {
                if demote_slot(m, d, slot) {
                    done = true;
                    break;
                }
            }
        }
        if !done {
            // last resort: demote T0
            let any = (0..NDIMS).find(|&d| m.factors[d][0] > 1);
            match any {
                Some(d) => {
                    demote_slot(m, d, 0);
                }
                None => return, // minimal mapping; nothing left to shrink
            }
        }
    }
}

/// Lift every fusible edge of a relaxed state above the decode
/// threshold while preserving the learned sigma ordering — the
/// fusion-greedy incumbent variant of the gradient search: all legal
/// edges fuse, and the group-capacity repair then cuts lowest-sigma
/// edges first, so the gradient's ranking still decides which fusions
/// survive.
pub fn fusion_greedy(relaxed: &Relaxed, w: &Workload) -> Relaxed {
    let mut greedy = relaxed.clone();
    for (i, s) in greedy.sigma.iter_mut().enumerate() {
        if w.fusible[i] {
            // keep ordering information, lift above the threshold
            *s = 0.51 + 0.49 * *s;
        }
    }
    greedy
}

/// Decode a full relaxed state into a hardware-valid [`Strategy`]
/// (standalone entry point: builds the divisor/prime tables for this
/// one call). Searches that decode many candidates of the same
/// workload should build one [`WorkloadTables`] and use
/// [`decode_with`] — the tables are exactly the per-dimension
/// factorizations this function otherwise recomputes per candidate.
pub fn decode(relaxed: &Relaxed, w: &Workload, hw: &HwConfig) -> Strategy {
    decode_with(relaxed, w, hw, &WorkloadTables::new(w))
}

/// [`decode`] over shared precomputed tables (the per-candidate hot
/// path of every search). Besides the memoized factorizations, the
/// fusion-group repair here is allocation-light: the per-layer L2
/// footprints are computed once (mappings never change during edge
/// cutting) and the group scan walks the fuse bits directly instead of
/// cloning the strategy per iteration.
pub fn decode_with(relaxed: &Relaxed, w: &Workload, hw: &HwConfig,
                   tables: &WorkloadTables) -> Strategy {
    assert_eq!(relaxed.theta.len(), w.len());
    let l_n = w.len();
    let mappings: Vec<LayerMapping> = (0..l_n)
        .map(|l| {
            let mut m = decode_layer_with(&relaxed.theta[l], l, hw,
                                          tables);
            repair_layer(&mut m, &w.layers[l].dims, hw);
            m
        })
        .collect();

    // fusion: threshold sigma, mask illegal edges
    let mut fuse: Vec<bool> = (0..l_n.saturating_sub(1))
        .map(|i| relaxed.sigma[i] > 0.5 && w.fusible[i])
        .collect();

    // per-layer L2 footprints: invariant under edge cutting
    let l2_bytes: Vec<f64> = (0..l_n)
        .map(|i| {
            let c = costmodel::components(&mappings[i],
                                          &w.layers[i].dims);
            (c.s_w2 + c.s_i2) * hw.element_bytes
        })
        .collect();

    // group-capacity repair: cut weakest edges until every group fits
    loop {
        // first violating multi-layer group (maximal fused run)
        let violated = costmodel::first_group_overflow(
            l_n, &fuse, hw.c2_bytes, true, |i| l2_bytes[i]);
        match violated {
            None => break,
            Some((a, b, _)) => {
                // cut the lowest-sigma edge inside the group
                let cut = (a..b)
                    .filter(|&i| fuse[i])
                    .min_by(|&x, &y| {
                        relaxed.sigma[x]
                            .partial_cmp(&relaxed.sigma[y])
                            .unwrap()
                    })
                    .expect("multi-layer group must have a fused edge");
                fuse[cut] = false;
            }
        }
    }

    Strategy { mappings, fuse }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{load_config, repo_root};
    use crate::util::prop::{check, ensure, Config};
    use crate::util::rng::Rng;
    use crate::workload::zoo;

    fn hw() -> HwConfig {
        load_config(&repo_root(), "large").unwrap()
    }

    #[test]
    fn allocate_primes_exact_targets() {
        // 64 = 2^6; targets 4,4,2,2 -> exactly that split
        let f = allocate_primes(64, [4.0, 4.0, 2.0, 2.0],
                                [u64::MAX; 4]);
        assert_eq!(f.iter().product::<u64>(), 64);
        assert_eq!(f, [4, 4, 2, 2]);
    }

    #[test]
    fn allocate_primes_respects_caps() {
        let f = allocate_primes(64, [64.0, 1.0, 1.0, 64.0],
                                [u64::MAX, u64::MAX, u64::MAX, 8]);
        assert!(f[3] <= 8);
        assert_eq!(64 % f.iter().product::<u64>(), 0);
    }

    #[test]
    fn allocate_primes_leftover_goes_to_dram() {
        // all targets 1 -> nothing allocated, all in the derived factor
        let f = allocate_primes(224, [1.0; 4], [u64::MAX; 4]);
        assert_eq!(f, [1, 1, 1, 1]);
    }

    #[test]
    fn decode_layer_always_divides() {
        let hw = hw();
        let w = zoo::vgg16();
        let mut rng = Rng::new(99);
        for _ in 0..50 {
            let l = rng.below(w.len());
            let mut theta = [[0.0; NSLOTS]; NDIMS];
            for d in 0..NDIMS {
                for s in 0..NSLOTS {
                    theta[d][s] = rng.range(-2.0, 8.0);
                }
            }
            let m = decode_layer(&theta, &w.layers[l].dims, &hw);
            for d in 0..NDIMS {
                let n = w.layers[l].dims[d] as u64;
                assert_eq!(n % m.inner(d), 0,
                           "dim {d}: {:?} !| {n}", m.factors[d]);
            }
            assert!(m.factors[DIM_K][SLOT_S] <= hw.pe_cols as u64);
            assert!(m.factors[DIM_C][SLOT_S] <= hw.pe_rows as u64);
        }
    }

    #[test]
    fn decode_strategy_always_feasible_prop() {
        // The paper's central decoding guarantee: ANY relaxed state
        // decodes to a hardware-valid strategy.
        let hw = hw();
        let suite = zoo::table1_suite();
        check("decode-feasible", &Config { cases: 48, seed: 7 },
              |r, size| {
                  let w = r.below(suite.len());
                  let workload = &suite[w];
                  let mut relaxed = Relaxed::neutral(workload);
                  for l in 0..workload.len() {
                      for d in 0..NDIMS {
                          for s in 0..NSLOTS {
                              relaxed.theta[l][d][s] =
                                  r.range(-3.0, 14.0 * size);
                          }
                      }
                  }
                  for i in 0..relaxed.sigma.len() {
                      relaxed.sigma[i] = r.f64();
                  }
                  (w, relaxed)
              },
              |(wi, relaxed)| {
                  let workload = &suite[*wi];
                  let s = decode(relaxed, workload, &hw);
                  costmodel::feasible(&s, workload, &hw)
                      .map_err(|e| format!("{}: {e}", workload.name))
              });
    }

    #[test]
    fn decode_with_tables_matches_standalone() {
        let hw = hw();
        let mut rng = Rng::new(0xD0);
        for w in zoo::table1_suite() {
            let tables = WorkloadTables::new(&w);
            for _ in 0..8 {
                let mut relaxed = Relaxed::neutral(&w);
                for l in 0..w.len() {
                    for d in 0..NDIMS {
                        for s in 0..NSLOTS {
                            relaxed.theta[l][d][s] =
                                rng.range(-3.0, 12.0);
                        }
                    }
                }
                for i in 0..relaxed.sigma.len() {
                    relaxed.sigma[i] = rng.f64();
                }
                let a = decode(&relaxed, &w, &hw);
                let b = decode_with(&relaxed, &w, &hw, &tables);
                assert_eq!(a.mappings, b.mappings, "{}", w.name);
                assert_eq!(a.fuse, b.fuse, "{}", w.name);
            }
        }
    }

    #[test]
    fn decode_tracks_targets_when_feasible() {
        let hw = hw();
        let w = zoo::vgg16();
        // ask for spatial 32x32 + modest L2 tiles on conv3_1
        let mut relaxed = Relaxed::neutral(&w);
        relaxed.theta[4][DIM_K][SLOT_S] = 5.0; // 32
        relaxed.theta[4][DIM_C][SLOT_S] = 5.0; // 32
        let s = decode(&relaxed, &w, &hw);
        assert_eq!(s.mappings[4].factors[DIM_K][SLOT_S], 32);
        assert_eq!(s.mappings[4].factors[DIM_C][SLOT_S], 32);
    }

    #[test]
    fn group_repair_cuts_weakest_edge() {
        let hw = hw();
        let w = zoo::vgg16();
        let mut relaxed = Relaxed::neutral(&w);
        // big L2 residency on the first three layers + fuse both edges
        for l in 0..3 {
            for d in 0..NDIMS {
                relaxed.theta[l][d][SLOT_T2] =
                    (w.layers[l].dims[d] as f64).log2();
            }
        }
        relaxed.sigma[0] = 0.9;
        relaxed.sigma[1] = 0.7; // weaker: cut first if needed
        let s = decode(&relaxed, &w, &hw);
        costmodel::feasible(&s, &w, &hw).unwrap();
    }
}
