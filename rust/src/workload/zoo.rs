//! The evaluation model zoo (paper Sec 4.1 / Table 1): GPT-3 6.7B
//! (MHA + FFN of one decoder block, replicated 32x), VGG19, VGG16,
//! MobileNetV1, ResNet18 — all expressed in the unified 7-dim space —
//! plus three exhaustively-enumerable `micro-*` models whose full
//! divisor/fusion spaces a test can brute-force (the exact mapper's
//! certification targets).
//!
//! GEMM convention (DESIGN.md §2): P = rows (M), K = output columns,
//! C = reduction dimension, N = batch (e.g. attention heads); R = S = 1.

use super::{Layer, LayerKind, Workload};

fn conv(name: &str, k: usize, c: usize, pq: usize, rs: usize) -> Layer {
    Layer::new(name, LayerKind::Conv, [1, k, c, pq, pq, rs, rs])
}

fn dw(name: &str, k: usize, pq: usize) -> Layer {
    // depthwise: one input channel per output channel (C folded to 1)
    Layer::new(name, LayerKind::Depthwise, [1, k, 1, pq, pq, 3, 3])
}

fn pw(name: &str, k: usize, c: usize, pq: usize) -> Layer {
    Layer::new(name, LayerKind::Pointwise, [1, k, c, pq, pq, 1, 1])
}

fn fc(name: &str, k: usize, c: usize) -> Layer {
    Layer::new(name, LayerKind::Fc, [1, k, c, 1, 1, 1, 1])
}

fn gemm(name: &str, batch: usize, m: usize, kout: usize, cred: usize)
        -> Layer {
    Layer::new(name, LayerKind::Gemm, [batch, kout, cred, m, 1, 1, 1])
}

/// GPT-3 6.7B decoder block (paper Sec 4.3.2): d_model=4096, 32 heads,
/// head_dim=128, FFN hidden 16384 (stated in the paper); sequence length
/// 2048, batch 1; 32 blocks replicated.
///
/// Edges: q/k/v projections are parallel consumers of the same input and
/// the score GEMM consumes two producers, so those edges are blocked;
/// the fusible chain edges are scores->attnV? (attnV also has two
/// producers) — in practice the legal fusions are proj->scores-candidates
/// along the single-producer path and ffn1->ffn2.
pub fn gpt3_6_7b() -> Workload {
    let seq = 2048;
    let d = 4096;
    let heads = 32;
    let hd = 128;
    let ffn = 16384;
    let layers = vec![
        gemm("q_proj", 1, seq, d, d),
        gemm("k_proj", 1, seq, d, d),
        gemm("v_proj", 1, seq, d, d),
        // per-head scores: [seq, hd] x [hd, seq], batched over heads
        gemm("attn_scores", heads, seq, seq, hd),
        // per-head context: [seq, seq] x [seq, hd]
        gemm("attn_context", heads, seq, hd, seq),
        gemm("out_proj", 1, seq, d, d),
        gemm("ffn_up", 1, seq, ffn, d),
        gemm("ffn_down", 1, seq, d, ffn),
    ];
    // blocked: q->k, k->v (parallel projections, not producer-consumer),
    // v->scores (scores consumes q AND k), scores->context ok shape-wise?
    // context consumes scores AND v (two producers) => blocked,
    // context->out_proj single producer => fusible, ffn_up->ffn_down ok.
    Workload::chain("gpt3-6.7b", layers, &[0, 1, 2, 4], 32.0)
}

/// VGG19: 16 conv layers + 3 FC (paper ref [21]).
pub fn vgg19() -> Workload {
    let layers = vec![
        conv("conv1_1", 64, 3, 224, 3),
        conv("conv1_2", 64, 64, 224, 3),
        conv("conv2_1", 128, 64, 112, 3),
        conv("conv2_2", 128, 128, 112, 3),
        conv("conv3_1", 256, 128, 56, 3),
        conv("conv3_2", 256, 256, 56, 3),
        conv("conv3_3", 256, 256, 56, 3),
        conv("conv3_4", 256, 256, 56, 3),
        conv("conv4_1", 512, 256, 28, 3),
        conv("conv4_2", 512, 512, 28, 3),
        conv("conv4_3", 512, 512, 28, 3),
        conv("conv4_4", 512, 512, 28, 3),
        conv("conv5_1", 512, 512, 14, 3),
        conv("conv5_2", 512, 512, 14, 3),
        conv("conv5_3", 512, 512, 14, 3),
        conv("conv5_4", 512, 512, 14, 3),
        fc("fc6", 4096, 25088),
        fc("fc7", 4096, 4096),
        fc("fc8", 1000, 4096),
    ];
    // conv5_4 -> fc6 crosses the flatten boundary (25088 = 512*7*7);
    // shape check blocks it automatically, but make it explicit.
    Workload::chain("vgg19", layers, &[15], 1.0)
}

/// VGG16: 13 conv layers + 3 FC.
pub fn vgg16() -> Workload {
    let layers = vec![
        conv("conv1_1", 64, 3, 224, 3),
        conv("conv1_2", 64, 64, 224, 3),
        conv("conv2_1", 128, 64, 112, 3),
        conv("conv2_2", 128, 128, 112, 3),
        conv("conv3_1", 256, 128, 56, 3),
        conv("conv3_2", 256, 256, 56, 3),
        conv("conv3_3", 256, 256, 56, 3),
        conv("conv4_1", 512, 256, 28, 3),
        conv("conv4_2", 512, 512, 28, 3),
        conv("conv4_3", 512, 512, 28, 3),
        conv("conv5_1", 512, 512, 14, 3),
        conv("conv5_2", 512, 512, 14, 3),
        conv("conv5_3", 512, 512, 14, 3),
        fc("fc6", 4096, 25088),
        fc("fc7", 4096, 4096),
        fc("fc8", 1000, 4096),
    ];
    Workload::chain("vgg16", layers, &[12], 1.0)
}

/// MobileNetV1 (alpha=1.0, 224x224): first conv + 13 depthwise-separable
/// blocks + FC (paper ref [20]).
pub fn mobilenet_v1() -> Workload {
    let mut layers = vec![conv("conv1", 32, 3, 112, 3)];
    // (in_ch, out_ch, spatial of the pointwise output)
    let blocks: [(usize, usize, usize); 13] = [
        (32, 64, 112),
        (64, 128, 56),
        (128, 128, 56),
        (128, 256, 28),
        (256, 256, 28),
        (256, 512, 14),
        (512, 512, 14),
        (512, 512, 14),
        (512, 512, 14),
        (512, 512, 14),
        (512, 512, 14),
        (512, 1024, 7),
        (1024, 1024, 7),
    ];
    for (i, &(cin, cout, sp)) in blocks.iter().enumerate() {
        layers.push(dw(&format!("dw{}", i + 1), cin, sp));
        layers.push(pw(&format!("pw{}", i + 1), cout, cin, sp));
    }
    layers.push(fc("fc", 1000, 1024));
    Workload::chain("mobilenet-v1", layers, &[], 1.0)
}

/// ResNet18 (ImageNet): conv1 + 8 basic blocks (2 conv each) + 3
/// projection shortcuts + FC (paper ref [19]). Residual joins block
/// fusion at every block output (the add has two producers).
pub fn resnet18() -> Workload {
    let mut layers = vec![conv("conv1", 64, 3, 112, 7)];
    let mut blocked = Vec::new();
    let stages: [(usize, usize, usize, bool); 8] = [
        // (in_ch, out_ch, spatial, has_projection)
        (64, 64, 56, false),
        (64, 64, 56, false),
        (64, 128, 28, true),
        (128, 128, 28, false),
        (128, 256, 14, true),
        (256, 256, 14, false),
        (256, 512, 7, true),
        (512, 512, 7, false),
    ];
    for (b, &(cin, cout, sp, proj)) in stages.iter().enumerate() {
        layers.push(conv(&format!("b{}_conv1", b + 1), cout, cin, sp, 3));
        layers.push(conv(&format!("b{}_conv2", b + 1), cout, cout, sp, 3));
        // the block output feeds a residual add (two producers):
        // block fusion across the add is illegal.
        blocked.push(layers.len() - 2); // conv2 -> next (join boundary)
        if proj {
            layers.push(pw(&format!("b{}_down", b + 1), cout, cin, sp));
            blocked.push(layers.len() - 2); // conv2 -> projection: not a
                                            // producer-consumer pair
        }
    }
    layers.push(fc("fc", 1000, 512));
    blocked.push(layers.len() - 2);
    Workload::chain("resnet18", layers, &blocked, 1.0)
}

/// Two fused 4x4 FC layers — the smallest fusible chain. The full
/// divisor/fusion space is ~10^5 strategies: exhaustively enumerable
/// in a debug-build test, yet rich enough to exercise tiling, fusion,
/// and capacity interplay.
pub fn micro_mlp() -> Workload {
    let layers = vec![fc("fc1", 4, 4), fc("fc2", 4, 4)];
    Workload::chain("micro-mlp", layers, &[], 1.0)
}

/// Two chained tiny GEMMs with asymmetric shapes (4->2 channel
/// contraction), fusible at the single edge.
pub fn micro_gemm() -> Workload {
    let layers = vec![gemm("g1", 1, 2, 4, 2), gemm("g2", 1, 2, 2, 4)];
    Workload::chain("micro-gemm", layers, &[], 1.0)
}

/// Three chained 2-channel pointwise layers — two fusible edges, so
/// all four fusion masks are reachable.
pub fn micro_chain() -> Workload {
    let layers = vec![
        pw("pw1", 2, 2, 1),
        pw("pw2", 2, 2, 1),
        pw("pw3", 2, 2, 1),
    ];
    Workload::chain("micro-chain", layers, &[], 1.0)
}

/// The exhaustively-enumerable micro models (exact-mapper oracle
/// targets; not part of the Table-1 suite).
pub fn micro_suite() -> Vec<Workload> {
    vec![micro_mlp(), micro_gemm(), micro_chain()]
}

/// The full Table-1 suite in paper order.
pub fn table1_suite() -> Vec<Workload> {
    vec![gpt3_6_7b(), vgg19(), vgg16(), mobilenet_v1(), resnet18()]
}

/// Canonical names of the built-in zoo models (each resolvable via
/// [`by_name`]; the serving layer's `workloads` verb lists these
/// alongside the checked-in spec files).
pub fn names() -> [&'static str; 8] {
    ["gpt3-6.7b", "vgg19", "vgg16", "mobilenet-v1", "resnet18",
     "micro-mlp", "micro-gemm", "micro-chain"]
}

/// Look a workload up by CLI name.
pub fn by_name(name: &str) -> Option<Workload> {
    match name {
        "gpt3" | "gpt3-6.7b" | "gpt3_6_7b" => Some(gpt3_6_7b()),
        "vgg19" => Some(vgg19()),
        "vgg16" => Some(vgg16()),
        "mobilenet" | "mobilenet-v1" | "mobilenetv1" => Some(mobilenet_v1()),
        "resnet18" => Some(resnet18()),
        "micro-mlp" => Some(micro_mlp()),
        "micro-gemm" => Some(micro_gemm()),
        "micro-chain" => Some(micro_chain()),
        _ => None,
    }
}

/// Single-layer operator set for the cost-model validation experiment
/// (paper Sec 4.2: standard, depthwise, pointwise, large-kernel
/// convolutions, and fully-connected layers).
pub fn validation_operators() -> Vec<Layer> {
    vec![
        conv("std_conv_small", 64, 64, 56, 3),
        conv("std_conv_large", 256, 128, 28, 3),
        conv("std_conv_wide", 512, 256, 14, 3),
        dw("depthwise_56", 128, 56),
        dw("depthwise_14", 512, 14),
        pw("pointwise_56", 128, 64, 56),
        pw("pointwise_7", 1024, 512, 7),
        conv("large_kernel_7x7", 64, 3, 112, 7),
        conv("large_kernel_5x5", 96, 48, 28, 5),
        fc("fc_mid", 4096, 4096),
        fc("fc_big", 4096, 25088),
        gemm("gemm_attn", 32, 2048, 2048, 128),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::DIM_K;

    #[test]
    fn suite_fits_l_max() {
        for w in table1_suite() {
            assert!(w.len() <= 32, "{} has {} layers", w.name, w.len());
            assert_eq!(w.fusible.len(), w.len() - 1);
        }
    }

    #[test]
    fn layer_counts_match_architectures() {
        assert_eq!(vgg16().len(), 16);
        assert_eq!(vgg19().len(), 19);
        assert_eq!(mobilenet_v1().len(), 28);
        assert_eq!(resnet18().len(), 21);
        assert_eq!(gpt3_6_7b().len(), 8);
    }

    #[test]
    fn gpt_ffn_edge_is_fusible() {
        let g = gpt3_6_7b();
        // ffn_up -> ffn_down is the 7th edge (index 6)
        assert!(g.fusible[6]);
        // parallel projections must not fuse
        assert!(!g.fusible[0]);
        assert!(!g.fusible[1]);
    }

    #[test]
    fn resnet_join_edges_blocked() {
        let r = resnet18();
        // within-block conv1->conv2 edges should be fusible somewhere
        assert!(r.fusible.iter().any(|&f| f));
        // fc edge blocked
        assert!(!r.fusible[r.len() - 2]);
    }

    #[test]
    fn vgg_ops_scale() {
        // VGG19 is strictly more work than VGG16
        assert!(vgg19().total_ops() > vgg16().total_ops());
    }

    #[test]
    fn gpt_dims_sane() {
        let g = gpt3_6_7b();
        assert_eq!(g.layers[6].dims[DIM_K], 16384); // FFN hidden
        assert_eq!(g.replicas, 32.0);
    }

    #[test]
    fn by_name_roundtrip() {
        for n in ["gpt3", "vgg19", "vgg16", "mobilenet", "resnet18"] {
            assert!(by_name(n).is_some(), "{n}");
        }
        assert!(by_name("alexnet").is_none());
        // every canonical name resolves to a workload of that name
        for n in names() {
            let w = by_name(n).expect(n);
            assert_eq!(w.name, n);
        }
    }

    #[test]
    fn micro_models_are_tiny_and_fusible() {
        for w in micro_suite() {
            assert!(w.len() <= 3, "{}", w.name);
            // every edge fusible: the exact mapper's fusion branching
            // is fully exercised
            assert!(w.fusible.iter().all(|&f| f), "{}", w.name);
            for l in &w.layers {
                assert!(l.dims.iter().all(|&d| d <= 4), "{}", l.name);
            }
        }
        assert_eq!(micro_mlp().fusible.len(), 1);
        assert_eq!(micro_chain().fusible.len(), 2);
    }

    #[test]
    fn validation_operators_diverse() {
        let ops = validation_operators();
        assert!(ops.len() >= 10);
        use crate::workload::LayerKind::*;
        for kind in [Conv, Depthwise, Pointwise, Fc, Gemm] {
            assert!(ops.iter().any(|l| l.kind == kind), "{kind:?} missing");
        }
    }
}
