//! DNN workload representation: the DAG of the problem formulation
//! (paper Sec 2.3) in the unified 7-dim problem space of Sec 3.1.1.

pub mod zoo;

/// Problem-dimension indices (mirror `python/compile/constants.py`).
pub const DIM_N: usize = 0;
pub const DIM_K: usize = 1;
pub const DIM_C: usize = 2;
pub const DIM_P: usize = 3;
pub const DIM_Q: usize = 4;
pub const DIM_R: usize = 5;
pub const DIM_S: usize = 6;
pub const NDIMS: usize = 7;
pub const DIM_NAMES: [&str; 7] = ["N", "K", "C", "P", "Q", "R", "S"];

/// Operator class of a layer (affects the validation operator mix and
/// how dims were derived, not the cost equations themselves).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    /// Standard convolution.
    Conv,
    /// Depthwise convolution (modeled as C=1 per output channel).
    Depthwise,
    /// 1x1 (pointwise) convolution.
    Pointwise,
    /// General matrix multiply (P = rows M, K = cols, C = reduction).
    Gemm,
    /// Fully-connected layer (GEMM with P = 1).
    Fc,
}

/// One computational layer (a DAG vertex).
#[derive(Clone, Debug)]
pub struct Layer {
    pub name: String,
    pub kind: LayerKind,
    /// Sizes in the unified space [N, K, C, P, Q, R, S].
    pub dims: [usize; NDIMS],
}

impl Layer {
    pub fn new(name: &str, kind: LayerKind, dims: [usize; NDIMS]) -> Layer {
        debug_assert!(dims.iter().all(|&d| d >= 1));
        Layer { name: name.to_string(), kind, dims }
    }

    /// Total MAC count.
    pub fn ops(&self) -> f64 {
        self.dims.iter().map(|&d| d as f64).product()
    }
}

/// A workload: a topologically-ordered chain of layers with explicit
/// fusion-legality on each consecutive edge. Multi-input joins (residual
/// adds, attention score inputs) are expressed by marking the edge
/// non-fusible (paper Sec 2.2's producer-consumer requirement).
#[derive(Clone, Debug)]
pub struct Workload {
    pub name: String,
    pub layers: Vec<Layer>,
    /// `fusible[i]` — may edge layers[i] -> layers[i+1] be fused?
    pub fusible: Vec<bool>,
    /// Whole-network replication factor (e.g. 32 transformer blocks when
    /// `layers` describes one block). Energy and latency each scale by
    /// this factor when reporting full-model numbers.
    pub replicas: f64,
}

impl Workload {
    /// Build a chain, deriving edge fusibility from producer-consumer
    /// shape compatibility (K_i == C_{i+1}, matching N) minus the
    /// explicitly blocked edges (joins).
    pub fn chain(name: &str, layers: Vec<Layer>, blocked: &[usize],
                 replicas: f64) -> Workload {
        let mut fusible = Vec::new();
        for i in 0..layers.len().saturating_sub(1) {
            let a = &layers[i];
            let b = &layers[i + 1];
            let shape_ok = (a.dims[DIM_K] == b.dims[DIM_C]
                            || b.kind == LayerKind::Depthwise
                               && a.dims[DIM_K] == b.dims[DIM_K])
                && a.dims[DIM_N] == b.dims[DIM_N];
            fusible.push(shape_ok && !blocked.contains(&i));
        }
        Workload { name: name.to_string(), layers, fusible, replicas }
    }

    pub fn len(&self) -> usize {
        self.layers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }

    /// Total MACs for one replica.
    pub fn total_ops(&self) -> f64 {
        self.layers.iter().map(Layer::ops).sum()
    }

    /// Dims as an [L][7] f64 matrix (AOT input staging).
    pub fn dims_matrix(&self) -> Vec<[f64; NDIMS]> {
        self.layers
            .iter()
            .map(|l| {
                let mut row = [0.0; NDIMS];
                for d in 0..NDIMS {
                    row[d] = l.dims[d] as f64;
                }
                row
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn conv(name: &str, k: usize, c: usize, pq: usize) -> Layer {
        Layer::new(name, LayerKind::Conv, [1, k, c, pq, pq, 3, 3])
    }

    #[test]
    fn chain_derives_fusibility_from_shapes() {
        let w = Workload::chain(
            "t",
            vec![conv("a", 64, 3, 224), conv("b", 64, 64, 224),
                 conv("c", 128, 64, 112)],
            &[],
            1.0,
        );
        assert_eq!(w.fusible, vec![true, true]);
    }

    #[test]
    fn chain_respects_blocked_edges() {
        let w = Workload::chain(
            "t",
            vec![conv("a", 64, 3, 224), conv("b", 64, 64, 224)],
            &[0],
            1.0,
        );
        assert_eq!(w.fusible, vec![false]);
    }

    #[test]
    fn chain_blocks_shape_mismatch() {
        // K=64 producer feeding C=32 consumer cannot fuse
        let w = Workload::chain(
            "t",
            vec![conv("a", 64, 3, 224), conv("b", 64, 32, 224)],
            &[],
            1.0,
        );
        assert_eq!(w.fusible, vec![false]);
    }

    #[test]
    fn ops_product() {
        let l = Layer::new("x", LayerKind::Gemm, [2, 4, 8, 16, 1, 1, 1]);
        assert_eq!(l.ops(), (2 * 4 * 8 * 16) as f64);
    }
}
