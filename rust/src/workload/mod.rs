//! DNN workload representation: the DAG of the problem formulation
//! (paper Sec 2.3) in the unified 7-dim problem space of Sec 3.1.1.
//!
//! Workloads come from two interchangeable sources: the built-in
//! builder functions of [`zoo`] and the JSON workload-spec files /
//! inline documents parsed by [`spec`] — both produce the same
//! [`Workload`] value (the spec re-expressions of the zoo models are
//! asserted bit-identical in `rust/tests/workload_spec.rs`).

pub mod spec;
pub mod zoo;

// Problem-dimension indices (mirror `python/compile/constants.py`).

/// Batch dimension index.
pub const DIM_N: usize = 0;
/// Output-channel (K) dimension index.
pub const DIM_K: usize = 1;
/// Input-channel / reduction (C) dimension index.
pub const DIM_C: usize = 2;
/// Output-height (P) dimension index (GEMM rows M).
pub const DIM_P: usize = 3;
/// Output-width (Q) dimension index.
pub const DIM_Q: usize = 4;
/// Kernel-height (R) dimension index.
pub const DIM_R: usize = 5;
/// Kernel-width (S) dimension index.
pub const DIM_S: usize = 6;
/// Number of problem dimensions in the unified space.
pub const NDIMS: usize = 7;
/// Canonical dimension names, indexed by `DIM_*`.
pub const DIM_NAMES: [&str; 7] = ["N", "K", "C", "P", "Q", "R", "S"];

/// Operator class of a layer (affects the validation operator mix and
/// how dims were derived, not the cost equations themselves).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    /// Standard convolution.
    Conv,
    /// Depthwise convolution (modeled as C=1 per output channel).
    Depthwise,
    /// 1x1 (pointwise) convolution.
    Pointwise,
    /// General matrix multiply (P = rows M, K = cols, C = reduction).
    Gemm,
    /// Fully-connected layer (GEMM with P = 1).
    Fc,
}

impl LayerKind {
    /// Canonical lower-case name (the workload-spec `kind` field).
    pub fn name(&self) -> &'static str {
        match self {
            LayerKind::Conv => "conv",
            LayerKind::Depthwise => "depthwise",
            LayerKind::Pointwise => "pointwise",
            LayerKind::Gemm => "gemm",
            LayerKind::Fc => "fc",
        }
    }

    /// Parse a canonical kind name (case-insensitive).
    pub fn parse(s: &str) -> Option<LayerKind> {
        Some(match s.to_ascii_lowercase().as_str() {
            "conv" => LayerKind::Conv,
            "depthwise" | "dw" => LayerKind::Depthwise,
            "pointwise" | "pw" => LayerKind::Pointwise,
            "gemm" => LayerKind::Gemm,
            "fc" => LayerKind::Fc,
            _ => return None,
        })
    }
}

/// One computational layer (a DAG vertex).
#[derive(Clone, Debug, PartialEq)]
pub struct Layer {
    /// Human-readable layer name (unique within a workload).
    pub name: String,
    /// Operator class.
    pub kind: LayerKind,
    /// Sizes in the unified space [N, K, C, P, Q, R, S].
    pub dims: [usize; NDIMS],
}

impl Layer {
    /// Build a layer (dims must all be >= 1).
    pub fn new(name: &str, kind: LayerKind, dims: [usize; NDIMS]) -> Layer {
        debug_assert!(dims.iter().all(|&d| d >= 1));
        Layer { name: name.to_string(), kind, dims }
    }

    /// Total MAC count.
    pub fn ops(&self) -> f64 {
        self.dims.iter().map(|&d| d as f64).product()
    }

    /// Shape fingerprint: FNV-1a over the operator kind and the seven
    /// dim sizes — the key the warm-start mapping library indexes by.
    /// The layer *name* is deliberately excluded: only the shape
    /// matters for mapping reuse across workloads.
    pub fn shape_fingerprint(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut eat = |bytes: &[u8]| {
            for &b in bytes {
                h ^= b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01B3);
            }
        };
        eat(self.kind.name().as_bytes());
        for &d in &self.dims {
            eat(&(d as u64).to_le_bytes());
        }
        h
    }
}

/// A workload: a topologically-ordered chain of layers with explicit
/// fusion-legality on each consecutive edge. Multi-input joins (residual
/// adds, attention score inputs) are expressed by marking the edge
/// non-fusible (paper Sec 2.2's producer-consumer requirement).
#[derive(Clone, Debug, PartialEq)]
pub struct Workload {
    /// Workload name (CLI / protocol identifier).
    pub name: String,
    /// Topologically-ordered layer chain.
    pub layers: Vec<Layer>,
    /// `fusible[i]` — may edge layers[i] -> layers[i+1] be fused?
    pub fusible: Vec<bool>,
    /// Whole-network replication factor (e.g. 32 transformer blocks when
    /// `layers` describes one block). Energy and latency each scale by
    /// this factor when reporting full-model numbers.
    pub replicas: f64,
}

/// The producer-consumer shape requirement for fusing edge `a -> b`
/// (paper Sec 2.2): the producer's output channels feed the consumer's
/// reduction (`K_a == C_b`; depthwise consumers match on `K` since
/// their `C` is folded to 1), with equal batch. Multi-producer joins
/// (residual adds, attention score/context inputs) do not satisfy a
/// producer-consumer relation at all and must be *blocked* explicitly
/// — shape compatibility is necessary, not sufficient.
pub fn edge_shape_compatible(a: &Layer, b: &Layer) -> bool {
    (a.dims[DIM_K] == b.dims[DIM_C]
        || b.kind == LayerKind::Depthwise
            && a.dims[DIM_K] == b.dims[DIM_K])
        && a.dims[DIM_N] == b.dims[DIM_N]
}

impl Workload {
    /// Build a chain, deriving edge fusibility from producer-consumer
    /// shape compatibility ([`edge_shape_compatible`]) minus the
    /// explicitly blocked edges (joins).
    pub fn chain(name: &str, layers: Vec<Layer>, blocked: &[usize],
                 replicas: f64) -> Workload {
        let mut fusible = Vec::new();
        for i in 0..layers.len().saturating_sub(1) {
            let shape_ok =
                edge_shape_compatible(&layers[i], &layers[i + 1]);
            fusible.push(shape_ok && !blocked.contains(&i));
        }
        Workload { name: name.to_string(), layers, fusible, replicas }
    }

    /// Layer count.
    pub fn len(&self) -> usize {
        self.layers.len()
    }

    /// Whether the workload has no layers.
    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }

    /// Total MACs for one replica.
    pub fn total_ops(&self) -> f64 {
        self.layers.iter().map(Layer::ops).sum()
    }

    /// Dims as an [L][7] f64 matrix (AOT input staging).
    pub fn dims_matrix(&self) -> Vec<[f64; NDIMS]> {
        self.layers
            .iter()
            .map(|l| {
                let mut row = [0.0; NDIMS];
                for d in 0..NDIMS {
                    row[d] = l.dims[d] as f64;
                }
                row
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn conv(name: &str, k: usize, c: usize, pq: usize) -> Layer {
        Layer::new(name, LayerKind::Conv, [1, k, c, pq, pq, 3, 3])
    }

    #[test]
    fn chain_derives_fusibility_from_shapes() {
        let w = Workload::chain(
            "t",
            vec![conv("a", 64, 3, 224), conv("b", 64, 64, 224),
                 conv("c", 128, 64, 112)],
            &[],
            1.0,
        );
        assert_eq!(w.fusible, vec![true, true]);
    }

    #[test]
    fn chain_respects_blocked_edges() {
        let w = Workload::chain(
            "t",
            vec![conv("a", 64, 3, 224), conv("b", 64, 64, 224)],
            &[0],
            1.0,
        );
        assert_eq!(w.fusible, vec![false]);
    }

    #[test]
    fn chain_blocks_shape_mismatch() {
        // K=64 producer feeding C=32 consumer cannot fuse
        let w = Workload::chain(
            "t",
            vec![conv("a", 64, 3, 224), conv("b", 64, 32, 224)],
            &[],
            1.0,
        );
        assert_eq!(w.fusible, vec![false]);
    }

    #[test]
    fn shape_fingerprint_keys_on_kind_and_dims_only() {
        let a = conv("a", 64, 3, 224);
        let renamed = conv("zzz", 64, 3, 224);
        assert_eq!(a.shape_fingerprint(), renamed.shape_fingerprint());
        let bigger = conv("a", 128, 3, 224);
        assert_ne!(a.shape_fingerprint(), bigger.shape_fingerprint());
        let other_kind = Layer::new("a", LayerKind::Pointwise,
                                    a.dims);
        assert_ne!(a.shape_fingerprint(),
                   other_kind.shape_fingerprint());
    }

    #[test]
    fn ops_product() {
        let l = Layer::new("x", LayerKind::Gemm, [2, 4, 8, 16, 1, 1, 1]);
        assert_eq!(l.ops(), (2 * 4 * 8 * 16) as f64);
    }
}
