//! The workload-spec DSL: workloads as *data* instead of code.
//!
//! A spec is a JSON document describing a [`Workload`] — layers with
//! their 7-dim shapes and operator kinds, plus explicit fusion-edge
//! information — so new deployment scenarios reach the optimizer
//! without a rebuild. Specs arrive three ways, all through one
//! validating parser ([`from_json`]):
//!
//! * **Checked-in files** under `data/workloads/*.json` — the five
//!   built-in zoo models are re-expressed there (asserted bit-identical
//!   to their [`super::zoo`] builders) alongside new scenario classes
//!   (LLaMA-7B decode/prefill, BERT-base encoder block, ResNet-50
//!   bottleneck stage). [`load_named`] resolves them by file stem, and
//!   the coordinator falls back to it for any workload name the zoo
//!   does not know.
//! * **CLI files** — `fadiff optimize --workload-file my_model.json`
//!   ([`load_file`]).
//! * **Inline wire documents** — the protocol's `workload_spec`
//!   parameter on `optimize` / `submit` / `sweep`, size-capped and
//!   validated at parse time exactly like `chains`
//!   (see `docs/protocol.md`).
//!
//! # Document shape
//!
//! ```json
//! {
//!   "name": "my-model",
//!   "replicas": 1,
//!   "layers": [
//!     {"name": "conv1", "kind": "conv",
//!      "dims": [1, 64, 3, 224, 224, 3, 3]},
//!     {"name": "conv2", "kind": "conv",
//!      "dims": [1, 64, 64, 224, 224, 3, 3]}
//!   ],
//!   "blocked": []
//! }
//! ```
//!
//! `dims` is always `[N, K, C, P, Q, R, S]` (see
//! [`crate::workload::DIM_NAMES`]); `kind` is one of `conv` /
//! `depthwise` / `pointwise` / `gemm` / `fc`. Edge fusibility is
//! expressed one of two mutually-exclusive ways:
//!
//! * `"blocked": [i, ...]` — edge indices whose fusion is forbidden
//!   (multi-producer joins); the remaining edges derive fusibility from
//!   producer-consumer shape compatibility, exactly like
//!   [`Workload::chain`]. This is the form the checked-in specs use.
//! * `"fusible": [bool, ...]` — one explicit flag per consecutive
//!   edge. A `true` flag on a shape-incompatible edge is rejected: the
//!   paper's producer-consumer requirement (Sec 2.2) is necessary for
//!   fusion, and multi-producer joins must be expressed as `false`.
//!
//! Validation is total: dimension bounds ([`MAX_DIM_SIZE`]), layer
//! count ([`MAX_SPEC_LAYERS`]), duplicate layer names, out-of-range or
//! duplicate blocked-edge indices, unknown keys/kinds, and
//! arity mismatches all fail with a one-line error instead of
//! constructing a malformed workload.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Result};

use crate::util::json::{num, obj, s, Json};
use crate::workload::{edge_shape_compatible, Layer, LayerKind, Workload,
                      NDIMS};

/// Maximum layer count accepted from a spec. Generous against the zoo
/// (largest model: 28 layers) while bounding the state any one request
/// can make the optimizer allocate (theta alone is `L * 7 * 4 * chains`
/// doubles).
pub const MAX_SPEC_LAYERS: usize = 64;

/// Maximum problem-dimension size. Large enough for any realistic
/// layer (GPT-3's FFN hidden is 16384; sequence dims reach a few
/// thousand) while keeping the divisor/prime precomputation
/// (`O(sqrt(n))` per distinct size) trivially cheap for hostile
/// inputs.
pub const MAX_DIM_SIZE: usize = 1 << 24;

/// Maximum serialized spec size (bytes) accepted from files and the
/// wire — a parse-time cap like the protocol's `MAX_CHAINS`, far under
/// the server's 1 MiB line cap so an inline spec can never dominate a
/// request.
pub const MAX_SPEC_BYTES: usize = 256 * 1024;

/// Maximum workload / layer name length.
pub const MAX_NAME_LEN: usize = 100;

fn field_usize(j: &Json, what: &str, max: usize) -> Result<usize> {
    let x = j.as_f64()?;
    if !(x.is_finite() && x >= 0.0 && x.fract() == 0.0) {
        bail!("{what} must be a non-negative integer, got {x}");
    }
    if x > max as f64 {
        bail!("{what} is {x}, above the cap of {max}");
    }
    Ok(x as usize)
}

fn checked_name(j: &Json, what: &str) -> Result<String> {
    let name = j.as_str()?;
    if name.is_empty() {
        bail!("{what} must not be empty");
    }
    if name.len() > MAX_NAME_LEN {
        bail!("{what} longer than {MAX_NAME_LEN} bytes");
    }
    Ok(name.to_string())
}

fn check_keys(j: &Json, what: &str, allowed: &[&str]) -> Result<()> {
    for key in j.as_obj()?.keys() {
        if !allowed.contains(&key.as_str()) {
            bail!("{what}: unknown key {key:?} (allowed: {allowed:?})");
        }
    }
    Ok(())
}

fn parse_layer(j: &Json, index: usize) -> Result<Layer> {
    let what = format!("layers[{index}]");
    check_keys(j, &what, &["name", "kind", "dims"])?;
    let name = checked_name(j.get("name")?, &format!("{what}.name"))?;
    let kind_s = j.get("kind")?.as_str()?;
    let kind = LayerKind::parse(kind_s)
        .ok_or_else(|| anyhow!(
            "{what}.kind: unknown kind {kind_s:?} (expected conv / \
             depthwise / pointwise / gemm / fc)"))?;
    let dims_j = j.get("dims")?.as_arr()?;
    if dims_j.len() != NDIMS {
        bail!("{what}.dims must have exactly {NDIMS} entries \
               [N, K, C, P, Q, R, S], got {}", dims_j.len());
    }
    let mut dims = [1usize; NDIMS];
    for (d, v) in dims_j.iter().enumerate() {
        let size = field_usize(v, &format!("{what}.dims[{d}]"),
                               MAX_DIM_SIZE)?;
        if size == 0 {
            bail!("{what}.dims[{d}] must be >= 1");
        }
        dims[d] = size;
    }
    Ok(Layer { name, kind, dims })
}

/// Parse and validate a workload-spec document (see module docs).
pub fn from_json(j: &Json) -> Result<Workload> {
    check_keys(j, "workload spec",
               &["name", "replicas", "layers", "blocked", "fusible"])?;
    let name = checked_name(j.get("name")?, "name")?;
    let replicas = match j.as_obj()?.get("replicas") {
        None => 1.0,
        Some(r) => {
            let x = r.as_f64()?;
            if !(x.is_finite() && x >= 1.0) {
                bail!("replicas must be a finite number >= 1, got {x}");
            }
            x
        }
    };
    let layers_j = j.get("layers")?.as_arr()?;
    if layers_j.is_empty() {
        bail!("layers must not be empty");
    }
    if layers_j.len() > MAX_SPEC_LAYERS {
        bail!("{} layers exceed the cap of {MAX_SPEC_LAYERS}",
              layers_j.len());
    }
    let layers: Vec<Layer> = layers_j
        .iter()
        .enumerate()
        .map(|(i, lj)| parse_layer(lj, i))
        .collect::<Result<_>>()?;
    for (i, a) in layers.iter().enumerate() {
        if layers[..i].iter().any(|b| b.name == a.name) {
            bail!("duplicate layer name {:?}", a.name);
        }
    }
    let edges = layers.len() - 1;
    let map = j.as_obj()?;
    if map.contains_key("blocked") && map.contains_key("fusible") {
        bail!("give either \"blocked\" or \"fusible\", not both");
    }
    if let Some(fus_j) = map.get("fusible") {
        let flags = fus_j.as_arr()?;
        if flags.len() != edges {
            bail!("fusible must have one entry per consecutive edge \
                   ({edges}), got {}", flags.len());
        }
        let mut fusible = Vec::with_capacity(edges);
        for (i, f) in flags.iter().enumerate() {
            let on = match f {
                Json::Bool(b) => *b,
                _ => bail!("fusible[{i}] must be a boolean"),
            };
            let pair_ok =
                edge_shape_compatible(&layers[i], &layers[i + 1]);
            if on && !pair_ok {
                bail!(
                    "fusible[{i}] marks edge {:?} -> {:?} fusible, but \
                     the shapes are not producer-consumer compatible \
                     (K/C mismatch or batch mismatch); multi-producer \
                     joins must be marked false",
                    layers[i].name, layers[i + 1].name
                );
            }
            fusible.push(on);
        }
        return Ok(Workload { name, layers, fusible, replicas });
    }
    let mut blocked = Vec::new();
    if let Some(b_j) = map.get("blocked") {
        for (i, v) in b_j.as_arr()?.iter().enumerate() {
            let e = field_usize(v, &format!("blocked[{i}]"),
                                usize::MAX)?;
            if e >= edges.max(1) || edges == 0 {
                bail!("blocked[{i}] = {e} out of range (the workload \
                       has {edges} consecutive edges)");
            }
            if blocked.contains(&e) {
                bail!("blocked edge {e} listed twice");
            }
            blocked.push(e);
        }
    }
    Ok(Workload::chain(&name, layers, &blocked, replicas))
}

/// Parse a spec from JSON text, enforcing the [`MAX_SPEC_BYTES`] size
/// cap before touching the parser.
pub fn from_str(text: &str) -> Result<Workload> {
    if text.len() > MAX_SPEC_BYTES {
        bail!("workload spec of {} bytes exceeds the cap of \
               {MAX_SPEC_BYTES}", text.len());
    }
    from_json(&Json::parse(text)?)
}

/// Parse an already-parsed inline `workload_spec` value (the protocol
/// parameter): size cap first, then full validation, with errors
/// prefixed `workload_spec:` for the wire. The single entry point the
/// server uses for both job requests and the `workloads` verb's
/// validate-describe form.
pub fn parse_inline(spec_j: &Json) -> Result<Workload> {
    let text = spec_j.compact();
    if text.len() > MAX_SPEC_BYTES {
        bail!("workload_spec of {} bytes exceeds the cap of \
               {MAX_SPEC_BYTES}", text.len());
    }
    from_json(spec_j).map_err(|e| anyhow!("workload_spec: {e}"))
}

/// Load and validate a spec file.
pub fn load_file(path: &Path) -> Result<Workload> {
    let meta = std::fs::metadata(path)
        .map_err(|e| anyhow!("workload spec {path:?}: {e}"))?;
    if meta.len() > MAX_SPEC_BYTES as u64 {
        bail!("workload spec {path:?} ({} bytes) exceeds the cap of \
               {MAX_SPEC_BYTES}", meta.len());
    }
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("workload spec {path:?}: {e}"))?;
    from_str(&text)
        .map_err(|e| anyhow!("workload spec {path:?}: {e}"))
}

/// The checked-in spec directory (`<repo>/data/workloads`).
pub fn spec_dir(repo_root: &Path) -> PathBuf {
    repo_root.join("data/workloads")
}

/// Load `data/workloads/<name>.json` if it exists. Returns `None` for
/// names with no spec file (including names that could escape the spec
/// directory — only `[A-Za-z0-9._-]` names are looked up, and `..` is
/// rejected outright).
pub fn load_named(repo_root: &Path, name: &str) -> Option<Result<Workload>> {
    load_named_from(&spec_dir(repo_root), name)
}

/// [`load_named`] against an explicit spec directory. The file's
/// declared `name` must equal the file stem — the stem is the lookup
/// key everywhere (resolution, listings, protocol), so a mismatched
/// file would be advertised under a name that then fails to resolve.
pub fn load_named_from(dir: &Path, name: &str)
                       -> Option<Result<Workload>> {
    let safe = !name.is_empty()
        && name.len() <= MAX_NAME_LEN
        && !name.contains("..")
        && name.chars().all(|c| {
            c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-')
        });
    if !safe {
        return None;
    }
    let path = dir.join(format!("{name}.json"));
    if !path.is_file() {
        return None;
    }
    Some(load_file(&path).and_then(|w| {
        if w.name == name {
            Ok(w)
        } else {
            Err(anyhow!(
                "spec file {path:?} declares name {:?}, which must \
                 match the file stem {name:?} (the stem is the \
                 lookup key)",
                w.name
            ))
        }
    }))
}

/// Names (file stems, sorted) of every checked-in spec.
pub fn list_spec_names(repo_root: &Path) -> Vec<String> {
    let mut names = Vec::new();
    if let Ok(entries) = std::fs::read_dir(spec_dir(repo_root)) {
        for entry in entries.flatten() {
            let path = entry.path();
            if path.extension().and_then(|e| e.to_str()) == Some("json")
            {
                if let Some(stem) =
                    path.file_stem().and_then(|s| s.to_str())
                {
                    names.push(stem.to_string());
                }
            }
        }
    }
    names.sort();
    names
}

/// Canonical JSON form of a workload: layers with explicit `fusible`
/// flags (no derivation on re-parse), deterministic field order. The
/// exact inverse of [`from_json`] for any workload whose fusible edges
/// satisfy [`edge_shape_compatible`] — which every constructor-built
/// workload does.
pub fn to_json(w: &Workload) -> Json {
    let layers = w
        .layers
        .iter()
        .map(|l| {
            obj(vec![
                ("name", s(&l.name)),
                ("kind", s(l.kind.name())),
                ("dims",
                 Json::Arr(l.dims
                     .iter()
                     .map(|&d| num(d as f64))
                     .collect())),
            ])
        })
        .collect();
    obj(vec![
        ("name", s(&w.name)),
        ("replicas", num(w.replicas)),
        ("layers", Json::Arr(layers)),
        ("fusible",
         Json::Arr(w.fusible.iter().map(|&f| Json::Bool(f)).collect())),
    ])
}

/// Deterministic 64-bit content fingerprint (FNV-1a over the canonical
/// compact serialization of [`to_json`]), rendered as 16 hex chars.
/// Two workloads fingerprint equal iff their canonical specs are
/// byte-identical — the coordinator keys inline-spec evaluation caches
/// on `spec:<fingerprint>` so distinct user specs never share a cache
/// while resubmissions of the same spec do.
pub fn fingerprint(w: &Workload) -> String {
    let text = to_json(w).compact();
    let mut hash: u64 = 0xCBF2_9CE4_8422_2325;
    for b in text.as_bytes() {
        hash ^= *b as u64;
        hash = hash.wrapping_mul(0x0000_0100_0000_01B3);
    }
    format!("{hash:016x}")
}

/// Wire description of a workload (the `workloads` verb's `describe`
/// payload): the canonical spec plus derived summary fields.
pub fn describe_json(w: &Workload) -> Json {
    let mut j = to_json(w);
    if let Json::Obj(map) = &mut j {
        map.insert("layer_count".into(), num(w.len() as f64));
        map.insert("fusible_edges".into(),
                   num(w.fusible.iter().filter(|&&f| f).count() as f64));
        map.insert("total_macs".into(), num(w.total_ops()));
        map.insert("fingerprint".into(), s(&fingerprint(w)));
    }
    j
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::zoo;

    const MINIMAL: &str = r#"{
        "name": "tiny",
        "layers": [
            {"name": "a", "kind": "conv", "dims": [1, 8, 3, 16, 16, 3, 3]},
            {"name": "b", "kind": "conv", "dims": [1, 8, 8, 16, 16, 3, 3]}
        ]
    }"#;

    #[test]
    fn minimal_spec_parses_and_derives_fusibility() {
        let w = from_str(MINIMAL).unwrap();
        assert_eq!(w.name, "tiny");
        assert_eq!(w.len(), 2);
        assert_eq!(w.replicas, 1.0);
        // K_a = 8 == C_b = 8, same batch -> fusible
        assert_eq!(w.fusible, vec![true]);
    }

    #[test]
    fn blocked_edges_are_respected() {
        let j = Json::parse(MINIMAL).unwrap();
        let mut m = j.as_obj().unwrap().clone();
        m.insert("blocked".into(), Json::Arr(vec![num(0.0)]));
        let w = from_json(&Json::Obj(m)).unwrap();
        assert_eq!(w.fusible, vec![false]);
    }

    #[test]
    fn explicit_fusible_roundtrip_matches_builders() {
        for w in zoo::table1_suite() {
            let j = to_json(&w);
            let back = from_json(&j).unwrap();
            assert_eq!(back, w, "{} round-trip", w.name);
            // and through text serialization too
            let back2 = from_str(&j.compact()).unwrap();
            assert_eq!(back2, w, "{} compact round-trip", w.name);
        }
    }

    #[test]
    fn fingerprint_distinguishes_content() {
        let a = from_str(MINIMAL).unwrap();
        let b = zoo::vgg16();
        assert_ne!(fingerprint(&a), fingerprint(&b));
        assert_eq!(fingerprint(&a), fingerprint(&from_str(MINIMAL)
            .unwrap()));
        // any content change moves the fingerprint
        let mut c = a.clone();
        c.layers[0].dims[1] = 16;
        assert_ne!(fingerprint(&a), fingerprint(&c));
    }

    fn expect_err(body: &str, needle: &str) {
        let err = from_str(body).unwrap_err().to_string();
        assert!(err.contains(needle), "{body}\n-> {err}");
    }

    #[test]
    fn rejects_malformed_specs() {
        expect_err(r#"{"layers": []}"#, "name");
        expect_err(r#"{"name": "x", "layers": []}"#, "empty");
        expect_err(
            r#"{"name": "x", "layers": [
                {"name": "a", "kind": "conv", "dims": [1, 2, 3]}]}"#,
            "exactly 7");
        expect_err(
            r#"{"name": "x", "layers": [
                {"name": "a", "kind": "warp",
                 "dims": [1, 1, 1, 1, 1, 1, 1]}]}"#,
            "unknown kind");
        expect_err(
            r#"{"name": "x", "layers": [
                {"name": "a", "kind": "fc",
                 "dims": [1, 0, 1, 1, 1, 1, 1]}]}"#,
            ">= 1");
        expect_err(
            r#"{"name": "x", "layers": [
                {"name": "a", "kind": "fc",
                 "dims": [1, 1.5, 1, 1, 1, 1, 1]}]}"#,
            "integer");
        expect_err(
            r#"{"name": "x", "layers": [
                {"name": "a", "kind": "fc",
                 "dims": [1, 99999999999, 1, 1, 1, 1, 1]}]}"#,
            "cap");
        expect_err(
            r#"{"name": "x", "layers": [
                {"name": "a", "kind": "fc", "dims": [1,1,1,1,1,1,1]},
                {"name": "a", "kind": "fc", "dims": [1,1,1,1,1,1,1]}]}"#,
            "duplicate layer name");
        expect_err(
            r#"{"name": "x", "typo_key": 1, "layers": [
                {"name": "a", "kind": "fc", "dims": [1,1,1,1,1,1,1]}]}"#,
            "unknown key");
    }

    #[test]
    fn rejects_bad_edges() {
        expect_err(
            r#"{"name": "x", "blocked": [5], "layers": [
                {"name": "a", "kind": "fc", "dims": [1,8,8,1,1,1,1]},
                {"name": "b", "kind": "fc", "dims": [1,8,8,1,1,1,1]}]}"#,
            "out of range");
        expect_err(
            r#"{"name": "x", "blocked": [0, 0], "layers": [
                {"name": "a", "kind": "fc", "dims": [1,8,8,1,1,1,1]},
                {"name": "b", "kind": "fc", "dims": [1,8,8,1,1,1,1]}]}"#,
            "twice");
        expect_err(
            r#"{"name": "x", "blocked": [0], "fusible": [true],
                "layers": [
                {"name": "a", "kind": "fc", "dims": [1,8,8,1,1,1,1]},
                {"name": "b", "kind": "fc", "dims": [1,8,8,1,1,1,1]}]}"#,
            "not both");
        expect_err(
            r#"{"name": "x", "fusible": [true, false], "layers": [
                {"name": "a", "kind": "fc", "dims": [1,8,8,1,1,1,1]},
                {"name": "b", "kind": "fc", "dims": [1,8,8,1,1,1,1]}]}"#,
            "one entry per consecutive edge");
        // the multi-producer blocking rule: an explicit fusible=true on
        // a shape-incompatible edge is an authoring error
        expect_err(
            r#"{"name": "x", "fusible": [true], "layers": [
                {"name": "a", "kind": "fc", "dims": [1,8,8,1,1,1,1]},
                {"name": "b", "kind": "fc", "dims": [1,8,4,1,1,1,1]}]}"#,
            "producer-consumer");
    }

    #[test]
    fn rejects_oversized_specs() {
        // layer-count cap
        let mut layers = Vec::new();
        for i in 0..MAX_SPEC_LAYERS + 1 {
            layers.push(format!(
                r#"{{"name": "l{i}", "kind": "fc",
                     "dims": [1,8,8,1,1,1,1]}}"#
            ));
        }
        let body = format!(r#"{{"name": "big", "layers": [{}]}}"#,
                           layers.join(","));
        expect_err(&body, "cap");
        // byte cap before the parser even runs
        let huge = format!(r#"{{"name": "{}"}}"#,
                           "x".repeat(MAX_SPEC_BYTES));
        expect_err(&huge, "cap");
    }

    #[test]
    fn named_lookup_sanitizes_and_lists() {
        let repo = crate::config::repo_root();
        assert!(load_named(&repo, "../hw_configs").is_none());
        assert!(load_named(&repo, "no/such/name").is_none());
        assert!(load_named(&repo, "definitely-absent").is_none());
        let names = list_spec_names(&repo);
        for name in &names {
            let w = load_named(&repo, name)
                .expect("listed spec resolves")
                .expect("listed spec parses");
            assert!(!w.is_empty());
        }
    }
}
