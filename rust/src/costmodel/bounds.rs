//! Admissible per-candidate lower bounds — the bound-and-prune
//! prefilter in front of the [`super::batch`] kernel.
//!
//! For a candidate strategy the exact model (Eqs. 13-19) prices every
//! traffic component from the full tiling. This module prices a *floor*
//! on the same quantities from per-layer constants plus two numbers
//! that are read straight off the candidate (its spatial K/C factors):
//!
//! * every weight element crosses DRAM->L2 and L2->RF at least once
//!   (`fill2_w >= |W|`, `fill0_w >= |W|`): under the honest-traffic
//!   clamp `t3 = max(dims/ext2, 1)`, each W-dim contributes
//!   `ext2 * t3 = max(dims, ext2) >= dims` and every other dim
//!   contributes `t3 >= 1`;
//! * every live input element is filled at least once
//!   (`fill2_i >= |I|`, same argument over the I-dims) and every
//!   output element drains at least once (`wb0_o >= |O|`, over the
//!   O-dims with `ext1 * t2 * t3 = ext2 * t3`);
//! * the PE-stream and accumulate terms are exact already:
//!   `read_pe_i = ops / sp_k`, `accwb_o = ops / sp_c`;
//! * the compute roofline is exact: `ops / (sp_k * sp_c)`.
//!
//! Substituting the floors into Eqs. 13-19 term by term keeps every
//! access sum `a_i` and therefore every roofline arm and the energy sum
//! below its exact value; the [`ROUNDING_SLACK`] factor then absorbs
//! the few-ulp float-reassociation drift of the pre-folded constants,
//! so `E_lb <= E`, `L_lb <= L` and `E_lb * L_lb <= EDP` hold *in f64*
//! (not just in exact arithmetic) for every candidate that passes
//! `Strategy::validate` (invalid candidates evaluate to infeasible
//! anyway, so their bound is never load-bearing). That admissibility is
//! what lets the prefilter skip the full kernel for candidates whose
//! bound already meets the incumbent without changing any search
//! result — pinned by `rust/tests/prune_warmstart.rs`.
//!
//! The capacity screen is not a bound but an *exact replica* of the
//! kernel's accumulator and fusion-group checks (same expressions, same
//! evaluation order, bit-identical verdicts), so `Infeasible` here
//! implies `feasible == false` from [`super::batch::eval_into`].

use crate::config::HwConfig;
use crate::mapping::{Strategy, SLOT_S, SLOT_T0, SLOT_T1, SLOT_T2};
use crate::workload::{Workload, DIM_C, DIM_K, NDIMS};

use super::{first_group_overflow, I_DIMS, O_DIMS, W_DIMS};

/// One-sided slack on the energy/latency floors, compensating for the
/// pre-folded per-signature constants associating their additions in a
/// different order than the kernel's live sums: reassociating the
/// handful of terms of Eqs. 13-19 perturbs an f64 result by a few ulps
/// (~1e-16 relative), so an exactly-tight candidate (every traffic
/// floor met with equality — full-residency tilings) could otherwise
/// see its "lower" bound land one ulp *above* the exact value and be
/// wrongly pruned. Scaling the floors down by 1e-12 — four orders of
/// magnitude above the worst reordering error observed in the offline
/// float mirror, ten below any real traffic slack — keeps the bound
/// strictly admissible at negligible cost in pruning power.
///
/// Public because the branch-and-bound exact mapper
/// (`search::exact`) applies the same slack to its partial-assignment
/// bounds, whose suffix floors are likewise pre-folded sums that may
/// associate differently than the kernel's per-leaf accumulation.
pub const ROUNDING_SLACK: f64 = 1.0 - 1e-12;

/// Outcome of screening one candidate.
#[derive(Clone, Copy, Debug)]
pub struct ScreenVerdict {
    /// Admissible lower bound on total energy (pJ).
    pub energy_lb: f64,
    /// Admissible lower bound on total latency (cycles).
    pub latency_lb: f64,
    /// `energy_lb * latency_lb` (a lower bound on EDP).
    pub edp_lb: f64,
    /// The kernel's accumulator / fusion-group check is guaranteed to
    /// fail for this candidate (exact replica, not a bound).
    pub capacity_infeasible: bool,
}

/// Reusable per-layer column for the fusion-group walk (mirrors
/// [`super::batch::SoaScratch`], which the kernel itself uses).
#[derive(Debug, Default)]
pub struct ScreenScratch {
    l2_bytes: Vec<f64>,
}

impl ScreenScratch {
    /// An empty scratch (grows on first use).
    pub fn new() -> ScreenScratch {
        ScreenScratch::default()
    }
}

/// Precomputed bound constants for one `(workload, hw)` pair.
///
/// All sig-combination constants are folded at construction; per
/// candidate the screen costs ~10 flops per layer plus the exact
/// footprint products for the capacity replica — 10-20x cheaper than
/// [`super::components`] + [`super::layer_cost`].
#[derive(Debug)]
pub struct BoundsCtx {
    layers: usize,
    /// Total MACs per layer.
    ops: Vec<f64>,
    /// Energy constant per layer, indexed `[sig_in << 1 | sig_out]`.
    e_const: Vec<[f64; 4]>,
    /// DRAM roofline arm per layer (fully constant per sig combo).
    l_dram: Vec<[f64; 4]>,
    /// L2 roofline arm constant part per layer and sig combo.
    l2_base: Vec<[f64; 4]>,
    /// L1 roofline arm constant part per layer (`|O| * eb / bw_l1`).
    l1_base: Vec<f64>,
    eb_bw_l2: f64,
    eb_bw_l1: f64,
    epa_l2: f64,
    epa_l1: f64,
    element_bytes: f64,
    acc_bytes: f64,
    c1_bytes: f64,
    c2_bytes: f64,
}

impl BoundsCtx {
    /// Build the bound constants for one workload on one hw config.
    pub fn new(w: &Workload, hw: &HwConfig) -> BoundsCtx {
        let l = w.len();
        let mut ops = Vec::with_capacity(l);
        let mut e_const = Vec::with_capacity(l);
        let mut l_dram = Vec::with_capacity(l);
        let mut l2_base = Vec::with_capacity(l);
        let mut l1_base = Vec::with_capacity(l);
        let eb = hw.element_bytes;
        for layer in &w.layers {
            let dims = &layer.dims;
            let size = |ds: &[usize]| -> f64 {
                ds.iter().map(|&d| dims[d] as f64).product()
            };
            let wsize = size(&W_DIMS);
            let isize_ = size(&I_DIMS);
            let osize = size(&O_DIMS);
            let macs: f64 = dims.iter().map(|&d| d as f64).product();
            let a0 = wsize + macs;
            let mut ec = [0.0f64; 4];
            let mut ld = [0.0f64; 4];
            let mut l2 = [0.0f64; 4];
            for (idx, (si, so)) in [(0.0, 0.0), (0.0, 1.0), (1.0, 0.0),
                                    (1.0, 1.0)]
                .into_iter()
                .enumerate()
            {
                let a3 = (1.0 - si) * isize_ + wsize
                    + (1.0 - so) * osize;
                let c2c = (1.0 - si) * isize_ + 2.0 * wsize
                    + so * osize;
                ec[idx] = macs * hw.energy_per_mac + a3 * hw.epa_dram
                    + c2c * hw.epa_l2
                    + osize * hw.epa_l1
                    + a0 * hw.epa_reg;
                ld[idx] = a3 * eb / hw.bw_dram;
                l2[idx] = c2c * eb / hw.bw_l2;
            }
            ops.push(macs);
            e_const.push(ec);
            l_dram.push(ld);
            l2_base.push(l2);
            l1_base.push(osize * eb / hw.bw_l1);
        }
        BoundsCtx {
            layers: l,
            ops,
            e_const,
            l_dram,
            l2_base,
            l1_base,
            eb_bw_l2: eb / hw.bw_l2,
            eb_bw_l1: eb / hw.bw_l1,
            epa_l2: hw.epa_l2,
            epa_l1: hw.epa_l1,
            element_bytes: hw.element_bytes,
            acc_bytes: hw.acc_bytes,
            c1_bytes: hw.c1_bytes,
            c2_bytes: hw.c2_bytes,
        }
    }

    /// Number of layers the context was built for.
    pub fn layers(&self) -> usize {
        self.layers
    }

    /// Screen one candidate: admissible energy/latency/EDP floors plus
    /// the exact-replica capacity verdict. The strategy's arity must
    /// match the workload (the engine guards this before screening).
    pub fn screen(&self, s: &Strategy, scratch: &mut ScreenScratch)
                  -> ScreenVerdict {
        let l = self.layers;
        scratch.l2_bytes.clear();
        scratch.l2_bytes.resize(l, 0.0);
        let (mut energy, mut latency) = (0.0f64, 0.0f64);
        let mut caps_ok = true;
        for i in 0..l {
            let m = &s.mappings[i];
            // exact footprint replica, mirroring `components`: ext
            // chains and products in the kernel's evaluation order so
            // the capacity verdict is bit-identical
            let mut ext1 = [0.0f64; NDIMS];
            let mut ext2 = [0.0f64; NDIMS];
            for d in 0..NDIMS {
                let f = &m.factors[d];
                let sp = f[SLOT_S] as f64;
                let e0 = f[SLOT_T0] as f64 * sp;
                ext1[d] = e0 * f[SLOT_T1] as f64;
                ext2[d] = ext1[d] * f[SLOT_T2] as f64;
            }
            let prod = |xs: &[usize], e: &[f64; NDIMS]| -> f64 {
                xs.iter().map(|&d| e[d]).product()
            };
            let s_w2 = prod(&W_DIMS, &ext2);
            let s_i2 = prod(&I_DIMS, &ext2);
            let s_o1 = prod(&O_DIMS, &ext1);
            scratch.l2_bytes[i] = (s_w2 + s_i2) * self.element_bytes;
            if s_o1 * self.acc_bytes > self.c1_bytes {
                caps_ok = false;
            }

            let sig_out = i < l - 1 && s.fuse[i];
            let sig_in = i > 0 && s.fuse[i - 1];
            let idx = ((sig_in as usize) << 1) | sig_out as usize;
            let ops = self.ops[i];
            let sp_k = (m.factors[DIM_K][SLOT_S] as f64).max(1.0);
            let sp_c = (m.factors[DIM_C][SLOT_S] as f64).max(1.0);
            let rk = ops / sp_k;
            let rc = ops / sp_c;
            energy += self.e_const[i][idx] + rk * self.epa_l2
                + rc * self.epa_l1;
            latency += (ops / (sp_k * sp_c))
                .max(self.l_dram[i][idx])
                .max(self.l2_base[i][idx] + rk * self.eb_bw_l2)
                .max(self.l1_base[i] + rc * self.eb_bw_l1);
        }
        if first_group_overflow(l, &s.fuse, self.c2_bytes, false,
                                |i| scratch.l2_bytes[i])
            .is_some()
        {
            caps_ok = false;
        }
        let energy_lb = energy * ROUNDING_SLACK;
        let latency_lb = latency * ROUNDING_SLACK;
        ScreenVerdict {
            energy_lb,
            latency_lb,
            edp_lb: energy_lb * latency_lb,
            capacity_infeasible: !caps_ok,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{load_config, repo_root};
    use crate::costmodel;
    use crate::workload::zoo;

    #[test]
    fn bound_is_below_exact_for_trivial_strategies() {
        let hw = load_config(&repo_root(), "large").unwrap();
        for w in zoo::table1_suite() {
            let ctx = BoundsCtx::new(&w, &hw);
            let mut scratch = ScreenScratch::new();
            let s = Strategy::trivial(&w);
            let v = ctx.screen(&s, &mut scratch);
            let exact = costmodel::evaluate(&s, &w, &hw);
            assert!(v.energy_lb <= exact.energy, "{}", w.name);
            assert!(v.latency_lb <= exact.latency, "{}", w.name);
            assert!(v.edp_lb <= exact.edp, "{}", w.name);
            assert!(!v.capacity_infeasible,
                    "trivial is feasible everywhere");
        }
    }

    #[test]
    fn capacity_replica_matches_kernel_on_oversized_group() {
        let hw = load_config(&repo_root(), "large").unwrap();
        let w = zoo::vgg16();
        let ctx = BoundsCtx::new(&w, &hw);
        let mut scratch = ScreenScratch::new();
        let mut s = Strategy::trivial(&w);
        for d in 0..NDIMS {
            s.mappings[0].factors[d][SLOT_T2] =
                w.layers[0].dims[d] as u64;
            s.mappings[1].factors[d][SLOT_T2] =
                w.layers[1].dims[d] as u64;
        }
        s.fuse[0] = true;
        let v = ctx.screen(&s, &mut scratch);
        assert!(v.capacity_infeasible);
        assert!(costmodel::feasible(&s, &w, &hw).is_err());
    }

    #[test]
    fn fused_edges_lower_the_bound() {
        // fusion removes DRAM write-back + refill floors, so the bound
        // must drop when an edge fuses (mirroring the exact model)
        let hw = load_config(&repo_root(), "large").unwrap();
        let w = zoo::gpt3_6_7b();
        let ctx = BoundsCtx::new(&w, &hw);
        let mut scratch = ScreenScratch::new();
        let mut s = Strategy::trivial(&w);
        let cold = ctx.screen(&s, &mut scratch);
        s.fuse[0] = true;
        let fused = ctx.screen(&s, &mut scratch);
        assert!(fused.energy_lb < cold.energy_lb);
    }
}
